"""End-to-end driver for the paper's experiments: CLUSTER vs SSSP-BF on all
three benchmark graph families, with the stop/complete variants.

  PYTHONPATH=src python examples/diameter_pipeline.py [--scale 0.5] \
      [--backend single|sharded|pallas]

Each graph is opened ONCE into a resident ``GraphSession``; every row after
that is just another estimator query against the same device buffers — the
stop/complete variants and the SSSP competitor share one upload, and the
final column is the certified [lower, upper] bracket from the full panel.
Every backend produces the same decomposition for a fixed seed (see
docs/engine.md), so the estimate column is backend-independent.
"""
import argparse
import time

from repro.config.base import GraphEngineConfig
from repro.core import (
    CascadeEstimator,
    ClusterQuotientEstimator,
    DeltaSteppingEstimator,
    IntervalEstimator,
    open_session,
)
from repro.graph import grid_mesh, random_geometric, social_like

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=float, default=0.5)
ap.add_argument("--backend", default="single",
                choices=["single", "sharded", "pallas"])
args = ap.parse_args()

graphs = {
    "road-like": random_geometric(int(20_000 * args.scale), 3.0, seed=1),
    "social-like": social_like(12, 8, seed=2, weight_dist="uniform", high=2**26),
    "mesh-bimodal": grid_mesh(int(48 * max(args.scale, 0.3)), "bimodal",
                              heavy_w=10**6, heavy_p=0.1, seed=3),
}
print(f"{'graph':14s} {'algo':10s} {'estimate':>12s} {'rounds':>7s} {'sec':>6s}")
for name, g in graphs.items():
    with open_session(g, GraphEngineConfig(backend=args.backend)) as sess:
        for variant in ("stop", "complete"):
            t0 = time.time()
            est = sess.estimate(ClusterQuotientEstimator(variant=variant))
            print(f"{name:14s} CL-{variant:8s} {est.phi_approx:12d} "
                  f"{est.growing_steps:7d} {time.time()-t0:6.1f}")
        t0 = time.time()
        casc = sess.estimate(CascadeEstimator(levels=2, tau_solve=64))
        print(f"{name:14s} {'cascade-2':10s} {casc.phi_approx:12d} "
              f"{casc.growing_steps:7d} {time.time()-t0:6.1f}")
        t0 = time.time()
        sssp = sess.estimate(DeltaSteppingEstimator())
        print(f"{name:14s} {'SSSP-BF':10s} {sssp.phi_approx:12d} "
              f"{sssp.growing_steps:7d} {time.time()-t0:6.1f}")
        iv = sess.estimate(IntervalEstimator())
        print(f"{name:14s} {'interval':10s} "
              f"[{iv.lower}, {iv.upper}] connected={iv.connected} "
              f"({sess.metrics.queries} queries, "
              f"{sess.metrics.edge_uploads} upload)")
