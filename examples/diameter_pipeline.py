"""End-to-end driver for the paper's experiments: CLUSTER vs SSSP-BF on all
three benchmark graph families, with the stop/complete variants.

  PYTHONPATH=src python examples/diameter_pipeline.py [--scale 0.5] \
      [--backend single|sharded|pallas]

Every backend produces the same decomposition for a fixed seed (see
docs/engine.md), so the estimate column is backend-independent.
"""
import argparse
import time

from repro.config.base import GraphEngineConfig
from repro.core import approximate_diameter, diameter_2approx_sssp
from repro.graph import grid_mesh, random_geometric, social_like

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=float, default=0.5)
ap.add_argument("--backend", default="single",
                choices=["single", "sharded", "pallas"])
args = ap.parse_args()

graphs = {
    "road-like": random_geometric(int(20_000 * args.scale), 3.0, seed=1),
    "social-like": social_like(12, 8, seed=2, weight_dist="uniform", high=2**26),
    "mesh-bimodal": grid_mesh(int(48 * max(args.scale, 0.3)), "bimodal",
                              heavy_w=10**6, heavy_p=0.1, seed=3),
}
print(f"{'graph':14s} {'algo':10s} {'estimate':>12s} {'rounds':>7s} {'sec':>6s}")
for name, g in graphs.items():
    for variant in ("stop", "complete"):
        t0 = time.time()
        est = approximate_diameter(
            g, GraphEngineConfig(variant=variant, backend=args.backend))
        print(f"{name:14s} CL-{variant:8s} {est.phi_approx:12d} "
              f"{est.growing_steps:7d} {time.time()-t0:6.1f}")
    t0 = time.time()
    lb, ub, ss, _conn = diameter_2approx_sssp(g)
    print(f"{name:14s} {'SSSP-BF':10s} {ub:12d} {ss:7d} {time.time()-t0:6.1f}")
