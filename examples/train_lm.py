"""Train a ~100M-param LM for a few hundred steps with checkpoint/restart.

Architecture: gemma2-family block (alternating local/global attention,
softcaps) at ~110M params. Kill it mid-run and re-invoke -- it resumes from
the last checkpoint and replays the data stream from its cursor.

  PYTHONPATH=src python examples/train_lm.py            # 300 steps (~CPU hours)
  PYTHONPATH=src python examples/train_lm.py --steps 5  # quick sanity
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.config.base import ShapeSpec, TrainConfig, TransformerConfig
from repro.data.pipeline import DataCursor, LMTokenPipeline
from repro.models import transformer as T
from repro.optim import adamw
from repro.runtime.fault import PreemptionGuard, StragglerMonitor

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq-len", type=int, default=128)
ap.add_argument("--ckpt-dir", default="/tmp/repro_example_lm100m")
args = ap.parse_args()

cfg = TransformerConfig(
    name="gemma2-110m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=6,
    d_head=64, d_ff=2304, vocab_size=32000,
    sliding_window=64, local_global_alternating=True,
    attn_logit_softcap=50.0, final_logit_softcap=30.0, act="gelu",
    tie_embeddings=True, dtype="float32",
)
n_params = cfg.param_count()
print(f"model: {n_params/1e6:.0f}M params")
assert 80e6 < n_params < 150e6

tc = TrainConfig(lr=6e-4, warmup=20, checkpoint_dir=args.ckpt_dir)
shape = ShapeSpec(name="ex", kind="train", seq_len=args.seq_len,
                  global_batch=args.batch)
pipe = LMTokenPipeline(cfg, shape, seed=0)
params = T.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw.init_state(params)
cursor = DataCursor()

if ckpt.latest_step(args.ckpt_dir) is not None:
    like = {"params": params, "m": opt.m, "v": opt.v}
    restored, extra = ckpt.restore(args.ckpt_dir, like)
    params, opt = restored["params"], adamw.AdamWState(
        m=restored["m"], v=restored["v"],
        step=jnp.int32(extra.get("opt_step", 0)))
    cursor = DataCursor.from_dict(extra.get("cursor", {}))
    print(f"resumed from step {cursor.step}")


@jax.jit
def step_fn(p, o, b):
    loss, g = jax.value_and_grad(T.lm_loss)(p, b, cfg)
    p, o, stats = adamw.apply_updates(p, o, g, tc, total_steps=args.steps)
    return p, o, loss, stats


mon = StragglerMonitor()
with PreemptionGuard() as guard:
    while cursor.step < args.steps:
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(cursor).items()}
        t0 = time.time()
        params, opt, loss, stats = step_fn(params, opt, batch)
        jax.block_until_ready(loss)
        mon.record(cursor.step, time.time() - t0)
        cursor.step += 1
        if cursor.step % 10 == 0 or cursor.step <= 3:
            print(f"step {cursor.step:4d}  loss {float(loss):.4f}  "
                  f"({args.batch * args.seq_len / (time.time() - t0):.0f} tok/s)")
        if cursor.step % 50 == 0 or guard.should_stop:
            ckpt.save(args.ckpt_dir, cursor.step,
                      {"params": params, "m": opt.m, "v": opt.v},
                      extra={"cursor": cursor.as_dict(),
                             "opt_step": int(opt.step)})
        if guard.should_stop:
            print("preempted -- checkpointed, exiting")
            break
print("done")
