"""Serve a small model with batched requests (prefill + KV-cache decode).

  PYTHONPATH=src python examples/serve_decode.py
"""
import sys

sys.argv = [sys.argv[0], "--arch", "mixtral-8x7b", "--smoke",
            "--batch", "4", "--prompt-len", "24", "--gen", "12"]
from repro.launch.serve import main

raise SystemExit(main())
