"""Full-graph GNN training on a synthetic citation-style graph, using the
paper's decomposition as the locality-aware partitioner (the engine feature
reused as a systems tool).

  PYTHONPATH=src python examples/train_gnn.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ShapeSpec, TrainConfig
from repro.config.registry import get_arch
from repro.core import cluster
from repro.data.pipeline import gnn_full_graph_batch
from repro.graph.partition import apply_partition, cluster_partition, cut_fraction
from repro.graph.structures import EdgeList
from repro.models import gnn as gnn_mod
from repro.optim import adamw

cfg = get_arch("gcn-cora", smoke=True)
shape = ShapeSpec(name="d", kind="full_graph", n_nodes=1000, n_edges=5000,
                  d_feat=64)
g = gnn_full_graph_batch(cfg, shape, seed=0, n_classes=cfg.d_out)

# --- the paper's technique as a partitioner -------------------------------
el = EdgeList(shape.n_nodes, g["src"], g["dst"],
              np.ones(len(g["src"]), np.int32))
dec = cluster(el, tau=16, seed=0)
perm = cluster_partition(dec.final_c, n_devices=4)
el2, inv = apply_partition(el, perm)
print(f"edge-cut at 4 devices: naive {cut_fraction(el, 4):.3f} -> "
      f"cluster-partitioned {cut_fraction(el2, 4):.3f}")

graph = {k: jnp.asarray(v) for k, v in g.items()}
params = gnn_mod.init_gnn(cfg, shape.d_feat, jax.random.PRNGKey(0))
opt = adamw.init_state(params)
tc = TrainConfig(lr=5e-3, warmup=5)

@jax.jit
def step(p, o, gr):
    loss, grads = jax.value_and_grad(gnn_mod.node_classification_loss)(p, gr, cfg)
    p, o, _ = adamw.apply_updates(p, o, grads, tc)
    return p, o, loss

for i in range(60):
    params, opt, loss = step(params, opt, graph)
    if i % 10 == 0:
        print(f"step {i:3d}  loss {float(loss):.4f}")
print(f"final loss {float(loss):.4f}")
assert float(loss) < 1.5
