"""Quickstart: the paper's technique in 30 lines.

Builds a weighted graph, decomposes it with CLUSTER(G, tau), and estimates
the weighted diameter from the quotient graph — then checks against the
exact answer.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
from scipy.sparse.csgraph import shortest_path

from repro.config.base import GraphEngineConfig
from repro.core import cluster, open_session
from repro.graph import grid_mesh
from repro.graph.structures import to_scipy_csr

# A 64x64 mesh with bimodal weights (the paper's Delta-sensitivity topology)
g = grid_mesh(64, "bimodal", heavy_w=10**6, heavy_p=0.1, seed=0)
print(f"graph: {g.n_nodes} nodes, {g.n_edges} directed edges")

# the paper's decomposition: clusters of bounded weighted radius
dec = cluster(g, tau=32, variant="stop", seed=0)
print(f"CLUSTER: {dec.n_clusters} clusters, radius {dec.radius}, "
      f"{dec.growing_steps} Delta-growing steps ({dec.n_stages} stages)")

# diameter from the quotient graph (open the graph once, then query)
est = open_session(g, GraphEngineConfig()).estimate()
true_phi = int(shortest_path(to_scipy_csr(g), method="D", directed=False).max())
print(f"Phi_approx = {est.phi_approx}  vs true {true_phi}  "
      f"(ratio {est.phi_approx / true_phi:.3f}, conservative: "
      f"{est.phi_approx >= true_phi})")
