"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.cin.ops import cin_layer
from repro.kernels.edge_relax.ops import block_edges_host, edge_relax
from repro.kernels.flash_attention.ops import attention
from repro.kernels.segment_mm.ops import segment_mm

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# edge_relax
# ---------------------------------------------------------------------------

def _mk_relax_problem(n, e, wmax, covered_frac, live_frac, seed):
    r = np.random.default_rng(seed)
    src = r.integers(0, n, e).astype(np.int32)
    dst = r.integers(0, n, e).astype(np.int32)
    w = r.integers(1, wmax + 1, e).astype(np.int32)
    blk = block_edges_host(src, dst, w, n)
    n_pad = blk["n_pad_nodes"]
    INF, BIG = 2**31 - 1, 2**30
    d = np.full(n_pad, INF, np.int32)
    live = r.random(n_pad) < live_frac
    d[live] = r.integers(0, 2 * wmax, live.sum())
    c = np.full(n_pad, INF, np.int32); c[live] = r.integers(0, n, live.sum())
    p = np.full(n_pad, INF, np.int32); p[live] = d[live]
    rw0 = np.full(n_pad, BIG, np.int32)
    cov = (r.random(n_pad) < covered_frac) & ~live
    rw0[cov] = r.integers(-wmax, 1, cov.sum())
    rc = np.full(n_pad, INF, np.int32); rc[cov] = r.integers(0, n, cov.sum())
    rp = np.full(n_pad, INF, np.int32); rp[cov] = r.integers(0, 4 * wmax, cov.sum())
    planes = tuple(jnp.asarray(x) for x in (d, c, p, rw0, rc, rp))
    args = (planes, jnp.asarray(blk["src"]), jnp.asarray(blk["dst"]),
            jnp.asarray(blk["w"]), jnp.asarray(blk["mask"]),
            jnp.asarray(blk["block_tile"]), jnp.int32(wmax), blk["n_tiles"])
    return args


@pytest.mark.parametrize("n,e,wmax", [
    (100, 400, 16), (700, 3000, 100), (1500, 2000, 2**20), (63, 4000, 7),
])
def test_edge_relax_matches_ref(n, e, wmax):
    args = _mk_relax_problem(n, e, wmax, 0.2, 0.3, seed=n + e)
    ref = edge_relax(*args, impl="ref")
    pal = edge_relax(*args, impl="interpret")
    for name, r_, p_ in zip("dcp", ref, pal):
        m = min(len(r_), len(p_))
        np.testing.assert_array_equal(np.asarray(r_)[:m], np.asarray(p_)[:m],
                                      err_msg=f"plane {name}")


@settings(max_examples=10, deadline=None)
@given(n=st.integers(16, 400), e=st.integers(16, 1200),
       wmax=st.sampled_from([3, 50, 1 << 16]), seed=st.integers(0, 999))
def test_edge_relax_property(n, e, wmax, seed):
    args = _mk_relax_problem(n, e, wmax, 0.25, 0.25, seed)
    ref = edge_relax(*args, impl="ref")
    pal = edge_relax(*args, impl="interpret")
    m = min(len(ref[0]), len(pal[0]))
    for r_, p_ in zip(ref, pal):
        np.testing.assert_array_equal(np.asarray(r_)[:m], np.asarray(p_)[:m])


# ---------------------------------------------------------------------------
# flash attention sweep
# ---------------------------------------------------------------------------

CASES = [
    # B, Hq, Hkv, Sq, Skv, D, causal, window, softcap, dtype
    (2, 4, 2, 128, 128, 64, True, 0, 0.0, jnp.float32),
    (1, 8, 1, 64, 256, 32, True, 0, 0.0, jnp.float32),
    (2, 4, 4, 96, 96, 64, True, 32, 0.0, jnp.float32),
    (1, 2, 1, 64, 64, 128, True, 0, 50.0, jnp.float32),
    (1, 4, 2, 1, 192, 64, True, 0, 0.0, jnp.float32),    # decode shape
    (2, 4, 2, 64, 64, 64, False, 0, 0.0, jnp.float32),   # bidirectional
    (1, 4, 2, 128, 128, 64, True, 0, 0.0, jnp.bfloat16), # dtype sweep
]


@pytest.mark.parametrize("case", CASES)
def test_attention_impls_agree(case):
    B, Hq, Hkv, Sq, Skv, D, causal, window, softcap, dt = case
    r = np.random.default_rng(B * Sq + Skv)
    q = jnp.asarray(r.standard_normal((B, Hq, Sq, D)), dt)
    k = jnp.asarray(r.standard_normal((B, Hkv, Skv, D)), dt)
    v = jnp.asarray(r.standard_normal((B, Hkv, Skv, D)), dt)
    kw = dict(causal=causal, window=window, softcap=softcap)
    tol = 1e-5 if dt == jnp.float32 else 3e-2
    ref = attention(q, k, v, impl="ref", **kw).astype(jnp.float32)
    for impl in ("blocked", "blocked_ad", "interpret"):
        out = attention(q, k, v, impl=impl, bq=32, bk=64, **kw).astype(jnp.float32)
        err = float(jnp.abs(ref - out).max())
        assert err < tol, (impl, err)


def test_attention_mef_grads_match_autodiff():
    r = np.random.default_rng(0)
    q = jnp.asarray(r.standard_normal((1, 4, 64, 32)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 2, 64, 32)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, 2, 64, 32)), jnp.float32)

    def loss(impl):
        return lambda q, k, v: (
            attention(q, k, v, impl=impl, bq=16, bk=16, window=16) ** 2
        ).sum()

    g1 = jax.grad(loss("blocked"), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss("blocked_ad"), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# segment_mm sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,e,d", [(100, 500, 32), (600, 2500, 64),
                                   (50, 2000, 128), (257, 513, 16)])
def test_segment_mm_matches_ref(n, e, d):
    r = np.random.default_rng(n + d)
    src = r.integers(0, n, e).astype(np.int32)
    dst = r.integers(0, n, e).astype(np.int32)
    coeff = r.standard_normal(e).astype(np.float32)
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    ref = segment_mm(x, jnp.asarray(src), jnp.asarray(dst),
                     jnp.asarray(coeff), n, impl="ref")
    pal = segment_mm(x, src, dst, coeff, n, impl="interpret")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# CIN sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,m,D,H,H2", [
    (4, 13, 16, 24, 20), (8, 39, 10, 200, 100), (2, 6, 128, 12, 8),
])
def test_cin_matches_ref(B, m, D, H, H2):
    r = np.random.default_rng(B + H)
    x0 = jnp.asarray(r.standard_normal((B, m, D)).astype(np.float32))
    xk = jnp.asarray(r.standard_normal((B, H, D)).astype(np.float32))
    w = jnp.asarray(r.standard_normal((H2, H, m)).astype(np.float32))
    ref = cin_layer(x0, xk, w, impl="ref")
    pal = cin_layer(x0, xk, w, impl="interpret")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=2e-4, atol=2e-4)
