"""Correctness of the paper's algorithms: CLUSTER/CLUSTER2 invariants,
quotient conservativeness, SSSP oracles, hypothesis property tests."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    approximate_diameter,
    bellman_ford,
    build_quotient,
    cluster,
    cluster2,
    delta_stepping,
    diameter_2approx_sssp,
    quotient_diameter,
)
from repro.core.quotient import quotient_diameter_minplus
from repro.graph import grid_mesh, random_connected, road_like, social_like
from repro.graph.structures import EdgeList, to_scipy_csr


def _true_sssp(edges, source):
    from scipy.sparse.csgraph import dijkstra
    return dijkstra(to_scipy_csr(edges), directed=False, indices=source)


def _true_diameter(edges):
    from scipy.sparse.csgraph import shortest_path
    d = shortest_path(to_scipy_csr(edges), method="D", directed=False)
    fin = d[np.isfinite(d)]
    return int(fin.max())


# ---------------------------------------------------------------------------
# SSSP baselines vs scipy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gen,kw", [
    (grid_mesh, dict(side=12, weight_dist="uniform", high=50)),
    (random_connected, dict(n=300, n_edges=900, weight_dist="uniform", high=1000)),
])
def test_bellman_ford_matches_dijkstra(gen, kw):
    g = gen(**kw, seed=3)
    res = bellman_ford(g, 0)
    truth = _true_sssp(g, 0)
    finite = np.isfinite(truth)
    np.testing.assert_array_equal(res.dist[finite], truth[finite].astype(np.int64))


def test_delta_stepping_matches_bellman_ford():
    g = random_connected(200, 800, seed=5, weight_dist="uniform", high=100)
    bf = bellman_ford(g, 7)
    ds = delta_stepping(g, 7, delta=50)
    np.testing.assert_array_equal(bf.dist, ds.dist)


def test_delta_stepping_skips_empty_buckets():
    """Regression: the bucket loop used to crawl b+1 through every EMPTY
    bucket (>= 2 supersteps each). On a sparse-weight path graph (weights
    1000, delta 10 -> ~100 empty buckets per hop) the jump to the next
    non-empty bucket must keep supersteps proportional to the number of
    OCCUPIED buckets, not to max_dist / delta."""
    n = 50
    u = np.arange(n - 1, dtype=np.int32)
    g = EdgeList.from_undirected(n, u, u + 1, np.full(n - 1, 1000, np.int32))
    bf = bellman_ford(g, 0)
    ds = delta_stepping(g, 0, delta=10)
    np.testing.assert_array_equal(bf.dist, ds.dist)
    # 49 occupied buckets; the old crawl needed ~2 * 49 * 100 supersteps
    assert ds.supersteps <= 4 * n, ds.supersteps


def test_multi_source_bf_matches_dijkstra_and_survives_max_weights():
    from repro.core import multi_source_bellman_ford

    g = random_connected(150, 500, seed=8, weight_dist="uniform", high=1000)
    res = multi_source_bellman_ford(g, [0, 7, 42])
    assert res.connected
    for i, s in enumerate([0, 7, 42]):
        truth = _true_sssp(g, s)
        np.testing.assert_array_equal(res.dist[i], truth.astype(np.int64))
    # regression: maximum legal edge weight (2^30 - 1) overflows int32 after
    # a couple of hops — the solve must escalate to int64, not wrap negative
    n = 6
    u = np.arange(n - 1, dtype=np.int32)
    gp = EdgeList.from_undirected(n, u, u + 1,
                                  np.full(n - 1, 2**30 - 1, np.int32))
    r = multi_source_bellman_ford(gp, [0])
    assert (r.dist >= 0).all()
    assert int(r.dist[0][-1]) == 5 * (2**30 - 1)


def test_single_source_loops_survive_max_weights():
    """Regression: _bf_loop/_delta_stepping_loop guarded only ``ds < INF``,
    so distances past 2^31 wrapped negative and became false minima. With
    weights near 2^30 a few hops overflow int32 — both loops must escalate
    to int64 (same provable bound as multi_source_bellman_ford) and return
    the exact path sums."""
    n = 6
    u = np.arange(n - 1, dtype=np.int32)
    w = np.full(n - 1, 2**30 - 1, np.int32)
    g = EdgeList.from_undirected(n, u, u + 1, w)
    expect = np.arange(n, dtype=np.int64) * (2**30 - 1)
    bf = bellman_ford(g, 0)
    assert (bf.dist >= 0).all()
    np.testing.assert_array_equal(bf.dist, expect)
    ds = delta_stepping(g, 0, delta=2**20)
    assert (ds.dist >= 0).all()
    np.testing.assert_array_equal(ds.dist, expect)
    # bucket-bound headroom: distances fit int32 here (n*wmax ~ 2.1e9 is
    # past 2^31 so this graph goes int64 anyway) — but even when distances
    # alone fit, (b+1)*delta can exceed 2^31 for a large delta; the dtype
    # pick must account for the delta headroom or the bucket walk stalls
    g3 = EdgeList.from_undirected(3, np.arange(2, dtype=np.int32),
                                  np.arange(1, 3, dtype=np.int32),
                                  np.full(2, 700_000_000, np.int32))
    ds3 = delta_stepping(g3, 0, delta=1_100_000_000)
    np.testing.assert_array_equal(
        ds3.dist, np.arange(3, dtype=np.int64) * 700_000_000)
    assert ds3.supersteps < 100, ds3.supersteps
    # the 2-approx bounds derived from these loops stay sound
    lb, ub, _, connected = diameter_2approx_sssp(g, seed=0)
    assert connected
    assert lb <= 5 * (2**30 - 1) <= ub
    from repro.core import farthest_point_lower_bound
    lb2, conn2 = farthest_point_lower_bound(g, rounds=2, seed=0)
    assert conn2 and 0 < lb2 <= 5 * (2**30 - 1)


def test_sssp_estimators_empty_graph():
    """Regression: rng.integers(0) raised ValueError — the empty graph gets
    the degenerate estimate of the DiameterEstimate.connected contract
    (diameter 0, connected True for n_nodes <= 1)."""
    from repro.core import farthest_point_lower_bound

    g = EdgeList(0, *(np.array([], np.int32),) * 3)
    assert diameter_2approx_sssp(g, seed=3) == (0, 0, 0, True)
    assert farthest_point_lower_bound(g, rounds=3, seed=3) == (0, True)
    # single node keeps working through the same path (one no-op superstep)
    g1 = EdgeList(1, *(np.array([], np.int32),) * 3)
    lb1, ub1, steps1, conn1 = diameter_2approx_sssp(g1)
    assert (lb1, ub1, conn1) == (0, 0, True) and steps1 <= 1


def _host_delta_stepping(edges: EdgeList, source: int, delta: int):
    """Host-loop oracle mirroring _delta_stepping_loop's structure and its
    superstep accounting: one superstep per inner light iteration (incl.
    the final no-change one) + one per heavy pass WITH an admissible heavy
    relaxation; empty buckets are jumped."""
    n, src, dst, w = (edges.n_nodes, edges.src.astype(np.int64),
                      edges.dst.astype(np.int64),
                      edges.weight.astype(np.int64))
    inf = np.int64(2**62)
    d = np.full(n, inf)
    d[source] = 0
    light = w < delta

    def relax(mask):
        ds = d[src]
        ok = (ds < inf) & mask
        dmin = np.full(n, inf)
        np.minimum.at(dmin, dst[ok], ds[ok] + w[ok])
        return dmin, ok

    b, k = 0, 0
    while ((d < inf) & (d >= b * delta)).any():
        lo, hi = b * delta, (b + 1) * delta
        changed = True
        while changed:
            in_bucket = (d >= lo) & (d < hi)
            dmin, _ = relax(in_bucket[src] & light)
            upd = dmin < d
            d = np.where(upd, dmin, d)
            changed = bool(upd.any())
            k += 1
        in_bucket = (d >= lo) & (d < hi)
        dmin, ok = relax(in_bucket[src] & ~light)
        d = np.where(dmin < d, dmin, d)
        k += int(ok.any())
        ahead = (d >= hi) & (d < inf)
        b = int(d[ahead].min()) // delta if ahead.any() else b + 1
    return d, k


@pytest.mark.parametrize("gen,kw,delta", [
    # all-light weights: every heavy pass is empty — the old accounting
    # charged one superstep per settled bucket anyway
    (random_connected, dict(n=150, n_edges=500, weight_dist="uniform",
                            high=40), 50),
    # mixed light/heavy
    (random_connected, dict(n=150, n_edges=500, weight_dist="uniform",
                            high=300), 64),
    (grid_mesh, dict(side=10, weight_dist="uniform", high=100), 30),
])
def test_delta_stepping_supersteps_match_host_oracle(gen, kw, delta):
    """Regression: outer_body counted the heavy pass even when the settled
    bucket had no admissible heavy relaxation, inflating the competitor's
    reported rounds in the Table-3 comparison."""
    g = gen(**kw, seed=6)
    res = delta_stepping(g, 0, delta=delta)
    d_host, k_host = _host_delta_stepping(g, 0, delta)
    fin = d_host < 2**62
    np.testing.assert_array_equal(res.dist[fin], d_host[fin])
    assert res.supersteps == k_host, (res.supersteps, k_host)


def test_sssp_2approx_bounds():
    g = grid_mesh(10, "unit")
    lb, ub, _, connected = diameter_2approx_sssp(g)
    true = _true_diameter(g)
    assert lb <= true <= ub
    assert connected


def test_sssp_estimators_flag_disconnected():
    """diameter_2approx_sssp / farthest_point_lower_bound only bound
    finite-distance pairs on disconnected inputs — they must say so."""
    from repro.core import farthest_point_lower_bound

    u = np.array([0, 1, 2, 3, 4, 5], np.int32)
    v = np.array([1, 2, 0, 4, 5, 3], np.int32)
    g = EdgeList.from_undirected(6, u, v, np.ones(6, np.int32))
    lb, ub, _, connected = diameter_2approx_sssp(g, seed=0)
    assert not connected
    assert lb >= 1  # still bounds the source's component
    lb2, connected2 = farthest_point_lower_bound(g, rounds=3, seed=0)
    assert not connected2
    assert lb2 >= 1
    g_conn = grid_mesh(6, "unit")
    assert farthest_point_lower_bound(g_conn, rounds=3)[1]


# ---------------------------------------------------------------------------
# CLUSTER invariants (paper Lemma 1 / Theorem 1 structure)
# ---------------------------------------------------------------------------

def _check_decomposition(g: EdgeList, dec, tau):
    n = g.n_nodes
    # partition: every node assigned, centers self-assigned
    assert dec.final_c.shape == (n,)
    assert (dec.final_c >= 0).all() and (dec.final_c < n).all()
    centers = np.unique(dec.final_c)
    assert (dec.final_c[centers] == centers).all(), "center must own itself"
    # radius = max dist upper bound; per-node pathw upper-bounds true dist
    assert dec.radius == dec.final_pathw.max()
    # pathw is an upper bound on the true distance to the center
    from scipy.sparse.csgraph import dijkstra
    csr = to_scipy_csr(g)
    some = np.random.default_rng(0).choice(centers, size=min(5, len(centers)),
                                           replace=False)
    d_true = dijkstra(csr, directed=False, indices=some)
    for i, c in enumerate(some):
        mine = dec.final_c == c
        assert (dec.final_pathw[mine] >= d_true[i][mine] - 1e-6).all()


@pytest.mark.parametrize("variant", ["stop", "complete"])
def test_cluster_partition_invariants(variant):
    g = social_like(9, 6, seed=2, weight_dist="uniform", high=2**16)
    tau = 8
    dec = cluster(g, tau, variant=variant, seed=4)
    _check_decomposition(g, dec, tau)


def test_cluster2_partition_invariants():
    g = grid_mesh(20, "uniform", high=100, seed=6)
    dec = cluster2(g, 8, seed=1)
    _check_decomposition(g, dec, 8)


def test_semantic_contraction_equals_restart():
    """Optimization (2) (continue clustering across Delta doublings through
    relay edges) must keep radii bounded by delta_end * stages — and coverage
    must be a superset of what one fresh PartialGrowth at delta_end reaches."""
    g = grid_mesh(24, "bimodal", heavy_w=500, heavy_p=0.15, seed=9)
    dec = cluster(g, 12, seed=3)
    # every covered node's realized path weight is consistent: <= stages * delta_end
    assert dec.final_pathw.max() <= dec.n_stages * dec.delta_end + 1


# ---------------------------------------------------------------------------
# Diameter approximation (paper Theorem 2: conservative, ratio small)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gen,kw,tau", [
    (grid_mesh, dict(side=24, weight_dist="uniform", high=100), 16),
    (grid_mesh, dict(side=24, weight_dist="bimodal", heavy_w=10_000), 16),
    (social_like, dict(n_log2=9, edge_factor=8, weight_dist="uniform", high=2**20), 8),
    (road_like, dict(n=2000), 12),
])
def test_diameter_conservative_and_tight(gen, kw, tau):
    g = gen(**kw, seed=11)
    est = approximate_diameter(g, tau=tau)
    true = _true_diameter(g)
    assert est.phi_approx >= true, "estimate must be conservative"
    assert est.phi_approx <= 3.0 * true, (
        f"ratio {est.phi_approx / true:.2f} way beyond the paper's <=1.5 band"
    )


def test_quotient_minplus_matches_scipy():
    g = social_like(8, 6, seed=13, weight_dist="uniform", high=1000)
    dec = cluster(g, 6, seed=0)
    q = build_quotient(g, dec)
    d1, connected = quotient_diameter(q)
    d2, connected2 = quotient_diameter_minplus(q)
    assert connected and connected2
    assert d1 == d2


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(20, 120),
    ef=st.integers(2, 5),
    tau=st.integers(2, 10),
    seed=st.integers(0, 10_000),
    wmax=st.sampled_from([1, 10, 1000, 2**20]),
)
def test_property_decomposition(n, ef, tau, seed, wmax):
    g = random_connected(n, n * ef, seed=seed, weight_dist="uniform", high=wmax)
    dec = cluster(g, tau, seed=seed)
    # partition covers all nodes; radius consistent; steps bounded by paper's
    # O(min(n/tau, l) log n) with a generous constant
    assert len(dec.final_c) == g.n_nodes
    centers = np.unique(dec.final_c)
    assert (dec.final_c[centers] == centers).all()
    logn = math.log2(max(n, 2))
    assert dec.growing_steps <= 4 * (2 * n / tau) * (logn + 1) + 64


@settings(max_examples=10, deadline=None)
@given(
    side=st.integers(4, 12),
    seed=st.integers(0, 10_000),
    heavy_p=st.floats(0.0, 0.3),
)
def test_property_diameter_conservative(side, seed, heavy_p):
    g = grid_mesh(side, "bimodal", heavy_w=997, heavy_p=heavy_p, seed=seed)
    est = approximate_diameter(g, tau=4)
    assert est.phi_approx >= _true_diameter(g)


# ---------------------------------------------------------------------------
# degenerate inputs: empty / single-node / edgeless / disconnected
# ---------------------------------------------------------------------------

def _edgeless(n):
    z = np.array([], dtype=np.int32)
    return EdgeList(n, z, z, z)


def test_empty_graph():
    est = approximate_diameter(_edgeless(0), tau=4)
    assert est.phi_approx == 0 and est.radius == 0
    dec = cluster(_edgeless(0), 4)
    assert dec.n_nodes == 0 and dec.n_clusters == 0


def test_single_node_graph():
    est = approximate_diameter(_edgeless(1), tau=4)
    assert est.phi_approx == 0 and est.connected
    dec = cluster(_edgeless(1), 4)
    assert dec.n_clusters == 1 and dec.radius == 0


def test_edgeless_nodes_become_singletons():
    dec = cluster(_edgeless(7), 2)
    assert (dec.final_c == np.arange(7)).all()
    assert (dec.final_pathw == 0).all()
    est = approximate_diameter(_edgeless(7), tau=2)
    assert not est.connected  # 7 isolated nodes: diameter is infinite


def test_disconnected_graph_flagged():
    # two disjoint triangles
    u = np.array([0, 1, 2, 3, 4, 5], np.int32)
    v = np.array([1, 2, 0, 4, 5, 3], np.int32)
    g = EdgeList.from_undirected(6, u, v, np.ones(6, np.int32))
    est = approximate_diameter(g, tau=2)
    assert not est.connected
    # the estimate still upper-bounds the largest FINITE distance (1 here)
    assert est.phi_approx >= 1
    dec = cluster2(g, 2, seed=0)
    assert len(np.unique(dec.final_c)) == dec.n_clusters


def test_resample_cap_bounds_stage_loop():
    """With a vanishing sampling probability the seed's resample path looped
    forever without consuming max_stages; now barren draws are capped and
    count against the stage budget."""
    g = grid_mesh(16, "uniform", high=10, seed=0)
    dec = cluster(g, 4, gamma=1e-12, seed=0, max_stages=3, threshold_const=0.01)
    assert dec.metrics.stages <= 3
    assert dec.metrics.resamples > 0
    # nothing was ever sampled -> everyone is a singleton, still a partition
    assert (dec.final_c == np.arange(g.n_nodes)).all()
