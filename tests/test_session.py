"""GraphSession / DiameterEstimator API: back-compat field-identity of the
deprecated wrappers, the warm-query residency contract (SessionMetrics),
PipelineMetrics aggregation, the estimator bound contract
(lower <= exact <= upper with a consistent ``connected`` flag), and the
certified IntervalEstimator bracket."""
import dataclasses
import warnings

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    ClusterQuotientEstimator,
    DeltaSteppingEstimator,
    DiameterEstimator,
    IntervalEstimator,
    LowerBoundEstimator,
    PipelineMetrics,
    SessionPool,
    approximate_diameter,
    approximate_diameter_batch,
    diameter_2approx_sssp,
    farthest_point_lower_bound,
    open_session,
)
from repro.graph import grid_mesh, random_connected, random_geometric
from repro.graph.structures import EdgeList, to_scipy_csr


def _true_diameter(edges):
    from scipy.sparse.csgraph import shortest_path
    d = shortest_path(to_scipy_csr(edges), method="D", directed=False)
    fin = d[np.isfinite(d)]
    return int(fin.max()) if len(fin) else 0


def _edgeless(n):
    z = np.array([], dtype=np.int32)
    return EdgeList(n, z, z, z)


def _two_triangles():
    u = np.array([0, 1, 2, 3, 4, 5], np.int32)
    v = np.array([1, 2, 0, 4, 5, 3], np.int32)
    return EdgeList.from_undirected(6, u, v, np.ones(6, np.int32))


def _assert_estimates_identical(a, b, ignore=("seconds",)):
    """Field-for-field identity of two DiameterEstimates (wall time aside)."""
    for f in dataclasses.fields(a):
        if f.name in ignore:
            continue
        x, y = getattr(a, f.name), getattr(b, f.name)
        if isinstance(x, np.ndarray) or isinstance(y, np.ndarray):
            np.testing.assert_array_equal(x, y, err_msg=f.name)
        else:
            assert x == y, (f.name, x, y)


# ---------------------------------------------------------------------------
# deprecated wrappers: delegate to sessions, warn, and stay field-identical
# ---------------------------------------------------------------------------

def test_wrapper_emits_deprecation_and_matches_session_path():
    g = random_geometric(800, avg_degree=3.0, seed=5)
    with pytest.deprecated_call():
        old = approximate_diameter(g, tau=8)
    new = open_session(g, tau=8).estimate(ClusterQuotientEstimator())
    _assert_estimates_identical(old, new)


def test_batch_wrapper_matches_pool_path():
    graphs = [random_geometric(500, avg_degree=3.0, seed=s) for s in range(3)]
    graphs.append(grid_mesh(10, "uniform", high=50, seed=1))  # second bucket
    with pytest.deprecated_call():
        old = approximate_diameter_batch(graphs, tau=6)
    new = SessionPool().estimate_many(graphs, tau=6)
    for a, b in zip(old, new):
        _assert_estimates_identical(a, b)


def test_wrapper_scipy_solver_still_works():
    g = grid_mesh(12, "uniform", high=100, seed=2)
    with pytest.deprecated_call():
        dev = approximate_diameter(g, tau=6)
    with pytest.deprecated_call():
        ora = approximate_diameter(g, tau=6, solver="scipy")
    assert dev.phi_approx == ora.phi_approx
    assert dev.connected == ora.connected


# ---------------------------------------------------------------------------
# estimators match the legacy free functions on the same seed
# ---------------------------------------------------------------------------

def test_delta_stepping_estimator_matches_legacy_numbers():
    g = random_geometric(700, avg_degree=3.0, seed=3)
    sess = open_session(g)
    est = sess.estimate(DeltaSteppingEstimator(seed=7))
    lb, ub, supersteps, connected = diameter_2approx_sssp(g, seed=7)
    assert (est.lower, est.upper, est.growing_steps, est.connected) == \
        (lb, ub, supersteps, connected)
    assert est.phi_approx == ub


def test_lower_bound_estimator_matches_legacy_numbers():
    g = random_geometric(700, avg_degree=3.0, seed=4)
    sess = open_session(g)
    est = sess.estimate(LowerBoundEstimator(rounds=4, seed=0))
    lb, connected = farthest_point_lower_bound(g, rounds=4, seed=0)
    assert (est.lower, est.connected) == (lb, connected)
    # the first hop is the 2-approx SSSP (same source draw for seed=0), so
    # its upper bound rides along for free
    _, ub, _, _ = diameter_2approx_sssp(g, seed=0)
    assert est.upper == ub


def test_estimators_satisfy_protocol():
    for e in (ClusterQuotientEstimator(), DeltaSteppingEstimator(),
              LowerBoundEstimator(), IntervalEstimator()):
        assert isinstance(e, DiameterEstimator)


# ---------------------------------------------------------------------------
# residency contract: warm queries build/upload nothing
# ---------------------------------------------------------------------------

def test_warm_queries_zero_rebuilds_zero_reuploads():
    g = random_geometric(600, avg_degree=3.0, seed=6)
    sess = open_session(g)
    assert sess.metrics.backend_builds == 1
    assert sess.metrics.edge_uploads == 1
    for _ in range(3):
        sess.estimate(ClusterQuotientEstimator())
    sess.estimate(DeltaSteppingEstimator())  # single backend: reuses buffers
    m = sess.metrics
    assert m.backend_builds == 1, "warm queries must not rebuild the backend"
    assert m.edge_uploads == 1, "warm queries must not re-upload edges"
    assert m.queries == 4
    assert m.warm_queries == 4


def test_pool_shares_bucket_and_matches_unpooled():
    graphs = [random_geometric(400, avg_degree=3.0, seed=s) for s in range(3)]
    pool = SessionPool()
    sessions = [pool.open(g, tau=8) for g in graphs]
    # one bucket: every session's padded edge arrays share a compiled shape
    assert len({s.n_edges for s in sessions}) == 1
    for g, sess in zip(graphs, sessions):
        pooled = sess.estimate(ClusterQuotientEstimator())
        solo = open_session(g, tau=8).estimate(ClusterQuotientEstimator())
        assert pooled.phi_approx == solo.phi_approx
        assert pooled.n_clusters == solo.n_clusters
        assert pooled.connected == solo.connected
    assert pool.metrics.backend_builds == len(graphs)


def test_pooled_delta_init_override_matches_unpooled():
    """Regression: a per-query delta_init="avg" override on a POOLED session
    must resolve over the real edges, not the padding self-loops (w=1),
    which would drag the average down and change the decomposition."""
    g = grid_mesh(8, "uniform", high=2000, seed=4)  # few edges, heavy avg
    pooled = SessionPool().open(g, tau=4)
    assert pooled.n_edges > g.n_edges  # padding actually happened
    est_pool = pooled.estimate(ClusterQuotientEstimator(delta_init="avg"))
    est_solo = open_session(g, tau=4).estimate(
        ClusterQuotientEstimator(delta_init="avg"))
    _assert_estimates_identical(est_pool, est_solo)


def test_pool_empty_graph_gets_no_phantom_node():
    """Regression: _pad_edges padded with 0 -> 0 self-loops even when
    n_nodes == 0, materializing a phantom node (edges pointing at node 0 of
    a 0-node graph) in pooled sessions."""
    pool = SessionPool()
    sess = pool.open(_edgeless(0))
    assert sess.n_nodes == 0
    assert sess.n_edges == 0, "empty graph must stay unpadded"
    est = sess.estimate(ClusterQuotientEstimator())
    assert est.phi_approx == 0 and est.connected
    # batch path: an empty graph among real ones keeps its degenerate
    # estimate and the real graphs their unpooled numbers
    graphs = [_edgeless(0), grid_mesh(6, "unit"), _edgeless(3)]
    ests = pool.estimate_many(graphs, tau=4)
    assert ests[0].phi_approx == 0 and ests[0].connected
    solo = open_session(graphs[1], tau=4).estimate(ClusterQuotientEstimator())
    assert ests[1].phi_approx == solo.phi_approx
    assert not ests[2].connected  # 3 isolated nodes


def test_sssp_estimators_survive_max_weights_on_session():
    """Regression: the estimator SSSP path used int32-only loops — on a
    heavy-weight path graph distances wrap negative and the reported
    bounds collapse. The bounds must bracket the true diameter."""
    n = 6
    u = np.arange(n - 1, dtype=np.int32)
    g = EdgeList.from_undirected(n, u, u + 1,
                                 np.full(n - 1, 2**30 - 1, np.int32))
    true = 5 * (2**30 - 1)
    sess = open_session(g, tau=2)
    ds = sess.estimate(DeltaSteppingEstimator(seed=0))
    assert ds.connected
    assert 0 < ds.lower <= true <= ds.upper
    lo = sess.estimate(LowerBoundEstimator(rounds=3, seed=0))
    assert lo.lower == true  # farthest-point hop realizes the full path
    ds2 = sess.estimate(DeltaSteppingEstimator(seed=0, delta=2**20))
    assert 0 < ds2.lower <= true <= ds2.upper


def test_delta_stepping_rejects_nonpositive_delta():
    sess = open_session(grid_mesh(4, "unit"))
    with pytest.raises(ValueError, match="delta"):
        sess.estimate(DeltaSteppingEstimator(delta=0))


def test_closed_session_rejects_queries():
    sess = open_session(grid_mesh(4, "unit"))
    sess.close()
    sess.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        sess.estimate(ClusterQuotientEstimator())


def test_pool_close_idempotent_and_pooled_sessions_reject_use():
    """Regression: SessionPool.close() must be idempotent, a closed pool
    must refuse to open new sessions or batch-estimate (instead of quietly
    resurrecting state), and previously pooled sessions must raise a clean
    RuntimeError via _check_open() on ANY use after pool close."""
    g = grid_mesh(4, "unit")
    pool = SessionPool()
    sess = pool.open(g, tau=2)
    sess.estimate(ClusterQuotientEstimator())
    pool.close()
    pool.close()  # idempotent: second close is a no-op
    assert pool.sessions == []
    with pytest.raises(RuntimeError, match="closed"):
        sess.estimate(ClusterQuotientEstimator())
    with pytest.raises(RuntimeError, match="closed"):
        sess.flat_device_edges()
    with pytest.raises(RuntimeError, match="closed"):
        _ = sess.max_weight
    with pytest.raises(RuntimeError, match="closed"):
        pool.open(g)
    with pytest.raises(RuntimeError, match="closed"):
        pool.estimate_many([g])
    # the context-manager path closes the same way
    with SessionPool() as pool2:
        s2 = pool2.open(g, tau=2)
    with pytest.raises(RuntimeError, match="closed"):
        s2.estimate(ClusterQuotientEstimator())


def test_tau_validation():
    g = grid_mesh(4, "unit")
    with pytest.raises(ValueError, match="tau"):
        open_session(g, tau=0)
    with pytest.raises(ValueError, match="tau"):
        open_session(g).estimate(ClusterQuotientEstimator(tau=-3))
    with pytest.raises(ValueError, match="tau"):
        SessionPool().estimate_many([g], tau=0)
    assert open_session(g, tau=1).tau == 1  # explicit small tau is accepted


# ---------------------------------------------------------------------------
# PipelineMetrics aggregation
# ---------------------------------------------------------------------------

def test_pipeline_metrics_add_and_merge():
    a = PipelineMetrics(decompose_syncs=2, finalize_syncs=1, quotient_syncs=1,
                        solve_syncs=1, solve_supersteps=10, n_quotient_edges=5)
    b = PipelineMetrics(decompose_syncs=3, solve_syncs=2, solve_supersteps=4)
    c = a + b
    assert c.decompose_syncs == 5 and c.solve_syncs == 3
    assert c.solve_supersteps == 14 and c.n_quotient_edges == 5
    assert c.total_host_syncs == a.total_host_syncs + b.total_host_syncs
    assert sum([a, b]) == c                       # __radd__ with int 0 start
    assert PipelineMetrics.merge([a, None, b]) == c


def test_interval_multi_instance_panel_keeps_every_result():
    """Regression: two estimators of the same class in one panel (e.g. a
    multi-seed lower-bound sweep) must both contribute — the results dict
    used to key on the shared class name and drop all but the last."""
    g = grid_mesh(12, "uniform", high=100, seed=5)
    sess = open_session(g, tau=6)
    iv = sess.estimate(IntervalEstimator(estimators=(
        LowerBoundEstimator(rounds=2, seed=0),
        LowerBoundEstimator(rounds=2, seed=3),
        ClusterQuotientEstimator())))
    assert set(iv.estimates) == {
        "farthest-point", "farthest-point#2", "cluster-quotient"}
    assert iv.lower == max(r.lower for r in iv.estimates.values()
                           if r.lower is not None)


def test_interval_reports_merged_pipeline_totals():
    g = grid_mesh(14, "uniform", high=100, seed=3)
    sess = open_session(g, tau=6)
    iv = sess.estimate(IntervalEstimator())
    assert iv.pipeline.total_host_syncs == sum(
        r.pipeline.total_host_syncs for r in iv.estimates.values())
    assert iv.pipeline.total_host_syncs > \
        iv.estimates["cluster-quotient"].pipeline.total_host_syncs


# ---------------------------------------------------------------------------
# estimator bound contract: lower <= exact <= upper, consistent `connected`
# ---------------------------------------------------------------------------

def _contract(g, tau=4):
    """Run all three estimators on one session; return (results, interval)."""
    sess = open_session(g, tau=tau)
    lo = sess.estimate(LowerBoundEstimator(rounds=3, seed=0))
    up = sess.estimate(ClusterQuotientEstimator())
    ds = sess.estimate(DeltaSteppingEstimator(seed=0))
    iv = sess.estimate(IntervalEstimator(estimators=(
        LowerBoundEstimator(rounds=3, seed=0), ClusterQuotientEstimator(),
        DeltaSteppingEstimator(seed=0))))
    return (lo, up, ds), iv


@pytest.mark.parametrize("gen,kw", [
    (grid_mesh, dict(side=10, weight_dist="uniform", high=100)),
    (random_connected, dict(n=200, n_edges=700, weight_dist="uniform",
                            high=2**20)),
])
def test_estimator_bound_contract_connected(gen, kw):
    g = gen(**kw, seed=8)
    exact = _true_diameter(g)
    (lo, up, ds), iv = _contract(g)
    assert lo.lower <= exact <= up.upper
    assert ds.lower <= exact <= ds.upper
    assert lo.connected and up.connected and ds.connected and iv.connected
    assert iv.lower <= exact <= iv.upper
    assert iv.lower == max(lo.lower, ds.lower)
    assert iv.upper == min(up.upper, ds.upper)


def test_estimator_contract_single_node_and_disconnected():
    # single node: diameter 0, everyone agrees it is connected
    (lo, up, ds), iv = _contract(_edgeless(1))
    assert (lo.connected, up.connected, ds.connected, iv.connected) == \
        (True,) * 4
    assert iv.lower == iv.upper == 0
    # disconnected (two triangles): every estimator must flag it, and the
    # bracket still certifies the largest finite-distance pair
    g = _two_triangles()
    (lo, up, ds), iv = _contract(g, tau=2)
    assert (lo.connected, up.connected, ds.connected, iv.connected) == \
        (False,) * 4
    assert 1 <= iv.lower <= iv.upper
    # isolated nodes: disconnected as well
    (lo, up, ds), iv = _contract(_edgeless(5), tau=2)
    assert (lo.connected, up.connected, ds.connected, iv.connected) == \
        (False,) * 4


def test_interval_bracket_certified_across_components():
    """Regression: on a disconnected graph, 2*ecc from an SSSP source in a
    SMALL component is no upper bound on the largest finite-distance pair —
    a lower-bound hop landing in a BIGGER component must not invert the
    bracket. The SSSP upper is dropped when disconnected; the cluster upper
    (which does cover all components) carries the bracket."""
    # component {0,1}: one heavy edge (1000); component {2,3,4}: unit triangle
    u = np.array([0, 2, 3, 4], np.int32)
    v = np.array([1, 3, 4, 2], np.int32)
    w = np.array([1000, 1, 1, 1], np.int32)
    g = EdgeList.from_undirected(5, u, v, w)
    sess = open_session(g, tau=2)
    ds = sess.estimate(DeltaSteppingEstimator(seed=0))    # source in triangle
    assert not ds.connected and ds.upper is None and ds.lower == 1
    iv = sess.estimate(IntervalEstimator(estimators=(
        LowerBoundEstimator(rounds=2, seed=11),           # source on heavy edge
        DeltaSteppingEstimator(seed=0),
        ClusterQuotientEstimator())))
    assert not iv.connected
    assert iv.lower == 1000                               # realized heavy path
    assert iv.lower <= iv.upper                           # bracket still sound


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(16, 80),
    ef=st.integers(2, 5),
    seed=st.integers(0, 10_000),
    wmax=st.sampled_from([1, 10, 1000, 2**20]),
)
def test_property_estimator_bracket(n, ef, seed, wmax):
    """LowerBoundEstimator <= scipy exact diameter <= ClusterQuotient upper,
    with a consistent connected flag, on random connected graphs."""
    g = random_connected(n, n * ef, seed=seed, weight_dist="uniform",
                         high=wmax)
    exact = _true_diameter(g)
    (lo, up, ds), iv = _contract(g)
    assert lo.lower <= exact <= up.upper
    assert ds.lower <= exact <= ds.upper
    assert lo.connected == up.connected == ds.connected == iv.connected
    assert iv.lower <= exact <= iv.upper
