"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def transfer_guarded():
    """Engine-loop tests run under ``jax.transfer_guard_device_to_host``
    set to "disallow": on TPU/GPU any device->host transfer that does NOT
    go through the sanctioned ``repro.analysis.guard.fetch`` raises
    immediately, so unannotated implicit transfers fail tier-1 rather than
    only lint. (On CPU the guard is inert — zero-copy buffer donation —
    which is why the static sync-lint exists; see guard.py.) Yields the
    :class:`TransferMeter` counting the sanctioned fetches, so tests can
    assert ``meter.transfers == metrics.host_syncs + ...`` equalities."""
    from repro.analysis import guard

    with guard.measured_transfers("disallow") as meter:
        yield meter
