"""Partition-sharded GraphStore: slab/halo layout, compressed residency,
partition properties, checkpoint round-trips and the session spill seam.

The multi-device halo-metric test rides the same subprocess pattern as
tests/test_distributed.py (XLA_FLAGS forcing 4 host devices must not
pollute this process's single-device world)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint import latest_step
from repro.graph import GraphStore, grid_mesh, random_geometric
from repro.graph.partition import (apply_partition, cluster_partition,
                                   cut_fraction, range_partition)
from repro.graph.storage import EdgeStore, PLANE_ROW_BYTES

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _graph(n=600, seed=2):
    return random_geometric(n, avg_degree=4.0, seed=seed)


# ---------------------------------------------------------------------------
# partition properties (satellite: balanced packing keeps its contracts)
# ---------------------------------------------------------------------------


class TestClusterPartition:
    def test_permutation_round_trips_node_ids(self):
        """apply_partition's (perm, inv) pair is a true bijection: every
        old id maps to exactly one new id and back, for many center
        layouts (uniform, skewed, single-cluster, one-per-node)."""
        r = np.random.default_rng(0)
        n = 257  # deliberately not divisible by n_devices
        layouts = [
            r.integers(0, 16, n),            # uniform clusters
            np.repeat(np.arange(8), [150, 50, 20, 15, 10, 6, 4, 2]),  # skew
            np.zeros(n, np.int64),           # one giant cluster
            np.arange(n),                    # all singletons
        ]
        for centers in layouts:
            centers = centers[:n]
            perm = cluster_partition(centers, 4)
            assert sorted(perm.tolist()) == list(range(len(centers)))
            inv = np.empty_like(perm)
            inv[perm] = np.arange(len(perm), dtype=np.int32)
            np.testing.assert_array_equal(perm[inv], np.arange(len(perm)))
            np.testing.assert_array_equal(inv[perm], np.arange(len(perm)))

    def test_clusters_contiguous_few_straddle_fixed_boundaries(self):
        """Clusters are contiguous runs in the new id order, so under the
        backends' FIXED ``q = ceil(n/P)`` owner rule at most P-1 clusters
        (those containing an internal boundary) can split across shards."""
        r = np.random.default_rng(1)
        centers = r.integers(0, 40, 1000)
        n_dev = 4
        perm = cluster_partition(centers, n_dev)
        new_centers = centers[perm]
        # contiguity: each cluster is one run of new ids
        runs = 1 + int((new_centers[1:] != new_centers[:-1]).sum())
        assert runs == len(np.unique(centers))
        inv = np.empty_like(perm)
        inv[perm] = np.arange(len(perm), dtype=np.int32)
        q = -(-len(centers) // n_dev)
        dev_of_old = inv // q
        split = sum(
            len(set(dev_of_old[centers == c].tolist())) > 1
            for c in np.unique(centers))
        assert split <= n_dev - 1, split

    def test_cut_not_worse_than_range_baseline(self):
        """On a locality-ordered graph the cluster relabeling must keep
        ``cut_fraction`` at or below the contiguous range partition the
        sharded backend would otherwise use."""
        from repro.core import cluster

        g = grid_mesh(24, "unit")
        base_perm = range_partition(g.n_nodes, 4)
        g_base, _ = apply_partition(g, base_perm)
        base_cut = cut_fraction(g_base, 4)
        dec = cluster(g, 16, seed=0)
        perm = cluster_partition(dec.final_c, 4)
        g2, _ = apply_partition(g, perm)
        assert cut_fraction(g2, 4) <= base_cut

    def test_skewed_sizes_are_load_balanced(self):
        """The old contiguous count-based fill dumped the whole size skew
        onto the last device; the packer must keep every device within
        ~optimal + one cluster even on adversarial size distributions."""
        sizes = [500, 100, 100, 100, 60, 50, 40, 30, 10, 10]
        centers = np.repeat(np.arange(len(sizes)), sizes)
        for n_dev in (2, 4):
            perm = cluster_partition(centers, n_dev)
            inv = np.empty_like(perm)
            inv[perm] = np.arange(len(perm), dtype=np.int32)
            q = -(-len(centers) // n_dev)
            loads = np.bincount(inv // q, minlength=n_dev)
            opt = len(centers) / n_dev
            assert loads.max() <= opt + max(sizes), (n_dev, loads.tolist())
            # and nothing like the all-on-one-device failure mode
            assert loads.max() < 0.8 * len(centers), (n_dev, loads.tolist())


# ---------------------------------------------------------------------------
# slab / halo layout
# ---------------------------------------------------------------------------


class TestSlabHaloLayout:
    def test_slabs_partition_the_edges_by_dst_owner(self):
        g = _graph()
        st = GraphStore(g, n_shards=4)
        total = 0
        q = st.nodes_per_shard
        for p in range(4):
            src, dst, w = st.slab(p)
            total += len(src)
            assert (dst // q == p).all()   # destination-owner rule
        assert total == st.n_edges
        # union of slabs == the store's edge list (as sets of triples)
        slab_set = set()
        for p in range(4):
            src, dst, w = st.slab(p)
            slab_set |= set(zip(src.tolist(), dst.tolist(), w.tolist()))
        e = st.edge_list()
        assert slab_set == set(zip(e.src.tolist(), e.dst.tolist(),
                                   e.weight.tolist()))

    def test_halo_index_covers_every_remote_source(self):
        """The halo-exchange consistency contract: every source a shard
        reads is owner-local or listed in its halo index."""
        g = _graph()
        st = GraphStore(g, n_shards=4)
        q = st.nodes_per_shard
        halo = st.halo_index()
        for p in range(4):
            src, dst, _ = st.slab(p)
            remote = np.unique(src[src // q != p])
            assert set(remote.tolist()) <= set(halo[p].tolist())
            local = src[src // q == p]
            assert not (set(local.tolist()) & set(halo[p].tolist()))

    def test_halo_bytes_strictly_below_fullplane(self):
        st = GraphStore(_graph(), n_shards=4)
        assert 0 < st.halo_bytes_per_superstep() \
            < st.fullplane_bytes_per_superstep()
        assert st.halo_bytes_per_superstep() == \
            PLANE_ROW_BYTES * 4 * 4 * st.halo_k()

    def test_cluster_relabeling_shrinks_the_halo(self):
        g = grid_mesh(24, "unit")
        from repro.core import cluster

        dec = cluster(g, 16, seed=0)
        plain = GraphStore(g, n_shards=4)
        packed = GraphStore(g, n_shards=4, centers=dec.final_c)
        assert packed.halo_rows() <= plain.halo_rows()
        # relabeled edges still the same multigraph (weights preserved
        # under the permutation)
        e = packed.edge_list()
        back_src = packed.perm[e.src]
        back_dst = packed.perm[e.dst]
        orig = g.remove_self_loops().coalesce()
        assert set(zip(back_src.tolist(), back_dst.tolist(),
                       e.weight.tolist())) == \
            set(zip(orig.src.tolist(), orig.dst.tolist(),
                    orig.weight.tolist()))

    def test_mutation_invalidates_layout(self):
        st = GraphStore(_graph(), n_shards=4)
        before = st.halo_rows()
        st.set_edge(0, st.n_nodes - 1, 5)
        st.flush()
        assert st._slabs is None   # lazy rebuild after mutation
        assert st.halo_rows() >= before


# ---------------------------------------------------------------------------
# compressed residency
# ---------------------------------------------------------------------------


class TestCompressedResidency:
    def test_slab_round_trips_and_counts_decompressions(self):
        g = _graph()
        plain = GraphStore(g, n_shards=4)
        comp = GraphStore(g, n_shards=4, compress=True)
        assert comp.decompressions == 0
        for p in range(4):
            a = plain.slab(p)
            b = comp.slab(p)
            for x, y in zip(a, b):
                np.testing.assert_array_equal(x, y)
        assert comp.decompressions == 4  # one unpack per slab access
        assert comp.resident_bytes() < comp.raw_bytes()
        assert plain.resident_bytes() == plain.raw_bytes()

    def test_sharded_graph_from_compressed_store_matches_plain(self):
        g = _graph(300)
        plain = GraphStore(g, n_shards=2)
        comp = GraphStore(g, n_shards=2, compress=True)
        sg_p = plain.sharded_graph(build_halo=True)
        sg_c = comp.sharded_graph(build_halo=True)
        assert comp.decompressions >= 2   # the on-demand grow-path unpacks
        np.testing.assert_array_equal(np.asarray(sg_p.src),
                                      np.asarray(sg_c.src))
        np.testing.assert_array_equal(np.asarray(sg_p.dst_local),
                                      np.asarray(sg_c.dst_local))
        np.testing.assert_array_equal(np.asarray(sg_p.weight),
                                      np.asarray(sg_c.weight))
        assert sg_p.halo_k == sg_c.halo_k


# ---------------------------------------------------------------------------
# checkpoint round-trip (satellite: free list + headroom survive restore)
# ---------------------------------------------------------------------------


def _mutate(store):
    """Deterministic mutation stream that exercises insert/delete/recycle."""
    n = store.n_nodes
    store.set_edge(1, 2, 9)
    store.set_edge(3, 4, 11)
    store.delete_edge(1, 2)
    store.set_edge(5, 6, 13)   # recycles (1, 2)'s slot (LIFO)
    store.flush()


class TestCheckpointRoundTrip:
    @pytest.mark.parametrize("cls,kw", [
        (EdgeStore, {}),
        (GraphStore, {"n_shards": 4}),
        (GraphStore, {"n_shards": 4, "compress": True}),
    ])
    def test_state_round_trip_preserves_free_list_and_capacity(
            self, tmp_path, cls, kw):
        from repro.checkpoint import restore, save

        g = _graph(200)
        st = cls(g, **kw)
        _mutate(st)
        cap, free, n_edges = st.capacity, list(st.free), st.n_edges
        save(str(tmp_path), 1, st.state_dict(), extra=st.extra_state())
        assert latest_step(str(tmp_path)) == 1
        tree, extra = restore(str(tmp_path), st.state_dict())
        st2 = cls.from_state(tree, extra)
        assert type(st2) is cls
        # capacity headroom and the LIFO free-slot order survive restore
        assert st2.capacity == cap
        assert st2.free == free
        assert st2.n_edges == n_edges
        assert st2.slot_of == st.slot_of
        e1, e2 = st.edge_list(), st2.edge_list()
        np.testing.assert_array_equal(e1.src, e2.src)
        np.testing.assert_array_equal(e1.dst, e2.dst)
        np.testing.assert_array_equal(e1.weight, e2.weight)
        # replaying the same update lands in the same slot on both sides
        st.set_edge(7, 8, 21)
        st2.set_edge(7, 8, 21)
        assert st.slot_of[(7, 8)] == st2.slot_of[(7, 8)]

    def test_graphstore_restore_keeps_partition_and_layout(self, tmp_path):
        from repro.checkpoint import restore, save
        from repro.core import cluster

        g = grid_mesh(16, "unit")
        dec = cluster(g, 8, seed=0)
        st = GraphStore(g, n_shards=4, centers=dec.final_c)
        save(str(tmp_path), 2, st.state_dict(), extra=st.extra_state())
        tree, extra = restore(str(tmp_path), st.state_dict())
        st2 = GraphStore.from_state(tree, extra)
        np.testing.assert_array_equal(st.perm, st2.perm)
        np.testing.assert_array_equal(st.inv_perm, st2.inv_perm)
        assert st2.n_shards == 4
        assert st.halo_k() == st2.halo_k()
        for p in range(4):
            for a, b in zip(st.slab(p), st2.slab(p)):
                np.testing.assert_array_equal(a, b)

    def test_restore_rejects_mismatched_geometry(self, tmp_path):
        from repro.checkpoint import restore, save

        g = _graph(120)
        st = GraphStore(g, n_shards=2)
        save(str(tmp_path), 1, st.state_dict(), extra=st.extra_state())
        tree, extra = restore(str(tmp_path), st.state_dict())
        other = GraphStore(g, n_shards=4)
        with pytest.raises(ValueError, match="n_shards"):
            other.load_state(tree, extra)
        smaller = GraphStore(_graph(60), n_shards=2)
        with pytest.raises(ValueError, match="n_nodes"):
            smaller.load_state(tree, extra)


# ---------------------------------------------------------------------------
# session spill seam + checkpointed decomposition through the session
# ---------------------------------------------------------------------------


class TestSessionIntegration:
    def test_spill_and_auto_unspill(self):
        from repro.core import ClusterQuotientEstimator, open_session

        g = _graph(400)
        st = GraphStore(g)
        with open_session(None, store=st, tau=8) as sess:
            est1 = sess.estimate(ClusterQuotientEstimator())
            builds = sess.metrics.backend_builds
            sess.spill()
            assert sess.spilled and sess.backend is None
            assert st.src is None   # device arrays released
            est2 = sess.estimate(ClusterQuotientEstimator())  # auto-unspill
            assert not sess.spilled
            assert sess.metrics.backend_builds == builds + 1
            assert est2.phi_approx == est1.phi_approx

    def test_preempt_and_resume_byte_identical(self, tmp_path):
        from repro.core import ClusterQuotientEstimator, open_session
        from repro.runtime.fault import Preempted, PreemptionGuard

        g = _graph(500, seed=5)
        ref_est = None
        with open_session(g, tau=8) as ref_sess:
            ref_est = ref_sess.estimate(ClusterQuotientEstimator())

        pg = PreemptionGuard()
        st = GraphStore(g)
        with open_session(None, store=st, tau=8,
                          checkpoint_dir=str(tmp_path), guard=pg) as sess:
            sess.checkpointer.preempt_after_stage = 1
            with pytest.raises(Preempted), pg:
                sess.estimate(ClusterQuotientEstimator())
            assert sess.checkpointer.saves >= 1
        assert latest_step(str(tmp_path)) is not None

        st2 = GraphStore(g)
        with open_session(None, store=st2, tau=8,
                          checkpoint_dir=str(tmp_path), resume=True,
                          guard=PreemptionGuard()) as sess2:
            est = sess2.estimate(ClusterQuotientEstimator())
            assert sess2.checkpointer.restores == 1
            assert est.phi_approx == ref_est.phi_approx
            assert est.n_clusters == ref_est.n_clusters
            # completion cleared the step dirs: no stale resume later
            assert latest_step(str(tmp_path)) is None

    def test_pool_shards_sessions_and_checkpoint_dirs(self, tmp_path):
        from repro.config.base import GraphEngineConfig
        from repro.core.session import SessionPool

        graphs = [_graph(220, seed=s) for s in (1, 2)]
        pool = SessionPool(GraphEngineConfig(),
                           checkpoint_dir=str(tmp_path), shards=2)
        try:
            for i, g in enumerate(graphs):
                sess = pool.open(g, tau=6)
                assert isinstance(sess.store, GraphStore)
                assert sess.store.n_shards == 2
                assert sess.checkpoint_dir == \
                    os.path.join(str(tmp_path), f"g{i}")
                est = sess.estimate()
                assert est.phi_approx > 0
        finally:
            pool.close()


# ---------------------------------------------------------------------------
# multi-device measured halo metric (subprocess: needs 4 host devices)
# ---------------------------------------------------------------------------


def test_sharded_backend_measures_halo_bytes_below_fullplane():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    code = """
    import numpy as np
    from repro.config.base import GraphEngineConfig
    from repro.core import ClusterQuotientEstimator, open_session
    from repro.graph import GraphStore, random_geometric

    g = random_geometric(1000, avg_degree=4.0, seed=1)
    results = {}
    for comm in ("halo", "allgather"):
        st = GraphStore(g, n_shards=4)
        cfg = GraphEngineConfig(backend="sharded", comm=comm)
        with open_session(None, cfg, store=st, tau=8) as sess:
            est = sess.estimate(ClusterQuotientEstimator())
            pm = est.pipeline
            results[comm] = (est.phi_approx, pm.halo_bytes,
                             pm.fullplane_bytes)
    (phi_h, halo_h, full_h) = results["halo"]
    (phi_a, halo_a, full_a) = results["allgather"]
    assert phi_h == phi_a, results            # byte-identical results
    assert 0 < halo_h < full_h, results       # measured wire-byte win
    assert halo_a == full_a, results          # baseline moves full planes
    print("HALO", halo_h, "FULL", full_h)
    """
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "HALO" in out.stdout
