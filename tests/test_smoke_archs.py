"""Per-arch smoke tests (deliverable f): instantiate the REDUCED config of
each assigned architecture, run one forward/train step on CPU, assert output
shapes + no NaNs. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import TrainConfig
from repro.config.registry import get_arch, list_archs
from repro.data.pipeline import gnn_full_graph_batch, gnn_molecule_batch
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tf_mod
from repro.optim import adamw

LM_ARCHS = ["gemma2-9b", "qwen1.5-32b", "mistral-nemo-12b",
            "moonshot-v1-16b-a3b", "mixtral-8x7b"]
GNN_ARCHS = ["gcn-cora", "gatedgcn", "meshgraphnet", "equiformer-v2"]


def _no_nan(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return all(
        not bool(jnp.isnan(l).any())
        for l in leaves if jnp.issubdtype(l.dtype, jnp.floating)
    )


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_arch(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = tf_mod.init_params(cfg, key)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)
    batch = {"tokens": toks, "labels": labels}

    logits, _ = tf_mod.forward(params, toks, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert _no_nan(logits)

    opt = adamw.init_state(params)
    tc = TrainConfig(lr=1e-3, warmup=1)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(tf_mod.lm_loss)(p, b, cfg)
        p, o, stats = adamw.apply_updates(p, o, g, tc)
        return p, o, loss

    p1, o1, loss1 = step(params, opt, batch)
    p2, o2, loss2 = step(p1, o1, batch)
    assert _no_nan(p2) and _no_nan(loss2)
    assert float(loss2) < float(loss1) + 1.0  # sane magnitude, moving


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode(arch):
    cfg = get_arch(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = tf_mod.init_params(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _ = tf_mod.forward(params, toks, cfg)
    cache = tf_mod.init_cache(cfg, B, 32)
    for i in range(S):
        dec_logits, cache = tf_mod.decode_step(params, cache, toks[:, i:i+1], cfg)
    np.testing.assert_allclose(
        np.asarray(full_logits[:, -1]), np.asarray(dec_logits),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_full_graph(arch):
    cfg = get_arch(arch, smoke=True)
    from repro.config.base import ShapeSpec
    shape = ShapeSpec(name="t", kind="full_graph", n_nodes=60, n_edges=240,
                      d_feat=12)
    graph = {k: jnp.asarray(v) for k, v in
             gnn_full_graph_batch(cfg, shape, seed=1, n_classes=cfg.d_out).items()}
    if cfg.kind in ("gatedgcn", "meshgraphnet"):
        graph["e"] = jnp.asarray(
            np.random.default_rng(0).standard_normal(
                (240, {"gatedgcn": 1, "meshgraphnet": 4}[cfg.kind])
            ).astype(np.float32))
    params = gnn_mod.init_gnn(cfg, 12, jax.random.PRNGKey(0),
                              d_edge_in={"gatedgcn": 1, "meshgraphnet": 4}.get(cfg.kind, 1))
    out = gnn_mod.gnn_forward(params, graph, cfg)
    assert out.shape == (60, cfg.d_out)
    assert _no_nan(out)
    loss, grads = jax.value_and_grad(gnn_mod.node_classification_loss)(
        params, graph, cfg)
    assert _no_nan(loss) and _no_nan(grads)
    # one optimizer step
    opt = adamw.init_state(params)
    p1, _, _ = adamw.apply_updates(params, opt, grads, TrainConfig(warmup=1))
    assert _no_nan(p1)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_molecule(arch):
    cfg = get_arch(arch, smoke=True)
    from repro.config.base import ShapeSpec
    shape = ShapeSpec(name="m", kind="batched_graphs", n_nodes=10, n_edges=20,
                      n_graphs=4)
    g = gnn_molecule_batch(cfg, shape, seed=2, d_feat=8)
    g = {k: jnp.asarray(v) for k, v in g.items()}
    g["targets"] = jnp.asarray(
        np.random.default_rng(1).standard_normal((4, cfg.d_out)).astype(np.float32))
    if cfg.kind in ("gatedgcn", "meshgraphnet"):
        d_e = {"gatedgcn": 1, "meshgraphnet": 4}[cfg.kind]
        g["e"] = jnp.ones((80, d_e), jnp.float32)
    params = gnn_mod.init_gnn(cfg, 8, jax.random.PRNGKey(3),
                              d_edge_in={"gatedgcn": 1, "meshgraphnet": 4}.get(cfg.kind, 1))
    loss = gnn_mod.graph_regression_loss(params, g, cfg)
    assert _no_nan(loss) and loss.shape == ()


def test_recsys_smoke_train_and_retrieval():
    cfg = get_arch("xdeepfm", smoke=True)
    key = jax.random.PRNGKey(0)
    params = recsys_mod.init_params(cfg, key)
    rng = np.random.default_rng(0)
    B, F, bag = 8, cfg.n_sparse, cfg.multi_hot
    batch = {
        "ids": jnp.asarray(rng.integers(0, cfg.vocab_per_field, (B, F, bag)).astype(np.int32)),
        "id_mask": jnp.ones((B, F, bag), jnp.float32),
        "dense": jnp.asarray(rng.standard_normal((B, cfg.n_dense)).astype(np.float32)),
        "labels": jnp.asarray(rng.integers(0, 2, B).astype(np.int32)),
    }
    logits = recsys_mod.forward(params, batch, cfg)
    assert logits.shape == (B,) and _no_nan(logits)
    loss, grads = jax.value_and_grad(recsys_mod.bce_loss)(params, batch, cfg)
    assert _no_nan(loss) and _no_nan(grads)

    # retrieval: 1 query against C candidates with fewer fields
    import dataclasses
    fu, fi, C = 2, 4, 16
    rcfg = dataclasses.replace(cfg, n_sparse=fu + fi)
    rparams = recsys_mod.init_params(rcfg, key)
    scores = recsys_mod.retrieval_scores(
        rparams,
        batch["ids"][:1, :fu], batch["id_mask"][:1, :fu], batch["dense"][:1],
        jnp.asarray(rng.integers(0, rcfg.vocab_per_field, (C, fi, bag)).astype(np.int32)),
        jnp.ones((C, fi, bag), jnp.float32),
        rcfg,
    )
    assert scores.shape == (C,) and _no_nan(scores)


def test_paper_graph_smoke():
    from repro.config.base import GraphEngineConfig
    from repro.core import approximate_diameter
    from repro.graph import grid_mesh
    cfg = get_arch("paper-graph", smoke=True)
    assert isinstance(cfg, GraphEngineConfig)
    g = grid_mesh(16, "unit")
    est = approximate_diameter(g, cfg)
    assert est.phi_approx >= 30  # true diameter = 30, conservative estimate
    assert est.connected


def test_all_archs_registered():
    names = list_archs()
    for a in ["gemma2-9b", "qwen1.5-32b", "mistral-nemo-12b",
              "moonshot-v1-16b-a3b", "mixtral-8x7b", "gcn-cora", "gatedgcn",
              "meshgraphnet", "equiformer-v2", "xdeepfm", "paper-graph"]:
        assert a in names
        assert get_arch(a) is not None
        assert get_arch(a, smoke=True) is not None
