"""Persistent fused megakernel vs the unfused growth loop: byte-identical
(d, c, pathw) planes AND identical GrowthStats on every problem, interpret
mode on CPU (``ref.py``-backed ``growth_loop`` is the oracle)."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.backend import PallasBackend, SingleDeviceBackend
from repro.core.engine import run_cluster
from repro.graph.structures import EdgeList
from repro.kernels.edge_relax.kernel import (
    validate_block_tile,
    validate_tiling,
)
from repro.kernels.edge_relax.megakernel import fits_vmem, vmem_footprint_bytes

INF, BIG = 2**31 - 1, 2**30


def _random_edges(n, e, wmax, seed):
    r = np.random.default_rng(seed)
    return EdgeList(
        n,
        r.integers(0, n, e).astype(np.int32),
        r.integers(0, n, e).astype(np.int32),
        r.integers(1, wmax + 1, e).astype(np.int32),
    )


def _seed_growth_state(backend, seed, center_frac=0.05, covered_frac=0.2,
                       wmax=100):
    """A mid-decomposition state on the backend's padded layout: some
    permanent centers (d=0 wavefronts), some covered relays with realistic
    offsets (including negative, the contraction rescaling), rest unreached."""
    r = np.random.default_rng(seed)
    st_ = backend.init_state()
    n, n_pad = backend.n_nodes, backend.n_pad
    roles = r.random(n)
    cen = roles < center_frac
    cen[0] = True  # at least one wave source
    cov = (roles >= center_frac) & (roles < center_frac + covered_frac)
    ids = np.arange(n_pad, dtype=np.int32)

    d = np.asarray(st_.d).copy(); c = np.asarray(st_.c).copy()
    p = np.asarray(st_.pathw).copy()
    fc = np.asarray(st_.final_c).copy()
    fp = np.asarray(st_.final_pathw).copy()
    off = np.asarray(st_.offset).copy()
    covered = np.asarray(st_.covered).copy()
    is_c = np.asarray(st_.is_center).copy()

    cen_idx = np.where(cen)[0]
    d[cen_idx] = 0; c[cen_idx] = cen_idx; p[cen_idx] = 0
    fc[cen_idx] = cen_idx; fp[cen_idx] = 0
    is_c[cen_idx] = True

    cov_idx = np.where(cov)[0]
    covered[cov_idx] = True
    fc[cov_idx] = r.choice(np.maximum(cen_idx, 0), cov_idx.size) \
        if cen_idx.size else 0
    fp[cov_idx] = r.integers(0, 4 * wmax, cov_idx.size)
    off[cov_idx] = r.integers(-wmax, 1, cov_idx.size)

    return st_._replace(
        d=jnp.asarray(d), c=jnp.asarray(c), pathw=jnp.asarray(p),
        final_c=jnp.asarray(fc), final_pathw=jnp.asarray(fp),
        offset=jnp.asarray(off), covered=jnp.asarray(covered),
        is_center=jnp.asarray(is_c))


def _assert_grow_parity(edges, delta, num_it, variant, seed, k_fused=4,
                        node_tile=256, edge_block=512):
    """fused (megakernel, interpret) vs unfused (ref growth_loop) on the
    SAME blocked layout and the SAME seeded state."""
    kw = dict(impl="ref", node_tile=node_tile, edge_block=edge_block)
    be_ref = PallasBackend(edges, **kw)
    be_mk = PallasBackend(edges, fuse=k_fused, **kw)
    assert be_mk.fuse == k_fused
    st0 = _seed_growth_state(be_ref, seed)
    half = jnp.int32(max(edges.n_nodes // 2, 1))
    s1, g1 = be_ref.grow(st0, jnp.int32(delta), half, jnp.int32(num_it),
                         variant)
    s2, g2 = be_mk.grow(st0, jnp.int32(delta), half, jnp.int32(num_it),
                        variant)
    for name in ("d", "c", "pathw"):
        np.testing.assert_array_equal(
            np.asarray(getattr(s1, name)), np.asarray(getattr(s2, name)),
            err_msg=f"plane {name} ({variant}, delta={delta})")
    assert int(g1.steps) == int(g2.steps)
    assert int(g1.reached) == int(g2.reached)
    assert bool(g1.changed_last) == bool(g2.changed_last)
    assert int(g2.kernel_launches) >= 1
    assert int(g2.kernel_supersteps) == int(g2.steps)
    return g2


# ---------------------------------------------------------------------------
# parity: random graphs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["stop", "complete"])
@pytest.mark.parametrize("n,e,wmax,delta", [
    (100, 400, 16, 40), (400, 1600, 100, 256), (700, 1500, 2**20, 2**21),
])
def test_megakernel_matches_growth_loop(n, e, wmax, delta, variant):
    edges = _random_edges(n, e, wmax, seed=n + e)
    _assert_grow_parity(edges, delta, num_it=24, variant=variant, seed=n)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(16, 300), e=st.integers(16, 900),
       wmax=st.sampled_from([3, 50, 1 << 16]), seed=st.integers(0, 999),
       k_fused=st.integers(1, 6),
       variant=st.sampled_from(["stop", "complete"]))
def test_megakernel_property(n, e, wmax, seed, k_fused, variant):
    edges = _random_edges(n, e, wmax, seed)
    _assert_grow_parity(edges, delta=2 * wmax, num_it=16, variant=variant,
                        seed=seed, k_fused=k_fused)


# ---------------------------------------------------------------------------
# parity: degenerate tilings and sentinel boundaries
# ---------------------------------------------------------------------------

def test_megakernel_single_node_tiles():
    # node_tile=1: every node is its own tile; every block is owned by one
    # node and the tile-straddling guard is exercised maximally
    edges = _random_edges(13, 60, 9, seed=7)
    _assert_grow_parity(edges, delta=20, num_it=16, variant="complete",
                        seed=7, k_fused=3, node_tile=1, edge_block=128)


def test_megakernel_all_padding_blocks():
    # 3 real edges over 300 nodes at edge_block=512: nearly every block is
    # pure phantom padding — the frontier must still converge and the
    # phantom slots stay inert
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 0], np.int32)
    w = np.array([5, 7, 11], np.int32)
    edges = EdgeList(300, src, dst, w)
    g = _assert_grow_parity(edges, delta=64, num_it=16, variant="complete",
                            seed=3, k_fused=4)
    assert int(g.dead_blocks) > 0  # padding tiles are frontier-skipped


def test_megakernel_tile_straddling_boundary():
    # every edge lands on a tile-boundary destination (multiples of the
    # node_tile) — the local_dst arithmetic must keep them in-tile
    node_tile = 64
    n = 8 * node_tile
    r = np.random.default_rng(11)
    dst = (r.integers(0, 8, 500) * node_tile).astype(np.int32)
    src = r.integers(0, n, 500).astype(np.int32)
    w = r.integers(1, 50, 500).astype(np.int32)
    edges = EdgeList(n, src, dst, w)
    _assert_grow_parity(edges, delta=128, num_it=16, variant="stop", seed=11,
                        node_tile=node_tile, edge_block=128)


def test_megakernel_sentinel_boundaries():
    # weights at the top of the legal range (just under BIG=2^30) with a
    # delta beyond it: candidate arithmetic must not wrap past INF and the
    # BIG relay clamp must match the reference exactly
    r = np.random.default_rng(5)
    n, e = 64, 300
    w = np.concatenate([
        np.full(20, BIG - 1, np.int32),
        np.full(20, BIG - 2, np.int32),
        r.integers(1, 1000, e - 40).astype(np.int32)])
    edges = EdgeList(n, r.integers(0, n, e).astype(np.int32),
                     r.integers(0, n, e).astype(np.int32), w)
    for delta in (BIG - 1, BIG, 1000):
        _assert_grow_parity(edges, delta=delta, num_it=12, variant="complete",
                            seed=5, node_tile=64, edge_block=128)


# ---------------------------------------------------------------------------
# full-decomposition byte-identity
# ---------------------------------------------------------------------------

def test_fused_decomposition_matches_single_backend():
    edges = _random_edges(500, 2000, 100, seed=42)
    ref = run_cluster(edges, SingleDeviceBackend(edges), tau=8, seed=1)
    fused = run_cluster(edges, PallasBackend(edges, impl="ref", fuse=4),
                        tau=8, seed=1)
    np.testing.assert_array_equal(ref.final_c, fused.final_c)
    np.testing.assert_array_equal(ref.final_pathw, fused.final_pathw)
    assert ref.radius == fused.radius
    assert ref.growing_steps == fused.growing_steps
    m = fused.metrics
    assert m.kernel_launches > 0
    assert m.kernel_supersteps == fused.growing_steps
    assert ref.metrics.kernel_launches == 0  # unfused path stays at zero


# ---------------------------------------------------------------------------
# tiling validation (satellite: clean errors, not wrong answers)
# ---------------------------------------------------------------------------

def test_validate_tiling_rejects_bad_shapes():
    validate_tiling(256, 512)  # defaults pass
    validate_tiling(1, 128)    # degenerate-but-legal
    with pytest.raises(ValueError, match="multiple of 128"):
        validate_tiling(256, 100)
    with pytest.raises(ValueError, match="multiple of 128"):
        validate_tiling(256, 0)
    with pytest.raises(ValueError, match="power of two"):
        validate_tiling(96, 512)
    with pytest.raises(ValueError, match="power of two"):
        validate_tiling(0, 512)


def test_validate_block_tile_rejects_interleaved_map():
    validate_block_tile(np.array([0, 0, 1, 2, 2]), n_tiles=3)
    with pytest.raises(ValueError, match="monotone"):
        validate_block_tile(np.array([0, 1, 0]), n_tiles=2)
    with pytest.raises(ValueError, match="in \\[0, 2\\)"):
        validate_block_tile(np.array([0, 1, 2]), n_tiles=2)


def test_pallas_backend_rejects_bad_tiling():
    edges = _random_edges(50, 100, 9, seed=0)
    with pytest.raises(ValueError, match="multiple of 128"):
        PallasBackend(edges, impl="ref", edge_block=100)
    with pytest.raises(ValueError, match="power of two"):
        PallasBackend(edges, impl="ref", node_tile=100)


def test_megakernel_vmem_guard_falls_back_to_unfused(monkeypatch):
    from repro.kernels.edge_relax import megakernel

    edges = _random_edges(40, 80, 9, seed=0)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        be = PallasBackend(edges, impl="ref", fuse=4)
    assert be.fuse == 4 and not rec  # small graph fits; no warning path
    assert fits_vmem(be.n_pad, 256, 512)
    assert not fits_vmem(10**9, 256, 512)
    assert vmem_footprint_bytes(10**9, 256, 512) > megakernel.VMEM_BUDGET_BYTES

    # an over-budget graph degrades to the unfused path with ONE warning,
    # not a crash mid-decomposition
    monkeypatch.setattr(megakernel, "fits_vmem", lambda *a, **k: False)
    with pytest.warns(RuntimeWarning, match="VMEM budget"):
        be2 = PallasBackend(edges, impl="ref", fuse=4)
    assert be2.fuse == 0
    with pytest.raises(ValueError, match="fuse"):
        PallasBackend(edges, impl="ref", fuse=-1)


# ---------------------------------------------------------------------------
# dispatch fallback (satellite: CPU-honest impl="pallas")
# ---------------------------------------------------------------------------

def test_edge_relax_pallas_impl_falls_back_on_cpu():
    import jax

    from repro.kernels.edge_relax import ops
    from repro.kernels.edge_relax.ops import block_edges_host, edge_relax

    if jax.default_backend() == "tpu":
        pytest.skip("fallback only engages off-TPU")
    r = np.random.default_rng(2)
    n, e = 100, 400
    src = r.integers(0, n, e).astype(np.int32)
    dst = r.integers(0, n, e).astype(np.int32)
    w = r.integers(1, 20, e).astype(np.int32)
    blk = block_edges_host(src, dst, w, n)
    n_pad = blk["n_pad_nodes"]
    d = np.full(n_pad, INF, np.int32); d[:5] = 0
    c = np.full(n_pad, INF, np.int32); c[:5] = np.arange(5)
    p = np.full(n_pad, INF, np.int32); p[:5] = 0
    rw0 = np.full(n_pad, BIG, np.int32)
    rc = np.full(n_pad, INF, np.int32)
    rp = np.full(n_pad, INF, np.int32)
    planes = tuple(jnp.asarray(x) for x in (d, c, p, rw0, rc, rp))
    args = (planes, jnp.asarray(blk["src"]), jnp.asarray(blk["dst"]),
            jnp.asarray(blk["w"]), jnp.asarray(blk["mask"]),
            jnp.asarray(blk["block_tile"]), jnp.int32(19), blk["n_tiles"])

    ops._PALLAS_FALLBACK_WARNED = False
    with pytest.warns(RuntimeWarning, match="falling back"):
        pal = edge_relax(*args, impl="pallas")
    ref = edge_relax(*args, impl="ref")
    for r_, p_ in zip(ref, pal):
        np.testing.assert_array_equal(np.asarray(r_), np.asarray(p_))
    assert ops._PALLAS_FALLBACK_WARNED
