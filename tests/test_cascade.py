"""Multi-level quotient cascade (``CascadeEstimator``): level-0 field
identity with the flat pipeline, the bound contract
``lower <= scipy exact <= upper`` at every level count across backends,
conservativeness of the int64->int32 weight rescale, degenerate inputs,
and the per-level ``PipelineMetrics`` accounting."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    CascadeEstimator,
    ClusterQuotientEstimator,
    DiameterEstimator,
    IntervalEstimator,
    LowerBoundEstimator,
    SessionPool,
    open_session,
    quotient_as_edgelist,
)
from repro.core.quotient import INF64, DeviceQuotient
from repro.graph import grid_mesh, random_connected, random_geometric
from repro.graph.structures import MAX_WEIGHT, EdgeList, to_scipy_csr

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _true_diameter(edges):
    from scipy.sparse.csgraph import shortest_path
    d = shortest_path(to_scipy_csr(edges), method="D", directed=False)
    fin = d[np.isfinite(d)]
    return int(fin.max()) if len(fin) else 0


def _edgeless(n):
    z = np.array([], dtype=np.int32)
    return EdgeList(n, z, z, z)


def _assert_estimates_identical(a, b, ignore=("seconds", "method")):
    for f in dataclasses.fields(a):
        if f.name in ignore:
            continue
        x, y = getattr(a, f.name), getattr(b, f.name)
        if isinstance(x, np.ndarray) or isinstance(y, np.ndarray):
            np.testing.assert_array_equal(x, y, err_msg=f.name)
        else:
            assert x == y, (f.name, x, y)


# ---------------------------------------------------------------------------
# level 0 == the flat pipeline, field for field
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["single", "pallas"])
def test_level0_cascade_field_identical_to_flat(backend):
    from repro.config.base import GraphEngineConfig

    g = random_geometric(900, avg_degree=3.0, seed=5)
    sess = open_session(g, GraphEngineConfig(backend=backend), tau=8)
    flat = sess.estimate(ClusterQuotientEstimator())
    casc = sess.estimate(CascadeEstimator(levels=0))
    _assert_estimates_identical(flat, casc)
    assert casc.method == "cascade"
    assert casc.pipeline.cascade_levels == 0
    assert casc.pipeline.level_clusters == []


def test_levels0_identical_even_when_quotient_is_large():
    """levels=0 must never cascade, no matter how small tau_solve is."""
    g = random_geometric(700, avg_degree=3.0, seed=2)
    sess = open_session(g, tau=8)
    flat = sess.estimate(ClusterQuotientEstimator())
    casc = sess.estimate(CascadeEstimator(levels=0, tau_solve=2))
    _assert_estimates_identical(flat, casc)


# ---------------------------------------------------------------------------
# bound contract across level counts and backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["single", "pallas"])
@pytest.mark.parametrize("levels", [0, 1, 2])
def test_cascade_bound_contract(backend, levels):
    from repro.config.base import GraphEngineConfig

    g = random_connected(300, 900, seed=9, weight_dist="uniform", high=1000)
    exact = _true_diameter(g)
    sess = open_session(g, GraphEngineConfig(backend=backend), tau=4,
                        tau_solve=4)
    lo = sess.estimate(LowerBoundEstimator(rounds=3, seed=0))
    up = sess.estimate(CascadeEstimator(levels=levels))
    assert lo.lower <= exact <= up.upper
    assert up.connected and lo.connected
    assert up.phi_approx == up.phi_quotient + 2 * up.radius
    if levels:
        assert up.pipeline.cascade_levels >= 1  # tau_solve=4 forces it


def test_cascade_monotone_in_levels():
    """Each extra level only coarsens the bound:
    diam(Q_l) <= 2 R_{l+1} + diam(Q_{l+1})."""
    g = random_geometric(1200, avg_degree=3.0, seed=3)
    sess = open_session(g, tau=8, tau_solve=8)
    uppers = [sess.estimate(CascadeEstimator(levels=lv)).upper
              for lv in (0, 1, 2, 3)]
    assert uppers == sorted(uppers)
    assert _true_diameter(g) <= uppers[0]


def test_cascade_sharded_backend_subprocess():
    """Level 0 on the sharded backend (forced 4-device host mesh), deeper
    levels on the device-resident single backend — the bound contract must
    hold end to end."""
    code = textwrap.dedent("""
    import jax, numpy as np
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    from repro.core import CascadeEstimator, open_session
    from repro.core.distributed import DistributedEngine
    from repro.graph import grid_mesh
    from repro.graph.structures import to_scipy_csr
    from scipy.sparse.csgraph import shortest_path
    g = grid_mesh(20, "uniform", high=100, seed=3)
    be = DistributedEngine(g, mesh, comm="halo").make_relax_fn()
    sess = open_session(g, tau=6, tau_solve=8, backend=be)
    est = sess.estimate(CascadeEstimator(levels=2))
    d = shortest_path(to_scipy_csr(g), method="D", directed=False)
    exact = int(d[np.isfinite(d)].max())
    assert est.connected
    assert est.upper >= exact, (est.upper, exact)
    assert est.pipeline.cascade_levels >= 1
    print("CASCADE-SHARDED-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "CASCADE-SHARDED-OK" in out.stdout


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(30, 120),
    ef=st.integers(2, 5),
    seed=st.integers(0, 10_000),
    levels=st.integers(0, 2),
    wmax=st.sampled_from([1, 10, 1000, 2**20]),
)
def test_property_cascade_bracket(n, ef, seed, levels, wmax):
    """lower <= scipy exact <= cascade upper on random connected graphs at
    every level count; the interval bracket stays certified."""
    g = random_connected(n, n * ef, seed=seed, weight_dist="uniform",
                         high=wmax)
    exact = _true_diameter(g)
    sess = open_session(g, tau=4, tau_solve=4)
    lo = sess.estimate(LowerBoundEstimator(rounds=3, seed=0))
    up = sess.estimate(CascadeEstimator(levels=levels))
    assert lo.lower <= exact <= up.upper
    assert lo.connected == up.connected
    iv = sess.estimate(IntervalEstimator(estimators=(
        LowerBoundEstimator(rounds=3, seed=0),
        CascadeEstimator(levels=levels))))
    assert iv.lower <= exact <= iv.upper


# ---------------------------------------------------------------------------
# the int64 -> int32 weight rescale
# ---------------------------------------------------------------------------

def test_quotient_as_edgelist_rescales_and_inerts_padding():
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    heavy = 3 * int(MAX_WEIGHT)  # int64-only quotient weight
    with enable_x64():
        dq = DeviceQuotient(
            centers=jnp.arange(3, dtype=jnp.int32),
            src=jnp.asarray([0, 1, 2, 7], jnp.int32),
            dst=jnp.asarray([1, 2, 0, 7], jnp.int32),
            weight=jnp.asarray([heavy, 5, 1, int(INF64)], jnp.int64),
            n_clusters=jnp.int32(3), n_edges=jnp.int32(3),
            max_weight=jnp.int64(heavy),
            weight_sum=jnp.int64(heavy + 6),
        )
    lv = quotient_as_edgelist(dq, 3, 3, heavy, heavy + 6, edge_bucket=4)
    assert lv.scale == 3
    w = np.asarray(lv.weight)
    # ceil(heavy / 3) == MAX_WEIGHT; small weights ceil-divide; minimum 1
    assert w[0] == int(MAX_WEIGHT) and w[1] == 2 and w[2] == 1
    # the host mirror (graph/structures.rescale_weights) must agree with
    # the device kernel edge for edge
    from repro.graph import rescale_weights
    w_host, scale_host = rescale_weights(np.array([heavy, 5, 1], np.int64))
    assert scale_host == lv.scale
    np.testing.assert_array_equal(w[:3].astype(np.int64), w_host)
    # padding slot became an inert self-loop
    assert (int(lv.src[3]), int(lv.dst[3]), int(w[3])) == (0, 0, 1)
    el = lv.to_edgelist()  # host materialization passes EdgeList validation
    assert el.n_nodes == 3 and el.n_edges == 3
    assert lv.weight_sum >= int(w[:3].sum())


def test_cascade_conservative_under_rescale():
    """Weights near 2^30 push quotient sums past int32 — the cascade must
    rescale (scale > 1 somewhere) and STILL upper-bound the exact
    diameter."""
    g = random_connected(120, 360, seed=4, weight_dist="uniform",
                         high=2**30 - 1)
    exact = _true_diameter(g)
    sess = open_session(g, tau=4, tau_solve=4)
    est = sess.estimate(CascadeEstimator(levels=2))
    assert est.pipeline.cascade_levels >= 1
    assert est.upper >= exact
    assert est.connected


# ---------------------------------------------------------------------------
# degenerate inputs + accounting
# ---------------------------------------------------------------------------

def test_cascade_degenerate_graphs():
    for n in (0, 1):
        est = open_session(_edgeless(n), tau=2).estimate(
            CascadeEstimator(levels=2, tau_solve=2))
        assert est.phi_approx == 0 and est.connected
    # edgeless nodes: disconnected, diameter bound 0 over finite pairs
    est = open_session(_edgeless(5), tau=2).estimate(
        CascadeEstimator(levels=2, tau_solve=2))
    assert not est.connected
    # two triangles: every level preserves the component structure
    u = np.array([0, 1, 2, 3, 4, 5], np.int32)
    v = np.array([1, 2, 0, 4, 5, 3], np.int32)
    g = EdgeList.from_undirected(6, u, v, np.ones(6, np.int32))
    est = open_session(g, tau=2).estimate(
        CascadeEstimator(levels=2, tau_solve=2))
    assert not est.connected
    assert est.phi_approx >= 1


def test_cascade_metrics_accounting():
    g = random_geometric(1000, avg_degree=3.0, seed=7)
    sess = open_session(g, tau=8)
    est = sess.estimate(CascadeEstimator(levels=2, tau_solve=8))
    pm = est.pipeline
    assert pm.cascade_levels == len(pm.level_clusters) \
        == len(pm.level_supersteps) == len(pm.level_syncs) >= 1
    assert pm.total_host_syncs == (pm.decompose_syncs + pm.finalize_syncs
                                   + pm.quotient_syncs + pm.solve_syncs)
    # per-level syncs are part of (not in addition to) the scalar counters
    assert sum(pm.level_syncs) < pm.total_host_syncs
    # growing_steps aggregates every level's decomposition supersteps
    flat = sess.estimate(ClusterQuotientEstimator())
    assert est.growing_steps == flat.growing_steps + sum(pm.level_supersteps)


def test_cascade_validation_and_protocol():
    g = grid_mesh(4, "unit")
    sess = open_session(g)
    with pytest.raises(ValueError, match="levels"):
        sess.estimate(CascadeEstimator(levels=-1))
    with pytest.raises(ValueError, match="tau_solve"):
        sess.estimate(CascadeEstimator(tau_solve=1))
    with pytest.raises(ValueError, match="tau_solve"):
        open_session(g, tau_solve=0)
    with pytest.raises(ValueError, match="tau_solve"):
        SessionPool(tau_solve=1)
    assert isinstance(CascadeEstimator(), DiameterEstimator)


def test_cascade_in_pool_matches_unpooled():
    g = random_geometric(500, avg_degree=3.0, seed=6)
    pooled = SessionPool(tau_solve=8).open(g, tau=6)
    solo = open_session(g, tau=6, tau_solve=8)
    a = pooled.estimate(CascadeEstimator(levels=2))
    b = solo.estimate(CascadeEstimator(levels=2))
    _assert_estimates_identical(a, b, ignore=("seconds",))
