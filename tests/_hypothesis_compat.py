"""Import hypothesis if present; otherwise provide a stub that lets the
suite collect everywhere and marks property tests skipped (the container
does not ship hypothesis; CI installs it via requirements-dev.txt)."""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    import pytest as _pytest

    def given(**kw):
        return lambda fn: _pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(**kw):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
