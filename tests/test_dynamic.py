"""Dynamic-graph subsystem: in-place updates on resident sessions.

The load-bearing contract — after EVERY applied ``UpdateBatch`` the session
bracket stays certified, ``lower <= scipy exact <= upper``, across
insert-only, mixed, and delete-heavy traces (including disconnecting
deletions) on all backends — plus the storage-layer contracts incremental
insertion relies on (``EdgeList.coalesce``/``remove_self_loops``
composition, ``EdgeStore`` min-coalescing and slot recycling), incremental
quotient parity with a full recompute, rebuild_fraction behavior, and the
serve-driver estimator-name validation.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.config.base import GraphEngineConfig
from repro.core import (
    ClusterQuotientEstimator,
    DiameterEstimator,
    DynamicQuotientEstimator,
    IntervalEstimator,
    LowerBoundEstimator,
    UpdateBatch,
    open_session,
)
from repro.graph import (
    grid_mesh,
    random_connected,
    random_geometric,
    temporal_trace,
)
from repro.graph.structures import EdgeList, EdgeStore, to_scipy_csr

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _true_diameter(edges):
    from scipy.sparse.csgraph import shortest_path
    d = shortest_path(to_scipy_csr(edges), method="D", directed=False)
    fin = d[np.isfinite(d)]
    return int(fin.max()) if len(fin) else 0


def _undirected_pairs(edges):
    return sorted({(int(u), int(v)) for u, v in zip(edges.src, edges.dst)
                   if u < v})


def _certify(sess):
    """lower <= scipy exact <= upper on the session's CURRENT graph."""
    iv = sess.estimate(IntervalEstimator())
    exact = _true_diameter(sess.edges)
    assert iv.lower <= exact <= iv.upper, (iv.lower, exact, iv.upper)
    return iv, exact


# ---------------------------------------------------------------------------
# UpdateBatch semantics and validation
# ---------------------------------------------------------------------------

def test_update_batch_validation():
    with pytest.raises(ValueError, match="length mismatch"):
        UpdateBatch(insert_src=[0], insert_dst=[1], insert_weight=[])
    with pytest.raises(ValueError, match=r"weights must be in \[1, 2\^30\)"):
        UpdateBatch.inserts([0], [1], [0])
    b = UpdateBatch.inserts([0], [1], [5])  # symmetric by default
    assert b.n_events == 2
    assert list(b.insert_src) == [0, 1] and list(b.insert_dst) == [1, 0]
    assert UpdateBatch.deletes([0], [1], symmetric=False).n_events == 1
    merged = UpdateBatch.merge([b, UpdateBatch.deletes([2], [3])])
    assert merged.n_events == 4


def test_update_batch_errors_leave_store_untouched():
    g = grid_mesh(4, "unit")
    sess = open_session(g, tau=2)
    before = _undirected_pairs(g)
    with pytest.raises(ValueError, match="missing edge"):
        sess.apply_updates(UpdateBatch.deletes([0], [15]))
    with pytest.raises(ValueError, match="missing edge"):
        sess.apply_updates(UpdateBatch.reweights([0], [15], [3]))
    with pytest.raises(ValueError, match="out of range"):
        sess.apply_updates(UpdateBatch.inserts([0], [99], [3]))
    with pytest.raises(ValueError, match="at most one reweight/delete"):
        sess.apply_updates(UpdateBatch.merge([
            UpdateBatch.deletes([0], [1]), UpdateBatch.reweights([0], [1], [2])]))
    assert _undirected_pairs(sess.edges) == before  # atomic: nothing applied


def test_insert_existing_key_keeps_minimum():
    """Insert-on-existing follows the coalesce contract: min weight wins."""
    g = grid_mesh(4, "uniform", high=100, seed=1)
    sess = open_session(g, tau=2)
    u, v = int(g.src[0]), int(g.dst[0])
    w0 = int(g.weight[0])
    rep = sess.apply_updates(UpdateBatch.inserts([u], [v], [w0 + 50]))
    assert rep.action == "noop" and rep.noops == 2  # heavier parallel edge
    store = sess.dynamic.store
    assert store.lookup(u, v) == w0
    rep = sess.apply_updates(UpdateBatch.inserts([u], [v], [max(w0 - 1, 1)]))
    if w0 > 1:
        assert rep.decreases == 2 and store.lookup(u, v) == w0 - 1


def test_noop_batch_and_closed_session():
    sess = open_session(grid_mesh(4, "unit"), tau=2)
    rep = sess.apply_updates(UpdateBatch())
    assert rep.action == "noop" and rep.supersteps == 0
    sess.close()
    with pytest.raises(RuntimeError, match="closed"):
        sess.apply_updates(UpdateBatch())


# ---------------------------------------------------------------------------
# EdgeStore: the mutable storage layer
# ---------------------------------------------------------------------------

def test_edge_store_coalesces_and_recycles():
    # duplicate (0,1) keeps min weight; self-loop dropped to free capacity
    e = EdgeList(4, np.array([0, 0, 2, 1], np.int32),
                 np.array([1, 1, 2, 0], np.int32),
                 np.array([7, 3, 9, 5], np.int32))
    store = EdgeStore(e, headroom=1.0, bucket=4)
    assert store.n_edges == 2              # (0,1)=3 and (1,0)=5
    assert store.lookup(0, 1) == 3 and store.lookup(1, 0) == 5
    assert store.lookup(2, 2) is None
    el = store.edge_list()
    assert el.n_edges == 2 and int(el.weight.min()) == 3
    cap0 = store.capacity
    store.delete_edge(0, 1)
    store.set_edge(2, 3, 8)                # reuses the freed slot
    assert store.flush() is False          # in-place scatter, no growth
    assert store.capacity == cap0
    assert store.lookup(0, 1) is None and store.lookup(2, 3) == 8
    # force growth past capacity: device arrays are replaced
    for k in range(cap0 + 2):
        store.set_edge(3, k % 3, 1 + k)
    assert store.flush() is True
    assert store.capacity > cap0 and store.uploads == 2


# ---------------------------------------------------------------------------
# coalesce() + remove_self_loops() composition (the insertion contract)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 12), e=st.integers(1, 40), seed=st.integers(0, 10**6))
def test_property_coalesce_self_loop_composition(n, e, seed):
    """Parallel edges keep the MINIMUM weight, in either composition order,
    matching the dense min-matrix oracle — and shortest paths through the
    coalesced graph equal scipy on the min-reduced CSR."""
    from scipy.sparse.csgraph import shortest_path

    r = np.random.default_rng(seed)
    src = r.integers(0, n, e).astype(np.int32)
    dst = r.integers(0, n, e).astype(np.int32)
    w = r.integers(1, 1000, e).astype(np.int32)
    g = EdgeList(n, src, dst, w)
    a = g.coalesce().remove_self_loops()
    b = g.remove_self_loops().coalesce()
    # dense min-reduction oracle (scipy csr SUMS duplicates, so the oracle
    # reduces first and only then builds the matrix)
    m = np.full((n, n), np.inf)
    np.minimum.at(m, (src, dst), w.astype(np.float64))
    np.fill_diagonal(m, np.inf)
    expect = {(i, j): m[i, j] for i, j in zip(*np.where(np.isfinite(m)))}
    for el in (a, b):
        got = {(int(u), int(v)): int(ww)
               for u, v, ww in zip(el.src, el.dst, el.weight)}
        assert got == expect
    if expect:
        d_el = shortest_path(to_scipy_csr(a), method="D", directed=False)
        mm = np.where(np.isfinite(m), m, 0)
        import scipy.sparse as sp
        d_or = shortest_path(sp.csr_matrix(mm), method="D", directed=False)
        np.testing.assert_allclose(d_el, d_or)


# ---------------------------------------------------------------------------
# THE acceptance contract: certified bracket after every batch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["single", "pallas"])
@pytest.mark.parametrize("mix", [
    dict(p_insert=1.0, p_reweight=0.0, p_delete=0.0),          # insert-only
    dict(p_insert=0.4, p_reweight=0.4, p_delete=0.2),          # mixed
    dict(p_insert=0.05, p_reweight=0.05, p_delete=0.9),        # delete-heavy
])
def test_certified_bracket_across_traces_and_backends(backend, mix):
    g = random_geometric(260, avg_degree=3.0, seed=4)
    sess = open_session(g, GraphEngineConfig(backend=backend), tau=4)
    for i, b in enumerate(temporal_trace(g, 3, events_per_batch=16,
                                         seed=11, **mix)):
        rep = sess.apply_updates(b)
        assert rep.action in ("noop", "relax", "repair", "rebuild")
        _certify(sess)


def test_disconnecting_deletions_stay_certified():
    """Cutting the only bridge must flag connected=False while the bracket
    still covers the largest finite-distance pair."""
    u = np.array([0, 1, 2, 3, 4, 5, 2], np.int32)
    v = np.array([1, 2, 0, 4, 5, 3, 3], np.int32)
    w = np.array([5, 5, 5, 7, 7, 7, 100], np.int32)
    g = EdgeList.from_undirected(6, u, v, w)
    sess = open_session(g, tau=2)
    iv0, _ = _certify(sess)
    assert iv0.connected
    rep = sess.apply_updates(UpdateBatch.deletes([2], [3]))
    iv, exact = _certify(sess)
    assert not iv.connected
    assert iv.lower >= 1 and exact >= 7
    # an isolated node via deletion: still certified, still disconnected
    sess.apply_updates(UpdateBatch.deletes([0], [1]))
    sess.apply_updates(UpdateBatch.deletes([0], [2]))
    iv, _ = _certify(sess)
    assert not iv.connected


def test_certified_bracket_sharded_backend_subprocess():
    code = textwrap.dedent("""
    import jax, numpy as np
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    from repro.core import IntervalEstimator, open_session
    from repro.core.distributed import DistributedEngine
    from repro.graph import grid_mesh, temporal_trace
    from repro.graph.structures import to_scipy_csr
    from scipy.sparse.csgraph import shortest_path
    g = grid_mesh(12, "uniform", high=100, seed=3)
    be = DistributedEngine(g, mesh, comm="halo").make_relax_fn()
    sess = open_session(g, tau=4, backend=be)
    for b in temporal_trace(g, 2, events_per_batch=10, seed=7):
        sess.apply_updates(b)   # migrates to the flat device store view
        iv = sess.estimate(IntervalEstimator())
        d = shortest_path(to_scipy_csr(sess.edges), method="D", directed=False)
        exact = int(d[np.isfinite(d)].max())
        assert iv.lower <= exact <= iv.upper, (iv.lower, exact, iv.upper)
    print("DYNAMIC-SHARDED-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DYNAMIC-SHARDED-OK" in out.stdout


@settings(max_examples=6, deadline=None)
@given(n=st.integers(24, 90), ef=st.integers(2, 4), seed=st.integers(0, 10**4),
       wmax=st.sampled_from([10, 1000, 2**20]))
def test_property_certified_bracket_under_updates(n, ef, seed, wmax):
    g = random_connected(n, n * ef, seed=seed, weight_dist="uniform",
                         high=wmax)
    sess = open_session(g, tau=4)
    for b in temporal_trace(g, 2, events_per_batch=10, p_insert=0.3,
                            p_reweight=0.4, p_delete=0.3, seed=seed + 1):
        sess.apply_updates(b)
        _certify(sess)


def test_capped_repair_stays_certified():
    """tighten_cap/regrow_cap bound update latency; stragglers become
    singletons and every bound stays certified. Cutting the largest
    cluster's center out of a unit cycle makes the whole cluster interior
    unreachable within one regrow step, forcing the singleton fallback."""
    n = 48
    u = np.arange(n, dtype=np.int32)
    g = EdgeList.from_undirected(n, u, (u + 1) % n, np.ones(n, np.int32))
    sess = open_session(g, tau=1)
    sess.estimate(DynamicQuotientEstimator())
    dec = sess.dynamic.dec
    vals, counts = np.unique(dec.final_c, return_counts=True)
    c = int(vals[counts.argmax()])
    assert counts.max() >= 4, "need a cluster deep enough to exceed the cap"
    rep = sess.apply_updates(
        UpdateBatch.deletes([c, c], [(c - 1) % n, (c + 1) % n]),
        tighten_cap=1, regrow_cap=1)
    assert rep.action == "repair"
    assert rep.new_singletons > 0  # the cap actually exercised the fallback
    iv, _ = _certify(sess)
    assert not iv.connected  # the center itself is now isolated


def test_session_edge_caches_track_mutations():
    """Regression: apply_updates refreshed the edges mirror and max_weight
    but not _n_edges, so the SSSP estimators derived their distance dtype
    from a stale (n_edges, max_weight) pair — crashing on delete-to-empty
    and, worse, silently wrapping int32 distances (upper < exact) when
    heavy edges were inserted into a session opened near-empty."""
    # delete every edge: estimators must see the empty graph, not crash
    u = np.array([0, 1], np.int32)
    g = EdgeList.from_undirected(3, u, u + 1, np.array([5, 7], np.int32))
    sess = open_session(g, tau=2)
    sess.apply_updates(UpdateBatch.deletes([0, 1], [1, 2]))
    assert sess.n_edges == 0 and sess.edges.n_edges == 0
    iv = sess.estimate(IntervalEstimator())
    assert not iv.connected and iv.lower == iv.upper == 0
    # near-empty open + heavy inserts: dtype choice must see the new edges
    heavy = 2**30 - 1
    g2 = EdgeList.from_undirected(6, np.array([0], np.int32),
                                  np.array([1], np.int32),
                                  np.array([1], np.int32))
    sess2 = open_session(g2, tau=2)
    chain = np.arange(5, dtype=np.int32)
    sess2.apply_updates(UpdateBatch.inserts(
        chain, chain + 1, np.full(5, heavy, np.int32)))
    assert sess2.max_weight == heavy and sess2.n_edges == 10
    iv2 = sess2.estimate(IntervalEstimator())
    exact = _true_diameter(sess2.edges)
    assert exact == 4 * heavy + 1  # the (0,1) unit edge kept its minimum
    assert iv2.connected and iv2.lower <= exact <= iv2.upper


# ---------------------------------------------------------------------------
# repaired certificates and repair accounting
# ---------------------------------------------------------------------------

def test_repaired_certificates_bound_center_distances():
    """After delete/reweight batches every node's pathw still upper-bounds
    its true distance to its assigned center (the invariant the 2R term of
    the upper bound rests on)."""
    from scipy.sparse.csgraph import shortest_path

    g = random_geometric(250, avg_degree=3.0, seed=9)
    sess = open_session(g, tau=4)
    for b in temporal_trace(g, 3, events_per_batch=14, p_insert=0.1,
                            p_reweight=0.4, p_delete=0.5, seed=5):
        sess.apply_updates(b)
        dec = sess.dynamic.dec
        centers = np.unique(dec.final_c)
        d = shortest_path(to_scipy_csr(sess.edges), method="D",
                          directed=False, indices=centers)
        row = {c: i for i, c in enumerate(centers)}
        for v in range(g.n_nodes):
            true = d[row[int(dec.final_c[v])], v]
            assert np.isfinite(true), "assigned center unreachable"
            assert dec.final_pathw[v] >= true - 1e-9
        assert dec.radius == dec.final_pathw.max()


def test_rebuild_fraction_controls_fallback():
    g = random_geometric(200, avg_degree=3.0, seed=2)
    pairs = _undirected_pairs(g)
    # rebuild_fraction=0: ANY retracted certificate forces a full rebuild
    sess = open_session(g, tau=4, rebuild_fraction=0.0)
    dels = pairs[: len(pairs) // 4]
    rep = sess.apply_updates(UpdateBatch.deletes(
        [p[0] for p in dels], [p[1] for p in dels]))
    assert rep.action == "rebuild"
    assert sess.dynamic.metrics.full_rebuilds == 1
    _certify(sess)
    # a permissive threshold takes the incremental path on the same batch
    sess2 = open_session(g, tau=4, rebuild_fraction=1.0)
    rep2 = sess2.apply_updates(UpdateBatch.deletes(
        [p[0] for p in dels], [p[1] for p in dels]))
    assert rep2.action == "repair"
    assert sess2.dynamic.metrics.full_rebuilds == 0
    _certify(sess2)
    with pytest.raises(ValueError, match="rebuild_fraction"):
        open_session(g, rebuild_fraction=1.5)


def test_update_metrics_accounting():
    g = random_geometric(220, avg_degree=3.0, seed=3)
    sess = open_session(g, tau=4)
    sess.estimate()  # static default: full pipeline
    trace = temporal_trace(g, 3, events_per_batch=12, seed=2)
    for b in trace:
        sess.apply_updates(b)
    m = sess.dynamic.metrics
    assert m.batches == 3
    assert m.baseline_supersteps > 0
    assert m.update_supersteps > 0
    assert m.relax_batches + m.repair_batches + m.full_rebuilds <= 3
    assert m.amortized_supersteps == pytest.approx(
        (m.update_supersteps + m.rebuild_supersteps) / 3)
    # post-update default estimate uses the maintained state
    est = sess.estimate()
    assert est.method == "dynamic-quotient"
    # a second estimate with no interleaved update is served from cache
    pm0 = est.pipeline.total_host_syncs
    est2 = sess.estimate()
    assert est2.pipeline.total_host_syncs == 0 <= pm0
    assert est2.phi_approx == est.phi_approx


# ---------------------------------------------------------------------------
# incremental quotient refresh == full recompute
# ---------------------------------------------------------------------------

def test_incremental_quotient_matches_full_recompute():
    g = random_geometric(400, avg_degree=3.0, seed=7)
    sess = open_session(g, tau=6)
    sess.estimate(DynamicQuotientEstimator())
    st = sess.dynamic
    for b in temporal_trace(g, 3, events_per_batch=10, seed=13):
        sess.apply_updates(b)
        if st.quotient_stale:
            continue  # cluster set changed: full recompute is the only path
        inc = sess.estimate(DynamicQuotientEstimator())
        k_inc, m_inc, wmax_inc, wsum_inc = st.dq_counters
        st.quotient_stale, st.solution, st.dq = True, None, None
        full = sess.estimate(DynamicQuotientEstimator())
        assert (inc.phi_approx, inc.connected) == (full.phi_approx,
                                                   full.connected)
        k_full, m_full, wmax_full, wsum_full = st.dq_counters
        assert (k_inc, m_inc, wsum_inc) == (k_full, m_full, wsum_full)
        # the full kernel records the PRE-coalesce max (conservative
        # envelope for the int32 fast-path pick); the merge records the
        # tighter coalesced max — both sound, merge never above full
        assert wmax_inc <= wmax_full
        np.testing.assert_array_equal(inc.quotient_ecc, full.quotient_ecc)


def test_dynamic_estimator_matches_static_bound_contract():
    """On a session with NO updates, the dynamic estimator reports the
    maintained decomposition's certified upper bound (same contract as
    ClusterQuotientEstimator, same quotient pipeline) without touching the
    session's warm-query residency counters."""
    g = random_geometric(300, avg_degree=3.0, seed=8)
    exact = _true_diameter(g)
    sess = open_session(g, tau=4)
    est = sess.estimate(DynamicQuotientEstimator())
    assert est.connected and est.upper >= exact
    assert est.phi_approx == est.phi_quotient + 2 * est.radius
    flat = sess.estimate(ClusterQuotientEstimator())
    assert flat.upper >= exact
    m = sess.metrics
    assert m.backend_builds == 1 and m.edge_uploads == 1
    assert isinstance(DynamicQuotientEstimator(), DiameterEstimator)


# ---------------------------------------------------------------------------
# temporal_trace generator
# ---------------------------------------------------------------------------

def test_temporal_trace_contract():
    g = random_geometric(120, avg_degree=3.0, seed=1)
    a = temporal_trace(g, 3, events_per_batch=9, seed=4)
    b = temporal_trace(g, 3, events_per_batch=9, seed=4)
    assert len(a) == 3
    for x, y in zip(a, b):  # seeded determinism
        for f in ("insert_src", "reweight_src", "delete_src",
                  "insert_weight", "reweight_weight"):
            np.testing.assert_array_equal(getattr(x, f), getattr(y, f))
    wlo, whi = int(g.weight.min()), int(g.weight.max())
    live = {(int(u), int(v)) for u, v in zip(g.src, g.dst) if u < v}
    for batch in a:
        assert batch.n_events > 0
        for w in (batch.insert_weight, batch.reweight_weight):
            if len(w):
                assert w.min() >= wlo and w.max() <= whi
        # replay the canonical (u<v) events against the live pair set
        for u, v in zip(batch.insert_src, batch.insert_dst):
            if u < v:
                assert (int(u), int(v)) not in live
                live.add((int(u), int(v)))
        for u, v in zip(batch.reweight_src, batch.reweight_dst):
            if u < v:
                assert (int(u), int(v)) in live
        for u, v in zip(batch.delete_src, batch.delete_dst):
            if u < v:
                assert (int(u), int(v)) in live
                live.remove((int(u), int(v)))
    with pytest.raises(ValueError, match="insert_mode"):
        temporal_trace(g, 1, insert_mode="bogus")
    with pytest.raises(ValueError, match="probability"):
        temporal_trace(g, 1, p_insert=0, p_reweight=0, p_delete=0)
    with pytest.raises(ValueError, match="n_batches"):
        temporal_trace(g, -1)


# ---------------------------------------------------------------------------
# serve driver: estimator-name validation (regression)
# ---------------------------------------------------------------------------

def test_serve_rejects_unknown_estimator_names():
    """Regression: _resolve_sync_budget quietly fell back to the cluster
    budget for ANY unrecognized estimator name, and _make_estimator raised
    a bare KeyError."""
    from repro.launch.serve import _make_estimator, _resolve_sync_budget

    with pytest.raises(ValueError, match="unknown estimator 'bogus'"):
        _make_estimator("bogus")
    with pytest.raises(ValueError, match="unknown estimator 'cluster2'"):
        _resolve_sync_budget("off", "cluster2")
    assert _resolve_sync_budget("off", "cluster") is None
    assert _resolve_sync_budget("7", "dynamic") == 7
    est = _make_estimator("dynamic")
    assert est.name == "dynamic-quotient"
