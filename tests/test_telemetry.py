"""Telemetry subsystem tests: span tracer semantics (nesting, exclusive
transfer attribution), streaming-histogram quantiles against numpy on
adversarial distributions, exporter round-trips, the TransferMeter
bounded-memory regression, and the zero-extra-sync contract — the PR 8
transfer-equality assertions must hold bit-identically with tracing on.
"""
import json

import numpy as np
import pytest

from repro.analysis import guard
from repro.runtime import telemetry
from repro.runtime.telemetry import (
    MetricsRegistry,
    StreamingHistogram,
    Tracer,
    export_chrome_trace,
    export_jsonl,
    export_prometheus,
    write_telemetry,
)


# ---------------------------------------------------------------------------
# span tracer: nesting, null path, exclusive attribution
# ---------------------------------------------------------------------------


class TestTracer:
    def test_span_without_tracer_is_null_singleton(self):
        s1 = telemetry.span("a", x=1)
        s2 = telemetry.span("b")
        assert s1 is s2 is telemetry.NULL_SPAN
        with s1 as sp:           # usable, inert
            sp.set(anything=2)

    def test_nesting_parent_child_indices(self):
        t = Tracer()
        with telemetry.tracing(t):
            with telemetry.span("outer"):
                with telemetry.span("inner.a"):
                    pass
                with telemetry.span("inner.b"):
                    pass
        by_name = {s.name: s for s in t.spans}
        outer, a, b = by_name["outer"], by_name["inner.a"], by_name["inner.b"]
        assert outer.parent is None and outer.depth == 0
        assert a.parent == outer.index and a.depth == 1
        assert b.parent == outer.index and b.depth == 1
        assert a.index < b.index               # start order
        assert t.spans[-1].name == "outer"     # close order
        assert outer.duration >= a.duration + b.duration - 1e-9

    def test_non_lifo_close_raises(self):
        t = Tracer()
        with telemetry.tracing(t):
            s1 = telemetry.span("a")
            s2 = telemetry.span("b")
            s1.__enter__()
            s2.__enter__()
            with pytest.raises(RuntimeError):
                s1.__exit__(None, None, None)
            s2.__exit__(None, None, None)
            s1.__exit__(None, None, None)

    def test_exclusive_attribution_partitions_measured(self):
        """The headline invariant: under a root span, the sum of per-span
        EXCLUSIVE transfer counts equals the measured total — every fetch
        is attributed to exactly one (the innermost live) span."""
        t = Tracer()
        with telemetry.tracing(t), guard.metered() as meter:
            with telemetry.span("root"):
                guard.fetch(np.arange(4), reason="root-level fetch")
                with telemetry.span("child"):
                    guard.fetch(np.arange(8), reason="child fetch")
                    guard.fetch(np.arange(2), reason="child fetch")
                with telemetry.span("empty-child"):
                    pass
        assert meter.transfers == 3
        assert t.total_transfers() == meter.transfers
        by_name = {s.name: s for s in t.spans}
        assert by_name["child"].transfers == 2
        assert by_name["child"].elements == 10
        assert by_name["root"].transfers == 1          # exclusive
        assert by_name["root"].transfers_incl == 3     # inclusive
        assert by_name["empty-child"].transfers == 0
        assert by_name["child"].by_reason == {"child fetch": 2}
        attr = t.attribution()
        assert attr["root"] == {"root-level fetch": 1}
        assert "empty-child" not in attr

    def test_tracing_adds_no_transfers(self):
        """Zero-extra-sync contract at the meter level: a traced region
        and an untraced region running the same fetches measure the same
        count (spans are pure host bookkeeping)."""
        def work():
            with telemetry.span("w"):
                guard.fetch(np.arange(3), reason="work")

        with guard.metered() as m_off:
            work()                      # no tracer installed -> NULL_SPAN
        t = Tracer()
        with telemetry.tracing(t), guard.metered() as m_on:
            work()
        assert m_on.transfers == m_off.transfers == 1
        assert m_on.elements == m_off.elements


# ---------------------------------------------------------------------------
# TransferMeter: bounded per-reason aggregation (regression for the
# unbounded .events list)
# ---------------------------------------------------------------------------


class TestTransferMeterAggregation:
    def test_ten_thousand_fetches_aggregate_not_accumulate(self):
        """10k fetches over 3 distinct reasons must aggregate into 3
        Counter entries — the meter's footprint is O(distinct reasons),
        not O(fetches). (The old ``events`` list grew one tuple per
        fetch; a long-lived serve loop leaked without bound.)"""
        x = np.arange(5)
        with guard.metered() as m:
            for i in range(10_000):
                guard.fetch(x, reason=f"reason-{i % 3}")
        assert m.transfers == 10_000
        assert m.elements == 50_000
        assert not hasattr(m, "events")
        assert len(m.reason_counts) == 3
        assert m.reasons() == ["reason-0", "reason-1", "reason-2"]
        assert m.by_reason()["reason-1"] == (3333, 16665)
        counts = m.by_reason()
        assert sum(c for c, _ in counts.values()) == 10_000
        assert sum(e for _, e in counts.values()) == 50_000

    def test_reasons_first_seen_order_distinct(self):
        with guard.metered() as m:
            guard.fetch(np.arange(1), reason="b")
            guard.fetch(np.arange(1), reason="a")
            guard.fetch(np.arange(1), reason="b")
        assert m.reasons() == ["b", "a"]

    def test_pop_meter_non_lifo_raises(self):
        m1 = guard.push_meter()
        m2 = guard.push_meter()
        with pytest.raises(RuntimeError):
            guard.pop_meter(m1)
        guard.pop_meter(m2)
        guard.pop_meter(m1)


# ---------------------------------------------------------------------------
# streaming histogram: quantiles vs numpy on adversarial distributions
# ---------------------------------------------------------------------------


def _fill(values):
    h = StreamingHistogram()
    for v in values:
        h.record(float(v))
    return h


class TestStreamingHistogram:
    def test_empty_histogram_is_all_zero(self):
        h = StreamingHistogram()
        assert h.quantile(0.5) == 0.0
        s = h.summary()
        assert s == {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                     "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}

    def test_constant_distribution_is_exact(self):
        h = _fill([3.25] * 1000)
        for q in (0.01, 0.5, 0.95, 0.99, 1.0):
            assert h.quantile(q) == pytest.approx(3.25, rel=1e-12)
        assert h.summary()["mean"] == pytest.approx(3.25)

    def test_bimodal_distribution(self):
        """Two far-apart spikes: every quantile must snap to one of the
        modes (the clamp to observed [min, max] plus log-bucketing keeps
        each mode in its own bucket)."""
        vals = [0.001] * 500 + [1000.0] * 500
        h = _fill(vals)
        assert h.quantile(0.25) == pytest.approx(0.001, rel=0.05)
        assert h.quantile(0.75) == pytest.approx(1000.0, rel=0.05)
        assert h.quantile(0.0) == pytest.approx(0.001, rel=0.05)
        assert h.quantile(1.0) == pytest.approx(1000.0, rel=1e-12)

    def test_heavy_tail_vs_numpy(self):
        rng = np.random.default_rng(7)
        vals = rng.lognormal(mean=2.0, sigma=1.5, size=20_000)
        h = _fill(vals)
        for q in (0.5, 0.95, 0.99):
            ref = float(np.percentile(vals, q * 100))
            assert h.quantile(q) == pytest.approx(ref, rel=0.08), q

    def test_uniform_vs_numpy(self):
        rng = np.random.default_rng(3)
        vals = rng.uniform(0.5, 100.0, size=10_000)
        h = _fill(vals)
        for q in (0.5, 0.95, 0.99):
            ref = float(np.percentile(vals, q * 100))
            assert h.quantile(q) == pytest.approx(ref, rel=0.08), q

    def test_merge_is_associative_and_matches_single_pass(self):
        rng = np.random.default_rng(11)
        a, b, c = (rng.exponential(5.0, size=3000) for _ in range(3))
        hab_c = _fill(a); hab_c.merge(_fill(b))
        habc1 = StreamingHistogram(); habc1.merge(hab_c); habc1.merge(_fill(c))
        hbc = _fill(b); hbc.merge(_fill(c))
        habc2 = _fill(a); habc2.merge(hbc)
        one = _fill(np.concatenate([a, b, c]))
        for q in (0.5, 0.95, 0.99):
            assert habc1.quantile(q) == pytest.approx(habc2.quantile(q),
                                                      rel=1e-12)
            assert habc1.quantile(q) == pytest.approx(one.quantile(q),
                                                      rel=1e-12)
        assert habc1.summary()["count"] == 9000

    def test_negative_and_nan_rejected(self):
        h = StreamingHistogram()
        with pytest.raises(ValueError):
            h.record(-1.0)
        with pytest.raises(ValueError):
            h.record(float("nan"))

    def test_tiny_values_hit_underflow_bucket(self):
        h = _fill([0.0, 1e-15, 1e-13])
        assert h.summary()["count"] == 3
        assert h.quantile(0.5) == pytest.approx(0.0, abs=1e-12)


# ---------------------------------------------------------------------------
# exporters: Perfetto round-trip, JSONL, Prometheus
# ---------------------------------------------------------------------------


def _traced_tracer():
    t = Tracer()
    with telemetry.tracing(t):
        with telemetry.span("outer", stage=1):
            guard.fetch(np.arange(6), reason="outer fetch")
            with telemetry.span("inner", level=2) as sp:
                guard.fetch(np.arange(4), reason="inner fetch")
                sp.set(supersteps=7)
    return t


class TestExporters:
    def test_chrome_trace_round_trip(self, tmp_path):
        """Re-parse the exported trace: span nesting must be recoverable
        from the timestamps (child interval inside parent interval) and
        the attached counters must survive in ``args``."""
        t = _traced_tracer()
        path = tmp_path / "trace.json"
        export_chrome_trace(t, str(path))
        payload = json.loads(path.read_text())
        events = payload["traceEvents"]
        assert [e["name"] for e in events] == ["outer", "inner"]
        outer, inner = events
        assert all(e["ph"] == "X" for e in events)
        # nesting: inner's [ts, ts+dur] within outer's
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0
        # attached counters + attribution ride in args
        assert outer["args"]["stage"] == 1
        assert outer["args"]["transfers"] == 1          # exclusive
        assert inner["args"]["supersteps"] == 7
        assert inner["args"]["transfers"] == 1
        assert inner["args"]["elements"] == 4
        assert inner["args"]["transfer_reasons"] == {"inner fetch": 1}

    def test_jsonl_spans_and_snapshot(self, tmp_path):
        t = _traced_tracer()
        reg = MetricsRegistry()
        reg.counter("c", 3)
        reg.observe("lat", 0.5)
        path = tmp_path / "spans.jsonl"
        export_jsonl(t, reg.snapshot(), str(path))
        lines = [json.loads(l) for l in path.read_text().splitlines()]
        spans = [l for l in lines if l["type"] == "span"]
        snap = [l for l in lines if l["type"] == "snapshot"]
        assert len(spans) == 2 and len(snap) == 1
        inner = next(s for s in spans if s["name"] == "inner")
        outer = next(s for s in spans if s["name"] == "outer")
        assert inner["parent"] == outer["index"]
        assert inner["by_reason"] == {"inner fetch": 1}
        assert snap[0]["counters"]["c"] == 3
        assert snap[0]["histograms"]["lat"]["count"] == 1

    def test_prometheus_text(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("engine.host_syncs", 4)
        reg.gauge("pool.sessions", 2)
        for v in (0.1, 0.2, 0.3):
            reg.observe("serve.latency.cascade", v)
        path = tmp_path / "metrics.prom"
        export_prometheus(reg.snapshot(), str(path))
        text = path.read_text()
        assert "engine_host_syncs_total 4" in text
        assert "pool_sessions 2" in text
        assert 'serve_latency_cascade{quantile="0.5"}' in text
        assert "serve_latency_cascade_count 3" in text

    def test_write_telemetry_bundle(self, tmp_path):
        t = _traced_tracer()
        reg = MetricsRegistry()
        reg.counter("x", 1)
        paths = write_telemetry(str(tmp_path), tracer=t, registry=reg)
        assert set(paths) == {"trace", "jsonl", "prom"}
        for p in paths.values():
            assert (tmp_path / p).exists() or __import__("os").path.exists(p)
        json.loads(open(paths["trace"]).read())   # parses

    def test_numpy_scalar_attrs_serialize(self, tmp_path):
        t = Tracer()
        with telemetry.tracing(t):
            with telemetry.span("s") as sp:
                sp.set(k=np.int32(5), v=np.float64(1.5))
        export_chrome_trace(t, str(tmp_path / "t.json"))
        args = json.loads((tmp_path / "t.json").read_text())[
            "traceEvents"][0]["args"]
        assert args["k"] == 5 and args["v"] == 1.5


# ---------------------------------------------------------------------------
# registry ingestion of the existing metrics dataclasses
# ---------------------------------------------------------------------------


class TestMetricsRegistry:
    def test_ingest_dataclass_and_meter(self):
        from repro.core.session import SessionMetrics

        sm = SessionMetrics()
        sm.sessions_opened = 2
        sm.queries = 5
        with guard.metered() as m:
            guard.fetch(np.arange(3), reason="r1")
            guard.fetch(np.arange(3), reason="r1")
        reg = MetricsRegistry()
        reg.ingest(sm, "session")
        reg.ingest(m, "serve.transfers")
        snap = reg.snapshot()
        assert snap.counters["session.sessions_opened"] == 2
        assert snap.counters["session.queries"] == 5
        assert snap.counters["serve.transfers.transfers"] == 2
        assert snap.counters["serve.transfers.elements"] == 6
        assert snap.counters["serve.transfers.reason.r1"] == 2

    def test_histogram_summary_in_snapshot(self):
        reg = MetricsRegistry()
        for v in range(1, 101):
            reg.observe("lat", float(v))
        s = reg.snapshot().histograms["lat"]
        assert s["count"] == 100
        assert s["p50"] == pytest.approx(50.0, rel=0.1)
        assert s["p99"] == pytest.approx(99.0, rel=0.1)


# ---------------------------------------------------------------------------
# zero-extra-sync contract: PR 8 transfer equalities under tracing
# ---------------------------------------------------------------------------


def _graph():
    from repro.graph import random_geometric

    return random_geometric(512, avg_degree=6.0, seed=1)


class TestEqualityContractsUnderTracing:
    def test_stages_equality_holds_traced(self):
        from repro.core import cluster

        t = Tracer()
        with telemetry.tracing(t), guard.measured_transfers() as meter:
            dec = cluster(_graph(), 12, seed=0)
        m = dec.metrics
        assert meter.transfers == m.host_syncs + m.finalize_syncs
        # and every one of them is attributed to a named span
        assert t.total_transfers() == meter.transfers
        # (tau=12 at n=512 keeps the stage threshold above n, so the
        # stage loop may not run — finalize always does)
        assert "engine.finalize" in {s.name for s in t.spans}

    def test_pipeline_equality_holds_traced(self):
        from repro.core import ClusterQuotientEstimator, open_session

        t = Tracer()
        with telemetry.tracing(t):
            with open_session(_graph(), tau=12) as sess:
                with guard.measured_transfers() as meter:
                    res = sess.estimate(ClusterQuotientEstimator())
        assert meter.transfers == res.pipeline.total_host_syncs

    def test_traced_equals_untraced_decomposition(self):
        """Determinism: tracing must not change the computation — same
        decomposition, same sync count, traced or not."""
        from repro.core import cluster

        with guard.measured_transfers() as m_off:
            dec_off = cluster(_graph(), 12, seed=0)
        t = Tracer()
        with telemetry.tracing(t), guard.measured_transfers() as m_on:
            dec_on = cluster(_graph(), 12, seed=0)
        assert m_on.transfers == m_off.transfers
        np.testing.assert_array_equal(dec_on.final_c, dec_off.final_c)
        np.testing.assert_array_equal(dec_on.final_pathw, dec_off.final_pathw)
