"""Distributed engine + sharding + pipeline-parallel tests on a small
in-process device mesh (spawned via subprocess so XLA_FLAGS can force 4
host devices without polluting other tests' single-device world)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_engine_matches_single_device():
    out = _run("""
    import jax, numpy as np
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    from repro.graph import grid_mesh
    from repro.core import approximate_diameter
    from repro.core.distributed import DistributedEngine
    g = grid_mesh(32, "bimodal", heavy_w=500, heavy_p=0.1, seed=7)
    single = approximate_diameter(g, tau=16)
    for comm in ("allgather", "halo"):
        eng = DistributedEngine(g, mesh, comm=comm)
        dist = approximate_diameter(g, tau=16, relax_fn=eng.make_relax_fn())
        # same seed => identical decomposition => identical estimate
        assert dist.phi_approx == single.phi_approx, (comm, dist, single)
        assert dist.n_clusters == single.n_clusters
    print("MATCH")
    """)
    assert "MATCH" in out


def test_distributed_engine_superstep_lowers_with_collectives():
    out = _run("""
    import jax
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    from repro.graph import social_like
    from repro.core.distributed import DistributedEngine
    from repro.runtime.roofline import parse_collectives
    g = social_like(8, 4, seed=3)
    eng = DistributedEngine(g, mesh, comm="allgather")
    lowered = eng.lower_superstep()
    compiled = lowered.compile()
    st = parse_collectives(compiled.as_text())
    assert "all-gather" in st.counts, st.counts
    print("COLLECTIVES", st.counts)
    """)
    assert "COLLECTIVES" in out


def test_halo_traffic_less_than_allgather():
    """The halo exchange must move fewer bytes than the full all-gather on a
    locality-friendly graph (the paper's partitioner makes this gap bigger)."""
    out = _run("""
    import jax
    mesh = jax.make_mesh((4,), ("data",))
    from repro.graph import grid_mesh
    from repro.core.distributed import DistributedEngine
    from repro.runtime.roofline import parse_collectives
    g = grid_mesh(32, "unit")
    stats = {}
    for comm in ("allgather", "halo"):
        eng = DistributedEngine(g, mesh, comm=comm)
        st = parse_collectives(eng.lower_superstep().compile().as_text())
        stats[comm] = st.wire_bytes
    assert stats["halo"] < stats["allgather"], stats
    print("BYTES", stats)
    """)
    assert "BYTES" in out


def test_cluster_partition_reduces_cut():
    out = _run("""
    import numpy as np
    from repro.graph import grid_mesh
    from repro.graph.partition import (apply_partition, cluster_partition,
                                       cut_fraction)
    from repro.core import cluster
    g = grid_mesh(32, "unit")
    # baseline a real framework faces: arbitrary (hash) node order
    r = np.random.default_rng(0)
    rand_perm = r.permutation(g.n_nodes).astype(np.int32)
    g_rand, _ = apply_partition(g, rand_perm)
    rand_cut = cut_fraction(g_rand, 4)
    dec = cluster(g, 16, seed=0)
    perm = cluster_partition(dec.final_c[rand_perm], 4)
    g2, _ = apply_partition(g_rand, perm)
    new_cut = cut_fraction(g2, 4)
    assert new_cut < 0.5 * rand_cut, (rand_cut, new_cut)
    print("CUT rand=%.3f cluster=%.3f" % (rand_cut, new_cut))
    """)
    assert "CUT" in out


def test_lm_cell_lowers_on_tiny_mesh_and_runs():
    """build_cell smoke-scale on a 2x2 mesh: lower, compile, EXECUTE."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import build_cell
    mesh = make_mesh((2, 2), ("data", "model"))
    import repro.config.base as base
    # shrink shapes for execution
    base.LM_SHAPES = tuple(
        s.__class__(**{**s.__dict__, "seq_len": 32, "global_batch": 4})
        for s in base.LM_SHAPES
    )
    cell = build_cell("mistral-nemo-12b", "train_4k", mesh, smoke=True)
    with mesh:
        fn = jax.jit(cell.step_fn, out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate)
        compiled = fn.lower(*cell.arg_specs).compile()
        # execute with real zeros matching the specs
        args = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype, device=s.sharding),
            cell.arg_specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        params, opt, loss, stats = compiled(*args)
        assert not bool(jnp.isnan(loss)), loss
    print("LOSS", float(loss))
    """)
    assert "LOSS" in out


def test_pipeline_parallel_gpipe():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.runtime.pipeline import gpipe_forward, stage_split
    mesh = jax.make_mesh((4,), ("pod",))
    L, D = 8, 16
    r = np.random.default_rng(0)
    w = jnp.asarray(r.standard_normal((L, D, D)).astype(np.float32)) * 0.3

    def stage_fn(sp, x):     # sp [L/4, D, D]
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        y, _ = jax.lax.scan(body, x, sp)
        return y

    run = gpipe_forward(mesh, stage_fn, n_micro=4, pod_axis="pod")
    x = jnp.asarray(r.standard_normal((8, D)).astype(np.float32))
    y_pipe = run(stage_split(w, 4), x)

    y_ref = x
    for i in range(L):
        y_ref = jnp.tanh(y_ref @ w[i])
    err = float(jnp.abs(y_pipe - y_ref).max())
    assert err < 1e-5, err
    print("PIPE OK", err)
    """)
    assert "PIPE OK" in out


def test_int8_allreduce_shardmap():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.common.compat import shard_map
    from repro.runtime.compression import int8_allreduce_shardmap
    mesh = jax.make_mesh((4,), ("data",))
    reduce_fn = int8_allreduce_shardmap(mesh, "data")
    r = np.random.default_rng(0)
    local = jnp.asarray(r.standard_normal((4, 1024)).astype(np.float32))

    def f(x):
        return reduce_fn({"g": x})["g"]

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                            out_specs=P("data"), check_vma=False))(local)
    want = jnp.broadcast_to(local.mean(0, keepdims=True), local.shape)
    rel = float(jnp.abs(out - want).max() / (jnp.abs(want).max() + 1e-9))
    assert rel < 0.05, rel     # int8 wire: ~1% quantization error budget
    print("INT8 OK", rel)
    """)
    assert "INT8 OK" in out
