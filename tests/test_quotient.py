"""Device-resident quotient pipeline: jitted build_quotient parity with the
numpy oracle across backends (random + degenerate graphs), int64 exactness
of the batched multi-source solve against the scipy oracle, the unified
(diameter, connected) contract, the end-to-end host-sync budget, and the
batched multi-graph entry point."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    approximate_diameter,
    approximate_diameter_batch,
    build_quotient,
    build_quotient_numpy,
    cluster,
    make_backend,
    quotient_diameter,
    quotient_diameter_device,
    quotient_diameter_minplus,
    QuotientGraph,
)
from repro.core.engine import Decomposition
from repro.graph import grid_mesh, random_connected, random_geometric, social_like
from repro.graph.structures import EdgeList

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _assert_quotient_equal(a: QuotientGraph, b: QuotientGraph):
    assert a.n_clusters == b.n_clusters
    np.testing.assert_array_equal(a.center_ids, b.center_ids)
    np.testing.assert_array_equal(a.src, b.src)
    np.testing.assert_array_equal(a.dst, b.dst)
    np.testing.assert_array_equal(a.weight, b.weight)


def _manual_dec(final_c: np.ndarray, final_pathw: np.ndarray) -> Decomposition:
    n = len(final_c)
    return Decomposition(
        n_nodes=n, final_c=final_c.astype(np.int32),
        final_pathw=final_pathw.astype(np.int32),
        radius=int(final_pathw.max()) if n else 0, delta_end=1,
        n_clusters=len(np.unique(final_c)) if n else 0,
        n_stages=1, growing_steps=0,
    )


# ---------------------------------------------------------------------------
# jitted build_quotient == numpy oracle, edge for edge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gen,kw,tau", [
    (random_geometric, dict(n=1200, avg_degree=3.0), 10),
    (social_like, dict(n_log2=8, edge_factor=6, weight_dist="uniform",
                       high=2**20), 6),
    (grid_mesh, dict(side=20, weight_dist="bimodal", heavy_w=500,
                     heavy_p=0.15), 8),
])
@pytest.mark.parametrize("backend", ["single", "pallas"])
def test_build_quotient_parity_random(gen, kw, tau, backend):
    g = gen(**kw, seed=7)
    be = make_backend(g, backend)
    dec = cluster(g, tau, seed=2, backend=be)
    _assert_quotient_equal(build_quotient_numpy(g, dec),
                           build_quotient(g, dec, backend=be))


def test_build_quotient_parity_sharded():
    """Sharded backend (forced 4-device host mesh, subprocess so the XLA
    device count doesn't leak) — the quotient reads the engine's per-device
    edge shards with no host round-trip and must match numpy exactly."""
    code = textwrap.dedent("""
    import jax, numpy as np
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    from repro.graph import grid_mesh
    from repro.core import build_quotient, build_quotient_numpy, cluster
    from repro.core.distributed import DistributedEngine
    g = grid_mesh(24, "bimodal", heavy_w=500, heavy_p=0.15, seed=3)
    be = DistributedEngine(g, mesh, comm="halo").make_relax_fn()
    dec = cluster(g, 12, seed=5, relax_fn=be)
    a = build_quotient_numpy(g, dec)
    b = build_quotient(g, dec, backend=be)
    assert a.n_clusters == b.n_clusters
    assert np.array_equal(a.src, b.src) and np.array_equal(a.dst, b.dst)
    assert np.array_equal(a.weight, b.weight)
    assert np.array_equal(a.center_ids, b.center_ids)
    print("QUOTIENT-SHARDED-PARITY-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "QUOTIENT-SHARDED-PARITY-OK" in out.stdout


def test_build_quotient_parity_degenerate():
    z = np.array([], np.int32)
    # empty graph
    _assert_quotient_equal(
        build_quotient_numpy(EdgeList(0, z, z, z), _manual_dec(z, z)),
        build_quotient(EdgeList(0, z, z, z), _manual_dec(z, z)))
    # edgeless nodes (every node a singleton cluster, no quotient edges)
    dec = _manual_dec(np.arange(5), np.zeros(5))
    _assert_quotient_equal(build_quotient_numpy(EdgeList(5, z, z, z), dec),
                           build_quotient(EdgeList(5, z, z, z), dec))
    # single cluster: every edge internal
    g = grid_mesh(4, "unit")
    dec1 = _manual_dec(np.zeros(g.n_nodes), np.ones(g.n_nodes))
    q_np, q_dev = build_quotient_numpy(g, dec1), build_quotient(g, dec1)
    _assert_quotient_equal(q_np, q_dev)
    assert q_dev.n_clusters == 1 and len(q_dev.src) == 0
    # disconnected graph
    u = np.array([0, 1, 2, 3, 4, 5], np.int32)
    v = np.array([1, 2, 0, 4, 5, 3], np.int32)
    gd = EdgeList.from_undirected(6, u, v, np.ones(6, np.int32))
    dec2 = _manual_dec(np.array([0, 0, 2, 3, 3, 5]), np.array([0, 1, 0, 0, 1, 0]))
    _assert_quotient_equal(build_quotient_numpy(gd, dec2),
                           build_quotient(gd, dec2))


# ---------------------------------------------------------------------------
# solve: int64 exactness + unified (diameter, connected) contract
# ---------------------------------------------------------------------------

def _synthetic_quotient(k: int, m: int, wmin: int, wmax: int, seed: int = 0):
    """Random coalesced undirected quotient (one direction per pair — the
    solvers symmetrize, matching scipy's directed=False)."""
    rng = np.random.default_rng(seed)
    iu, iv = np.triu_indices(k, 1)
    sel = rng.choice(len(iu), size=min(m, len(iu)), replace=False)
    w = rng.integers(wmin, wmax, len(sel)).astype(np.int64)
    return QuotientGraph(k, np.arange(k, dtype=np.int32),
                         iu[sel].astype(np.int32), iv[sel].astype(np.int32), w)


def test_minplus_int64_regression_above_2_24():
    """Regression: the min-plus fallback cast int64 weights to float32,
    silently corrupting anything above 2^24. Cross-check the scipy oracle
    on weights well past that."""
    q = _synthetic_quotient(24, 90, 2**24, 2**30, seed=1)
    d_sp, c_sp = quotient_diameter(q)
    d_mp, c_mp = quotient_diameter_minplus(q)
    assert d_sp > 2**24
    assert (d_mp, c_mp) == (d_sp, c_sp)


def test_device_solve_exact_int64_up_to_2_40():
    """Acceptance: the device quotient solve matches the scipy oracle
    EXACTLY on int64 weights up to 2^40."""
    q = _synthetic_quotient(30, 90, 2**39, 2**40, seed=2)
    d_sp, c_sp = quotient_diameter(q)
    d_dev, ecc, c_dev = quotient_diameter_device(q)
    assert d_sp > 2**32  # float32 would corrupt this
    assert (d_dev, c_dev) == (d_sp, c_sp)
    assert int(ecc.max()) == d_sp
    assert len(ecc) == q.n_clusters


def test_quotient_solvers_agree_on_disconnected():
    """Regression: the fallback used to return a bare finite max on a
    disconnected quotient while scipy returned (diameter, connected). All
    three paths now share the contract."""
    q = QuotientGraph(4, np.arange(4, dtype=np.int32),
                      np.array([0, 1], np.int32), np.array([1, 0], np.int32),
                      np.array([7, 7], np.int64))
    assert quotient_diameter(q) == (7, False)
    assert quotient_diameter_minplus(q) == (7, False)
    d, ecc, connected = quotient_diameter_device(q)
    assert (d, connected) == (7, False)


@pytest.mark.parametrize("gen,kw,tau", [
    (grid_mesh, dict(side=16, weight_dist="uniform", high=100), 8),
    (random_connected, dict(n=400, n_edges=1400, weight_dist="uniform",
                            high=2**20), 8),
])
def test_device_solver_matches_scipy_end_to_end(gen, kw, tau):
    g = gen(**kw, seed=9)
    dev = approximate_diameter(g, tau=tau)
    ora = approximate_diameter(g, tau=tau, solver="scipy")
    assert dev.phi_approx == ora.phi_approx
    assert dev.phi_quotient == ora.phi_quotient
    assert dev.connected == ora.connected


# ---------------------------------------------------------------------------
# pipeline host-sync budget + batched entry point
# ---------------------------------------------------------------------------

def test_pipeline_host_sync_budget():
    g = random_geometric(3000, avg_degree=3.0, seed=4)
    est = approximate_diameter(g, tau=16)
    pm = est.pipeline
    assert pm is not None
    assert pm.finalize_syncs == 1
    assert pm.quotient_syncs == 1
    assert pm.solve_syncs <= 1
    assert pm.total_host_syncs <= 8, pm


def test_batch_matches_individual_runs():
    graphs = [random_geometric(600, avg_degree=3.0, seed=s) for s in range(3)]
    batch = approximate_diameter_batch(graphs, tau=8)
    for g, est in zip(graphs, batch):
        solo = approximate_diameter(g, tau=8)
        assert est.phi_approx == solo.phi_approx
        assert est.n_clusters == solo.n_clusters
        assert est.connected == solo.connected


def test_batch_mixed_sizes_and_degenerates():
    z = np.array([], np.int32)
    graphs = [grid_mesh(6, "unit"), EdgeList(3, z, z, z), grid_mesh(6, "unit", seed=1)]
    ests = approximate_diameter_batch(graphs, tau=4)
    assert len(ests) == 3
    assert ests[0].phi_approx == approximate_diameter(graphs[0], tau=4).phi_approx
    assert not ests[1].connected  # 3 isolated nodes
