"""Tests for ``repro.analysis``: the static checkers (on a fixture corpus
of known-good / known-bad snippets, including regression snippets for the
PR 4 int32-overflow and PR 3 --tau-0 falsy-coercion bug classes) and the
runtime transfer-guard equality contracts
(``guard.measured_transfers() == the hand-incremented metrics``)."""
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import guard, run_analysis
from repro.analysis import (determinism_lint, dtype_lint, pallas_lint,
                            sync_lint)
from repro.analysis.common import SourceFile

REPO_SRC = str(Path(__file__).resolve().parents[1] / "src")


def lint(checker, text, path="snippet.py"):
    sf = SourceFile.parse(path=path, text=textwrap.dedent(text))
    return checker.check(sf)


def codes(findings):
    return sorted({f.code for f in findings})


# ---------------------------------------------------------------------------
# sync-lint
# ---------------------------------------------------------------------------


class TestSyncLint:
    def test_int_of_device_value_flagged(self):
        fs = lint(sync_lint, """
            import jax.numpy as jnp

            def f(x):
                d = jnp.minimum(x, 0)
                return int(jnp.max(d))
        """)
        assert codes(fs) == ["SYNC001"]

    def test_item_and_tolist_flagged(self):
        fs = lint(sync_lint, """
            import jax.numpy as jnp

            def f(x):
                d = jnp.cumsum(x)
                a = d.item()
                b = d.tolist()
                return a, b
        """)
        assert codes(fs) == ["SYNC002"]
        assert len(fs) == 2

    def test_asarray_of_device_value_flagged(self):
        fs = lint(sync_lint, """
            import numpy as np
            import jax.numpy as jnp

            def f(x):
                d = jnp.sort(x)
                return np.asarray(d)
        """)
        assert codes(fs) == ["SYNC003"]

    def test_truthiness_of_device_value_flagged(self):
        fs = lint(sync_lint, """
            import jax.numpy as jnp

            def f(x):
                u = jnp.any(x)
                if u:
                    return 1
                return 0
        """)
        assert codes(fs) == ["SYNC004"]

    def test_iteration_over_device_value_flagged(self):
        fs = lint(sync_lint, """
            import jax.numpy as jnp

            def f(x):
                d = jnp.abs(x)
                return [v for v in d]
        """)
        assert codes(fs) == ["SYNC005"]

    def test_device_get_flagged(self):
        fs = lint(sync_lint, """
            import jax

            def f(x):
                return jax.device_get(x + 1)
        """)
        assert "SYNC006" in codes(fs)

    def test_jitted_params_are_tainted_except_static(self):
        fs = lint(sync_lint, """
            import jax
            from functools import partial

            @partial(jax.jit, static_argnames=("n",))
            def f(x, n):
                return int(x) + int(n)
        """)
        # int(x) is one SYNC001; int(n) is static, hence host-side
        assert codes(fs) == ["SYNC001"]
        assert len(fs) == 1

    def test_guard_fetch_result_is_host_side(self):
        fs = lint(sync_lint, """
            import jax.numpy as jnp
            from repro.analysis import guard

            def f(x):
                stats = jnp.stack([x.sum(), x.max()])
                host = guard.fetch(stats, reason="test: packed stats")
                return int(host[0]), int(host[1])
        """)
        assert fs == []

    def test_metadata_and_none_checks_are_host_side(self):
        fs = lint(sync_lint, """
            import jax
            import jax.numpy as jnp

            def f(x, y):
                d = jnp.square(x)
                n = d.shape[0]
                if y is None and jax.default_backend() == "cpu":
                    return n
                return d.ndim
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# dtype-bound-lint
# ---------------------------------------------------------------------------


class TestDtypeLint:
    # the PR 4 overflow class, reduced to its shape
    PR4_BAD = """
        import jax.numpy as jnp

        def relax(src, w, n):
            d = jnp.full(n, 2**30, jnp.int32)
            return jnp.minimum(d, d[src] + w)
    """

    def test_pr4_int32_overflow_pattern_flagged(self):
        assert codes(lint(dtype_lint, self.PR4_BAD)) == ["DTYPE001"]

    def test_dtype_helper_clears_the_finding(self):
        fs = lint(dtype_lint, """
            import jax.numpy as jnp
            from repro.core.sssp import sssp_dtype_for

            def relax(src, w, n, wmax):
                dt = sssp_dtype_for(n, wmax, 0)
                d = jnp.full(n, 2**30, dt)
                return jnp.minimum(d, d[src] + w)
        """)
        assert fs == []

    # the PR 3 --tau 0 class: every falsy-coercion spelling
    @pytest.mark.parametrize("snippet", [
        "def f(tau):\n    return tau or 16\n",
        "def f(args):\n    return args.tau or 16\n",
        "def f(tau):\n    return not tau\n",
        "def f(levels):\n    if levels:\n        return 1\n    return 0\n",
    ])
    def test_pr3_falsy_knob_coercion_flagged(self, snippet):
        assert codes(lint(dtype_lint, snippet)) == ["DTYPE002"]

    def test_explicit_none_comparison_is_clean(self):
        fs = lint(dtype_lint, """
            def f(tau, levels):
                t = 16 if tau is None else tau
                if levels > 0:
                    t += levels
                return t
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# pallas-lint
# ---------------------------------------------------------------------------


class TestPallasLint:
    def test_index_map_arity_mismatch_flagged(self):
        fs = lint(pallas_lint, """
            from jax.experimental import pallas as pl

            def validate_tiling(nt, eb):
                return nt, eb

            def launch(kernel, x):
                validate_tiling(8, 128)
                return pl.pallas_call(
                    kernel,
                    grid=(4, 4),
                    in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
                )(x)
        """)
        assert codes(fs) == ["PL001"]

    def test_vararg_index_map_satisfies_any_arity(self):
        fs = lint(pallas_lint, """
            from jax.experimental import pallas as pl

            def validate_tiling(nt, eb):
                return nt, eb

            def launch(kernel, x):
                validate_tiling(8, 128)
                return pl.pallas_call(
                    kernel,
                    grid=(4, 4),
                    in_specs=[pl.BlockSpec((8, 128), lambda i, *rest: (i, 0))],
                    out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
                )(x)
        """)
        assert fs == []

    def test_missing_validator_flagged(self):
        fs = lint(pallas_lint, """
            from jax.experimental import pallas as pl

            def launch(kernel, x):
                return pl.pallas_call(kernel, grid=(4,))(x)
        """)
        assert codes(fs) == ["PL002"]

    def test_oversized_scratch_flagged(self):
        fs = lint(pallas_lint, """
            import jax.numpy as jnp
            from jax.experimental import pallas as pl
            from jax.experimental.pallas import tpu as pltpu

            def validate_tiling(nt, eb):
                return nt, eb

            def launch(kernel, x):
                validate_tiling(8, 128)
                return pl.pallas_call(
                    kernel,
                    grid=(4,),
                    scratch_shapes=[pltpu.VMEM((4096, 1024), jnp.float32)],
                )(x)
        """)
        # 4096*1024*4 = 16 MiB > the 8 MiB budget; the scratch+grid combo
        # without dimension_semantics also races (PL004)
        assert codes(fs) == ["PL003", "PL004"]

    def test_sequential_semantics_clear_the_race_finding(self):
        fs = lint(pallas_lint, """
            import jax.numpy as jnp
            from jax.experimental import pallas as pl
            from jax.experimental.pallas import tpu as pltpu

            def validate_tiling(nt, eb):
                return nt, eb

            def launch(kernel, x):
                validate_tiling(8, 128)
                return pl.pallas_call(
                    kernel,
                    grid=(4,),
                    scratch_shapes=[pltpu.VMEM((8, 128), jnp.int32)],
                    compiler_params=pltpu.TPUCompilerParams(
                        dimension_semantics=("arbitrary",)),
                )(x)
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# determinism-lint
# ---------------------------------------------------------------------------

DECOMP_PATH = "src/repro/core/engine.py"   # any decomposition-module path


class TestDeterminismLint:
    def test_global_rng_flagged_everywhere(self):
        fs = lint(determinism_lint, """
            import numpy as np

            def f():
                return np.random.rand(3)
        """, path="snippet.py")
        assert codes(fs) == ["DET001"]

    def test_seedless_default_rng_flagged_seeded_ok(self):
        bad = lint(determinism_lint, """
            import numpy as np

            def f():
                return np.random.default_rng()
        """)
        good = lint(determinism_lint, """
            import numpy as np

            def f(seed):
                return np.random.default_rng(seed)
        """)
        assert codes(bad) == ["DET001"] and good == []

    def test_wall_clock_flagged_in_clocked_scope(self):
        snippet = """
            import time

            def f():
                return time.perf_counter()
        """
        assert codes(lint(determinism_lint, snippet,
                          path=DECOMP_PATH)) == ["DET002"]
        # every repro module is in scope, not just the decomp set...
        assert codes(lint(determinism_lint, snippet,
                          path="src/repro/launch/serve.py")) == ["DET002"]
        # ...except the sanctioned clock seam itself and non-repro files
        assert lint(determinism_lint, snippet,
                    path="src/repro/runtime/telemetry.py") == []
        assert lint(determinism_lint, snippet, path="bench.py") == []

    def test_set_iteration_order_flagged_in_decomp_modules(self):
        fs = lint(determinism_lint, """
            import numpy as np

            def f(st):
                dirty = {1, 2, 3}
                a = list(dirty)
                b = np.fromiter(st.dirty_centers, np.int64)
                return a, b
        """, path=DECOMP_PATH)
        assert codes(fs) == ["DET003"] and len(fs) == 2

    def test_builtin_hash_flagged_in_decomp_modules(self):
        fs = lint(determinism_lint, """
            def f(name):
                return hash(name)
        """, path=DECOMP_PATH)
        assert codes(fs) == ["DET004"]


# ---------------------------------------------------------------------------
# pragma grammar (suppression + empty-reason errors), via run_analysis
# ---------------------------------------------------------------------------


class TestPragmas:
    def test_pragma_suppresses_but_is_reported(self, tmp_path):
        p = tmp_path / "annotated.py"
        p.write_text(textwrap.dedent("""
            import jax.numpy as jnp

            def f(x):
                d = jnp.cumsum(x)
                return d.item()  # sync: test corpus — intentional fetch
        """))
        active, suppressed, errors = run_analysis([str(p)])
        assert active == [] and errors == []
        assert codes(suppressed) == ["SYNC002"]

    def test_pragma_on_preceding_line_covers_statement(self, tmp_path):
        p = tmp_path / "annotated.py"
        p.write_text(textwrap.dedent("""
            import jax.numpy as jnp

            def f(x):
                d = jnp.cumsum(x)
                # sync: test corpus — pragma above the statement
                return d.item()
        """))
        active, suppressed, errors = run_analysis([str(p)])
        assert active == [] and errors == []
        assert codes(suppressed) == ["SYNC002"]

    def test_empty_reason_pragma_is_an_error(self, tmp_path):
        p = tmp_path / "bad.py"
        p.write_text("x = 1  # sync:\n")
        active, suppressed, errors = run_analysis([str(p)])
        assert codes(errors) == ["PRAGMA000"]

    def test_wrong_checker_pragma_does_not_suppress(self, tmp_path):
        p = tmp_path / "wrong.py"
        p.write_text(textwrap.dedent("""
            import jax.numpy as jnp

            def f(x):
                d = jnp.cumsum(x)
                return d.item()  # dtype: wrong pragma for a sync finding
        """))
        active, _, _ = run_analysis([str(p)])
        assert codes(active) == ["SYNC002"]


def test_repo_src_is_clean():
    """The acceptance contract: the full suite over src/ has zero active
    findings and zero errors (every intentional site is pragma-annotated)."""
    active, suppressed, errors = run_analysis([REPO_SRC])
    assert [f.format() for f in active] == []
    assert [f.format() for f in errors] == []
    assert suppressed   # the annotated fetch sites exist


# ---------------------------------------------------------------------------
# runtime transfer-guard equality contracts
# ---------------------------------------------------------------------------


def _graph():
    from repro.graph import random_geometric

    return random_geometric(512, avg_degree=6.0, seed=1)


class TestTransferGuardEquality:
    def test_stages_measured_equals_counted(self, transfer_guarded):
        from repro.core import cluster

        dec = cluster(_graph(), 12, seed=0)
        m = dec.metrics
        assert transfer_guarded.transfers == m.host_syncs + m.finalize_syncs
        # every transfer is a sanctioned, reasoned guard.fetch
        assert all(r for r in transfer_guarded.reasons())

    def test_oneshot_measured_equals_counted(self, transfer_guarded):
        from repro.core import cluster

        dec = cluster(_graph(), 12, seed=0, mode="oneshot")
        m = dec.metrics
        assert m.host_syncs == 1   # the mode's headline contract
        assert transfer_guarded.transfers == m.host_syncs + m.finalize_syncs

    def test_checkpointed_decomposition_measured_equals_counted(
            self, tmp_path, transfer_guarded):
        """The extended equality contract: with a StageCheckpointer armed,
        every device leaf the checkpoint writer materializes goes through
        guard.fetch and lands in ``checkpoint_syncs`` — so
        ``measured == host_syncs + finalize_syncs + checkpoint_syncs``
        and the durability cost never hides inside the algorithmic
        budget (``checkpoint_syncs`` stays OUT of total_host_syncs)."""
        from repro.core import StageCheckpointer, cluster

        # tau=4 keeps the stage threshold (8 tau log n) below n=512 so
        # the stage loop — and with it the boundary hook — actually runs
        ck = StageCheckpointer(str(tmp_path), every=1)
        dec = cluster(_graph(), 4, seed=0, checkpointer=ck)
        m = dec.metrics
        assert ck.saves >= 1
        assert m.checkpoint_syncs > 0
        assert transfer_guarded.transfers == \
            m.host_syncs + m.finalize_syncs + m.checkpoint_syncs
        assert all(r for r in transfer_guarded.reasons())

    def test_pipeline_measured_equals_counted(self):
        from repro.core import ClusterQuotientEstimator, open_session

        with open_session(_graph(), tau=12) as sess:
            with guard.measured_transfers() as meter:
                res = sess.estimate(ClusterQuotientEstimator())
            assert meter.transfers == res.pipeline.total_host_syncs

    def test_cascade_measured_equals_counted(self):
        from repro.core import CascadeEstimator, open_session

        with open_session(_graph(), tau=12) as sess:
            with guard.measured_transfers() as meter:
                res = sess.estimate(CascadeEstimator(levels=2, tau_solve=16))
            assert meter.transfers == res.pipeline.total_host_syncs

    def test_dynamic_update_measured_equals_counted(self):
        from repro.core import UpdateBatch, open_session

        g = _graph()
        with open_session(g, tau=12) as sess:

            def batch(seed):
                r = np.random.default_rng(seed)
                i = r.integers(0, g.n_edges, 4)
                u = r.integers(0, g.n_nodes, 3).astype(np.int32)
                v = r.integers(0, g.n_nodes, 3).astype(np.int32)
                return UpdateBatch(
                    insert_src=u, insert_dst=v,
                    insert_weight=np.full(3, 5, np.int32),
                    reweight_src=g.src[i], reweight_dst=g.dst[i],
                    reweight_weight=np.full(4, 7, np.int32))

            sess.apply_updates(batch(0))   # initializes the dynamic state
            before = sess.dynamic.metrics.update_syncs
            with guard.measured_transfers() as meter:
                sess.apply_updates(batch(1))
            delta = sess.dynamic.metrics.update_syncs - before
            assert meter.transfers == delta
            assert meter.transfers > 0

    def test_fetch_requires_a_reason(self):
        import jax.numpy as jnp

        with pytest.raises(ValueError):
            guard.fetch(jnp.zeros(3), reason="  ")

    def test_nested_meters_both_count(self):
        import jax.numpy as jnp

        with guard.measured_transfers() as outer:
            with guard.measured_transfers() as inner:
                guard.fetch(jnp.arange(4), reason="test: nested fetch")
            guard.fetch(jnp.arange(2), reason="test: outer-only fetch")
        assert inner.transfers == 1 and outer.transfers == 2
        assert outer.elements == 6
