"""One-shot exponential-shift decomposition mode (core/engine.run_oneshot).

Contracts under test:
  * the weighted-radius certificate: for every node, the scipy-exact
    distance from its assigned center is <= final_pathw (the same bound the
    staged engine certifies — oneshot folds shifts into d, never pathw);
  * IntervalEstimator keeps `lower <= scipy exact <= upper` under BOTH
    modes on single/pallas (in-process) and sharded (subprocess) backends;
  * deterministic=True makes the output a seed-independent function of the
    graph, byte-identical across two processes with DIFFERENT seeds;
  * mode="stages" is byte-identical to the pre-mode default path;
  * unknown mode names raise ValueError listing the valid names everywhere
    a mode enters (library, session, estimator, both launcher CLIs);
  * the one-shot sync contract: exactly ONE host sync per decomposition.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import scipy.sparse as sp
from scipy.sparse.csgraph import dijkstra

from _hypothesis_compat import given, settings, st

from repro.core import (
    CascadeEstimator,
    ClusterQuotientEstimator,
    ENGINE_MODES,
    IntervalEstimator,
    LowerBoundEstimator,
    check_engine_mode,
    cluster,
    open_session,
    resolve_engine_mode,
)
from repro.graph import grid_mesh, random_geometric, social_like

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _adj(g):
    return sp.coo_matrix((g.weight, (g.src, g.dst)),
                         shape=(g.n_nodes, g.n_nodes)).tocsr()


def _exact_diameter(g) -> int:
    D = dijkstra(_adj(g))
    finite = D[np.isfinite(D)]
    return int(finite.max()) if finite.size else 0


def _assert_radius_certificate(g, dec):
    """dist(center(u), u) <= final_pathw[u] for every node, scipy-exact."""
    centers = np.unique(dec.final_c)
    D = dijkstra(_adj(g), indices=centers)
    row = {c: i for i, c in enumerate(centers)}
    for u in range(g.n_nodes):
        d = D[row[dec.final_c[u]], u]
        assert d <= dec.final_pathw[u] + 1e-9, (
            f"node {u}: exact {d} > certified {dec.final_pathw[u]}")


# ---------------------------------------------------------------------------
# mode validation / registry
# ---------------------------------------------------------------------------


def test_unknown_mode_raises_listing_names():
    with pytest.raises(ValueError, match="stages"):
        check_engine_mode("bogus")
    with pytest.raises(ValueError, match="oneshot"):
        resolve_engine_mode("bogus")
    g = grid_mesh(6, "unit")
    with pytest.raises(ValueError, match="unknown engine mode"):
        cluster(g, 4, mode="bogus")


def test_mode_errors_before_device_work_in_session_and_estimators():
    from repro.config.base import GraphEngineConfig

    g = grid_mesh(6, "unit")
    with pytest.raises(ValueError, match="unknown engine mode"):
        open_session(g, GraphEngineConfig(mode="bogus"))
    sess = open_session(g)
    with pytest.raises(ValueError, match="unknown engine mode"):
        sess.estimate(ClusterQuotientEstimator(mode="bogus"))
    with pytest.raises(ValueError, match="unknown engine mode"):
        sess.estimate(CascadeEstimator(level_mode="bogus"))


def test_auto_resolves_to_stages_without_tuning():
    assert resolve_engine_mode("auto") == "stages"
    for m in ENGINE_MODES:
        check_engine_mode(m)  # every advertised name is accepted


def test_launchers_reject_unknown_engine_mode():
    """--engine-mode bogus must ValueError (not argparse-exit) BEFORE any
    graph is built, on both CLIs — the PR 5 estimator-name contract."""
    from repro.launch import diameter as dia_mod
    from repro.launch import serve as serve_mod

    argv = sys.argv
    try:
        sys.argv = ["diameter.py", "--n", "50", "--engine-mode", "bogus"]
        with pytest.raises(ValueError, match="unknown engine mode"):
            dia_mod.main()
        sys.argv = ["serve.py", "--mode", "graph-diameter", "--graph-n",
                    "50", "--engine-mode", "bogus"]
        with pytest.raises(ValueError, match="unknown engine mode"):
            serve_mod.main()
    finally:
        sys.argv = argv


def test_decomposition_mode_registry():
    from repro.core import DECOMPOSITION_MODES
    from repro.core.engine import run_cluster, run_oneshot

    assert DECOMPOSITION_MODES["stages"].runner is run_cluster
    assert DECOMPOSITION_MODES["oneshot"].runner is run_oneshot


# ---------------------------------------------------------------------------
# stages mode: identity pin
# ---------------------------------------------------------------------------


def test_stages_mode_is_the_default_byte_identical():
    g = random_geometric(1200, avg_degree=3.0, seed=2)
    a = cluster(g, 12, seed=5)
    b = cluster(g, 12, seed=5, mode="stages")
    np.testing.assert_array_equal(a.final_c, b.final_c)
    np.testing.assert_array_equal(a.final_pathw, b.final_pathw)
    assert a.growing_steps == b.growing_steps


# ---------------------------------------------------------------------------
# oneshot: certificate + sync contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["single", "pallas"])
def test_oneshot_radius_certificate_and_single_sync(backend):
    g = random_geometric(1000, avg_degree=3.0, seed=3)
    dec = cluster(g, 12, seed=7, mode="oneshot", backend=backend)
    assert dec.metrics.host_syncs == 1, dec.metrics
    assert dec.metrics.stages == 1
    assert dec.metrics.state_transfers <= 1
    _assert_radius_certificate(g, dec)


def test_oneshot_backend_parity():
    g = grid_mesh(20, "bimodal", heavy_w=500, heavy_p=0.15, seed=3)
    a = cluster(g, 8, seed=5, mode="oneshot")
    b = cluster(g, 8, seed=5, mode="oneshot", backend="pallas")
    np.testing.assert_array_equal(a.final_c, b.final_c)
    np.testing.assert_array_equal(a.final_pathw, b.final_pathw)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=20, max_value=300),
       seed=st.integers(min_value=0, max_value=2**31 - 1),
       deterministic=st.booleans())
def test_oneshot_radius_bound_property(n, seed, deterministic):
    g = random_geometric(n, avg_degree=3.0, seed=seed % 1000)
    dec = cluster(g, max(n // 50, 2), seed=seed, mode="oneshot",
                  deterministic=deterministic)
    assert dec.metrics.host_syncs == 1
    # every node is assigned and certified
    assert (dec.final_pathw >= 0).all()
    _assert_radius_certificate(g, dec)


# ---------------------------------------------------------------------------
# interval bracket under both modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["single", "pallas"])
@pytest.mark.parametrize("mode", ["stages", "oneshot"])
def test_interval_bracket_both_modes(backend, mode):
    from repro.config.base import GraphEngineConfig

    g = random_geometric(700, avg_degree=3.0, seed=4)
    exact = _exact_diameter(g)
    sess = open_session(g, GraphEngineConfig(backend=backend, mode=mode))
    iv = sess.estimate(IntervalEstimator(estimators=(
        LowerBoundEstimator(), ClusterQuotientEstimator())))
    assert iv.lower <= exact <= iv.upper, (iv.lower, exact, iv.upper)


def test_interval_bracket_oneshot_sharded_subprocess():
    code = textwrap.dedent("""
    import jax, numpy as np
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    import scipy.sparse as sp
    from scipy.sparse.csgraph import dijkstra
    from repro.graph import grid_mesh
    from repro.core import (ClusterQuotientEstimator, IntervalEstimator,
                            LowerBoundEstimator, cluster, open_session)
    from repro.core.distributed import DistributedEngine
    g = grid_mesh(18, "bimodal", heavy_w=500, heavy_p=0.15, seed=3)
    eng = DistributedEngine(g, mesh)
    be = eng.make_relax_fn()
    # sharded backend parity with single-device oneshot, byte for byte
    ref = cluster(g, 8, seed=5, mode="oneshot")
    out = cluster(g, 8, seed=5, mode="oneshot", relax_fn=be)
    assert np.array_equal(ref.final_c, out.final_c)
    assert np.array_equal(ref.final_pathw, out.final_pathw)
    assert out.metrics.host_syncs == 1, out.metrics
    # certified bracket through the session layer on the sharded backend
    from repro.config.base import GraphEngineConfig
    sess = open_session(g, GraphEngineConfig(mode="oneshot"), backend=be)
    iv = sess.estimate(IntervalEstimator(estimators=(
        LowerBoundEstimator(), ClusterQuotientEstimator())))
    A = sp.coo_matrix((g.weight, (g.src, g.dst)),
                      shape=(g.n_nodes, g.n_nodes)).tocsr()
    D = dijkstra(A)
    exact = int(D[np.isfinite(D)].max())
    assert iv.lower <= exact <= iv.upper, (iv.lower, exact, iv.upper)
    print("ONESHOT-SHARDED-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ONESHOT-SHARDED-OK" in out.stdout


def test_cascade_level_mode_oneshot_keeps_bracket():
    g = social_like(9, 6, seed=2, weight_dist="uniform", high=2**20)
    exact = _exact_diameter(g)
    sess = open_session(g, tau_solve=8)
    iv = sess.estimate(IntervalEstimator(estimators=(
        LowerBoundEstimator(),
        CascadeEstimator(levels=2, level_mode="oneshot"))))
    assert iv.lower <= exact <= iv.upper, (iv.lower, exact, iv.upper)


# ---------------------------------------------------------------------------
# deterministic variant: seed independence across processes
# ---------------------------------------------------------------------------


def test_deterministic_seed_independent_in_process():
    g = random_geometric(900, avg_degree=3.0, seed=6)
    a = cluster(g, 10, seed=1, mode="oneshot", deterministic=True)
    b = cluster(g, 10, seed=2**30 + 17, mode="oneshot", deterministic=True)
    np.testing.assert_array_equal(a.final_c, b.final_c)
    np.testing.assert_array_equal(a.final_pathw, b.final_pathw)
    # the random variant genuinely depends on the seed (sanity check that
    # the deterministic path isn't trivially constant)
    c = cluster(g, 10, seed=1, mode="oneshot")
    d = cluster(g, 10, seed=2, mode="oneshot")
    assert not np.array_equal(c.final_c, d.final_c)


def test_deterministic_byte_identical_across_processes():
    """Two processes, DIFFERENT seeds: deterministic output must hash the
    same (the sharded/dynamic reproducibility story)."""
    code = textwrap.dedent("""
    import sys, hashlib, numpy as np
    from repro.graph import random_geometric
    from repro.core import cluster
    g = random_geometric(600, avg_degree=3.0, seed=11)
    dec = cluster(g, 8, seed=int(sys.argv[1]), mode="oneshot",
                  deterministic=True)
    h = hashlib.md5(dec.final_c.tobytes() + dec.final_pathw.tobytes())
    print("HASH", h.hexdigest())
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    hashes = []
    for seed in ("3", "424242"):
        out = subprocess.run([sys.executable, "-c", code, seed],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        assert out.returncode == 0, out.stderr[-3000:]
        line = [l for l in out.stdout.splitlines() if l.startswith("HASH")]
        assert line, out.stdout
        hashes.append(line[0])
    assert hashes[0] == hashes[1], hashes


# ---------------------------------------------------------------------------
# autotune integration
# ---------------------------------------------------------------------------


def test_tuning_record_mode_derivation_and_validation():
    import dataclasses

    from repro.core.autotune import (AutotuneError, compute_graph_stats,
                                     derive_tuning, validate_tuning)

    g = random_geometric(2000, avg_degree=3.0, seed=1)
    stats = compute_graph_stats(g)
    rec = derive_tuning(stats)
    assert rec.mode in ("stages", "oneshot")  # never "auto": records store
    validate_tuning(rec, stats)               # the RESOLVED mode
    for bad in ("auto", "bogus"):
        with pytest.raises(AutotuneError, match="mode"):
            validate_tuning(dataclasses.replace(rec, mode=bad), stats)
    # cfg.mode="auto" on a tuned session resolves to the record's choice;
    # the default "stages" stays pinned even under autotune
    from repro.config.base import GraphEngineConfig

    sess = open_session(g, GraphEngineConfig(mode="auto", autotune="auto"))
    assert sess.cfg.mode == sess.tuning.mode
    sess2 = open_session(g, GraphEngineConfig(autotune="auto"))
    assert sess2.cfg.mode == "stages"


def test_tuning_cache_backcompat_without_mode_field():
    """JSON cache entries recorded before TuningRecord grew ``mode`` must
    load with the 'stages' default."""
    import dataclasses

    from repro.core.autotune import TuningRecord

    fields = {f.name for f in dataclasses.fields(TuningRecord)}
    d = {"signature": "x", "tau": 8, "tau_solve": 64, "levels": 0,
         "delta_init": 4, "node_tile": 128, "edge_block": 128, "fuse": 0,
         "predicted_superstep_s": 1e-6, "padded_edges": 128}
    assert fields - set(d) == {"mode"}
    assert TuningRecord(**d).mode == "stages"


# ---------------------------------------------------------------------------
# degenerate inputs
# ---------------------------------------------------------------------------


def test_oneshot_degenerates():
    from repro.graph.structures import EdgeList

    empty = EdgeList(n_nodes=0, src=np.zeros(0, np.int32),
                     dst=np.zeros(0, np.int32), weight=np.zeros(0, np.int32))
    dec = cluster(empty, 1, mode="oneshot")
    assert dec.n_nodes == 0 and dec.n_clusters == 0
    single = EdgeList(n_nodes=1, src=np.zeros(0, np.int32),
                      dst=np.zeros(0, np.int32), weight=np.zeros(0, np.int32))
    dec = cluster(single, 1, mode="oneshot")
    assert dec.n_clusters == 1 and dec.radius == 0
