"""Deeper model tests: Wigner-D exactness, eSCN equivariance, MoE paths
(GSPMD vs explicit-a2a vs virtual experts), mef-attention gradients."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.wigner import edge_rotation, rotate_irreps, wigner_d_stack


def _rand_rot(n, seed=0):
    r = np.random.default_rng(seed)
    a = r.standard_normal((n, 3, 3))
    q, _ = np.linalg.qr(a)
    det = np.linalg.det(q)
    q[:, :, 0] *= det[:, None]
    return jnp.asarray(q, jnp.float32)


def test_wigner_orthogonal_and_homomorphic():
    R1, R2 = _rand_rot(4, 1), _rand_rot(4, 2)
    b1, b2 = wigner_d_stack(R1, 6), wigner_d_stack(R2, 6)
    b12 = wigner_d_stack(R1 @ R2, 6)
    for l in range(7):
        eye = jnp.eye(2 * l + 1)
        assert float(jnp.abs(
            jnp.einsum("eij,ekj->eik", b1[l], b1[l]) - eye).max()) < 1e-4
        assert float(jnp.abs(
            b12[l] - jnp.einsum("eij,ejk->eik", b1[l], b2[l])).max()) < 1e-3


def test_edge_rotation_aligns_to_z():
    r = np.random.default_rng(3)
    v = jnp.asarray(r.standard_normal((32, 3)), jnp.float32)
    R = edge_rotation(v)
    z = jnp.einsum("eij,ej->ei", R, v / jnp.linalg.norm(v, axis=-1, keepdims=True))
    assert float(jnp.abs(z - jnp.array([0.0, 0.0, 1.0])).max()) < 1e-5


def test_rotate_irreps_roundtrip():
    r = np.random.default_rng(4)
    R = _rand_rot(8, 5)
    blocks = wigner_d_stack(R, 4)
    feat = jnp.asarray(r.standard_normal((8, 25, 3)), jnp.float32)
    back = rotate_irreps(rotate_irreps(feat, blocks), blocks, transpose=True)
    assert float(jnp.abs(back - feat).max()) < 1e-4


def test_equiformer_invariance_under_rotation():
    from repro.config.base import GNNConfig
    from repro.models import gnn as G
    r = np.random.default_rng(0)
    n, E, F = 40, 160, 12
    graph = {
        "x": jnp.asarray(r.standard_normal((n, F)), jnp.float32),
        "src": jnp.asarray(r.integers(0, n, E), jnp.int32),
        "dst": jnp.asarray(r.integers(0, n, E), jnp.int32),
        "pos": jnp.asarray(r.standard_normal((n, 3)), jnp.float32),
    }
    cfg = GNNConfig(kind="equiformer_v2", d_out=5, n_layers=2, d_hidden=16,
                    l_max=3, m_max=2, n_heads=4)
    p = G.init_gnn(cfg, F, jax.random.PRNGKey(0))
    out1 = G.gnn_forward(p, graph, cfg)
    th = 1.1
    Rz = jnp.asarray([[np.cos(th), -np.sin(th), 0],
                      [np.sin(th), np.cos(th), 0], [0, 0, 1]], jnp.float32)
    g2 = dict(graph, pos=graph["pos"] @ Rz.T)
    out2 = G.gnn_forward(p, g2, cfg)
    rel = float(jnp.abs(out1 - out2).max() / (jnp.abs(out1).max() + 1e-9))
    assert rel < 1e-3, rel


# ---------------------------------------------------------------------------
# MoE a2a vs GSPMD (multi-device subprocess, as in test_distributed)
# ---------------------------------------------------------------------------
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_a2a_matches_gspmd():
    out = _run("""
    import jax, jax.numpy as jnp
    import repro.models.transformer as T
    from repro.config.base import MoEConfig
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = MoEConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
                    vocab_size=128, n_experts=4, top_k=2, capacity_factor=8.0,
                    moe_groups=2, dtype="float32")
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(0), (4, 16), 0, 128)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    with mesh:
        T.MOE_A2A = None
        ref, _ = jax.jit(lambda p, t: T.forward(p, t, cfg))(p, toks)
        g1 = jax.jit(jax.grad(lambda p: T.lm_loss(p, batch, cfg)))(p)
        T.MOE_A2A = (mesh, 8.0)
        a2a, _ = jax.jit(lambda p, t: T.forward(p, t, cfg))(p, toks)
        g2 = jax.jit(jax.grad(lambda p: T.lm_loss(p, batch, cfg)))(p)
    T.MOE_A2A = None
    assert float(jnp.abs(ref - a2a).max()) < 1e-4
    worst = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), g1, g2)))
    assert worst < 1e-3, worst
    print("A2A OK")
    """)
    assert "A2A OK" in out


def test_moe_a2a_virtual_experts():
    out = _run("""
    import jax, jax.numpy as jnp
    import repro.models.transformer as T
    from repro.config.base import MoEConfig
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    cfg = MoEConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                    vocab_size=64, n_experts=2, top_k=1, capacity_factor=8.0,
                    moe_groups=1, dtype="float32")
    p = T.init_params(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    with mesh:
        T.MOE_A2A = None
        ref, _ = jax.jit(lambda p, t: T.forward(p, t, cfg))(p, toks)
        T.MOE_A2A = (mesh, 8.0)
        a2a, _ = jax.jit(lambda p, t: T.forward(p, t, cfg))(p, toks)
    T.MOE_A2A = None
    assert float(jnp.abs(ref - a2a).max()) < 1e-4
    print("VIRT OK")
    """)
    assert "VIRT OK" in out


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 and uniform routing, drop fraction stays small; gates of
    dropped tokens must be exactly zeroed (output bounded)."""
    from repro.config.base import MoEConfig
    from repro.models import transformer as T
    cfg = MoEConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                    vocab_size=64, n_experts=4, top_k=2, capacity_factor=1.0,
                    dtype="float32")
    p = T.init_params(cfg, jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 64), 0, 64)
    logits, _ = T.forward(p, toks, cfg)
    assert not bool(jnp.isnan(logits).any())
