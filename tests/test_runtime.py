"""Runtime substrate: checkpoint roundtrip + elastic restore, compression
telescoping, fault handling, optimizer math, data pipeline determinism."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.config.base import ShapeSpec, TrainConfig, TransformerConfig
from repro.data.pipeline import DataCursor, LMTokenPipeline
from repro.optim import adamw
from repro.runtime.compression import (
    dequantize_int8,
    ef_compress_grads,
    init_residual,
    quantize_int8,
)
from repro.runtime.fault import PreemptionGuard, StragglerMonitor, retriable


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    k = jax.random.PRNGKey(0)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": [jnp.ones(3), jnp.zeros((2, 2))]},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t, extra={"cursor": {"step": 7, "shard": 1}})
    assert latest_step(str(tmp_path)) == 7
    restored, extra = restore(str(tmp_path), t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 t, restored)
    assert extra["cursor"]["step"] == 7


def test_checkpoint_gc_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, t, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_atomicity_partial_write(tmp_path):
    """A leftover tmp dir (simulated crash) must not shadow the last good
    checkpoint."""
    t = _tree()
    save(str(tmp_path), 3, t)
    os.makedirs(tmp_path / "tmp.9.999", exist_ok=True)  # dead partial write
    with open(tmp_path / "tmp.9.999" / "garbage.npy", "w") as f:
        f.write("not a checkpoint")
    assert latest_step(str(tmp_path)) == 3
    restored, _ = restore(str(tmp_path), t)
    assert restored is not None


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore with an explicit sharding tree (single-device here; the same
    API re-shards onto any mesh — the dry-run meshes use it)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = _tree()
    save(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, _ = restore(str(tmp_path), t, shardings=sh)
    assert restored["a"].sharding == NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_quantization_bounds():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal(1000).astype(np.float32)) * 3
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_telescopes():
    """Sum of EF-compressed grads ~ sum of raw grads: the residual telescopes
    so the cumulative quantization error stays bounded (EF-SGD invariant)."""
    r = np.random.default_rng(1)
    grads = [{"w": jnp.asarray(r.standard_normal(256).astype(np.float32))}
             for _ in range(30)]
    resid = init_residual(grads[0])
    sent_total = jnp.zeros(256)
    raw_total = jnp.zeros(256)
    for g in grads:
        q, s, resid = ef_compress_grads(g, resid)
        sent_total = sent_total + dequantize_int8(q["w"], s["w"])
        raw_total = raw_total + g["w"]
    # cumulative error = final residual, NOT 30x the per-step error
    np.testing.assert_allclose(np.asarray(sent_total + resid["w"]),
                               np.asarray(raw_total), rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(sent_total - raw_total).max()) < 0.1


# ---------------------------------------------------------------------------
# fault
# ---------------------------------------------------------------------------

def test_preemption_guard_catches_sigterm():
    with PreemptionGuard() as g:
        assert not g.should_stop
        os.kill(os.getpid(), signal.SIGTERM)
        assert g.should_stop
        assert g.received == signal.SIGTERM


def test_preemption_guard_restores_handlers_on_exit():
    """The guard must put back whatever handlers were installed before it
    — nesting a guard inside launcher-installed handlers (or pytest's)
    must not leak its own handler past the with-block."""
    seen = []
    prev_term = signal.signal(signal.SIGTERM, lambda s, f: seen.append(s))
    try:
        with PreemptionGuard() as g:
            assert signal.getsignal(signal.SIGTERM) == g._handler
        assert signal.getsignal(signal.SIGTERM) is not g._handler
        os.kill(os.getpid(), signal.SIGTERM)
        assert seen == [signal.SIGTERM]   # the outer handler is back
        assert not g.should_stop          # the exited guard saw nothing
    finally:
        signal.signal(signal.SIGTERM, prev_term)


def test_preemption_guard_is_not_retriable():
    """Preempted must escape retriable() (the wrapper retries
    RuntimeError): a preemption is a clean exit, never an in-place retry."""
    from repro.runtime.fault import Preempted

    calls = {"n": 0}

    def preempts():
        calls["n"] += 1
        raise Preempted(3, "/tmp/ckpt/step_3")

    with pytest.raises(Preempted) as e:
        retriable(preempts, base_delay=0.001)()
    assert calls["n"] == 1          # no retry
    assert e.value.stage == 3
    assert not isinstance(e.value, RuntimeError)


def test_retriable_retries_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert retriable(flaky, base_delay=0.001)() == "ok"
    assert calls["n"] == 3


def test_retriable_exhausts_with_deterministic_backoff(monkeypatch):
    """Retry count and the doubling backoff schedule are exact: the real
    ``time.sleep`` is patched out, so the test asserts the SCHEDULE
    (0.1, 0.2, 0.4, ...) rather than measuring wall-clock."""
    slept = []
    # det: test patches time.sleep to record the backoff schedule, no real waiting
    monkeypatch.setattr("repro.runtime.fault.time.sleep", slept.append)
    calls = {"n": 0}

    def always_fails():
        calls["n"] += 1
        raise OSError(f"boom {calls['n']}")

    with pytest.raises(OSError, match="boom 4"):
        retriable(always_fails, retries=3, base_delay=0.1)()
    assert calls["n"] == 4                      # 1 try + 3 retries
    assert slept == [0.1, 0.2, 0.4]             # deterministic doubling


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(threshold=2.0)
    for i in range(8):
        m.record(i, 0.1)
    assert m.record(8, 0.5)          # 5x EWMA -> straggler
    assert 8 in m.flagged
    assert not m.record(9, 0.11)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    tc = TrainConfig(lr=0.1, warmup=1, weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw.init_state(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw.apply_updates(params, opt, g, tc)
    assert float(loss(params)) < 1e-3


def test_adamw_clipping():
    tc = TrainConfig(lr=1e-3, warmup=1, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw.init_state(params)
    g = {"w": jnp.full(4, 100.0)}
    _, _, stats = adamw.apply_updates(params, opt, g, tc)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)


def test_zero1_specs_divisible_only():
    from jax.sharding import PartitionSpec as P
    specs = {"a": P(None, "model"), "b": P()}
    shapes = {"a": jax.ShapeDtypeStruct((42, 64), jnp.float32),
              "b": jax.ShapeDtypeStruct((32,), jnp.float32)}
    out = adamw.zero1_state_specs(specs, shapes, axis_size=16)
    assert out["a"] == P(None, "model")      # 42 not divisible -> unchanged
    assert out["b"] == P("data")             # 32 divisible -> sharded


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_replay():
    cfg = TransformerConfig(vocab_size=128)
    shape = ShapeSpec(name="t", kind="train", seq_len=16, global_batch=4)
    p = LMTokenPipeline(cfg, shape, seed=3)
    c = DataCursor(step=5, shard=2)
    b1, b2 = p.batch(c), p.batch(c)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    c2 = DataCursor(step=6, shard=2)
    assert not np.array_equal(p.batch(c2)["tokens"], b1["tokens"])


def test_pipeline_shards_differ():
    cfg = TransformerConfig(vocab_size=128)
    shape = ShapeSpec(name="t", kind="train", seq_len=16, global_batch=4)
    p = LMTokenPipeline(cfg, shape, seed=3)
    a = p.batch(DataCursor(step=0, shard=0))
    b = p.batch(DataCursor(step=0, shard=1))
    assert not np.array_equal(a["tokens"], b["tokens"])
