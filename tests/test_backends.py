"""Cross-backend parity: SingleDevice / Sharded (allgather + halo) / Pallas
must produce byte-identical decompositions for a fixed seed, and the
device-resident engine must hold its sync/transfer contract (plane pack at
most once per cluster() call, exactly one host sync per stage)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import cluster, cluster2, make_backend
from repro.graph import grid_mesh, random_geometric, social_like

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _paper_graphs():
    # one per paper family (Table 1), CPU-sized
    return {
        "road": random_geometric(1500, avg_degree=3.0, seed=1),
        "social": social_like(8, 6, seed=2, weight_dist="uniform", high=2**20),
        "mesh": grid_mesh(24, "bimodal", heavy_w=500, heavy_p=0.15, seed=3),
    }


@pytest.mark.parametrize("gname", ["road", "social", "mesh"])
def test_single_vs_pallas_byte_identical(gname):
    g = _paper_graphs()[gname]
    a = cluster(g, 12, seed=5)
    b = cluster(g, 12, seed=5, backend="pallas")
    np.testing.assert_array_equal(a.final_c, b.final_c)
    np.testing.assert_array_equal(a.final_pathw, b.final_pathw)
    assert a.growing_steps == b.growing_steps
    assert a.delta_end == b.delta_end


def test_cluster2_backend_parity():
    g = grid_mesh(24, "uniform", high=100, seed=6)
    a = cluster2(g, 8, seed=1)
    b = cluster2(g, 8, seed=1, backend="pallas")
    np.testing.assert_array_equal(a.final_c, b.final_c)
    np.testing.assert_array_equal(a.final_pathw, b.final_pathw)


def test_engine_sync_and_transfer_contract():
    g = random_geometric(2000, avg_degree=3.0, seed=2)
    dec = cluster(g, 8, seed=4)
    m = dec.metrics
    assert m.state_transfers <= 1, "planes must pack at most once per cluster()"
    assert m.host_syncs == m.stages, "a stage costs exactly one host sync"
    assert m.grow_calls >= m.stages  # >= one PartialGrowth per covering stage


def test_make_backend_factory():
    g = grid_mesh(8, "unit")
    assert make_backend(g, "single").kind == "single"
    assert make_backend(g, "pallas").kind == "pallas"
    be = make_backend(g, "pallas")
    assert make_backend(g, be) is be
    with pytest.raises(ValueError):
        make_backend(g, "nope")


def test_sharded_backends_byte_identical():
    """allgather + halo on a forced 4-device host mesh == single device,
    byte for byte (subprocess so XLA device count doesn't leak)."""
    code = textwrap.dedent("""
    import jax, numpy as np
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    from repro.graph import grid_mesh
    from repro.core import cluster
    from repro.core.distributed import DistributedEngine
    g = grid_mesh(24, "bimodal", heavy_w=500, heavy_p=0.15, seed=3)
    ref = cluster(g, 12, seed=5)
    for comm in ("allgather", "halo"):
        eng = DistributedEngine(g, mesh, comm=comm)
        out = cluster(g, 12, seed=5, relax_fn=eng.make_relax_fn())
        assert np.array_equal(ref.final_c, out.final_c), comm
        assert np.array_equal(ref.final_pathw, out.final_pathw), comm
        assert out.metrics.state_transfers <= 1, out.metrics
        assert out.metrics.host_syncs == out.metrics.stages, out.metrics
    print("SHARDED-PARITY-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-PARITY-OK" in out.stdout
