"""Graph-statistics autotuner: stats correctness, knob derivation,
validation, cache round-trip, and the session pin/override contract."""
import dataclasses

import numpy as np
import pytest

from repro.core import autotune
from repro.core.autotune import (
    AutotuneError,
    TuningRecord,
    clear_cache,
    compute_graph_stats,
    derive_tuning,
    get_tuning,
    graph_signature,
    load_cache,
    save_cache,
    validate_tuning,
)
from repro.core.session import open_session
from repro.config.base import GraphEngineConfig
from repro.graph.structures import EdgeList


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_cache()
    yield
    clear_cache()


def _edges(n=500, e=2000, wmax=100, seed=0):
    r = np.random.default_rng(seed)
    return EdgeList(n, r.integers(0, n, e).astype(np.int32),
                    r.integers(0, n, e).astype(np.int32),
                    r.integers(1, wmax + 1, e).astype(np.int32))


# ---------------------------------------------------------------------------
# stats pass
# ---------------------------------------------------------------------------

def test_graph_stats_match_numpy():
    edges = _edges(seed=3)
    s = compute_graph_stats(edges)
    deg = np.bincount(edges.dst, minlength=edges.n_nodes)
    assert s.n_nodes == edges.n_nodes and s.n_edges == edges.n_edges
    assert s.max_degree == int(deg.max())
    assert s.min_weight == int(edges.weight.min())
    assert s.max_weight == int(edges.weight.max())
    assert s.weight_sum == int(edges.weight.astype(np.int64).sum())
    assert s.avg_weight == s.weight_sum // edges.n_edges
    # histograms: log2 buckets cover every edge / node exactly once
    assert sum(s.weight_hist) == edges.n_edges
    assert sum(s.degree_hist) == edges.n_nodes
    w_buckets = np.clip(np.floor(np.log2(np.maximum(
        edges.weight, 1))).astype(int), 0, autotune.N_BUCKETS - 1)
    expect = np.bincount(w_buckets, minlength=autotune.N_BUCKETS)
    assert tuple(int(x) for x in expect) == s.weight_hist


def test_graph_stats_empty_and_heavy_weights():
    empty = compute_graph_stats(EdgeList(
        0, np.zeros(0, np.int32), np.zeros(0, np.int32), np.zeros(0, np.int32)))
    assert empty.n_edges == 0 and empty.weight_sum == 0
    # weight_sum overflows int32 — must be exact via the host int64 path
    big = EdgeList(4, np.zeros(8, np.int32), np.ones(8, np.int32),
                   np.full(8, 2**30 - 1, np.int32))
    s = compute_graph_stats(big)
    assert s.weight_sum == 8 * (2**30 - 1)
    assert s.weight_hist[29] == 8


def test_signature_is_stable_and_shape_sensitive():
    a = graph_signature(compute_graph_stats(_edges(seed=1)))
    b = graph_signature(compute_graph_stats(_edges(seed=1)))
    c = graph_signature(compute_graph_stats(_edges(seed=2)))
    assert a == b
    assert a != c


# ---------------------------------------------------------------------------
# derivation + validation
# ---------------------------------------------------------------------------

def test_derive_tuning_is_valid_across_shapes():
    for n, e, wmax in [(50, 100, 3), (2000, 8000, 100), (500, 4000, 2**28)]:
        stats = compute_graph_stats(_edges(n, e, wmax, seed=n))
        rec = derive_tuning(stats)
        validate_tuning(rec, stats)  # must not raise
        assert 4 <= rec.tau <= n
        assert rec.tau_solve >= 64 and rec.levels in (0, 1, 2)
        assert 1 <= rec.delta_init < 2**30


def test_derive_tuning_hub_skew_doubles_tau():
    n, e = 4000, 16000
    r = np.random.default_rng(0)
    flat = EdgeList(n, r.integers(0, n, e).astype(np.int32),
                    r.integers(0, n, e).astype(np.int32),
                    r.integers(1, 100, e).astype(np.int32))
    hub_dst = r.integers(0, n, e).astype(np.int32)
    hub_dst[: e // 2] = 0  # one node takes half the edges
    hub = EdgeList(n, flat.src, hub_dst, flat.weight)
    t_flat = derive_tuning(compute_graph_stats(flat))
    t_hub = derive_tuning(compute_graph_stats(hub))
    assert t_hub.tau == 2 * t_flat.tau


def test_derive_tuning_delta_tracks_median_weight():
    light = derive_tuning(compute_graph_stats(_edges(wmax=3, seed=1)))
    heavy = derive_tuning(compute_graph_stats(_edges(wmax=2**20, seed=1)))
    assert light.delta_init < heavy.delta_init
    # heavy-tailed: median-based delta sits far below the mean-based "avg"
    skewed = _edges(seed=4)
    w = np.asarray(skewed.weight).copy()
    w[:20] = 2**29  # 1% giants drag the mean up ~4 orders of magnitude
    stats = compute_graph_stats(EdgeList(skewed.n_nodes, skewed.src,
                                         skewed.dst, w))
    rec = derive_tuning(stats)
    assert rec.delta_init < stats.avg_weight


def test_validate_tuning_rejects_stale_records():
    stats = compute_graph_stats(_edges())
    rec = derive_tuning(stats)
    for bad in (
        dataclasses.replace(rec, edge_block=100),       # kernel precondition
        dataclasses.replace(rec, tau=0),
        dataclasses.replace(rec, tau_solve=1),
        dataclasses.replace(rec, levels=9),
        dataclasses.replace(rec, delta_init=2**30),
        dataclasses.replace(rec, fuse=-1),
    ):
        with pytest.raises((AutotuneError, ValueError)):
            validate_tuning(bad, stats)


def test_validate_tuning_rejects_roofline_regression():
    # a graph large enough that the tiling choice matters: a wildly padded
    # alternative must fail the 1.05x roofline check
    stats = compute_graph_stats(_edges(n=20000, e=60000, seed=9))
    rec = derive_tuning(stats)
    worst = None
    for nt in autotune.NODE_TILE_CANDIDATES:
        for eb in autotune.EDGE_BLOCK_CANDIDATES:
            t, _ = autotune._tiling_time(stats.n_nodes, stats.n_edges, nt, eb)
            if worst is None or t > worst[2]:
                worst = (nt, eb, t)
    assert worst[2] > rec.predicted_superstep_s * 1.05
    stale = dataclasses.replace(rec, node_tile=worst[0], edge_block=worst[1])
    with pytest.raises(AutotuneError, match="stale"):
        validate_tuning(stale, stats)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def test_get_tuning_caches_by_signature():
    edges = _edges(seed=5)
    r1 = get_tuning(edges)
    r2 = get_tuning(edges)
    assert r1 is r2
    assert autotune.TUNE_EVENTS == {"hits": 1, "misses": 1}
    get_tuning(_edges(seed=6))
    assert autotune.TUNE_EVENTS["misses"] == 2
    # backend is part of the key: pallas may fuse where single cannot
    get_tuning(edges, backend="pallas")
    assert autotune.TUNE_EVENTS["misses"] == 3


def test_cache_round_trip(tmp_path):
    path = str(tmp_path / "tune.json")
    edges = _edges(seed=7)
    rec = get_tuning(edges, record=True, cache_path=path)
    clear_cache()
    assert load_cache(path) == 1
    hit = get_tuning(edges)
    assert hit == rec
    assert autotune.TUNE_EVENTS == {"hits": 1, "misses": 0}
    # explicit save path and missing-file load
    assert save_cache(str(tmp_path / "again.json")).endswith("again.json")
    assert load_cache(str(tmp_path / "absent.json")) == 0


def test_loaded_record_survives_dataclass_round_trip(tmp_path):
    path = str(tmp_path / "tune.json")
    get_tuning(_edges(seed=8), record=True, cache_path=path)
    clear_cache()
    load_cache(path)
    (rec,) = autotune._CACHE.values()
    assert isinstance(rec, TuningRecord)
    validate_tuning(rec, compute_graph_stats(_edges(seed=8)))


# ---------------------------------------------------------------------------
# session wiring: pins beat the tuner; defaults follow it
# ---------------------------------------------------------------------------

def test_session_autotune_defaults_and_pins():
    edges = _edges(n=2000, e=6000, seed=11)
    cfg = GraphEngineConfig(autotune="auto")
    tuned = open_session(edges, cfg)
    assert tuned.tuning is not None
    assert tuned.tau == tuned.tuning.tau
    assert tuned.tau_solve == tuned.tuning.tau_solve
    assert tuned.cfg.delta_init == str(tuned.tuning.delta_init)

    pinned = open_session(edges, GraphEngineConfig(
        autotune="auto", delta_init="123"), tau=17, tau_solve=99)
    assert pinned.tau == 17 and pinned.tau_solve == 99
    assert pinned.cfg.delta_init == "123"  # numeric config stays pinned

    off = open_session(edges, GraphEngineConfig())
    assert off.tuning is None

    with pytest.raises(ValueError, match="autotune"):
        open_session(edges, GraphEngineConfig(), autotune="bogus")


def test_session_autotune_estimates():
    edges = _edges(n=1500, e=5000, seed=13)
    sess = open_session(edges, GraphEngineConfig(autotune="auto"))
    est = sess.estimate()
    assert est.phi_approx >= est.radius >= 0
    baseline = open_session(edges, GraphEngineConfig()).estimate()
    assert est.connected == baseline.connected
