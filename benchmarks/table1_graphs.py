"""Paper Table 1: benchmark graph statistics (nodes, edges, Phi lower bound,
weight distribution moments) for the CPU-scaled graph families."""
from __future__ import annotations

import numpy as np

from benchmarks.common import benchmark_graphs, emit, true_diameter


def run(scale: float = 1.0):
    rows = []
    for name, g in benchmark_graphs(scale).items():
        w = g.weight.astype(np.float64)
        rows.append({
            "graph": name,
            "nodes": g.n_nodes,
            "edges": g.n_edges // 2,      # undirected pairs (Table 1 style)
            "phi": true_diameter(g),
            "w_mean": round(float(w.mean()), 1),
            "w_std": round(float(w.std()), 1),
            "w_max": int(w.max()),
        })
    emit("table1_graphs", rows)
    return rows


if __name__ == "__main__":
    run()
