"""Kernel micro-benchmarks: ref (3-pass segment-min cascade) vs the fused
one-pass kernel semantics. On CPU the Pallas interpreter is not a timing
proxy, so we time the REF paths (what actually executes offline) and report
the kernel's HBM-pass ratio as the derived metric the TPU would see."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.edge_relax.ops import block_edges_host, edge_relax


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    r = np.random.default_rng(0)
    for n, e in [(10_000, 50_000), (100_000, 500_000)]:
        src = r.integers(0, n, e).astype(np.int32)
        dst = r.integers(0, n, e).astype(np.int32)
        w = r.integers(1, 1000, e).astype(np.int32)
        blk = block_edges_host(src, dst, w, n)
        n_pad = blk["n_pad_nodes"]
        INF, BIG = 2**31 - 1, 2**30
        d = r.integers(0, 2000, n_pad).astype(np.int32)
        planes = tuple(jnp.asarray(x) for x in (
            d, r.integers(0, n, n_pad).astype(np.int32), d,
            np.full(n_pad, BIG, np.int32), np.full(n_pad, INF, np.int32),
            np.full(n_pad, INF, np.int32)))
        args = (planes, jnp.asarray(blk["src"]), jnp.asarray(blk["dst"]),
                jnp.asarray(blk["w"]), jnp.asarray(blk["mask"]),
                jnp.asarray(blk["block_tile"]), jnp.int32(1000),
                blk["n_tiles"])
        us = _time(lambda *a: edge_relax(*a, impl="ref"), *args)
        # ref: 3 segment-min passes + 2 mask passes over E + gather of 6
        # planes; kernel: 1 pass over E + 1 gather. Bytes ratio:
        ratio = (3 + 2) / 1.0
        rows.append({
            "name": f"edge_relax_n{n}", "us_per_call_ref": round(us, 1),
            "derived_hbm_pass_ratio": ratio,
        })
    emit("kernel_bench", rows)
    return rows


if __name__ == "__main__":
    run()
