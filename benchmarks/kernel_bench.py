"""Kernel micro-benchmarks: ref (3-pass segment-min cascade) vs the fused
one-pass kernel semantics. On CPU the Pallas interpreter is not a timing
proxy, so we time the REF paths (what actually executes offline) and report
the kernel's HBM-pass ratio as the derived metric the TPU would see.

Also benches the decomposition ENGINE's sync/transfer profile: device
supersteps (the paper's MR-round analogue) vs host synchronizations and
plane packs, comparing the seed's chatty host loop model against the
device-resident engine (results -> BENCH_engine.json)."""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.common import bench_engine_path
from repro.kernels.edge_relax.ops import block_edges_host, edge_relax


def _sub_jaxprs(v):
    from jax.core import ClosedJaxpr, Jaxpr
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)


def _count_eqns(jaxpr) -> int:
    """Recursive device-op count. ``pallas_call`` counts as ONE dispatched
    op — its kernel body runs on-chip and is exactly the work the fusion
    removes from the XLA op stream."""
    total = 0
    for eqn in jaxpr.eqns:
        total += 1
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                total += _count_eqns(sub)
    return total


def _while_body(jaxpr):
    """The body jaxpr of the outermost while loop (the superstep loop on the
    chained path; the kernel-launch loop on the fused path)."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "while":
            return eqn.params["body_jaxpr"].jaxpr
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                b = _while_body(sub)
                if b is not None:
                    return b
    return None


def run_kernel_fusion_bench(n: int = 1200, k_fused: int = 8, seed: int = 0):
    """Megakernel contract, CPU-checkable half: the fused grow superstep
    must issue STRICTLY fewer device ops than the chained (unfused) loop.

    Op counts come from the traced jaxprs (one superstep = one iteration of
    the outermost while body; the fused body covers ``k_fused`` supersteps
    per kernel launch). Per-superstep wall times are interpret-mode numbers
    at small n — a semantics check, not a TPU timing proxy.
    """
    from repro.core.backend import PallasBackend
    from repro.graph import random_geometric

    g = random_geometric(n, avg_degree=3.0, seed=seed)
    chain = PallasBackend(g, impl="ref")
    fused = PallasBackend(g, impl="ref", fuse=k_fused)
    st = chain.init_state()
    st = st._replace(d=st.d.at[0].set(0), c=st.c.at[0].set(0),
                     pathw=st.pathw.at[0].set(0))
    delta, half, ni = jnp.int32(300), jnp.int32(n // 2), jnp.int32(32)

    def g_chain(s):
        return chain.grow(s, delta, half, ni, "complete")

    def g_fused(s):
        return fused.grow(s, delta, half, ni, "complete")

    ops_chained = _count_eqns(_while_body(jax.make_jaxpr(g_chain)(st).jaxpr))
    ops_fused_launch = _count_eqns(
        _while_body(jax.make_jaxpr(g_fused)(st).jaxpr))
    ops_fused = ops_fused_launch / k_fused
    assert ops_fused < ops_chained, (
        f"fused superstep issues {ops_fused:.1f} device ops, chained issues "
        f"{ops_chained} — fusion must strictly reduce the op stream")

    t0 = time.perf_counter()
    s1, st1 = g_chain(st)
    jax.block_until_ready(s1.d)
    dt_chain = time.perf_counter() - t0
    t0 = time.perf_counter()
    s2, st2 = g_fused(st)
    jax.block_until_ready(s2.d)
    dt_fused = time.perf_counter() - t0
    steps = max(int(st1.steps), 1)
    assert int(st1.steps) == int(st2.steps)
    np.testing.assert_array_equal(np.asarray(s1.d), np.asarray(s2.d))
    return {
        "graph": f"road-like-n{n}",
        "k_fused": k_fused,
        "device_ops_per_superstep_chained": ops_chained,
        "device_ops_per_superstep_fused": round(ops_fused, 1),
        "op_reduction": round(ops_chained / max(ops_fused, 1e-9), 1),
        "supersteps": steps,
        "kernel_launches": int(st2.kernel_launches),
        "dead_blocks_skipped": int(st2.dead_blocks),
        "interpret_s_per_superstep_chained": round(dt_chain / steps, 4),
        "interpret_s_per_superstep_fused": round(dt_fused / steps, 4),
    }


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    r = np.random.default_rng(0)
    for n, e in [(10_000, 50_000), (100_000, 500_000)]:
        src = r.integers(0, n, e).astype(np.int32)
        dst = r.integers(0, n, e).astype(np.int32)
        w = r.integers(1, 1000, e).astype(np.int32)
        blk = block_edges_host(src, dst, w, n)
        n_pad = blk["n_pad_nodes"]
        INF, BIG = 2**31 - 1, 2**30
        d = r.integers(0, 2000, n_pad).astype(np.int32)
        planes = tuple(jnp.asarray(x) for x in (
            d, r.integers(0, n, n_pad).astype(np.int32), d,
            np.full(n_pad, BIG, np.int32), np.full(n_pad, INF, np.int32),
            np.full(n_pad, INF, np.int32)))
        args = (planes, jnp.asarray(blk["src"]), jnp.asarray(blk["dst"]),
                jnp.asarray(blk["w"]), jnp.asarray(blk["mask"]),
                jnp.asarray(blk["block_tile"]), jnp.int32(1000),
                blk["n_tiles"])
        us = _time(lambda *a: edge_relax(*a, impl="ref"), *args)
        # ref: 3 segment-min passes + 2 mask passes over E + gather of 6
        # planes; kernel: 1 pass over E + 1 gather. Bytes ratio:
        ratio = (3 + 2) / 1.0
        rows.append({
            "name": f"edge_relax_n{n}", "us_per_call_ref": round(us, 1),
            "derived_hbm_pass_ratio": ratio,
        })
    emit("kernel_bench", rows)
    run_engine_sync_bench()
    return rows


BENCH_ENGINE = bench_engine_path()

# update-latency caps for the dynamic bench (see core/dynamic.py): every
# batch costs at most 1 forest sweep + regrow_cap + tighten_cap edge sweeps
DYN_TIGHTEN_CAP = 4
DYN_REGROW_CAP = 8


def run_dynamic_bench(n: int = 20_000, n_batches: int = 6):
    """The dynamic-update contract: amortized supersteps per ~1%-of-edges
    ``UpdateBatch`` versus a full re-decomposition of the same session.

    Asserts (a) the amortized update cost is STRICTLY below the full
    rebuild cost at every scale, (b) the 1/5 contract at the recorded
    bench scale (n >= 20000 — smaller CI graphs decompose in too few
    supersteps for the fixed per-batch floor to amortize against), and
    (c) the post-replay interval bracket is still certified.
    """
    from repro.analysis import guard
    from repro.core import (DynamicQuotientEstimator, IntervalEstimator,
                            open_session)
    from repro.graph import random_geometric, temporal_trace

    g = random_geometric(n, avg_degree=3.0, seed=1)
    sess = open_session(g)
    t0 = time.perf_counter()
    sess.estimate(DynamicQuotientEstimator())   # opens dynamic mode
    dt_open = time.perf_counter() - t0
    st = sess.dynamic
    trace = temporal_trace(g, n_batches,
                           events_per_batch=max(g.n_edges // 200, 8), seed=7)
    syncs0 = st.metrics.update_syncs
    t0 = time.perf_counter()
    actions = []
    with guard.measured_transfers() as upd_meter:
        for b in trace:
            rep = sess.apply_updates(b, tighten_cap=DYN_TIGHTEN_CAP,
                                     regrow_cap=DYN_REGROW_CAP)
            actions.append(rep.action)
    dt_upd = (time.perf_counter() - t0) / max(n_batches, 1)
    m = st.metrics
    upd_syncs = m.update_syncs - syncs0
    assert upd_meter.transfers == upd_syncs, (
        f"dynamic replay measured {upd_meter.transfers} device->host "
        f"transfers but DynamicMetrics counted {upd_syncs}")
    amortized = m.amortized_supersteps
    assert amortized < m.baseline_supersteps, (
        f"amortized update cost {amortized} supersteps/batch is not below "
        f"a full re-decomposition ({m.baseline_supersteps})")
    if n >= 20_000:
        assert amortized * 5 <= m.baseline_supersteps, (
            f"amortized {amortized} supersteps/batch above 1/5 of a full "
            f"re-decomposition ({m.baseline_supersteps})")
    t0 = time.perf_counter()
    iv = sess.estimate(IntervalEstimator())
    dt_est = time.perf_counter() - t0
    assert iv.lower <= iv.upper, (iv.lower, iv.upper)
    block = {
        "graph": f"road-like-n{n}",
        "batches": m.batches,
        "events_per_batch": max(g.n_edges // 200, 8),
        "actions": actions,
        "amortized_update_supersteps": round(amortized, 2),
        "full_redecomposition_supersteps": m.baseline_supersteps,
        "update_ratio": round(amortized / max(m.baseline_supersteps, 1), 3),
        "pointer_rounds": m.pointer_rounds,
        "full_rebuilds": m.full_rebuilds,
        "tighten_cap": DYN_TIGHTEN_CAP,
        "regrow_cap": DYN_REGROW_CAP,
        "update_s_per_batch": round(dt_upd, 3),
        "open_s": round(dt_open, 2),
        "post_update_estimate_s": round(dt_est, 3),
        "update_syncs": upd_syncs,
        "measured_transfers": upd_meter.transfers,
        "interval_lower": iv.lower,
        "interval_upper": iv.upper,
        "connected": iv.connected,
    }
    sess.close()
    return block


def run_stream_bench(n: int = 2_000_000, shards: int = 4,
                     preempt_after: int = 2, lower_rounds: int = 0,
                     levels: int = 2, tau_solve: int = 64,
                     seed: int = 1, out_path: str = BENCH_ENGINE):
    """The out-of-core streaming contract: a graph 100x the n=20k engine
    bench decomposes through a partition-sharded ``GraphStore`` under
    SIMULATED MID-RUN PREEMPTION — a real SIGTERM delivered at a stage
    boundary — then resumes from the durable checkpoint and finishes with
    a byte-identical certified bracket. Asserts:

      (a) the store's static halo plan moves STRICTLY fewer bytes per
          superstep than the full-plane all-gather baseline, and — when
          more than one device is visible — the measured
          ``EngineMetrics.halo_bytes`` of the sharded run stays strictly
          below its ``fullplane_bytes`` counterfactual;
      (b) the interrupted run really was killed mid-decomposition
          (``Preempted`` escaped, >= 1 durable save);
      (c) the resumed run restores exactly once and its [lower, upper]
          interval equals the uninterrupted reference bracket.

    CI re-enters this function at small n (stream-smoke job); the
    recorded BENCH block is the full-scale run.
    """
    import tempfile

    from repro.config.base import GraphEngineConfig
    from repro.core import (CascadeEstimator, IntervalEstimator,
                            LowerBoundEstimator, open_session)
    from repro.graph import GraphStore, random_geometric
    from repro.runtime.fault import Preempted, PreemptionGuard

    g = random_geometric(n, avg_degree=3.0, seed=seed)
    multi = jax.device_count() >= shards > 1
    store = GraphStore(g, n_shards=shards, compress=True)
    halo_b = store.halo_bytes_per_superstep()
    full_b = store.fullplane_bytes_per_superstep()
    assert 0 < halo_b < full_b, (
        f"halo plan moves {halo_b} B/superstep, full-plane baseline "
        f"{full_b} — sharding must strictly shrink the collective")
    cfg = GraphEngineConfig(backend="sharded" if multi else "single",
                            comm="halo", seed=seed)
    # The decomposition (the preemption target) goes FIRST so the killed
    # run dies cheaply at its stage boundary; the cascade keeps the solve
    # off the quadratic flat-quotient path at full scale. The
    # farthest-point lower is optional (``lower_rounds=0`` skips it —
    # each round is a full Bellman-Ford, intractable at n=2M on CPU;
    # the bracket then certifies [0, upper]).
    panel = (CascadeEstimator(levels=levels, tau_solve=tau_solve),)
    if lower_rounds > 0:
        panel = panel + (LowerBoundEstimator(rounds=lower_rounds),)

    # uninterrupted reference bracket
    t0 = time.perf_counter()
    sess = open_session(None, cfg, store=store)
    iv_ref = sess.estimate(IntervalEstimator(estimators=panel))
    dt_ref = time.perf_counter() - t0
    ref_pm = iv_ref.pipeline
    if multi:
        assert 0 < ref_pm.halo_bytes < ref_pm.fullplane_bytes, (
            f"measured halo bytes {ref_pm.halo_bytes} not strictly below "
            f"full-plane {ref_pm.fullplane_bytes}")
    sess.close()

    # interrupted run: a REAL SIGTERM fires at a stage boundary of the
    # decomposition; the durable save lands before Preempted escapes
    ckpt_dir = tempfile.mkdtemp(prefix="repro_stream_ckpt_")
    pg = PreemptionGuard()
    sess_i = open_session(None, cfg, store=store,
                          checkpoint_dir=ckpt_dir, guard=pg)
    sess_i.checkpointer.preempt_after_stage = preempt_after
    t0 = time.perf_counter()
    preempted_at = None
    try:
        with pg:
            sess_i.estimate(IntervalEstimator(estimators=panel))
    except Preempted as p:
        preempted_at = p.stage
    dt_kill = time.perf_counter() - t0
    assert preempted_at is not None, (
        "simulated preemption never fired — decomposition finished before "
        f"stage {preempt_after}")
    saves = sess_i.checkpointer.saves
    assert saves >= 1, "killed run left no durable checkpoint"
    sess_i.close()

    # resume: restore once, finish, byte-identical bracket
    t0 = time.perf_counter()
    sess_r = open_session(None, cfg, store=store, checkpoint_dir=ckpt_dir,
                          resume=True, guard=PreemptionGuard())
    iv_res = sess_r.estimate(IntervalEstimator(estimators=panel))
    dt_res = time.perf_counter() - t0
    assert sess_r.checkpointer.restores == 1, sess_r.checkpointer.restores
    assert (iv_res.lower, iv_res.upper) == (iv_ref.lower, iv_ref.upper), (
        f"resumed bracket [{iv_res.lower}, {iv_res.upper}] != reference "
        f"[{iv_ref.lower}, {iv_ref.upper}] — resume must be byte-identical")
    assert iv_res.connected == iv_ref.connected
    sess_r.checkpointer.complete()
    sess_r.close()

    block = {
        "graph": f"road-like-n{n}",
        "n_nodes": g.n_nodes,
        "n_edges": g.n_edges,
        "scale_vs_engine_bench": round(n / 20_000, 1),
        "shards": store.n_shards,
        "backend": cfg.backend,
        "compress": True,
        "resident_bytes": store.resident_bytes(),
        "raw_bytes": store.raw_bytes(),
        "compression_ratio": round(
            store.raw_bytes() / max(store.resident_bytes(), 1), 3),
        "halo_k": store.halo_k(),
        "halo_bytes_per_superstep": halo_b,
        "fullplane_bytes_per_superstep": full_b,
        "halo_fraction": round(halo_b / max(full_b, 1), 4),
        "measured_halo_bytes": ref_pm.halo_bytes,
        "measured_fullplane_bytes": ref_pm.fullplane_bytes,
        "preempted_at_stage": preempted_at,
        "checkpoint_saves": saves,
        "checkpoint_restores": 1,
        "checkpoint_syncs": ref_pm.checkpoint_syncs,
        "interval_lower": iv_ref.lower,
        "interval_upper": iv_ref.upper,
        "interval_lower_resumed": iv_res.lower,
        "interval_upper_resumed": iv_res.upper,
        "bracket_identical": True,
        "connected": iv_ref.connected,
        "reference_s": round(dt_ref, 2),
        "killed_run_s": round(dt_kill, 2),
        "resumed_run_s": round(dt_res, 2),
    }
    # merge into BENCH_engine.json without clobbering the engine rows
    try:
        with open(out_path) as f:
            row = json.load(f)
    except (OSError, ValueError):
        row = {}
    row["stream"] = block
    with open(out_path, "w") as f:
        json.dump(row, f, indent=1)
    print("stream:", json.dumps(block))
    return block


def run_engine_sync_bench(n: int = 20_000, tau: int = 32,
                          out_path: str = BENCH_ENGINE,
                          warm_queries: int = 3):
    """Supersteps vs host-syncs: seed's chatty loop model vs the engine.

    Seed cost model (per CLUSTER call): one uncovered-counter sync per
    stage + two scalar syncs (steps, reached) per grow call, and — on the
    distributed path — one full plane pack/pad + device_put per grow call.
    Device-resident engine: one sync per stage, one pack total. Asserts the
    acceptance criteria: pack <= 1 per cluster() call, syncs == stages.

    Also benches the SESSION serving contract: one ``open_session`` +
    ``warm_queries`` repeat queries. Asserts (a) warm queries perform ZERO
    backend rebuilds and ZERO edge re-uploads (``SessionMetrics``), and
    (b) ``IntervalEstimator`` certifies lower <= upper on the bench graph
    with bounds matching the legacy scripts' numbers.
    """
    from repro.analysis import guard
    from repro.core import (
        CascadeEstimator,
        ClusterQuotientEstimator,
        IntervalEstimator,
        LowerBoundEstimator,
        cluster,
        open_session,
    )
    from repro.graph import random_geometric

    g = random_geometric(n, avg_degree=3.0, seed=1)
    t0 = time.perf_counter()
    with guard.measured_transfers() as stage_meter:
        dec = cluster(g, tau, seed=3)
    dt = time.perf_counter() - t0
    m = dec.metrics
    assert m.state_transfers <= 1, f"plane pack ran {m.state_transfers}x"
    assert m.host_syncs == m.stages, (m.host_syncs, m.stages)
    # every sync the metrics claim is a transfer the guard measured — the
    # counter is a proven measurement, not bookkeeping (repro.analysis)
    assert stage_meter.transfers == m.host_syncs + m.finalize_syncs, (
        stage_meter.transfers, m.host_syncs, m.finalize_syncs)

    old_syncs = m.stages + 2 * m.grow_calls   # chatty-loop model (see above)
    old_packs = m.grow_calls                  # distributed seed packed per grow
    row = {
        "graph": f"road-like-n{n}",
        "supersteps": m.growing_steps,        # MR-round analogue (device)
        "stages": m.stages,
        "grow_calls": m.grow_calls,
        "host_syncs_engine": m.host_syncs,
        "host_syncs_chatty_loop": old_syncs,
        "plane_packs_engine": m.state_transfers,
        "plane_packs_chatty_loop": old_packs,
        "sync_reduction": round(old_syncs / max(m.host_syncs, 1), 2),
        "host_syncs_total": m.host_syncs + m.finalize_syncs,
        "measured_transfers": stage_meter.transfers,
        "seconds": round(dt, 2),
    }

    # full pipeline: decompose -> device quotient -> batched BF solve, at
    # the pipeline's own production tau (paper: quotient ~ n/1000 nodes).
    # Acceptance: <= 8 host syncs end-to-end on the bench graph.
    sess = open_session(g)
    t0 = time.perf_counter()
    with guard.measured_transfers() as pipe_meter:
        est = sess.estimate(ClusterQuotientEstimator())
    dt_pipe = time.perf_counter() - t0
    pm = est.pipeline
    assert pm is not None
    assert pm.total_host_syncs <= 8, f"pipeline ran {pm.total_host_syncs} syncs"
    assert pipe_meter.transfers == pm.total_host_syncs, (
        pipe_meter.transfers, pm.total_host_syncs)
    row["pipeline"] = {
        "phi_approx": est.phi_approx,
        "n_clusters": est.n_clusters,
        "quotient_edges": pm.n_quotient_edges,
        "host_syncs_total": pm.total_host_syncs,
        "measured_transfers": pipe_meter.transfers,
        "host_syncs_decompose": pm.decompose_syncs,
        "host_syncs_finalize": pm.finalize_syncs,
        "host_syncs_quotient": pm.quotient_syncs,
        "host_syncs_solve": pm.solve_syncs,
        "solve_supersteps": pm.solve_supersteps,
        "seconds": round(dt_pipe, 2),
    }

    # multi-level quotient cascade: same session, quotient re-decomposed
    # until it fits a small solve budget. Acceptance: the final solve runs
    # STRICTLY fewer BF supersteps than the flat pipeline's, and the
    # cascade's upper still brackets against the farthest-point lower.
    t0 = time.perf_counter()
    with guard.measured_transfers() as casc_meter:
        casc = sess.estimate(CascadeEstimator(levels=2, tau_solve=64))
    dt_casc = time.perf_counter() - t0
    cpm = casc.pipeline
    assert casc_meter.transfers == cpm.total_host_syncs, (
        casc_meter.transfers, cpm.total_host_syncs)
    assert cpm.cascade_levels >= 1, "bench cascade never cascaded"
    assert cpm.solve_supersteps < pm.solve_supersteps, (
        f"cascade solve ran {cpm.solve_supersteps} supersteps, flat ran "
        f"{pm.solve_supersteps}")
    # each extra level only coarsens: diam(Q_l) <= 2 R_{l+1} + diam(Q_{l+1})
    assert casc.phi_approx >= est.phi_approx, (casc.phi_approx, est.phi_approx)
    iv_c = sess.estimate(IntervalEstimator(estimators=(
        LowerBoundEstimator(), CascadeEstimator(levels=2, tau_solve=64))))
    assert iv_c.lower <= iv_c.upper, (iv_c.lower, iv_c.upper)
    assert iv_c.connected == casc.connected == est.connected
    row["cascade"] = {
        "levels": cpm.cascade_levels,
        "tau_solve": 64,
        "phi_approx": casc.phi_approx,
        "level_clusters": cpm.level_clusters,
        "level_supersteps": cpm.level_supersteps,
        "level_syncs": cpm.level_syncs,
        "solve_supersteps": cpm.solve_supersteps,
        "solve_supersteps_flat": pm.solve_supersteps,
        "host_syncs_total": cpm.total_host_syncs,
        "measured_transfers": casc_meter.transfers,
        "interval_lower": iv_c.lower,
        "interval_upper": iv_c.upper,
        "connected": casc.connected,
        "seconds": round(dt_casc, 2),
    }

    # one-shot exponential-shift mode (core/engine.run_oneshot): the whole
    # decomposition is ONE jitted fixpoint. Acceptance: strictly fewer host
    # syncs than the stage engine on the same graph/tau/seed, and the
    # certified bracket stays valid when the pipeline's level-0
    # decomposition runs in oneshot mode.
    t0 = time.perf_counter()
    with guard.measured_transfers() as one_meter:
        dec_1 = cluster(g, tau, seed=3, mode="oneshot")
    dt_1 = time.perf_counter() - t0
    m1 = dec_1.metrics
    assert one_meter.transfers == m1.host_syncs + m1.finalize_syncs, (
        one_meter.transfers, m1.host_syncs, m1.finalize_syncs)
    assert m1.host_syncs < m.host_syncs, (
        f"oneshot ran {m1.host_syncs} host syncs, stage engine ran "
        f"{m.host_syncs} — the mode exists to beat the stage loop's syncs")
    assert m1.host_syncs == 1 and m1.stages == 1, m1
    assert m1.state_transfers <= 1, m1
    iv_1 = sess.estimate(IntervalEstimator(estimators=(
        LowerBoundEstimator(), ClusterQuotientEstimator(mode="oneshot"))))
    assert iv_1.lower <= iv_1.upper, (iv_1.lower, iv_1.upper)
    row["oneshot"] = {
        "supersteps": dec_1.growing_steps,
        "supersteps_stages": m.growing_steps,
        "host_syncs": m1.host_syncs,
        "host_syncs_total": m1.host_syncs + m1.finalize_syncs,
        "measured_transfers": one_meter.transfers,
        "host_syncs_stages": m.host_syncs,
        "sync_reduction": round(m.host_syncs / max(m1.host_syncs, 1), 2),
        "radius": dec_1.radius,
        "radius_stages": dec.radius,
        "n_clusters": dec_1.n_clusters,
        "n_clusters_stages": dec.n_clusters,
        "interval_lower": iv_1.lower,
        "interval_upper": iv_1.upper,
        "connected": iv_1.connected,
        "seconds": round(dt_1, 2),
    }

    # session serving contract: repeat queries must stay resident. (No
    # amortization ratio here — the engine bench above already compiled the
    # shared programs in-process, so the "first" query is NOT cold; the
    # serve driver measures real cold-vs-warm amortization.)
    sm = sess.metrics
    builds0, uploads0 = sm.backend_builds, sm.edge_uploads
    t0 = time.perf_counter()
    for _ in range(warm_queries):
        sess.estimate(ClusterQuotientEstimator())
    dt_warm = (time.perf_counter() - t0) / max(warm_queries, 1)
    rebuilds = sm.backend_builds - builds0
    reuploads = sm.edge_uploads - uploads0
    assert rebuilds == 0, f"warm queries rebuilt the backend {rebuilds}x"
    assert reuploads == 0, f"warm queries re-uploaded edges {reuploads}x"

    # dynamic updates: amortized in-place absorption vs full rebuild, on a
    # FRESH session (this one's graph must keep serving the asserts above).
    # Only at the recorded bench scale — the quotient/cascade CI smokes
    # re-enter this function at n=6000 and must not pay the replay (the
    # dedicated dynamic-smoke job runs run_dynamic_bench directly).
    if n >= 20_000:
        row["dynamic"] = run_dynamic_bench(n=n)

    # megakernel + autotuner contract: (a) the fused superstep issues
    # strictly fewer device ops than the chained loop (asserted inside the
    # fusion bench), and (b) the autotuned knobs match-or-beat the fixed
    # defaults on warm pipeline latency. The latency assert is gated at the
    # recorded bench scale — CI smokes at n=6000 are noise-dominated.
    kb = run_kernel_fusion_bench()
    from repro.config.base import GraphEngineConfig
    tuned_sess = open_session(g, GraphEngineConfig(autotune="auto"))
    tuned_sess.estimate()                       # compile + cold query
    t0 = time.perf_counter()
    est_tuned = tuned_sess.estimate()
    dt_tuned = time.perf_counter() - t0
    t0 = time.perf_counter()
    sess.estimate(ClusterQuotientEstimator())   # flat defaults, same warmth
    dt_flat = time.perf_counter() - t0
    tpm = est_tuned.pipeline
    if n >= 20_000:
        assert dt_tuned <= dt_flat * 1.1, (
            f"autotuned warm query took {dt_tuned:.3f}s vs flat default "
            f"{dt_flat:.3f}s — tuning must match-or-beat the defaults")
        if tpm.cascade_levels:
            assert tpm.solve_supersteps < pm.solve_supersteps, (
                tpm.solve_supersteps, pm.solve_supersteps)
    t = tuned_sess.tuning
    kb["autotune"] = {
        "tau": t.tau, "tau_solve": t.tau_solve, "levels": t.levels,
        "delta_init": t.delta_init,
        "node_tile": t.node_tile, "edge_block": t.edge_block,
        "fuse": t.fuse,
        "predicted_superstep_s": round(t.predicted_superstep_s, 6),
        "warm_query_s_tuned": round(dt_tuned, 3),
        "warm_query_s_default": round(dt_flat, 3),
        "phi_approx_tuned": est_tuned.phi_approx,
        "solve_supersteps_tuned": tpm.solve_supersteps,
        "solve_supersteps_default": pm.solve_supersteps,
    }
    tuned_sess.close()
    row["kernel"] = kb

    iv = sess.estimate(IntervalEstimator())
    assert iv.lower <= est.phi_approx, (iv.lower, est.phi_approx)
    assert iv.lower <= iv.upper, (iv.lower, iv.upper)
    row["session"] = {
        "backend_builds": sm.backend_builds,
        "edge_uploads": sm.edge_uploads,
        "queries": sm.queries,
        "warm_queries": sm.warm_queries,
        "warm_rebuilds": rebuilds,
        "warm_reuploads": reuploads,
        "warm_query_s": round(dt_warm, 3),
        "interval_lower": iv.lower,
        "interval_upper": iv.upper,
        "interval_host_syncs": iv.pipeline.total_host_syncs,
    }

    # telemetry contract (PR 10): tracing is pure host bookkeeping — the
    # warm-query wall time stays within 5% of untraced, and the measured
    # transfer total partitions EXACTLY into named spans (every sync is
    # attributed to the innermost live span; none left on the floor).
    from repro.runtime import telemetry

    def _warm_query():
        sess.estimate(ClusterQuotientEstimator())

    reps = 3
    _warm_query()                                # equalize warmth
    off = []
    for _ in range(reps):
        t0 = time.perf_counter()
        _warm_query()
        off.append(time.perf_counter() - t0)
    tracer = telemetry.Tracer()
    on = []
    with telemetry.tracing(tracer), guard.measured_transfers() as tele_meter:
        for _ in range(reps):
            t0 = time.perf_counter()
            _warm_query()
            on.append(time.perf_counter() - t0)
    attributed = tracer.total_transfers()
    assert attributed == tele_meter.transfers, (
        f"span attribution lost syncs: {attributed} attributed vs "
        f"{tele_meter.transfers} measured")
    by_span = {name: sum(r.values())
               for name, r in sorted(tracer.attribution().items())}
    overhead = min(on) / max(min(off), 1e-9)
    if n >= 20_000:                              # CI smokes are noise-bound
        assert overhead <= 1.05, (
            f"tracing overhead {overhead:.3f}x exceeds the 1.05x budget "
            f"(traced {min(on):.4f}s vs untraced {min(off):.4f}s)")
    row["telemetry"] = {
        "warm_query_s_untraced": round(min(off), 4),
        "warm_query_s_traced": round(min(on), 4),
        "overhead_ratio": round(overhead, 3),
        "overhead_budget": 1.05,
        "measured_transfers": tele_meter.transfers,
        "attributed_transfers": attributed,
        "sync_attribution": by_span,
        "spans": len(tracer.spans),
    }
    sess.close()

    # the transfer-guard equality contracts (repro.analysis): every block's
    # hand-incremented sync counter equals the number of device->host
    # transfers the guard actually measured over that region, so the BENCH
    # sync numbers are proven measurements. Each pair was already asserted
    # equal at its measurement site above; a drift breaks the bench loudly.
    contracts = {
        "stages": {"measured_transfers": stage_meter.transfers,
                   "counted_syncs": m.host_syncs + m.finalize_syncs},
        "oneshot": {"measured_transfers": one_meter.transfers,
                    "counted_syncs": m1.host_syncs + m1.finalize_syncs},
        "pipeline": {"measured_transfers": pipe_meter.transfers,
                     "counted_syncs": pm.total_host_syncs},
        "cascade": {"measured_transfers": casc_meter.transfers,
                    "counted_syncs": cpm.total_host_syncs},
    }
    if "dynamic" in row:
        contracts["dynamic"] = {
            "measured_transfers": row["dynamic"]["measured_transfers"],
            "counted_syncs": row["dynamic"]["update_syncs"]}
    all_equal = all(c["measured_transfers"] == c["counted_syncs"]
                    for c in contracts.values())
    assert all_equal, contracts
    row["analysis"] = {
        "meter": "repro.analysis.guard: cooperative guard.fetch metering "
                 "under jax.transfer_guard (teeth on TPU/GPU; sync-lint is "
                 "the universal static enforcement)",
        "contracts": contracts,
        "all_equal": all_equal,
    }

    with open(out_path, "w") as f:
        json.dump(row, f, indent=1)
    print(",".join(f"{k}={v}" for k, v in row.items()))
    return row


if __name__ == "__main__":
    import sys

    if len(sys.argv) > 1 and sys.argv[1] == "stream":
        # standalone entry so CI / large runs can set XLA_FLAGS (e.g.
        # --xla_force_host_platform_device_count=4) before jax initializes
        n_arg = int(sys.argv[2]) if len(sys.argv) > 2 else 2_000_000
        shards_arg = int(sys.argv[3]) if len(sys.argv) > 3 else 4
        rounds_arg = int(sys.argv[4]) if len(sys.argv) > 4 else 0
        run_stream_bench(n=n_arg, shards=shards_arg,
                         lower_rounds=rounds_arg)
    else:
        run()
