"""Paper Table 4 / Figure 1: approximation ratio vs weight std-dev sigma.

Four topologies (two social-like, mesh, road-like), normal weights
symmetrized around mu=1 with sigma in {0, 2^1..2^12}, 10 runs averaged at
paper fidelity (3 here for CPU budget). Expected reproduction: ratio falls
with sigma on dense social graphs, stays flat / drifts up on sparse ones.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, true_diameter
from repro.config.base import GraphEngineConfig
from repro.core import ClusterQuotientEstimator, open_session
from repro.graph import grid_mesh, random_geometric, social_like
from repro.graph.generators import assign_weights
from repro.graph.structures import EdgeList


def _with_weights(g: EdgeList, sigma: float, seed: int) -> EdgeList:
    if sigma == 0:
        w = np.ones(g.n_edges, np.int32)
    else:
        w = assign_weights(g.n_edges, "normal", seed=seed, sigma=sigma, mu=1.0)
    return EdgeList(g.n_nodes, g.src, g.dst, w)


def run(scale: float = 1.0, repeats: int = 3):
    topos = {
        "orkut-like": social_like(12, 16, seed=4),
        "livejournal-like": social_like(12, 8, seed=5),
        "mesh": grid_mesh(48, seed=6),
        "roads-CAL-like": random_geometric(int(20_000 * scale), 3.0, seed=7),
    }
    sigmas = [0] + [2 ** i for i in range(1, 13, 2)]
    rows = []
    for tname, g0 in topos.items():
        for sigma in sigmas:
            ratios = []
            for rep in range(repeats):
                g = _with_weights(g0, sigma, seed=100 + rep)
                phi = true_diameter(g)
                est = open_session(
                    g, GraphEngineConfig(seed=rep),
                    tau=max(g.n_nodes // 256, 4),
                ).estimate(ClusterQuotientEstimator())
                ratios.append(est.phi_approx / max(phi, 1))
            rows.append({
                "topology": tname, "sigma": sigma,
                "eps_mean": round(float(np.mean(ratios)), 3),
                "eps_std": round(float(np.std(ratios)), 3),
            })
    emit("table4_sigma", rows)
    return rows


if __name__ == "__main__":
    run()
