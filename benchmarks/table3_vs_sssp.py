"""Paper Table 3: CLUSTER vs SSSP-BF (the practical competitor).

The paper's headline: CLUSTER is up to ~10x faster on road networks (high
unweighted diameter) with approximation <= 1.5, while on social networks the
gap narrows. Offline, wall time on one CPU is an imperfect proxy for a
16-node Spark cluster, so we report BOTH wall time and the platform-
independent ROUND count: growing steps (CLUSTER) vs Bellman-Ford supersteps
(SSSP-BF). Rounds are exactly what Theorem 1 bounds.
"""
from __future__ import annotations

import time

from benchmarks.common import benchmark_graphs, emit, engine_config, true_diameter
from repro.core import approximate_diameter, diameter_2approx_sssp


def run(scale: float = 1.0):
    rows = []
    for name, g in benchmark_graphs(scale).items():
        phi = true_diameter(g)

        t0 = time.perf_counter()
        est = approximate_diameter(g, engine_config(tau_fraction=2e-2))
        t_cluster = time.perf_counter() - t0

        t0 = time.perf_counter()
        lb, ub, supersteps, _connected = diameter_2approx_sssp(g, seed=7)
        t_sssp = time.perf_counter() - t0

        rows.append({
            "graph": name,
            "t_cluster_s": round(t_cluster, 2),
            "t_sssp_bf_s": round(t_sssp, 2),
            "rounds_cluster": est.growing_steps,
            "rounds_sssp_bf": supersteps,
            "round_speedup": round(supersteps / max(est.growing_steps, 1), 2),
            "eps_cluster": round(est.phi_approx / max(phi, 1), 3),
            "eps_sssp_bf": round(ub / max(phi, 1), 3),
        })
    emit("table3_vs_sssp", rows)
    road = [r for r in rows if "road" in r["graph"]][0]
    assert road["round_speedup"] > 2, "round advantage must hold on roads"
    assert all(r["eps_cluster"] < 2.0 for r in rows)
    return rows


if __name__ == "__main__":
    run()
