"""Paper Table 3: CLUSTER vs SSSP-BF (the practical competitor).

The paper's headline: CLUSTER is up to ~10x faster on road networks (high
unweighted diameter) with approximation <= 1.5, while on social networks the
gap narrows. Offline, wall time on one CPU is an imperfect proxy for a
16-node Spark cluster, so we report BOTH wall time and the platform-
independent ROUND count: growing steps (CLUSTER) vs Bellman-Ford supersteps
(SSSP-BF). Rounds are exactly what Theorem 1 bounds.

Both methods are ``DiameterEstimator`` queries against ONE resident
``GraphSession`` per graph — the paper's Table-3 comparison as a first-class
API call (the SSSP estimator reads the same device edge buffers the
decomposition used, so the timing gap is pure algorithm, not upload skew).
"""
from __future__ import annotations

import time

from benchmarks.common import benchmark_graphs, emit, engine_config, true_diameter
from repro.core import (CascadeEstimator, ClusterQuotientEstimator,
                        DeltaSteppingEstimator, open_session)


def run(scale: float = 1.0):
    rows = []
    for name, g in benchmark_graphs(scale).items():
        phi = true_diameter(g)
        sess = open_session(g, engine_config(tau_fraction=2e-2))

        t0 = time.perf_counter()
        est = sess.estimate(ClusterQuotientEstimator())
        t_cluster = time.perf_counter() - t0

        # multi-level cascade on the SAME session (tau_solve forced small so
        # CPU-scale graphs actually cascade); the quotient solve must shrink
        t0 = time.perf_counter()
        casc = sess.estimate(CascadeEstimator(levels=2, tau_solve=32))
        t_cascade = time.perf_counter() - t0

        t0 = time.perf_counter()
        sssp = sess.estimate(DeltaSteppingEstimator(seed=7))
        t_sssp = time.perf_counter() - t0

        rows.append({
            "graph": name,
            "t_cluster_s": round(t_cluster, 2),
            "t_cascade_s": round(t_cascade, 2),
            "t_sssp_bf_s": round(t_sssp, 2),
            "rounds_cluster": est.growing_steps,
            "rounds_cascade": casc.growing_steps,
            "rounds_sssp_bf": sssp.growing_steps,
            "round_speedup": round(
                sssp.growing_steps / max(est.growing_steps, 1), 2),
            "eps_cluster": round(est.phi_approx / max(phi, 1), 3),
            "eps_cascade": round(casc.phi_approx / max(phi, 1), 3),
            "eps_sssp_bf": round(sssp.phi_approx / max(phi, 1), 3),
            "cascade_levels": casc.pipeline.cascade_levels,
            "solve_supersteps_flat": est.pipeline.solve_supersteps,
            "solve_supersteps_cascade": casc.pipeline.solve_supersteps,
        })
        sess.close()
    emit("table3_vs_sssp", rows)
    road = [r for r in rows if "road" in r["graph"]][0]
    assert road["round_speedup"] > 2, "round advantage must hold on roads"
    assert all(r["eps_cluster"] < 2.0 for r in rows)
    # the cascade stays a conservative upper bound (>= 1 when exact phi is
    # exact; true_diameter falls back to a lower bound on big graphs, which
    # only strengthens the inequality)
    assert all(r["eps_cascade"] >= 1.0 for r in rows), rows
    assert all(r["solve_supersteps_cascade"] <= r["solve_supersteps_flat"]
               for r in rows), rows
    return rows


if __name__ == "__main__":
    run()
