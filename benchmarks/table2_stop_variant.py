"""Paper Table 2: `complete` vs `stop` PartialGrowth variants.

For each benchmark graph: estimated diameter, ratio vs true/lower-bound
diameter, wall time, and growing-step count (the platform-independent round
proxy) for both variants. The paper's finding to reproduce: `stop` is faster
with negligible approximation degradation.
"""
from __future__ import annotations

import time

from benchmarks.common import benchmark_graphs, emit, engine_config, true_diameter
from repro.core import ClusterQuotientEstimator, open_session


def run(scale: float = 1.0):
    rows = []
    for name, g in benchmark_graphs(scale).items():
        phi = true_diameter(g)
        # one resident session; the two variants are per-query overrides
        sess = open_session(g, engine_config(tau_fraction=2e-2))
        for variant in ("complete", "stop"):
            t0 = time.perf_counter()
            est = sess.estimate(ClusterQuotientEstimator(variant=variant))
            dt = time.perf_counter() - t0
            rows.append({
                "graph": name, "variant": variant, "phi_true": phi,
                "phi_approx": est.phi_approx,
                "ratio": round(est.phi_approx / max(phi, 1), 3),
                "steps": est.growing_steps, "clusters": est.n_clusters,
                "seconds": round(dt, 2),
            })
        sess.close()
    emit("table2_stop_variant", rows)
    # paper's claim: stop <= complete in steps, ratio degradation negligible
    by = {(r["graph"], r["variant"]): r for r in rows}
    for gname in {r["graph"] for r in rows}:
        s, c = by[(gname, "stop")], by[(gname, "complete")]
        assert s["steps"] <= c["steps"] + 2, (gname, "stop must not do more work")
    return rows


if __name__ == "__main__":
    run()
