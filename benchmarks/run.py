"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale 0.5] [--only table3]

Writes JSON per table under results/ and prints CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    cluster2_ablation,
    delta_init,
    kernel_bench,
    table1_graphs,
    table2_stop_variant,
    table3_vs_sssp,
    table4_sigma,
)

TABLES = {
    "table1": lambda scale: table1_graphs.run(scale),
    "table2": lambda scale: table2_stop_variant.run(scale),
    "table3": lambda scale: table3_vs_sssp.run(scale),
    "table4": lambda scale: table4_sigma.run(scale),
    "delta_init": lambda scale: delta_init.run(),
    "kernels": lambda scale: kernel_bench.run(),
    "cluster2": lambda scale: cluster2_ablation.run(),
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    failures = []
    for name, fn in TABLES.items():
        if args.only and args.only not in name:
            continue
        print(f"### {name} " + "#" * 50, flush=True)
        t0 = time.perf_counter()
        try:
            fn(args.scale)
            print(f"### {name} done in {time.perf_counter() - t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print("BENCH FAILURES:", failures)
        return 1
    print("all benchmarks complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
