"""Paper Section 5 "Impact of parameter Delta" experiment.

1024x1024 mesh (scaled), weights 1e6 w.p. 0.1 else 1. Run once with
Delta_init = 1 (paper: ends at 64, ratio 1.001) and once with Delta_init =
the graph diameter (paper: ratio ~8). Also the paper's practical default
Delta_init = avg edge weight.
"""
from __future__ import annotations

from benchmarks.common import emit, true_diameter
from repro.config.base import GraphEngineConfig
from repro.core import ClusterQuotientEstimator, open_session
from repro.graph import grid_mesh


def run(side: int = 128):
    g = grid_mesh(side, "bimodal", heavy_w=10**6, heavy_p=0.1, seed=8)
    phi = true_diameter(g)
    rows = []
    # one resident session; Delta_init is a per-query override
    sess = open_session(g, GraphEngineConfig())
    for name, delta0 in [("min", "min"), ("avg", "avg"),
                         ("diameter", str(max(phi, 1)))]:
        est = sess.estimate(ClusterQuotientEstimator(delta_init=delta0))
        rows.append({
            "delta_init": name, "phi_true": phi, "phi_approx": est.phi_approx,
            "ratio": round(est.phi_approx / max(phi, 1), 3),
            "delta_end": est.delta_end, "steps": est.growing_steps,
        })
    emit("delta_init", rows)
    by = {r["delta_init"]: r for r in rows}
    # the paper's qualitative finding: huge initial Delta hurts the ratio
    assert by["min"]["ratio"] <= by["diameter"]["ratio"] + 1e-9
    return rows


if __name__ == "__main__":
    run()
