"""Shared benchmark plumbing: CPU-scaled versions of the paper's graphs.

The paper's benchmarks (Table 1) are DIMACS road networks (up to 2.4e7
nodes) and SNAP social graphs (up to 4e6 nodes) on a 16-node Spark cluster.
Offline on one CPU we reproduce each FAMILY at the largest size that keeps
the full suite in CPU-minutes, holding the paper's structural knobs
(weights, density, topology) fixed; DESIGN.md §7 records the substitution.
tau scales as n/50 instead of the paper's n/1000 — at CPU scale n/1000 would
give a degenerate 4-node quotient; the paper's own rule is "as large as fits
one reducer", and n/50 preserves quotient_size << n while keeping the
estimator statistically meaningful.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.graph import grid_mesh, random_geometric, social_like
from repro.graph.structures import EdgeList, to_scipy_csr

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "/root/repo/results")


def true_diameter(edges: EdgeList, exact_limit: int = 9_000) -> int:
    """Exact weighted diameter via scipy for small graphs; for larger ones
    the paper's own farthest-point SSSP lower bound (Table 1 methodology)."""
    if edges.n_nodes <= exact_limit:
        from scipy.sparse.csgraph import shortest_path
        d = shortest_path(to_scipy_csr(edges), method="D", directed=False)
        fin = d[np.isfinite(d)]
        return int(fin.max())
    from repro.core import farthest_point_lower_bound
    lb, _connected = farthest_point_lower_bound(edges, rounds=6)
    return lb


def benchmark_graphs(scale: float = 1.0) -> Dict[str, EdgeList]:
    """The paper's three graph families at CPU scale."""
    n_road = int(40_000 * scale)
    side = int(64 * max(scale, 0.25))
    return {
        "road-CAL-like": random_geometric(n_road, avg_degree=3.0, seed=1),
        "lj-uniform-like": social_like(
            13, 8, seed=2, weight_dist="uniform", high=2**26),
        "mesh-bimodal": grid_mesh(side, "bimodal", heavy_w=10**6, heavy_p=0.1,
                                  seed=3),
    }


def engine_config(backend: str = "single", **kw) -> "GraphEngineConfig":
    """GraphEngineConfig for benches: backend selectable via REPRO_BACKEND
    (single | sharded | pallas) without editing every table module."""
    from repro.config.base import GraphEngineConfig

    backend = os.environ.get("REPRO_BACKEND", backend)
    return GraphEngineConfig(backend=backend, **kw)


def emit(table: str, rows: List[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{table}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    # CSV to stdout (the bench contract: name,us_per_call,derived)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    return path
