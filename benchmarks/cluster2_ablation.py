"""Paper optimization (1) ablation: CLUSTER vs CLUSTER2 for the
decomposition step. The paper chose CLUSTER in its experiments; we verify
CLUSTER2 (the theory-faithful Alg. 2) costs more rounds at similar quality."""
from __future__ import annotations

import time

from benchmarks.common import benchmark_graphs, emit, true_diameter
from repro.config.base import GraphEngineConfig
from repro.core import ClusterQuotientEstimator, open_session


def run(scale: float = 0.5):
    rows = []
    for name, g in benchmark_graphs(scale).items():
        phi = true_diameter(g)
        # one resident session; the algorithms are per-query overrides
        sess = open_session(g, GraphEngineConfig(tau_fraction=2e-2))
        for use2 in (False, True):
            t0 = time.perf_counter()
            est = sess.estimate(ClusterQuotientEstimator(use_cluster2=use2))
            rows.append({
                "graph": name, "algo": "CLUSTER2" if use2 else "CLUSTER",
                "ratio": round(est.phi_approx / max(phi, 1), 3),
                "steps": est.growing_steps,
                "clusters": est.n_clusters,
                "seconds": round(time.perf_counter() - t0, 2),
            })
        sess.close()
    emit("cluster2_ablation", rows)
    return rows


if __name__ == "__main__":
    run()
