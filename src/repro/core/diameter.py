"""DEPRECATED one-shot entry points, kept as thin wrappers over the
session API (``core/session.py`` + ``core/estimators.py``).

``approximate_diameter(edges, cfg)`` opens a throwaway ``GraphSession`` and
runs ``ClusterQuotientEstimator`` — paying the full open cost (edge upload,
backend build) on every call. For repeated queries, method comparisons, or
many graphs, use the resident-graph API instead:

    from repro.core import open_session, ClusterQuotientEstimator
    sess = open_session(edges, cfg)          # upload + build ONCE
    est = sess.estimate()                    # paper pipeline
    est2 = sess.estimate(ClusterQuotientEstimator(variant="complete"))

    from repro.core import SessionPool
    with SessionPool(cfg) as pool:           # many same-shaped graphs,
        ests = pool.estimate_many(graphs)    # one shared compile per bucket

Both wrappers emit ``DeprecationWarning`` and produce field-identical
``DiameterEstimate``s to the session path (asserted by
``tests/test_session.py``). ``PipelineMetrics`` / ``DiameterEstimate`` /
``tau_for`` / ``EDGE_BUCKET`` re-exports keep old import sites working.
"""
from __future__ import annotations

import warnings
from typing import List, Optional, Sequence

from repro.config.base import GraphEngineConfig
from repro.core.estimators import (  # noqa: F401  (re-exported)
    ClusterQuotientEstimator,
    DiameterEstimate,
    PipelineMetrics,
)
from repro.core.session import (  # noqa: F401  (re-exported)
    EDGE_BUCKET,
    GraphSession,
    SessionPool,
    _pad_edges,
    tau_for,
)
from repro.graph.structures import EdgeList

_DEPRECATION = (
    "{name}() is deprecated: it rebuilds the backend and re-uploads the edge "
    "arrays on every call. Use repro.core.open_session(...) + a "
    "DiameterEstimator (or SessionPool for many graphs) instead."
)


def approximate_diameter(
    edges: EdgeList,
    cfg: Optional[GraphEngineConfig] = None,
    tau: Optional[int] = None,
    relax_fn=None,
    solver: str = "device",
) -> DiameterEstimate:
    """Deprecated one-shot paper pipeline. ``relax_fn`` (a RelaxBackend)
    overrides the backend selected by ``cfg.backend``."""
    warnings.warn(_DEPRECATION.format(name="approximate_diameter"),
                  DeprecationWarning, stacklevel=2)
    sess = GraphSession(edges, cfg, tau=tau, backend=relax_fn)
    return ClusterQuotientEstimator(solver=solver).estimate(sess)


def approximate_diameter_batch(
    graphs: Sequence[EdgeList],
    cfg: Optional[GraphEngineConfig] = None,
    tau: Optional[int] = None,
) -> List[DiameterEstimate]:
    """Deprecated batch entry point; delegates to ``SessionPool`` (same
    node-count grouping, same edge-pad buckets, same per-graph delta_init
    resolution — estimates are field-identical to the old loop)."""
    warnings.warn(_DEPRECATION.format(name="approximate_diameter_batch"),
                  DeprecationWarning, stacklevel=2)
    with SessionPool(cfg) as pool:
        return pool.estimate_many(graphs, tau=tau)
