"""End-to-end diameter approximation (paper Section 4 + Section 5 pipeline).

Phi_approx(G) = Phi(G_C) + 2 * R, where G_C is the quotient of the
decomposition and R its radius. Conservative: Phi_approx >= Phi(G).
Defaults follow the paper's experimental choices: CLUSTER (not CLUSTER2),
"stop" variant, Delta_init = average edge weight, tau ~ n/1000 quotient size.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.common import Timer, get_logger
from repro.config.base import GraphEngineConfig
from repro.core.backend import make_backend
from repro.core.cluster import Decomposition, cluster, cluster2
from repro.core.quotient import build_quotient, quotient_diameter
from repro.graph.structures import EdgeList

log = get_logger("repro.diameter")


@dataclass
class DiameterEstimate:
    phi_approx: int
    phi_quotient: int
    radius: int
    n_clusters: int
    growing_steps: int
    n_stages: int
    delta_end: int
    seconds: float
    connected: bool
    # phi_approx is a conservative estimate of the diameter ONLY when
    # ``connected`` — for a disconnected graph it upper-bounds the largest
    # finite-distance pair (the true diameter is infinite).


def tau_for(n_nodes: int, fraction: float = 1e-3, minimum: int = 4) -> int:
    """Paper Section 5: pick tau so the quotient has ~ n/1000 nodes. CLUSTER
    yields O(tau log^2 n) clusters; in practice ~ tau * small-constant, so we
    take tau = n * fraction / log(n) with a floor."""
    logn = max(math.log(max(n_nodes, 2)), 1.0)
    return max(int(n_nodes * fraction / logn), minimum)


def approximate_diameter(
    edges: EdgeList,
    cfg: Optional[GraphEngineConfig] = None,
    tau: Optional[int] = None,
    relax_fn=None,
) -> DiameterEstimate:
    """Paper pipeline. ``relax_fn`` (a RelaxBackend) overrides the backend
    selected by ``cfg.backend``; for a disconnected input the estimate covers
    only finite-distance pairs and ``connected`` is False."""
    cfg = cfg or GraphEngineConfig()
    tau = tau or tau_for(edges.n_nodes, cfg.tau_fraction)
    backend = relax_fn if relax_fn is not None else make_backend(
        edges, cfg.backend, comm=cfg.comm, impl=cfg.relax_impl)
    with Timer() as t:
        if cfg.use_cluster2:
            dec: Decomposition = cluster2(
                edges, tau, gamma=cfg.gamma, seed=cfg.seed,
                delta_init=cfg.delta_init, relax_fn=backend,
            )
        else:
            dec = cluster(
                edges, tau, gamma=cfg.gamma, variant=cfg.variant,
                delta_init=cfg.delta_init, seed=cfg.seed,
                max_stages=cfg.max_stages,
                max_steps_per_phase=cfg.max_steps_per_phase,
                relax_fn=backend,
            )
        q = build_quotient(edges, dec)
        phi_q, connected = quotient_diameter(q)
        phi = phi_q + 2 * dec.radius
        if not connected:
            log.warning(
                "graph is disconnected: phi_approx=%d only bounds "
                "finite-distance pairs", phi)
    log.info(
        "phi_approx=%d (quotient=%d radius=%d clusters=%d steps=%d) in %.2fs",
        phi, phi_q, dec.radius, dec.n_clusters, dec.growing_steps, t.seconds,
    )
    return DiameterEstimate(
        phi_approx=phi,
        phi_quotient=phi_q,
        radius=dec.radius,
        n_clusters=dec.n_clusters,
        growing_steps=dec.growing_steps,
        n_stages=dec.n_stages,
        delta_end=dec.delta_end,
        seconds=t.seconds,
        connected=connected,
    )
