"""End-to-end diameter approximation (paper Section 4 + Section 5 pipeline).

Phi_approx(G) = Phi(G_C) + 2 * R, where G_C is the quotient of the
decomposition and R its radius. Conservative: Phi_approx >= Phi(G).
Defaults follow the paper's experimental choices: CLUSTER (not CLUSTER2),
"stop" variant, Delta_init = average edge weight, tau ~ n/1000 quotient size.

The whole pipeline — decompose -> quotient -> local solve — is device
resident: the decomposition engine costs one host sync per stage plus one
packed finalize fetch, the quotient is one jitted segment-ops pass over the
backend's device edge arrays (zero syncs), and the solve is a batched
multi-source Bellman-Ford whose packed result is the last fetch.
``PipelineMetrics`` accounts for every device->host synchronization;
``benchmarks/kernel_bench.py`` records it in BENCH_engine.json and asserts
the budget (<= 8 on the bench graph).

``approximate_diameter_batch`` runs many graphs through ONE compiled
pipeline: graphs sharing a node count are padded to a common edge-array
bucket (inert self-loops), so the stage program, quotient kernel and solve
compile once per bucket instead of once per graph — the serving scenario.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.common import Timer, get_logger, next_multiple
from repro.config.base import GraphEngineConfig
from repro.core.backend import make_backend
from repro.core.cluster import Decomposition, _initial_delta, cluster, cluster2
from repro.core.quotient import (
    build_quotient_device,
    build_quotient_numpy,
    quotient_diameter,
    solve_device_quotient,
)
from repro.graph.structures import EdgeList

log = get_logger("repro.diameter")

EDGE_BUCKET = 256  # batch mode pads edge arrays to a multiple of this


@dataclass
class PipelineMetrics:
    """Host-sync accounting for one approximate_diameter call.

    Every field counts device->host fetches (the paper's round-overhead
    analogue); device supersteps are tracked separately. The end-to-end
    budget the bench asserts is ``total_host_syncs <= 8``.
    """

    decompose_syncs: int = 0   # one per engine stage (stop-decision scalars)
    finalize_syncs: int = 0    # packed final-plane fetch (1 per decomposition)
    quotient_syncs: int = 0    # (n_clusters, n_edges) scalar fetch
    solve_syncs: int = 0       # packed (diameter, connected, steps, ecc) fetch
    solve_supersteps: int = 0  # device BF supersteps inside the solve
    n_quotient_edges: int = 0

    @property
    def total_host_syncs(self) -> int:
        return (self.decompose_syncs + self.finalize_syncs
                + self.quotient_syncs + self.solve_syncs)


@dataclass
class DiameterEstimate:
    phi_approx: int
    phi_quotient: int
    radius: int
    n_clusters: int
    growing_steps: int
    n_stages: int
    delta_end: int
    seconds: float
    connected: bool
    # phi_approx is a conservative estimate of the diameter ONLY when
    # ``connected`` — for a disconnected graph it upper-bounds the largest
    # finite-distance pair (the true diameter is infinite).
    pipeline: Optional[PipelineMetrics] = None
    quotient_ecc: Optional[np.ndarray] = None  # int64 [n_clusters]


def tau_for(n_nodes: int, fraction: float = 1e-3, minimum: int = 4) -> int:
    """Paper Section 5: pick tau so the quotient has ~ n/1000 nodes. CLUSTER
    yields O(tau log^2 n) clusters; in practice ~ tau * small-constant, so we
    take tau = n * fraction / log(n) with a floor."""
    logn = max(math.log(max(n_nodes, 2)), 1.0)
    return max(int(n_nodes * fraction / logn), minimum)


def _device_quotient_solve(edges: EdgeList, dec: Decomposition, backend,
                           pm: PipelineMetrics):
    """quotient + local solve, device-resident. Returns
    (phi_quotient, eccentricities, connected)."""
    import jax.numpy as jnp

    from jax.experimental import enable_x64

    dq = build_quotient_device(edges, dec, backend=backend)
    if dq is None:  # no nodes or no edges: quotient is trivially empty
        k = dec.n_clusters
        return 0, np.zeros(k, np.int64), k <= 1
    with enable_x64():  # ONE packed fetch of the three device counters
        kmw = np.asarray(jnp.stack([
            dq.n_clusters.astype(jnp.int64), dq.n_edges.astype(jnp.int64),
            dq.max_weight]))
    pm.quotient_syncs += 1
    k, m, wmax = int(kmw[0]), int(kmw[1]), int(kmw[2])
    pm.n_quotient_edges = m
    if k <= 1:
        return 0, np.zeros(k, np.int64), True
    diam, ecc, connected, steps = solve_device_quotient(dq, k, m, wmax)
    pm.solve_syncs += 1
    pm.solve_supersteps = steps
    return diam, ecc, connected


def approximate_diameter(
    edges: EdgeList,
    cfg: Optional[GraphEngineConfig] = None,
    tau: Optional[int] = None,
    relax_fn=None,
    solver: str = "device",
) -> DiameterEstimate:
    """Paper pipeline. ``relax_fn`` (a RelaxBackend) overrides the backend
    selected by ``cfg.backend``; for a disconnected input the estimate covers
    only finite-distance pairs and ``connected`` is False.

    ``solver="device"`` (default) runs the quotient + solve on device;
    ``solver="scipy"`` keeps the host oracle path (tests / debugging).
    """
    cfg = cfg or GraphEngineConfig()
    tau = tau or tau_for(edges.n_nodes, cfg.tau_fraction)
    backend = relax_fn if relax_fn is not None else make_backend(
        edges, cfg.backend, comm=cfg.comm, impl=cfg.relax_impl)
    pm = PipelineMetrics()
    ecc = None
    with Timer() as t:
        if cfg.use_cluster2:
            dec: Decomposition = cluster2(
                edges, tau, gamma=cfg.gamma, seed=cfg.seed,
                delta_init=cfg.delta_init, relax_fn=backend,
            )
        else:
            dec = cluster(
                edges, tau, gamma=cfg.gamma, variant=cfg.variant,
                delta_init=cfg.delta_init, seed=cfg.seed,
                max_stages=cfg.max_stages,
                max_steps_per_phase=cfg.max_steps_per_phase,
                relax_fn=backend,
            )
        if dec.metrics is not None:
            pm.decompose_syncs = dec.metrics.host_syncs
            pm.finalize_syncs = dec.metrics.finalize_syncs
        if solver == "scipy":
            q = build_quotient_numpy(edges, dec)
            phi_q, connected = quotient_diameter(q)
        else:
            phi_q, ecc, connected = _device_quotient_solve(
                edges, dec, backend, pm)
        phi = phi_q + 2 * dec.radius
        if not connected:
            log.warning(
                "graph is disconnected: phi_approx=%d only bounds "
                "finite-distance pairs", phi)
    log.info(
        "phi_approx=%d (quotient=%d radius=%d clusters=%d steps=%d "
        "host_syncs=%d) in %.2fs",
        phi, phi_q, dec.radius, dec.n_clusters, dec.growing_steps,
        pm.total_host_syncs, t.seconds,
    )
    return DiameterEstimate(
        phi_approx=phi,
        phi_quotient=phi_q,
        radius=dec.radius,
        n_clusters=dec.n_clusters,
        growing_steps=dec.growing_steps,
        n_stages=dec.n_stages,
        delta_end=dec.delta_end,
        seconds=t.seconds,
        connected=connected,
        pipeline=pm,
        quotient_ecc=ecc,
    )


# ---------------------------------------------------------------------------
# batched multi-graph entry point (serving scenario)
# ---------------------------------------------------------------------------


def _pad_edges(edges: EdgeList, e_pad: int) -> EdgeList:
    """Pad the edge arrays to ``e_pad`` with inert self-loops (0 -> 0, w=1).

    A self-loop never wins a relaxation (d[0] + 1 >= d[0]) and is never a
    cross edge in the quotient, so the decomposition and estimate are the
    same as on the unpadded graph — but all graphs in a bucket now share
    one compiled pipeline.
    """
    e = edges.n_edges
    if e_pad <= e:
        return edges
    pad = e_pad - e
    z = np.zeros(pad, np.int32)
    return EdgeList(
        edges.n_nodes,
        np.concatenate([edges.src, z]),
        np.concatenate([edges.dst, z]),
        np.concatenate([edges.weight, np.ones(pad, np.int32)]),
    )


def approximate_diameter_batch(
    graphs: Sequence[EdgeList],
    cfg: Optional[GraphEngineConfig] = None,
    tau: Optional[int] = None,
) -> List[DiameterEstimate]:
    """Run the pipeline over many graphs, amortizing compilation.

    Graphs are grouped by node count; within a group the edge arrays are
    padded to one bucketed size, so the jitted stage program, quotient
    kernel and solve are compiled once per group and reused (the jit caches
    key on shapes + static config, not on backend instances). Delta_init is
    resolved from each graph's REAL edges before padding, so estimates match
    the one-graph entry point exactly.
    """
    cfg = cfg or GraphEngineConfig()
    results: List[Optional[DiameterEstimate]] = [None] * len(graphs)
    by_n = {}
    for i, g in enumerate(graphs):
        by_n.setdefault(g.n_nodes, []).append(i)
    for n, idxs in by_n.items():
        e_pad = next_multiple(
            max(graphs[i].n_edges for i in idxs) or 1, EDGE_BUCKET)
        group_tau = tau or tau_for(n, cfg.tau_fraction)
        for i in idxs:
            g = graphs[i]
            delta0 = _initial_delta(g, cfg.delta_init)
            gcfg = dataclasses.replace(cfg, delta_init=str(delta0))
            results[i] = approximate_diameter(
                _pad_edges(g, e_pad), gcfg, tau=group_tau)
    return results  # type: ignore[return-value]
