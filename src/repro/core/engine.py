"""Device-resident CLUSTER / CLUSTER2 orchestrator (paper Alg. 1/2).

The seed's stage loop was host-driven and chatty: per stage it synced the
uncovered counter, sampled centers with host numpy, and per Δ-doubling synced
``steps``/``reached`` scalars — and the distributed path re-packed and
re-padded all node-state planes on every grow call. Against the paper's cost
model (MR rounds == device supersteps, host round-trips are pure overhead)
that is exactly the wrong shape.

Here the whole per-stage body is ONE jitted program over the canonical
padded planes (``EngineState``):

  sample centers (jax.random, resample-capped) -> promote -> reset
  -> Δ-doubling loop of PartialGrowth calls (backend.grow, traceable)
  -> cover -> uncovered counter

so a stage costs exactly one host synchronization — the fetch of a small
int32 stats vector used for the stop decision — and plane pack/pad happens
once per decomposition (``backend.init_state``), not once per grow call.

Host-sync cost model (counted by ``EngineMetrics`` and checked by the engine
bench): seed loop = 1 (uncovered) + 2 per grow call (steps, reached) per
stage, plus one plane pack per grow call on the distributed path; this
engine = 1 per stage, 1 pack total.

The engine is MODE-PLUGGABLE (``DECOMPOSITION_MODES``): the shared machinery
(center sampling, the promote/reset/grow/cover stage scaffold, grow dispatch,
``_finalize``, metrics accounting) is common, and each mode supplies its grow
strategy:

  * ``"stages"`` — the paper's stage loop above (``run_cluster`` /
    ``run_cluster2``), one host sync per stage;
  * ``"oneshot"`` — MPVX exponential start times (``run_oneshot``): the full
    center budget is drawn at once, each center starts the wave at
    ``d = shift_max - shift_c``, and ONE relax fixpoint with the on-chip stop
    rule resolves the shifted competition — a single host sync for the whole
    decomposition. ``deterministic=True`` derives centers and shifts from
    node-id hashes (Elkin–Haeupler-style deterministic LDD), making the
    output a seed-independent function of the graph;
  * ``"auto"`` — resolved against an autotuning record
    (``resolve_engine_mode``): the stats pass predicts the stage count and
    picks oneshot when the stage loop's sync overhead exceeds the fixpoint's
    superstep roofline.
"""
from __future__ import annotations

import math
import os
import signal as _signal
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import guard
from repro.checkpoint import checkpoint as ckpt
from repro.common import get_logger
from repro.core.backend import RelaxBackend, dispatch_grow
from repro.runtime import telemetry
from repro.runtime.fault import Preempted, PreemptionGuard
from repro.core.state import (
    EngineState,
    INF,
    cover,
    finalize_singletons,
    promote_centers,
    promote_centers_shifted,
    reset_in_stage,
    uncovered_count,
)
from repro.graph.structures import EdgeList

log = get_logger("repro.engine")

MAX_RESAMPLES = 8  # consecutive empty center draws tolerated inside a stage

ENGINE_MODES = ("stages", "oneshot", "auto")


def check_engine_mode(mode: str) -> None:
    """Reject unknown engine modes with the valid names (same contract as
    launch/serve.py's ``_check_estimator_name``)."""
    if mode not in ENGINE_MODES:
        raise ValueError(
            f"unknown engine mode {mode!r} (expected one of {ENGINE_MODES})")


def resolve_engine_mode(mode: str, tuning=None) -> str:
    """Validate ``mode`` and resolve ``"auto"`` to a concrete mode: the
    autotuning record's choice when one is available, else ``"stages"``
    (the byte-identical default)."""
    check_engine_mode(mode)
    if mode == "auto":
        return tuning.mode if tuning is not None else "stages"
    return mode


@dataclass
class EngineMetrics:
    """Round/sync accounting (the paper's resource to minimize)."""

    stages: int = 0           # stage-loop iterations (incl. barren resamples)
    host_syncs: int = 0       # device->host scalar fetches in the stage loop
    grow_calls: int = 0       # PartialGrowth invocations (Δ-doublings + 1 each)
    state_transfers: int = 0  # plane pack/pad + device placements
    resamples: int = 0        # extra center draws taken inside stages
    growing_steps: int = 0    # total supersteps (the MR-round proxy)
    finalize_syncs: int = 0   # device->host fetches of the final planes
    # megakernel counters (0 on unfused backends)
    kernel_launches: int = 0     # fused pallas_call dispatches
    kernel_supersteps: int = 0   # supersteps executed inside fused kernels
    dma_stall_blocks: int = 0    # frontier-skipped edge blocks (DMA-only)
    # sharded-comm accounting (0 on single-device backends). The halo /
    # all-gather plans are STATIC, so bytes = plan bytes x measured
    # supersteps — exact, and metered without any extra host sync.
    halo_bytes: int = 0          # plane-row bytes the comm plan moved
    fullplane_bytes: int = 0     # what a full-plane all-gather would move
    # durability accounting: guard.fetch leaf materializations spent by
    # stage-boundary checkpoint saves. Deliberately OUTSIDE host_syncs —
    # checkpoint cadence is a durability knob, not an algorithmic round,
    # and the paper's sync budget must not drift with it. The extended
    # sync-equality contract is
    #   measured == host_syncs + finalize_syncs + checkpoint_syncs.
    checkpoint_syncs: int = 0


@dataclass
class Decomposition:
    """Output of CLUSTER / CLUSTER2."""

    n_nodes: int
    final_c: np.ndarray        # int32 [n] cluster center id per node
    final_pathw: np.ndarray    # int32 [n] dist-from-center upper bound
    radius: int                # R_CL(tau) = max final_pathw
    delta_end: int
    n_clusters: int
    n_stages: int
    growing_steps: int         # total Delta-growing steps (the paper's
                               # round-complexity proxy)
    metrics: Optional[EngineMetrics] = None
    # device-resident copies of the final planes (length n, sliced from the
    # padded layout) — the quotient stage consumes these without a host
    # round-trip; None for hand-built decompositions
    final_c_dev: Optional[jnp.ndarray] = None
    final_pathw_dev: Optional[jnp.ndarray] = None

    def cluster_sizes(self) -> np.ndarray:
        _, counts = np.unique(self.final_c, return_counts=True)
        return counts


def _empty_decomposition(n: int, metrics: EngineMetrics) -> Decomposition:
    return Decomposition(
        n_nodes=n, final_c=np.zeros(n, np.int32),
        final_pathw=np.zeros(n, np.int32), radius=0, delta_end=1,
        n_clusters=n, n_stages=0, growing_steps=0, metrics=metrics,
    )


def _comm_accounting(metrics: EngineMetrics, backend: RelaxBackend,
                     total_steps: int) -> None:
    """Exact wire-byte accounting for sharded backends: the collective
    plan (halo all_to_all tables or the full-plane all-gather) is fixed
    when the backend is built, so bytes = plan bytes x measured
    supersteps with zero additional host syncs. Single-device backends
    expose no per-step plan and stay at 0."""
    per = int(getattr(backend, "halo_bytes_per_step", 0) or 0)
    base = int(getattr(backend, "fullplane_bytes_per_step", 0) or 0)
    metrics.halo_bytes = per * total_steps
    metrics.fullplane_bytes = base * total_steps


@dataclass
class StageCheckpointer:
    """Stage-boundary checkpoint/restore hook for the staged engine.

    At every stage boundary — right after the stage's single stats fetch
    — ``run_cluster`` hands this hook the full decomposition state: the
    ``EngineState`` planes, the RNG key, the host scalars (stage counter,
    Δ, uncovered count, superstep totals) and, when a ``GraphStore`` is
    attached, its host-mirrored slabs/buffers. Every ``every``-th stage
    the tree goes through ``checkpoint.save`` (atomic rename, so a
    preempted writer never corrupts the latest complete step).

    Under an entered :class:`PreemptionGuard` whose signal has fired, the
    save is unconditional and :class:`Preempted` is raised AFTER the
    checkpoint is durable. Resume is byte-identical by construction:
    per-stage center draws use ``fold_in(key, stage)``, the state is all
    int32/bool (no fp accumulation drift), and the saved key + stage
    counter regenerate exactly the remaining draws — so a killed
    decomposition restores and finishes with the same bracket the
    uninterrupted run produces.

    ``preempt_after_stage`` (tests / the stream bench) delivers a REAL
    ``SIGTERM`` to this process at that stage boundary, exercising the
    actual signal path rather than faking the flag; it therefore
    requires an attached, entered guard.

    One-shot mode has no stage boundary (single fixpoint, single sync)
    and ignores the checkpointer.
    """

    ckpt_dir: str
    guard: Optional[PreemptionGuard] = None
    store: Optional[Any] = None      # graph.storage.GraphStore (or EdgeStore)
    every: int = 1
    keep: int = 3
    resume: bool = False
    preempt_after_stage: int = 0     # 0 = never; k = SIGTERM at boundary k
    saves: int = 0
    restores: int = 0
    last_path: Optional[str] = None
    _fired: bool = field(default=False, repr=False)

    def _tree(self, state, key) -> Dict[str, Any]:
        tree: Dict[str, Any] = {"planes": state, "key": key}
        if self.store is not None:
            tree["store"] = self.store.state_dict()
        return tree

    def save(self, state, key, scalars: Dict[str, Any],
             metrics: Optional[EngineMetrics] = None) -> str:
        extra: Dict[str, Any] = {"engine": {k: int(v) if isinstance(v, (int, np.integer)) else v
                                            for k, v in scalars.items()}}
        if self.store is not None:
            extra["store"] = self.store.extra_state()
        # nested meter: the save's own guard.fetch calls (one per device
        # leaf) are measured here and booked as checkpoint_syncs, keeping
        # the algorithmic sync budget clean
        with guard.measured_transfers(level="allow") as m:
            path = ckpt.save(self.ckpt_dir, int(scalars["stage"]),
                             self._tree(state, key), extra=extra,
                             keep=self.keep)
        if metrics is not None:
            metrics.checkpoint_syncs += m.transfers
        self.saves += 1
        self.last_path = path
        return path

    def at_stage_boundary(self, state, key, scalars: Dict[str, Any],
                          metrics: Optional[EngineMetrics] = None) -> None:
        """Called by ``run_cluster`` after each stage's stats fetch.
        Saves on cadence; on observed preemption saves unconditionally
        and raises :class:`Preempted`."""
        stage = int(scalars["stage"])
        if (self.preempt_after_stage and stage >= self.preempt_after_stage
                and not self._fired):
            if self.guard is None:
                raise RuntimeError(
                    "preempt_after_stage requires an attached (and entered) "
                    "PreemptionGuard — a raw SIGTERM would kill the process")
            self._fired = True
            # a REAL signal: the guard's handler runs synchronously on
            # delivery, flipping should_stop before the check below
            os.kill(os.getpid(), _signal.SIGTERM)
        preempted = self.guard is not None and self.guard.should_stop
        if preempted or (self.every and stage % self.every == 0):
            self.save(state, key, scalars, metrics)
        if preempted:
            raise Preempted(stage, self.last_path,
                            getattr(self.guard, "received", None))

    def try_restore(self, like_state, like_key):
        """Restore the latest checkpoint, or None when the directory is
        empty (fresh start). Plane leaves are re-placed against
        ``like_state``'s shardings leaf-by-leaf, so a checkpoint written
        under one device layout restores onto whatever the current
        backend built (the elastic path). The attached store, when
        present, is restored in place."""
        if ckpt.latest_step(self.ckpt_dir) is None:
            return None
        tree, extra = ckpt.restore(self.ckpt_dir,
                                   self._tree(like_state, like_key))
        state = jax.tree_util.tree_map(
            lambda cur, new: jax.device_put(np.asarray(new), cur.sharding),
            like_state, tree["planes"])
        # uncommitted on purpose (plain asarray, no device_put): a fresh
        # PRNGKey is uncommitted too, so jit may co-locate it with however
        # the planes are sharded; committing it to one device would break
        # multi-device resume
        key = jnp.asarray(np.asarray(tree["key"]), dtype=like_key.dtype)
        if self.store is not None and "store" in tree:
            self.store.load_state(tree["store"], extra.get("store", {}))
        self.restores += 1
        return state, key, extra.get("engine", {})

    def complete(self) -> None:
        """The decomposition finished: clear step directories so a later
        query on the same directory never resumes from a stale bracket,
        and consume the resume flag."""
        self.resume = False
        if os.path.isdir(self.ckpt_dir):
            import re
            import shutil
            for d in os.listdir(self.ckpt_dir):
                if re.fullmatch(r"step_\d+", d):
                    shutil.rmtree(os.path.join(self.ckpt_dir, d),
                                  ignore_errors=True)


def _sample_centers(key, p, state: EngineState, n: int, max_resamples: int):
    """Draw a center mask over the REAL node slots, redrawing (with a folded
    key) while the draw is empty, up to ``max_resamples`` extra attempts.

    Sampling over exactly [n] (never the padded tail) keeps the draw — and
    therefore the whole decomposition — identical across backends with
    different padded layouts.
    """
    eligible = (~state.covered[:n]) & (~state.is_center[:n])

    def draw(t):
        u = jax.random.uniform(jax.random.fold_in(key, t), (n,))
        return (u < p) & eligible

    def cond(carry):
        t, mask = carry
        return (~mask.any()) & (t < max_resamples)

    def body(carry):
        t, _ = carry
        return t + 1, draw(t + 1)

    t, mask = jax.lax.while_loop(cond, body, (jnp.int32(0), draw(0)))
    return mask, t


def _pad_mask(mask, n_pad: int):
    n = mask.shape[0]
    if n_pad == n:
        return mask
    return jnp.concatenate([mask, jnp.zeros((n_pad - n,), bool)])


def _pad_vec(x, n_pad: int):
    n = x.shape[0]
    if n_pad == n:
        return x
    return jnp.concatenate([x, jnp.zeros((n_pad - n,), x.dtype)])


def _stage_scaffold(state: EngineState, mask, n_new, grow_body, barren_tail,
                    start_d=None):
    """The stage skeleton shared by CLUSTER, CLUSTER2 and the one-shot mode:
    promote the sampled centers, reset the in-stage wave, run the mode's
    grow strategy, all under one ``lax.cond`` so a barren draw (empty mask)
    costs nothing. ``grow_body(st) -> (st, *tail)`` must return the same
    pytree structure as ``(state,) + barren_tail``.

    ``start_d`` switches to the one-shot promote (centers enter at the
    shifted distance) and SKIPS the in-stage reset — the one-shot runs once
    on a fresh ``init_state`` where every non-center is already unreached,
    and a reset would zero the shifts back out.
    """
    n_pad = state.d.shape[0]

    def barren(st):
        return (st,) + tuple(barren_tail)

    def run_stage(st):
        if start_d is None:
            st = promote_centers(st, _pad_mask(mask, n_pad))
            st = reset_in_stage(st)
        else:
            st = promote_centers_shifted(st, _pad_mask(mask, n_pad),
                                         _pad_vec(start_d, n_pad))
        return grow_body(st)

    return jax.lax.cond(n_new > 0, run_stage, barren, state)


@partial(jax.jit, static_argnames=("spec", "variant", "n", "max_resamples"))
def _cluster_stage(
    state: EngineState,
    key,
    delta,
    u_count,
    p_scale,          # f32: gamma * tau * log n
    max_delta,
    num_it,
    graph_args,       # backend edge arrays, TRACED (shape-keyed cache)
    *,
    spec,             # backend.grow_spec() (hashable static)
    variant: str,
    n: int,
    max_resamples: int,
):
    """One CLUSTER stage as a single device program.

    The jit cache keys on (spec, variant, n, shapes) — NOT on a per-call
    backend object — so repeated decompositions of same-shaped graphs reuse
    one compiled stage program, like the seed's jitted partial_growth did.

    Returns (state, delta, stats) with stats = int32 [9]:
    (n_new, steps, grow_calls, resamples, uncovered_after,
     kernel_launches, kernel_supersteps, dead_blocks, delta_end).
    delta_end rides in the stats vector so the host tracks the Δ ceiling
    without a second scalar fetch at decomposition end.
    """

    def grow(st, dl, half, ni, var):
        return dispatch_grow(spec, graph_args, st, dl, half, ni, var)

    p = jnp.minimum(1.0, p_scale / u_count.astype(jnp.float32))
    mask, resamples = _sample_centers(key, p, state, n, max_resamples)
    n_new = jnp.sum(mask).astype(jnp.int32)

    zero = jnp.int32(0)

    def grow_body(st):
        # goal: half of the stage's uncovered set, counting the nodes that
        # just became centers (paper counts them inside V').
        half_target = jnp.maximum((u_count + 1) // 2 - n_new, 0)

        def cond(carry):
            return ~carry[-1]

        def body(carry):
            s, dl, steps, grows, launches, ksteps, dead, _ = carry
            s, stats = grow(s, dl, half_target, num_it, variant)
            steps = steps + stats.steps
            grows = grows + 1
            launches = launches + stats.kernel_launches
            ksteps = ksteps + stats.kernel_supersteps
            dead = dead + stats.dead_blocks
            stop = (stats.reached >= half_target) | (dl >= max_delta)
            dl = jnp.where(stop, dl, jnp.minimum(dl * 2, max_delta))
            return (s, dl, steps, grows, launches, ksteps, dead, stop)

        st, dl, steps, grows, launches, ksteps, dead, _ = jax.lax.while_loop(
            cond, body,
            (st, delta, zero, zero, zero, zero, zero, jnp.bool_(False)),
        )
        st = cover(st, dl)
        return st, dl, steps, grows, launches, ksteps, dead

    state, delta_end, steps, grows, launches, ksteps, dead = _stage_scaffold(
        state, mask, n_new, grow_body, (delta, zero, zero, zero, zero, zero))
    stats = jnp.stack([
        n_new, steps, grows, resamples,
        uncovered_count(state).astype(jnp.int32),
        launches, ksteps, dead, delta_end,
    ])
    return state, delta_end, stats


@partial(jax.jit, static_argnames=("spec", "n"))
def _cluster2_stage(state: EngineState, key, delta, p, num_it, graph_args,
                    *, spec, n: int):
    """One CLUSTER2 stage: fixed Δ budget, growth to quiescence."""
    eligible = (~state.covered[:n]) & (~state.is_center[:n])
    mask = (jax.random.uniform(key, (n,)) < p) & eligible
    n_new = jnp.sum(mask).astype(jnp.int32)

    def grow_body(st):
        st, gstats = dispatch_grow(spec, graph_args, st, delta, jnp.int32(0),
                                   num_it, "complete")
        st = cover(st, delta)
        return st, jnp.stack([
            gstats.steps, jnp.int32(gstats.kernel_launches),
            jnp.int32(gstats.kernel_supersteps),
            jnp.int32(gstats.dead_blocks)])

    state, gvec = _stage_scaffold(state, mask, n_new, grow_body,
                                  (jnp.zeros((4,), jnp.int32),))
    stats = jnp.concatenate([
        jnp.stack([n_new, gvec[0], uncovered_count(state).astype(jnp.int32)]),
        gvec[1:]])
    return state, stats


def _finalize(
    state: EngineState,
    n: int,
    delta_end: int,
    n_stages: int,
    total_steps: int,
    metrics: EngineMetrics,
) -> Decomposition:
    state = finalize_singletons(state)
    fc_dev = state.final_c[:n]
    fp_dev = state.final_pathw[:n]
    with telemetry.span("engine.finalize", n=n) as sp:
        # ONE packed device->host fetch for both final planes
        planes = guard.fetch(jnp.stack([fc_dev, fp_dev]),
                             reason="finalize: packed (final_c, final_pathw)")
        metrics.finalize_syncs += 1
        sp.set(supersteps=total_steps, halo_bytes=metrics.halo_bytes,
               checkpoint_syncs=metrics.checkpoint_syncs)
    final_c, final_pathw = planes[0], planes[1]
    assert (final_pathw < np.int32(INF)).all(), "uncovered node escaped finalization"
    return Decomposition(
        n_nodes=n,
        final_c=final_c,
        final_pathw=final_pathw,
        radius=int(final_pathw.max()) if n else 0,
        delta_end=delta_end,
        n_clusters=int(len(np.unique(final_c))) if n else 0,
        n_stages=n_stages,
        growing_steps=total_steps,
        metrics=metrics,
        final_c_dev=fc_dev,
        final_pathw_dev=fp_dev,
    )


def run_cluster(
    edges: Optional[EdgeList],
    backend: RelaxBackend,
    tau: int,
    *,
    gamma: float = 2.0,
    variant: str = "stop",
    delta0: int = 1,
    seed: int = 0,
    max_stages: int = 64,
    max_steps_per_phase: int = 0,
    threshold_const: float = 8.0,
    max_resamples: int = MAX_RESAMPLES,
    max_delta: Optional[int] = None,
    checkpointer: Optional[StageCheckpointer] = None,
) -> Decomposition:
    """Paper Algorithm 1 on the device-resident engine.

    ``edges`` may be None when the graph exists only as the backend's
    device arrays (a quotient cascade level) — ``max_delta`` (the Δ-doubling
    ceiling, normally derived from the host weight sum) must then be given
    explicitly; the node count comes from ``backend.n_nodes``.

    ``checkpointer`` (a :class:`StageCheckpointer`) makes the decomposition
    preemption-safe: state is saved at stage boundaries, an observed
    SIGTERM/SIGINT raises :class:`~repro.runtime.fault.Preempted` after a
    durable save, and ``checkpointer.resume=True`` restores the latest
    checkpoint and finishes byte-identically.
    """
    if edges is None and max_delta is None:
        raise ValueError("run_cluster(edges=None) needs an explicit max_delta")
    n = backend.n_nodes if edges is None else edges.n_nodes
    metrics = EngineMetrics()
    if n == 0:
        return _empty_decomposition(0, metrics)
    logn = max(math.log(max(n, 2)), 1.0)
    threshold = max(int(threshold_const * tau * logn), 1)
    num_it = jnp.int32(max_steps_per_phase or max(2 * n // max(tau, 1), 8))
    if max_delta is None:
        max_delta = int(np.int64(edges.weight.astype(np.int64).sum()) + 1)
    max_delta = jnp.int32(min(max(int(max_delta), 1), 2**30))
    p_scale = jnp.float32(gamma * tau * logn)

    transfers0 = backend.transfers
    state = backend.init_state()
    spec = backend.grow_spec()
    graph_args = backend.graph_args()
    key = jax.random.PRNGKey(seed)
    delta = jnp.int32(delta0)
    delta_host = delta0   # tracks delta_end via the stats vector — the
    u_host = n            # Δ ceiling never needs its own scalar fetch
    total_steps = 0
    n_stages = 0
    stage = 0

    if checkpointer is not None and checkpointer.resume:
        restored = checkpointer.try_restore(state, key)
        checkpointer.resume = False  # consumed either way
        if restored is not None:
            state, key, sc = restored
            for want, got in (("seed", seed), ("n", n), ("tau", tau),
                              ("variant", variant)):
                if want in sc and sc[want] != got:
                    raise ValueError(
                        f"checkpoint {want}={sc[want]!r} does not match this "
                        f"run's {want}={got!r}; refusing a divergent resume")
            stage = int(sc["stage"])
            delta_host = int(sc["delta"])
            u_host = int(sc["uncovered"])
            total_steps = int(sc["total_steps"])
            n_stages = int(sc["n_stages"])
            delta = jnp.int32(delta_host)
            metrics.stages = stage
            log.info("resumed decomposition at stage %d (uncovered=%d, "
                     "delta=%d) from %s", stage, u_host, delta_host,
                     checkpointer.ckpt_dir)

    while stage < max_stages and u_host >= threshold:
        with telemetry.span("engine.stage", stage=stage) as sp:
            state, delta, stats = _cluster_stage(
                state, jax.random.fold_in(key, stage), delta,
                jnp.int32(u_host), p_scale, max_delta, num_it, graph_args,
                spec=spec, variant=variant, n=n,
                max_resamples=max_resamples,
            )
            # the stage's single host synchronization: the stop-decision
            # scalars
            (n_new, steps, grows, resamples, u_host,
             launches, ksteps, dead, delta_host) = map(int, guard.fetch(
                 stats, reason="stage stop decision: packed int32 stats"))
            sp.set(centers=n_new, supersteps=steps, grow_calls=grows,
                   kernel_launches=launches, dma_stall_blocks=dead,
                   uncovered=u_host)
        metrics.host_syncs += 1
        metrics.grow_calls += grows
        metrics.resamples += resamples
        metrics.kernel_launches += launches
        metrics.kernel_supersteps += ksteps
        metrics.dma_stall_blocks += dead
        total_steps += steps
        stage += 1
        metrics.stages = stage
        if n_new > 0:
            n_stages += 1
        log.info(
            "stage %d: centers+%d steps=%d grows=%d resamples=%d uncovered=%d",
            stage, n_new, steps, grows, resamples, u_host,
        )
        if checkpointer is not None:
            checkpointer.at_stage_boundary(
                state, key,
                {"stage": stage, "delta": delta_host, "uncovered": u_host,
                 "total_steps": total_steps, "n_stages": n_stages,
                 "seed": seed, "n": n, "tau": tau, "variant": variant},
                metrics)

    if checkpointer is not None:
        checkpointer.complete()
    metrics.growing_steps = total_steps
    metrics.state_transfers = backend.transfers - transfers0
    _comm_accounting(metrics, backend, total_steps)
    return _finalize(state, n, delta_host, n_stages, total_steps, metrics)


def run_cluster2(
    edges: EdgeList,
    backend: RelaxBackend,
    tau: int,
    *,
    delta: int,
    seed: int = 0,
) -> Decomposition:
    """Paper Algorithm 2 re-clustering pass (fixed Δ = 2 R_CL(tau))."""
    n = edges.n_nodes
    metrics = EngineMetrics()
    if n == 0:
        return _empty_decomposition(0, metrics)
    num_it = jnp.int32(4 * n)
    transfers0 = backend.transfers
    state = backend.init_state()
    spec = backend.grow_spec()
    graph_args = backend.graph_args()
    key = jax.random.PRNGKey(seed)
    stages = int(math.ceil(math.log2(max(n, 2)))) + 1
    total_steps = 0
    stage_count = 0
    u_host = n

    for i in range(1, stages + 1):
        if u_host == 0:
            break
        p = 1.0 if i == stages else min(1.0, (2.0 ** i) / n)
        with telemetry.span("engine.stage", stage=i, variant="cluster2") as sp:
            state, stats = _cluster2_stage(
                state, jax.random.fold_in(key, i), jnp.int32(delta),
                jnp.float32(p), num_it, graph_args, spec=spec, n=n,
            )
            (n_new, steps, u_host,
             launches, ksteps, dead) = map(int, guard.fetch(
                 stats, reason="cluster2 stage: packed int32 stats"))
            sp.set(centers=n_new, supersteps=steps, kernel_launches=launches,
                   dma_stall_blocks=dead, uncovered=u_host)
        metrics.host_syncs += 1
        metrics.kernel_launches += launches
        metrics.kernel_supersteps += ksteps
        metrics.dma_stall_blocks += dead
        total_steps += steps
        metrics.stages += 1
        if n_new > 0:
            stage_count += 1
            metrics.grow_calls += 1

    metrics.growing_steps = total_steps
    metrics.state_transfers = backend.transfers - transfers0
    _comm_accounting(metrics, backend, total_steps)
    return _finalize(state, n, int(delta), stage_count, total_steps, metrics)


@partial(jax.jit, static_argnames=("spec", "n", "deterministic"))
def _oneshot_stage(state: EngineState, key, p, shift_max, shift_scale,
                   delta, num_it, graph_args, *, spec, n: int,
                   deterministic: bool):
    """The whole one-shot decomposition as a single device program.

    Draw the full center budget at once (probability ``p`` per node), give
    each center an exponential start shift ``s_c`` quantized to int32, and
    start its wave at ``d = shift_max - s_c`` so larger shifts mean earlier
    (lexicographically smaller) starts — the MPVX exponential-start-times
    race expressed directly in the existing ``(d, c, pathw)`` tuple-min.
    ONE ``dispatch_grow`` fixpoint (variant="complete", on-chip stop rule)
    resolves the competition, then ``cover(Δ)`` freezes everything reached.

    ``deterministic=True`` replaces ``jax.random`` with Knuth multiplicative
    hashes of the node id, making centers and shifts a pure function of the
    graph (seed-independent, Elkin–Haeupler style).

    Returns (state, stats) with stats = int32 [6]:
    (n_new, steps, uncovered_after, kernel_launches, kernel_supersteps,
     dead_blocks) — read back in ONE host sync.
    """
    ids = jnp.arange(n, dtype=jnp.int32)
    if deterministic:
        h1 = ids.astype(jnp.uint32) * jnp.uint32(2654435761)
        h2 = ids.astype(jnp.uint32) * jnp.uint32(2246822519)
        u1 = h1.astype(jnp.float32) * jnp.float32(2.0 ** -32)
        u2 = (h2.astype(jnp.float32) + 0.5) * jnp.float32(2.0 ** -32)
    else:
        k1, k2 = jax.random.split(key)
        u1 = jax.random.uniform(k1, (n,))
        u2 = jnp.maximum(jax.random.uniform(k2, (n,)), jnp.float32(2.0 ** -32))

    mask = u1 < p
    # empty draw (tiny n or unlucky seed): force the argmin-u1 node so the
    # one-shot never degenerates to an all-singleton decomposition
    mask = jnp.where(mask.any(), mask, ids == jnp.argmin(u1).astype(jnp.int32))
    n_new = jnp.sum(mask).astype(jnp.int32)

    # exponential shift, clamped to [0, shift_max]; float32 rounding near
    # 2^29 could overshoot, so clip AFTER the int cast too
    shift = jnp.minimum(-jnp.log(u2) * shift_scale,
                        shift_max.astype(jnp.float32))
    shift_i = jnp.clip(shift.astype(jnp.int32), 0, shift_max)
    start_d = shift_max - shift_i

    def grow_body(st):
        st, gstats = dispatch_grow(spec, graph_args, st, delta, jnp.int32(0),
                                   num_it, "complete")
        st = cover(st, delta)
        return st, jnp.stack([
            gstats.steps, jnp.int32(gstats.kernel_launches),
            jnp.int32(gstats.kernel_supersteps),
            jnp.int32(gstats.dead_blocks)])

    state, gvec = _stage_scaffold(state, mask, n_new, grow_body,
                                  (jnp.zeros((4,), jnp.int32),),
                                  start_d=start_d)
    stats = jnp.concatenate([
        jnp.stack([n_new, gvec[0], uncovered_count(state).astype(jnp.int32)]),
        gvec[1:]])
    return state, stats


def run_oneshot(
    edges: Optional[EdgeList],
    backend: RelaxBackend,
    tau: int,
    *,
    gamma: float = 2.0,
    seed: int = 0,
    deterministic: bool = False,
    max_steps_per_phase: int = 0,
    max_delta: Optional[int] = None,
) -> Decomposition:
    """One-shot exponential-shift decomposition (MPVX exponential start
    times; deterministic Elkin–Haeupler-style hashed shifts when
    ``deterministic=True``).

    The full center budget ``k ~ gamma * tau * log n`` is drawn in one go,
    each center enters the wave at ``d = shift_max - shift_c`` (its
    exponential start shift folded into the initial distance), and one relax
    fixpoint with the on-chip stop rule resolves the whole race: a single
    host synchronization for the entire decomposition, versus one per stage
    for ``run_cluster``.

    ``pathw`` still accumulates the realized path weight from the owning
    center (centers start at ``pathw = 0``), so ``final_pathw`` remains a
    genuine dist-upper-bound certificate and every downstream bracket
    (quotient, cascade, interval) stays valid. Nodes the shifted waves never
    reach within Δ become singleton clusters via ``_finalize``, same as the
    staged engine.

    Like ``run_cluster``, ``edges`` may be None for cascade levels resident
    only as backend device arrays — ``max_delta`` must then be explicit.
    """
    if edges is None and max_delta is None:
        raise ValueError("run_oneshot(edges=None) needs an explicit max_delta")
    n = backend.n_nodes if edges is None else edges.n_nodes
    metrics = EngineMetrics()
    if n == 0:
        return _empty_decomposition(0, metrics)
    logn = max(math.log(max(n, 2)), 1.0)
    k_target = max(gamma * tau * logn, 1.0)
    p = jnp.float32(min(1.0, k_target / n))
    num_it = jnp.int32(max_steps_per_phase or 4 * n)
    if max_delta is None:
        # Δ defaults to a few times the per-center weight share (floored at
        # the average edge weight so typical edges stay traversable): radius
        # is bounded by Δ, so the full weight sum — run_cluster's doubling
        # CEILING — would be hopelessly loose as a fixed budget. Nodes no
        # shifted wave reaches within Δ become singletons, which keeps every
        # bracket valid whatever Δ is.
        wsum = int(np.int64(edges.weight.astype(np.int64).sum()))
        avg_w = wsum // max(edges.n_edges, 1)
        max_delta = int(max(4.0 * wsum / k_target, 4.0 * avg_w)) + 1
    max_delta = min(max(int(max_delta), 1), 2**30)
    # shifts live in the lower half of the Δ budget: d <= shift_max + wsum
    # < 2^31 stays int32-safe, and every center still covers radius >= Δ/2
    shift_max = jnp.int32(max_delta // 2)
    shift_scale = jnp.float32(
        (max_delta // 2) / max(math.log(max(k_target, 2.0)), 1.0))

    transfers0 = backend.transfers
    state = backend.init_state()
    spec = backend.grow_spec()
    graph_args = backend.graph_args()
    key = jax.random.PRNGKey(seed)

    with telemetry.span("engine.oneshot", n=n,
                        deterministic=deterministic) as sp:
        state, stats = _oneshot_stage(
            state, key, p, shift_max, shift_scale, jnp.int32(max_delta),
            num_it, graph_args, spec=spec, n=n, deterministic=deterministic,
        )
        # the decomposition's single host synchronization
        (n_new, steps, u_host, launches, ksteps, dead) = map(int, guard.fetch(
            stats, reason="oneshot: packed int32 stats, the only sync"))
        sp.set(centers=n_new, supersteps=steps, kernel_launches=launches,
               dma_stall_blocks=dead, uncovered=u_host)
    metrics.stages = 1
    metrics.host_syncs = 1
    metrics.grow_calls = 1
    metrics.growing_steps = steps
    metrics.kernel_launches = launches
    metrics.kernel_supersteps = ksteps
    metrics.dma_stall_blocks = dead
    metrics.state_transfers = backend.transfers - transfers0
    _comm_accounting(metrics, backend, steps)
    log.info("oneshot: centers=%d steps=%d uncovered=%d deterministic=%s",
             n_new, steps, u_host, deterministic)
    return _finalize(state, n, int(max_delta), 1, steps, metrics)


class DecompositionMode(NamedTuple):
    """A pluggable decomposition strategy: shared machinery (center
    sampling, the ``_stage_scaffold`` promote/grow/cover skeleton, grow
    dispatch, ``_finalize``, metrics) lives above; each mode contributes its
    runner over a built ``RelaxBackend``."""

    name: str
    runner: Callable[..., Decomposition]


DECOMPOSITION_MODES: Dict[str, DecompositionMode] = {
    "stages": DecompositionMode("stages", run_cluster),
    "oneshot": DecompositionMode("oneshot", run_oneshot),
}
