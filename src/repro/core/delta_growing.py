"""The Delta-growing step (paper Section 3) and the PartialGrowth loop.

One growing step = one relaxation superstep over all edges:

  for each edge (u, v):
    if u is a *relay* (covered in a previous stage): the edge stands in for
      the contracted edge (c_u, v) with rescaled weight w + offset_u; since
      centers always have in-stage d = 0, the candidate is just the clamped
      rescaled weight.
    else (u live this stage): classic Bellman-Ford candidate d_u + w,
      admissible when d_u < Delta (active) and w < Delta (light edge).

  per destination v (uncovered, non-center): lexicographic (d, c) segment-min
  with a third pass carrying the realized original-graph path weight.

The PartialGrowth stopping rule (paper + Section 5 experiments):
  repeat until no state updated            ("complete" variant)
         or |{d < Delta}| >= target/2      ("stop" variant)
         or k == num_it                    (2n/tau cap; never hit in practice)
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.state import INF, EngineState
from repro.graph.segment_ops import segment_min_triple


class GrowthStats(NamedTuple):
    steps: jnp.ndarray          # growing steps executed in this call
    reached: jnp.ndarray        # |{uncovered non-center: d < Delta}|
    changed_last: jnp.ndarray   # whether the final step still changed state


def edge_candidates(
    state: EngineState,
    src: jnp.ndarray,
    weight: jnp.ndarray,
    delta: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-edge (cand_d, cand_c, cand_pathw); INF where inadmissible."""
    relay = state.covered[src]
    # relay branch: contracted edge (final_c[src], v), rescaled + clamped >= 0
    w_red = jnp.maximum(weight + state.offset[src], 0)
    relay_ok = relay & (w_red < delta)
    # live branch
    d_src = state.d[src]
    live_ok = (~relay) & (d_src < delta) & (weight < delta)
    d_safe = jnp.where(live_ok, d_src, 0)

    cand_d = jnp.where(relay_ok, w_red, jnp.where(live_ok, d_safe + weight, INF))
    cand_c = jnp.where(relay_ok, state.final_c[src], jnp.where(live_ok, state.c[src], INF))
    p_src = jnp.where(relay_ok, state.final_pathw[src], jnp.where(live_ok, state.pathw[src], 0))
    p_safe = jnp.where(p_src >= INF - jnp.int32(2**30), jnp.int32(0), p_src)  # guard
    cand_p = jnp.where(relay_ok | live_ok, p_safe + weight, INF)
    return cand_d, cand_c, cand_p


def growing_step(
    state: EngineState,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    weight: jnp.ndarray,
    delta: jnp.ndarray,
    n_nodes: int,
) -> Tuple[EngineState, jnp.ndarray]:
    """One Delta-growing step. Returns (new_state, any_change)."""
    cand_d, cand_c, cand_p = edge_candidates(state, src, weight, delta)
    d_min, c_min, p_min = segment_min_triple(cand_d, cand_c, cand_p, dst, n_nodes)

    # strict improvement only (paper: "if d_v > d_u + w(u,v)"), receivers are
    # uncovered non-centers; centers are also protected by d = 0 minimality.
    recv = (~state.covered) & (~state.is_center)
    upd = recv & (d_min < state.d)
    new = state._replace(
        d=jnp.where(upd, d_min, state.d),
        c=jnp.where(upd, c_min, state.c),
        pathw=jnp.where(upd, p_min, state.pathw),
    )
    return new, jnp.any(upd)


@partial(jax.jit, static_argnames=("n_nodes", "variant"))
def partial_growth(
    state: EngineState,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    weight: jnp.ndarray,
    delta: jnp.ndarray,
    half_target: jnp.ndarray,
    num_it: jnp.ndarray,
    n_nodes: int,
    variant: str = "stop",
) -> Tuple[EngineState, GrowthStats]:
    """Paper's PartialGrowth(G, X, Delta, num_it) as a lax.while_loop.

    ``half_target``: |uncovered at stage start| / 2 — the coverage goal.
    ``variant``: "stop" halts once the goal is met; "complete" runs to
    quiescence (paper Table 2 compares both).
    """

    def reached_count(s: EngineState) -> jnp.ndarray:
        return jnp.sum((~s.covered) & (~s.is_center) & (s.d < delta))

    def cond(carry):
        s, k, changed = carry
        more = changed & (k < num_it)
        if variant == "stop":
            more = more & (reached_count(s) < half_target)
        return more

    def body(carry):
        s, k, _ = carry
        s2, ch = growing_step(s, src, dst, weight, delta, n_nodes)
        return (s2, k + 1, ch)

    init = (state, jnp.int32(0), jnp.bool_(True))
    final, k, changed = jax.lax.while_loop(cond, body, init)
    stats = GrowthStats(steps=k, reached=reached_count(final), changed_last=changed)
    return final, stats
