"""The Delta-growing step (paper Section 3) and the PartialGrowth loop.

One growing step = one relaxation superstep over all edges:

  for each edge (u, v):
    if u is a *relay* (covered in a previous stage): the edge stands in for
      the contracted edge (c_u, v) with rescaled weight w + offset_u; since
      centers always have in-stage d = 0, the candidate is just the clamped
      rescaled weight.
    else (u live this stage): classic Bellman-Ford candidate d_u + w,
      admissible when d_u < Delta (active) and w < Delta (light edge).

  per destination v (uncovered, non-center): lexicographic (d, c) segment-min
  with a third pass carrying the realized original-graph path weight.

The PartialGrowth stopping rule (paper + Section 5 experiments):
  repeat until no state updated            ("complete" variant)
         or |{d < Delta}| >= target/2      ("stop" variant)
         or k == num_it                    (2n/tau cap; never hit in practice)
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.state import INF, EngineState, relay_planes
from repro.graph.segment_ops import segment_min_triple
from repro.kernels.edge_relax.ref import edge_relax_candidates


class GrowthStats(NamedTuple):
    steps: jnp.ndarray          # growing steps executed in this call
    reached: jnp.ndarray        # |{uncovered non-center: d < Delta}|
    changed_last: jnp.ndarray   # whether the final step still changed state
    # megakernel counters (0 on the unfused paths; see edge_relax/megakernel)
    kernel_launches: jnp.ndarray = 0    # fused pallas_call dispatches
    kernel_supersteps: jnp.ndarray = 0  # supersteps executed inside kernels
    dead_blocks: jnp.ndarray = 0        # frontier-skipped (DMA-stall) blocks


def edge_candidates(
    state: EngineState,
    src: jnp.ndarray,
    weight: jnp.ndarray,
    delta: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-edge (cand_d, cand_c, cand_pathw); INF where inadmissible.

    Thin adapter: derives the relay planes from ``state`` and defers to the
    ONE canonical candidate rule in ``kernels/edge_relax/ref.py`` (shared by
    the single-device, sharded, and Pallas backends). Covered sources have
    in-stage d = INF, so the live branch is self-masking for them.
    """
    rw0, rc, rp, _ = relay_planes(state)
    return edge_relax_candidates(
        state.d[src], state.c[src], state.pathw[src],
        rw0[src], rc[src], rp[src],
        weight, jnp.bool_(True), delta,
    )


def growing_step(
    state: EngineState,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    weight: jnp.ndarray,
    delta: jnp.ndarray,
    n_nodes: int,
) -> Tuple[EngineState, jnp.ndarray]:
    """One Delta-growing step. Returns (new_state, any_change)."""
    cand_d, cand_c, cand_p = edge_candidates(state, src, weight, delta)
    d_min, c_min, p_min = segment_min_triple(cand_d, cand_c, cand_p, dst, n_nodes)

    # strict improvement only (paper: "if d_v > d_u + w(u,v)"), receivers are
    # uncovered non-centers; centers are also protected by d = 0 minimality.
    recv = (~state.covered) & (~state.is_center)
    upd = recv & (d_min < state.d)
    new = state._replace(
        d=jnp.where(upd, d_min, state.d),
        c=jnp.where(upd, c_min, state.c),
        pathw=jnp.where(upd, p_min, state.pathw),
    )
    return new, jnp.any(upd)


def growth_loop(
    state: EngineState,
    relax_step,
    frozen: jnp.ndarray,
    delta: jnp.ndarray,
    half_target: jnp.ndarray,
    num_it: jnp.ndarray,
    variant: str,
) -> Tuple[EngineState, GrowthStats]:
    """THE PartialGrowth while_loop, shared by every backend.

    ``relax_step(s) -> (d_min, c_min, p_min)`` is the backend's one-superstep
    relax (jnp segment ops, Pallas kernel, ...); the stopping rule, update
    mask, and stats live only here so the byte-identical-backends invariant
    cannot drift.
    """

    def reached_count(s: EngineState) -> jnp.ndarray:
        return jnp.sum((~frozen) & (s.d < delta))

    def cond(carry):
        s, k, changed = carry
        more = changed & (k < num_it)
        if variant == "stop":
            more = more & (reached_count(s) < half_target)
        return more

    def body(carry):
        s, k, _ = carry
        d_min, c_min, p_min = relax_step(s)
        upd = (~frozen) & (d_min < s.d)
        s2 = s._replace(
            d=jnp.where(upd, d_min, s.d),
            c=jnp.where(upd, c_min, s.c),
            pathw=jnp.where(upd, p_min, s.pathw),
        )
        return (s2, k + 1, jnp.any(upd))

    init = (state, jnp.int32(0), jnp.bool_(True))
    final, k, changed = jax.lax.while_loop(cond, body, init)
    stats = GrowthStats(steps=k, reached=reached_count(final), changed_last=changed)
    return final, stats


@partial(jax.jit, static_argnames=("n_nodes", "variant"))
def partial_growth(
    state: EngineState,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    weight: jnp.ndarray,
    delta: jnp.ndarray,
    half_target: jnp.ndarray,
    num_it: jnp.ndarray,
    n_nodes: int,
    variant: str = "stop",
) -> Tuple[EngineState, GrowthStats]:
    """Paper's PartialGrowth(G, X, Delta, num_it) as a lax.while_loop.

    ``half_target``: |uncovered at stage start| / 2 — the coverage goal.
    ``variant``: "stop" halts once the goal is met; "complete" runs to
    quiescence (paper Table 2 compares both).
    """

    # relay planes are a function of covered/final_*/offset only, which do
    # not change within a grow call — derive them once, not per superstep.
    rw0, rc, rp, frozen = relay_planes(state)

    def relax_step(s: EngineState):
        cand_d, cand_c, cand_p = edge_relax_candidates(
            s.d[src], s.c[src], s.pathw[src], rw0[src], rc[src], rp[src],
            weight, jnp.bool_(True), delta,
        )
        return segment_min_triple(cand_d, cand_c, cand_p, dst, n_nodes)

    return growth_loop(state, relax_step, frozen, delta, half_target, num_it,
                       variant)
