"""The paper's contribution: weighted graph decomposition + diameter approx."""
from repro.core.state import EngineState, init_state, pad_state, relay_planes, INF
from repro.core.delta_growing import growing_step, partial_growth, edge_candidates
from repro.core.backend import (
    RelaxBackend,
    SingleDeviceBackend,
    ShardedBackend,
    PallasBackend,
    make_backend,
)
from repro.core.engine import EngineMetrics, run_cluster, run_cluster2
from repro.core.cluster import cluster, cluster2, Decomposition
from repro.core.quotient import (
    build_quotient,
    build_quotient_device,
    build_quotient_numpy,
    quotient_diameter,
    quotient_diameter_device,
    quotient_diameter_minplus,
    DeviceQuotient,
    QuotientGraph,
)
from repro.core.session import (
    EDGE_BUCKET,
    GraphSession,
    SessionMetrics,
    SessionPool,
    open_session,
    tau_for,
)
from repro.core.estimators import (
    ClusterQuotientEstimator,
    DeltaSteppingEstimator,
    DiameterEstimate,
    DiameterEstimator,
    DiameterInterval,
    IntervalEstimator,
    LowerBoundEstimator,
    PipelineMetrics,
)
from repro.core.diameter import (
    approximate_diameter,
    approximate_diameter_batch,
)
from repro.core.sssp import (
    bellman_ford,
    delta_stepping,
    diameter_2approx_sssp,
    farthest_point_lower_bound,
    multi_source_bellman_ford,
)

__all__ = [
    "EngineState",
    "init_state",
    "pad_state",
    "relay_planes",
    "INF",
    "RelaxBackend",
    "SingleDeviceBackend",
    "ShardedBackend",
    "PallasBackend",
    "make_backend",
    "EngineMetrics",
    "run_cluster",
    "run_cluster2",
    "growing_step",
    "partial_growth",
    "edge_candidates",
    "cluster",
    "cluster2",
    "Decomposition",
    "build_quotient",
    "build_quotient_device",
    "build_quotient_numpy",
    "quotient_diameter",
    "quotient_diameter_device",
    "quotient_diameter_minplus",
    "DeviceQuotient",
    "QuotientGraph",
    "EDGE_BUCKET",
    "GraphSession",
    "SessionMetrics",
    "SessionPool",
    "open_session",
    "tau_for",
    "ClusterQuotientEstimator",
    "DeltaSteppingEstimator",
    "DiameterEstimator",
    "DiameterInterval",
    "IntervalEstimator",
    "LowerBoundEstimator",
    "approximate_diameter",
    "approximate_diameter_batch",
    "DiameterEstimate",
    "PipelineMetrics",
    "bellman_ford",
    "delta_stepping",
    "diameter_2approx_sssp",
    "farthest_point_lower_bound",
    "multi_source_bellman_ford",
]
