"""DiameterEstimator: interchangeable diameter queries over a GraphSession.

The paper's experimental core (Table 3) is a head-to-head between the
cluster-quotient pipeline and SSSP-based estimators. Each method is a
``DiameterEstimator`` — ``estimate(session) -> DiameterEstimate`` — running
against the session's RESIDENT device buffers, so methods can be compared on
the same graph without re-uploading or rebuilding anything:

  * ``ClusterQuotientEstimator`` — the paper pipeline (Sections 4+5):
    decompose -> device quotient -> batched multi-source solve. Conservative
    UPPER bound (Phi_approx >= Phi(G) when connected).
  * ``DeltaSteppingEstimator`` — the Section 5 competitor: one SSSP from a
    random source gives ecc <= Phi <= 2 ecc. ``delta=None`` degenerates to
    Bellman-Ford, the paper's optimal setting on a round-driven platform
    (and byte-identical to the legacy ``diameter_2approx_sssp``).
  * ``LowerBoundEstimator`` — repeated SSSP hopping to the farthest node
    (how the paper computes the Phi column of Table 1). LOWER bound only.
  * ``IntervalEstimator`` — composite: runs a panel of estimators and
    returns a certified ``[lower, upper]`` bracket (``DiameterInterval``)
    with per-estimator results and merged ``PipelineMetrics``.
  * ``DynamicQuotientEstimator`` — the dynamic-graph subsystem's query
    side (``core/dynamic.py``): serves the decomposition the session
    maintains under ``apply_updates`` with incremental quotient refresh
    and a cached solve.

Every estimator surfaces the same ``connected`` flag contract: on a
disconnected input the bounds cover only finite-distance pairs and
``connected`` is False (the true diameter is infinite).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import ClassVar, Dict, List, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.common import Timer, get_logger
from repro.core.cluster import Decomposition, cluster, cluster2
from repro.core.engine import resolve_engine_mode
from repro.core.quotient import (
    build_quotient_device,
    build_quotient_from_level,
    build_quotient_numpy,
    quotient_as_edgelist,
    quotient_diameter,
    solve_device_quotient,
)
from repro.analysis import guard
from repro.core.session import GraphSession, tau_for
from repro.runtime import telemetry

log = get_logger("repro.estimators")


@dataclass
class PipelineMetrics:
    """Host-sync accounting for one estimator query.

    Every field counts device->host fetches (the paper's round-overhead
    analogue); device supersteps are tracked separately. The end-to-end
    budget the bench asserts is ``total_host_syncs <= 8``. Metrics add:
    ``a + b`` (or ``sum([...])``) is the field-wise aggregate, so batch and
    interval queries report one combined sync total.
    """

    decompose_syncs: int = 0   # one per engine stage (stop-decision scalars)
    finalize_syncs: int = 0    # packed final-plane fetch (1 per decomposition)
    checkpoint_syncs: int = 0  # device leaves materialized for durability
                               # (stage-boundary checkpoints). Deliberately
                               # NOT in total_host_syncs: durability cost is
                               # a knob (checkpoint_every), not part of the
                               # algorithmic round budget the bench asserts.
    halo_bytes: int = 0        # plane-row bytes the sharded comm plan moved
    fullplane_bytes: int = 0   # what a full-plane all-gather would have moved
                               # (both 0 on single-device backends; bytes,
                               # not syncs — never in total_host_syncs)
    quotient_syncs: int = 0    # (k, m, max_w, w_sum) counter fetch, 1 / level
    solve_syncs: int = 0       # packed (diameter, connected, steps, ecc) fetch
    solve_supersteps: int = 0  # device BF supersteps inside the solve
    n_quotient_edges: int = 0  # level-0 quotient edge count
    # cascade accounting (CascadeEstimator): one list entry per EXTRA level
    # (the flat pipeline is level 0 and keeps these empty, so a level-0
    # cascade stays field-identical to ClusterQuotientEstimator). Lists
    # concatenate under ``+`` like the scalar counters add.
    cascade_levels: int = 0              # extra decomposition levels run
    level_syncs: List[int] = field(default_factory=list)       # per level
    level_supersteps: List[int] = field(default_factory=list)  # per level
    level_clusters: List[int] = field(default_factory=list)    # quotient k
                                                               # after level

    @property
    def total_host_syncs(self) -> int:
        return (self.decompose_syncs + self.finalize_syncs
                + self.quotient_syncs + self.solve_syncs)

    def __add__(self, other: "PipelineMetrics") -> "PipelineMetrics":
        if not isinstance(other, PipelineMetrics):
            return NotImplemented
        return PipelineMetrics(**{
            f.name: getattr(self, f.name) + getattr(other, f.name)
            for f in dataclasses.fields(self)})

    def __radd__(self, other) -> "PipelineMetrics":
        if other == 0:  # support sum([...]) with the default start
            return self
        return self.__add__(other)

    @staticmethod
    def merge(items) -> "PipelineMetrics":
        """Field-wise aggregate of many metrics (None entries skipped)."""
        return sum((m for m in items if m is not None), PipelineMetrics())


@dataclass
class DiameterEstimate:
    phi_approx: int
    phi_quotient: int
    radius: int
    n_clusters: int
    growing_steps: int
    n_stages: int
    delta_end: int
    seconds: float
    connected: bool
    # phi_approx is a conservative estimate of the diameter ONLY when
    # ``connected`` — for a disconnected graph it upper-bounds the largest
    # finite-distance pair (the true diameter is infinite).
    pipeline: Optional[PipelineMetrics] = None
    # int64 eccentricities of the SOLVED quotient's clusters: length
    # n_clusters for the flat pipeline; for a cascade that ran extra levels
    # it covers the FINAL level's clusters (pipeline.level_clusters[-1] of
    # them), in original units (scaled back by the cumulative rescale).
    quotient_ecc: Optional[np.ndarray] = None
    # which estimator produced this, and the certified bracket it provides:
    # ``lower <= Phi(G) <= upper`` (each may be None when the method gives
    # no bound on that side; bounds cover finite pairs when disconnected).
    method: str = "cluster-quotient"
    lower: Optional[int] = None
    upper: Optional[int] = None


@dataclass
class DiameterInterval:
    """Certified diameter bracket from a panel of estimators."""

    lower: int
    upper: int
    connected: bool
    estimates: Dict[str, DiameterEstimate]
    pipeline: PipelineMetrics   # merged host-sync totals across the panel
    seconds: float


@runtime_checkable
class DiameterEstimator(Protocol):
    """One diameter-query method over a resident ``GraphSession``."""

    name: str

    def estimate(self, session: GraphSession) -> DiameterEstimate:
        ...


# ---------------------------------------------------------------------------
# the paper pipeline
# ---------------------------------------------------------------------------


def _fetch_quotient_counters(dq, pm: PipelineMetrics):
    """ONE packed fetch of the four device counters:
    (n_clusters, n_edges, max_weight, weight_sum)."""
    from repro.core.quotient import fetch_quotient_counters

    pm.quotient_syncs += 1
    return fetch_quotient_counters(dq)


def _device_quotient_solve(edges, dec: Decomposition, backend,
                           pm: PipelineMetrics):
    """quotient + local solve, device-resident. Returns
    (phi_quotient, eccentricities, connected)."""
    with telemetry.span("quotient.build") as sp:
        dq = build_quotient_device(edges, dec, backend=backend)
        if dq is None:  # no nodes or no edges: quotient is trivially empty
            k = dec.n_clusters
            return 0, np.zeros(k, np.int64), k <= 1
        k, m, wmax, _ = _fetch_quotient_counters(dq, pm)
        pm.n_quotient_edges = m
        sp.set(clusters=k, edges=m)
    if k <= 1:
        return 0, np.zeros(k, np.int64), True
    with telemetry.span("quotient.solve", clusters=k) as sp:
        diam, ecc, connected, steps = solve_device_quotient(dq, k, m, wmax)
        pm.solve_syncs += 1
        pm.solve_supersteps = steps
        sp.set(supersteps=steps)
    return diam, ecc, connected


def _cascade_quotient_solve(edges, dec: Decomposition, backend,
                            pm: PipelineMetrics, cfg, tau_solve: int,
                            max_levels: int, level_mode: str = "stages"):
    """Multi-level quotient cascade (companion paper arXiv:1407.3144 applies
    the decomposition RECURSIVELY until the residual graph is small).

    While the quotient still exceeds the solve budget (``k > tau_solve``)
    and levels remain, re-enter the engine ON THE QUOTIENT: rescale its
    int64 weights into the engine's int32 planes (``quotient_as_edgelist``,
    ceiling division — conservative), decompose with a device-resident
    ``SingleDeviceBackend`` over the resident buffers, and quotient again.
    Per-level cluster radii accumulate into the upper bound:

        Phi(G) <= 2 R_0 + sum_{l>=1} S_l * 2 R_l + S_L * diam(Q_L)

    with S_l the cumulative rescale factor (1 unless weights overflowed
    int32). Returns (phi_quotient_tail, ecc, connected, extra_steps) where
    ``phi_quotient_tail`` is everything except level-0's ``2 R_0`` — so
    ``phi = tail + 2 * dec.radius`` holds at every level count, and a
    level-0 cascade is field-identical to the flat pipeline.

    ``level_mode`` selects the decomposition mode for the RE-ENTRANT levels
    ("stages" or "oneshot"): quotient levels are small and stage-count
    bound, so oneshot's single-fixpoint growth often wins there even when
    level 0 runs staged.
    """
    from repro.core.backend import SingleDeviceBackend
    from repro.core.engine import run_cluster, run_oneshot

    with telemetry.span("quotient.build") as sp:
        dq = build_quotient_device(edges, dec, backend=backend)
        if dq is None:  # no nodes or no edges: quotient is trivially empty
            k = dec.n_clusters
            return 0, np.zeros(k, np.int64), k <= 1, 0
        k, m, wmax, wsum = _fetch_quotient_counters(dq, pm)
        pm.n_quotient_edges = m
        sp.set(clusters=k, edges=m)
    scale_total = 1
    radius_tail = 0   # sum_{l>=1} S_l * 2 R_l
    extra_steps = 0
    level = 0
    while level < max_levels and k > max(tau_solve, 1) and m > 0:
        level += 1
        with telemetry.span("cascade.level", level=level) as sp:
            lv = quotient_as_edgelist(dq, k, m, wmax, wsum)
            be = SingleDeviceBackend.from_device(lv.n_nodes, lv.src, lv.dst,
                                                 lv.weight)
            if level_mode == "oneshot":
                dec_l = run_oneshot(
                    None, be, tau_for(k, cfg.tau_fraction),
                    gamma=cfg.gamma, seed=cfg.seed + level,
                    deterministic=cfg.deterministic,
                    max_steps_per_phase=cfg.max_steps_per_phase,
                    max_delta=lv.weight_sum + 1,
                )
            else:
                dec_l = run_cluster(
                    None, be, tau_for(k, cfg.tau_fraction),
                    gamma=cfg.gamma, variant=cfg.variant,
                    delta0=max(lv.weight_sum // max(m, 1), 1),
                    seed=cfg.seed + level, max_stages=cfg.max_stages,
                    max_steps_per_phase=cfg.max_steps_per_phase,
                    max_delta=lv.weight_sum + 1,
                )
            scale_total *= lv.scale
            radius_tail += scale_total * 2 * dec_l.radius
            extra_steps += dec_l.growing_steps
            pm.decompose_syncs += dec_l.metrics.host_syncs
            pm.finalize_syncs += dec_l.metrics.finalize_syncs
            dq = build_quotient_from_level(lv, dec_l)
            k, m, wmax, wsum = _fetch_quotient_counters(dq, pm)
            pm.level_syncs.append(dec_l.metrics.host_syncs
                                  + dec_l.metrics.finalize_syncs + 1)
            pm.level_supersteps.append(dec_l.growing_steps)
            pm.level_clusters.append(k)
            sp.set(clusters=k, supersteps=dec_l.growing_steps,
                   syncs=pm.level_syncs[-1])
        log.info("cascade level %d: %d clusters -> %d (scale=%d steps=%d)",
                 level, lv.n_nodes, k, lv.scale, dec_l.growing_steps)
        if k == lv.n_nodes:
            # no shrinkage (the level's stage threshold exceeded its node
            # count -> all singletons): further levels would repeat the
            # same non-progress, so solve what we have
            log.info("cascade level %d did not shrink the quotient; "
                     "solving at %d clusters", level, k)
            break
    pm.cascade_levels = level
    if k <= 1:
        return radius_tail, np.zeros(k, np.int64), True, extra_steps
    with telemetry.span("quotient.solve", clusters=k) as sp:
        diam, ecc, connected, steps = solve_device_quotient(dq, k, m, wmax)
        pm.solve_syncs += 1
        pm.solve_supersteps = steps
        sp.set(supersteps=steps)
    return (radius_tail + scale_total * diam,
            np.asarray(ecc, np.int64) * scale_total, connected, extra_steps)


def _resolve_query_cfg(session: GraphSession, est) -> Tuple[object, int]:
    """Apply an estimator's per-query overrides to the session config and
    resolve tau. Shared by ClusterQuotientEstimator and CascadeEstimator."""
    cfg = session.cfg
    delta_init = est.delta_init
    if delta_init is not None:
        # resolve symbolic modes through the session: on a pooled
        # (padded) session "avg"/"min" must reflect the REAL edges
        delta_init = str(session.resolve_delta_init(delta_init))
    overrides = {k: v for k, v in (
        ("variant", est.variant), ("seed", est.seed),
        ("delta_init", delta_init),
        ("use_cluster2", est.use_cluster2),
        ("mode", getattr(est, "mode", None))) if v is not None}
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    # "auto" resolves against the session's autotuning record (if any);
    # explicit per-query "stages"/"oneshot" always wins, and bad names
    # raise before any device work
    mode = resolve_engine_mode(cfg.mode, session.tuning)
    if mode != cfg.mode:
        cfg = dataclasses.replace(cfg, mode=mode)
    tau = est.tau if est.tau is not None else session.tau
    if tau < 1:
        raise ValueError(f"tau must be >= 1, got {tau}")
    return cfg, tau


def _run_decomposition(edges, backend, cfg, tau: int,
                       pm: PipelineMetrics,
                       checkpointer=None) -> Decomposition:
    """Level-0 decomposition on the session's resident backend."""
    if cfg.use_cluster2:
        dec: Decomposition = cluster2(
            edges, tau, gamma=cfg.gamma, seed=cfg.seed,
            delta_init=cfg.delta_init, relax_fn=backend,
        )
    else:
        dec = cluster(
            edges, tau, gamma=cfg.gamma, variant=cfg.variant,
            delta_init=cfg.delta_init, seed=cfg.seed,
            max_stages=cfg.max_stages,
            max_steps_per_phase=cfg.max_steps_per_phase,
            relax_fn=backend,
            mode=cfg.mode, deterministic=cfg.deterministic,
            checkpointer=checkpointer,
        )
    if dec.metrics is not None:
        pm.decompose_syncs = dec.metrics.host_syncs
        pm.finalize_syncs = dec.metrics.finalize_syncs
        pm.checkpoint_syncs = dec.metrics.checkpoint_syncs
        pm.halo_bytes = dec.metrics.halo_bytes
        pm.fullplane_bytes = dec.metrics.fullplane_bytes
    return dec


def _package_estimate(method: str, dec: Decomposition, phi_q: int,
                      connected: bool, pm: PipelineMetrics, ecc,
                      seconds: float, extra_steps: int = 0) -> DiameterEstimate:
    phi = phi_q + 2 * dec.radius
    log.info(
        "phi_approx=%d (quotient=%d radius=%d clusters=%d steps=%d "
        "host_syncs=%d) in %.2fs",
        phi, phi_q, dec.radius, dec.n_clusters,
        dec.growing_steps + extra_steps, pm.total_host_syncs, seconds,
    )
    return DiameterEstimate(
        phi_approx=phi,
        phi_quotient=phi_q,
        radius=dec.radius,
        n_clusters=dec.n_clusters,
        growing_steps=dec.growing_steps + extra_steps,
        n_stages=dec.n_stages,
        delta_end=dec.delta_end,
        seconds=seconds,
        connected=connected,
        pipeline=pm,
        quotient_ecc=ecc,
        method=method,
        upper=phi,
    )


@dataclass
class ClusterQuotientEstimator:
    """Paper pipeline: Phi_approx(G) = Phi(G_C) + 2 R (conservative upper).

    ``tau``/``variant``/``seed``/``delta_init``/``use_cluster2``/``mode``
    override the session defaults per query — the resident graph is reused,
    so e.g. a stop-vs-complete, CLUSTER-vs-CLUSTER2 or stages-vs-oneshot
    comparison costs two queries on one session, not two uploads.
    ``solver="device"`` (default) runs the quotient + solve on device;
    ``solver="scipy"`` keeps the host oracle path (tests / debugging).
    """

    name: ClassVar[str] = "cluster-quotient"

    tau: Optional[int] = None
    solver: str = "device"
    variant: Optional[str] = None
    seed: Optional[int] = None
    delta_init: Optional[str] = None
    use_cluster2: Optional[bool] = None
    mode: Optional[str] = None       # stages | oneshot | auto (engine mode)

    def estimate(self, session: GraphSession) -> DiameterEstimate:
        cfg, tau = _resolve_query_cfg(session, self)
        edges, backend = session.edges, session.backend
        pm = PipelineMetrics()
        ecc = None
        with session.track_query(), Timer() as t:
            dec = _run_decomposition(
                edges, backend, cfg, tau, pm,
                checkpointer=getattr(session, "checkpointer", None))
            if self.solver == "scipy":
                q = build_quotient_numpy(edges, dec)
                phi_q, connected = quotient_diameter(q)
            else:
                phi_q, ecc, connected = _device_quotient_solve(
                    edges, dec, backend, pm)
            if not connected:
                log.warning(
                    "graph is disconnected: phi_approx=%d only bounds "
                    "finite-distance pairs", phi_q + 2 * dec.radius)
        return _package_estimate(self.name, dec, phi_q, connected, pm, ecc,
                                 t.seconds)


@dataclass
class CascadeEstimator:
    """Multi-level quotient cascade: the paper pipeline applied RECURSIVELY
    (companion paper arXiv:1407.3144) until the residual quotient fits the
    batched-BF solve budget.

    Level 0 decomposes the session graph on its resident backend exactly
    like ``ClusterQuotientEstimator``; while the quotient still has more
    than ``tau_solve`` clusters and ``levels`` allows, the engine re-enters
    ON THE QUOTIENT (``quotient_as_edgelist`` -> device-resident
    ``SingleDeviceBackend`` -> decompose -> quotient), accumulating each
    level's ``2 * radius`` (times the cumulative int64->int32 weight
    rescale) into the conservative upper bound. ``levels=0`` is
    field-identical to the flat pipeline.

    Deeper levels always run single-device — the quotient is small by
    construction, mirroring the paper's "solve locally in one reducer".
    ``n_clusters``/``radius``/``n_stages``/``delta_end`` on the returned
    estimate describe LEVEL 0 (per-level breakdowns live in
    ``pipeline.level_*``); ``quotient_ecc`` covers the final solved level.
    """

    name: ClassVar[str] = "cascade"

    levels: int = 2
    tau_solve: Optional[int] = None
    tau: Optional[int] = None
    variant: Optional[str] = None
    seed: Optional[int] = None
    delta_init: Optional[str] = None
    use_cluster2: Optional[bool] = None
    mode: Optional[str] = None        # level-0 engine mode override
    level_mode: Optional[str] = None  # mode for re-entrant quotient levels;
                                      # None = follow the level-0 mode

    def estimate(self, session: GraphSession) -> DiameterEstimate:
        if self.levels < 0:
            raise ValueError(f"levels must be >= 0, got {self.levels}")
        tau_solve = (self.tau_solve if self.tau_solve is not None
                     else session.tau_solve)
        if tau_solve < 2:
            raise ValueError(f"tau_solve must be >= 2, got {tau_solve}")
        cfg, tau = _resolve_query_cfg(session, self)
        level_mode = resolve_engine_mode(
            self.level_mode if self.level_mode is not None else cfg.mode,
            session.tuning)
        edges, backend = session.edges, session.backend
        pm = PipelineMetrics()
        with session.track_query(), Timer() as t:
            dec = _run_decomposition(
                edges, backend, cfg, tau, pm,
                checkpointer=getattr(session, "checkpointer", None))
            phi_q, ecc, connected, extra = _cascade_quotient_solve(
                edges, dec, backend, pm, cfg, tau_solve, self.levels,
                level_mode=level_mode)
            if not connected:
                log.warning(
                    "graph is disconnected: phi_approx=%d only bounds "
                    "finite-distance pairs", phi_q + 2 * dec.radius)
        return _package_estimate(self.name, dec, phi_q, connected, pm, ecc,
                                 t.seconds, extra_steps=extra)


@dataclass
class DynamicQuotientEstimator:
    """Query side of the dynamic-graph subsystem (``core/dynamic.py``).

    Serves the conservative upper bound ``Phi(G_C) + 2 R`` from the
    decomposition the session MAINTAINS under ``apply_updates`` instead of
    re-decomposing per query: the quotient is refreshed incrementally (only
    (cluster, cluster) keys touching clusters dirtied since the last solve
    are recomputed) and the solve result is cached until the next update —
    so a query against an unchanged session costs ZERO device work beyond
    the cached scalars, and a post-update query costs one dirty-slice
    quotient pass plus the batched solve.

    On a session that has never seen an update this initializes dynamic
    mode (one full decomposition — the same work the flat pipeline's first
    query does); the bound contract is identical to
    ``ClusterQuotientEstimator``'s: certified upper when connected, largest
    finite-distance pair otherwise (flagged via ``connected``).
    """

    name: ClassVar[str] = "dynamic-quotient"

    def estimate(self, session: GraphSession) -> DiameterEstimate:
        from repro.core import dynamic as dyn_mod

        pm = PipelineMetrics()
        with session.track_query(), Timer() as t:
            st = dyn_mod.ensure_dynamic(session)
            phi_q, ecc, connected = dyn_mod.solve_session_quotient(
                session, pm)
            if not connected:
                log.warning(
                    "graph is disconnected: phi_approx=%d only bounds "
                    "finite-distance pairs", phi_q + 2 * st.dec.radius)
        return _package_estimate(self.name, st.dec, phi_q, connected, pm,
                                 ecc, t.seconds)


# ---------------------------------------------------------------------------
# SSSP estimators (the competitors), on the session's resident edge arrays
# ---------------------------------------------------------------------------


def _trivial_estimate(method: str, n_nodes: int) -> DiameterEstimate:
    """Empty / single-node graphs: diameter 0, connected iff <= 1 node."""
    return DiameterEstimate(
        phi_approx=0, phi_quotient=0, radius=0, n_clusters=n_nodes,
        growing_steps=0, n_stages=0, delta_end=0, seconds=0.0,
        connected=n_nodes <= 1, pipeline=PipelineMetrics(),
        method=method, lower=0, upper=0 if n_nodes <= 1 else None)


def _sssp_from(session: GraphSession, source: int, delta: Optional[int]):
    """One SSSP on the resident edge arrays; ONE packed host fetch of
    (dist, supersteps). ``delta=None`` -> Bellman-Ford. Returns
    (dist, supersteps, inf) — the distance dtype follows the same provable
    bound as ``sssp.bellman_ford`` (int64 when ``n * max_weight`` would
    overflow int32, so heavy-weight graphs never wrap negative)."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.sssp import _bf_loop, _delta_stepping_loop, sssp_dtype_for

    n = session.n_nodes
    src, dst, w = session.flat_device_edges()
    # dtype: delta=None means unbucketed; None and 0 pick the same bound
    dtype, inf = sssp_dtype_for(n, session.max_weight, delta or 0)
    with enable_x64(), telemetry.span("sssp.solve", source=source) as sp:
        infj = jnp.asarray(inf, dtype)
        d0 = jnp.full(n, infj, dtype=dtype).at[source].set(0)
        wd = w.astype(dtype)
        if delta is None:
            d, k = _bf_loop(src, dst, wd, d0, infj, n)
        else:
            d, k = _delta_stepping_loop(src, dst, wd, d0,
                                        jnp.asarray(delta, dtype), infj, n)
        out = guard.fetch(jnp.concatenate(
            [d.astype(jnp.int64), k[None].astype(jnp.int64)]),
            reason="sssp estimator: packed (dist plane, supersteps)")
        sp.set(supersteps=int(out[n]))
    return out[:n], int(out[n]), inf


@dataclass
class DeltaSteppingEstimator:
    """2-approximation from one SSSP: ecc(source) <= Phi <= 2 ecc(source).

    ``delta=None`` (default) runs Bellman-Ford — the paper notes the best
    Delta-stepping setting on a round-driven platform degenerates to
    Delta = inf — and reproduces the legacy ``diameter_2approx_sssp``
    numbers exactly (same source draw, same relaxation order).
    """

    name: ClassVar[str] = "delta-stepping"

    seed: int = 0
    delta: Optional[int] = None

    def estimate(self, session: GraphSession) -> DiameterEstimate:
        if self.delta is not None and self.delta < 1:
            raise ValueError(f"delta must be >= 1, got {self.delta} "
                             "(use delta=None for Bellman-Ford)")
        n = session.n_nodes
        if n <= 1:
            with session.track_query():
                return _trivial_estimate(self.name, n)
        with session.track_query(), Timer() as t:
            rng = np.random.default_rng(self.seed)
            s = int(rng.integers(n))
            dist, supersteps, inf = _sssp_from(session, s, self.delta)
        reached = dist < inf
        ecc = int(dist[reached].max())
        connected = bool(reached.all())
        pm = PipelineMetrics(solve_syncs=1, solve_supersteps=supersteps)
        # on a disconnected input 2*ecc only covers the SOURCE's component —
        # unlike the cluster-quotient upper it does NOT bound the largest
        # finite-distance pair, so it is no certified upper bound at all
        # (the realized ecc stays a valid lower bound either way).
        return DiameterEstimate(
            phi_approx=2 * ecc, phi_quotient=0, radius=ecc, n_clusters=0,
            growing_steps=supersteps, n_stages=1,
            # dtype: delta=None (unbucketed BF) reports delta_end=0
            delta_end=self.delta or 0,
            seconds=t.seconds, connected=connected, pipeline=pm,
            method=self.name, lower=ecc, upper=2 * ecc if connected else None)


@dataclass
class LowerBoundEstimator:
    """Farthest-point SSSP hopping (paper Table 1's Phi column): a certified
    LOWER bound — every hop realizes an actual shortest-path distance.

    The FIRST hop is exactly the 2-approx SSSP (random source, same draw as
    ``DeltaSteppingEstimator`` for the same seed), so on connected inputs
    the result also carries its free ``upper = 2 * ecc(first source)`` —
    which is why the default ``IntervalEstimator`` panel does not need a
    separate ``DeltaSteppingEstimator`` run.
    """

    name: ClassVar[str] = "farthest-point"

    rounds: int = 4
    seed: int = 0

    def estimate(self, session: GraphSession) -> DiameterEstimate:
        n = session.n_nodes
        if n <= 1:
            with session.track_query():
                return _trivial_estimate(self.name, n)
        with session.track_query(), Timer() as t:
            rng = np.random.default_rng(self.seed)
            s = int(rng.integers(n))
            best, total_steps, hops = 0, 0, 0
            first_ecc = 0
            connected = True
            pm = PipelineMetrics()
            for _ in range(self.rounds):
                dist, supersteps, inf = _sssp_from(session, s, None)
                pm.solve_syncs += 1
                pm.solve_supersteps += supersteps
                total_steps += supersteps
                hops += 1
                connected = connected and bool((dist < inf).all())
                fin = np.where(dist < inf, dist, -1)
                far = int(fin.argmax())
                best = max(best, int(fin.max()))
                if hops == 1:
                    first_ecc = int(fin.max())
                if far == s:
                    break
                s = far
        return DiameterEstimate(
            phi_approx=best, phi_quotient=0, radius=0, n_clusters=0,
            growing_steps=total_steps, n_stages=hops, delta_end=0,
            seconds=t.seconds, connected=connected, pipeline=pm,
            method=self.name, lower=best,
            upper=2 * first_ecc if connected else None)


# ---------------------------------------------------------------------------
# composite: certified [lower, upper] bracket
# ---------------------------------------------------------------------------


@dataclass
class IntervalEstimator:
    """Run a panel of estimators on ONE resident session and combine their
    bounds: lower = max of lower bounds, upper = min of upper bounds. The
    bracket is certified even on disconnected inputs (both sides then bound
    the largest finite-distance pair; ``connected=False`` flags it). The
    default panel is farthest-point (whose first hop doubles as the SSSP
    2-approx upper — running ``DeltaSteppingEstimator`` too would repeat
    that exact Bellman-Ford) plus the cluster-quotient pipeline — or, on a
    session in dynamic mode (``apply_updates``), the maintained
    ``DynamicQuotientEstimator`` so the upper side rides the repaired
    decomposition instead of re-decomposing."""

    name: ClassVar[str] = "interval"

    estimators: Tuple = ()

    def estimate(self, session: GraphSession) -> DiameterInterval:
        upper_est = (DynamicQuotientEstimator()
                     if getattr(session, "_dynamic", None) is not None
                     else ClusterQuotientEstimator())
        panel = self.estimators or (LowerBoundEstimator(), upper_est)
        with Timer() as t:
            results: Dict[str, DiameterEstimate] = {}
            for e in panel:
                key, dup = e.name, 2
                while key in results:  # multi-instance panels (e.g. seeds)
                    key, dup = f"{e.name}#{dup}", dup + 1
                results[key] = e.estimate(session)
        lowers = [r.lower for r in results.values() if r.lower is not None]
        uppers = [r.upper for r in results.values() if r.upper is not None]
        if not uppers:
            raise ValueError("interval panel produced no upper bound "
                             "(include a cluster-quotient or SSSP estimator)")
        flags = {r.connected for r in results.values()}
        if len(flags) > 1:
            log.warning("estimators disagree on connectivity: %s",
                        {k: r.connected for k, r in results.items()})
        lower, upper = max(lowers, default=0), min(uppers)
        if lower > upper:
            raise AssertionError(
                f"certified bracket violated: lower {lower} > upper {upper}")
        return DiameterInterval(
            lower=lower, upper=upper,
            connected=all(flags),
            estimates=results,
            pipeline=PipelineMetrics.merge(
                r.pipeline for r in results.values()),
            seconds=t.seconds,
        )
