"""CLUSTER(G, tau) — paper Algorithm 1 — as thin wrappers over the
device-resident engine (``core/engine.py``) and a ``RelaxBackend``
(``core/backend.py``).

Stages sample O(tau log n) new centers from the uncovered nodes (jax.random,
on device), grow all clusters with Delta-growing steps, double Delta until at
least half the stage's uncovered nodes are reached (continuing the partial
clustering across doublings — paper Section 5 optimization (2)), then freeze
coverage. Remaining nodes become singletons. Each stage is one jitted device
program costing one host sync; see ``docs/engine.md``.

The returned radius is max over nodes of the realized path weight from the
assigned center — an exact upper bound on the clustering radius in G.
"""
from __future__ import annotations

from typing import Optional, Union

from repro.common import get_logger
from repro.core.backend import RelaxBackend, make_backend
from repro.core.engine import (
    Decomposition,
    EngineMetrics,
    resolve_engine_mode,
    run_cluster,
    run_cluster2,
    run_oneshot,
)
from repro.graph.structures import EdgeList

log = get_logger("repro.cluster")

__all__ = ["Decomposition", "EngineMetrics", "cluster", "cluster2",
           "_initial_delta"]


def _initial_delta(edges: EdgeList, mode: str) -> int:
    if edges.n_edges == 0:
        return 1  # nothing to grow along; any positive budget works
    if mode == "min":
        # paper pseudocode: 1 + min edge weight
        return int(edges.weight.min()) + 1
    if mode == "avg":
        # paper Section 5: average edge weight is a good initial guess
        return max(int(edges.weight.mean()), 1)
    return max(int(mode), 1)


def _resolve_backend(edges: EdgeList, backend, relax_fn) -> RelaxBackend:
    """``relax_fn`` is the legacy hook name — it now takes a RelaxBackend
    (``DistributedEngine.make_relax_fn()`` returns one). ``backend`` accepts
    a spec string ("single" | "sharded" | "pallas") or a backend instance."""
    if relax_fn is not None:
        if isinstance(relax_fn, RelaxBackend):
            return relax_fn
        raise TypeError(
            "cluster(relax_fn=...) now expects a RelaxBackend (e.g. "
            "DistributedEngine.make_relax_fn() or core.backend.make_backend); "
            f"got {type(relax_fn).__name__}")
    return make_backend(edges, backend)


def cluster(
    edges: EdgeList,
    tau: int,
    gamma: float = 2.0,
    variant: str = "stop",
    delta_init: str = "avg",
    seed: int = 0,
    max_stages: int = 64,
    max_steps_per_phase: int = 0,
    threshold_const: float = 8.0,
    relax_fn=None,
    backend: Union[str, RelaxBackend] = "single",
    mode: str = "stages",
    deterministic: bool = False,
    checkpointer=None,
) -> Decomposition:
    """Paper Algorithm 1. ``variant`` in {"stop", "complete"} (Table 2).

    ``backend`` selects the execution engine (see ``core/backend.py``); all
    backends produce byte-identical decompositions for a fixed seed.

    ``mode`` selects the decomposition strategy ("stages" — the paper's
    stage loop, default and byte-identical to before this knob existed —
    or "oneshot" — MPVX exponential-shift growth, one relax fixpoint, one
    host sync; see ``core/engine.py``). ``"auto"`` resolves to "stages"
    here (no tuning record in scope — sessions resolve it against theirs).
    ``deterministic`` applies to oneshot only: hash-derived shifts make the
    output a seed-independent function of the graph.

    ``checkpointer`` (a ``core.engine.StageCheckpointer``) makes the staged
    run preemption-safe: state is saved at stage boundaries and a resumed
    run finishes with a byte-identical decomposition. Oneshot mode has no
    stage boundaries, so the checkpointer is ignored there (one device
    program either completes or re-runs from scratch).
    """
    be = _resolve_backend(edges, backend, relax_fn)
    mode = resolve_engine_mode(mode)
    if mode == "oneshot":
        if checkpointer is not None:
            log.info("oneshot mode has no stage boundaries; "
                     "checkpointer ignored")
        return run_oneshot(
            edges, be, tau,
            gamma=gamma, seed=seed, deterministic=deterministic,
            max_steps_per_phase=max_steps_per_phase,
        )
    return run_cluster(
        edges, be, tau,
        gamma=gamma, variant=variant,
        delta0=_initial_delta(edges, delta_init),
        seed=seed, max_stages=max_stages,
        max_steps_per_phase=max_steps_per_phase,
        threshold_const=threshold_const,
        checkpointer=checkpointer,
    )


def cluster2(
    edges: EdgeList,
    tau: int,
    gamma: float = 2.0,
    seed: int = 0,
    delta_init: str = "avg",
    base: Optional[Decomposition] = None,
    relax_fn=None,
    backend: Union[str, RelaxBackend] = "single",
) -> Decomposition:
    """CLUSTER2(G, tau) — paper Algorithm 2.

    First runs CLUSTER to obtain R_CL(tau); then re-clusters from scratch
    with fixed growth budget Delta = 2 R_CL(tau) and center-selection
    probability doubling each stage (last stage selects everything left).
    Growth runs to quiescence each stage (PartialGrowth2).

    CLUSTER2 is inherently staged (the doubling selection probability IS
    the algorithm), so it has no one-shot mode; use ``cluster(mode=...)``
    for mode-pluggable decomposition.
    """
    be = _resolve_backend(edges, backend, relax_fn)
    if base is None:
        base = cluster(edges, tau, gamma=gamma, seed=seed,
                       delta_init=delta_init, relax_fn=be)
    delta = max(2 * base.radius, 2)
    return run_cluster2(edges, be, tau, delta=delta, seed=seed + 1)
