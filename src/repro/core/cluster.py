"""CLUSTER(G, tau) — paper Algorithm 1, host-driven stage loop.

Stages sample O(tau log n) new centers from the uncovered nodes, grow all
clusters with Delta-growing steps (jitted ``partial_growth`` while_loop),
double Delta until at least half the stage's uncovered nodes are reached
(continuing the partial clustering across doublings — paper Section 5
optimization (2)), then freeze coverage. Remaining nodes become singletons.

The returned radius is max over nodes of the realized path weight from the
assigned center — an exact upper bound on the clustering radius in G.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import get_logger
from repro.core.delta_growing import partial_growth
from repro.core.state import (
    EngineState,
    INF,
    cover,
    finalize_singletons,
    init_state,
    promote_centers,
    reset_in_stage,
    uncovered_count,
)
from repro.graph.structures import EdgeList

log = get_logger("repro.cluster")


@dataclass
class Decomposition:
    """Output of CLUSTER / CLUSTER2."""

    n_nodes: int
    final_c: np.ndarray        # int32 [n] cluster center id per node
    final_pathw: np.ndarray    # int32 [n] dist-from-center upper bound
    radius: int                # R_CL(tau) = max final_pathw
    delta_end: int
    n_clusters: int
    n_stages: int
    growing_steps: int         # total Delta-growing steps (the paper's
                               # round-complexity proxy)

    def cluster_sizes(self) -> np.ndarray:
        _, counts = np.unique(self.final_c, return_counts=True)
        return counts


def _initial_delta(edges: EdgeList, mode: str) -> int:
    if mode == "min":
        # paper pseudocode: 1 + min edge weight
        return int(edges.weight.min()) + 1
    if mode == "avg":
        # paper Section 5: average edge weight is a good initial guess
        return max(int(edges.weight.mean()), 1)
    return max(int(mode), 1)


def cluster(
    edges: EdgeList,
    tau: int,
    gamma: float = 2.0,
    variant: str = "stop",
    delta_init: str = "avg",
    seed: int = 0,
    max_stages: int = 64,
    max_steps_per_phase: int = 0,
    threshold_const: float = 8.0,
    relax_fn=None,
) -> Decomposition:
    """Paper Algorithm 1. ``variant`` in {"stop", "complete"} (Table 2).

    ``relax_fn``: optional override of the jitted growth loop — the
    distributed engine passes its shard_map variant here.
    """
    n = edges.n_nodes
    logn = max(math.log(max(n, 2)), 1.0)
    threshold = max(int(threshold_const * tau * logn), 1)
    num_it = jnp.int32(max_steps_per_phase or max(2 * n // max(tau, 1), 8))

    src = jnp.asarray(edges.src)
    dst = jnp.asarray(edges.dst)
    w = jnp.asarray(edges.weight)

    grow = relax_fn or (
        lambda st, delta, half, var: partial_growth(
            st, src, dst, w, jnp.int32(delta), jnp.int32(half), num_it, n, variant=var
        )
    )

    rng = np.random.default_rng(seed)
    state = init_state(n)
    delta = _initial_delta(edges, delta_init)
    max_delta = int(min(np.int64(edges.weight.astype(np.int64).sum()) + 1, 2**30))
    total_steps = 0
    stage = 0

    while stage < max_stages:
        u_count = int(uncovered_count(state))
        if u_count < threshold:
            break
        p = min(1.0, gamma * tau * logn / u_count)
        coin = rng.random(n) < p
        eligible = np.asarray((~state.covered) & (~state.is_center))
        new_centers = jnp.asarray(coin & eligible)
        n_new = int(new_centers.sum())
        if n_new == 0:  # resample cheaply rather than wasting a stage
            continue
        state = promote_centers(state, new_centers)
        state = reset_in_stage(state)

        # goal: half of the stage's uncovered set, counting the nodes that
        # just became centers (paper counts them inside V').
        half_target = max((u_count + 1) // 2 - n_new, 0)

        doublings = 0
        while True:
            state, stats = grow(state, delta, half_target, variant)
            total_steps += int(stats.steps)
            if int(stats.reached) >= half_target:
                break
            if delta >= max_delta:
                log.warning("delta saturated at %d; covering what we reached", delta)
                break
            delta = min(delta * 2, max_delta)
            doublings += 1

        state = cover(state, jnp.int32(delta))
        stage += 1
        log.info(
            "stage %d: centers+%d delta=%d steps=%d uncovered %d -> %d",
            stage, n_new, delta, int(stats.steps), u_count, int(uncovered_count(state)),
        )

    state = finalize_singletons(state)

    final_c = np.asarray(state.final_c)
    final_pathw = np.asarray(state.final_pathw)
    assert (final_pathw < np.int32(INF)).all(), "uncovered node escaped finalization"
    return Decomposition(
        n_nodes=n,
        final_c=final_c,
        final_pathw=final_pathw,
        radius=int(final_pathw.max()) if n else 0,
        delta_end=delta,
        n_clusters=int(len(np.unique(final_c))),
        n_stages=stage,
        growing_steps=total_steps,
    )


def cluster2(
    edges: EdgeList,
    tau: int,
    gamma: float = 2.0,
    seed: int = 0,
    delta_init: str = "avg",
    base: Optional[Decomposition] = None,
    relax_fn=None,
) -> Decomposition:
    """CLUSTER2(G, tau) — paper Algorithm 2.

    First runs CLUSTER to obtain R_CL(tau); then re-clusters from scratch
    with fixed growth budget Delta = 2 R_CL(tau) and center-selection
    probability doubling each stage (last stage selects everything left).
    Growth runs to quiescence each stage (PartialGrowth2).
    """
    n = edges.n_nodes
    if base is None:
        base = cluster(edges, tau, gamma=gamma, seed=seed, delta_init=delta_init,
                       relax_fn=relax_fn)
    delta = max(2 * base.radius, 2)

    src = jnp.asarray(edges.src)
    dst = jnp.asarray(edges.dst)
    w = jnp.asarray(edges.weight)
    num_it = jnp.int32(4 * n)

    grow = relax_fn or (
        lambda st, dl, half, var: partial_growth(
            st, src, dst, w, jnp.int32(dl), jnp.int32(half), num_it, n, variant=var
        )
    )

    rng = np.random.default_rng(seed + 1)
    state = init_state(n)
    total_steps = 0
    stages = int(math.ceil(math.log2(max(n, 2)))) + 1
    stage_count = 0
    for i in range(1, stages + 1):
        u_count = int(uncovered_count(state))
        if u_count == 0:
            break
        p = 1.0 if i == stages else min(1.0, (2.0**i) / n)
        coin = rng.random(n) < p
        eligible = np.asarray((~state.covered) & (~state.is_center))
        new_centers = jnp.asarray(coin & eligible)
        if int(new_centers.sum()) == 0:
            continue
        state = promote_centers(state, new_centers)
        state = reset_in_stage(state)
        # PartialGrowth2: run to quiescence under the fixed budget
        state, stats = grow(state, delta, 0, "complete")
        total_steps += int(stats.steps)
        state = cover(state, jnp.int32(delta))
        stage_count += 1

    state = finalize_singletons(state)
    final_c = np.asarray(state.final_c)
    final_pathw = np.asarray(state.final_pathw)
    return Decomposition(
        n_nodes=n,
        final_c=final_c,
        final_pathw=final_pathw,
        radius=int(final_pathw.max()) if n else 0,
        delta_end=delta,
        n_clusters=int(len(np.unique(final_c))),
        n_stages=stage_count,
        growing_steps=total_steps,
    )
