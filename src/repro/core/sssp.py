"""SSSP baselines (paper Section 5 competitor + Table 1 lower bounds).

- ``bellman_ford``: the natural parallel Bellman-Ford (SSSP-BF). Each
  superstep relaxes every edge; the superstep count is the competitor's
  round complexity in the MR model (the quantity CLUSTER beats).
- ``delta_stepping``: Meyer & Sanders bucketed SSSP. The paper notes that on
  a round-driven platform the best setting degenerates to Delta = inf ==
  Bellman-Ford; we implement real buckets anyway for completeness.
- ``batched_bf_loop`` / ``multi_source_bellman_ford``: frontier Bellman-Ford
  ``vmap``ped over a batch of sources — the device-local quotient solve
  (``core/quotient.py``) runs this over ALL quotient nodes in one program.
- ``diameter_2approx_sssp``: 2-approximation from a random source.
- ``farthest_point_lower_bound``: repeated SSSP hopping to the farthest node
  (how the paper computes the Phi column of Table 1).

Distance dtype is picked from a provable bound (``sssp_dtype_for``): every
shortest path has < n edges, so when ``n * max_weight`` fits int32 the
loops run in int32; otherwise they run in int64 under ``enable_x64``
(legal edge weights go up to 2^30 - 1, which overflows int32 after a
handful of hops — the old int32-only loops silently wrapped negative and
reported false minima). ``SSSPResult.inf`` carries the unreached sentinel
of the chosen dtype so callers mask with the right value.

Disconnected inputs: every estimator surfaces a ``connected`` flag
(consistent with ``DiameterEstimate.connected``) instead of silently
bounding only finite-distance pairs. Empty graphs (``n_nodes == 0``) get
the degenerate estimate (diameter 0, ``connected=True`` — the same
``n_nodes <= 1`` convention as ``DiameterEstimate``) instead of a crash.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import guard
from repro.graph.structures import EdgeList

INF = jnp.int32(2**31 - 1)
INF64 = 2**62  # int64 unreached sentinel; guarded adds stay < 2^63


def sssp_dtype_for(n_nodes: int, max_weight: int, delta: int = 0):
    """(dtype, inf) from the provable distance bound: every shortest path
    has < n edges, so distances are < n * max_weight. int32 fast path when
    that fits, int64 (under enable_x64) otherwise.

    ``delta``: headroom for Δ-stepping's bucket bound ``(b + 1) * delta``
    — it can exceed the largest distance by up to one bucket, so bucketed
    callers must pass their delta or the int32 fast path could wrap the
    bound negative and stall the bucket walk."""
    if n_nodes * max(int(max_weight), 1) + int(delta) < 2**31 - 1:
        return jnp.int32, 2**31 - 1
    return jnp.int64, INF64


@dataclass
class SSSPResult:
    dist: np.ndarray
    supersteps: int
    inf: int = int(2**31 - 1)  # unreached sentinel of dist's dtype


@dataclass
class MultiSSSPResult:
    dist: np.ndarray  # [S, n]
    supersteps: int
    connected: bool   # every source reaches every node


@partial(jax.jit, static_argnames=("n_nodes",))
def _bf_loop(src, dst, w, d0, inf, n_nodes: int):
    """Dtype-generic frontier Bellman-Ford; ``inf`` is the unreached
    sentinel in d0's dtype. Overflow safety comes from the caller's dtype
    pick (``sssp_dtype_for``): admitted ``ds < inf`` are real path sums
    < n * max_weight, so the guarded add ``ds + w`` provably fits — int64
    additionally keeps ``inf`` below dtype_max / 2."""
    def cond(carry):
        _, changed, _ = carry
        return changed

    def body(carry):
        d, _, k = carry
        ds = d[src]
        ok = ds < inf
        cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, inf)
        dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
        upd = dmin < d
        return jnp.where(upd, dmin, d), jnp.any(upd), k + 1

    d, _, k = jax.lax.while_loop(cond, body, (d0, jnp.bool_(True), jnp.int32(0)))
    return d, k


def _edge_arrays(edges: EdgeList, dtype):
    return (jnp.asarray(edges.src), jnp.asarray(edges.dst),
            jnp.asarray(edges.weight).astype(dtype))


def bellman_ford(edges: EdgeList, source: int) -> SSSPResult:
    from jax.experimental import enable_x64

    n = edges.n_nodes
    wmax = int(edges.weight.max()) if edges.n_edges else 1
    dtype, inf = sssp_dtype_for(n, wmax)
    with enable_x64():
        infj = jnp.asarray(inf, dtype)
        d0 = jnp.full(n, infj, dtype=dtype).at[source].set(0)
        d, k = _bf_loop(*_edge_arrays(edges, dtype), d0, infj, n)
        dist = guard.fetch(d, reason="sssp baseline: distance plane")
        k = int(guard.fetch(k, reason="sssp baseline: superstep counter"))
    return SSSPResult(dist=dist, supersteps=k, inf=inf)


@partial(jax.jit, static_argnames=("n_nodes",))
def batched_bf_loop(src, dst, w, d0, inf, n_nodes: int):
    """Frontier Bellman-Ford over a batch of sources at once.

    ``d0`` is [n_nodes, S] — NODES ALONG AXIS 0, so each superstep is one
    contiguous row-gather ``d[src]`` plus one ND ``segment_min`` (row-wise
    scatter), which XLA vectorizes ~5x better than a vmap of per-source
    scalar scatters. ``inf`` is the unreached sentinel in d0's dtype
    (int64-safe: callers trace this under ``jax.experimental.enable_x64``
    with ``inf < dtype_max / 2`` so the guarded add never overflows).
    Padding edges are expressed as ``w >= inf`` and never relax. The loop
    runs until no distance changes anywhere in the batch. Returns
    (dist [n_nodes, S], supersteps).
    """
    w_ok = w < inf

    def cond(carry):
        _, changed, _ = carry
        return changed

    def body(carry):
        d, _, k = carry
        du = d[src, :]                                   # [E, S]
        ok = (du < inf) & w_ok[:, None]
        cand = jnp.where(ok, jnp.where(ok, du, 0) + w[:, None], inf)
        dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
        dnew = jnp.minimum(d, dmin)
        return dnew, jnp.any(dnew < d), k + 1

    d, _, k = jax.lax.while_loop(
        cond, body, (d0, jnp.bool_(True), jnp.int32(0)))
    return d, k


def multi_source_bellman_ford(edges: EdgeList, sources) -> MultiSSSPResult:
    """All-sources-at-once SSSP (one compiled program, one host sync).

    Distance dtype is picked by ``sssp_dtype_for`` from the same provable
    bound as the single-source loops.
    """
    from jax.experimental import enable_x64

    n = edges.n_nodes
    sources = np.asarray(sources, dtype=np.int32)
    wmax = int(edges.weight.max()) if edges.n_edges else 1
    dtype, inf = sssp_dtype_for(n, wmax)
    with enable_x64():
        infj = jnp.asarray(inf, dtype)
        d0 = jnp.full((n, len(sources)), infj, dtype=dtype)
        d0 = d0.at[jnp.asarray(sources), jnp.arange(len(sources))].set(0)
        d, k = batched_bf_loop(
            jnp.asarray(edges.src), jnp.asarray(edges.dst),
            jnp.asarray(edges.weight).astype(dtype), d0, infj, n)
        # public contract stays [S, n]
        dist = guard.fetch(d, reason="multi-sssp: distance planes").T
        k = int(guard.fetch(k, reason="multi-sssp: superstep counter"))
    return MultiSSSPResult(dist=dist, supersteps=k,
                           connected=bool((dist < inf).all()))


@partial(jax.jit, static_argnames=("n_nodes",))
def _delta_stepping_loop(src, dst, w, d0, delta, inf, n_nodes: int):
    """Dtype-generic bucketed SSSP. ``delta`` must be in d0's dtype, and
    the caller must have picked the dtype with delta headroom
    (``sssp_dtype_for(n, wmax, delta)``) so the bucket bound
    ``(b + 1) * delta`` — which can exceed the largest distance by one
    bucket — never overflows.

    Superstep accounting: each inner light-relax iteration is one
    superstep; the per-bucket heavy pass counts ONE superstep only when the
    settled bucket actually has an admissible heavy relaxation — a bucket
    with no heavy edges costs no round on a round-driven platform, and
    counting it inflated the competitor's Table-3 rounds.
    """
    light = w < delta
    one = jnp.asarray(1, d0.dtype)
    zero = jnp.asarray(0, d0.dtype)

    def outer_cond(carry):
        d, b, k = carry
        # any unsettled node in a future bucket?
        return jnp.any((d < inf) & (d >= b * delta)) & (k < jnp.int32(2**30))

    def outer_body(carry):
        d, b, k = carry
        lo, hi = b * delta, (b + one) * delta

        def inner_cond(c):
            _, changed, _ = c
            return changed

        def inner_body(c):
            d_, _, k_ = c
            in_bucket = (d_ >= lo) & (d_ < hi)
            # light-edge relaxations from the current bucket
            ds = d_[src]
            ok = (ds < inf) & in_bucket[src] & light
            cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, inf)
            dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
            upd = dmin < d_
            return jnp.where(upd, dmin, d_), jnp.any(upd), k_ + 1

        d, _, k = jax.lax.while_loop(inner_cond, inner_body, (d, jnp.bool_(True), k))
        # one heavy pass for the settled bucket — a superstep only if any
        # heavy relaxation is admissible from this bucket
        in_bucket = (d >= lo) & (d < hi)
        ds = d[src]
        ok = (ds < inf) & in_bucket[src] & ~light
        cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, inf)
        dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
        d = jnp.where(dmin < d, dmin, d)
        k = k + jnp.any(ok).astype(jnp.int32)
        # jump straight to the next non-empty bucket: crawling b+1 burns a
        # full inner-loop superstep per EMPTY bucket, pathological when
        # weights are large relative to delta (road graphs)
        ahead = (d >= hi) & (d < inf)
        d_next = jnp.min(jnp.where(ahead, d, inf))
        b = jnp.where(jnp.any(ahead), d_next // delta, b + one)
        return d, b, k

    d, b, k = jax.lax.while_loop(
        outer_cond, outer_body, (d0, zero, jnp.int32(0)))
    return d, k


def delta_stepping(edges: EdgeList, source: int, delta: int) -> SSSPResult:
    from jax.experimental import enable_x64

    n = edges.n_nodes
    wmax = int(edges.weight.max()) if edges.n_edges else 1
    dtype, inf = sssp_dtype_for(n, wmax, delta)
    with enable_x64():
        infj = jnp.asarray(inf, dtype)
        d0 = jnp.full(n, infj, dtype=dtype).at[source].set(0)
        d, k = _delta_stepping_loop(
            *_edge_arrays(edges, dtype), d0, jnp.asarray(delta, dtype),
            infj, n,
        )
        dist = guard.fetch(d, reason="delta-stepping: distance plane")
        k = int(guard.fetch(k, reason="delta-stepping: superstep counter"))
    return SSSPResult(dist=dist, supersteps=k, inf=inf)


def diameter_2approx_sssp(edges: EdgeList, seed: int = 0) -> Tuple[int, int, int, bool]:
    """(lower_bound, upper_bound, supersteps, connected) from one
    random-source SSSP. On a disconnected input the bounds only cover the
    source's component — ``connected=False`` flags that (consistent with
    ``DiameterEstimate.connected``; the true diameter is infinite).
    An empty graph returns the degenerate (0, 0, 0, True) — the same
    ``n_nodes <= 1`` convention as ``DiameterEstimate.connected``."""
    if edges.n_nodes == 0:
        return 0, 0, 0, True
    rng = np.random.default_rng(seed)
    s = int(rng.integers(edges.n_nodes))
    res = bellman_ford(edges, s)
    reached = res.dist < res.inf
    ecc = int(res.dist[reached].max())
    return ecc, 2 * ecc, res.supersteps, bool(reached.all())


def farthest_point_lower_bound(edges: EdgeList, rounds: int = 4, seed: int = 0) -> Tuple[int, bool]:
    """Paper Table 1's Phi column: repeated SSSP hopping to the farthest
    node. Returns (lower_bound, connected); on a disconnected input the
    bound only covers components the hops visited. An empty graph returns
    the degenerate (0, True)."""
    if edges.n_nodes == 0:
        return 0, True
    rng = np.random.default_rng(seed)
    s = int(rng.integers(edges.n_nodes))
    best = 0
    connected = True
    for _ in range(rounds):
        res = bellman_ford(edges, s)
        connected = connected and bool((res.dist < res.inf).all())
        dist = np.where(res.dist < res.inf, res.dist, -1)
        far = int(dist.argmax())
        best = max(best, int(dist.max()))
        if far == s:
            break
        s = far
    return best, connected
