"""SSSP baselines (paper Section 5 competitor + Table 1 lower bounds).

- ``bellman_ford``: the natural parallel Bellman-Ford (SSSP-BF). Each
  superstep relaxes every edge; the superstep count is the competitor's
  round complexity in the MR model (the quantity CLUSTER beats).
- ``delta_stepping``: Meyer & Sanders bucketed SSSP. The paper notes that on
  a round-driven platform the best setting degenerates to Delta = inf ==
  Bellman-Ford; we implement real buckets anyway for completeness.
- ``diameter_2approx_sssp``: 2-approximation from a random source.
- ``farthest_point_lower_bound``: repeated SSSP hopping to the farthest node
  (how the paper computes the Phi column of Table 1).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structures import EdgeList

INF = jnp.int32(2**31 - 1)


@dataclass
class SSSPResult:
    dist: np.ndarray
    supersteps: int


@partial(jax.jit, static_argnames=("n_nodes",))
def _bf_loop(src, dst, w, d0, n_nodes: int):
    def cond(carry):
        _, changed, _ = carry
        return changed

    def body(carry):
        d, _, k = carry
        ds = d[src]
        ok = ds < INF
        cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, INF)
        dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
        upd = dmin < d
        return jnp.where(upd, dmin, d), jnp.any(upd), k + 1

    d, _, k = jax.lax.while_loop(cond, body, (d0, jnp.bool_(True), jnp.int32(0)))
    return d, k


def bellman_ford(edges: EdgeList, source: int) -> SSSPResult:
    n = edges.n_nodes
    d0 = jnp.full(n, INF, dtype=jnp.int32).at[source].set(0)
    d, k = _bf_loop(jnp.asarray(edges.src), jnp.asarray(edges.dst), jnp.asarray(edges.weight), d0, n)
    return SSSPResult(dist=np.asarray(d), supersteps=int(k))


@partial(jax.jit, static_argnames=("n_nodes",))
def _delta_stepping_loop(src, dst, w, d0, delta, n_nodes: int):
    light = w < delta

    def relax(d, mask_src):
        ds = d[src]
        ok = (ds < INF) & mask_src[src]
        cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, INF)
        dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
        upd = dmin < d
        return jnp.where(upd, dmin, d), jnp.any(upd)

    def outer_cond(carry):
        d, b, k = carry
        # any unsettled node in a future bucket?
        return jnp.any((d < INF) & (d >= b * delta)) & (k < jnp.int32(2**30))

    def outer_body(carry):
        d, b, k = carry
        lo, hi = b * delta, (b + 1) * delta

        def inner_cond(c):
            _, changed, _ = c
            return changed

        def inner_body(c):
            d_, _, k_ = c
            in_bucket = (d_ >= lo) & (d_ < hi)
            # light-edge relaxations from the current bucket
            ds = d_[src]
            ok = (ds < INF) & in_bucket[src] & light
            cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, INF)
            dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
            upd = dmin < d_
            return jnp.where(upd, dmin, d_), jnp.any(upd), k_ + 1

        d, _, k = jax.lax.while_loop(inner_cond, inner_body, (d, jnp.bool_(True), k))
        # one heavy pass for the settled bucket
        in_bucket = (d >= lo) & (d < hi)
        ds = d[src]
        ok = (ds < INF) & in_bucket[src] & ~light
        cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, INF)
        dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
        d = jnp.where(dmin < d, dmin, d)
        return d, b + 1, k + 1

    d, b, k = jax.lax.while_loop(outer_cond, outer_body, (d0, jnp.int32(0), jnp.int32(0)))
    return d, k


def delta_stepping(edges: EdgeList, source: int, delta: int) -> SSSPResult:
    n = edges.n_nodes
    d0 = jnp.full(n, INF, dtype=jnp.int32).at[source].set(0)
    d, k = _delta_stepping_loop(
        jnp.asarray(edges.src), jnp.asarray(edges.dst), jnp.asarray(edges.weight),
        d0, jnp.int32(delta), n,
    )
    return SSSPResult(dist=np.asarray(d), supersteps=int(k))


def diameter_2approx_sssp(edges: EdgeList, seed: int = 0) -> Tuple[int, int, int]:
    """(lower_bound, upper_bound, supersteps) from one random-source SSSP."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(edges.n_nodes))
    res = bellman_ford(edges, s)
    finite = res.dist[res.dist < np.int32(INF)]
    ecc = int(finite.max())
    return ecc, 2 * ecc, res.supersteps


def farthest_point_lower_bound(edges: EdgeList, rounds: int = 4, seed: int = 0) -> int:
    """Paper Table 1's Phi column: repeated SSSP from the farthest node."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(edges.n_nodes))
    best = 0
    for _ in range(rounds):
        res = bellman_ford(edges, s)
        dist = np.where(res.dist < np.int32(INF), res.dist, -1)
        far = int(dist.argmax())
        best = max(best, int(dist.max()))
        if far == s:
            break
        s = far
    return best
