"""SSSP baselines (paper Section 5 competitor + Table 1 lower bounds).

- ``bellman_ford``: the natural parallel Bellman-Ford (SSSP-BF). Each
  superstep relaxes every edge; the superstep count is the competitor's
  round complexity in the MR model (the quantity CLUSTER beats).
- ``delta_stepping``: Meyer & Sanders bucketed SSSP. The paper notes that on
  a round-driven platform the best setting degenerates to Delta = inf ==
  Bellman-Ford; we implement real buckets anyway for completeness.
- ``batched_bf_loop`` / ``multi_source_bellman_ford``: frontier Bellman-Ford
  ``vmap``ped over a batch of sources — the device-local quotient solve
  (``core/quotient.py``) runs this over ALL quotient nodes in one program.
- ``diameter_2approx_sssp``: 2-approximation from a random source.
- ``farthest_point_lower_bound``: repeated SSSP hopping to the farthest node
  (how the paper computes the Phi column of Table 1).

Disconnected inputs: every estimator surfaces a ``connected`` flag
(consistent with ``DiameterEstimate.connected``) instead of silently
bounding only finite-distance pairs.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structures import EdgeList

INF = jnp.int32(2**31 - 1)


@dataclass
class SSSPResult:
    dist: np.ndarray
    supersteps: int


@dataclass
class MultiSSSPResult:
    dist: np.ndarray  # [S, n]
    supersteps: int
    connected: bool   # every source reaches every node


@partial(jax.jit, static_argnames=("n_nodes",))
def _bf_loop(src, dst, w, d0, n_nodes: int):
    def cond(carry):
        _, changed, _ = carry
        return changed

    def body(carry):
        d, _, k = carry
        ds = d[src]
        ok = ds < INF
        cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, INF)
        dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
        upd = dmin < d
        return jnp.where(upd, dmin, d), jnp.any(upd), k + 1

    d, _, k = jax.lax.while_loop(cond, body, (d0, jnp.bool_(True), jnp.int32(0)))
    return d, k


def bellman_ford(edges: EdgeList, source: int) -> SSSPResult:
    n = edges.n_nodes
    d0 = jnp.full(n, INF, dtype=jnp.int32).at[source].set(0)
    d, k = _bf_loop(jnp.asarray(edges.src), jnp.asarray(edges.dst), jnp.asarray(edges.weight), d0, n)
    return SSSPResult(dist=np.asarray(d), supersteps=int(k))


@partial(jax.jit, static_argnames=("n_nodes",))
def batched_bf_loop(src, dst, w, d0, inf, n_nodes: int):
    """Frontier Bellman-Ford over a batch of sources at once.

    ``d0`` is [n_nodes, S] — NODES ALONG AXIS 0, so each superstep is one
    contiguous row-gather ``d[src]`` plus one ND ``segment_min`` (row-wise
    scatter), which XLA vectorizes ~5x better than a vmap of per-source
    scalar scatters. ``inf`` is the unreached sentinel in d0's dtype
    (int64-safe: callers trace this under ``jax.experimental.enable_x64``
    with ``inf < dtype_max / 2`` so the guarded add never overflows).
    Padding edges are expressed as ``w >= inf`` and never relax. The loop
    runs until no distance changes anywhere in the batch. Returns
    (dist [n_nodes, S], supersteps).
    """
    w_ok = w < inf

    def cond(carry):
        _, changed, _ = carry
        return changed

    def body(carry):
        d, _, k = carry
        du = d[src, :]                                   # [E, S]
        ok = (du < inf) & w_ok[:, None]
        cand = jnp.where(ok, jnp.where(ok, du, 0) + w[:, None], inf)
        dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
        dnew = jnp.minimum(d, dmin)
        return dnew, jnp.any(dnew < d), k + 1

    d, _, k = jax.lax.while_loop(
        cond, body, (d0, jnp.bool_(True), jnp.int32(0)))
    return d, k


def multi_source_bellman_ford(edges: EdgeList, sources) -> MultiSSSPResult:
    """All-sources-at-once SSSP (one compiled program, one host sync).

    Distance dtype is picked from a provable bound: every shortest path has
    < n edges, so when ``n * max_weight`` fits int32 the solve runs in
    int32; otherwise it runs int64 under enable_x64 (legal edge weights go
    up to 2^30 - 1, which overflows int32 after a handful of hops).
    """
    from jax.experimental import enable_x64

    n = edges.n_nodes
    sources = np.asarray(sources, dtype=np.int32)
    wmax = int(edges.weight.max()) if edges.n_edges else 1
    int32_safe = n * max(wmax, 1) < 2**31 - 1
    dtype, inf = (jnp.int32, 2**31 - 1) if int32_safe else (jnp.int64, 2**62)
    with enable_x64():
        inf = jnp.asarray(inf, dtype)
        d0 = jnp.full((n, len(sources)), inf, dtype=dtype)
        d0 = d0.at[jnp.asarray(sources), jnp.arange(len(sources))].set(0)
        d, k = batched_bf_loop(
            jnp.asarray(edges.src), jnp.asarray(edges.dst),
            jnp.asarray(edges.weight).astype(dtype), d0, inf, n)
        dist = np.asarray(d).T  # public contract stays [S, n]
    return MultiSSSPResult(dist=dist, supersteps=int(k),
                           connected=bool((dist < int(inf)).all()))


@partial(jax.jit, static_argnames=("n_nodes",))
def _delta_stepping_loop(src, dst, w, d0, delta, n_nodes: int):
    light = w < delta

    def relax(d, mask_src):
        ds = d[src]
        ok = (ds < INF) & mask_src[src]
        cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, INF)
        dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
        upd = dmin < d
        return jnp.where(upd, dmin, d), jnp.any(upd)

    def outer_cond(carry):
        d, b, k = carry
        # any unsettled node in a future bucket?
        return jnp.any((d < INF) & (d >= b * delta)) & (k < jnp.int32(2**30))

    def outer_body(carry):
        d, b, k = carry
        lo, hi = b * delta, (b + 1) * delta

        def inner_cond(c):
            _, changed, _ = c
            return changed

        def inner_body(c):
            d_, _, k_ = c
            in_bucket = (d_ >= lo) & (d_ < hi)
            # light-edge relaxations from the current bucket
            ds = d_[src]
            ok = (ds < INF) & in_bucket[src] & light
            cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, INF)
            dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
            upd = dmin < d_
            return jnp.where(upd, dmin, d_), jnp.any(upd), k_ + 1

        d, _, k = jax.lax.while_loop(inner_cond, inner_body, (d, jnp.bool_(True), k))
        # one heavy pass for the settled bucket
        in_bucket = (d >= lo) & (d < hi)
        ds = d[src]
        ok = (ds < INF) & in_bucket[src] & ~light
        cand = jnp.where(ok, jnp.where(ok, ds, 0) + w, INF)
        dmin = jax.ops.segment_min(cand, dst, num_segments=n_nodes)
        d = jnp.where(dmin < d, dmin, d)
        # jump straight to the next non-empty bucket: crawling b+1 burns a
        # full inner-loop superstep per EMPTY bucket, pathological when
        # weights are large relative to delta (road graphs)
        ahead = (d >= hi) & (d < INF)
        d_next = jnp.min(jnp.where(ahead, d, INF))
        b = jnp.where(jnp.any(ahead), d_next // delta, b + 1)
        return d, b, k + 1

    d, b, k = jax.lax.while_loop(outer_cond, outer_body, (d0, jnp.int32(0), jnp.int32(0)))
    return d, k


def delta_stepping(edges: EdgeList, source: int, delta: int) -> SSSPResult:
    n = edges.n_nodes
    d0 = jnp.full(n, INF, dtype=jnp.int32).at[source].set(0)
    d, k = _delta_stepping_loop(
        jnp.asarray(edges.src), jnp.asarray(edges.dst), jnp.asarray(edges.weight),
        d0, jnp.int32(delta), n,
    )
    return SSSPResult(dist=np.asarray(d), supersteps=int(k))


def diameter_2approx_sssp(edges: EdgeList, seed: int = 0) -> Tuple[int, int, int, bool]:
    """(lower_bound, upper_bound, supersteps, connected) from one
    random-source SSSP. On a disconnected input the bounds only cover the
    source's component — ``connected=False`` flags that (consistent with
    ``DiameterEstimate.connected``; the true diameter is infinite)."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(edges.n_nodes))
    res = bellman_ford(edges, s)
    reached = res.dist < np.int32(INF)
    ecc = int(res.dist[reached].max())
    return ecc, 2 * ecc, res.supersteps, bool(reached.all())


def farthest_point_lower_bound(edges: EdgeList, rounds: int = 4, seed: int = 0) -> Tuple[int, bool]:
    """Paper Table 1's Phi column: repeated SSSP hopping to the farthest
    node. Returns (lower_bound, connected); on a disconnected input the
    bound only covers components the hops visited."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(edges.n_nodes))
    best = 0
    connected = True
    for _ in range(rounds):
        res = bellman_ford(edges, s)
        connected = connected and bool((res.dist < np.int32(INF)).all())
        dist = np.where(res.dist < np.int32(INF), res.dist, -1)
        far = int(dist.argmax())
        best = max(best, int(dist.max()))
        if far == s:
            break
        s = far
    return best, connected
