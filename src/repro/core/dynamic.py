"""Dynamic-graph subsystem: incremental updates on resident sessions.

The decomposition pipeline assumes a frozen graph — before this module, any
edge change forced a cold ``open_session()`` rebuild (re-upload, re-pack,
full re-decomposition). But low-diameter decompositions are repairable from
approximate distance information alone (Becker–Emek–Lenzen), and the only
state the diameter bound actually depends on is the set of certified
cluster radii (Ceccarello et al.): each node v carries ``final_pathw[v]`` =
the weight of a REAL path from its center, and the quotient edge weights
are built from those certificates. So updates can be absorbed by bounded
incremental relaxation on the already-resident device buffers:

  * **insertions / weight decreases** — distances only shrink, so every
    existing certificate stays valid; the new edges seed a dirty frontier
    and a monotone tightening relax (``backend.grow``, the PR 1 engine's
    own jitted program, ``complete`` variant) propagates the improvements.
    Every PREFIX of the monotone relax is certified, so ``tighten_cap``
    bounds its supersteps without giving anything up.
  * **deletions / weight increases** — a certificate may now reference a
    path that no longer exists. One edge sweep rebuilds the WITNESS FOREST
    (``_forest_repair``): each non-center picks an in-cluster parent with
    strictly smaller old ``pathw`` — acyclic, rooted at the ``pathw = 0``
    centers — minimizing ``pathw[u] + w`` under the current weights, and
    pointer doubling (O(log n) node-local rounds, no edge traffic)
    re-derives every certificate along the forest: weight increases
    inflate exactly the affected subtrees, with no invalidation fixpoint
    and no kill cascade. Nodes whose chain fails to root (descent edge
    deleted, no alternative) are DEAD: a confined regrow re-attaches them
    from the alive boundary through the same engine relax, and anything
    still unreached becomes a singleton cluster (Alg. 1's own treatment of
    uncovered nodes — which is what keeps disconnecting deletions
    certified). When the retracted fraction exceeds
    ``session.rebuild_fraction`` the session falls back to a full
    re-decomposition (fresh center sampling).

Dirty tracking is node-granular on purpose: cluster-granular marking would
be unsound — with the "stop" variant a node's realized path may thread
through nodes whose FINAL cluster differs (mid-stage reassignment races,
~20% of nodes on RMAT graphs) — so ``ensure_dynamic`` recertifies the
initial decomposition through the forest once at dynamic-mode entry (and
after every full rebuild), after which every certificate is witnessed by
an in-cluster parent edge and dead sets stay proportional to the update.

The quotient is refreshed incrementally (``core/quotient.py::
quotient_update_device``): only (cluster, cluster) keys touching dirty
clusters are recomputed — the PR 2 kernel runs over just the dirty-incident
edge slice and the result is merged with the cached quotient's clean
entries — so every post-update ``estimate()`` still returns a certified
``[lower, upper]`` bracket at a cost proportional to the touched region.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import guard
from repro.common import Timer, get_logger, next_multiple
from repro.core.engine import Decomposition
from repro.core.state import EngineState, INF
from repro.graph.segment_ops import segment_min_triple
from repro.graph.storage import EdgeStore, GraphStore
from repro.graph.structures import MAX_WEIGHT
from repro.runtime import telemetry

log = get_logger("repro.dynamic")

# delta ceiling for repair relaxation: matches the engine's max_delta clip
# (2^30) so candidate adds provably stay inside int32
_REPAIR_DELTA = 2**30
# while_loop iteration cap for repair relaxation (fixpoint detection exits
# far earlier; this only guards against adversarial cycles)
_REPAIR_NUM_IT = 2**30
# default superstep cap for the insert/decrease tightening relax — every
# prefix of the monotone relax is certified, so the cap trades tightness
# (picked back up by later batches) for bounded update cost
DEFAULT_TIGHTEN_CAP = 8
# dirty-incident quotient slices are padded to a multiple of this so the
# incremental-refresh programs recompile once per size bucket
DIRTY_EDGE_BUCKET = 256


def _i32(x) -> np.ndarray:
    a = np.asarray(x, dtype=np.int32).reshape(-1)
    return a


@dataclass
class UpdateBatch:
    """One batch of edge mutations, in DIRECTED triples.

    Semantics against the resident graph (which keeps at most one slot per
    directed key, min-coalesced — the ``EdgeList.coalesce`` contract):

      * insert (u, v, w): new key -> edge added; existing key -> the slot
        keeps ``min(old, w)`` (inserting a heavier parallel edge is a no-op,
        exactly like coalescing a multigraph).
      * reweight (u, v, w): SETS the weight (increase or decrease); the key
        must exist.
      * delete (u, v): removes the key; it must exist.

    Undirected graphs store both directions — build batches with
    ``symmetric=True`` (the default of the constructors) to emit both.
    """

    insert_src: np.ndarray = field(default_factory=lambda: _i32([]))
    insert_dst: np.ndarray = field(default_factory=lambda: _i32([]))
    insert_weight: np.ndarray = field(default_factory=lambda: _i32([]))
    reweight_src: np.ndarray = field(default_factory=lambda: _i32([]))
    reweight_dst: np.ndarray = field(default_factory=lambda: _i32([]))
    reweight_weight: np.ndarray = field(default_factory=lambda: _i32([]))
    delete_src: np.ndarray = field(default_factory=lambda: _i32([]))
    delete_dst: np.ndarray = field(default_factory=lambda: _i32([]))

    def __post_init__(self):
        for name in ("insert_src", "insert_dst", "insert_weight",
                     "reweight_src", "reweight_dst", "reweight_weight",
                     "delete_src", "delete_dst"):
            setattr(self, name, _i32(getattr(self, name)))
        if not (len(self.insert_src) == len(self.insert_dst)
                == len(self.insert_weight)):
            raise ValueError("insert arrays length mismatch")
        if not (len(self.reweight_src) == len(self.reweight_dst)
                == len(self.reweight_weight)):
            raise ValueError("reweight arrays length mismatch")
        if len(self.delete_src) != len(self.delete_dst):
            raise ValueError("delete arrays length mismatch")
        for w in (self.insert_weight, self.reweight_weight):
            if len(w) and (w.min() < 1 or w.max() > int(MAX_WEIGHT)):
                raise ValueError("update weights must be in [1, 2^30)")

    @property
    def n_events(self) -> int:
        return (len(self.insert_src) + len(self.reweight_src)
                + len(self.delete_src))

    @staticmethod
    def _sym(u, v, w=None):
        u, v = _i32(u), _i32(v)
        uu = np.concatenate([u, v])
        vv = np.concatenate([v, u])
        if w is None:
            return uu, vv
        w = _i32(w)
        return uu, vv, np.concatenate([w, w])

    @classmethod
    def inserts(cls, u, v, w, *, symmetric: bool = True) -> "UpdateBatch":
        if symmetric:
            u, v, w = cls._sym(u, v, w)
        return cls(insert_src=u, insert_dst=v, insert_weight=w)

    @classmethod
    def reweights(cls, u, v, w, *, symmetric: bool = True) -> "UpdateBatch":
        if symmetric:
            u, v, w = cls._sym(u, v, w)
        return cls(reweight_src=u, reweight_dst=v, reweight_weight=w)

    @classmethod
    def deletes(cls, u, v, *, symmetric: bool = True) -> "UpdateBatch":
        if symmetric:
            u, v = cls._sym(u, v)
        return cls(delete_src=u, delete_dst=v)

    @staticmethod
    def merge(batches) -> "UpdateBatch":
        """Concatenate several batches into one (applied in order)."""
        batches = list(batches)
        kw = {}
        for f in dataclasses.fields(UpdateBatch):
            kw[f.name] = np.concatenate(
                [getattr(b, f.name) for b in batches]) if batches else _i32([])
        return UpdateBatch(**kw)


@dataclass
class DynamicMetrics:
    """Amortized-cost accounting across a session's whole update stream."""

    batches: int = 0
    inserts: int = 0          # effective new keys
    decreases: int = 0        # weight shrank (incl. insert-on-existing)
    increases: int = 0        # weight grew
    deletes: int = 0
    noop_events: int = 0      # e.g. inserting a heavier parallel edge
    relax_batches: int = 0    # decrease-only batches (frontier relax)
    repair_batches: int = 0   # forest recertify + confined regrow batches
    full_rebuilds: int = 0    # rebuild_fraction exceeded
    update_supersteps: int = 0   # EDGE sweeps: forest sweep + regrow + tighten
    pointer_rounds: int = 0      # node-local doubling rounds (O(n) gathers,
                                 # no edge traffic — reported separately)
    rebuild_supersteps: int = 0  # growing steps spent inside full rebuilds
    update_syncs: int = 0        # device->host fetches on the update path
    store_uploads: int = 0       # full edge-array placements (build/growth)
    store_scatters: int = 0      # in-place scatter rounds
    baseline_supersteps: int = 0  # growing steps of the last FULL
                                  # decomposition (the rebuild comparator)

    @property
    def amortized_supersteps(self) -> float:
        """Update supersteps per applied batch (rebuild steps included —
        a triggered rebuild is part of the update cost)."""
        total = self.update_supersteps + self.rebuild_supersteps
        return total / max(self.batches, 1)


@dataclass
class UpdateReport:
    """What one ``apply_updates`` call did."""

    action: str               # "noop" | "relax" | "repair" | "rebuild"
    inserts: int
    decreases: int
    increases: int
    deletes: int
    noops: int
    dirty_fraction: float     # retracted certificates / n (delete path)
    supersteps: int           # edge sweeps this batch (forest+regrow+tighten)
    pointer_rounds: int       # node-local doubling rounds this batch
    dead_nodes: int           # certificates the witness forest could not root
    new_singletons: int       # nodes no center could re-reach
    cluster_set_changed: bool
    seconds: float


@dataclass
class DynamicState:
    """Per-session dynamic bookkeeping (created on first apply_updates)."""

    store: EdgeStore
    dec: Decomposition
    metrics: DynamicMetrics = field(default_factory=DynamicMetrics)
    # cached device quotient of (store, dec) + its fetched counters
    dq: Optional[object] = None
    dq_counters: Optional[Tuple[int, int, int, int]] = None
    # center ids whose (cluster, cluster) quotient keys need recomputation
    dirty_centers: Set[int] = field(default_factory=set)
    quotient_stale: bool = True   # full kernel pass needed (cluster set
                                  # changed / no cache yet)
    # cached solve result (phi_quotient, ecc, connected, supersteps)
    solution: Optional[Tuple[int, np.ndarray, bool, int]] = None


# ---------------------------------------------------------------------------
# jitted kernels: support invalidation + repair state assembly
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n", "k_rounds"))
def _forest_repair(src, dst, w, fc, fp, *, n: int, k_rounds: int):
    """Witness-forest recertification: ONE edge sweep + O(log n) node
    rounds, no kill cascade.

    Every non-center v picks a parent u over its in-cluster edges with
    ``fp[u] < fp[v]`` (STRICT descent in the OLD certificates, so the
    forest is acyclic and rooted at the fp = 0 centers), minimizing the
    lexicographic ``(fp[u] + w, u)`` under the CURRENT weights. Pointer
    doubling then accumulates each node's root distance along the forest:
    the result is the weight of a REAL path in the current graph from v's
    own center (the chain stays inside the cluster), i.e. a fresh
    certificate — weight increases are absorbed by inflating exactly the
    affected subtrees, with no invalidation fixpoint and no regrow.

    A node is DEAD only when its chain does not reach a center (its
    descent edge was deleted, every alternative too) or the accumulated
    weight saturates the engine's 2^30 envelope — those go to the confined
    regrow. Returns (alive bool [n], fp_new int32 [n]); the doubling
    rounds are node-local O(n) gathers, NOT edge sweeps (accounted
    separately as ``pointer_rounds``).
    """
    INFi = jnp.int32(2**31 - 1)
    BIG = jnp.int32(2**30)
    ids = jnp.arange(n, dtype=jnp.int32)
    is_center = fc == ids
    adm = ((fc[src] == fc[dst]) & (fp[src] < fp[dst]) & (src != dst)
           & (fp[src] < BIG) & (w < BIG))
    val = jnp.where(adm, jnp.where(adm, fp[src], 0) + w, INFi)
    v_min, parent, pw = segment_min_triple(val, src, w, dst, n)
    has_parent = (v_min < INFi) & ~is_center
    parent = jnp.where(has_parent, parent, ids)
    acc = jnp.where(has_parent, pw, jnp.int32(0))

    def body(_, carry):
        par, a = carry
        ap = a[par]
        # saturating add: BIG - a never underflows (a >= 0), so the
        # comparison detects a + ap >= BIG without overflowing int32
        a2 = jnp.where((a >= BIG) | (ap >= BIG - a), BIG, a + ap)
        return par[par], a2

    parent, acc = jax.lax.fori_loop(0, k_rounds, body, (parent, acc))
    rooted = is_center[parent] & (acc < BIG)
    alive = is_center | (has_parent & rooted)
    fp_new = jnp.where(is_center, jnp.int32(0),
                       jnp.where(alive, acc, INFi))
    return alive, fp_new


def _repair_state(fc, fp, alive, n: int, *, confine: bool):
    """EngineState for the repair/frontier relax: d == pathw == the current
    certificates (INF on retracted nodes), centers frozen at 0, NO covered
    relays (plain distance semantics — the relay/contraction machinery is a
    per-stage construct the repair does not need).

    With ``confine=True`` every ALIVE node is frozen too: alive nodes feed
    candidates (their certificates are the sources) but only retracted
    nodes receive, so the relax wave cannot sweep the graph — its depth is
    the dead region's own hop depth, not the global improvement cascade's.
    """
    ids = jnp.arange(n, dtype=jnp.int32)
    fc_r = jnp.where(alive, fc, INF)
    fp_r = jnp.where(alive, fp, INF)
    z = jnp.zeros(n, jnp.int32)
    f = jnp.zeros(n, bool)
    frozen = alive if confine else (fc == ids) & alive
    return EngineState(
        d=fp_r, c=fc_r, pathw=fp_r, final_c=fc_r, final_pathw=fp_r,
        offset=z, covered=f, is_center=frozen,
    )


@partial(jax.jit, static_argnames=("n",))
def _finalize_repair(state: EngineState, *, n: int):
    """Post-relax planes: unreached nodes become singleton clusters (c =
    self, pathw = 0), mirroring Alg. 1's last line — this is what keeps
    disconnecting deletions certified. Returns (c, pathw, n_singletons)."""
    ids = jnp.arange(n, dtype=jnp.int32)
    dead = state.pathw >= INF
    c = jnp.where(dead, ids, state.c)
    p = jnp.where(dead, jnp.int32(0), state.pathw)
    return c, p, jnp.sum(dead).astype(jnp.int32)


# ---------------------------------------------------------------------------
# session plumbing
# ---------------------------------------------------------------------------


def _rebind_session_buffers(session, store: EdgeStore) -> None:
    """Point the session's resident views at the store's device arrays."""
    from repro.core.backend import SingleDeviceBackend

    be = session.backend
    if getattr(be, "kind", None) == "single":
        be.rebind(store.src, store.dst, store.weight)
    else:
        # blocked (pallas) and sharded layouts cannot be scatter-updated in
        # place; dynamic sessions run the decomposition on the flat store
        # view instead (the same device-resident re-entry the cascade uses)
        log.info("dynamic updates: migrating %s backend to the flat "
                 "device store view", getattr(be, "kind", "custom"))
        session.backend = SingleDeviceBackend.from_device(
            session.n_nodes, store.src, store.dst, store.weight)
    session._flat_edges = (store.src, store.dst, store.weight)


def _full_decomposition(session) -> Decomposition:
    """One full decomposition with the session's own defaults (the same
    path a ClusterQuotientEstimator query takes), on the resident store."""
    from repro.core.cluster import cluster

    cfg = session.cfg
    delta0 = session.resolve_delta_init(cfg.delta_init)
    return cluster(
        session.edges, session.tau, gamma=cfg.gamma, variant=cfg.variant,
        delta_init=str(delta0), seed=cfg.seed, max_stages=cfg.max_stages,
        max_steps_per_phase=cfg.max_steps_per_phase,
        relax_fn=session.backend,
        mode=cfg.mode, deterministic=cfg.deterministic,
    )


def _recertify(session, dec: Decomposition) -> Tuple[Decomposition, int, int]:
    """Reroute every certificate through the witness forest + confined
    regrow, so each node's ``(c, pathw)`` is witnessed by an in-cluster
    parent edge. The engine's decompositions don't guarantee that — with
    the "stop" variant a realized path may thread through nodes whose
    FINAL cluster differs (mid-stage reassignment races; ~20% of nodes on
    RMAT graphs) — and the incremental repair needs forest-witnessed
    certificates to keep later dead sets proportional to the update, not
    the race history. Runs once at dynamic-mode entry and after every full
    rebuild. Returns (dec, edge_sweeps, pointer_rounds)."""
    n = session.n_nodes
    if n == 0 or dec.final_c_dev is None:
        return dec, 0, 0
    with telemetry.span("dynamic.recertify", n=n) as sp:
        src, dst, w = session.flat_device_edges()
        rounds = int(np.ceil(np.log2(max(n, 2)))) + 1
        alive, fp_base = _forest_repair(
            src, dst, w, dec.final_c_dev, dec.final_pathw_dev,
            n=n, k_rounds=rounds)
        state = _repair_state(dec.final_c_dev, fp_base, alive, n,
                              confine=True)
        state, stats = session.backend.grow(
            state, jnp.int32(_REPAIR_DELTA), jnp.int32(0),
            jnp.int32(_REPAIR_NUM_IT), "complete")
        c_dev, p_dev, n_single = _finalize_repair(state, n=n)
        fc, fp, grow_steps, singles = _fetch_repair_planes(
            c_dev, p_dev, (stats.steps, n_single))
        sp.set(pointer_rounds=rounds, supersteps=1 + int(grow_steps),
               singletons=int(singles))
    if singles:
        log.info("recertify: %d unreachable nodes became singletons", singles)
    dec = _make_decomposition(dec, fc, fp, c_dev, p_dev, 0,
                              dec.n_clusters + singles)
    return dec, 1 + grow_steps, rounds


def ensure_dynamic(session) -> DynamicState:
    """Idempotently switch a session into dynamic mode: build the mutable
    edge store from the resident graph (pool padding self-loops become free
    capacity), rebind the backend to it, run the initial certified
    decomposition that every later update repairs, and recertify it
    through the witness forest (one-time open cost)."""
    st = session._dynamic
    if st is not None:
        return st
    session._check_open()
    # a store-backed session keeps ITS storage layer (spill/checkpoint
    # seams stay live under updates); otherwise build a single-shard
    # GraphStore — EdgeStore semantics plus the slab/halo introspection
    store = getattr(session, "store", None)
    if store is None:
        store = GraphStore(session.edges)
        session.store = store
    else:
        store.ensure_device()
    _rebind_session_buffers(session, store)
    # host mirror turns lazy: materialized from the store on access, and
    # the edge COUNT tracks the store (build min-coalesces duplicates and
    # recycles self-loops, so it may differ from the opened EdgeList's)
    session._edges, session._edges_fn = None, store.edge_list
    session._n_edges = store.n_edges
    session._delta_stats = None
    session._max_weight = None
    dec = _full_decomposition(session)
    st = DynamicState(store=store, dec=dec)
    st.metrics.baseline_supersteps = dec.growing_steps
    st.metrics.store_uploads = store.uploads
    session._dynamic = st
    dec, boot_sweeps, boot_rounds = _recertify(session, dec)
    st.dec = dec
    st.metrics.update_syncs += 1
    log.info("dynamic mode: %d nodes, %d edges (capacity %d), baseline "
             "decomposition %d supersteps (+%d bootstrap recertify sweeps)",
             session.n_nodes, store.n_edges, store.capacity,
             dec.growing_steps, boot_sweeps)
    return st


# ---------------------------------------------------------------------------
# classification + application
# ---------------------------------------------------------------------------


@dataclass
class _Plan:
    inserts: int = 0
    decreases: int = 0
    increases: int = 0
    deletes: int = 0
    noops: int = 0
    touched: List[int] = field(default_factory=list)       # any change

    @property
    def has_decrease(self) -> bool:
        return self.inserts + self.decreases > 0

    @property
    def has_increase(self) -> bool:
        return self.increases + self.deletes > 0


def _stage_events(store: EdgeStore, batch: UpdateBatch) -> _Plan:
    """Validate, classify, and stage every event on the host store.

    Validation runs BEFORE any mutation so a bad batch leaves the store
    untouched (atomic per batch). Reweights and deletes refer to the
    PRE-batch edge set; a key may appear in at most one of them per batch.
    """
    mutated = []
    for u, v, kind in [
        *((int(u), int(v), "reweight") for u, v in
          zip(batch.reweight_src, batch.reweight_dst)),
        *((int(u), int(v), "delete") for u, v in
          zip(batch.delete_src, batch.delete_dst)),
    ]:
        store._check_endpoint(u, v)
        if store.lookup(u, v) is None:
            raise ValueError(f"{kind} of missing edge ({u}, {v})")
        mutated.append((u, v))
    if len(set(mutated)) != len(mutated):
        raise ValueError(
            "a directed edge key may appear in at most one reweight/delete "
            "per batch (apply sequential changes in separate batches)")
    for u, v in zip(batch.insert_src, batch.insert_dst):
        store._check_endpoint(int(u), int(v))

    plan = _Plan()
    for u, v, w in zip(batch.insert_src, batch.insert_dst,
                       batch.insert_weight):
        u, v, w = int(u), int(v), int(w)
        if u == v:
            plan.noops += 1      # self-loops are inert by construction
            continue
        old = store.lookup(u, v)
        if old is None:
            store.set_edge(u, v, w)
            plan.inserts += 1
        elif w < old:
            store.set_edge(u, v, w)
            plan.decreases += 1
        else:
            plan.noops += 1      # min-coalesce: heavier parallel edge
            continue
        plan.touched += (u, v)
    for u, v, w in zip(batch.reweight_src, batch.reweight_dst,
                       batch.reweight_weight):
        u, v, w = int(u), int(v), int(w)
        old = store.lookup(u, v)
        if w == old:
            plan.noops += 1
            continue
        store.set_edge(u, v, w)
        plan.touched += (u, v)
        if w < old:
            plan.decreases += 1
        else:
            plan.increases += 1
    for u, v in zip(batch.delete_src, batch.delete_dst):
        u, v = int(u), int(v)
        store.delete_edge(u, v)
        plan.deletes += 1
        plan.touched += (u, v)
    return plan


def _fetch_repair_planes(c_dev, p_dev, scalars) -> Tuple[np.ndarray, ...]:
    """ONE packed device->host fetch of the repaired planes + int32 stats."""
    n = int(c_dev.shape[0])
    packed = guard.fetch(jnp.concatenate(
        [c_dev, p_dev] + [jnp.asarray(s, jnp.int32)[None] for s in scalars]),
        reason="dynamic repair: packed planes + int32 stats")
    return (packed[:n], packed[n:2 * n], *map(int, packed[2 * n:]))


def _make_decomposition(prev: Decomposition, fc, fp, fc_dev, fp_dev,
                        steps: int, n_clusters: int) -> Decomposition:
    return dataclasses.replace(
        prev,
        final_c=fc, final_pathw=fp,
        radius=int(fp.max()) if len(fp) else 0,
        n_clusters=n_clusters,
        growing_steps=prev.growing_steps + steps,
        final_c_dev=fc_dev, final_pathw_dev=fp_dev,
        metrics=None,
    )


def apply_updates(session, batch: UpdateBatch, *,
                  tighten_cap: Optional[int] = DEFAULT_TIGHTEN_CAP,
                  regrow_cap: Optional[int] = None) -> UpdateReport:
    """Apply one ``UpdateBatch`` to a resident session in place.

    See the module docstring for the algorithm; this is the orchestration:
    stage + scatter the buffer mutations, pick the repair strategy from the
    event mix and the dirty fraction, repair the decomposition on device,
    and record which quotient keys the next ``estimate()`` must refresh.

    ``tighten_cap`` bounds the insert/decrease tightening relax (None =
    run to fixpoint, 0 = skip). ``regrow_cap`` bounds the confined regrow
    the same way: dead nodes the capped wave does not reach become
    singleton clusters — Alg. 1's own treatment of uncovered nodes — so a
    serving deployment gets a HARD per-batch superstep bound; the quality
    debt (extra clusters, looser quotient) is certified and paid back by
    the next full rebuild. Both caps keep every bound certified.
    """
    session._check_open()
    st = ensure_dynamic(session)
    store, m = st.store, st.metrics
    n = session.n_nodes

    with Timer() as t:
        plan = _stage_events(store, batch)
        changed = plan.inserts + plan.decreases + plan.increases + plan.deletes
        m.batches += 1
        m.inserts += plan.inserts
        m.decreases += plan.decreases
        m.increases += plan.increases
        m.deletes += plan.deletes
        m.noop_events += plan.noops
        if changed == 0:
            return UpdateReport(
                action="noop", inserts=0, decreases=0, increases=0,
                deletes=0, noops=plan.noops, dirty_fraction=0.0,
                supersteps=0, pointer_rounds=0, dead_nodes=0,
                new_singletons=0, cluster_set_changed=False,
                seconds=t.seconds)

        # a scatter round produces NEW device array objects (functional
        # update), a capacity growth a full re-upload — either way every
        # resident view must be re-pointed at the store's current arrays
        store.flush()
        _rebind_session_buffers(session, store)
        m.store_uploads = store.uploads
        m.store_scatters = store.scatters
        # invalidate the session's host-side caches of the mutated graph
        # (the edge-list mirror re-materializes lazily on access; the edge
        # COUNT must track the store NOW — the SSSP estimators derive their
        # distance dtype from (n_edges, max_weight) on every query)
        session._edges = None
        session._n_edges = store.n_edges
        session._max_weight = None
        session._delta_stats = None

        old_dec = st.dec
        old_fc, old_fp = old_dec.final_c, old_dec.final_pathw
        fc_dev, fp_dev = old_dec.final_c_dev, old_dec.final_pathw_dev
        action = "relax"
        dirty_fraction = 0.0
        rounds = dead = singles = 0
        steps = 0
        alive, fp_base = None, fp_dev

        if plan.has_increase:
            # recertify through the witness forest: one edge sweep +
            # O(log n) pointer-doubling rounds absorb every weight increase
            # in place; only true orphans (deleted descent edges with no
            # alternative) come out dead. The dead fraction IS the dirty
            # region and picks repair vs full rebuild.
            rounds = int(np.ceil(np.log2(max(n, 2)))) + 1
            with telemetry.span("dynamic.forest_repair", n=n,
                                pointer_rounds=rounds) as sp:
                alive, fp_base = _forest_repair(
                    store.src, store.dst, store.weight, fc_dev, fp_dev,
                    n=n, k_rounds=rounds)
                dead = int(guard.fetch(jnp.sum(~alive),
                                       reason="dynamic: dead-node count picks "
                                              "repair vs rebuild"))
                sp.set(dead=dead)
            m.update_syncs += 1
            m.update_supersteps += 1   # the parent-selection edge sweep
            m.pointer_rounds += rounds
            steps += 1
            dirty_fraction = dead / max(n, 1)
            action = ("rebuild" if dirty_fraction > session.rebuild_fraction
                      else "repair")
        if action == "rebuild":
            with telemetry.span("dynamic.rebuild", n=n,
                                dirty_fraction=dirty_fraction) as sp:
                dec = _full_decomposition(session)
                m.full_rebuilds += 1
                m.rebuild_supersteps += dec.growing_steps
                m.baseline_supersteps = dec.growing_steps
                # fresh decompositions are not forest-witnessed (stop-variant
                # races) — recertify so later repairs stay incremental
                dec, r_sweeps, r_rounds = _recertify(session, dec)
                sp.set(supersteps=dec.growing_steps + r_sweeps)
            m.update_supersteps += r_sweeps
            m.pointer_rounds += r_rounds
            steps += r_sweeps
            rounds += r_rounds
        else:
            grow_steps = jnp.int32(0)
            if action == "repair":
                # confined regrow: re-attach the retracted region from its
                # alive boundary (runs to ITS fixpoint; the wave cannot
                # leave the dead region, so depth = dead-region hop depth)
                with telemetry.span("dynamic.regrow", n=n, dead=dead,
                                    cap=regrow_cap):
                    state = _repair_state(fc_dev, fp_base, alive, n,
                                          confine=True)
                    g_cap = (jnp.int32(_REPAIR_NUM_IT) if regrow_cap is None
                             else jnp.int32(int(regrow_cap)))
                    state, stats = session.backend.grow(
                        state, jnp.int32(_REPAIR_DELTA), jnp.int32(0),
                        g_cap, "complete")
                    grow_steps = stats.steps
            else:
                state = _repair_state(
                    fc_dev, fp_base, jnp.ones(n, bool), n, confine=False)
            tighten_steps = jnp.int32(0)
            if plan.has_decrease and tighten_cap != 0:
                # frontier tightening for inserts/decreases: a monotone
                # relax whose EVERY prefix is certified (each improvement
                # composes existing certificates with real edges), so the
                # step cap bounds the update cost without giving anything
                # up — a global rewire is tightened incrementally over the
                # next batches (or by the next full rebuild) instead of
                # stalling this one. tighten_cap=None runs to fixpoint.
                with telemetry.span("dynamic.relax", n=n, cap=tighten_cap):
                    cap = (jnp.int32(_REPAIR_NUM_IT) if tighten_cap is None
                           else jnp.int32(int(tighten_cap)))
                    state = state._replace(
                        is_center=state.pathw == jnp.int32(0))
                    state, tstats = session.backend.grow(
                        state, jnp.int32(_REPAIR_DELTA), jnp.int32(0),
                        cap, "complete")
                    tighten_steps = tstats.steps
            with telemetry.span("dynamic.finalize", n=n) as sp:
                c_dev, p_dev, n_single = _finalize_repair(state, n=n)
                fc, fp, g_steps, t_steps, singles = _fetch_repair_planes(
                    c_dev, p_dev, (grow_steps, tighten_steps, n_single))
                sp.set(supersteps=int(g_steps) + int(t_steps),
                       singletons=int(singles))
            m.update_syncs += 1
            steps += g_steps + t_steps
            m.update_supersteps += g_steps + t_steps
            if action == "repair":
                m.repair_batches += 1
            else:
                m.relax_batches += 1
            dec = _make_decomposition(old_dec, fc, fp, c_dev, p_dev, steps,
                                      old_dec.n_clusters + singles)

        # quotient refresh bookkeeping: which keys must be recomputed. The
        # cluster SET only changes on a rebuild or when the repair minted
        # singletons: a center always keeps fc == self (it is frozen in
        # every repair/tighten relax), so no cluster can vanish, and the
        # only way a new fc value appears is _finalize_repair's
        # singletonization — which is exactly what ``singles`` counts.
        cluster_set_changed = action == "rebuild" or singles > 0
        if cluster_set_changed:
            st.quotient_stale = True
            st.dirty_centers.clear()
        else:
            moved = ((old_fc != dec.final_c)
                     | (old_fp != dec.final_pathw))
            touched = np.unique(np.asarray(plan.touched, np.int64))
            dirty = set(np.unique(old_fc[moved]).tolist())
            dirty |= set(np.unique(dec.final_c[moved]).tolist())
            dirty |= set(np.unique(dec.final_c[touched]).tolist())
            dirty |= set(np.unique(old_fc[touched]).tolist())
            st.dirty_centers |= dirty
        st.solution = None
        st.dec = dec

    log.info("update batch: %s (+%d/-%d edges, %d reweights) sweeps=%d "
             "pointer_rounds=%d dead=%d singletons=%d dirty=%.3f in %.3fs",
             action, plan.inserts, plan.deletes,
             plan.decreases + plan.increases, steps, rounds, dead, singles,
             dirty_fraction, t.seconds)
    return UpdateReport(
        action=action, inserts=plan.inserts, decreases=plan.decreases,
        increases=plan.increases, deletes=plan.deletes, noops=plan.noops,
        dirty_fraction=dirty_fraction, supersteps=steps,
        pointer_rounds=rounds, dead_nodes=dead, new_singletons=singles,
        cluster_set_changed=cluster_set_changed, seconds=t.seconds)


# ---------------------------------------------------------------------------
# the query side: certified quotient solve over the maintained state
# ---------------------------------------------------------------------------


def _dirty_incident_slice(store: EdgeStore, fc: np.ndarray,
                          dirty_ids: np.ndarray):
    """Host gather of the edges whose (cluster, cluster) key touches a dirty
    cluster, padded to a DIRTY_EDGE_BUCKET multiple. Returns device arrays
    (src, dst, w, mask) — a SMALL upload proportional to the dirty region,
    not the graph."""
    import jax.numpy as jnp

    dirty = np.zeros(len(fc) + 1, bool)
    dirty[dirty_ids] = True
    sel = store.valid & (dirty[fc[store.h_src]] | dirty[fc[store.h_dst]])
    idx = np.flatnonzero(sel)
    e_pad = next_multiple(max(len(idx), 1), DIRTY_EDGE_BUCKET)
    src = np.zeros(e_pad, np.int32)
    dst = np.zeros(e_pad, np.int32)
    w = np.ones(e_pad, np.int32)
    mask = np.zeros(e_pad, bool)
    src[: len(idx)] = store.h_src[idx]
    dst[: len(idx)] = store.h_dst[idx]
    w[: len(idx)] = store.h_weight[idx]
    mask[: len(idx)] = True
    return (jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w),
            jnp.asarray(mask), len(idx))


def solve_session_quotient(session, pm) -> Tuple[int, np.ndarray, bool]:
    """(phi_quotient, eccentricities, connected) for the maintained
    decomposition, refreshing the cached quotient incrementally: only the
    (cluster, cluster) keys recorded dirty since the last solve are
    recomputed through the PR 2 kernel; everything else merges from the
    cache. Results are cached until the next update."""
    from repro.core.quotient import (
        build_quotient_device,
        fetch_quotient_counters,
        quotient_update_device,
        solve_device_quotient,
    )

    st = session._dynamic
    dec, store = st.dec, st.store
    if st.solution is not None and not st.quotient_stale \
            and not st.dirty_centers:
        phi_q, ecc, connected, steps = st.solution
        pm.solve_supersteps = steps
        return phi_q, ecc, connected

    n = session.n_nodes
    if n == 0 or store.n_edges == 0:
        k = dec.n_clusters
        st.solution = (0, np.zeros(k, np.int64), k <= 1, 0)
        st.quotient_stale = False
        st.dirty_centers.clear()
        return 0, np.zeros(k, np.int64), k <= 1

    with telemetry.span("quotient.build", dynamic=True) as sp:
        if st.dq is None or st.quotient_stale or st.dq_counters is None:
            dq = build_quotient_device(session.edges, dec,
                                       backend=session.backend)
            sp.set(incremental=False)
        else:
            dirty_ids = np.fromiter(  # det: order-insensitive — ids only scatter into boolean dirty masks
                st.dirty_centers, np.int64, count=len(st.dirty_centers))
            sub_src, sub_dst, sub_w, sub_mask, _ = _dirty_incident_slice(
                store, dec.final_c, dirty_ids)
            dq = quotient_update_device(
                st.dq, st.dq_counters[1], (sub_src, sub_dst, sub_w, sub_mask),
                dec.final_c_dev, dec.final_pathw_dev, dirty_ids, n)
            sp.set(incremental=True, dirty_centers=len(dirty_ids))
        k, mq, wmax, wsum = fetch_quotient_counters(dq)
        sp.set(clusters=k, edges=mq)
    pm.quotient_syncs += 1
    pm.n_quotient_edges = mq
    st.dq, st.dq_counters = dq, (k, mq, wmax, wsum)
    st.quotient_stale = False
    st.dirty_centers.clear()
    if k <= 1:
        st.solution = (0, np.zeros(k, np.int64), True, 0)
        return 0, np.zeros(k, np.int64), True
    with telemetry.span("quotient.solve", dynamic=True, clusters=k) as sp:
        diam, ecc, connected, steps = solve_device_quotient(dq, k, mq, wmax)
        sp.set(supersteps=steps)
    pm.solve_syncs += 1
    pm.solve_supersteps = steps
    st.solution = (diam, ecc, connected, steps)
    return diam, ecc, connected
