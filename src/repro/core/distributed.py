"""Distributed Δ-growing engine: the paper's MR rounds as shard_map supersteps.

The MR(M_T, M_L) round of the paper maps onto one TPU-pod superstep:

  paper round (shuffle + reduce-by-key)  ==  one shard_map superstep:
    1. each device owns a contiguous node range (states d/c/pathw + frozen
       relay fields) and the destination-sorted edges whose *destination*
       falls in that range (so the tuple-min reduce-by-key is device-local);
    2. source states are fetched across devices — either a full all-gather
       of the node-state planes (baseline) or a static halo exchange via
       all_to_all (optimized; the edge list is static, so each device pair's
       needed ids are known ahead of time);
    3. the Bellman-Ford relax + lexicographic (d, c) tuple-min runs locally
       (jnp segment ops or the Pallas edge_relax kernel on TPU).

  The while_loop trip count of supersteps is exactly the quantity the paper
  proves small (O(min{n/τ, ℓ_R} log n)) — each trip costs one collective, as
  each MR round costs one shuffle.

Node ids are padded to a multiple of the device count; the phantom tail is
pinned at INF/covered=False and never wins a min. Partitioning is pluggable:
``range`` (contiguous) or ``cluster`` (locality-aware, derived from the
paper's own decomposition — see graph/partition.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import ceil_div, get_logger, next_multiple
from repro.common.compat import shard_map
from repro.core.state import EngineState, INF
from repro.graph.segment_ops import segment_min_triple
from repro.graph.structures import EdgeList
from repro.kernels.edge_relax.ref import edge_relax_candidates

log = get_logger("repro.distributed")


@dataclass
class ShardedGraph:
    """Edges partitioned by destination owner, padded per device.

    Per-device edge slots are padded with the phantom edge (src=dst=n_pad-1,
    w=INF-guarded) which never relaxes anything.
    """

    n_nodes: int                 # real node count
    n_pad: int                   # padded (multiple of n_devices)
    n_devices: int
    src: jnp.ndarray             # int32 [P, E_loc] global source ids
    dst_local: jnp.ndarray       # int32 [P, E_loc] destination ids local to owner
    weight: jnp.ndarray          # int32 [P, E_loc]
    edge_mask: jnp.ndarray       # bool  [P, E_loc]
    # halo exchange plan (comm="halo"): for device pair (q -> p), q != p,
    # send_ids[q, p, :] are q-local node indices whose states p needs.
    # Device-local sources are read straight from the local plane (no wire).
    send_ids: Optional[jnp.ndarray] = None   # int32 [P, P, K] q-local ids
    recv_slot: Optional[jnp.ndarray] = None  # int32 [P, E_loc] slot into the
                                             # received halo table [P*K]
    src_is_local: Optional[jnp.ndarray] = None  # bool [P, E_loc]
    src_local_idx: Optional[jnp.ndarray] = None # int32 [P, E_loc]
    halo_k: int = 0

    @property
    def nodes_per_device(self) -> int:
        return self.n_pad // self.n_devices


def shard_graph(
    edges: EdgeList,
    n_devices: int,
    build_halo: bool = True,
) -> ShardedGraph:
    """Partition destination-sorted edges by destination owner (host side)."""
    n = edges.n_nodes
    n_pad = next_multiple(n, n_devices)
    q = n_pad // n_devices

    e = edges.sorted_by_dst()
    owner = e.dst // q
    counts = np.bincount(owner, minlength=n_devices)
    e_loc = max(int(counts.max()), 1)

    src = np.full((n_devices, e_loc), n_pad - 1, dtype=np.int32)
    dstl = np.full((n_devices, e_loc), q - 1, dtype=np.int32)
    w = np.ones((n_devices, e_loc), dtype=np.int32)
    mask = np.zeros((n_devices, e_loc), dtype=bool)

    starts = np.concatenate([[0], np.cumsum(counts)])
    for p in range(n_devices):
        s, t = int(starts[p]), int(starts[p + 1])
        c = t - s
        if c == 0:
            continue
        src[p, :c] = e.src[s:t]
        dstl[p, :c] = e.dst[s:t] - p * q
        w[p, :c] = e.weight[s:t]
        mask[p, :c] = True

    g = ShardedGraph(
        n_nodes=n, n_pad=n_pad, n_devices=n_devices,
        src=jnp.asarray(src), dst_local=jnp.asarray(dstl),
        weight=jnp.asarray(w), edge_mask=jnp.asarray(mask),
    )
    if build_halo:
        _attach_halo_plan(g, src, mask, q)
    return g


def _attach_halo_plan(g: ShardedGraph, src: np.ndarray, mask: np.ndarray, q: int) -> None:
    """Static halo exchange plan. For each dst-owner p, the set of REMOTE
    sources it reads is fixed; build [P, P, K] send tables + per-edge slots.
    Local sources (owner == p) bypass the exchange entirely."""
    n_dev = g.n_devices
    uniq_per_pair = [[np.empty(0, np.int64)] * n_dev for _ in range(n_dev)]
    k_max = 1
    for p in range(n_dev):
        srcs = src[p][mask[p]]
        owners = srcs // q
        for o in range(n_dev):
            if o == p:
                continue  # local reads don't travel
            u = np.unique(srcs[owners == o])
            uniq_per_pair[o][p] = u  # device o sends these (global ids) to p
            k_max = max(k_max, len(u))
    send = np.zeros((n_dev, n_dev, k_max), dtype=np.int32)
    for o in range(n_dev):
        for p in range(n_dev):
            u = uniq_per_pair[o][p]
            if len(u):
                send[o, p, : len(u)] = u - o * q  # o-local indices
    recv_slot = np.zeros_like(src)
    is_local = np.zeros(src.shape, dtype=bool)
    local_idx = np.zeros_like(src)
    for p in range(n_dev):
        lookup = {}
        for o in range(n_dev):
            for j, gid in enumerate(uniq_per_pair[o][p]):
                lookup[int(gid)] = o * k_max + j
        owners = src[p] // q
        is_local[p] = (owners == p) & mask[p]
        local_idx[p] = np.where(is_local[p], src[p] - p * q, 0)
        recv_slot[p] = np.array(
            [lookup.get(int(s), 0) if (mm and not loc) else 0
             for s, mm, loc in zip(src[p], mask[p], is_local[p])],
            dtype=np.int32,
        )
    g.send_ids = jnp.asarray(send)
    g.recv_slot = jnp.asarray(recv_slot)
    g.src_is_local = jnp.asarray(is_local)
    g.src_local_idx = jnp.asarray(local_idx)
    g.halo_k = k_max


# ---------------------------------------------------------------------------
# The superstep
# ---------------------------------------------------------------------------

# node-state planes carried through the distributed loop (per-device shards):
#   d, c, pathw          in-stage wave
#   relay_w0             covered relay base: offset (d_cover - Delta) else INF
#   relay_c, relay_p     covered relay center / path weight
#   frozen               covered | is_center (never receives updates)
# The planes are derived ONCE per grow call from the canonical EngineState by
# ``core.state.relay_planes`` (see core/backend.ShardedBackend) — not packed
# and re-padded per call as in the seed engine.


def _relax_local(src_d, src_c, src_p, src_rw0, src_rc, src_rp,
                 w, dst_local, edge_mask, delta, q,
                 d, c, pw, frozen):
    """Device-local relax + lexicographic tuple-min (the reduce-by-key).

    Candidate rule and tuple-min are the shared canonical implementations
    (``kernels/edge_relax/ref.py`` + ``graph/segment_ops.py``) — the same
    code every other backend runs, which is what makes the backends
    byte-identical."""
    cand_d, cand_c, cand_p = edge_relax_candidates(
        src_d, src_c, src_p, src_rw0, src_rc, src_rp, w, edge_mask, delta)
    d_min, c_min, p_min = segment_min_triple(cand_d, cand_c, cand_p,
                                             dst_local, q)
    upd = (~frozen) & (d_min < d)
    return (
        jnp.where(upd, d_min, d),
        jnp.where(upd, c_min, c),
        jnp.where(upd, p_min, pw),
        jnp.any(upd),
    )


class DistributedEngine:
    """shard_map executor for Δ-growing supersteps on a device mesh.

    ``comm``: "halo" (default) exchanges only the statically-needed boundary
    states via all_to_all (bytes = 6·4·P·P·K per superstep, typically ≪ n
    with locality-aware partitions). "allgather" broadcasts the six source
    planes each superstep (baseline; collective bytes = 6·4·n_pad·P).
    Both produce byte-identical planes; comm is a pure traffic knob.

    ``graph``: optionally a prebuilt ``ShardedGraph`` (e.g. from
    ``GraphStore.sharded_graph()``) so the relabel/shard work isn't repeated;
    it is validated against the mesh and rebuilt from ``edges`` on mismatch.
    """

    def __init__(
        self,
        edges: EdgeList,
        mesh: Mesh,
        comm: str = "halo",
        axis_names: Optional[Tuple[str, ...]] = None,
        graph: Optional[ShardedGraph] = None,
    ):
        self.mesh = mesh
        self.axes = tuple(axis_names or mesh.axis_names)
        self.n_devices = int(np.prod([mesh.shape[a] for a in self.axes]))
        self.comm = comm
        if graph is not None and graph.n_devices != self.n_devices:
            log.warning(
                "prebuilt ShardedGraph has %d shards but mesh has %d devices; "
                "resharding from edges", graph.n_devices, self.n_devices,
            )
            graph = None
        if graph is not None and comm == "halo" and graph.send_ids is None:
            graph = None  # prebuilt without a halo plan; rebuild with one
        self.graph = graph if graph is not None else shard_graph(
            edges, self.n_devices, build_halo=(comm == "halo"))
        self.q = self.graph.nodes_per_device
        self._step = self._build_superstep()
        self._growth = self._build_growth_loop()
        # device-place the static edge shards once per engine, not per call
        self.gparts = self.device_put_graph()

    # -- sharding helpers ---------------------------------------------------
    def node_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axes))

    def edge_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axes, None))

    def device_put_graph(self):
        es = self.edge_sharding()
        g = self.graph
        out = [jax.device_put(x, es) for x in (g.src, g.dst_local, g.weight, g.edge_mask)]
        if self.comm == "halo":
            out.append(jax.device_put(g.send_ids, NamedSharding(self.mesh, P(self.axes, None, None))))
            out.append(jax.device_put(g.recv_slot, es))
            out.append(jax.device_put(g.src_is_local, es))
            out.append(jax.device_put(g.src_local_idx, es))
        return tuple(out)

    # -- communication accounting (bytes per superstep, whole mesh) ---------
    def comm_bytes_per_superstep(self) -> int:
        """Bytes moved across the mesh by one superstep's source-plane
        exchange (6 int32 planes per node row = 24 B/row)."""
        if self.n_devices <= 1:
            return 0
        if self.comm == "halo":
            # the all_to_all ships a fixed [P, K] table per device (the
            # self-row is allocated on the wire plan even though it stays
            # local), so the conservative count is P·P·K rows mesh-wide.
            return 24 * self.n_devices * self.n_devices * self.graph.halo_k
        return self.fullplane_bytes_per_superstep()

    def fullplane_bytes_per_superstep(self) -> int:
        """Bytes one full-plane all-gather of the six planes would move."""
        if self.n_devices <= 1:
            return 0
        return 24 * self.graph.n_pad * self.n_devices

    # -- superstep bodies (run inside shard_map; arrays are per-device) -----
    def _gather_src_planes(self, planes_local, src, recv_slot, send_ids,
                           is_local=None, local_idx=None):
        axis = self.axes
        if self.comm == "allgather":
            full = [jax.lax.all_gather(x, axis, tiled=True) for x in planes_local]
            return [f[src] for f in full]
        # halo: q sends states of send_ids[q, p] to p (all_to_all over axis 0);
        # device-local sources are read straight off the local plane.
        outs = []
        for x in planes_local:
            buf = x[send_ids]                      # [P, K] rows for each peer
            got = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                                     tiled=True)
            remote = got.reshape(-1)[recv_slot]    # [E_loc]
            outs.append(jnp.where(is_local, x[local_idx], remote))
        return outs

    def _build_superstep(self) -> Callable:
        axes = self.axes
        q = self.q
        comm = self.comm

        def step(planes, gparts, delta):
            d, c, pw, rw0, rc, rp, frozen = planes
            if comm == "halo":
                src, dstl, w, emask, send_ids, recv_slot, is_loc, loc_idx = gparts
            else:
                src, dstl, w, emask = gparts
                send_ids = recv_slot = is_loc = loc_idx = None

            def body(d, c, pw, rw0, rc, rp, frozen, src, dstl, w, emask, *halo):
                # edge shards arrive as [1, E_loc] (leading sharded axis of
                # extent 1 per device) — drop it for the local compute.
                src, dstl, w, emask = src[0], dstl[0], w[0], emask[0]
                send_ids_l = halo[0][0] if halo else None   # [P, K]
                recv_slot_l = halo[1][0] if halo else None  # [E_loc]
                is_loc_l = halo[2][0] if halo else None
                loc_idx_l = halo[3][0] if halo else None
                srcs = self._gather_src_planes(
                    (d, c, pw, rw0, rc, rp), src, recv_slot_l, send_ids_l,
                    is_loc_l, loc_idx_l,
                )
                nd, nc, npw, ch = _relax_local(
                    srcs[0], srcs[1], srcs[2], srcs[3], srcs[4], srcs[5],
                    w, dstl, emask, delta, q, d, c, pw, frozen,
                )
                ch = jax.lax.all_gather(ch[None], axes, tiled=True).any()
                return nd, nc, npw, ch

            in_specs = [P(axes)] * 7 + [P(axes, None)] * 4
            out_specs = (P(axes), P(axes), P(axes), P())
            args = [d, c, pw, rw0, rc, rp, frozen, src, dstl, w, emask]
            if comm == "halo":
                in_specs += [P(axes, None, None)] + [P(axes, None)] * 3
                args += [send_ids, recv_slot, is_loc, loc_idx]
            nd, nc, npw, ch = shard_map(
                body, mesh=self.mesh, in_specs=tuple(in_specs),
                out_specs=out_specs, check_vma=False,
            )(*args)
            return (nd, nc, npw, rw0, rc, rp, frozen), ch

        return step

    def _build_growth_loop(self) -> Callable:
        step = self._step

        @partial(jax.jit, static_argnames=("variant",))
        def growth(planes, gparts, delta, half_target, num_it, variant="stop"):
            def reached(pl_):
                d, _, _, _, _, _, frozen = pl_
                return jnp.sum((~frozen) & (d < delta))

            def cond(carry):
                pl_, k, ch = carry
                more = ch & (k < num_it)
                if variant == "stop":
                    more = more & (reached(pl_) < half_target)
                return more

            def body(carry):
                pl_, k, _ = carry
                pl2, ch = step(pl_, gparts, delta)
                return pl2, k + 1, ch

            planes, k, ch = jax.lax.while_loop(cond, body, (planes, jnp.int32(0), jnp.bool_(True)))
            return planes, k, reached(planes), ch

        return growth

    # -- public API matching cluster()'s relax_fn hook ----------------------
    def make_relax_fn(self):
        """Adapter: cluster(..., relax_fn=engine.make_relax_fn()).

        Returns a ``ShardedBackend`` over this engine: the decomposition
        engine keeps the canonical planes sharded and device-resident for
        the whole run (one pack, zero per-grow host round-trips)."""
        from repro.core.backend import ShardedBackend

        return ShardedBackend(self)

    # -- dry-run entry: one compiled superstep ------------------------------
    def lower_superstep(self, delta: int = 1 << 20):
        """lower+compile one superstep from ShapeDtypeStructs (no data)."""
        ns, es = self.node_sharding(), self.edge_sharding()
        g = self.graph
        sds = jax.ShapeDtypeStruct
        planes = tuple(
            sds((g.n_pad,), jnp.bool_ if i == 6 else jnp.int32, sharding=ns)
            for i in range(7)
        )
        eshape = g.src.shape
        gparts = [
            sds(eshape, jnp.int32, sharding=es),
            sds(eshape, jnp.int32, sharding=es),
            sds(eshape, jnp.int32, sharding=es),
            sds(eshape, jnp.bool_, sharding=es),
        ]
        if self.comm == "halo":
            gparts.append(sds(g.send_ids.shape, jnp.int32,
                              sharding=NamedSharding(self.mesh, P(self.axes, None, None))))
            gparts.append(sds(eshape, jnp.int32, sharding=es))
            gparts.append(sds(eshape, jnp.bool_, sharding=es))
            gparts.append(sds(eshape, jnp.int32, sharding=es))

        def one_step(planes, gparts):
            out, ch = self._step(planes, tuple(gparts), jnp.int32(delta))
            return out, ch

        return jax.jit(one_step).lower(planes, gparts)
