"""GraphSession: a resident-graph handle for query-many serving.

The paper's serving story is many diameter queries over massive graphs; the
one-shot entry points (``approximate_diameter(edges, cfg)``) paid the full
open cost on every call — a fresh ``RelaxBackend`` (edge re-upload plus, for
the Pallas backend, a host re-blocking pass) and a cold jit-cache walk.
``GraphSession`` splits that into open-once / query-many:

  * ``open_session(edges, cfg)`` uploads the edge buffers, constructs the
    backend and packs the padded node planes EXACTLY once; every estimator
    query afterwards runs against the resident device buffers
    (``session.backend`` for the decomposition/quotient path,
    ``session.flat_device_edges()`` for the SSSP estimators) with zero
    re-upload and zero backend rebuild.
  * Compiled programs are shared across sessions automatically: every jitted
    stage keys on (shape bucket, static config) — see ``GrowSpec`` — so two
    sessions over same-shaped graphs hit one compile.
  * ``SessionPool`` manages bucketed sessions for MANY same-shaped graphs:
    edge arrays are padded to a common bucket with inert self-loops
    (subsuming the old ``approximate_diameter_batch`` internals), so a whole
    group of graphs shares one compiled pipeline.

``SessionMetrics`` counts the expensive events (backend builds, edge-array
uploads) so the serving bench can ASSERT the warm path does neither
(recorded in ``BENCH_engine.json`` by ``benchmarks/kernel_bench.py``).

Resident graphs are also MUTABLE: ``session.apply_updates(UpdateBatch)``
absorbs edge insertions/reweights/deletions into the resident buffers in
place and repairs the maintained decomposition by bounded incremental
relaxation (``core/dynamic.py``); after the first update, ``estimate()``
defaults to the maintained ``DynamicQuotientEstimator``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common import get_logger, next_multiple
from repro.config.base import GraphEngineConfig
from repro.core.backend import RelaxBackend, make_backend
from repro.core.cluster import _initial_delta
from repro.core.engine import resolve_engine_mode
from repro.graph.storage import EdgeStore, GraphStore
from repro.graph.structures import EdgeList
from repro.runtime import telemetry

log = get_logger("repro.session")

EDGE_BUCKET = 256  # pooled sessions pad edge arrays to a multiple of this

# Quotient solve budget (max clusters the batched-BF solve takes head-on);
# above it ``CascadeEstimator`` re-enters the engine on the quotient.
DEFAULT_TAU_SOLVE = 1024

# Dynamic updates: when a delete/increase batch dirties more than this
# fraction of the nodes (at cluster granularity), incremental repair is
# abandoned for a full re-decomposition (see ``core/dynamic.py``).
DEFAULT_REBUILD_FRACTION = 0.25


def tau_for(n_nodes: int, fraction: float = 1e-3, minimum: int = 4) -> int:
    """Paper Section 5: pick tau so the quotient has ~ n/1000 nodes. CLUSTER
    yields O(tau log^2 n) clusters; in practice ~ tau * small-constant, so we
    take tau = n * fraction / log(n) with a floor."""
    logn = max(math.log(max(n_nodes, 2)), 1.0)
    return max(int(n_nodes * fraction / logn), minimum)


@dataclass
class SessionMetrics:
    """Open-vs-query cost accounting, shared across a pool's sessions.

    ``backend_builds`` / ``edge_uploads`` count the expensive open-path
    events; a query that triggers neither is WARM. The serving bench asserts
    warm queries stay at zero builds and zero uploads.
    """

    sessions_opened: int = 0
    backend_builds: int = 0   # RelaxBackend constructions (edge layout + jit keys)
    edge_uploads: int = 0     # host->device edge-array placements
    queries: int = 0          # estimator runs against a session
    warm_queries: int = 0     # queries that triggered no build and no upload


class GraphSession:
    """One resident graph: edges on device, backend built, ready to query.

    ``estimate(estimator)`` runs any ``DiameterEstimator`` against the
    resident handle; with no argument it runs the paper pipeline
    (``ClusterQuotientEstimator``). Usable as a context manager; ``close()``
    drops the device buffers.
    """

    def __init__(
        self,
        edges: Optional[EdgeList],
        cfg: Optional[GraphEngineConfig] = None,
        *,
        tau: Optional[int] = None,
        tau_solve: Optional[int] = None,
        rebuild_fraction: Optional[float] = None,
        backend: Optional[RelaxBackend] = None,
        metrics: Optional[SessionMetrics] = None,
        delta_stats: Optional[Dict[str, int]] = None,
        autotune: Optional[str] = None,
        store: Optional[EdgeStore] = None,
        checkpoint_dir: Optional[str] = None,
        resume: bool = False,
        guard=None,
    ):
        if tau is not None and tau < 1:
            raise ValueError(f"tau must be >= 1, got {tau}")
        if tau_solve is not None and tau_solve < 2:
            raise ValueError(f"tau_solve must be >= 2, got {tau_solve}")
        if rebuild_fraction is not None and not 0.0 <= rebuild_fraction <= 1.0:
            raise ValueError(
                f"rebuild_fraction must be in [0, 1], got {rebuild_fraction}")
        if edges is None:
            if store is None:
                raise ValueError("GraphSession needs edges or a store")
            edges = store.edge_list()
        # out-of-core storage layer: when present, it (not the raw edge
        # arrays) is the source of truth — the backend binds its buffers,
        # spill()/unspill() move residency, and the stage checkpointer
        # persists its host mirrors alongside the engine planes
        self.store: Optional[EdgeStore] = store
        self._spilled = False
        self._edges: Optional[EdgeList] = edges
        self._edges_fn = None  # dynamic mode: lazy host-mirror thunk
        self._n_nodes = edges.n_nodes
        self._n_edges = edges.n_edges
        # symbolic Delta_init modes pre-resolved over the REAL edges — set
        # by SessionPool so padding self-loops never skew "avg"/"min"
        self._delta_stats = delta_stats
        self.cfg = cfg or GraphEngineConfig()
        self.metrics = metrics if metrics is not None else SessionMetrics()
        self.metrics.sessions_opened += 1

        # -- graph-statistics autotuner (core/autotune.py) ------------------
        # Pin semantics: an explicit ``tau``/``tau_solve`` argument or a
        # numeric ``delta_init`` config always wins; only symbolic/default
        # knobs are tuned. A prebuilt ``backend`` also pins the tiling.
        mode = autotune if autotune is not None else self.cfg.autotune
        if mode not in ("off", "auto", "record"):
            raise ValueError(
                f"autotune must be off | auto | record, got {mode!r}")
        self.tuning = None
        if mode != "off" and edges.n_nodes > 0 and edges.n_edges > 0:
            from repro.core.autotune import get_tuning

            self.tuning = get_tuning(edges, backend=self.cfg.backend,
                                     record=(mode == "record"))
            if self.cfg.delta_init in ("avg", "min"):
                self.cfg = dataclasses.replace(
                    self.cfg, delta_init=str(self.tuning.delta_init))

        # -- decomposition mode (core/engine.py) ----------------------------
        # Same pin semantics: an explicit "stages"/"oneshot" config always
        # wins (the default "stages" stays byte-identical even under
        # autotune); only "auto" defers to the tuning record. Unknown names
        # raise here, before any device work.
        mode_resolved = resolve_engine_mode(self.cfg.mode, self.tuning)
        if mode_resolved != self.cfg.mode:
            self.cfg = dataclasses.replace(self.cfg, mode=mode_resolved)

        # the open/pack cost center: backend construction uploads the edge
        # buffers and (for the Pallas backend) runs the host blocking pass
        with telemetry.span("session.open", nodes=edges.n_nodes,
                            edges=edges.n_edges, mode=self.cfg.mode) as sp:
            if backend is None:
                backend = self._build_backend()
            sp.set(backend=getattr(backend, "kind", "custom"))
        # a prebuilt backend counts too: its construction and edge upload
        # are this session's open cost (they happened, just outside) — the
        # warm-query contract must account for them either way
        self.metrics.backend_builds += 1
        self.metrics.edge_uploads += 1
        self.backend: Optional[RelaxBackend] = backend
        if tau is not None:
            self.tau = tau
        elif self.tuning is not None:
            self.tau = self.tuning.tau
        else:
            self.tau = tau_for(edges.n_nodes, self.cfg.tau_fraction)
        # solve budget for CascadeEstimator: quotients above this many
        # clusters get another decomposition level instead of a direct solve
        if tau_solve is not None:
            self.tau_solve = tau_solve
        elif self.tuning is not None:
            self.tau_solve = self.tuning.tau_solve
        else:
            self.tau_solve = DEFAULT_TAU_SOLVE
        # dynamic updates: dirty fraction beyond which a delete/increase
        # batch triggers a full re-decomposition instead of repair
        self.rebuild_fraction = (rebuild_fraction
                                 if rebuild_fraction is not None
                                 else DEFAULT_REBUILD_FRACTION)
        self._max_weight: Optional[int] = None
        self._flat_edges: Optional[Tuple] = None
        self._dynamic = None  # core.dynamic.DynamicState after apply_updates
        self._closed = False
        # preemption-safe decomposition: a checkpoint_dir arms a
        # StageCheckpointer that the cluster-quotient estimators hand to
        # run_cluster; resume=True picks up the latest stage checkpoint
        # (engine planes + RNG key + store mirrors) for a byte-identical
        # finish after a kill
        self.checkpoint_dir = checkpoint_dir
        self.guard = guard
        self.checkpointer = None
        if checkpoint_dir is not None:
            from repro.core.engine import StageCheckpointer

            self.checkpointer = StageCheckpointer(
                checkpoint_dir, guard=guard, store=store, resume=resume)
        log.debug("opened session: %d nodes, %d edges, tau=%d, backend=%s",
                  edges.n_nodes, edges.n_edges, self.tau,
                  getattr(self.backend, "kind", "custom"))

    def _build_backend(self) -> RelaxBackend:
        """Construct the RelaxBackend over the store (when attached) or the
        raw edges — shared by the open path and ``unspill``."""
        t = self.tuning
        src = self.store if self.store is not None else self.edges
        return make_backend(
            src, self.cfg.backend, comm=self.cfg.comm,
            impl=self.cfg.relax_impl,
            node_tile=self.cfg.node_tile or (t.node_tile if t else 0),
            edge_block=self.cfg.edge_block or (t.edge_block if t else 0),
            fuse=self.cfg.fuse_supersteps or (t.fuse if t else 0))

    # -- resident buffers ---------------------------------------------------

    @property
    def edges(self) -> Optional[EdgeList]:
        """Host edge mirror. On a dynamic session this is materialized
        LAZILY from the device store's host buffers (a 1-edge update must
        not pay an O(E) copy), cached until the next mutation."""
        if self._edges is None and self._edges_fn is not None:
            self._edges = self._edges_fn()
        return self._edges

    @edges.setter
    def edges(self, value: Optional[EdgeList]) -> None:
        self._edges = value

    @property
    def n_nodes(self) -> int:
        return self._n_nodes

    @property
    def n_edges(self) -> int:
        return self._n_edges

    @property
    def max_weight(self) -> int:
        """Largest edge weight, cached for the session's lifetime (the SSSP
        estimators pick their distance dtype from it on every query; pooled
        padding self-loops carry w=1 and cannot change the max)."""
        self._check_open()
        if self._max_weight is None:
            self._max_weight = (int(self.edges.weight.max())
                                if self._n_edges else 1)
        return self._max_weight

    def resolve_delta_init(self, mode: str) -> int:
        """Resolve a symbolic Delta_init ("avg" | "min" | numeric) for this
        graph. Pooled sessions resolve over the REAL (pre-padding) edge
        stats, so per-query overrides match an unpooled session exactly."""
        self._check_open()
        if self._delta_stats is not None and mode in self._delta_stats:
            return self._delta_stats[mode]
        return _initial_delta(self.edges, mode)

    def flat_device_edges(self):
        """Flat device ``(src, dst, weight)`` arrays for the SSSP estimators.

        The single-device backend's own buffers are reused directly; other
        backends hold blocked/sharded layouts with phantom endpoints, so the
        flat view is uploaded ONCE on first use and cached for the session's
        lifetime (counted as one ``edge_uploads``).
        """
        self._check_open()
        import jax.numpy as jnp

        if self._flat_edges is None:
            be = self.backend
            if getattr(be, "kind", None) == "single":
                self._flat_edges = (be.src, be.dst, be.weight)
            else:
                self._flat_edges = (jnp.asarray(self.edges.src),
                                    jnp.asarray(self.edges.dst),
                                    jnp.asarray(self.edges.weight))
                self.metrics.edge_uploads += 1
        return self._flat_edges

    # -- querying -----------------------------------------------------------

    def estimate(self, estimator=None):
        """Run ``estimator`` on this session. Default: the paper pipeline
        (``ClusterQuotientEstimator``) — or, once the session has absorbed
        updates (``apply_updates``), the maintained
        ``DynamicQuotientEstimator``, so post-update queries reuse the
        repaired decomposition instead of re-decomposing. On an autotuned
        session whose record calls for a cascade (``tuning.levels > 0``),
        the default becomes ``CascadeEstimator`` at that depth — the
        solve-superstep win the tuner exists for."""
        self._check_open()
        if estimator is None:
            from repro.core.estimators import (CascadeEstimator,
                                               ClusterQuotientEstimator,
                                               DynamicQuotientEstimator)

            if self._dynamic is not None:
                estimator = DynamicQuotientEstimator()
            elif self.tuning is not None and self.tuning.levels > 0:
                estimator = CascadeEstimator(levels=self.tuning.levels)
            else:
                estimator = ClusterQuotientEstimator()
        return estimator.estimate(self)

    # -- dynamic updates ----------------------------------------------------

    @property
    def dynamic(self):
        """The session's ``DynamicState`` (None until the first
        ``apply_updates`` / ``DynamicQuotientEstimator`` query)."""
        return self._dynamic

    def apply_updates(self, batch, **kw):
        """Absorb an ``UpdateBatch`` into the RESIDENT graph in place:
        scatter the edge mutations onto the device buffers and repair the
        maintained decomposition by bounded incremental relaxation (full
        re-decomposition only when the dirty fraction exceeds
        ``rebuild_fraction``). Returns an ``UpdateReport``; see
        ``core/dynamic.py`` for the algorithm and its certification
        argument (``tighten_cap`` bounds the insert/decrease tightening
        relax)."""
        self._check_open()
        from repro.core.dynamic import apply_updates

        return apply_updates(self, batch, **kw)

    @contextlib.contextmanager
    def track_query(self):
        """Estimator-side hook: counts the query and classifies it warm when
        it triggered no backend build and no edge upload."""
        self._check_open()
        m = self.metrics
        b0, u0 = m.backend_builds, m.edge_uploads
        m.queries += 1
        yield
        if m.backend_builds == b0 and m.edge_uploads == u0:
            m.warm_queries += 1

    # -- spill seam (ROADMAP serving item) ----------------------------------

    @property
    def spilled(self) -> bool:
        return self._spilled

    def spill(self):
        """Drop this session's DEVICE buffers while keeping the host
        mirrors: the store's paired host arrays stay the source of truth,
        so a spilled session costs no accelerator memory but reopens
        transparently — the next query auto-unspills (rebuild + re-upload,
        counted in ``SessionMetrics`` so it is not misread as warm).
        Requires a store-backed session (``open_session(store=...)``)."""
        if self._closed:
            raise RuntimeError("session is closed")
        if self.store is None:
            raise RuntimeError(
                "spill() requires a store-backed session "
                "(open_session(..., store=EdgeStore/GraphStore))")
        if self._dynamic is not None:
            raise RuntimeError(
                "cannot spill a session in dynamic mode: the maintained "
                "decomposition planes are device-resident state")
        if self._spilled:
            return
        # materialize the host edge mirror first — edge_list() reads the
        # host buffers, but the cached EdgeList must exist before the
        # device arrays go away
        self._edges = self.store.edge_list()
        self.store.drop_device()
        self.backend = None
        self._flat_edges = None
        self._spilled = True
        log.debug("session spilled (%d nodes, %d edges host-resident)",
                  self._n_nodes, self._n_edges)

    def unspill(self):
        """Restore device residency after :meth:`spill`: re-upload the
        store buffers and rebuild the backend. No-op when resident."""
        if not self._spilled:
            return
        self._spilled = False
        with telemetry.span("session.unspill", nodes=self._n_nodes,
                            edges=self._n_edges):
            self.store.ensure_device()
            self.backend = self._build_backend()
        self.metrics.backend_builds += 1
        self.metrics.edge_uploads += 1

    # -- lifecycle ----------------------------------------------------------

    def _check_open(self):
        if self._closed:
            raise RuntimeError("session is closed")
        if self._spilled:
            self.unspill()

    def close(self):
        """Release the graph buffers: the device-side backend, flat views
        and dynamic-update state AND the host edge arrays (only the scalar
        shape/config survives, so a closed session costs nothing to keep
        around). Idempotent; any later use raises via ``_check_open``."""
        if self.store is not None:
            self.store.drop_device()
        self.store = None
        self.checkpointer = None
        self.backend = None
        self._flat_edges = None
        self._dynamic = None
        self._edges = None
        self._edges_fn = None
        self._closed = True

    def __enter__(self) -> "GraphSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def open_session(
    edges: Optional[EdgeList] = None,
    cfg: Optional[GraphEngineConfig] = None,
    *,
    tau: Optional[int] = None,
    tau_solve: Optional[int] = None,
    rebuild_fraction: Optional[float] = None,
    backend: Optional[RelaxBackend] = None,
    metrics: Optional[SessionMetrics] = None,
    autotune: Optional[str] = None,
    store: Optional[EdgeStore] = None,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
    guard=None,
) -> GraphSession:
    """Open a graph once for many queries. ``backend`` passes a prebuilt
    ``RelaxBackend`` through (e.g. ``DistributedEngine.make_relax_fn()``);
    otherwise one is constructed from ``cfg.backend``. ``tau_solve`` sets
    the session's cascade solve budget (``CascadeEstimator``);
    ``rebuild_fraction`` its dynamic-update repair-vs-rebuild threshold.
    ``autotune`` ("off" | "auto" | "record") overrides ``cfg.autotune``:
    under auto/record the session derives tau/tau_solve/delta_init/kernel
    tiling from one device statistics pass (``core/autotune.py``), keeping
    any knob you pass explicitly.

    ``store`` binds a :class:`~repro.graph.storage.EdgeStore` /
    ``GraphStore`` as the session's storage layer (``edges`` may then be
    omitted) — enabling ``spill()``/``unspill()`` and letting stage
    checkpoints capture the edge buffers. ``checkpoint_dir`` (+ optional
    ``guard``, a ``runtime.fault.PreemptionGuard``) makes staged
    decompositions preemption-safe; ``resume=True`` continues from the
    latest stage checkpoint for a byte-identical finish."""
    return GraphSession(edges, cfg, tau=tau, tau_solve=tau_solve,
                        rebuild_fraction=rebuild_fraction,
                        backend=backend, metrics=metrics, autotune=autotune,
                        store=store, checkpoint_dir=checkpoint_dir,
                        resume=resume, guard=guard)


# ---------------------------------------------------------------------------
# bucketed padding (shared-compile serving)
# ---------------------------------------------------------------------------


def _pad_edges(edges: EdgeList, e_pad: int) -> EdgeList:
    """Pad the edge arrays to ``e_pad`` with inert self-loops (0 -> 0, w=1).

    A self-loop never wins a relaxation (d[0] + 1 >= d[0]) and is never a
    cross edge in the quotient, so the decomposition and estimate are the
    same as on the unpadded graph — but all graphs in a bucket now share
    one compiled pipeline.

    A graph with NO nodes has no valid endpoint for the padding self-loop:
    a ``0 -> 0`` edge would materialize a phantom node the estimators then
    see through ``flat_device_edges`` — the empty graph stays unpadded.
    """
    e = edges.n_edges
    if e_pad <= e or edges.n_nodes == 0:
        return edges
    pad = e_pad - e
    z = np.zeros(pad, np.int32)
    return EdgeList(
        edges.n_nodes,
        np.concatenate([edges.src, z]),
        np.concatenate([edges.dst, z]),
        np.concatenate([edges.weight, np.ones(pad, np.int32)]),
    )


class SessionPool:
    """Bucketed sessions over many same-shaped graphs, one shared compile.

    ``open(edges)`` pads the edge arrays to a bucket multiple (inert
    self-loops) and resolves ``delta_init`` from the REAL edges first, so
    estimates match an unpooled session exactly while every same-bucket
    session shares the jitted stage/quotient/solve programs.
    ``estimate_many(graphs)`` reproduces the old batch entry point's
    grouping (by node count, padded to the group maximum).

    All sessions share one ``SessionMetrics``, so the pool can answer "did
    any warm query rebuild a backend or re-upload edges?" with a counter.
    """

    def __init__(self, cfg: Optional[GraphEngineConfig] = None,
                 edge_bucket: int = EDGE_BUCKET,
                 tau_solve: Optional[int] = None,
                 rebuild_fraction: Optional[float] = None,
                 checkpoint_dir: Optional[str] = None,
                 shards: int = 0,
                 resume: bool = False,
                 guard=None):
        if tau_solve is not None and tau_solve < 2:
            raise ValueError(f"tau_solve must be >= 2, got {tau_solve}")
        if shards < 0:
            raise ValueError(f"shards must be >= 0, got {shards}")
        self.cfg = cfg or GraphEngineConfig()
        self.edge_bucket = edge_bucket
        self.tau_solve = tau_solve
        self.rebuild_fraction = rebuild_fraction
        # out-of-core / fault-tolerance knobs, threaded into every opened
        # session: ``shards > 1`` backs sessions with a partition-aware
        # GraphStore (capacity pinned to the group's edge bucket via
        # min_capacity, so same-bucket stores still share jit shapes);
        # ``checkpoint_dir`` gives each session its own subdirectory
        # (g0, g1, ...) so pooled checkpoints never collide.
        self.checkpoint_dir = checkpoint_dir
        self.shards = int(shards)
        self.resume = resume
        self.guard = guard
        self.metrics = SessionMetrics()
        self.sessions: List[GraphSession] = []
        self._opened = 0
        self._closed = False

    def _check_open(self):
        if self._closed:
            raise RuntimeError("session pool is closed")

    def _make_session(self, edges: EdgeList, tau: Optional[int],
                      e_pad: Optional[int]) -> GraphSession:
        # two cheap reductions over the real weights cover both symbolic
        # modes AND the config's own delta_init; they must run BEFORE
        # padding (inert w=1 self-loops would skew avg/min) and cost noise
        # next to one decomposition
        stats = {"avg": _initial_delta(edges, "avg"),
                 "min": _initial_delta(edges, "min")}
        delta0 = stats.get(self.cfg.delta_init)
        if delta0 is None:
            delta0 = _initial_delta(edges, self.cfg.delta_init)
        gcfg = dataclasses.replace(self.cfg, delta_init=str(delta0))
        e_pad = e_pad or next_multiple(max(edges.n_edges, 1), self.edge_bucket)
        ckpt_dir = None
        if self.checkpoint_dir is not None:
            ckpt_dir = os.path.join(self.checkpoint_dir, f"g{self._opened}")
        self._opened += 1
        if self.shards > 1:
            # store-backed session: the store's capacity padding (inert
            # self-loop free slots, floored at e_pad) plays the role of
            # _pad_edges, and its slabs/halo drive the sharded layout
            store = GraphStore(edges, n_shards=self.shards,
                               min_capacity=e_pad, bucket=self.edge_bucket)
            return GraphSession(None, gcfg, tau=tau,
                                tau_solve=self.tau_solve,
                                rebuild_fraction=self.rebuild_fraction,
                                metrics=self.metrics, delta_stats=stats,
                                store=store, checkpoint_dir=ckpt_dir,
                                resume=self.resume, guard=self.guard)
        return GraphSession(_pad_edges(edges, e_pad), gcfg, tau=tau,
                            tau_solve=self.tau_solve,
                            rebuild_fraction=self.rebuild_fraction,
                            metrics=self.metrics, delta_stats=stats,
                            checkpoint_dir=ckpt_dir,
                            resume=self.resume, guard=self.guard)

    def open(self, edges: EdgeList, *, tau: Optional[int] = None,
             e_pad: Optional[int] = None) -> GraphSession:
        """Open a RESIDENT session (tracked until ``pool.close()``)."""
        self._check_open()
        sess = self._make_session(edges, tau, e_pad)
        self.sessions.append(sess)
        return sess

    def estimate_many(self, graphs: Sequence[EdgeList], estimator=None,
                      tau: Optional[int] = None) -> List:
        """Open + query every graph, grouped by node count so each group is
        padded to ONE bucketed edge size and shares one compiled pipeline.

        One-shot: each session is closed (buffers dropped) right after its
        query and never registered with the pool, so memory stays at ONE
        graph's buffers no matter how many graphs stream through — the
        compiled programs, the expensive part, outlive the sessions in the
        jit cache. Keep sessions resident via ``pool.open()`` when serving
        repeat queries.
        """
        self._check_open()
        if tau is not None and tau < 1:
            raise ValueError(f"tau must be >= 1, got {tau}")
        results: List = [None] * len(graphs)
        by_n: Dict[int, List[int]] = {}
        for i, g in enumerate(graphs):
            by_n.setdefault(g.n_nodes, []).append(i)
        for n, idxs in by_n.items():
            e_pad = next_multiple(
                max(graphs[i].n_edges for i in idxs) or 1, self.edge_bucket)
            group_tau = tau if tau is not None else tau_for(
                n, self.cfg.tau_fraction)
            for i in idxs:
                sess = self._make_session(graphs[i], group_tau, e_pad)
                try:
                    results[i] = sess.estimate(estimator)
                finally:
                    sess.close()
        return results

    def close(self):
        """Close every pooled session and retire the pool. Idempotent —
        repeated closes are no-ops; any later ``open``/``estimate_many``
        (or a query on a previously pooled session) raises a clean
        ``RuntimeError`` instead of resurrecting freed buffers."""
        if self._closed:
            return
        for s in self.sessions:
            s.close()
        self.sessions.clear()
        self._closed = True

    def __enter__(self) -> "SessionPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
