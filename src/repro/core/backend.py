"""RelaxBackend: one Δ-growing engine, three interchangeable executions.

The decomposition engine (``core/engine.py``) operates on the canonical
plane-based state (``EngineState``, padded once per decomposition by
``state.pad_state``) and delegates every grow call to a backend:

  * ``SingleDeviceBackend`` — flat edge arrays + the jitted
    ``partial_growth`` while_loop (today's laptop path);
  * ``ShardedBackend`` — wraps ``DistributedEngine`` (allgather or halo
    shard_map supersteps on a device mesh);
  * ``PallasBackend`` — routes the local relax through the fused
    ``kernels/edge_relax`` kernel (Pallas on TPU, jnp oracle elsewhere).

All three share the same per-edge candidate rule
(``kernels/edge_relax/ref.edge_relax_candidates``) and the same
lexicographic (d, c, pathw) tuple-min, so for a fixed seed they produce
byte-identical decompositions. ``grow`` is traceable: the engine calls it
from inside one jitted per-stage program, so a stage costs a single host
synchronization regardless of how many supersteps or Δ-doublings it runs.

``transfers`` counts host->device state placements (the pack/pad the seed
engine paid on every grow call); the engine bench asserts it is at most one
per ``cluster()`` call.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.delta_growing import GrowthStats, growth_loop, partial_growth
from repro.core.state import EngineState, init_state, pad_state, relay_planes
from repro.graph.storage import EdgeStore, GraphStore
from repro.graph.structures import EdgeList


@runtime_checkable
class RelaxBackend(Protocol):
    """What the decomposition engine needs from an execution backend."""

    kind: str          # "single" | "sharded" | "pallas"
    n_nodes: int       # real node count
    n_pad: int         # padded plane length (backend-specific layout)
    transfers: int     # host->device state placements (pack/pad events)

    def init_state(self) -> EngineState:
        """Padded, device-resident initial planes. Called once per
        decomposition — the ONLY place planes are packed/padded."""
        ...

    def grow(
        self,
        state: EngineState,
        delta: jnp.ndarray,
        half_target: jnp.ndarray,
        num_it: jnp.ndarray,
        variant: str,
    ) -> Tuple[EngineState, GrowthStats]:
        """One PartialGrowth call on the padded planes. Must be traceable
        (the engine invokes it inside its jitted stage program)."""
        ...

    def grow_spec(self) -> "GrowSpec":
        """Hashable-by-value jit cache key for the engine's stage program."""
        ...

    def graph_args(self) -> Tuple[jnp.ndarray, ...]:
        """Device edge arrays, passed as TRACED operands through the stage
        jit — so re-clustering the same-shaped graph (even via a fresh
        backend instance) hits the compile cache instead of retracing."""
        ...

    def quotient_args(self) -> Tuple[jnp.ndarray, ...]:
        """Flat device ``(src, dst, weight, mask)`` edge views for the
        quotient pass (``core/quotient.py``) — the SAME device buffers the
        backend already holds, so building the quotient costs no host
        round-trip. ``mask`` marks real (non-padding) edges; padded entries
        may carry phantom node ids >= n_nodes."""
        ...


class GrowSpec(tuple):
    """(kind, *static_meta) — the static half of a backend's grow call.

    Value-hashable for the single/pallas kinds, so distinct backend
    instances over same-shaped graphs share one compiled stage program. The
    sharded kind embeds its (long-lived) backend instance, which keys by
    identity — reusing a DistributedEngine reuses its compilation.
    """

    def __new__(cls, *items):
        return super().__new__(cls, items)


def dispatch_grow(spec: GrowSpec, graph_args, state, delta, half_target,
                  num_it, variant: str):
    """Route a grow call from (static spec, traced graph arrays)."""
    kind = spec[0]
    if kind == "single":
        (n_pad,) = spec[1:]
        src, dst, weight = graph_args
        return partial_growth(state, src, dst, weight,
                              jnp.int32(delta), jnp.int32(half_target),
                              jnp.int32(num_it), n_pad, variant=variant)
    if kind == "pallas":
        n_tiles, node_tile, edge_block, impl, fuse = spec[1:]
        bsrc, bdst, bw, bmask, btile = graph_args
        if fuse:
            return _megakernel_growth(state, bsrc, bdst, bw, bmask, btile,
                                      jnp.int32(delta), jnp.int32(half_target),
                                      jnp.int32(num_it), n_tiles, node_tile,
                                      edge_block, impl, fuse, variant)
        return _pallas_growth(state, bsrc, bdst, bw, bmask, btile,
                              jnp.int32(delta), jnp.int32(half_target),
                              jnp.int32(num_it), n_tiles, node_tile,
                              edge_block, impl, variant)
    if kind == "sharded":
        (backend,) = spec[1:]
        return backend.grow(state, delta, half_target, num_it, variant)
    raise ValueError(f"unknown grow spec kind {kind!r}")


# ---------------------------------------------------------------------------
# single device
# ---------------------------------------------------------------------------


class SingleDeviceBackend:
    """Flat destination-indexed edge arrays + jitted while_loop growth.

    Accepts either a host ``EdgeList`` (uploaded here, the classic path)
    or an ``EdgeStore``/``GraphStore`` — then the store's RESIDENT device
    buffers are bound directly (no re-upload; inert free slots are the
    same 0->0/w=1 padding pooled sessions use, invisible to relaxation)
    and the store keeps ownership: dynamic updates scatter in place and
    ``rebind`` after capacity growth.
    """

    kind = "single"

    def __init__(self, edges):
        if isinstance(edges, EdgeStore):
            store = edges
            store.ensure_device()
            self.n_nodes = store.n_nodes
            self.n_pad = store.n_nodes
            self.src = store.src
            self.dst = store.dst
            self.weight = store.weight
            self.transfers = 0
            return
        self.n_nodes = edges.n_nodes
        self.n_pad = edges.n_nodes
        self.src = jnp.asarray(edges.src)
        self.dst = jnp.asarray(edges.dst)
        self.weight = jnp.asarray(edges.weight)
        self.transfers = 0

    @classmethod
    def from_device(cls, n_nodes: int, src: jnp.ndarray, dst: jnp.ndarray,
                    weight: jnp.ndarray) -> "SingleDeviceBackend":
        """Wrap ALREADY-RESIDENT device edge arrays (int32, inert-padded)
        — the cascade re-enters the engine on a quotient level without a
        host round-trip or re-upload (``core/quotient.QuotientLevel``)."""
        be = cls.__new__(cls)
        be.n_nodes = n_nodes
        be.n_pad = n_nodes
        be.src, be.dst, be.weight = src, dst, weight
        be.transfers = 0
        return be

    def rebind(self, src: jnp.ndarray, dst: jnp.ndarray,
               weight: jnp.ndarray) -> None:
        """Swap the resident edge arrays IN PLACE (dynamic updates mutate
        the graph under a live backend: scatter-updated buffers keep their
        shape and every compiled program; a capacity-grown store re-lands
        here with a longer shape, costing one retrace per capacity bucket).
        Node count and grow spec are unchanged — only the edges move."""
        self.src, self.dst, self.weight = src, dst, weight

    def init_state(self) -> EngineState:
        self.transfers += 1
        return init_state(self.n_pad)

    def grow_spec(self) -> GrowSpec:
        return GrowSpec("single", self.n_pad)

    def graph_args(self):
        return (self.src, self.dst, self.weight)

    def quotient_args(self):
        return (self.src, self.dst, self.weight,
                jnp.ones(self.src.shape, dtype=bool))

    def grow(self, state, delta, half_target, num_it, variant):
        return partial_growth(
            state, self.src, self.dst, self.weight,
            jnp.int32(delta), jnp.int32(half_target), jnp.int32(num_it),
            self.n_pad, variant=variant,
        )


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=(
    "n_tiles", "node_tile", "edge_block", "impl", "variant"))
def _pallas_growth(
    state: EngineState,
    bsrc, bdst, bw, bmask, block_tile,
    delta, half_target, num_it,
    n_tiles: int, node_tile: int, edge_block: int, impl: str,
    variant: str,
):
    """PartialGrowth where each superstep is one fused edge_relax pass."""
    from repro.kernels.edge_relax.ops import edge_relax

    rw0, rc, rp, frozen = relay_planes(state)

    def relax_step(s):
        return edge_relax(
            (s.d, s.c, s.pathw, rw0, rc, rp),
            bsrc, bdst, bw, bmask, block_tile, delta,
            n_tiles, node_tile=node_tile, edge_block=edge_block, impl=impl,
        )

    return growth_loop(state, relax_step, frozen, delta, half_target, num_it,
                       variant)


@partial(jax.jit, static_argnames=(
    "n_tiles", "node_tile", "edge_block", "impl", "fuse", "variant"))
def _megakernel_growth(
    state: EngineState,
    bsrc, bdst, bw, bmask, block_tile,
    delta, half_target, num_it,
    n_tiles: int, node_tile: int, edge_block: int, impl: str, fuse: int,
    variant: str,
):
    """PartialGrowth where each while-body is ONE persistent fused kernel
    running up to ``fuse`` supersteps with resident planes + on-chip stop
    rule (``kernels/edge_relax/megakernel.py``)."""
    from repro.kernels.edge_relax.megakernel import megakernel_growth_loop

    interpret = impl != "pallas" or jax.default_backend() != "tpu"
    return megakernel_growth_loop(
        state, bsrc, bdst, bw, bmask, block_tile,
        delta, half_target, num_it,
        n_tiles, node_tile, edge_block,
        k_fused=fuse, interpret=interpret, variant=variant)


class PallasBackend:
    """Blocked dst-sorted edge layout + fused one-pass relax kernel.

    ``fuse > 0`` switches grow calls to the persistent megakernel: each
    while-loop body runs up to ``fuse`` supersteps in one pallas_call with
    VMEM-resident planes and an on-chip frontier bitmap. Off TPU the
    megakernel runs in interpret mode (parity/testing only — slow).
    """

    kind = "pallas"

    def __init__(self, edges: EdgeList, impl: str = "auto",
                 node_tile: Optional[int] = None,
                 edge_block: Optional[int] = None,
                 fuse: int = 0):
        from repro.kernels.edge_relax.kernel import (
            EDGE_BLOCK, NODE_TILE, validate_tiling)
        from repro.kernels.edge_relax.ops import block_edges_host

        self.node_tile = node_tile or NODE_TILE
        self.edge_block = edge_block or EDGE_BLOCK
        validate_tiling(self.node_tile, self.edge_block)
        if impl == "auto":
            impl = "pallas" if jax.default_backend() == "tpu" else "ref"
        self.impl = impl
        blk = block_edges_host(edges.src, edges.dst, edges.weight,
                               edges.n_nodes, self.node_tile, self.edge_block)
        self.n_nodes = edges.n_nodes
        self.n_pad = blk["n_pad_nodes"]
        self.n_tiles = blk["n_tiles"]
        if fuse:
            from repro.kernels.edge_relax.megakernel import fits_vmem
            if fuse < 0:
                raise ValueError(f"fuse must be >= 0, got {fuse}")
            if not fits_vmem(self.n_pad, self.node_tile, self.edge_block):
                import warnings
                warnings.warn(
                    f"megakernel resident planes for n_pad={self.n_pad} "
                    "exceed the VMEM budget; falling back to the unfused "
                    "pallas grow path", RuntimeWarning, stacklevel=2)
                fuse = 0
        self.fuse = int(fuse)
        self._bsrc = jnp.asarray(blk["src"])
        self._bdst = jnp.asarray(blk["dst"])
        self._bw = jnp.asarray(blk["w"])
        self._bmask = jnp.asarray(blk["mask"])
        self._btile = jnp.asarray(blk["block_tile"])
        self.transfers = 0

    def init_state(self) -> EngineState:
        self.transfers += 1
        return pad_state(init_state(self.n_nodes), self.n_pad)

    def grow_spec(self) -> GrowSpec:
        return GrowSpec("pallas", self.n_tiles, self.node_tile,
                        self.edge_block, self.impl, self.fuse)

    def graph_args(self):
        return (self._bsrc, self._bdst, self._bw, self._bmask, self._btile)

    def quotient_args(self):
        # the blocked layout, flattened: padding slots point at the phantom
        # node and are masked out
        return (self._bsrc.reshape(-1), self._bdst.reshape(-1),
                self._bw.reshape(-1), self._bmask.reshape(-1).astype(bool))

    def grow(self, state, delta, half_target, num_it, variant):
        if self.fuse:
            return _megakernel_growth(
                state, self._bsrc, self._bdst, self._bw, self._bmask,
                self._btile, jnp.int32(delta), jnp.int32(half_target),
                jnp.int32(num_it), self.n_tiles, self.node_tile,
                self.edge_block, self.impl, self.fuse, variant,
            )
        return _pallas_growth(
            state, self._bsrc, self._bdst, self._bw, self._bmask, self._btile,
            jnp.int32(delta), jnp.int32(half_target), jnp.int32(num_it),
            self.n_tiles, self.node_tile, self.edge_block, self.impl,
            variant,
        )


# ---------------------------------------------------------------------------
# sharded (allgather / halo)
# ---------------------------------------------------------------------------


class ShardedBackend:
    """Wraps ``DistributedEngine``: shard_map supersteps on a device mesh.

    The canonical planes live sharded on the mesh; each grow call derives the
    relay planes (elementwise, on device) and runs the engine's jitted
    superstep while_loop. No per-grow pack or host round-trip.
    """

    kind = "sharded"

    def __init__(self, engine):
        self.eng = engine
        self.n_nodes = engine.graph.n_nodes
        self.n_pad = engine.graph.n_pad
        self.transfers = 0

    def init_state(self) -> EngineState:
        self.transfers += 1
        st = pad_state(init_state(self.n_nodes), self.n_pad)
        ns = self.eng.node_sharding()
        return EngineState(*(jax.device_put(x, ns) for x in st))

    def grow_spec(self) -> GrowSpec:
        # identity-keyed: the mesh/shard_map closures live on the (long-
        # lived) DistributedEngine, so reuse of the engine reuses the
        # compiled stage program.
        return GrowSpec("sharded", self)

    def graph_args(self):
        return ()

    def quotient_args(self):
        # per-device [P, E_loc] shards, flattened with destinations mapped
        # back to global ids (dst_local + owner * nodes_per_device)
        g = self.eng.graph
        P = g.src.shape[0]
        offs = (jnp.arange(P, dtype=jnp.int32)
                * jnp.int32(g.nodes_per_device))[:, None]
        return (g.src.reshape(-1), (g.dst_local + offs).reshape(-1),
                g.weight.reshape(-1), g.edge_mask.reshape(-1).astype(bool))

    def grow(self, state, delta, half_target, num_it, variant):
        rw0, rc, rp, frozen = relay_planes(state)
        planes = (state.d, state.c, state.pathw, rw0, rc, rp, frozen)
        planes, k, reached, changed = self.eng._growth(
            planes, self.eng.gparts, jnp.int32(delta),
            jnp.int32(half_target), jnp.int32(num_it), variant=variant,
        )
        state = state._replace(d=planes[0], c=planes[1], pathw=planes[2])
        return state, GrowthStats(steps=k, reached=reached,
                                  changed_last=changed)

    # -- wire-byte accounting (read by engine._comm_accounting) ----------

    @property
    def halo_bytes_per_step(self) -> int:
        """Collective plane-row bytes one superstep moves under the
        engine's comm mode — exact: the plan is static, no sync needed."""
        return self.eng.comm_bytes_per_superstep()

    @property
    def fullplane_bytes_per_step(self) -> int:
        """What the full-plane all-gather baseline would move."""
        return self.eng.fullplane_bytes_per_superstep()


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------


def make_backend(
    edges,
    spec="single",
    *,
    mesh=None,
    comm: str = "halo",
    impl: str = "auto",
    node_tile: int = 0,
    edge_block: int = 0,
    fuse: int = 0,
) -> RelaxBackend:
    """Resolve a backend from a config spec (or pass one through).

    ``edges`` may be an ``EdgeList`` or a ``graph.storage`` store: the
    single kind binds the store's resident device buffers directly, the
    sharded kind reuses a ``GraphStore``'s prebuilt slab/halo layout via
    ``sharded_graph()`` when the shard count matches the mesh, and the
    pallas kind re-blocks from the store's valid edges.

    ``comm`` defaults to ``"halo"``: supersteps exchange ONLY the static
    halo plan's boundary plane rows (``"allgather"`` — the full-plane
    baseline the halo_bytes metric is measured against — remains
    selectable and byte-identical in results).

    ``node_tile`` / ``edge_block`` / ``fuse`` apply to the pallas kind only
    (0 = kernel defaults / unfused); typically filled in by the autotuner.
    """
    if not isinstance(spec, str):
        return spec  # already a RelaxBackend
    store = edges if isinstance(edges, EdgeStore) else None
    if spec in ("", "single"):
        return SingleDeviceBackend(edges)
    if spec == "pallas":
        e = store.edge_list() if store is not None else edges
        return PallasBackend(e, impl=impl, node_tile=node_tile or None,
                             edge_block=edge_block or None, fuse=fuse)
    if spec == "sharded":
        from repro.core.distributed import DistributedEngine

        if mesh is None:
            from repro.launch.mesh import host_device_mesh

            mesh = host_device_mesh()
        graph = None
        if isinstance(store, GraphStore) and store.n_shards > 1:
            graph = store.sharded_graph(build_halo=(comm == "halo"))
        e = store.edge_list() if store is not None else edges
        return ShardedBackend(DistributedEngine(e, mesh, comm=comm,
                                                graph=graph))
    raise ValueError(f"unknown backend {spec!r} "
                     "(expected single | sharded | pallas)")
