"""Quotient graph construction and local diameter solve (paper Section 4).

Nodes of G_C are clusters; for each original edge (u, v) with c_u != c_v the
quotient edge weight is w(u,v) + dist(c_u, u) + dist(c_v, v) (we use the
engine's realized path weights, which upper-bound the dists, keeping the
estimate conservative). Parallel edges keep the minimum.

The paper picks tau so the quotient fits in one reducer's local memory and is
solved locally in O(1) rounds. We mirror that fully on device:

  * ``_quotient_kernel`` — one jitted segment-ops pass over the backend's
    device edge arrays (cross-edge detection, key sort, (cluster, cluster)
    coalescing via the engine's lexicographic tuple-min from
    ``graph/segment_ops.py``). No host round-trip; composes with
    SingleDevice/Sharded/Pallas through ``backend.quotient_args()``.
  * ``_solve_kernel`` — batched multi-source SSSP (``sssp.batched_bf_loop``
    vmapped over all quotient sources), int64-safe (traced under
    ``jax.experimental.enable_x64``), returning
    (diameter, eccentricities, connected) in ONE packed fetch.

scipy APSP (``quotient_diameter``) is kept as the test oracle only; the
jnp min-plus fallback is int64-safe and shares the (diameter, connected)
contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.analysis import guard
from repro.common import next_multiple
from repro.core.cluster import Decomposition
from repro.graph.segment_ops import segment_min_triple
from repro.graph.structures import MAX_WEIGHT, EdgeList, weight_scale_for

# Unreached sentinel for the int64 solve. Guarded adds keep everything
# strictly below 2 * INF64 < 2^63, so int64 arithmetic never overflows.
INF64 = np.int64(2**62)
# k is padded to a multiple of this (and m to a multiple of 8x) so the solve
# program re-compiles only per size bucket, not per graph.
K_BUCKET = 16
# cascade levels pad the quotient edge arrays to a multiple of this so the
# per-level engine programs recompile only per size bucket
LEVEL_EDGE_BUCKET = 256


@dataclass
class QuotientGraph:
    n_clusters: int
    center_ids: np.ndarray  # original node id of each quotient node
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray  # int64 (sums of three int32 terms)


class DeviceQuotient(NamedTuple):
    """Device-resident quotient: fixed [E]-length arrays + scalar counters.

    Edges are sorted by (cluster, cluster) key with exactly the first
    ``n_edges`` slots valid; invalid slots carry weight INF64 and sentinel
    endpoints, so slicing to any padded length >= n_edges stays sound.
    """

    centers: jnp.ndarray     # int32 [n], first n_clusters slots valid
    src: jnp.ndarray         # int32 [E] compact cluster labels
    dst: jnp.ndarray         # int32 [E]
    weight: jnp.ndarray      # int64 [E], INF64 on invalid slots
    n_clusters: jnp.ndarray  # int32 scalar (on device)
    n_edges: jnp.ndarray     # int32 scalar (on device)
    max_weight: jnp.ndarray  # int64 scalar — lets the solve pick an int32
                             # fast path when k_pad * max_weight < 2^31
    weight_sum: jnp.ndarray  # int64 scalar, sum of coalesced quotient
                             # weights — the cascade derives Delta_init and
                             # max_delta for the next level from it without
                             # an extra fetch


def build_quotient_numpy(edges: EdgeList, dec: Decomposition) -> QuotientGraph:
    """Host numpy reference (the parity oracle for the jitted pass)."""
    centers, inverse = np.unique(dec.final_c, return_inverse=True)
    k = len(centers)
    cu = inverse[edges.src]
    cv = inverse[edges.dst]
    cross = cu != cv
    cu, cv = cu[cross], cv[cross]
    wq = (
        edges.weight[cross].astype(np.int64)
        + dec.final_pathw[edges.src[cross]].astype(np.int64)
        + dec.final_pathw[edges.dst[cross]].astype(np.int64)
    )
    # min-coalesce parallel quotient edges
    key = cu.astype(np.int64) * k + cv.astype(np.int64)
    order = np.lexsort((wq, key))
    key_s = key[order]
    first = np.ones(len(key_s), dtype=bool)
    if len(key_s):
        first[1:] = key_s[1:] != key_s[:-1]
    idx = order[first]
    return QuotientGraph(
        n_clusters=k,
        center_ids=centers,
        src=cu[idx].astype(np.int32),
        dst=cv[idx].astype(np.int32),
        weight=wq[idx],
    )


@partial(jax.jit, static_argnames=("n",))
def _quotient_kernel(src, dst, w, mask, final_c, final_pathw, *, n: int):
    """One segment-ops pass: cross-edge detect -> key sort -> coalesce.

    ``src``/``dst`` may contain phantom ids >= n (Pallas/sharded padding);
    ``mask`` marks real edges. Traced under enable_x64, so the quotient
    weight (a sum of three int32 terms) is exact int64.
    """
    E = src.shape[0]
    centers, inverse = jnp.unique(
        final_c, size=n, fill_value=jnp.int32(n), return_inverse=True)
    k = jnp.sum(centers < n).astype(jnp.int32)
    valid = mask.astype(bool) & (src >= 0) & (src < n) & (dst >= 0) & (dst < n)
    su = jnp.clip(src, 0, n - 1)
    sv = jnp.clip(dst, 0, n - 1)
    cu = inverse[su].astype(jnp.int32)
    cv = inverse[sv].astype(jnp.int32)
    cross = valid & (cu != cv)
    wq = (w.astype(jnp.int64)
          + final_pathw[su].astype(jnp.int64)
          + final_pathw[sv].astype(jnp.int64))
    wq = jnp.where(cross, wq, jnp.int64(INF64))
    key_inf = jnp.int64(INF64)
    key = jnp.where(
        cross, cu.astype(jnp.int64) * (n + 1) + cv.astype(jnp.int64), key_inf)
    order = jnp.lexsort((wq, key))
    key_s, wq_s = key[order], wq[order]
    cu_s, cv_s = cu[order], cv[order]
    valid_s = key_s < key_inf
    first = valid_s & jnp.concatenate(
        [jnp.ones((1,), bool), key_s[1:] != key_s[:-1]])
    seg = jnp.clip(jnp.cumsum(first) - 1, 0, max(E - 1, 0)).astype(jnp.int32)
    # coalesce parallel (cluster, cluster) edges with the engine's
    # lexicographic tuple-min (within a segment cu/cv are constant, so the
    # tie-break passes just carry the endpoints through)
    q_w, q_src, q_dst = segment_min_triple(
        jnp.where(valid_s, wq_s, jnp.int64(INF64)),
        jnp.where(valid_s, cu_s, jnp.int32(n)),
        jnp.where(valid_s, cv_s, jnp.int32(n)),
        seg, num_segments=max(E, 1),
    )
    n_q = jnp.sum(first).astype(jnp.int32)
    q_w = q_w[:E]
    return DeviceQuotient(
        centers=centers.astype(jnp.int32),
        src=q_src[:E], dst=q_dst[:E], weight=q_w,
        n_clusters=k, n_edges=n_q,
        max_weight=jnp.max(jnp.where(cross, wq, jnp.int64(0))),
        weight_sum=jnp.sum(jnp.where(q_w < key_inf, q_w, jnp.int64(0))),
    )


def fetch_quotient_counters(dq: DeviceQuotient) -> Tuple[int, int, int, int]:
    """ONE packed host fetch of the four device counters:
    ``(n_clusters, n_edges, max_weight, weight_sum)``. Callers account the
    sync (``PipelineMetrics.quotient_syncs``) themselves."""
    with enable_x64():
        kmws = guard.fetch(jnp.stack([
            dq.n_clusters.astype(jnp.int64), dq.n_edges.astype(jnp.int64),
            dq.max_weight, dq.weight_sum]),
            reason="quotient: packed (k, m, wmax, wsum) counters")
    return int(kmws[0]), int(kmws[1]), int(kmws[2]), int(kmws[3])


def _flat_quotient_args(edges: EdgeList):
    """Fallback device edge arrays when the backend doesn't expose its own."""
    return (jnp.asarray(edges.src), jnp.asarray(edges.dst),
            jnp.asarray(edges.weight),
            jnp.ones((edges.n_edges,), dtype=bool))


def _decomposition_planes(dec: Decomposition, n: int):
    fc = dec.final_c_dev if dec.final_c_dev is not None else jnp.asarray(dec.final_c)
    fp = (dec.final_pathw_dev if dec.final_pathw_dev is not None
          else jnp.asarray(dec.final_pathw))
    return fc[:n], fp[:n]


def build_quotient_device(
    edges: EdgeList,
    dec: Decomposition,
    backend=None,
) -> Optional[DeviceQuotient]:
    """Run the jitted quotient pass on the backend's device edge arrays.

    Returns None for graphs with no nodes or no edges (host shortcut — the
    quotient is trivially empty). Zero host syncs: the counters stay on
    device until the caller fetches them.
    """
    n = edges.n_nodes
    if n == 0 or edges.n_edges == 0:
        return None
    if backend is not None and hasattr(backend, "quotient_args"):
        src, dst, w, mask = backend.quotient_args()
    else:
        src, dst, w, mask = _flat_quotient_args(edges)
    fc, fp = _decomposition_planes(dec, n)
    with enable_x64():
        return _quotient_kernel(src, dst, w, mask, fc, fp, n=n)


def build_quotient(edges: EdgeList, dec: Decomposition, backend=None) -> QuotientGraph:
    """Device-backed quotient construction, materialized to the host
    ``QuotientGraph`` (same edge order and dtypes as the numpy oracle —
    edge-for-edge comparable). The fused pipeline in ``core/diameter.py``
    skips this materialization and feeds ``DeviceQuotient`` straight into
    the solve."""
    dq = build_quotient_device(edges, dec, backend=backend)
    if dq is None:
        centers = (np.unique(dec.final_c) if edges.n_nodes
                   else np.array([], np.int32))
        z = np.array([], np.int32)
        return QuotientGraph(
            n_clusters=len(centers), center_ids=centers.astype(np.int32),
            src=z, dst=z, weight=z.astype(np.int64))
    k, m = map(int, guard.fetch(jnp.stack([dq.n_clusters, dq.n_edges]),
                                reason="host quotient: (k, m) counters"))
    with enable_x64():  # int64 arrays must be sliced with x64 tracing on
        return QuotientGraph(
            n_clusters=k,
            center_ids=np.asarray(dq.centers[:k]),
            src=np.asarray(dq.src[:m]),
            dst=np.asarray(dq.dst[:m]),
            weight=np.asarray(dq.weight[:m]),
        )


# ---------------------------------------------------------------------------
# cascade levels: re-enter the engine on the quotient itself
# ---------------------------------------------------------------------------


class QuotientLevel(NamedTuple):
    """A ``DeviceQuotient`` re-expressed in the engine's edge layout: flat
    int32 device arrays over ``n_nodes = k`` compact cluster labels, padding
    slots rewritten as inert self-loops (0 -> 0, w = 1).

    Quotient weights are int64 sums while the engine's ``EngineState``
    planes are int32, so weights are rescaled by ``scale`` (ceiling
    division — conservative: ``scale * dist_rescaled >= dist_true`` for
    every pair, so upper bounds survive the cascade). ``scale`` is 1
    whenever the level already fits int32.
    """

    n_nodes: int          # k (host)
    n_edges: int          # real quotient edge count m (host)
    src: jnp.ndarray      # int32 [e_pad]
    dst: jnp.ndarray      # int32 [e_pad]
    weight: jnp.ndarray   # int32 [e_pad], ceil(w / scale); 1 on padding
    scale: int            # original units = scale * level units
    weight_sum: int       # upper bound on sum(weight) in LEVEL units

    def to_edgelist(self) -> EdgeList:
        """Host materialization (tests / oracles): the first ``n_edges``
        slots are exactly the coalesced quotient edges."""
        m = self.n_edges
        with enable_x64():
            return EdgeList(
                self.n_nodes,
                np.asarray(self.src[:m]), np.asarray(self.dst[:m]),
                np.asarray(self.weight[:m]))


@jax.jit
def _level_edges_kernel(src, dst, w, scale):
    """Rewrite sliced DeviceQuotient buffers as engine-ready edges: valid
    slots keep their endpoints with ceil-rescaled int32 weight, invalid
    slots (weight >= INF64, incl. the empty-segment int64-max fill) become
    inert self-loops. Traced under enable_x64 (w is int64)."""
    valid = w < jnp.int64(INF64)
    w32 = jnp.where(valid, (w + scale - 1) // scale, jnp.int64(1))
    w32 = jnp.clip(w32, 1, jnp.int64(int(MAX_WEIGHT))).astype(jnp.int32)
    s = jnp.where(valid, src, jnp.int32(0))
    t = jnp.where(valid, dst, jnp.int32(0))
    return s, t, w32


def quotient_as_edgelist(
    dq: DeviceQuotient, k: int, m: int, max_weight: int, weight_sum: int = 0,
    *, edge_bucket: int = LEVEL_EDGE_BUCKET,
) -> QuotientLevel:
    """Adapter: ``DeviceQuotient`` buffers -> the engine's edge layout,
    entirely on device (no host round-trip — the (k, m, max_weight,
    weight_sum) counters must already be fetched).

    Edge arrays are sliced to an ``edge_bucket`` multiple so same-scale
    levels share one compiled stage program. ``weight_sum`` (level units)
    uses the ceil-sum bound ``sum(ceil(w/s)) <= sum(w)/s + m``.
    """
    scale = weight_scale_for(max_weight)
    E = dq.src.shape[0]
    e_pad = min(next_multiple(max(m, 1), edge_bucket), max(E, 1))
    with enable_x64():
        src, dst, w32 = _level_edges_kernel(
            dq.src[:e_pad], dq.dst[:e_pad], dq.weight[:e_pad],
            jnp.int64(scale))
    ws = int(weight_sum) // scale + m
    return QuotientLevel(n_nodes=k, n_edges=m, src=src, dst=dst, weight=w32,
                         scale=scale, weight_sum=ws)


def build_quotient_from_level(level: QuotientLevel, dec: Decomposition
                              ) -> DeviceQuotient:
    """One more cascade level: the jitted quotient pass over a level's
    device edge arrays and its decomposition's device planes. Padding
    self-loops are never cross edges, so no mask is needed beyond ones."""
    fc, fp = _decomposition_planes(dec, level.n_nodes)
    mask = jnp.ones(level.src.shape, dtype=bool)
    with enable_x64():
        return _quotient_kernel(level.src, level.dst, level.weight, mask,
                                fc, fp, n=level.n_nodes)


# ---------------------------------------------------------------------------
# incremental refresh: recompute only the keys touching dirty clusters
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n",))
def _merge_quotient_kernel(cs, cd, cw, fs, fd, fw, dirty_compact, *, n: int):
    """Merge a cached quotient's CLEAN entries with freshly recomputed
    dirty-side entries.

    ``dirty_compact`` is a bool [n] mask over compact cluster labels. Every
    cached entry touching a dirty cluster is dropped (its contributing
    edges, endpoint assignments, or path-weight certificates may have
    changed); the fresh entries — produced by ``_quotient_kernel`` over
    exactly the dirty-incident edge slice — cover all such pairs, so the
    two sets are DISJOINT by construction and a key sort (no re-coalesce)
    restores the ``DeviceQuotient`` sorted-key invariant. Traced under
    enable_x64 (weights are int64).
    """
    drop = (dirty_compact[jnp.clip(cs, 0, n - 1)]
            | dirty_compact[jnp.clip(cd, 0, n - 1)])
    keep = (cw < jnp.int64(INF64)) & ~drop
    src = jnp.concatenate([jnp.where(keep, cs, jnp.int32(n)), fs])
    dst = jnp.concatenate([jnp.where(keep, cd, jnp.int32(n)), fd])
    w = jnp.concatenate([jnp.where(keep, cw, jnp.int64(INF64)), fw])
    valid = w < jnp.int64(INF64)
    key = jnp.where(
        valid, src.astype(jnp.int64) * (n + 1) + dst.astype(jnp.int64),
        jnp.int64(INF64))
    order = jnp.argsort(key)
    src, dst, w = src[order], dst[order], w[order]
    valid = w < jnp.int64(INF64)
    return (src, dst, w,
            jnp.sum(valid).astype(jnp.int32),
            jnp.max(jnp.where(valid, w, jnp.int64(0))),
            jnp.sum(jnp.where(valid, w, jnp.int64(0))))


def quotient_update_device(
    cached: DeviceQuotient,
    m_cached: int,
    dirty_edge_args: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray],
    final_c_dev: jnp.ndarray,
    final_pathw_dev: jnp.ndarray,
    dirty_center_ids: np.ndarray,
    n: int,
) -> DeviceQuotient:
    """Incremental quotient refresh (the dynamic-update fast path).

    Only the (cluster, cluster) keys touching a dirty cluster are
    recomputed: ``dirty_edge_args`` is the (small, padded) device slice of
    edges with a dirty-cluster endpoint, run through the SAME
    ``_quotient_kernel`` as a full build; the cached quotient contributes
    every clean-clean pair unchanged. ONLY sound when the cluster (center)
    set is identical to the cached build's — the compact label spaces must
    agree — which the caller guarantees (a changed cluster set forces a
    full rebuild of the quotient).
    """
    sub_src, sub_dst, sub_w, sub_mask = dirty_edge_args
    with enable_x64():
        fresh = _quotient_kernel(sub_src, sub_dst, sub_w, sub_mask,
                                 final_c_dev, final_pathw_dev, n=n)
        dirty_node = np.zeros(n + 1, bool)
        dirty_node[np.asarray(dirty_center_ids, np.int64)] = True
        # compact-label dirty mask: centers[i] is the i-th cluster's center
        dirty_compact = jnp.asarray(dirty_node)[cached.centers]
        m_pad = min(next_multiple(max(m_cached, 1), K_BUCKET * 8),
                    int(cached.src.shape[0]))
        src, dst, w, n_q, wmax, wsum = _merge_quotient_kernel(
            cached.src[:m_pad], cached.dst[:m_pad], cached.weight[:m_pad],
            fresh.src, fresh.dst, fresh.weight, dirty_compact, n=n)
        return DeviceQuotient(
            centers=cached.centers, src=src, dst=dst, weight=w,
            n_clusters=cached.n_clusters, n_edges=n_q,
            max_weight=wmax, weight_sum=wsum,
        )


# ---------------------------------------------------------------------------
# quotient solve: batched multi-source SSSP on device
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("k_pad",))
def _solve_kernel(qsrc, qdst, qw, k, *, k_pad: int):
    """Exact APSP on the quotient via Bellman-Ford from ALL k_pad sources at
    once (``sssp.batched_bf_loop``, distances laid out [node, source]).
    Distance dtype follows ``qw`` — int32 fast path when the caller proved
    every shortest path fits, int64 otherwise. Edges are directed (callers
    pass both directions of the symmetrized graph). Returns one packed
    int64 vector: [diameter, connected, supersteps, ecc[0..k_pad)].
    """
    from repro.core.sssp import batched_bf_loop

    inf = jnp.asarray(
        2**62 if qw.dtype == jnp.int64 else 2**31 - 1, qw.dtype)
    s = jnp.clip(qsrc, 0, k_pad - 1).astype(jnp.int32)
    t = jnp.clip(qdst, 0, k_pad - 1).astype(jnp.int32)
    eye = jnp.eye(k_pad, dtype=bool)
    d0 = jnp.where(eye, jnp.asarray(0, qw.dtype), inf)
    d, steps = batched_bf_loop(s, t, qw, d0, inf, k_pad)
    node_ok = jnp.arange(k_pad) < k
    pair_ok = node_ok[:, None] & node_ok[None, :]
    finite = pair_ok & (d < inf)
    connected = jnp.sum(finite) == k.astype(jnp.int64) * k.astype(jnp.int64)
    d_fin = jnp.where(finite, d, jnp.asarray(0, qw.dtype)).astype(jnp.int64)
    ecc = jnp.max(d_fin, axis=0)  # [node, source]: reduce over nodes
    diam = jnp.max(d_fin)
    head = jnp.stack([diam, connected.astype(jnp.int64),
                      steps.astype(jnp.int64)])
    return jnp.concatenate([head, ecc])


def solve_device_quotient(
    dq: DeviceQuotient, k: int, m: int, max_weight: int = 0,
) -> Tuple[int, np.ndarray, bool, int]:
    """(diameter, eccentricities, connected, supersteps) from a device
    quotient whose (n_clusters, n_edges, max_weight) counters have been
    fetched. Pads k and m to size buckets so same-scale graphs share one
    compiled solve, then fetches the packed result — ONE host sync.

    When ``k_pad * max_weight < 2^31 - 1`` the solve runs in int32 (every
    shortest path has < k edges, so distances and guarded adds provably
    fit) — about 2x the CPU throughput of the exact-by-construction int64
    path used otherwise.
    """
    if k <= 1:
        return 0, np.zeros(k, np.int64), True, 0
    k_pad = next_multiple(k, K_BUCKET)
    E = dq.src.shape[0]
    m_pad = min(next_multiple(max(m, 1), 8 * K_BUCKET), E)
    int32_safe = k_pad * max(int(max_weight), 1) < 2**31 - 1
    with enable_x64():
        qw = dq.weight[:m_pad]
        if int32_safe:
            # invalid (padding) slots carry INF64 -> map onto the int32 INF
            qw = jnp.where(qw >= jnp.int64(INF64),
                           jnp.int64(2**31 - 1), qw).astype(jnp.int32)
        out = guard.fetch(_solve_kernel(
            dq.src[:m_pad], dq.dst[:m_pad], qw,
            jnp.int32(k), k_pad=k_pad),
            reason="quotient solve: packed (diam, connected, steps, ecc)")
    return int(out[0]), out[3:3 + k], bool(out[1]), int(out[2])


def quotient_diameter_device(q: QuotientGraph) -> Tuple[int, np.ndarray, bool]:
    """Device solve over a host ``QuotientGraph``: symmetrizes (matching the
    scipy oracle's ``directed=False``) and runs the batched multi-source
    SSSP. Exact for int64 weights (the acceptance bar: weights up to 2^40
    match scipy bit-for-bit). Returns (diameter, eccentricities, connected).
    """
    k = q.n_clusters
    if k <= 1:
        return 0, np.zeros(k, np.int64), True
    src = np.concatenate([q.src, q.dst]).astype(np.int32)
    dst = np.concatenate([q.dst, q.src]).astype(np.int32)
    w = np.concatenate([q.weight, q.weight]).astype(np.int64)
    wmax = int(w.max()) if len(w) else 0
    with enable_x64():
        dq = DeviceQuotient(
            centers=jnp.asarray(q.center_ids.astype(np.int32)),
            src=jnp.asarray(src), dst=jnp.asarray(dst), weight=jnp.asarray(w),
            n_clusters=jnp.int32(k), n_edges=jnp.int32(len(src)),
            max_weight=jnp.int64(wmax),
            weight_sum=jnp.int64(int(w.sum()) if len(w) else 0),
        )
    diam, ecc, connected, _ = solve_device_quotient(dq, k, len(src), wmax)
    return diam, ecc, connected


# ---------------------------------------------------------------------------
# host oracles (tests only)
# ---------------------------------------------------------------------------


def quotient_diameter(q: QuotientGraph) -> Tuple[int, bool]:
    """Exact weighted diameter of the quotient — the scipy TEST ORACLE for
    the device solve. Returns (diameter, connected)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import shortest_path

    if q.n_clusters <= 1:
        return 0, True
    m = sp.csr_matrix(
        (q.weight.astype(np.float64), (q.src, q.dst)),
        shape=(q.n_clusters, q.n_clusters),
    )
    dist = shortest_path(m, method="D", directed=False)
    finite = np.isfinite(dist)
    connected = bool(finite.all())
    diam = float(dist[finite].max()) if finite.any() else 0.0
    return int(diam), connected


def quotient_diameter_minplus(q: QuotientGraph) -> Tuple[int, bool]:
    """jnp min-plus matrix-squaring fallback (cross-checks scipy in tests
    and serves as the device-local path when scipy is unavailable).

    int64-safe: the squaring runs under enable_x64 with guarded adds, so
    weights above 2^24 (which float32 silently rounds) stay exact. Shares
    the (diameter, connected) contract with ``quotient_diameter`` — a
    disconnected quotient is flagged instead of reporting a finite max.
    """
    k = q.n_clusters
    if k <= 1:
        return 0, True
    big = np.int64(INF64)
    m = np.full((k, k), big, dtype=np.int64)
    np.minimum.at(m, (q.src, q.dst), q.weight.astype(np.int64))
    np.minimum.at(m, (q.dst, q.src), q.weight.astype(np.int64))
    np.fill_diagonal(m, 0)

    with enable_x64():
        d = jnp.asarray(m)
        steps = int(np.ceil(np.log2(max(k - 1, 1)))) or 1
        for _ in range(steps):
            d = _minplus_square(d)
    arr = guard.fetch(d, reason="minplus oracle: squared distance matrix")
    finite = arr < big
    connected = bool(finite.all())
    return int(arr[finite].max()), connected


@jax.jit
def _minplus_square(d):
    """One guarded int64 min-plus squaring step (d must carry INF64 for
    unreachable pairs; the guard keeps INF64 + INF64 from overflowing)."""
    big = jnp.int64(INF64)
    a = d[:, :, None]
    b = d[None, :, :]
    ok = (a < big) & (b < big)
    cand = jnp.where(ok, jnp.where(ok, a, 0) + jnp.where(ok, b, 0), big)
    return jnp.min(cand, axis=1)
