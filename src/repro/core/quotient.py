"""Quotient graph construction and local diameter solve (paper Section 4).

Nodes of G_C are clusters; for each original edge (u, v) with c_u != c_v the
quotient edge weight is w(u,v) + dist(c_u, u) + dist(c_v, v) (we use the
engine's realized path weights, which upper-bound the dists, keeping the
estimate conservative). Parallel edges keep the minimum.

The paper picks tau so the quotient fits in one reducer's local memory and is
solved locally in O(1) rounds; we mirror that with a host-local exact APSP
(scipy Dijkstra from every cluster; jnp min-plus fallback for tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from repro.core.cluster import Decomposition
from repro.graph.structures import EdgeList


@dataclass
class QuotientGraph:
    n_clusters: int
    center_ids: np.ndarray  # original node id of each quotient node
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray  # int64 (sums of three int32 terms)


def build_quotient(edges: EdgeList, dec: Decomposition) -> QuotientGraph:
    centers, inverse = np.unique(dec.final_c, return_inverse=True)
    k = len(centers)
    cu = inverse[edges.src]
    cv = inverse[edges.dst]
    cross = cu != cv
    cu, cv = cu[cross], cv[cross]
    wq = (
        edges.weight[cross].astype(np.int64)
        + dec.final_pathw[edges.src[cross]].astype(np.int64)
        + dec.final_pathw[edges.dst[cross]].astype(np.int64)
    )
    # min-coalesce parallel quotient edges
    key = cu.astype(np.int64) * k + cv.astype(np.int64)
    order = np.lexsort((wq, key))
    key_s = key[order]
    first = np.ones(len(key_s), dtype=bool)
    if len(key_s):
        first[1:] = key_s[1:] != key_s[:-1]
    idx = order[first]
    return QuotientGraph(
        n_clusters=k,
        center_ids=centers,
        src=cu[idx].astype(np.int32),
        dst=cv[idx].astype(np.int32),
        weight=wq[idx],
    )


def quotient_diameter(q: QuotientGraph) -> Tuple[int, bool]:
    """Exact weighted diameter of the quotient (local solve). Returns
    (diameter, connected)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import shortest_path

    if q.n_clusters <= 1:
        return 0, True
    m = sp.csr_matrix(
        (q.weight.astype(np.float64), (q.src, q.dst)),
        shape=(q.n_clusters, q.n_clusters),
    )
    dist = shortest_path(m, method="D", directed=False)
    finite = np.isfinite(dist)
    connected = bool(finite.all())
    diam = float(dist[finite].max()) if finite.any() else 0.0
    return int(diam), connected


def quotient_diameter_minplus(q: QuotientGraph) -> int:
    """jnp min-plus matrix-squaring fallback (used to cross-check scipy in
    tests and as the device-local path when scipy is unavailable)."""
    import jax.numpy as jnp

    k = q.n_clusters
    if k <= 1:
        return 0
    big = np.float32(1e18)
    m = np.full((k, k), big, dtype=np.float32)
    m[q.src, q.dst] = np.minimum(m[q.src, q.dst], q.weight.astype(np.float32))
    m[q.dst, q.src] = np.minimum(m[q.dst, q.src], q.weight.astype(np.float32))
    np.fill_diagonal(m, 0.0)
    d = jnp.asarray(m)
    steps = int(np.ceil(np.log2(max(k - 1, 1)))) or 1
    for _ in range(steps):
        d = jnp.min(d[:, :, None] + d[None, :, :], axis=1)
    arr = np.asarray(d)
    return int(arr[arr < big / 2].max())
