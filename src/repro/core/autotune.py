"""Graph-statistics autotuner for the decomposition pipeline knobs.

The pipeline's warm latency is dominated by a handful of knobs the paper
leaves to the operator: ``delta_init`` (first Δ-doubling rung), ``tau``
(center budget → quotient size), ``tau_solve``/``levels`` (cascade solve
budget — the bench's 460 → 151 solve-superstep win), and the Pallas kernel
tiling (``node_tile``/``edge_block``). This module derives all of them from
ONE cheap device pass over the edges:

  * degree + weight log2 histograms (32 buckets each), max degree, min/max
    weight — computed on device via ``graph/segment_ops.segment_aggregate``
    and fetched in a single packed int32 vector (one host sync);
  * ``derive_tuning`` turns the statistics into a ``TuningRecord``;
  * kernel tiling candidates are scored with the ``runtime/roofline.py``
    machine constants (HBM stream time vs VPU match-matrix time), and
    ``validate_tuning`` re-checks the chosen tiling against the model and
    the kernel preconditions (``kernels/edge_relax/kernel.validate_tiling``);
  * records are cached in-process keyed by a graph signature; ``record``
    mode persists the cache to JSON so later processes can ``load_cache``.

Pin/override semantics (see ``GraphSession``): explicit ``tau``/``tau_solve``
arguments and numeric ``delta_init`` configs always win over the autotuner;
only symbolic/default knobs are tuned.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import guard
from repro.common import get_logger, next_multiple
from repro.graph.segment_ops import segment_aggregate
from repro.graph.structures import EdgeList
from repro.kernels.edge_relax.kernel import validate_tiling
from repro.kernels.edge_relax.megakernel import DEFAULT_K_FUSED, fits_vmem
from repro.runtime.roofline import HBM_BW, PEAK_FLOPS

log = get_logger("repro.autotune")

N_BUCKETS = 32  # log2 histogram buckets (covers the int32 weight range)

# tiling candidates scored by the roofline model; every pair satisfies the
# kernel preconditions (edge_block % 128 == 0, node_tile power of two)
NODE_TILE_CANDIDATES = (128, 256, 512)
EDGE_BLOCK_CANDIDATES = (128, 256, 512, 1024)
# match matrix + streamed intermediates must stay well inside VMEM
_MAX_MATRIX_BYTES = 4 * 2**20

# int32 relax runs on the VPU, not the bf16 MXU the roofline peak describes;
# the effective elementwise int throughput is roughly peak/16 on v5e.
_VPU_DISCOUNT = 16.0

# cluster-count model k_hat ~ C * tau * log n, calibrated on the bench graph
# (n=20000 road-like, tau=32 -> 677 clusters => C ~ 2.1); used only to pick
# the cascade depth, which tolerates a 2x miss either way.
_CLUSTERS_PER_TAU_LOG_N = 2.2

# a source skew (max_degree / avg_degree) beyond this marks a hub-heavy
# graph: clusters cover faster, so a larger tau cuts radius without blowing
# up the quotient
_HUB_SKEW = 32.0

# host round-trip cost per stage-loop sync (dispatch + scalar fetch); the
# stage engine pays one per stage, the one-shot engine one total, so mode
# selection compares predicted_stages * this against the one-shot fixpoint's
# extra device work (~ one wave over the hop radius at the roofline rate)
_HOST_SYNC_S = 2e-4

TUNE_EVENTS: Dict[str, int] = {"hits": 0, "misses": 0}


class AutotuneError(ValueError):
    """A derived tuning record failed validation."""


@dataclass(frozen=True)
class GraphStats:
    """One-pass device statistics of an edge list."""

    n_nodes: int
    n_edges: int
    avg_degree: float
    max_degree: int
    min_weight: int
    avg_weight: int
    max_weight: int
    weight_sum: int
    degree_hist: Tuple[int, ...]  # log2-bucketed in-degree counts
    weight_hist: Tuple[int, ...]  # log2-bucketed edge-weight counts


@dataclass(frozen=True)
class TuningRecord:
    """Derived pipeline knobs + the model predictions behind them."""

    signature: str
    tau: int
    tau_solve: int
    levels: int               # cascade depth (0 = direct quotient solve)
    delta_init: int
    node_tile: int
    edge_block: int
    fuse: int                 # megakernel fusion depth (0 = unfused)
    predicted_superstep_s: float  # roofline estimate for one relax pass
    padded_edges: int             # edge slots after blocking at this tiling
    # decomposition mode (core/engine.py) for sessions opened with
    # cfg.mode="auto": "oneshot" when the predicted stage-loop sync overhead
    # exceeds the one-shot fixpoint's superstep roofline. Appended LAST with
    # a default so JSON caches recorded before this field load cleanly.
    mode: str = "stages"


@partial(jax.jit, static_argnames=("n_nodes",))
def _stats_pass(dst, weight, n_nodes: int):
    """Everything histogram-shaped, in one device program: returns a packed
    int32 vector [deg_hist(32) | weight_hist(32) | max_deg, min_w, max_w]."""
    ones = jnp.ones_like(dst)
    deg = segment_aggregate(ones, dst, n_nodes, "sum")

    def lg(x):
        f = jnp.maximum(x, 1).astype(jnp.float32)
        return jnp.clip(jnp.floor(jnp.log2(f)).astype(jnp.int32),
                        0, N_BUCKETS - 1)

    deg_hist = jnp.bincount(lg(deg), length=N_BUCKETS)
    w_hist = jnp.bincount(lg(weight), length=N_BUCKETS)
    scalars = jnp.stack([deg.max(), weight.min(), weight.max()])
    return jnp.concatenate([deg_hist, w_hist, scalars]).astype(jnp.int32)


def compute_graph_stats(edges: EdgeList) -> GraphStats:
    """Device histograms + ONE packed host fetch. The weight sum (which can
    overflow int32) is reduced on the host from the resident numpy mirror."""
    n, e = edges.n_nodes, edges.n_edges
    if n == 0 or e == 0:
        zeros = (0,) * N_BUCKETS
        return GraphStats(n, e, 0.0, 0, 1, 1, 1, 0, zeros, zeros)
    vec = guard.fetch(_stats_pass(jnp.asarray(edges.dst),
                                  jnp.asarray(edges.weight), n),
                      reason="autotune: packed degree/weight histograms")
    deg_hist = tuple(int(x) for x in vec[:N_BUCKETS])
    w_hist = tuple(int(x) for x in vec[N_BUCKETS:2 * N_BUCKETS])
    max_deg, min_w, max_w = (int(x) for x in vec[2 * N_BUCKETS:])
    w_sum = int(edges.weight.astype(np.int64).sum())
    return GraphStats(
        n_nodes=n, n_edges=e, avg_degree=e / n, max_degree=max_deg,
        min_weight=min_w, avg_weight=max(w_sum // e, 1), max_weight=max_w,
        weight_sum=w_sum, degree_hist=deg_hist, weight_hist=w_hist)


def graph_signature(stats: GraphStats) -> str:
    """Stable content key: graphs with identical coarse statistics share a
    tuning record (and the cache entry that goes with it)."""
    payload = (stats.n_nodes, stats.n_edges, stats.max_degree,
               stats.min_weight, stats.max_weight, stats.weight_sum,
               stats.degree_hist, stats.weight_hist)
    return hashlib.md5(repr(payload).encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# knob derivation
# ---------------------------------------------------------------------------


def _tiling_time(n_nodes: int, n_edges: int, node_tile: int,
                 edge_block: int) -> Tuple[float, int]:
    """Roofline estimate (seconds, padded edge slots) for one relax pass.

    HBM term: the blocked (src, dst, w, mask) int32 arrays stream once.
    Compute term: the [node_tile, edge_block] match matrix costs ~3 compare/
    select passes per cell on the VPU. The kernel double-buffers DMA against
    compute, so the pass time is the max of the two, not the sum.
    Padding model: each tile rounds up to whole edge blocks (+ half a block
    for destination skew), with at least one block per tile.
    """
    n_pad = next_multiple(n_nodes + 1, node_tile)
    n_tiles = n_pad // node_tile
    per_tile = n_edges / n_tiles
    blocks_per_tile = max(math.ceil((per_tile + edge_block / 2) / edge_block), 1)
    padded = n_tiles * blocks_per_tile * edge_block
    t_hbm = (padded * 4 * 4) / HBM_BW
    t_compute = (padded * node_tile * 3) / (PEAK_FLOPS / _VPU_DISCOUNT)
    return max(t_hbm, t_compute), padded


def _best_tiling(stats: GraphStats) -> Tuple[int, int, float, int]:
    best = None
    for nt in NODE_TILE_CANDIDATES:
        for eb in EDGE_BLOCK_CANDIDATES:
            if nt * eb * 4 * 4 > _MAX_MATRIX_BYTES:
                continue
            t, padded = _tiling_time(stats.n_nodes, stats.n_edges, nt, eb)
            if best is None or t < best[2]:
                best = (nt, eb, t, padded)
    assert best is not None
    return best


def _median_weight_bucket(stats: GraphStats) -> int:
    half = max(stats.n_edges, 1) / 2
    acc = 0
    for b, cnt in enumerate(stats.weight_hist):
        acc += cnt
        if acc >= half:
            return b
    return 0


def derive_tuning(stats: GraphStats, *, backend: str = "single",
                  tau_fraction: float = 1e-3) -> TuningRecord:
    """Map graph statistics to pipeline knobs. Every choice here is a
    PERFORMANCE decision — the pipeline is correct for any legal value —
    so the formulas are deliberately simple and documented in place."""
    n = max(stats.n_nodes, 1)
    logn = max(math.log(max(n, 2)), 1.0)

    # tau: the session default (n * fraction / log n), doubled on hub-heavy
    # graphs where coverage per stage is fast and a larger quotient is the
    # cheaper way to shrink the radius term of Phi_approx.
    tau = max(int(n * tau_fraction / logn), 4)
    skew = stats.max_degree / max(stats.avg_degree, 1.0)
    if skew > _HUB_SKEW:
        tau = min(tau * 2, max(n // 8, 4))
    tau = max(4, min(tau, n))

    # cascade depth from the expected cluster count: every level divides the
    # solve frontier by ~ (k_hat / tau_solve)^(1/levels); two levels covers
    # every graph the bench exercises.
    k_hat = min(n, max(1, int(_CLUSTERS_PER_TAU_LOG_N * tau * logn)))
    tau_solve = max(64, min(1024, int(math.sqrt(n))))
    if k_hat <= tau_solve:
        levels = 0
    else:
        levels = min(2, math.ceil(math.log(k_hat / tau_solve) / math.log(3)))

    # delta_init: one bucket above the median edge weight — the mean (the
    # "avg" default) overshoots badly on heavy-tailed weights, wasting the
    # first stage on an over-wide Δ.
    b = _median_weight_bucket(stats)
    delta_init = max(1, min(2 ** (b + 1), 2**30 - 1))

    node_tile, edge_block, pred_t, padded = _best_tiling(stats)
    n_pad = next_multiple(n + 1, node_tile)
    fuse = 0
    if (backend == "pallas" and jax.default_backend() == "tpu"
            and fits_vmem(n_pad, node_tile, edge_block)):
        fuse = DEFAULT_K_FUSED

    # engine mode for cfg.mode="auto" sessions: the stage loop halves the
    # uncovered set per stage until the 8*tau*log n threshold, so it needs
    # ~ log2(n / threshold) stages, each costing one host round-trip; the
    # one-shot alternative pays a single sync but its fixpoint must sweep
    # the whole hop radius (~ sqrt(n) on the road-like graphs the paper
    # targets) in one grow call. Pick whichever the model prices cheaper.
    s_hat = max(1, math.ceil(math.log2(max(n / max(8.0 * tau * logn, 1.0),
                                           2.0))))
    hop_hat = max(int(math.sqrt(n)), 1)
    mode = ("oneshot" if s_hat * _HOST_SYNC_S > hop_hat * pred_t
            else "stages")

    return TuningRecord(
        signature=graph_signature(stats), tau=tau, tau_solve=tau_solve,
        levels=levels, delta_init=delta_init, node_tile=node_tile,
        edge_block=edge_block, fuse=fuse, predicted_superstep_s=pred_t,
        padded_edges=padded, mode=mode)


def validate_tuning(rec: TuningRecord, stats: GraphStats) -> None:
    """Re-check a record against the kernel preconditions and the roofline
    model (guards hand-edited or stale cache entries)."""
    validate_tiling(rec.node_tile, rec.edge_block)
    if not 1 <= rec.tau <= max(stats.n_nodes, 4):
        raise AutotuneError(f"tau {rec.tau} out of range for n={stats.n_nodes}")
    if rec.tau_solve < 2:
        raise AutotuneError(f"tau_solve must be >= 2, got {rec.tau_solve}")
    if not 0 <= rec.levels <= 4:
        raise AutotuneError(f"levels must be in [0, 4], got {rec.levels}")
    if not 1 <= rec.delta_init < 2**30:
        raise AutotuneError(f"delta_init {rec.delta_init} outside [1, 2^30)")
    if rec.fuse < 0:
        raise AutotuneError(f"fuse must be >= 0, got {rec.fuse}")
    if rec.mode not in ("stages", "oneshot"):
        raise AutotuneError(
            f"mode must be 'stages' or 'oneshot' (a record stores the "
            f"RESOLVED mode, never 'auto'), got {rec.mode!r}")
    t, _ = _tiling_time(stats.n_nodes, stats.n_edges,
                        rec.node_tile, rec.edge_block)
    best_t = _best_tiling(stats)[2]
    if t > best_t * 1.05:
        raise AutotuneError(
            f"tiling ({rec.node_tile}, {rec.edge_block}) predicted "
            f"{t:.3e}s vs best {best_t:.3e}s — record is stale for this "
            "graph shape")


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

_CACHE: Dict[str, TuningRecord] = {}


def _default_cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache",
                     "repro_autotune.json"))


def _cache_key(sig: str, backend: str) -> str:
    return f"{sig}:{backend}:{jax.default_backend()}"


def clear_cache() -> None:
    _CACHE.clear()
    TUNE_EVENTS["hits"] = TUNE_EVENTS["misses"] = 0


def save_cache(path: Optional[str] = None) -> str:
    path = path or _default_cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {k: dataclasses.asdict(v) for k, v in _CACHE.items()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return path


def load_cache(path: Optional[str] = None) -> int:
    """Populate the in-process cache from a recorded JSON file; returns the
    number of records loaded (0 when the file is absent)."""
    path = path or _default_cache_path()
    if not os.path.exists(path):
        return 0
    with open(path) as f:
        payload = json.load(f)
    for k, d in payload.items():
        _CACHE[k] = TuningRecord(**d)
    return len(payload)


def get_tuning(edges: EdgeList, *, backend: str = "single",
               record: bool = False,
               cache_path: Optional[str] = None) -> TuningRecord:
    """Stats pass + derivation with in-process caching. ``record=True``
    additionally persists the cache file after a miss."""
    stats = compute_graph_stats(edges)
    key = _cache_key(graph_signature(stats), backend)
    hit = _CACHE.get(key)
    if hit is not None:
        TUNE_EVENTS["hits"] += 1
        return hit
    TUNE_EVENTS["misses"] += 1
    rec = derive_tuning(stats, backend=backend)
    validate_tuning(rec, stats)
    _CACHE[key] = rec
    if record:
        save_cache(cache_path)
    log.info("autotuned %s: tau=%d tau_solve=%d levels=%d delta0=%d "
             "tiling=(%d,%d) fuse=%d", key, rec.tau, rec.tau_solve,
             rec.levels, rec.delta_init, rec.node_tile, rec.edge_block,
             rec.fuse)
    return rec
