"""Engine state for the weighted decomposition (paper Alg. 1/2).

Per-node arrays (all int32 unless noted):

  in-stage (reset when a new batch of centers is sampled):
    d       tentative distance in the *reduced* graph from the owning center
    c       tentative center id (INF = unassigned)
    pathw   realized path weight from the center in the ORIGINAL graph along
            the relaxation tree (exact upper bound on dist(c_u, u))

  persistent:
    final_c     cluster assignment (INF until covered)
    final_pathw dist-from-center upper bound frozen at cover time
    offset      for covered nodes: d_at_cover - Delta_at_cover  (paper's
                reduced-edge rescaling w(u,v) - (Delta - d_u), Section 3);
                0 otherwise. May be negative.
    covered     bool: assigned in a previous stage (frozen, emits as relay)
    is_center   bool: permanent cluster center (paper: C_{i+1} = X superset C_i)

The contraction G^reduced(Delta) is realized *semantically*: covered nodes
relay their center's wave with the rescaled weight folded in; centers always
sit at d = 0, so a relay edge (u,v) re-expands a contracted cluster in a
single growing step, exactly like the paper's contracted edge (c_u, v).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.int32(2**31 - 1)


class EngineState(NamedTuple):
    d: jnp.ndarray
    c: jnp.ndarray
    pathw: jnp.ndarray
    final_c: jnp.ndarray
    final_pathw: jnp.ndarray
    offset: jnp.ndarray
    covered: jnp.ndarray
    is_center: jnp.ndarray

    @property
    def n(self) -> int:
        return self.d.shape[0]


def init_state(n_nodes: int) -> EngineState:
    z = jnp.zeros(n_nodes, dtype=jnp.int32)
    inf = jnp.full(n_nodes, INF, dtype=jnp.int32)
    f = jnp.zeros(n_nodes, dtype=bool)
    return EngineState(d=inf, c=inf, pathw=inf, final_c=inf, final_pathw=inf,
                       offset=z, covered=f, is_center=f)


def pad_state(state: EngineState, n_pad: int) -> EngineState:
    """Pad the canonical planes to ``n_pad`` slots.

    Tail slots are inert permanent centers: they are never sampled
    (``eligible`` excludes centers), never receive updates (receivers are
    non-centers), never counted (uncovered/reached counts exclude centers),
    and never emit candidates (every padded edge is masked by its backend).
    This is done ONCE per decomposition — backends keep the padded state
    device-resident across all stages.
    """
    n = state.n
    if n_pad == n:
        return state
    if n_pad < n:
        raise ValueError(f"n_pad {n_pad} < n {n}")

    def padto(x, fill):
        return jnp.concatenate([x, jnp.full((n_pad - n,), fill, x.dtype)])

    return EngineState(
        d=padto(state.d, INF),
        c=padto(state.c, INF),
        pathw=padto(state.pathw, INF),
        final_c=padto(state.final_c, INF),
        final_pathw=padto(state.final_pathw, INF),
        offset=padto(state.offset, 0),
        covered=padto(state.covered, False),
        is_center=padto(state.is_center, True),
    )


def relay_planes(state: EngineState):
    """Branch-free relay candidate planes ``(rw0, rc, rp, frozen)``.

    Covered nodes relay their center's wave with the contraction rescaling
    (``offset``) folded in; everyone else gets an additive-safe BIG so the
    relay branch is inadmissible. ``frozen`` marks nodes that never receive
    updates. These planes only change at ``cover()`` time, so backends derive
    them once per grow call (cheap elementwise ops that stay on device).
    """
    big = jnp.int32(2**30)
    relay = state.covered
    rw0 = jnp.where(relay, state.offset, big)
    rc = jnp.where(relay, state.final_c, INF)
    rp = jnp.where(relay, state.final_pathw, INF)
    frozen = state.covered | state.is_center
    return rw0, rc, rp, frozen


def promote_centers(state: EngineState, new_centers: jnp.ndarray) -> EngineState:
    """Mark ``new_centers`` (bool mask) as permanent centers with state
    (self, 0). Centers self-assign: final_c = self, final_pathw = 0."""
    ids = jnp.arange(state.n, dtype=jnp.int32)
    sel = new_centers & ~state.is_center & ~state.covered
    return state._replace(
        d=jnp.where(sel, 0, state.d),
        c=jnp.where(sel, ids, state.c),
        pathw=jnp.where(sel, 0, state.pathw),
        final_c=jnp.where(sel, ids, state.final_c),
        final_pathw=jnp.where(sel, 0, state.final_pathw),
        is_center=state.is_center | sel,
    )


def promote_centers_shifted(state: EngineState, new_centers: jnp.ndarray,
                            start_d: jnp.ndarray) -> EngineState:
    """One-shot mode promote: centers enter the wave at ``d = start_d``
    (the exponential start shift folded into the initial distance, MPVX
    style) instead of 0. ``pathw`` still starts at 0, so ``final_pathw``
    remains a realized path weight from the owning center — the radius
    certificate is identical to the staged engine's."""
    ids = jnp.arange(state.n, dtype=jnp.int32)
    sel = new_centers & ~state.is_center & ~state.covered
    return state._replace(
        d=jnp.where(sel, start_d, state.d),
        c=jnp.where(sel, ids, state.c),
        pathw=jnp.where(sel, 0, state.pathw),
        final_c=jnp.where(sel, ids, state.final_c),
        final_pathw=jnp.where(sel, 0, state.final_pathw),
        is_center=state.is_center | sel,
    )


def reset_in_stage(state: EngineState) -> EngineState:
    """Reset in-stage wave state: centers at (self,0), others unreached.

    Used at the start of a stage (a new PartialGrowth call in the paper).
    Covered nodes keep final_* / offset and never receive updates.
    """
    ids = jnp.arange(state.n, dtype=jnp.int32)
    is_c = state.is_center
    return state._replace(
        d=jnp.where(is_c, 0, INF),
        c=jnp.where(is_c, ids, INF),
        pathw=jnp.where(is_c, 0, INF),
    )


def cover(state: EngineState, delta: jnp.ndarray) -> EngineState:
    """Freeze every uncovered non-center node with in-stage d < delta
    (paper: ``Assign each u in V' to the cluster centered at c_u``) and fold
    the reduction rescaling into its relay offset."""
    newly = (~state.covered) & (~state.is_center) & (state.d < delta)
    return state._replace(
        final_c=jnp.where(newly, state.c, state.final_c),
        final_pathw=jnp.where(newly, state.pathw, state.final_pathw),
        offset=jnp.where(newly, state.d - delta, state.offset),
        covered=state.covered | newly,
    )


def uncovered_count(state: EngineState) -> jnp.ndarray:
    return jnp.sum((~state.covered) & (~state.is_center))


def finalize_singletons(state: EngineState) -> EngineState:
    """Remaining uncovered nodes become singleton clusters centered at
    themselves (last line of Alg. 1)."""
    ids = jnp.arange(state.n, dtype=jnp.int32)
    rem = (~state.covered) & (~state.is_center)
    return state._replace(
        final_c=jnp.where(rem, ids, state.final_c),
        final_pathw=jnp.where(rem, 0, state.final_pathw),
        is_center=state.is_center | rem,
    )
