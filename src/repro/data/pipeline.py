"""Synthetic, seeded, shardable data pipelines for every arch family.

Real-cluster semantics preserved offline:
  * deterministic per-(shard, step) seeding — a restored job replays the
    exact stream from its data cursor (checkpointed as `extra`);
  * over-decomposition: 4x more logical shards than hosts, so straggling /
    lost hosts can hand shards to peers without resharding model state;
  * fixed shapes per step — no recompilation, ever.

LM batches are uniform random tokens with shifted labels; GNN regimes build
on graph/generators + graph/sampler; recsys draws Zipf-ish ids (hot vocab
head) to exercise the embedding-bag gather path realistically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.config.base import GNNConfig, RecsysConfig, ShapeSpec, TransformerConfig

OVERDECOMPOSE = 4


@dataclass
class DataCursor:
    """Checkpointable pipeline position."""
    step: int = 0
    shard: int = 0

    def as_dict(self):
        return {"step": self.step, "shard": self.shard}

    @staticmethod
    def from_dict(d):
        return DataCursor(step=int(d.get("step", 0)), shard=int(d.get("shard", 0)))


def _seed_for(base: int, shard: int, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([base, shard, step]).generate_state(4)
    )


class LMTokenPipeline:
    def __init__(self, cfg: TransformerConfig, shape: ShapeSpec, n_hosts: int = 1,
                 seed: int = 0):
        self.cfg, self.shape = cfg, shape
        self.n_shards = n_hosts * OVERDECOMPOSE
        self.seed = seed

    def batch(self, cursor: DataCursor) -> Dict[str, np.ndarray]:
        r = _seed_for(self.seed, cursor.shard, cursor.step)
        B, S = self.shape.global_batch, self.shape.seq_len
        toks = r.integers(0, self.cfg.vocab_size, (B, S), dtype=np.int32)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1
        return {"tokens": toks, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        cur = DataCursor()
        while True:
            yield self.batch(cur)
            cur.step += 1


class RecsysPipeline:
    def __init__(self, cfg: RecsysConfig, shape: ShapeSpec, seed: int = 0):
        self.cfg, self.shape = cfg, shape
        self.seed = seed

    def batch(self, cursor: DataCursor) -> Dict[str, np.ndarray]:
        r = _seed_for(self.seed, cursor.shard, cursor.step)
        B = self.shape.batch
        F, bag, V = self.cfg.n_sparse, max(self.cfg.multi_hot, 1), self.cfg.vocab_per_field
        # Zipf head: 80% of lookups hit the first 1% of rows
        hot = max(V // 100, 1)
        coin = r.random((B, F, bag)) < 0.8
        ids = np.where(
            coin,
            r.integers(0, hot, (B, F, bag)),
            r.integers(0, V, (B, F, bag)),
        ).astype(np.int32)
        mask = np.ones((B, F, bag), np.float32)
        dense = r.standard_normal((B, self.cfg.n_dense)).astype(np.float32)
        labels = r.integers(0, 2, B).astype(np.int32)
        return {"ids": ids, "id_mask": mask, "dense": dense, "labels": labels}


def gnn_full_graph_batch(cfg: GNNConfig, shape: ShapeSpec, seed: int = 0,
                         n_classes: int = 7) -> Dict[str, np.ndarray]:
    """Synthetic full-graph batch at the shape's (n_nodes, n_edges) scale.
    RMAT-ish degree skew, features/labels/positions as the arch needs."""
    r = np.random.default_rng(seed)
    n, e = shape.n_nodes, shape.n_edges
    # power-ish degree: endpoints = floor(n * u^2)
    src = (n * r.random(e) ** 2).astype(np.int32) % n
    dst = (n * r.random(e) ** 2).astype(np.int32) % n
    x = r.standard_normal((n, shape.d_feat)).astype(np.float32)
    return {
        "x": x,
        "src": src,
        "dst": dst,
        "labels": r.integers(0, n_classes, n).astype(np.int32),
        "pos": r.standard_normal((n, 3)).astype(np.float32),
    }


def gnn_molecule_batch(cfg: GNNConfig, shape: ShapeSpec, seed: int = 0,
                       d_feat: int = 32) -> Dict[str, np.ndarray]:
    """`n_graphs` disjoint molecules flattened into one padded graph."""
    r = np.random.default_rng(seed)
    g, n, e = shape.n_graphs, shape.n_nodes, shape.n_edges
    N, E = g * n, g * e
    offs = np.repeat(np.arange(g, dtype=np.int32) * n, e)
    src = (r.integers(0, n, E).astype(np.int32) + offs)
    dst = (r.integers(0, n, E).astype(np.int32) + offs)
    return {
        "x": r.standard_normal((N, d_feat)).astype(np.float32),
        "src": src,
        "dst": dst,
        "pos": r.standard_normal((N, 3)).astype(np.float32),
        "graph_id": np.repeat(np.arange(g, dtype=np.int32), n),
        "targets": r.standard_normal((g, 1)).astype(np.float32),
        "labels": np.zeros(N, np.int32),
    }
