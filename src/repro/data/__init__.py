from repro.data.pipeline import (
    DataCursor, LMTokenPipeline, RecsysPipeline,
    gnn_full_graph_batch, gnn_molecule_batch,
)
