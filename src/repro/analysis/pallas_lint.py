"""pallas-lint: static validation of ``pl.pallas_call`` sites.

Pallas failures on real hardware are late and cryptic (a mis-arity index
map traces fine and mosaics wrong; an oversized scratch OOMs at compile;
a cross-block scratch race returns different answers per run). These
rules check, at the AST level, the contracts the kernels in
``kernels/edge_relax/{kernel,megakernel}.py`` rely on:

  PL001  BlockSpec index_map arity must match the iteration space:
         len(grid) positional args, plus num_scalar_prefetch more under
         a ``PrefetchScalarGridSpec`` (a ``*rest`` vararg satisfies the
         tail). A wrong arity either crashes at trace time or silently
         drops a grid axis.
  PL002  a module containing ``pallas_call`` must route its tile shapes
         through a validator (``validate_tiling`` / ``validate_block_tile``
         / ``fits_vmem``): lane-misaligned edge blocks or non-power-of-two
         node tiles produce wrong DMA descriptors, not error messages.
  PL003  VMEM budget: constant-shaped scratch_shapes are summed against
         the 8 MiB accumulator budget (``megakernel.VMEM_BUDGET_BYTES``);
         variable-shaped scratch requires the module to carry a runtime
         footprint guard (``vmem_footprint_bytes`` / ``fits_vmem``).
  PL004  scratch accumulators + a multi-dim grid require
         ``dimension_semantics`` declaring every axis "arbitrary"
         (sequential): without it the compiler may parallelize a grid
         axis over which the kernel accumulates read-modify-write, which
         is a write race.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.common import Finding, SourceFile, dotted_name, finding

# keep in sync with kernels/edge_relax/megakernel.VMEM_BUDGET_BYTES
VMEM_BUDGET_BYTES = 8 * 2**20

_DTYPE_BYTES = {"int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
                "int16": 2, "uint16": 2, "bfloat16": 2, "float16": 2,
                "int32": 4, "uint32": 4, "float32": 4,
                "int64": 8, "uint64": 8, "float64": 8}

_VALIDATORS = ("validate_tiling", "validate_block_tile", "fits_vmem",
               "vmem_footprint_bytes")


def _const_int(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.BinOp):
        l, r = _const_int(node.left), _const_int(node.right)
        if l is None or r is None:
            return None
        try:
            if isinstance(node.op, ast.Mult):
                return l * r
            if isinstance(node.op, ast.Add):
                return l + r
            if isinstance(node.op, ast.Pow):
                return l ** r
            if isinstance(node.op, ast.FloorDiv):
                return l // r
        except Exception:
            return None
    return None


def _kwarg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


class _SiteContext:
    """A pallas_call together with the grid spec that shapes it."""

    def __init__(self, call: ast.Call, local_defs: Dict[str, ast.Call]):
        self.call = call
        self.local_defs = local_defs
        self.grid_len: Optional[int] = None
        self.prefetch = 0
        self.specs: List[ast.AST] = []
        self.scratch: Optional[ast.AST] = None
        self.semantics: Optional[ast.AST] = None
        self._resolve()

    def _deref(self, node: Optional[ast.AST]) -> Optional[ast.AST]:
        """Follow one level of local Name -> assigned Call."""
        if isinstance(node, ast.Name) and node.id in self.local_defs:
            return self.local_defs[node.id]
        return node

    def _resolve(self) -> None:
        src = self.call
        grid_spec = self._deref(_kwarg(src, "grid_spec"))
        if isinstance(grid_spec, ast.Call) and \
                dotted_name(grid_spec.func).endswith("PrefetchScalarGridSpec"):
            pf = _kwarg(grid_spec, "num_scalar_prefetch")
            self.prefetch = _const_int(pf) or 0 if pf is not None else 0
            src = grid_spec
        grid = _kwarg(src, "grid")
        if isinstance(grid, (ast.Tuple, ast.List)):
            self.grid_len = len(grid.elts)
        elif grid is not None and _const_int(grid) is not None:
            self.grid_len = 1
        for key in ("in_specs", "out_specs"):
            val = self._deref(_kwarg(src, key))
            self.specs.extend(self._spec_elements(val))
        self.scratch = self._deref(_kwarg(src, "scratch_shapes"))
        params = self._deref(_kwarg(self.call, "compiler_params"))
        if isinstance(params, ast.Call):
            self.semantics = _kwarg(params, "dimension_semantics")
        elif params is not None:
            self.semantics = None

    def _spec_elements(self, val: Optional[ast.AST]) -> List[ast.AST]:
        """Expand [spec]*9 / [a, b, c] lists of (possibly Name-bound)
        BlockSpec constructor calls."""
        out: List[ast.AST] = []
        if isinstance(val, ast.BinOp) and isinstance(val.op, ast.Mult):
            for side in (val.left, val.right):
                out.extend(self._spec_elements(side))
            return out
        if isinstance(val, (ast.Tuple, ast.List)):
            for e in val.elts:
                e = self._deref(e)
                if isinstance(e, ast.Call):
                    out.append(e)
            return out
        val = self._deref(val)
        if isinstance(val, ast.Call):
            out.append(val)
        return out


def _index_map_of(spec: ast.AST) -> Optional[ast.AST]:
    if not isinstance(spec, ast.Call):
        return None
    if not dotted_name(spec.func).endswith("BlockSpec"):
        return None
    im = _kwarg(spec, "index_map")
    if im is not None:
        return im
    # positional BlockSpec(block_shape, index_map)
    if len(spec.args) >= 2:
        return spec.args[1]
    return None


def _scratch_bytes(node: ast.AST) -> Optional[int]:
    """pltpu.VMEM((a, b), dtype) -> a*b*sizeof(dtype) when constant."""
    if not (isinstance(node, ast.Call)
            and dotted_name(node.func).endswith(("VMEM", "SMEM"))):
        return None
    if not node.args:
        return None
    shape = node.args[0]
    dims = (shape.elts if isinstance(shape, (ast.Tuple, ast.List))
            else [shape])
    total = 1
    for d in dims:
        c = _const_int(d)
        if c is None:
            return None
        total *= c
    nbytes = 4
    if len(node.args) >= 2:
        dt = dotted_name(node.args[1]).split(".")[-1]
        nbytes = _DTYPE_BYTES.get(dt, 4)
    return total * nbytes


def check(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    calls = [n for n in ast.walk(sf.tree)
             if isinstance(n, ast.Call)
             and dotted_name(n.func).endswith("pallas_call")]
    if not calls:
        return findings

    has_validator = any(
        isinstance(n, (ast.Call, ast.FunctionDef))
        and (dotted_name(getattr(n, "func", n)) or
             getattr(n, "name", "")).split(".")[-1] in _VALIDATORS
        for n in ast.walk(sf.tree))
    if not has_validator:
        findings.append(finding(
            "pallas", "PL002", sf, calls[0],
            "module invokes pallas_call but never routes tile shapes "
            "through validate_tiling/validate_block_tile/fits_vmem; "
            "misaligned tiles fail silently on hardware"))

    module_has_footprint_guard = any(
        v in sf.text for v in ("vmem_footprint_bytes", "fits_vmem"))

    for call in calls:
        # collect local `name = <Call>` bindings in the enclosing function
        local_defs: Dict[str, ast.Call] = {}
        for fn in ast.walk(sf.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                    fn.lineno <= call.lineno <= (fn.end_lineno or fn.lineno):
                for stmt in ast.walk(fn):
                    if (isinstance(stmt, ast.Assign)
                            and len(stmt.targets) == 1
                            and isinstance(stmt.targets[0], ast.Name)
                            and isinstance(stmt.value, ast.Call)):
                        local_defs[stmt.targets[0].id] = stmt.value
        ctx = _SiteContext(call, local_defs)

        # PL001 — index_map arity vs grid (+ scalar prefetch operands)
        if ctx.grid_len is not None:
            want = ctx.grid_len + ctx.prefetch
            for spec in ctx.specs:
                im = _index_map_of(spec)
                if not isinstance(im, ast.Lambda):
                    continue
                if im.args.vararg is not None:
                    continue   # *rest absorbs the tail
                got = len(im.args.args) + len(im.args.posonlyargs)
                if got != want:
                    findings.append(finding(
                        "pallas", "PL001", sf, im,
                        f"BlockSpec index_map takes {got} args but the "
                        f"iteration space supplies {want} "
                        f"(grid={ctx.grid_len} + "
                        f"scalar_prefetch={ctx.prefetch}); a dropped grid "
                        "axis mosaics the wrong block"))

        # PL003 — VMEM budget on scratch shapes
        if ctx.scratch is not None:
            elems = (ctx.scratch.elts
                     if isinstance(ctx.scratch, (ast.Tuple, ast.List))
                     else [ctx.scratch])
            total = 0
            unknown = False
            for e in elems:
                b = _scratch_bytes(e)
                if b is None:
                    unknown = True
                else:
                    total += b
            if total > VMEM_BUDGET_BYTES:
                findings.append(finding(
                    "pallas", "PL003", sf, ctx.scratch,
                    f"scratch_shapes total {total} bytes exceeds the "
                    f"{VMEM_BUDGET_BYTES}-byte VMEM accumulator budget"))
            elif unknown and not module_has_footprint_guard:
                findings.append(finding(
                    "pallas", "PL003", sf, ctx.scratch,
                    "variable-shaped VMEM scratch without a runtime "
                    "footprint guard (vmem_footprint_bytes/fits_vmem); "
                    "an oversized tile OOMs at compile time on device"))

        # PL004 — scratch accumulators need sequential grid semantics
        if ctx.scratch is not None and (ctx.grid_len or 0) >= 1:
            ok = False
            if isinstance(ctx.semantics, (ast.Tuple, ast.List)):
                vals = [getattr(e, "value", None) for e in ctx.semantics.elts]
                ok = (len(vals) == ctx.grid_len
                      and all(v == "arbitrary" for v in vals))
            if not ok:
                findings.append(finding(
                    "pallas", "PL004", sf, call,
                    "pallas_call accumulates into scratch across a grid "
                    "but does not declare dimension_semantics="
                    "('arbitrary', ...) for every axis; a parallelized "
                    "axis turns the accumulation into a write race"))
    return findings
