"""``repro.analysis`` — static sync/dtype/kernel/determinism checking
plus runtime transfer-guard enforcement.

Static half (stdlib-only, ``python -m repro.analysis src/``):

  * :mod:`repro.analysis.sync_lint` — implicit device->host transfers
  * :mod:`repro.analysis.dtype_lint` — distance-dtype bounds, falsy knobs
  * :mod:`repro.analysis.pallas_lint` — pallas_call contracts
  * :mod:`repro.analysis.determinism_lint` — entropy in decomposition paths

Runtime half (:mod:`repro.analysis.guard`): ``guard.fetch`` is the one
sanctioned fetch point; ``guard.measured_transfers()`` meters a region
and proves ``measured == EngineMetrics.host_syncs`` (see guard docstring
for the exact contracts).

Importing this package pulls no jax — the linters must run in a bare CI
job. ``guard`` imports jax lazily inside its functions.
"""
from repro.analysis.common import Finding, SourceFile, run_checkers


def all_checkers():
    """Name -> checker map, importing lazily so a syntax error in one
    checker doesn't mask the others in tracebacks."""
    from repro.analysis import (
        determinism_lint,
        dtype_lint,
        pallas_lint,
        sync_lint,
    )
    return {
        "sync": sync_lint.check,
        "dtype": dtype_lint.check,
        "pallas": pallas_lint.check,
        "det": determinism_lint.check,
    }


def run_analysis(paths, checkers=None):
    """Run (a subset of) the checkers. Returns
    ``(active, suppressed, errors)`` finding lists."""
    table = all_checkers()
    if checkers:
        table = {k: v for k, v in table.items() if k in checkers}
    return run_checkers(paths, table)


__all__ = ["Finding", "SourceFile", "run_checkers", "all_checkers",
           "run_analysis"]
