"""dtype-bound-lint: distance arithmetic must go through the provable
bound helpers, and integer knobs must never be truthiness-coerced.

Two bug classes this repo has already shipped and fixed by hand:

  DTYPE001  bare int32 distance accumulation (the PR 4 overflow): a
            function that (a) builds an int32 array, (b) adds a
            distance-named term to a weight-named term, and (c) never
            consults ``sssp_dtype_for`` — the provable-bound dtype picker
            — can silently wrap ``d + w`` past 2^31 on heavy graphs.
            Routing through ``sssp_dtype_for(n, max_weight, delta)``
            clears the finding.

  DTYPE002  falsy coercion of an integer knob (the PR 3 ``--tau 0`` bug):
            ``tau or DEFAULT``, ``not tau``, ``if tau:`` treat the legal
            value 0 as "unset". Knobs must compare ``is None`` /
            ``== 0`` explicitly. Checked for the knob names
            {tau, tau_solve, delta, levels} as bare names or attribute
            tails (``args.tau``, ``cfg.levels``).
"""
from __future__ import annotations

import ast
import re
from typing import List

from repro.analysis.common import Finding, SourceFile, dotted_name, finding

_KNOBS = {"tau", "tau_solve", "delta", "levels"}
_DIST_RE = re.compile(r"^(d|d0|dist|distance|pathw|fp|path_w)\d*(_\w+)?$")
_WEIGHT_RE = re.compile(r"^(w|wt|weight|weights|qw|wd)\d*(_\w+)?$")


def _name_tail(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return ""


def _matches(node: ast.AST, pattern: re.Pattern) -> bool:
    if isinstance(node, ast.Name):
        return bool(pattern.match(node.id))
    if isinstance(node, ast.Subscript):     # d[src] + w
        return _matches(node.value, pattern)
    if isinstance(node, ast.Call):          # d.astype(...) + w
        if isinstance(node.func, ast.Attribute):
            return _matches(node.func.value, pattern)
    return False


def _is_int32_marker(node: ast.AST) -> bool:
    """jnp.int32 / np.int32 reference (as a cast, dtype= value, or
    .astype argument)."""
    name = dotted_name(node)
    return name.endswith(".int32") or name == "int32"


class _FnScan(ast.NodeVisitor):
    def __init__(self):
        self.makes_int32 = False
        self.dist_plus_weight: List[ast.BinOp] = []
        self.calls_dtype_helper = False

    def visit_Call(self, node: ast.Call) -> None:
        name = dotted_name(node.func)
        if name.endswith("sssp_dtype_for") or name.endswith("dtype_for"):
            self.calls_dtype_helper = True
        if _is_int32_marker(node.func):
            self.makes_int32 = True
        for a in list(node.args) + [kw.value for kw in node.keywords]:
            if _is_int32_marker(a):
                self.makes_int32 = True
        self.generic_visit(node)

    def visit_keyword(self, node: ast.keyword) -> None:
        if node.arg == "dtype" and _is_int32_marker(node.value):
            self.makes_int32 = True
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, ast.Add):
            pair = (node.left, node.right)
            for a, b in (pair, pair[::-1]):
                if _matches(a, _DIST_RE) and _matches(b, _WEIGHT_RE):
                    self.dist_plus_weight.append(node)
                    break
        self.generic_visit(node)


def check(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan = _FnScan()
            scan.visit(node)
            if (scan.makes_int32 and scan.dist_plus_weight
                    and not scan.calls_dtype_helper):
                for binop in scan.dist_plus_weight:
                    findings.append(finding(
                        "dtype", "DTYPE001", sf, binop,
                        "int32 distance accumulation without "
                        "sssp_dtype_for: d + w can wrap past 2^31 "
                        "(the PR 4 overflow class); pick the dtype from "
                        "the provable bound"))
        elif isinstance(node, ast.BoolOp) and isinstance(node.op, ast.Or):
            head = node.values[0]
            if _name_tail(head) in _KNOBS:
                findings.append(finding(
                    "dtype", "DTYPE002", sf, node,
                    f"'{_name_tail(head)} or ...' coerces the legal value "
                    "0 to the fallback (the PR 3 --tau 0 bug); compare "
                    "'is None' explicitly"))
        elif isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
            if _name_tail(node.operand) in _KNOBS:
                findings.append(finding(
                    "dtype", "DTYPE002", sf, node,
                    f"'not {_name_tail(node.operand)}' is true for the "
                    "legal value 0; compare 'is None' explicitly"))
        elif isinstance(node, (ast.If, ast.While)):
            if _name_tail(node.test) in _KNOBS:
                findings.append(finding(
                    "dtype", "DTYPE002", sf, node.test,
                    f"truthiness of knob '{_name_tail(node.test)}' treats "
                    "0 as unset; compare 'is None' explicitly"))
    return findings
