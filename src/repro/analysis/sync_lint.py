"""sync-lint: find implicit device->host transfers in hot paths.

The paper's entire advantage over Δ-stepping is round complexity; in this
repo a round IS a host synchronization, and the BENCH contracts (≤8
pipeline syncs, 1-sync oneshot) rest on hand-incremented counters. This
checker makes the counters and the code unable to drift: every expression
that forces a device value onto the host must either

  * route through the sanctioned ``repro.analysis.guard.fetch(x, reason=...)``
    helper (counted at runtime, annotated by construction), or
  * carry a ``# sync: <reason>`` pragma on/next to the flagged line.

Detection is an intra-function taint walk. Taint seeds:

  * expressions rooted in ``jnp.*`` / ``jax.*`` calls (device values),
  * results of calls to module-local functions decorated ``@jax.jit`` /
    ``@partial(jax.jit, ...)``,
  * every non-static parameter of a jitted function (tracers).

Taint propagates through assignment, arithmetic, subscripts, tuple
unpacking, and method calls on tainted receivers; it is CLEARED by shape
/ dtype metadata access and by ``guard.fetch`` (whose result is host
numpy). Sinks:

  SYNC001  int()/float()/complex() on a device value
  SYNC002  .item()/.tolist() on a device value
  SYNC003  np.asarray()/np.array() on a device value
  SYNC004  truthiness of a device value (if/while/assert/bool()/not/and/or)
  SYNC005  iteration over a device value (for / comprehension / starred)
  SYNC006  explicit jax.device_get / .block_until_ready (still a sync —
           must be pragma'd so it shows up in the sync budget)
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.common import (
    Finding,
    SourceFile,
    dotted_name,
    finding,
    is_jitted,
    jit_static_argnames,
)

# attribute access that yields host metadata, not a device buffer
_META_ATTRS = {"shape", "dtype", "ndim", "size", "nbytes", "at", "weak_type"}
# numpy module aliases whose asarray/array is a device->host sink
_NP_ALIASES = {"np", "numpy", "onp"}
# jax module roots that produce device values
_JAX_ROOTS = {"jnp", "jax", "lax", "pl", "pltpu"}
# jax/jnp calls that return HOST values (strings, ints, dtype metadata,
# python containers) — never tainted
_HOST_RETURNING = {
    "jax.default_backend", "jax.devices", "jax.local_devices",
    "jax.device_count", "jax.local_device_count", "jax.process_index",
    "jax.process_count", "jnp.issubdtype", "jnp.iinfo", "jnp.finfo",
    "jnp.dtype", "jnp.result_type", "jnp.promote_types", "jnp.ndim",
    "jnp.shape",
}
_HOST_RETURNING_PREFIXES = ("jax.tree_util.", "jax.tree.")


def _root_name(node: ast.AST) -> str:
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = getattr(node, "value", None) or getattr(node, "func", None)
        if node is None:
            return ""
    return node.id if isinstance(node, ast.Name) else ""


class _FunctionLinter(ast.NodeVisitor):
    """One function scope: seed taint, propagate, flag sinks."""

    def __init__(self, sf: SourceFile, fn: ast.AST, jitted_locals: Set[str],
                 findings: List[Finding]):
        self.sf = sf
        self.fn = fn
        self.jitted_locals = jitted_locals
        self.findings = findings
        self.record = True   # pass 1 (taint fixpoint) sets this False
        self.tainted: Set[str] = set()
        if is_jitted(fn):
            static = jit_static_argnames(fn)
            args = fn.args
            for a in (args.posonlyargs + args.args + args.kwonlyargs):
                if a.arg not in static and a.arg != "self":
                    self.tainted.add(a.arg)

    # ---- taint query ------------------------------------------------

    def is_tainted(self, node: Optional[ast.AST]) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _META_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, ast.BinOp):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.Compare):
            # `x is None` is an identity check on the python object —
            # host-side, never a transfer, whatever x holds
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return (self.is_tainted(node.left)
                    or any(self.is_tainted(c) for c in node.comparators))
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            return self._call_tainted(node)
        if isinstance(node, ast.NamedExpr):
            return self.is_tainted(node.value)
        return False

    def _call_tainted(self, node: ast.Call) -> bool:
        name = dotted_name(node.func)
        root = name.split(".", 1)[0] if name else _root_name(node.func)
        # sanctioned fetch: host numpy out, never tainted
        if self._is_guard_fetch(node):
            return False
        # metadata/introspection calls return host values
        if name in _HOST_RETURNING or \
                name.startswith(_HOST_RETURNING_PREFIXES):
            return False
        # jnp.stack(...), jax.random.uniform(...), lax.while_loop(...)
        if root in _JAX_ROOTS:
            return True
        # module-local jitted functions return device values
        if name in self.jitted_locals:
            return True
        # method call on a tainted receiver: x.astype(...), x.sum(), ...
        if isinstance(node.func, ast.Attribute):
            if node.func.attr in ("item", "tolist", "block_until_ready"):
                # handled as sinks; their results are host values
                return False
            if node.func.attr in ("memory_analysis", "cost_analysis"):
                # AOT introspection: host metadata, no device buffer
                return False
            return self.is_tainted(node.func.value)
        # builtins that preserve device-ness of their argument
        if name in ("abs", "min", "max", "sum"):
            return any(self.is_tainted(a) for a in node.args)
        return False

    @staticmethod
    def _is_guard_fetch(node: ast.Call) -> bool:
        name = dotted_name(node.func)
        return ((name == "fetch" or name.endswith(".fetch"))
                and any(kw.arg == "reason" for kw in node.keywords))

    # ---- helpers ----------------------------------------------------

    def _flag(self, code: str, node: ast.AST, msg: str) -> None:
        if self.record:
            self.findings.append(finding("sync", code, self.sf, node, msg))

    def run(self) -> None:
        """Flow-sensitive single pass. Loop bodies are pre-visited with
        findings muted so loop-carried taint (a name assigned late in the
        body, used at the top) reaches a fixpoint before recording —
        straight-line code keeps exact statement order, so a host int
        later rebound to a device value doesn't poison its earlier uses."""
        self.visit(self.fn)

    def _muted_visit(self, *nodes: ast.AST) -> None:
        prev, self.record = self.record, False
        try:
            for n in nodes:
                self.visit(n)
        finally:
            self.record = prev

    def _taint_target(self, target: ast.AST, on: bool) -> None:
        if isinstance(target, ast.Name):
            (self.tainted.add if on else self.tainted.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._taint_target(e, on)
        elif isinstance(target, ast.Starred):
            self._taint_target(target.value, on)

    def _check_truthiness(self, test: ast.AST) -> None:
        if self.is_tainted(test):
            self._flag("SYNC004", test,
                       "truthiness of a device value forces a host sync "
                       "(use jnp.where/lax.cond, or guard.fetch the scalar)")

    # ---- statements -------------------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        on = self.is_tainted(node.value)
        # map(int, np.asarray(stats)) unpacking: handled at the Call sink
        for t in node.targets:
            self._taint_target(t, on)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        if self.is_tainted(node.value):
            self._taint_target(node.target, True)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self.generic_visit(node)
        if node.value is not None and node.target is not None:
            self._taint_target(node.target, self.is_tainted(node.value))

    def visit_If(self, node: ast.If) -> None:
        self._check_truthiness(node.test)
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        for stmt in node.body:
            self._muted_visit(stmt)
        self._check_truthiness(node.test)
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        self._check_truthiness(node.test)
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        self._check_truthiness(node.test)
        self.generic_visit(node)

    def visit_BoolOp(self, node: ast.BoolOp) -> None:
        for v in node.values:
            if self.is_tainted(v):
                self._flag("SYNC004", v,
                           "and/or on a device value coerces it to bool "
                           "(host sync); use jnp.logical_and/or")
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        for stmt in node.body:
            self._muted_visit(stmt)
        if self.is_tainted(node.iter):
            self._flag("SYNC005", node.iter,
                       "iterating a device array fetches one element per "
                       "step; batch into one guard.fetch")
        self._taint_target(node.target, self.is_tainted(node.iter))
        self.generic_visit(node)

    def visit_comprehension(self, node: ast.comprehension) -> None:
        if self.is_tainted(node.iter):
            self._flag("SYNC005", node.iter,
                       "comprehension over a device array fetches "
                       "element-wise; batch into one guard.fetch")
        self.generic_visit(node)

    # ---- calls (the scalar-coercion sinks) --------------------------

    def visit_Call(self, node: ast.Call) -> None:
        name = dotted_name(node.func)
        arg0 = node.args[0] if node.args else None
        if name in ("int", "float", "complex") and self.is_tainted(arg0):
            self._flag("SYNC001", node,
                       f"{name}() on a device value is an implicit "
                       "device->host transfer; route through guard.fetch")
        elif name == "bool" and self.is_tainted(arg0):
            self._flag("SYNC004", node,
                       "bool() on a device value is an implicit host sync; "
                       "route through guard.fetch")
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr in ("item", "tolist")
              and self.is_tainted(node.func.value)):
            self._flag("SYNC002", node,
                       f".{node.func.attr}() on a device value is an "
                       "implicit device->host transfer; route through "
                       "guard.fetch")
        elif (name.split(".", 1)[0] in _NP_ALIASES
              and name.split(".")[-1] in ("asarray", "array")
              and self.is_tainted(arg0)):
            self._flag("SYNC003", node,
                       "np.asarray() on a device value materializes on the "
                       "host; route through guard.fetch so the transfer is "
                       "counted")
        elif name in ("jax.device_get",) or (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "block_until_ready"
                and self.is_tainted(node.func.value)):
            self._flag("SYNC006", node,
                       "explicit device sync; annotate with '# sync:' so "
                       "it shows up in the sync budget")
        elif name == "map" and len(node.args) == 2:
            # map(int, <device value>) — the engine's old stats pattern
            f, it = node.args
            if (isinstance(f, ast.Name) and f.id in ("int", "float")
                    and self.is_tainted(it)):
                self._flag("SYNC001", node,
                           "map(int, <device value>) coerces element-wise "
                           "on the host; guard.fetch the vector first")
        self.generic_visit(node)

    # nested defs get their own scope (fresh linter)
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if node is self.fn:
            self.generic_visit(node)
        elif self.record:   # nested scopes linted once, on the record pass
            _FunctionLinter(self.sf, node, self.jitted_locals,
                            self.findings).run()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        # lambdas inherit the enclosing taint set (closures)
        self.generic_visit(node)


def check(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    jitted_locals: Set[str] = {
        n.name for n in ast.walk(sf.tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        and is_jitted(n)
    }
    for node in sf.tree.body:
        _lint_scope(sf, node, jitted_locals, findings)
    return findings


def _lint_scope(sf: SourceFile, node: ast.AST, jitted_locals: Set[str],
                findings: List[Finding]) -> None:
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        _FunctionLinter(sf, node, jitted_locals, findings).run()
    elif isinstance(node, ast.ClassDef):
        for item in node.body:
            _lint_scope(sf, item, jitted_locals, findings)
    # module-level statements: no taint seeds (imports, constants)
