"""Runtime transfer accounting: make every BENCH sync number a measured
quantity instead of a bookkeeping claim.

The repo's hot paths never fetch ad hoc — they call :func:`fetch`, the
ONE sanctioned device->host materialization point (sync-lint enforces
this statically). ``fetch`` does three things:

  * increments every active :class:`TransferMeter` (so a harness wrapped
    around ``run_cluster``/``run_oneshot``/estimator queries/
    ``apply_updates`` measures the true transfer count),
  * records the caller's ``reason`` (the runtime twin of the ``# sync:``
    pragma — annotated by construction),
  * performs the copy inside ``jax.transfer_guard_device_to_host("allow")``
    so it stays legal under the meter's ambient ``"disallow"`` guard.

:func:`measured_transfers` installs ``transfer_guard_device_to_host``
at the requested level around the measured region. On TPU/GPU backends
that guard has teeth: any fetch that bypasses ``guard.fetch`` raises.
On the CPU backend jax arrays share the host buffer, so the guard never
fires (``np.asarray`` is a zero-copy view, not a transfer) — there the
*static* sync-lint is the enforcement layer and the meter still measures
the logical transfer count, which is the paper-relevant quantity (each
``fetch`` is a blocking device round-trip on a real accelerator).

The equality contract proven by the tier-1 tests and ``kernel_bench``:

  measured == EngineMetrics.host_syncs + finalize_syncs   (decomposition)
  measured == PipelineMetrics.total_host_syncs            (pipeline query)
  measured == DynamicMetrics.update_syncs delta           (update batch)
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

import numpy as np


@dataclass
class TransferMeter:
    """Counts sanctioned fetches inside a ``measured_transfers`` region."""

    transfers: int = 0
    elements: int = 0
    events: List[Tuple[str, int]] = field(default_factory=list)

    def reasons(self) -> List[str]:
        return [r for r, _ in self.events]


# stack, not a single slot: harnesses nest (a bench region around an
# estimator that itself opens a region around the engine)
_METERS: List[TransferMeter] = []


def active_meter() -> Optional[TransferMeter]:
    return _METERS[-1] if _METERS else None


@contextlib.contextmanager
def measured_transfers(level: str = "disallow") -> Iterator[TransferMeter]:
    """Measure sanctioned transfers in the enclosed region and (on
    accelerator backends) forbid unsanctioned ones at ``level``
    ("disallow" | "log" | "allow")."""
    import jax

    meter = TransferMeter()
    _METERS.append(meter)
    try:
        with jax.transfer_guard_device_to_host(level):
            yield meter
    finally:
        _METERS.pop()


def fetch(x, *, reason: str) -> np.ndarray:
    """The sanctioned device->host materialization. ``reason`` is
    mandatory and non-empty — it is the runtime twin of the ``# sync:``
    pragma, and shows up in ``TransferMeter.events`` for auditing."""
    if not reason or not reason.strip():
        raise ValueError("guard.fetch requires a non-empty reason")
    import jax

    with jax.transfer_guard_device_to_host("allow"):
        out = np.asarray(x)
    for m in _METERS:
        m.transfers += 1
        m.elements += int(out.size)
        m.events.append((reason, int(out.size)))
    return out
