"""Runtime transfer accounting: make every BENCH sync number a measured
quantity instead of a bookkeeping claim.

The repo's hot paths never fetch ad hoc — they call :func:`fetch`, the
ONE sanctioned device->host materialization point (sync-lint enforces
this statically). ``fetch`` does three things:

  * increments every active :class:`TransferMeter` (so a harness wrapped
    around ``run_cluster``/``run_oneshot``/estimator queries/
    ``apply_updates`` measures the true transfer count),
  * records the caller's ``reason`` (the runtime twin of the ``# sync:``
    pragma — annotated by construction),
  * performs the copy inside ``jax.transfer_guard_device_to_host("allow")``
    so it stays legal under the meter's ambient ``"disallow"`` guard.

:func:`measured_transfers` installs ``transfer_guard_device_to_host``
at the requested level around the measured region. On TPU/GPU backends
that guard has teeth: any fetch that bypasses ``guard.fetch`` raises.
On the CPU backend jax arrays share the host buffer, so the guard never
fires (``np.asarray`` is a zero-copy view, not a transfer) — there the
*static* sync-lint is the enforcement layer and the meter still measures
the logical transfer count, which is the paper-relevant quantity (each
``fetch`` is a blocking device round-trip on a real accelerator).

The equality contract proven by the tier-1 tests and ``kernel_bench``:

  measured == EngineMetrics.host_syncs + finalize_syncs   (decomposition)
  measured == PipelineMetrics.total_host_syncs            (pipeline query)
  measured == DynamicMetrics.update_syncs delta           (update batch)
"""
from __future__ import annotations

import contextlib
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclass
class TransferMeter:
    """Counts sanctioned fetches inside a ``measured_transfers`` region.

    Per-reason accounting is aggregated (one ``Counter`` entry per
    distinct reason string), so a meter's memory is bounded by the number
    of distinct fetch sites — not by the number of fetches. A long serve
    run used to accumulate one ``(reason, size)`` tuple per fetch.
    """

    transfers: int = 0
    elements: int = 0
    reason_counts: Counter = field(default_factory=Counter)
    reason_elements: Counter = field(default_factory=Counter)

    def reasons(self) -> List[str]:
        """Distinct fetch reasons seen in this region, first-seen order."""
        return list(self.reason_counts)

    def by_reason(self) -> Dict[str, Tuple[int, int]]:
        """reason -> (fetch count, total elements fetched)."""
        return {r: (int(c), int(self.reason_elements[r]))
                for r, c in self.reason_counts.items()}


# stack, not a single slot: harnesses nest (a bench region around an
# estimator that itself opens a region around the engine)
_METERS: List[TransferMeter] = []


def active_meter() -> Optional[TransferMeter]:
    return _METERS[-1] if _METERS else None


def push_meter() -> TransferMeter:
    """Push a meter-only region: counts sanctioned fetches without
    touching the jax transfer guard (and without importing jax). This is
    the attribution hook telemetry spans use — pushing a meter costs one
    list append, adds zero host syncs, and composes with any ambient
    ``measured_transfers`` region because ``fetch`` increments every
    meter on the stack."""
    meter = TransferMeter()
    _METERS.append(meter)
    return meter


def pop_meter(meter: TransferMeter) -> TransferMeter:
    # validate before popping: a mismatched pop must not eat someone
    # else's meter on its way to raising
    if not _METERS or _METERS[-1] is not meter:
        raise RuntimeError("guard meter stack corrupted: non-LIFO pop")
    return _METERS.pop()


@contextlib.contextmanager
def metered() -> Iterator[TransferMeter]:
    """Context-manager form of ``push_meter``/``pop_meter``."""
    meter = push_meter()
    try:
        yield meter
    finally:
        pop_meter(meter)


@contextlib.contextmanager
def measured_transfers(level: str = "disallow") -> Iterator[TransferMeter]:
    """Measure sanctioned transfers in the enclosed region and (on
    accelerator backends) forbid unsanctioned ones at ``level``
    ("disallow" | "log" | "allow")."""
    import jax

    meter = TransferMeter()
    _METERS.append(meter)
    try:
        with jax.transfer_guard_device_to_host(level):
            yield meter
    finally:
        _METERS.pop()


def fetch(x, *, reason: str) -> np.ndarray:
    """The sanctioned device->host materialization. ``reason`` is
    mandatory and non-empty — it is the runtime twin of the ``# sync:``
    pragma, and shows up in ``TransferMeter.by_reason()`` for auditing."""
    if not reason or not reason.strip():
        raise ValueError("guard.fetch requires a non-empty reason")
    import jax

    with jax.transfer_guard_device_to_host("allow"):
        out = np.asarray(x)
    size = int(out.size)
    for m in _METERS:
        m.transfers += 1
        m.elements += size
        m.reason_counts[reason] += 1
        m.reason_elements[reason] += size
    return out
