"""CLI: ``python -m repro.analysis [paths...]``.

Exit status 0 iff every finding is pragma-annotated (``# sync:`` /
``# dtype:`` / ``# pallas:`` / ``# det:`` with a non-empty reason).
Suppressed findings are listed with ``-v`` for auditing; parse errors
and empty-reason pragmas always fail.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import all_checkers, run_analysis


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static sync/dtype/pallas/determinism analysis")
    ap.add_argument("paths", nargs="*", default=["src/"],
                    help="files or directories to check (default: src/)")
    ap.add_argument("--checkers", default=",".join(all_checkers()),
                    help="comma-separated subset: sync,dtype,pallas,det")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also list pragma-suppressed findings")
    args = ap.parse_args(argv)

    names = [c.strip() for c in args.checkers.split(",") if c.strip()]
    unknown = set(names) - set(all_checkers())
    if unknown:
        ap.error(f"unknown checkers: {sorted(unknown)} "
                 f"(expected a subset of {sorted(all_checkers())})")

    active, suppressed, errors = run_analysis(args.paths or ["src/"],
                                              checkers=names)
    for f in errors:
        print(f.format())
    for f in active:
        print(f.format())
    if args.verbose:
        for f in suppressed:
            print(f"{f.format()}  [suppressed by pragma]")
    print(f"repro.analysis: {len(active)} finding(s), "
          f"{len(suppressed)} pragma-annotated, {len(errors)} error(s)",
          file=sys.stderr)
    return 1 if (active or errors) else 0


if __name__ == "__main__":
    sys.exit(main())
