"""determinism-lint: decomposition paths promise byte-identical output.

The deterministic one-shot mode (Elkin–Haeupler-style hashed shifts) and
the megakernel parity tests both assert byte-identical results, and the
dynamic path's certified re-clustering depends on replayable decisions.
These only hold if nothing in the decomposition modules draws entropy
from outside the PRNG-key discipline:

  DET001  unseeded host randomness (np.random.* module-state calls,
          random.*): a default_rng(seed)/Generator instance is fine,
          the global-state API is not.
  DET002  time-dependent values (time.time/monotonic/perf_counter,
          datetime.now). Inside decomposition modules wall-clock must
          never reach a decision; in every other ``repro`` module bare
          clock reads must route through the one sanctioned seam,
          ``repro.runtime.telemetry.clock()``/``wall_time()`` (the
          DET002 twin of ``guard.fetch``), so timing sites stay
          auditable. ``runtime/telemetry`` itself is the exempt seam.
  DET003  iteration-order dependence on sets: materializing a set into
          an ordered container (list/tuple/sorted-less np.fromiter/
          np.array, or a bare for-loop) makes downstream output depend
          on hash-iteration order. Tracked for intra-function set
          values and the known set-typed attributes of the dynamic
          subsystem (``dirty_centers``).
  DET004  builtin hash() — PYTHONHASHSEED-dependent for strings.

Rules DET003–DET004 apply only inside decomposition modules (engine,
state, dynamic, quotient, cluster, kernels); DET002 applies to every
``repro`` module except the telemetry seam; DET001 applies everywhere.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.common import Finding, SourceFile, dotted_name, finding

_DECOMP_MARKERS = ("core/engine", "core/state", "core/dynamic",
                   "core/quotient", "core/cluster", "kernels/")

# the ONE module allowed to read the clock directly — everything else in
# repro/ must call telemetry.clock()/wall_time()
_CLOCK_SEAM_MARKERS = ("runtime/telemetry",)

# attributes known (module contract) to hold builtin sets
_KNOWN_SET_ATTRS = {"dirty_centers"}

_TIME_CALLS = {"time.time", "time.monotonic", "time.perf_counter",
               "time.process_time", "datetime.now", "datetime.utcnow",
               "datetime.datetime.now", "datetime.datetime.utcnow"}

_ORDERING_CONSUMERS = {"list", "tuple", "np.fromiter", "numpy.fromiter",
                       "np.array", "numpy.array", "np.asarray",
                       "numpy.asarray"}


def _is_decomp_module(path: str) -> bool:
    p = path.replace("\\", "/")
    return any(m in p for m in _DECOMP_MARKERS)


def _clock_scope(path: str) -> bool:
    """DET002 applies to every repro module except the sanctioned
    telemetry seam (and to all decomposition modules regardless)."""
    p = path.replace("\\", "/")
    if any(m in p for m in _CLOCK_SEAM_MARKERS):
        return False
    return "repro/" in p or _is_decomp_module(path)


def _is_set_expr(node: ast.AST, set_names: Set[str]) -> bool:
    if isinstance(node, ast.Set) or isinstance(node, ast.SetComp):
        return True
    if isinstance(node, ast.Call) and dotted_name(node.func) == "set":
        return True
    if isinstance(node, ast.Name):
        return node.id in set_names
    if isinstance(node, ast.Attribute):
        return node.attr in _KNOWN_SET_ATTRS
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        return (_is_set_expr(node.left, set_names)
                or _is_set_expr(node.right, set_names))
    return False


class _Scope(ast.NodeVisitor):
    def __init__(self, sf: SourceFile, findings: List[Finding],
                 decomp: bool, clocked: bool):
        self.sf = sf
        self.findings = findings
        self.decomp = decomp
        self.clocked = clocked
        self.set_names: Set[str] = set()

    def _flag(self, code: str, node: ast.AST, msg: str) -> None:
        self.findings.append(finding("det", code, self.sf, node, msg))

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        if _is_set_expr(node.value, self.set_names):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.set_names.add(t.id)

    def visit_Call(self, node: ast.Call) -> None:
        name = dotted_name(node.func)
        # DET001 — global-state randomness (everywhere)
        if name.startswith(("np.random.", "numpy.random.", "random.")):
            tail = name.split(".")[-1]
            if tail not in ("default_rng", "Generator", "SeedSequence",
                            "PCG64"):
                self._flag("DET001", node,
                           f"{name}() draws from global RNG state; use "
                           "np.random.default_rng(seed) so decompositions "
                           "replay byte-identically")
            elif tail == "default_rng" and not node.args \
                    and not node.keywords:
                self._flag("DET001", node,
                           "default_rng() without a seed is entropy-"
                           "seeded; pass an explicit seed")
        # DET002 — bare wall clock outside the sanctioned seam
        if self.clocked and name in _TIME_CALLS:
            if self.decomp:
                self._flag("DET002", node,
                           f"{name}() inside a decomposition module: "
                           "wall-clock must never reach a decision")
            else:
                self._flag("DET002", node,
                           f"{name}() bypasses the sanctioned clock seam; "
                           "route timing through repro.runtime.telemetry."
                           "clock()/wall_time()")
        if self.decomp:
            # DET003 — ordered materialization of a set
            if name in _ORDERING_CONSUMERS and node.args and \
                    _is_set_expr(node.args[0], self.set_names):
                self._flag("DET003", node,
                           f"{name}(<set>) fixes hash-iteration order "
                           "into the output; sort first or prove the "
                           "consumer order-insensitive")
            # DET004 — builtin hash
            if name == "hash":
                self._flag("DET004", node,
                           "builtin hash() is PYTHONHASHSEED-dependent "
                           "for strings; use a keyed/integer hash")
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if self.decomp and _is_set_expr(node.iter, self.set_names):
            self._flag("DET003", node.iter,
                       "iterating a set fixes hash order into control "
                       "flow; sort first or prove order-insensitivity")
        self.generic_visit(node)

    def visit_comprehension(self, node: ast.comprehension) -> None:
        if self.decomp and _is_set_expr(node.iter, self.set_names):
            self._flag("DET003", node.iter,
                       "comprehension over a set fixes hash order into "
                       "the result; sort first")
        self.generic_visit(node)


def check(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    _Scope(sf, findings, _is_decomp_module(sf.path),
           _clock_scope(sf.path)).visit(sf.tree)
    return findings
