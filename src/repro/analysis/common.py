"""Shared infrastructure for the ``repro.analysis`` checkers.

Everything here is stdlib-only (``ast`` + ``re``): the static half of the
suite must run in a bare CI job with no jax installed, and must never
import the code it is checking.

The unit of work is a :class:`SourceFile` — parsed AST plus the per-line
pragma table. A checker is a function ``(SourceFile) -> list[Finding]``;
suppression is applied centrally in :func:`run_checkers` so every checker
shares one pragma grammar:

    # sync: <reason>      suppress sync-lint on this line / the next line
    # dtype: <reason>     suppress dtype-bound-lint
    # pallas: <reason>    suppress pallas-lint
    # det: <reason>       suppress determinism-lint

A pragma with an empty reason is itself a finding (PRAGMA000): the whole
point is that every intentional violation carries a justification the
reviewer can audit.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

CHECKERS = ("sync", "dtype", "pallas", "det")

# "# sync: reason" (reason mandatory) — also match a bare "# sync:" so we
# can flag the missing justification instead of silently ignoring it
_PRAGMA_RE = re.compile(
    r"#\s*(?P<checker>sync|dtype|pallas|det)\s*:(?P<reason>.*)$")


@dataclass(frozen=True)
class Finding:
    """One checker hit, anchored to a source line (``end_line`` tracks
    multi-line statements so a pragma beside the closing paren still
    suppresses)."""

    checker: str   # one of CHECKERS (or "pragma" for grammar errors)
    code: str      # short rule id, e.g. "SYNC001"
    path: str
    line: int
    message: str
    end_line: int = 0

    def format(self) -> str:
        return (f"{self.path}:{self.line}: "
                f"[{self.checker}/{self.code}] {self.message}")


@dataclass
class Pragma:
    checker: str
    reason: str
    line: int


@dataclass
class SourceFile:
    """A parsed module plus its pragma table."""

    path: str
    text: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)
    # checker -> set of line numbers the pragma covers (its own line and
    # the next, so a standalone pragma comment covers the statement below)
    pragma_lines: Dict[str, Set[int]] = field(default_factory=dict)
    pragmas: List[Pragma] = field(default_factory=list)
    empty_pragmas: List[Pragma] = field(default_factory=list)

    @classmethod
    def parse(cls, path: str, text: Optional[str] = None) -> "SourceFile":
        if text is None:
            text = Path(path).read_text()
        tree = ast.parse(text, filename=path)
        sf = cls(path=path, text=text, tree=tree,
                 lines=text.splitlines(),
                 pragma_lines={c: set() for c in CHECKERS})
        for lineno, line in enumerate(sf.lines, start=1):
            m = _PRAGMA_RE.search(line)
            if not m:
                continue
            p = Pragma(checker=m.group("checker"),
                       reason=m.group("reason").strip(), line=lineno)
            if not p.reason:
                sf.empty_pragmas.append(p)
                continue
            sf.pragmas.append(p)
            sf.pragma_lines[p.checker].update((lineno, lineno + 1))
        return sf

    def is_suppressed(self, checker: str, node: ast.AST) -> bool:
        """A finding is suppressed when any line the flagged statement
        spans (or the line just above it) carries that checker's pragma —
        multi-line calls keep their pragma next to the closing paren."""
        covered = self.pragma_lines.get(checker, ())
        lo = getattr(node, "lineno", 0)
        hi = getattr(node, "end_lineno", lo) or lo
        return any(ln in covered for ln in range(lo, hi + 2))


def iter_python_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from (str(f) for f in sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            yield str(path)


def dotted_name(node: ast.AST) -> str:
    """'np.asarray' for Attribute chains, 'int' for Names, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def decorator_names(fn: ast.AST) -> List[str]:
    """Dotted names of a function's decorators; calls are unwrapped, so
    ``@partial(jax.jit, ...)`` contributes both 'partial' and 'jax.jit'."""
    names: List[str] = []
    for dec in getattr(fn, "decorator_list", []):
        if isinstance(dec, ast.Call):
            names.append(dotted_name(dec.func))
            names.extend(dotted_name(a) for a in dec.args)
        else:
            names.append(dotted_name(dec))
    return [n for n in names if n]


def jit_static_argnames(fn: ast.AST) -> Set[str]:
    """The static_argnames tuple of a ``@partial(jax.jit, ...)`` /
    ``@jax.jit`` decorator (constant strings only)."""
    out: Set[str] = set()
    for dec in getattr(fn, "decorator_list", []):
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg != "static_argnames":
                continue
            v = kw.value
            elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            for e in elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    out.add(e.value)
    return out


def is_jitted(fn: ast.AST) -> bool:
    names = decorator_names(fn)
    return any(n in ("jax.jit", "jit") or n.endswith(".jit") for n in names)


Checker = Callable[[SourceFile], List[Finding]]


def run_checkers(
    paths: Sequence[str],
    checkers: Dict[str, Checker],
) -> tuple:
    """Run every checker over every file. Returns
    ``(active_findings, suppressed_findings, errors)`` where suppressed
    findings are the pragma-annotated ones (reported for transparency,
    not failures) and errors are unparseable files / empty-reason pragmas
    (always failures)."""
    active: List[Finding] = []
    suppressed: List[Finding] = []
    errors: List[Finding] = []
    for path in iter_python_files(paths):
        try:
            sf = SourceFile.parse(path)
        except SyntaxError as exc:
            errors.append(Finding("pragma", "PARSE", path,
                                  exc.lineno or 0, f"syntax error: {exc.msg}"))
            continue
        for p in sf.empty_pragmas:
            errors.append(Finding(
                "pragma", "PRAGMA000", path, p.line,
                f"'# {p.checker}:' pragma has no reason — every intentional "
                f"violation must carry a justification"))
        for name, checker in checkers.items():
            for f in checker(sf):
                node = _AnchorNode(f.line, f.end_line or f.line)
                if sf.is_suppressed(f.checker, node):
                    suppressed.append(f)
                else:
                    active.append(f)
    return active, suppressed, errors


class _AnchorNode:
    """Minimal line-anchor shim for suppression checks on a Finding."""

    def __init__(self, line: int, end_line: int):
        self.lineno = line
        self.end_lineno = end_line


def finding(checker: str, code: str, sf: SourceFile, node: ast.AST,
            message: str) -> Finding:
    return Finding(checker, code, sf.path, getattr(node, "lineno", 0),
                   message, getattr(node, "end_lineno", 0) or 0)
