from repro.config.base import (
    ArchConfig,
    TransformerConfig,
    MoEConfig,
    GNNConfig,
    RecsysConfig,
    GraphEngineConfig,
    ShapeSpec,
    MeshConfig,
    TrainConfig,
    LM_SHAPES,
    GNN_SHAPES,
    RECSYS_SHAPES,
)
from repro.config.registry import register_arch, get_arch, list_archs, arch_shapes

__all__ = [
    "ArchConfig",
    "TransformerConfig",
    "MoEConfig",
    "GNNConfig",
    "RecsysConfig",
    "GraphEngineConfig",
    "ShapeSpec",
    "MeshConfig",
    "TrainConfig",
    "LM_SHAPES",
    "GNN_SHAPES",
    "RECSYS_SHAPES",
    "register_arch",
    "get_arch",
    "list_archs",
    "arch_shapes",
]
