"""Dataclass configuration system.

Every selectable architecture is an ``ArchConfig`` subclass instance registered
under its ``--arch`` id. Shapes are ``ShapeSpec``s; each arch family carries its
own shape set (per the assignment: LM shapes are seq x batch, GNN shapes are
graph sizes, recsys shapes are batch regimes).

Configs are plain frozen dataclasses: hashable (usable as jit static args),
serializable via ``dataclasses.asdict``, overridable via ``.replace()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell for an architecture.

    ``kind`` selects which step gets lowered:
      - "train"    -> train_step
      - "prefill"  -> serve_prefill (full-sequence forward, no grads)
      - "decode"   -> serve_step (1 new token against a KV cache of seq_len)
      - "full_graph" / "minibatch" / "batched_graphs" -> GNN regimes
      - "recsys_train" / "recsys_serve" / "retrieval" -> recsys regimes
    """

    name: str
    kind: str
    # LM fields
    seq_len: int = 0
    global_batch: int = 0
    # GNN fields
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    n_graphs: int = 0
    # recsys fields
    batch: int = 0
    n_candidates: int = 0


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class ArchConfig:
    name: str = "base"
    family: str = "base"  # lm | gnn | recsys | graph

    def param_count(self) -> int:  # overridden per family
        return 0


@dataclass(frozen=True)
class TransformerConfig(ArchConfig):
    family: str = "lm"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    d_head: int = 0  # 0 -> d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 1024
    # attention variants
    sliding_window: int = 0          # 0 = full attention on every layer
    local_global_alternating: bool = False  # gemma2: even layers local(SW), odd global
    attn_logit_softcap: float = 0.0  # gemma2: 50.0
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    qkv_bias: bool = False           # qwen1.5
    rope_theta: float = 10000.0
    max_position: int = 131072
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"                # swiglu gate act ("gelu" for gemma2)
    dtype: str = "bfloat16"
    # remat / scan
    remat: str = "none"              # none | full | dots_saveable
    scan_layers: bool = True
    loss_chunks: int = 0             # CE chunking (0 = auto: 8 when S>=2k)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    def param_count(self) -> int:
        d, h = self.d_model, self.head_dim
        attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d
        mlp = 3 * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d


@dataclass(frozen=True)
class MoEConfig(TransformerConfig):
    """Mixture-of-experts transformer (mixtral / moonlight style)."""

    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25    # slots per expert vs perfect balance
    moe_groups: int = 0              # dispatch groups (= DP shards; 0 -> 1).
                                     # Group-local dispatch keeps the capacity
                                     # buffer sharded over 'data' instead of
                                     # replicated (see models/transformer.py)
    n_shared_experts: int = 0        # moonlight: shared expert(s) always active
    d_ff_shared: int = 0             # width of shared expert (0 -> d_ff)
    moe_every: int = 1               # MoE layer every k-th layer (1 = all layers)
    router_aux_loss: float = 0.01

    def param_count(self) -> int:
        d, h = self.d_model, self.head_dim
        attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d
        moe = 3 * d * self.d_ff * self.n_experts + d * self.n_experts
        shared = 3 * d * (self.d_ff_shared or self.d_ff) * self.n_shared_experts
        per_layer = attn + moe + shared + 2 * d
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d

    def active_param_count(self) -> int:
        d, h = self.d_model, self.head_dim
        attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d
        moe = 3 * d * self.d_ff * self.top_k + d * self.n_experts
        shared = 3 * d * (self.d_ff_shared or self.d_ff) * self.n_shared_experts
        per_layer = attn + moe + shared + 2 * d
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d


@dataclass(frozen=True)
class GNNConfig(ArchConfig):
    family: str = "gnn"
    kind: str = "gcn"                # gcn | gatedgcn | meshgraphnet | equiformer_v2
    n_layers: int = 2
    d_hidden: int = 16
    d_in: int = 0                    # input feature dim (0 -> shape-provided)
    d_out: int = 7                   # output classes / targets
    aggregator: str = "mean"         # mean | sum | max | gated
    norm: str = "sym"                # sym | none (GCN adjacency normalization)
    mlp_layers: int = 2              # meshgraphnet per-block MLP depth
    d_edge: int = 0                  # edge feature dim (0 -> none)
    # equiformer-v2 fields
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    dtype: str = "float32"
    residual: bool = False

    def param_count(self) -> int:
        d = self.d_hidden
        return self.n_layers * (3 * d * d + 2 * d)  # rough; exact per model


@dataclass(frozen=True)
class RecsysConfig(ArchConfig):
    family: str = "recsys"
    kind: str = "xdeepfm"
    n_sparse: int = 39
    n_dense: int = 13                 # criteo-style numeric features
    embed_dim: int = 10
    vocab_per_field: int = 100_000    # embedding rows per sparse field
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    mlp_dims: Tuple[int, ...] = (400, 400)
    multi_hot: int = 1                # ids per field (embedding-bag degree)
    dtype: str = "float32"

    def param_count(self) -> int:
        emb = self.n_sparse * self.vocab_per_field * self.embed_dim
        m = self.n_sparse
        cin = 0
        prev = m
        for hk in self.cin_layers:
            cin += hk * prev * m
            prev = hk
        mlp_in = self.n_sparse * self.embed_dim + self.n_dense
        mlp = 0
        prev = mlp_in
        for w in self.mlp_dims:
            mlp += prev * w + w
            prev = w
        return emb + cin + mlp + prev + sum(self.cin_layers) + 1


@dataclass(frozen=True)
class GraphEngineConfig(ArchConfig):
    """Config for the paper's decomposition/diameter engine."""

    family: str = "graph"
    tau_fraction: float = 1e-3       # tau ~ n * tau_fraction (paper: quotient ~ n/1000)
    gamma: float = 2.0               # center-sampling constant (paper: gamma)
    variant: str = "stop"            # stop | complete  (paper Table 2)
    delta_init: str = "avg"          # avg | min | <int>  (paper: avg edge weight)
    max_stages: int = 64
    max_steps_per_phase: int = 0     # 0 -> 2n/tau (paper's num_it)
    use_cluster2: bool = False       # paper optimization (1): default CLUSTER
    seed: int = 0
    backend: str = "single"          # single | sharded | pallas (core/backend.py)
    comm: str = "halo"               # sharded backend collective: halo (static
                                     # boundary-row exchange, default) | allgather
                                     # (full-plane baseline); byte-identical results
    relax_impl: str = "auto"         # pallas backend kernel impl: auto | ref | pallas
    autotune: str = "off"            # off | auto | record (core/autotune.py)
    fuse_supersteps: int = 0         # pallas megakernel fusion depth
                                     # (0 = unfused unless the autotuner engages)
    node_tile: int = 0               # pallas tiling overrides; 0 = kernel
    edge_block: int = 0              # defaults (or autotuned under autotune)
    mode: str = "stages"             # stages | oneshot | auto (core/engine.py
                                     # decomposition modes; "auto" defers to
                                     # the autotuning record)
    deterministic: bool = False      # oneshot: hash-derived shifts, output
                                     # is a seed-independent graph function


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 10
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    seed: int = 0
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    zero1: bool = True                # shard optimizer state over data axis
    grad_compression: str = "none"    # none | int8_ef
    log_every: int = 10


# ---------------------------------------------------------------------------
# Canonical shape sets (from the assignment).
# ---------------------------------------------------------------------------

LM_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec(name="train_4k", kind="train", seq_len=4096, global_batch=256),
    ShapeSpec(name="prefill_32k", kind="prefill", seq_len=32768, global_batch=32),
    ShapeSpec(name="decode_32k", kind="decode", seq_len=32768, global_batch=128),
    ShapeSpec(name="long_500k", kind="decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec(name="full_graph_sm", kind="full_graph", n_nodes=2708, n_edges=10556, d_feat=1433),
    ShapeSpec(
        name="minibatch_lg",
        kind="minibatch",
        n_nodes=232_965,
        n_edges=114_615_892,
        batch_nodes=1024,
        fanout=(15, 10),
        d_feat=602,
    ),
    ShapeSpec(name="ogb_products", kind="full_graph", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100),
    ShapeSpec(name="molecule", kind="batched_graphs", n_nodes=30, n_edges=64, n_graphs=128, d_feat=32),
)

RECSYS_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec(name="train_batch", kind="recsys_train", batch=65536),
    ShapeSpec(name="serve_p99", kind="recsys_serve", batch=512),
    ShapeSpec(name="serve_bulk", kind="recsys_serve", batch=262144),
    ShapeSpec(name="retrieval_cand", kind="retrieval", batch=1, n_candidates=1_000_000),
)


def shapes_for_family(family: str) -> Tuple[ShapeSpec, ...]:
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}[family]


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
