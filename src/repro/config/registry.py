"""Architecture registry: maps ``--arch`` ids to config factories.

Importing ``repro.configs`` registers all assigned architectures. Factories are
lazy so importing the registry never builds big configs eagerly.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Tuple

from repro.config.base import ArchConfig, ShapeSpec, shapes_for_family

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}
_SMOKE_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}

# arch-id -> module under repro.configs that registers it
_ARCH_MODULES = {
    "gemma2-9b": "gemma2_9b",
    "qwen1.5-32b": "qwen1_5_32b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "gcn-cora": "gcn_cora",
    "gatedgcn": "gatedgcn",
    "meshgraphnet": "meshgraphnet",
    "equiformer-v2": "equiformer_v2",
    "xdeepfm": "xdeepfm",
    "paper-graph": "paper_graph",
}


def register_arch(name: str, factory: Callable[[], ArchConfig], smoke: Callable[[], ArchConfig]) -> None:
    _REGISTRY[name] = factory
    _SMOKE_REGISTRY[name] = smoke


def _ensure_loaded(name: str) -> None:
    if name in _REGISTRY:
        return
    mod = _ARCH_MODULES.get(name)
    if mod is None:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    importlib.import_module(f"repro.configs.{mod}")


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    _ensure_loaded(name)
    reg = _SMOKE_REGISTRY if smoke else _REGISTRY
    return reg[name]()


def list_archs() -> Tuple[str, ...]:
    return tuple(sorted(_ARCH_MODULES))


def arch_shapes(name: str) -> Tuple[ShapeSpec, ...]:
    cfg = get_arch(name)
    return shapes_for_family(cfg.family)
