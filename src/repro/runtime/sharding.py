"""Per-family sharding rules: param / data / state PartitionSpec trees.

Mesh semantics (launch/mesh.py):
  single pod  (16, 16)      axes ("data", "model")
  multi-pod   (2, 16, 16)   axes ("pod", "data", "model") — 'pod' joins the
                            data-parallel axes by default (DP over pods);
                            runtime/pipeline.py can claim it for PP instead.

LM params are stacked [L, ...]: the layer axis never shards (it is the scan
axis); the widest non-layer dim takes 'model' (TP). MoE experts shard over
'model' (EP). GNN full-graph shards nodes/edges over the whole flat mesh.
Recsys shards embedding rows over 'model' and the batch over data axes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import (
    ArchConfig,
    GNNConfig,
    MoEConfig,
    RecsysConfig,
    ShapeSpec,
    TransformerConfig,
)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All data-parallel axes ('pod' + 'data' when multi-pod)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def flat_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def lm_param_specs(cfg: TransformerConfig, mesh: Mesh) -> Dict[str, Any]:
    """PartitionSpec tree matching models/transformer.init_params."""
    m = "model"
    layers: Dict[str, P] = {
        "attn_norm": P(None, None),
        "mlp_norm": P(None, None),
        "wq": P(None, None, m),      # column-parallel
        "wk": P(None, None, m),
        "wv": P(None, None, m),
        "wo": P(None, m, None),      # row-parallel (all-reduce after)
    }
    if cfg.qkv_bias:
        layers |= {"bq": P(None, m), "bk": P(None, m), "bv": P(None, m)}
    if isinstance(cfg, MoEConfig):
        # EP when the expert count divides the model axis; otherwise TP the
        # expert FFN width (mixtral: 8 experts on a 16-wide axis)
        if cfg.n_experts % mesh.shape[m] == 0:
            e_gate, e_down = P(None, m, None, None), P(None, m, None, None)
        else:
            e_gate, e_down = P(None, None, None, m), P(None, None, m, None)
        layers |= {
            "router": P(None, None, None),
            "w_gate": e_gate,
            "w_up": e_gate,
            "w_down": e_down,
        }
        if cfg.n_shared_experts:
            layers |= {
                "ws_gate": P(None, None, m),
                "ws_up": P(None, None, m),
                "ws_down": P(None, m, None),
            }
    else:
        layers |= {
            "w_gate": P(None, None, m),
            "w_up": P(None, None, m),
            "w_down": P(None, m, None),
        }
    specs: Dict[str, Any] = {
        "embed": P(m, None),          # vocab-sharded
        "final_norm": P(None),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(None, m)
    return specs


def lm_batch_specs(mesh: Mesh) -> Dict[str, P]:
    d = data_axes(mesh)
    return {"tokens": P(d, None), "labels": P(d, None)}


def lm_cache_specs(cfg: TransformerConfig, mesh: Mesh, batch: int) -> Dict[str, Any]:
    """KV cache [L, B, Hkv, S, Dh]. decode_32k shards B over data axes; the
    long_500k cell (B=1) shards the SEQUENCE over the flat mesh instead
    (sequence parallelism for the cache — see DESIGN.md)."""
    d = data_axes(mesh)
    if batch == 1:
        spec = P(None, None, None, d + ("model",), None)   # SP over cache len
    else:
        spec = P(None, d, None, "model", None)
    return {"k": spec, "v": spec, "len": P()}


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def gnn_param_specs(params, mesh: Mesh):
    """GNN weights are small (<= few MB): replicate everything."""
    return jax.tree.map(lambda _: P(), params)


def gnn_graph_specs(mesh: Mesh, minibatch: bool = False) -> Dict[str, P]:
    flat = flat_axes(mesh)
    d = data_axes(mesh)
    if minibatch:
        # sampled blocks: batch-of-seeds over data axes, big padded node/edge
        # tables over the flat mesh
        return {
            "x": P(flat, None), "src": P(flat), "dst": P(flat),
            "labels": P(flat), "seed_slots": P(d),
        }
    return {
        "x": P(flat, None), "src": P(flat), "dst": P(flat),
        "labels": P(flat), "pos": P(flat, None), "e": P(flat, None),
        "graph_id": P(flat), "targets": P(flat, None),
    }


# ---------------------------------------------------------------------------
# Recsys
# ---------------------------------------------------------------------------

def recsys_param_specs(cfg: RecsysConfig, mesh: Mesh):
    return {
        "tables": P(None, "model", None),   # row-sharded vocab
        "linear": P(None, "model"),
        "cin": [P() for _ in cfg.cin_layers],
        "cin_out": P(),
        "mlp": [{"w": P(), "b": P()} for _ in range(len(cfg.mlp_dims) + 1)],
        "bias": P(),
    }


def recsys_batch_specs(mesh: Mesh) -> Dict[str, P]:
    d = data_axes(mesh)
    return {
        "ids": P(d, None, None),
        "id_mask": P(d, None, None),
        "dense": P(d, None),
        "labels": P(d),
    }


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def named(mesh: Mesh, tree):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
