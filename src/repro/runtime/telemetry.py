"""Telemetry: nestable span tracing with per-span transfer attribution,
a unified metrics registry, and Perfetto/JSONL/Prometheus exporters.

The paper's claims are observability claims — fewer rounds, less wire
traffic, bounded space — and this module is where those quantities stop
being scattered dataclass fields and become one queryable surface:

  * :func:`span` opens a nestable phase span (``engine.stage``,
    ``quotient.solve``, ``dynamic.relax``, ...). On close each span
    attaches the counters produced nearby (supersteps, kernel_launches,
    halo_bytes, ...) plus **per-reason transfer attribution**: a
    ``guard`` meter is pushed for the span's lifetime, and the exclusive
    share (own fetches minus descendants') labels every measured sync
    with the span that caused it.
  * :class:`MetricsRegistry` folds ``EngineMetrics`` / ``PipelineMetrics``
    / ``SessionMetrics`` / ``DynamicMetrics`` / ``TransferMeter``
    snapshots into one :class:`TelemetrySnapshot` of counters, gauges and
    streaming histograms (p50/p95/p99).
  * :func:`export_chrome_trace` / :func:`export_jsonl` /
    :func:`export_prometheus` write the three consumer formats;
    :func:`write_telemetry` is the one-call launcher hook.

Hard contracts:

  * **Zero host syncs.** Nothing here touches jax — span attribution
    uses ``guard.push_meter``/``pop_meter`` (list appends), never the
    transfer guard. The PR 8 transfer-equality asserts hold bit-exact
    with tracing enabled (see ``kernel_bench``'s ``"telemetry"`` block).
  * **Near-zero cost when off.** With no tracer installed, ``span()``
    returns a shared no-op singleton — no allocation on hot paths.
  * **One clock seam.** :func:`clock` / :func:`wall_time` are the ONLY
    sanctioned time reads in ``src/repro`` (the DET002 twin of
    ``guard.fetch``): determinism-lint flags bare ``time.*`` calls
    everywhere else, so every timing site is auditable here.

No jax, no ``repro.common`` imports (``common.util.Timer`` routes its
clock through here, so the dependency must point this way).
"""
from __future__ import annotations

import contextlib
import json
import math
import os
import time
from collections import Counter
from dataclasses import dataclass, field, fields, is_dataclass
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.analysis import guard

# --------------------------------------------------------------------------
# The sanctioned clock seam (DET002 twin of guard.fetch)
# --------------------------------------------------------------------------


def clock() -> float:
    """Monotonic seconds — the ONE sanctioned ``perf_counter`` read.

    Every duration in ``src/repro`` (Timer, span timing, serve latency)
    routes through here so determinism-lint can flag stray wall-clock
    reads in compute paths while this module stays the audited seam.
    """
    return time.perf_counter()


def wall_time() -> float:
    """Epoch seconds — the ONE sanctioned ``time.time`` read. For
    provenance metadata only (checkpoint ``written_at`` stamps, export
    headers); never feeds a computed result."""
    return time.time()


# --------------------------------------------------------------------------
# Span tracer
# --------------------------------------------------------------------------


@dataclass
class SpanRecord:
    """A closed span. ``transfers``/``elements``/``by_reason`` are the
    span's *exclusive* share (own fetches minus descendants'), so summing
    them over any trace equals the total measured transfers exactly."""

    name: str
    start: float                     # seconds from tracer epoch
    duration: float
    depth: int
    index: int                       # start order, unique within a trace
    parent: Optional[int]            # parent span's index
    attrs: Dict[str, Any] = field(default_factory=dict)
    transfers: int = 0               # exclusive fetch count
    elements: int = 0                # exclusive fetched elements
    transfers_incl: int = 0          # inclusive (self + descendants)
    by_reason: Dict[str, int] = field(default_factory=dict)


class Span:
    """A live span: context manager pushed by ``Tracer.span``. ``set()``
    attaches attributes (supersteps, kernel_launches, ...) any time
    before close."""

    __slots__ = ("_tracer", "name", "attrs", "index", "depth", "_parent",
                 "_t0", "_meter", "_child_transfers", "_child_elements",
                 "_child_reasons")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any],
                 index: int, depth: int, parent: Optional[int]):
        self._tracer = tracer
        self.name = name
        self.attrs = dict(attrs)
        self.index = index
        self.depth = depth
        self._parent = parent
        self._child_transfers = 0
        self._child_elements = 0
        self._child_reasons: Counter = Counter()

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._t0 = clock()
        self._meter = guard.push_meter()
        return self

    def __exit__(self, *exc: Any) -> None:
        end = clock()
        tracer = self._tracer
        # validate BEFORE popping the guard meter so an out-of-order close
        # raises without corrupting the meter stack
        if not tracer._live or tracer._live[-1] is not self:
            raise RuntimeError("span stack corrupted: non-LIFO close")
        meter = guard.pop_meter(self._meter)
        tracer._live.pop()
        excl_reasons = meter.reason_counts - self._child_reasons
        record = SpanRecord(
            name=self.name,
            start=self._t0 - tracer.epoch,
            duration=end - self._t0,
            depth=self.depth,
            index=self.index,
            parent=self._parent,
            attrs=self.attrs,
            transfers=meter.transfers - self._child_transfers,
            elements=meter.elements - self._child_elements,
            transfers_incl=meter.transfers,
            by_reason={r: int(c) for r, c in excl_reasons.items() if c},
        )
        tracer.spans.append(record)
        if tracer._live:
            parent = tracer._live[-1]
            parent._child_transfers += meter.transfers
            parent._child_elements += meter.elements
            parent._child_reasons += meter.reason_counts


class _NullSpan:
    """Shared no-op span: returned when no tracer is installed so hot
    paths pay one truthiness check and no allocation."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Tracer:
    """Collects closed :class:`SpanRecord`\\ s for one traced region."""

    def __init__(self) -> None:
        self.epoch = clock()
        self.spans: List[SpanRecord] = []
        self._live: List[Span] = []
        self._next_index = 0

    def span(self, name: str, **attrs: Any) -> Span:
        parent = self._live[-1].index if self._live else None
        s = Span(self, name, attrs, self._next_index,
                 depth=len(self._live), parent=parent)
        self._next_index += 1
        self._live.append(s)
        return s

    # -- trace-level queries -------------------------------------------

    def total_transfers(self) -> int:
        """Sum of exclusive transfer counts == total fetches measured
        under any root span (exclusive counts partition the total)."""
        return sum(s.transfers for s in self.spans)

    def attribution(self) -> Dict[str, Dict[str, int]]:
        """span name -> {reason: exclusive fetch count}, aggregated over
        all spans with that name. Fetches outside any span don't appear
        here — wrap the region in a root span for exactness."""
        out: Dict[str, Counter] = {}
        for s in self.spans:
            if s.transfers:
                out.setdefault(s.name, Counter()).update(s.by_reason)
        return {name: dict(c) for name, c in out.items()}


# Stack, not a slot: a serve harness traces the whole replay while a
# bench traces one query inside it.
_TRACERS: List[Tracer] = []


def active_tracer() -> Optional[Tracer]:
    return _TRACERS[-1] if _TRACERS else None


def span(name: str, **attrs: Any):
    """Open a span on the active tracer, or a shared no-op when tracing
    is off. Usage: ``with telemetry.span("engine.stage", stage=i) as sp:
    ...; sp.set(supersteps=k)``."""
    if not _TRACERS:
        return NULL_SPAN
    return _TRACERS[-1].span(name, **attrs)


@contextlib.contextmanager
def tracing(tracer: Optional[Tracer] = None) -> Iterator[Tracer]:
    """Install a tracer for the enclosed region."""
    t = tracer if tracer is not None else Tracer()
    _TRACERS.append(t)
    try:
        yield t
    finally:
        popped = _TRACERS.pop()
        if popped is not t:
            raise RuntimeError("tracer stack corrupted: non-LIFO pop")


# --------------------------------------------------------------------------
# Streaming histogram
# --------------------------------------------------------------------------

_HIST_GROWTH = 1.08
_HIST_LOG_GROWTH = math.log(_HIST_GROWTH)
_HIST_TINY = 1e-12


class StreamingHistogram:
    """Log-bucketed streaming histogram: O(distinct magnitudes) memory,
    exact-associative merge, quantiles within a ``GROWTH`` relative
    factor (~4% at 1.08) of the true order statistic.

    Values are nonnegative (latencies, counts); values below ``1e-12``
    (including 0) share one underflow bucket. ``quantile`` clamps to the
    exact observed ``[min, max]``, so constant data is quantile-exact.
    """

    __slots__ = ("count", "total", "min", "max", "_buckets", "_zero")

    GROWTH = _HIST_GROWTH

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets: Dict[int, int] = {}
        self._zero = 0

    def record(self, value: float) -> None:
        v = float(value)
        if v < 0.0 or math.isnan(v):
            raise ValueError(f"histogram values must be >= 0, got {value}")
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v < _HIST_TINY:
            self._zero += 1
        else:
            idx = int(math.floor(math.log(v) / _HIST_LOG_GROWTH))
            self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def merge(self, other: "StreamingHistogram") -> "StreamingHistogram":
        """Fold ``other`` into self. Bucket-count addition — associative
        and commutative exactly, so shard-then-merge equals streaming."""
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        self._zero += other._zero
        for idx, c in other._buckets.items():
            self._buckets[idx] = self._buckets.get(idx, 0) + c
        return self

    def quantile(self, q: float) -> float:
        """Approximate ``q``-quantile (q in [0, 1]). Empty -> 0.0."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        if q >= 1.0:
            return self.max    # the extremes are tracked exactly
        if q <= 0.0:
            return self.min
        # rank in [1, count]; walk buckets in value order
        rank = max(1, int(math.ceil(q * self.count)))
        if rank <= self._zero:
            return max(0.0, self.min)
        seen = self._zero
        for idx in sorted(self._buckets):
            seen += self._buckets[idx]
            if seen >= rank:
                # geometric midpoint of the bucket, clamped to observed range
                mid = math.exp((idx + 0.5) * _HIST_LOG_GROWTH)
                return min(max(mid, self.min), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min,
            "max": self.max,
            "mean": self.mean,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


# --------------------------------------------------------------------------
# Metrics registry
# --------------------------------------------------------------------------


@dataclass
class TelemetrySnapshot:
    """One frozen view of everything the registry knows: monotonic
    counters, point-in-time gauges, and histogram summaries."""

    counters: Dict[str, float] = field(default_factory=dict)
    gauges: Dict[str, float] = field(default_factory=dict)
    histograms: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: dict(v) for k, v in self.histograms.items()}}


class MetricsRegistry:
    """Unifies the repo's per-subsystem metrics dataclasses into one
    namespace. ``ingest`` folds any metrics dataclass's numeric fields in
    as ``<prefix>.<field>`` counters; ``TransferMeter`` additionally
    contributes per-reason ``<prefix>.reason.<reason>`` counters."""

    def __init__(self) -> None:
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, StreamingHistogram] = {}

    def counter(self, name: str, inc: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + inc

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def histogram(self, name: str) -> StreamingHistogram:
        hist = self.histograms.get(name)
        if hist is None:
            hist = self.histograms[name] = StreamingHistogram()
        return hist

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).record(value)

    def ingest(self, metrics: Any, prefix: str) -> None:
        """Fold a metrics object in. Accepts the repo's dataclasses
        (EngineMetrics, PipelineMetrics, SessionMetrics, DynamicMetrics),
        a ``guard.TransferMeter``, or any object with numeric attrs."""
        if isinstance(metrics, guard.TransferMeter):
            self.counter(f"{prefix}.transfers", metrics.transfers)
            self.counter(f"{prefix}.elements", metrics.elements)
            for reason, (n, elems) in metrics.by_reason().items():
                self.counter(f"{prefix}.reason.{reason}", n)
            return
        if is_dataclass(metrics):
            pairs = [(f.name, getattr(metrics, f.name)) for f in fields(metrics)]
        else:
            pairs = [(k, v) for k, v in vars(metrics).items()
                     if not k.startswith("_")]
        for name, value in pairs:
            if isinstance(value, bool) or not isinstance(value, (int, float)):
                continue
            self.counter(f"{prefix}.{name}", float(value))

    def snapshot(self) -> TelemetrySnapshot:
        return TelemetrySnapshot(
            counters=dict(self.counters),
            gauges=dict(self.gauges),
            histograms={k: h.summary() for k, h in self.histograms.items()},
        )


# --------------------------------------------------------------------------
# Exporters
# --------------------------------------------------------------------------


def _json_default(obj):
    """Span attrs may carry numpy scalars (counter fetches); unwrap them."""
    item = getattr(obj, "item", None)
    if callable(item):
        return item()
    return str(obj)


def export_chrome_trace(tracer: Tracer, path: str) -> None:
    """Chrome/Perfetto trace JSON (load in ui.perfetto.dev or
    chrome://tracing). One complete ("X") event per span; counters and
    per-reason transfer attribution ride in ``args``."""
    events = []
    for s in sorted(tracer.spans, key=lambda s: s.index):
        args: Dict[str, Any] = dict(s.attrs)
        args["transfers"] = s.transfers
        args["elements"] = s.elements
        if s.by_reason:
            args["transfer_reasons"] = s.by_reason
        events.append({
            "name": s.name,
            "ph": "X",
            "cat": "repro",
            "pid": 1,
            "tid": 1,
            "ts": s.start * 1e6,      # Chrome trace wants microseconds
            "dur": s.duration * 1e6,
            "args": args,
        })
    payload = {"traceEvents": events, "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_json_default)


def export_jsonl(tracer: Optional[Tracer], snapshot: Optional[TelemetrySnapshot],
                 path: str) -> None:
    """One JSON object per line: ``span`` records (close order) then one
    final ``snapshot`` record. Harness-friendly: grep/jq-able, appendable."""
    with open(path, "w") as f:
        if tracer is not None:
            for s in tracer.spans:
                f.write(json.dumps({
                    "type": "span", "name": s.name, "index": s.index,
                    "parent": s.parent, "depth": s.depth,
                    "start_s": s.start, "duration_s": s.duration,
                    "transfers": s.transfers, "elements": s.elements,
                    "by_reason": s.by_reason, "attrs": s.attrs,
                }, default=_json_default) + "\n")
        if snapshot is not None:
            f.write(json.dumps({"type": "snapshot", **snapshot.to_dict()}) + "\n")


def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if ch.isalnum() or ch == "_" else "_")
    s = "".join(out)
    if s and s[0].isdigit():
        s = "_" + s
    return s


def export_prometheus(snapshot: TelemetrySnapshot, path: str) -> None:
    """Prometheus text exposition format: counters as ``_total``,
    gauges verbatim, histograms as quantile-labeled summaries."""
    lines: List[str] = []
    for name in sorted(snapshot.counters):
        pname = _prom_name(name) + "_total"
        lines.append(f"# TYPE {pname} counter")
        lines.append(f"{pname} {snapshot.counters[name]:g}")
    for name in sorted(snapshot.gauges):
        pname = _prom_name(name)
        lines.append(f"# TYPE {pname} gauge")
        lines.append(f"{pname} {snapshot.gauges[name]:g}")
    for name in sorted(snapshot.histograms):
        summ = snapshot.histograms[name]
        pname = _prom_name(name)
        lines.append(f"# TYPE {pname} summary")
        for q, key in (("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99")):
            lines.append(f'{pname}{{quantile="{q}"}} {summ[key]:g}')
        lines.append(f"{pname}_count {summ['count']:g}")
        lines.append(f"{pname}_sum {summ['sum']:g}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def write_telemetry(out_dir: str, tracer: Optional[Tracer] = None,
                    registry: Optional[MetricsRegistry] = None) -> Dict[str, str]:
    """The one-call launcher hook: write ``trace.json`` (Perfetto),
    ``spans.jsonl`` and ``metrics.prom`` under ``out_dir``. Returns the
    paths written."""
    os.makedirs(out_dir, exist_ok=True)
    written: Dict[str, str] = {}
    snapshot = registry.snapshot() if registry is not None else None
    if tracer is not None:
        trace_path = os.path.join(out_dir, "trace.json")
        export_chrome_trace(tracer, trace_path)
        written["trace"] = trace_path
    if tracer is not None or snapshot is not None:
        jsonl_path = os.path.join(out_dir, "spans.jsonl")
        export_jsonl(tracer, snapshot, jsonl_path)
        written["jsonl"] = jsonl_path
    if snapshot is not None:
        prom_path = os.path.join(out_dir, "metrics.prom")
        export_prometheus(snapshot, prom_path)
        written["prom"] = prom_path
    return written
