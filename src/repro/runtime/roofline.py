"""Roofline term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips x 819e9  HBM B/s)
  collective = collective_wire_bytes / (chips x 50e9 ICI B/s per link)

FLOPs/bytes come from compiled.cost_analysis(). Collective bytes are NOT in
cost_analysis — we parse the optimized HLO text and sum operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops. Sizes are whole-array; per-chip wire bytes depend on the algorithm
(ring all-gather moves (n-1)/n of the output through each link), so we apply
the standard per-collective ring factors.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

# TPU v5e per-chip constants (from the assignment)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# instruction form: %name = <result shape(s)> op(...). Result tuples may
# embed /*index=NNN*/ comments, so the shape region must be matched with `.`
# (anchored at the instruction's "=") rather than [^=].
_COLLECTIVE_RE = re.compile(
    r"^\s*%?[\w.\-]+\s*=\s*(?P<outshape>.*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(?P<dt>(?:pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128))\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def wire_bytes(self) -> float:
        """Ring-algorithm wire bytes per chip (factors applied at parse)."""
        return float(sum(self.bytes_by_op.values()))


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum RING-algorithm wire bytes per chip for every collective.

    Result-shape conventions in SPMD HLO:
      all-gather      result = post-gather (big)  -> wire ~ (g-1)/g * result
      all-reduce      result = local shard        -> wire ~ 2 (g-1)/g * result
      reduce-scatter  result = post-scatter (small)-> wire ~ (g-1) * result
      all-to-all      result = local size         -> wire ~ (g-1)/g * result
      collective-permute                          -> wire ~ 1 * result
    g = replica group size (parsed from replica_groups=[n,g]<=[...]).
    -start/-done async pairs counted once (at -start).
    """
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue  # counted at -start
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("outshape"))
        g = 0
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        g = max(g, 2)
        if op == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = float(nbytes) * (g - 1)
        elif op == "collective-permute":
            wire = float(nbytes)
        else:  # all-gather / all-to-all
            wire = float(nbytes) * (g - 1) / g
        st.counts[op] = st.counts.get(op, 0) + 1
        st.bytes_by_op[op] = st.bytes_by_op.get(op, 0) + wire
    return st


@dataclass
class RooflineReport:
    name: str
    n_chips: int
    hlo_flops: float
    hlo_bytes: float
    collective: CollectiveStats
    model_flops: float = 0.0          # 6*N*D analytic (0 if n/a)
    bytes_per_device: float = 0.0     # from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.n_chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.n_chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective.wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the dominant-resource time implies for the
        useful (model) FLOPs: model_time_at_peak / bound_time."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        ideal = (self.model_flops or self.hlo_flops) / (self.n_chips * PEAK_FLOPS)
        return ideal / bound if bound else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "chips": self.n_chips,
            "hlo_gflops": round(self.hlo_flops / 1e9, 2),
            "hlo_gbytes": round(self.hlo_bytes / 1e9, 3),
            "coll_gbytes": round(self.collective.wire_bytes / 1e9, 4),
            "t_compute_ms": round(self.t_compute * 1e3, 4),
            "t_memory_ms": round(self.t_memory * 1e3, 4),
            "t_collective_ms": round(self.t_collective * 1e3, 4),
            "bottleneck": self.bottleneck,
            "useful_ratio": round(self.useful_flops_ratio, 3),
            "roofline_frac": round(self.roofline_fraction, 3),
            "bytes_per_dev_mb": round(self.bytes_per_device / 1e6, 1),
            "collectives": dict(self.collective.counts),
        }


def analyze(name: str, lowered, compiled, n_chips: int,
            model_flops: float = 0.0) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    # XLA reports the PER-PARTITION program's flops/bytes under SPMD
    # (verified against an analytic matmul); scale to global so the
    # assignment's  HLO_FLOPs / (chips x peak)  formula applies directly.
    flops = float(cost.get("flops", 0.0)) * n_chips
    nbytes = float(cost.get("bytes accessed", 0.0)) * n_chips
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = parse_collectives(hlo)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem_bytes = float(getattr(ma, "argument_size_in_bytes", 0)
                          + getattr(ma, "output_size_in_bytes", 0)
                          + getattr(ma, "temp_size_in_bytes", 0))
    except Exception:
        mem_bytes = 0.0
    return RooflineReport(
        name=name, n_chips=n_chips, hlo_flops=flops, hlo_bytes=nbytes,
        collective=coll, model_flops=model_flops, bytes_per_device=mem_bytes,
    )
