"""Pipeline parallelism over the 'pod' axis (GPipe via collective_permute).

For multi-pod meshes the default is DP over 'pod'; this module provides the
alternative: each pod owns a contiguous block of layers, microbatches stream
through pods with ppermute handoffs — the cross-pod DCI link then carries
activations (B_micro x S x D) instead of a full gradient all-reduce, which
wins when params >> activations (the usual regime for the big LM archs; the
trade is quantified in EXPERIMENTS.md §Perf).

shard_map formulation: the layer-stacked params [L, ...] shard their L axis
over 'pod' (each pod holds L/P layers). One pipeline step runs the classic
GPipe schedule: n_micro + n_stage - 1 ticks; tick t has stage s processing
microbatch t - s. Activations hop stages via ppermute; the bubble fraction
(n_stage - 1)/(n_micro + n_stage - 1) is the known GPipe overhead.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.compat import shard_map


def gpipe_forward(
    mesh: Mesh,
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    n_micro: int,
    pod_axis: str = "pod",
):
    """Build a pipelined forward: params_stacked [P_stages, ...] x [B, ...].

    stage_fn(stage_params, x) -> x : one pod's chunk of the network.
    Returns fn(params_stacked, batch) -> out with batch split into n_micro
    microbatches along axis 0.
    """
    n_stage = mesh.shape[pod_axis]

    def pipelined(stage_params, batch):
        # inside shard_map: stage_params is this pod's slice (leading dim 1)
        sp = jax.tree.map(lambda x: x[0], stage_params)
        stage = jax.lax.axis_index(pod_axis)
        micro = jnp.split(batch, n_micro, axis=0)
        micro = jnp.stack(micro)                      # [M, mB, ...]
        m_shape = micro.shape[1:]

        n_tick = n_micro + n_stage - 1
        fwd_perm = [(i, (i + 1) % n_stage) for i in range(n_stage)]

        def tick(carry, t):
            buf, outs = carry                          # buf: [mB, ...] in-flight
            mb_idx = t - stage                         # microbatch at this stage
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # stage 0 ingests a fresh microbatch; others take the handoff
            take = jnp.clip(mb_idx, 0, n_micro - 1)
            x_in = jnp.where(stage == 0, micro[take], buf)
            y = stage_fn(sp, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage emits; others pass along the ring
            out_idx = t - (n_stage - 1)
            emit = (stage == n_stage - 1) & active
            outs = jax.lax.cond(
                (out_idx >= 0) & (out_idx < n_micro),
                lambda o: o.at[jnp.clip(out_idx, 0, n_micro - 1)].set(
                    jnp.where(emit, y, o[jnp.clip(out_idx, 0, n_micro - 1)])
                ),
                lambda o: o,
                outs,
            )
            nxt = jax.lax.ppermute(y, pod_axis, fwd_perm)
            return (nxt, outs), None

        buf0 = jnp.zeros(m_shape, batch.dtype)
        outs0 = jnp.zeros((n_micro,) + m_shape, batch.dtype)
        (_, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(n_tick, dtype=jnp.int32)
        )
        # every pod holds the last stage's emissions only on the last pod;
        # broadcast so outputs are replicated over 'pod'
        outs = jax.lax.all_gather(outs, pod_axis)[n_stage - 1]
        return outs.reshape((-1,) + m_shape[1:])

    other_axes = tuple(a for a in mesh.axis_names if a != pod_axis)

    def run(params_stacked, batch):
        return shard_map(
            pipelined,
            mesh=mesh,
            in_specs=(P(pod_axis), P(other_axes[0] if other_axes else None)),
            out_specs=P(other_axes[0] if other_axes else None),
            check_vma=False,
        )(params_stacked, batch)

    return run


def stage_split(params_layers, n_stage: int):
    """Reshape layer-stacked params [L, ...] -> [n_stage, L/n_stage, ...]."""
    def f(x):
        L = x.shape[0]
        assert L % n_stage == 0, (L, n_stage)
        return x.reshape(n_stage, L // n_stage, *x.shape[1:])
    return jax.tree.map(f, params_layers)
