"""Distributed runtime: sharding rules, PP, compression, fault, roofline."""
