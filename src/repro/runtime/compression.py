"""Compression codecs: lossy int8 for gradients, lossless int32 for graphs.

Two regimes live here:

  * Error-feedback int8 gradient compression for the DP all-reduce
    (Seide et al. / EF-SGD): per-tensor symmetric int8 quantization with
    an error-feedback residual — the quantization error of step t is
    added back at step t+1, so the residual telescopes and the compressed
    optimizer matches uncompressed SGD/Adam to first order. LOSSY by
    construction; fine for gradients, forbidden for graph structure.

  * ``pack_i32``/``unpack_i32`` — LOSSLESS host-side packing for the
    int32 edge arrays held by ``graph.storage.GraphStore``. Slab columns
    (sorted destination ids, near-sorted sources after the
    cluster-locality relabeling) are delta-encoded, zig-zag mapped to
    unsigned, and stored at the minimal width that fits — a dst column of
    a sorted slab typically packs to 1–2 bytes/edge instead of 4. The
    round-trip is exact (byte-identical int32 out), so compressed
    residency never perturbs the decomposition.

The compressed all-reduce runs inside shard_map: quantize locally, all-to-all
int8 chunks (reduce-scatter shape), local fp32 reduction, re-quantize the
reduced shard, all-gather int8 — total bytes on the wire ~ 1/4 of fp32
ring all-reduce. On CPU/dry-run the same code lowers with int8 collectives
visible in the HLO (counted by the roofline pass).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


# ---------------------------------------------------------------------------
# Lossless int32 packing (GraphStore slab residency)
# ---------------------------------------------------------------------------


class PackedI32(NamedTuple):
    """A losslessly packed int32 column: zig-zag deltas at minimal width.

    ``data`` holds the unsigned zig-zag deltas in the narrowest numpy
    dtype that fits their maximum; ``first`` anchors the delta chain.
    ``unpack_i32`` reproduces the original array byte-identically.
    """

    data: np.ndarray
    n: int
    first: int

    @property
    def nbytes(self) -> int:
        return int(self.data.nbytes)


def pack_i32(x: np.ndarray) -> PackedI32:
    """Delta + zig-zag + minimal-width packing of an int32 array.

    Deltas of int32 values need 33 bits in the worst case, so the
    intermediate math runs in int64; zig-zag folds the sign
    (``z = (d << 1) ^ (d >> 63)``) so small negative deltas stay small
    unsigned values, then the column is stored at the narrowest of
    uint8/16/32/64 that holds the maximum.
    """
    x = np.ascontiguousarray(np.asarray(x, dtype=np.int32))
    if x.ndim != 1:
        raise ValueError(f"pack_i32 expects a 1-d column, got shape {x.shape}")
    if x.size == 0:
        return PackedI32(np.zeros(0, np.uint8), 0, 0)
    wide = x.astype(np.int64)
    d = np.diff(wide, prepend=wide[:1])
    z = ((d << 1) ^ (d >> 63)).astype(np.uint64)
    z[0] = 0  # the anchor rides in `first`, not the delta stream
    hi = int(z.max()) if z.size else 0
    for dt in (np.uint8, np.uint16, np.uint32, np.uint64):
        if hi <= np.iinfo(dt).max:
            return PackedI32(z.astype(dt), int(x.size), int(x[0]))
    raise AssertionError("unreachable: uint64 always fits a zig-zag delta")


def unpack_i32(p: PackedI32) -> np.ndarray:
    """Exact inverse of :func:`pack_i32` — byte-identical int32 out."""
    if p.n == 0:
        return np.zeros(0, np.int32)
    z = p.data.astype(np.uint64)
    d = (z >> np.uint64(1)).astype(np.int64) ^ -(z & np.uint64(1)).astype(np.int64)
    d[0] = p.first
    return np.cumsum(d).astype(np.int32)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads, residual):
    """Add error feedback, quantize. Returns (q_tree, scale_tree, new_resid)."""
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual
    )
    qs = jax.tree.map(quantize_int8, corrected)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    new_resid = jax.tree.map(
        lambda c, qq, ss: c - dequantize_int8(qq, ss), corrected, q, s
    )
    return q, s, new_resid


def init_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(q, scale, axis_name):
    """Mean-reduce int8-compressed tensors across `axis_name` inside
    shard_map: dequantize -> psum -> (values stay fp32 for the optimizer).
    Wire bytes: int8 payload enters the collective via the all_to_all
    reduce-scatter decomposition below when tensors are large."""
    deq = jax.tree.map(dequantize_int8, q, scale)
    n = jax.lax.psum(1, axis_name)
    return jax.tree.map(lambda v: jax.lax.psum(v, axis_name) / n, deq)


def int8_allreduce_shardmap(mesh: Mesh, axis: str):
    """Returns fn(grads_fp32) -> mean over `axis` with int8 wire format.

    Decomposition per leaf: reshape to [W, chunk] (W = axis size), quantize,
    all_to_all (each peer gets its chunk from everyone: int8 on the wire),
    local fp32 mean of the W received chunks, re-quantize, all_gather int8,
    dequantize. Leaves smaller than W*16 fall back to fp32 psum.
    """
    w = mesh.shape[axis]

    def reduce_leaf(g):
        flat = g.reshape(-1).astype(jnp.float32)
        n = flat.shape[0]
        if n < w * 16:
            return jax.lax.pmean(g.astype(jnp.float32), axis).astype(g.dtype)
        pad = (-n) % w
        fp = jnp.pad(flat, (0, pad)).reshape(w, -1)
        q, s = quantize_int8(fp)
        got = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0, tiled=True)
        s_all = jax.lax.all_gather(s, axis)
        chunk = jnp.mean(got.astype(jnp.float32) * s_all[:, None].reshape(w, 1), axis=0)
        q2, s2 = quantize_int8(chunk)
        gq = jax.lax.all_gather(q2, axis, tiled=True)
        gs = jax.lax.all_gather(s2, axis)
        out = (gq.astype(jnp.float32).reshape(w, -1) * gs[:, None]).reshape(-1)
        out = out[:n] if pad else out
        return out.reshape(g.shape).astype(g.dtype)

    def fn(grads):
        return jax.tree.map(reduce_leaf, grads)

    return fn
