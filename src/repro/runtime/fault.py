"""Fault tolerance: preemption handling, retries, straggler mitigation.

Mechanisms (all exercised by tests):
  * PreemptionGuard — SIGTERM/SIGINT sets a flag; the training loop
    checkpoints and exits cleanly at the next step boundary.
  * retriable() — exponential-backoff retry wrapper for transient device /
    filesystem errors (the restart path re-enters from the last checkpoint).
  * StragglerMonitor — per-step wall-time EWMA; steps slower than
    `threshold x` the EWMA are logged with the step payload so an external
    scheduler can re-shard or evict the slow host. The data pipeline
    over-decomposes shards 4x (data/pipeline.py) so rebalancing is possible
    without re-sharding model state.
  * The paper's own `stop` rule is a SEMANTIC straggler cut: a growth phase
    ends when half the frontier is covered instead of waiting for the
    slowest tail of the wave (Table 2 shows the accuracy cost is negligible).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.common import get_logger

log = get_logger("repro.fault")


class Preempted(Exception):
    """A decomposition was preempted at a stage boundary AFTER its
    checkpoint was durably written.

    Raised by the stage-boundary checkpoint hook (``core.engine.
    StageCheckpointer``) when a ``PreemptionGuard`` observed SIGTERM /
    SIGINT: the current stage finishes, the full state (planes + RNG key
    + stage scalars + GraphStore buffers) is saved, and THEN this fires —
    so catching it at the launcher and exiting with
    :data:`EXIT_PREEMPTED` guarantees ``--resume`` restarts from the
    exact boundary and finishes byte-identically.

    Deliberately a direct ``Exception`` subclass (not ``RuntimeError``):
    :func:`retriable` retries ``RuntimeError`` by default, and a
    preemption must never be retried in place.
    """

    def __init__(self, stage: int, path: Optional[str], signum: Optional[int] = None):
        super().__init__(
            f"preempted at stage boundary {stage}; checkpoint at {path}")
        self.stage = stage
        self.path = path
        self.signum = signum


# BSD EX_TEMPFAIL: the conventional "re-run me" exit status the launchers
# return after a clean preemption checkpoint.
EXIT_PREEMPTED = 75


class PreemptionGuard:
    """SIGTERM-aware context: `guard.should_stop` flips on preemption."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._signals = signals
        self._prev = {}
        self.should_stop = False
        self.received: Optional[int] = None

    def _handler(self, signum, frame):
        self.should_stop = True
        self.received = signum
        log.warning("preemption signal %s received; will checkpoint and exit", signum)

    def __enter__(self) -> "PreemptionGuard":
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc) -> None:
        for s, prev in self._prev.items():
            signal.signal(s, prev)


def retriable(fn: Callable, retries: int = 3, base_delay: float = 0.1,
              exceptions=(OSError, IOError, RuntimeError)):
    """Exponential-backoff wrapper for transient failures."""

    def wrapped(*args, **kwargs):
        delay = base_delay
        for attempt in range(retries + 1):
            try:
                return fn(*args, **kwargs)
            except exceptions as e:
                if attempt == retries:
                    raise
                log.warning("attempt %d failed (%s); retrying in %.2fs",
                            attempt + 1, e, delay)
                time.sleep(delay)
                delay *= 2

    return wrapped


@dataclass
class StragglerMonitor:
    """EWMA step timing; flags outlier steps (straggling hosts/steps)."""

    threshold: float = 2.0
    alpha: float = 0.1
    ewma: float = 0.0
    n: int = 0
    flagged: List[int] = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        if self.n >= 3 and seconds > self.threshold * self.ewma:
            self.flagged.append(step)
            log.warning(
                "straggler: step %d took %.3fs (%.1fx EWMA %.3fs)",
                step, seconds, seconds / max(self.ewma, 1e-9), self.ewma,
            )
            slow = True
        else:
            slow = False
        self.ewma = seconds if self.n == 0 else (
            (1 - self.alpha) * self.ewma + self.alpha * seconds
        )
        self.n += 1
        return slow
