"""Explicit all-to-all expert parallelism (shard_map), the production path.

GSPMD cannot shard a scatter whose destination dim ('expert') is indexed by
data-dependent values: it materializes the full [E, C, d] dispatch buffer on
every data rank and reduce-scatters it (measured 891 GB wire/chip at
moonshot/train_4k even after constraint pinning). This module hand-writes
what the hardware should do — the DeepSeek/MaxText dispatch:

  1. tokens are already sharded over EVERY mesh axis (the residual stream is
     sequence-sharded over 'model' by act_spec);
  2. each chip routes its local tokens, sorts the (token, choice) pairs by
     destination model-rank, and packs a [M, C_s, d] send buffer;
  3. one all_to_all over 'model' delivers tokens to their experts' owner;
  4. the owner runs its E/M experts as dense local GEMMs (position-in-expert
     sort again, all chip-local);
  5. the reverse all_to_all returns expert outputs to the token owners, which
     combine with their locally-kept gates.

Wire bytes per chip per layer = 2 x (M-1)/M x C_s x M x d x 2B (+ the same in
bwd) — activations only, no replication. Differentiable end-to-end (a2a
transposes to a2a; scatters/gathers are local).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.compat import shard_map


def _pack_by_destination(h, flat_dest, tok_idx, n_dest, cap, keep_extra=None):
    """Sort (token, choice) pairs by destination, pack into [n_dest, cap, d].
    Returns (buffer, slot, keep). Dropped pairs write to a pad column."""
    n = flat_dest.shape[0]
    order = jnp.argsort(flat_dest, stable=True)
    sorted_d = flat_dest[order]
    seg_start = jnp.searchsorted(sorted_d, jnp.arange(n_dest, dtype=flat_dest.dtype))
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - seg_start[sorted_d].astype(jnp.int32)
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    if keep_extra is not None:
        keep = keep & keep_extra
    slot = jnp.where(keep, pos, cap)              # pad column
    buf = jnp.zeros((n_dest, cap + 1, h.shape[-1]), h.dtype)
    buf = buf.at[flat_dest, slot].add(h[tok_idx] * keep.astype(h.dtype)[:, None])
    return buf[:, :cap], slot, keep


def moe_ffn_a2a(
    mesh: Mesh,
    x2d: jnp.ndarray,          # [T, d] tokens (sharded over ALL axes outside)
    exp_idx: jnp.ndarray,      # [T, k] global expert ids
    gate_vals: jnp.ndarray,    # [T, k] f32
    w_gate: jnp.ndarray,       # [E, d, f]
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,       # [E, f, d]
    act_fn,
    capacity_factor: float = 1.25,
    model_axis: str = "model",
) -> jnp.ndarray:
    T, d = x2d.shape
    E, _, f = w_gate.shape
    k = exp_idx.shape[1]
    flat = tuple(mesh.axis_names)
    M = mesh.shape[model_axis]

    if E < M:
        # VIRTUAL EXPERTS (mixtral: 8 experts on a 16-wide axis): each
        # expert's FFN width splits across v ranks; a token sends one copy
        # per f-shard and the combine's existing sum adds the partials —
        # exact TP-within-expert, expressed as EP so the same a2a works.
        assert M % E == 0, (E, M)
        v = M // E
        f2 = f // v
        w_gate = jnp.concatenate(
            [w_gate[:, :, i * f2:(i + 1) * f2] for i in range(v)], axis=0)
        w_up = jnp.concatenate(
            [w_up[:, :, i * f2:(i + 1) * f2] for i in range(v)], axis=0)
        w_down = jnp.concatenate(
            [w_down[:, i * f2:(i + 1) * f2, :] for i in range(v)], axis=0)
        exp_idx = jnp.concatenate(
            [exp_idx + i * E for i in range(v)], axis=1)      # [T, k*v]
        gate_vals = jnp.concatenate([gate_vals] * v, axis=1)
        E, f, k = E * v, f2, k * v

    E_loc = E // M
    n_chips = int(np.prod([mesh.shape[a] for a in flat]))
    Tl = T // n_chips
    C_s = max(int(math.ceil(Tl * k / M * capacity_factor)), 4)
    C_e = max(int(math.ceil(M * C_s / E_loc * capacity_factor)), 4)

    def body(h, exp, gate, wg, wu, wd):
        # h [Tl, d]; exp/gate [Tl, k]; wg/wu [E_loc, d, f]; wd [E_loc, f, d]
        dest = (exp // E_loc).reshape(-1)               # [Tl*k] model rank
        e_loc = (exp % E_loc).reshape(-1)
        tok_idx = jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), k)

        send_x, slot, keep = _pack_by_destination(h, dest, tok_idx, M, C_s)
        # expert-id metadata travels in its own (tiny) a2a
        e_buf = jnp.full((M, C_s + 1), E_loc, jnp.int32)  # E_loc = invalid
        e_buf = e_buf.at[dest, slot].set(
            jnp.where(keep, e_loc, E_loc).astype(jnp.int32))
        e_send = e_buf[:, :C_s]

        recv_x = jax.lax.all_to_all(send_x, model_axis, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(e_send, model_axis, 0, 0, tiled=True)

        # ---- local expert compute --------------------------------------
        fx = recv_x.reshape(M * C_s, d)
        fe = recv_e.reshape(M * C_s)
        valid = fe < E_loc
        # invalid slots get their own destination bucket (E_loc) so padding
        # cannot crowd out the last expert's capacity
        x_disp_all, slot2, keep2 = _pack_by_destination(
            fx, jnp.where(valid, fe, E_loc).astype(jnp.int32),
            jnp.arange(M * C_s, dtype=jnp.int32), E_loc + 1, C_e,
            keep_extra=valid)
        x_disp = x_disp_all[:E_loc]
        g = act_fn(jnp.einsum("ecd,edf->ecf", x_disp, wg))
        u = jnp.einsum("ecd,edf->ecf", x_disp, wu)
        y = jnp.einsum("ecf,efd->ecd", g * u, wd)       # [E_loc, C_e, d]
        y_pad = jnp.concatenate(
            [y, jnp.zeros((E_loc, 1, d), y.dtype)], axis=1)
        fe_safe = jnp.where(valid, fe, 0)
        y_rows = y_pad[fe_safe, jnp.where(keep2, slot2, C_e)]  # [M*C_s, d]
        y_back = y_rows.reshape(M, C_s, d)

        back = jax.lax.all_to_all(y_back, model_axis, 0, 0, tiled=True)
        back_pad = jnp.concatenate(
            [back, jnp.zeros((M, 1, d), back.dtype)], axis=1)
        y_tok = back_pad[dest, jnp.where(keep, slot, C_s)]     # [Tl*k, d]
        y_tok = y_tok * (gate.reshape(-1) * keep.astype(jnp.float32))[:, None]
        return jax.ops.segment_sum(y_tok, tok_idx, num_segments=Tl)

    out = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(flat, None), P(flat, None), P(flat, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=P(flat, None),
        check_vma=False,
    )(x2d, exp_idx, gate_vals, w_gate, w_up, w_down)
    return out
