"""Real-spherical-harmonic Wigner rotation matrices (Ivanic-Ruedenberg).

EquiformerV2's eSCN trick rotates each edge's irrep features so the edge
direction aligns with +z; the SO(3) tensor-product convolution then reduces
to independent SO(2) mixes per |m| (O(L^3) instead of O(L^6)). The rotation
is the block-diagonal Wigner-D in the REAL spherical harmonic basis.

We precompute, per l, the SPARSE bilinear recursion of Ivanic & Ruedenberg
(J. Phys. Chem. 1996, + 1998 erratum): D^l = M_l(r (x) D^{l-1}) where r is
the l=1 rotation (a permuted copy of the 3x3 rotation matrix) — host-side
index/coefficient tables, evaluated on device as gather-multiply-segment_sum
batched over edges. Trace-time cost is O(1); runtime cost O(E * nnz_l).
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _d1_index(m: int) -> int:
    """Real-SH l=1 ordering m=-1,0,1 -> cartesian (y, z, x) row of R."""
    return {-1: 1, 0: 2, 1: 0}[m]


def _delta(a, b) -> float:
    return 1.0 if a == b else 0.0


@lru_cache(maxsize=None)
def _l_recursion_table(l: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Sparse map for D^l from (r, D^{l-1}).

    Returns (r_idx, d_idx, coeff, out_idx): each term contributes
      coeff * r_flat[r_idx] * Dprev_flat[d_idx]  to  D_flat[out_idx].
    Index layout: r_flat = r[(i+1)*3 + (j+1)] for i,j in -1..1;
    Dprev_flat over (2l-1)^2 with m in -l+1..l-1; D_flat over (2l+1)^2.
    """
    terms: List[Tuple[int, int, float, int]] = []
    n_prev = 2 * l - 1

    def ridx(i: int, j: int) -> int:
        return (i + 1) * 3 + (j + 1)

    def didx(mu: int, m: int) -> int:
        return (mu + l - 1) * n_prev + (m + l - 1)

    def P(i: int, mu: int, m2: int) -> List[Tuple[int, int, float]]:
        """Expansion of the paper's P function into (r_idx, d_idx, coeff)."""
        if m2 == l:
            return [
                (ridx(i, 1), didx(mu, l - 1), 1.0),
                (ridx(i, -1), didx(mu, -l + 1), -1.0),
            ]
        if m2 == -l:
            return [
                (ridx(i, 1), didx(mu, -l + 1), 1.0),
                (ridx(i, -1), didx(mu, l - 1), 1.0),
            ]
        return [(ridx(i, 0), didx(mu, m2), 1.0)]

    for m1 in range(-l, l + 1):
        for m2 in range(-l, l + 1):
            out = (m1 + l) * (2 * l + 1) + (m2 + l)
            denom = float((l + m2) * (l - m2)) if abs(m2) < l else float(2 * l * (2 * l - 1))
            u = np.sqrt((l + m1) * (l - m1) / denom)
            v = 0.5 * np.sqrt(
                (1 + _delta(m1, 0)) * (l + abs(m1) - 1) * (l + abs(m1)) / denom
            ) * (1 - 2 * _delta(m1, 0))
            w = -0.5 * np.sqrt(
                (l - abs(m1) - 1) * (l - abs(m1)) / denom
            ) * (1 - _delta(m1, 0))

            parts: List[Tuple[int, int, float]] = []
            if u:
                parts += [(r, d, u * c) for r, d, c in P(0, m1, m2)]
            if v:
                if m1 == 0:
                    sub = P(1, 1, m2) + P(-1, -1, m2)
                elif m1 > 0:
                    sub = [(r, d, c * np.sqrt(1 + _delta(m1, 1)))
                           for r, d, c in P(1, m1 - 1, m2)]
                    sub += [(r, d, -c * (1 - _delta(m1, 1)))
                            for r, d, c in P(-1, -m1 + 1, m2)]
                else:
                    sub = [(r, d, c * (1 - _delta(m1, -1)))
                           for r, d, c in P(1, m1 + 1, m2)]
                    sub += [(r, d, c * np.sqrt(1 + _delta(m1, -1)))
                            for r, d, c in P(-1, -m1 - 1, m2)]
                parts += [(r, d, v * c) for r, d, c in sub]
            if w and m1 != 0:
                if m1 > 0:
                    sub = P(1, m1 + 1, m2) + P(-1, -m1 - 1, m2)
                else:
                    sub = [(r, d, c) for r, d, c in P(1, m1 - 1, m2)]
                    sub += [(r, d, -c) for r, d, c in P(-1, -m1 + 1, m2)]
                parts += [(r, d, w * c) for r, d, c in sub]

            terms += [(r, d, c, out) for r, d, c in parts if c != 0.0]

    r_idx = np.array([t[0] for t in terms], np.int32)
    d_idx = np.array([t[1] for t in terms], np.int32)
    coeff = np.array([t[2] for t in terms], np.float32)
    out_idx = np.array([t[3] for t in terms], np.int32)
    return r_idx, d_idx, coeff, out_idx


def rotation_to_d1(rot: jnp.ndarray) -> jnp.ndarray:
    """3x3 cartesian rotation(s) [..., 3, 3] -> l=1 real-SH rotation r."""
    perm = np.array([_d1_index(m) for m in (-1, 0, 1)])
    return rot[..., perm, :][..., :, perm]


@partial(jax.jit, static_argnames=("l_max",))
def wigner_d_stack(rot: jnp.ndarray, l_max: int) -> List[jnp.ndarray]:
    """Per-l Wigner-D blocks for a batch of rotations.

    rot [E, 3, 3] -> list of [E, 2l+1, 2l+1] for l = 0..l_max.
    """
    E = rot.shape[0]
    r = rotation_to_d1(rot)                    # [E, 3, 3]
    r_flat = r.reshape(E, 9)
    blocks = [jnp.ones((E, 1, 1), rot.dtype), r]
    d_prev = r
    for l in range(2, l_max + 1):
        ri, di, cf, oi = _l_recursion_table(l)
        vals = (
            r_flat[:, ri]
            * d_prev.reshape(E, -1)[:, di]
            * jnp.asarray(cf)[None, :]
        )
        d_l = jax.ops.segment_sum(
            vals.T, jnp.asarray(oi), num_segments=(2 * l + 1) ** 2
        ).T.reshape(E, 2 * l + 1, 2 * l + 1)
        blocks.append(d_l)
        d_prev = d_l
    return blocks


def edge_rotation(vec: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """Rotation matrices aligning each edge vector with +z.

    vec [E, 3] -> R [E, 3, 3] with R @ (vec/|vec|) = z. Uses the Rodrigues
    construction; degenerate (anti)parallel cases fall back to diag(1,-1,-1).
    """
    n = vec / (jnp.linalg.norm(vec, axis=-1, keepdims=True) + eps)
    z = jnp.array([0.0, 0.0, 1.0], vec.dtype)
    v = jnp.cross(n, jnp.broadcast_to(z, n.shape))      # rotation axis * sin
    c = n[..., 2]                                       # cos(theta)
    vx = jnp.zeros(n.shape[:-1] + (3, 3), vec.dtype)
    vx = vx.at[..., 0, 1].set(-v[..., 2]).at[..., 0, 2].set(v[..., 1])
    vx = vx.at[..., 1, 0].set(v[..., 2]).at[..., 1, 2].set(-v[..., 0])
    vx = vx.at[..., 2, 0].set(-v[..., 1]).at[..., 2, 1].set(v[..., 0])
    eye = jnp.eye(3, dtype=vec.dtype)
    k = 1.0 / jnp.maximum(1.0 + c, eps)
    r = eye + vx + (vx @ vx) * k[..., None, None]
    flip = jnp.diag(jnp.array([1.0, -1.0, -1.0], vec.dtype))
    anti = (c < -1.0 + 1e-6)[..., None, None]
    return jnp.where(anti, flip, r)


def rotate_irreps(feat: jnp.ndarray, blocks: List[jnp.ndarray],
                  transpose: bool = False) -> jnp.ndarray:
    """Apply block-diagonal Wigner-D to irrep features.

    feat [E, K, C] with K = (l_max+1)^2 (real-SH coefficient order
    l ascending, m = -l..l within l); blocks from wigner_d_stack.
    """
    outs = []
    off = 0
    for l, d in enumerate(blocks):
        k = 2 * l + 1
        f = feat[:, off : off + k]
        dm = jnp.swapaxes(d, -1, -2) if transpose else d
        outs.append(jnp.einsum("eij,ejc->eic", dm, f))
        off += k
    return jnp.concatenate(outs, axis=1)
