"""The four assigned GNN architectures, on a shared segment-op substrate.

JAX has no sparse message-passing primitive (BCOO only) — per the assignment,
message passing here IS built from `jnp.take` gathers + `jax.ops.segment_sum/
max` scatters over an edge index (ref path), with the Pallas `segment_mm`
kernel as the TPU hot path for the scalar-coefficient SpMM cases (GCN).

Batch conventions (one per assigned shape regime):
  full_graph      x [N, F], edges (src, dst) [E], labels [N] (CE on mask)
  minibatch       layered blocks from the neighbor sampler (padded, static)
  batched_graphs  G disjoint small graphs flattened; graph_id [N] for pooling
All forward passes take a `graph` dict so the same step functions lower for
every regime.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import GNNConfig
from repro.models.wigner import edge_rotation, rotate_irreps, wigner_d_stack

Params = Dict[str, Any]


def _dense(k, fan_in, *shape):
    return jax.random.normal(k, shape, jnp.float32) * (fan_in ** -0.5)


def _mlp_init(key, dims: Tuple[int, ...]) -> Params:
    ks = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": _dense(ks[i], dims[i], dims[i], dims[i + 1])
        for i in range(len(dims) - 1)
    } | {f"b{i}": jnp.zeros(dims[i + 1]) for i in range(len(dims) - 1)}


def _mlp_apply(p: Params, x: jnp.ndarray, n: int, act=jax.nn.relu,
               final_act: bool = False) -> jnp.ndarray:
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def _layernorm(x, eps=1e-5):
    m = x.mean(-1, keepdims=True)
    v = x.var(-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + eps)


def segment_softmax(scores, seg, num_segments):
    smax = jax.ops.segment_max(scores, seg, num_segments=num_segments)
    ex = jnp.exp(scores - smax[seg])
    den = jax.ops.segment_sum(ex, seg, num_segments=num_segments)
    return ex / jnp.maximum(den[seg], 1e-9)


# ---------------------------------------------------------------------------
# GCN  (Kipf & Welling; sym-normalized SpMM)
# ---------------------------------------------------------------------------

def gcn_init(cfg: GNNConfig, d_in: int, key) -> Params:
    dims = [d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.d_out]
    ks = jax.random.split(key, len(dims))
    return {
        "layers": [
            {"w": _dense(ks[i], dims[i], dims[i], dims[i + 1]),
             "b": jnp.zeros(dims[i + 1])}
            for i in range(len(dims) - 1)
        ]
    }


def gcn_forward(params, graph, cfg: GNNConfig) -> jnp.ndarray:
    x, src, dst = graph["x"], graph["src"], graph["dst"]
    n = x.shape[0]
    ones = jnp.ones_like(src, jnp.float32)
    deg = jax.ops.segment_sum(ones, dst, num_segments=n) + 1.0  # +self loop
    if cfg.norm == "sym":
        coeff = jax.lax.rsqrt(deg[src]) * jax.lax.rsqrt(deg[dst])
        self_coeff = 1.0 / deg
    else:
        coeff = 1.0 / deg[dst]
        self_coeff = 1.0 / deg
    for i, lp in enumerate(params["layers"]):
        h = x @ lp["w"]
        agg = jax.ops.segment_sum(h[src] * coeff[:, None], dst, num_segments=n)
        x = agg + h * self_coeff[:, None] + lp["b"]
        if i < len(params["layers"]) - 1:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# GatedGCN  (Bresson & Laurent; edge-gated residual message passing)
# ---------------------------------------------------------------------------

def gatedgcn_init(cfg: GNNConfig, d_in: int, d_edge_in: int, key) -> Params:
    d = cfg.d_hidden
    ks = jax.random.split(key, 4 + cfg.n_layers)
    p: Params = {
        "embed_h": {"w": _dense(ks[0], d_in, d_in, d), "b": jnp.zeros(d)},
        "embed_e": {"w": _dense(ks[1], max(d_edge_in, 1), max(d_edge_in, 1), d),
                    "b": jnp.zeros(d)},
        "head": {"w": _dense(ks[2], d, d, cfg.d_out), "b": jnp.zeros(cfg.d_out)},
        "layers": [],
    }
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[3 + i], 5)
        p["layers"].append({
            name: {"w": _dense(kk[j], d, d, d), "b": jnp.zeros(d)}
            for j, name in enumerate(["A", "B", "C", "D", "E"])
        })
    return p


def gatedgcn_forward(params, graph, cfg: GNNConfig) -> jnp.ndarray:
    src, dst = graph["src"], graph["dst"]
    n = graph["x"].shape[0]
    lin = lambda lp, x: x @ lp["w"] + lp["b"]
    h = lin(params["embed_h"], graph["x"])
    e_in = graph.get("e")
    if e_in is None:
        e_in = jnp.ones((src.shape[0], 1), h.dtype)
    e = lin(params["embed_e"], e_in)
    for lp in params["layers"]:
        e_new = lin(lp["C"], e) + lin(lp["D"], h)[src] + lin(lp["E"], h)[dst]
        gate = jax.nn.sigmoid(e_new)
        msg = gate * lin(lp["B"], h)[src]
        num = jax.ops.segment_sum(msg, dst, num_segments=n)
        den = jax.ops.segment_sum(gate, dst, num_segments=n)
        h_new = lin(lp["A"], h) + num / (den + 1e-6)
        h = h + jax.nn.relu(_layernorm(h_new))     # residual + norm
        e = e + jax.nn.relu(_layernorm(e_new))
    return lin(params["head"], h)


# ---------------------------------------------------------------------------
# MeshGraphNet  (Pfaff et al.; encode-process-decode, sum aggregation)
# ---------------------------------------------------------------------------

def meshgraphnet_init(cfg: GNNConfig, d_in: int, d_edge_in: int, key) -> Params:
    d, m = cfg.d_hidden, cfg.mlp_layers
    ks = jax.random.split(key, 3 + 2 * cfg.n_layers)
    mk = lambda k, din: _mlp_init(k, (din,) + (d,) * m)
    p: Params = {
        "enc_node": mk(ks[0], d_in),
        "enc_edge": mk(ks[1], max(d_edge_in, 1)),
        "dec": _mlp_init(ks[2], (d,) * m + (cfg.d_out,)),
        "blocks": [],
    }
    for i in range(cfg.n_layers):
        p["blocks"].append({
            "edge": mk(ks[3 + 2 * i], 3 * d),
            "node": mk(ks[4 + 2 * i], 2 * d),
        })
    return p


def meshgraphnet_forward(params, graph, cfg: GNNConfig) -> jnp.ndarray:
    src, dst = graph["src"], graph["dst"]
    n = graph["x"].shape[0]
    m = cfg.mlp_layers
    h = _layernorm(_mlp_apply(params["enc_node"], graph["x"], m))
    e_in = graph.get("e")
    if e_in is None:
        e_in = jnp.ones((src.shape[0], 1), h.dtype)
    e = _layernorm(_mlp_apply(params["enc_edge"], e_in, m))
    for blk in params["blocks"]:
        e_up = _mlp_apply(blk["edge"], jnp.concatenate([h[src], h[dst], e], -1), m)
        e = e + _layernorm(e_up)
        agg = jax.ops.segment_sum(e, dst, num_segments=n)
        h_up = _mlp_apply(blk["node"], jnp.concatenate([h, agg], -1), m)
        h = h + _layernorm(h_up)
    return _mlp_apply(params["dec"], h, m)


# ---------------------------------------------------------------------------
# EquiformerV2  (eSCN SO(2) convolutions + equivariant attention)
# ---------------------------------------------------------------------------
#
# Irrep features: [N, K, C] with K = (l_max+1)^2 real-SH coefficients.
# Per edge: rotate source features into the edge frame (Wigner-D), mix with
# SO(2) linears per |m| <= m_max (the eSCN trick) scaled by radial-basis
# weights, modulate by scalar attention (softmax over incoming edges from the
# l=0 channel), rotate back, aggregate at the destination, gated nonlinearity
# + equivariant RMS norm per l. See DESIGN.md §Arch-applicability for the
# simplifications vs the reference implementation.

N_RBF = 8


def _sh_index_ranges(l_max: int):
    return [(l * l, (l + 1) * (l + 1)) for l in range(l_max + 1)]


def _m_components(l_max: int, m: int) -> Tuple[List[int], List[int]]:
    """Flat indices of the (+m, -m) coefficient pairs across l >= |m|."""
    pos, neg = [], []
    for l in range(abs(m), l_max + 1):
        base = l * l + l          # m = 0 position of degree l
        pos.append(base + m)
        neg.append(base - m)
    return pos, neg


def equiformer_init(cfg: GNNConfig, d_in: int, key) -> Params:
    C, L, M = cfg.d_hidden, cfg.l_max, cfg.m_max
    ks = jax.random.split(key, 8 + cfg.n_layers)
    p: Params = {
        "embed": {"w": _dense(ks[0], d_in, d_in, C), "b": jnp.zeros(C)},
        "head": _mlp_init(ks[1], (C, C, cfg.d_out)),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[2 + i], 3 + 2 * (M + 1))
        lp: Params = {
            # radial network: distances -> per-(l, channel) scales
            "radial": _mlp_init(kk[0], (N_RBF, C, (L + 1) * C)),
            "attn": _mlp_init(kk[1], (C, C, cfg.n_heads)),
            "gate": {"w": _dense(kk[2], C, C, (L + 1) * C), "b": jnp.zeros((L + 1) * C)},
        }
        for m in range(M + 1):
            n_l = L + 1 - m
            fan = n_l * C
            lp[f"so2_r_{m}"] = _dense(kk[3 + 2 * m], fan, n_l * C, n_l * C)
            if m > 0:
                lp[f"so2_i_{m}"] = _dense(kk[4 + 2 * m], fan, n_l * C, n_l * C)
        p["layers"].append(lp)
    return p


def _rbf(dist: jnp.ndarray, n: int = N_RBF, cutoff: float = 5.0) -> jnp.ndarray:
    mu = jnp.linspace(0.0, cutoff, n)
    beta = (n / cutoff) ** 2
    return jnp.exp(-beta * (dist[:, None] - mu[None, :]) ** 2)


def equiformer_forward(params, graph, cfg: GNNConfig) -> jnp.ndarray:
    """graph: x [N, F] scalar features, pos [N, 3], src/dst [E]."""
    src, dst, pos = graph["src"], graph["dst"], graph["pos"]
    n = graph["x"].shape[0]
    C, L, M = cfg.d_hidden, cfg.l_max, cfg.m_max
    K = (L + 1) ** 2

    vec = pos[dst] - pos[src]
    dist = jnp.linalg.norm(vec, axis=-1)
    rot = edge_rotation(vec)
    dmats = wigner_d_stack(rot, L)
    rbf = _rbf(dist)

    feat = jnp.zeros((n, K, C))
    feat = feat.at[:, 0, :].set(graph["x"] @ params["embed"]["w"] + params["embed"]["b"])

    for lp in params["layers"]:
        x_src = feat[src]                                   # [E, K, C]
        x_rot = rotate_irreps(x_src, dmats)                 # edge frame

        # radial modulation: per-(l, channel) scale from the distance
        scale = _mlp_apply(lp["radial"], rbf, 2).reshape(-1, L + 1, C)
        x_mod = jnp.concatenate(
            [
                x_rot[:, a:b] * scale[:, l : l + 1]
                for l, (a, b) in enumerate(_sh_index_ranges(L))
            ],
            axis=1,
        )

        # SO(2) mixes per |m| <= m_max (coefficients with |m| > m_max drop —
        # the eSCN m-truncation)
        y = jnp.zeros_like(x_mod)
        E = x_mod.shape[0]
        for m in range(M + 1):
            pos_i, neg_i = _m_components(L, m)
            xp = x_mod[:, jnp.asarray(pos_i)].reshape(E, -1)   # [E, n_l*C]
            wr = lp[f"so2_r_{m}"]
            if m == 0:
                yp = xp @ wr
                y = y.at[:, jnp.asarray(pos_i)].set(yp.reshape(E, -1, C))
            else:
                xn = x_mod[:, jnp.asarray(neg_i)].reshape(E, -1)
                wi = lp[f"so2_i_{m}"]
                yp = xp @ wr - xn @ wi
                yn = xn @ wr + xp @ wi
                y = y.at[:, jnp.asarray(pos_i)].set(yp.reshape(E, -1, C))
                y = y.at[:, jnp.asarray(neg_i)].set(yn.reshape(E, -1, C))

        # scalar attention over incoming edges (heads over channel groups)
        scores = _mlp_apply(lp["attn"], y[:, 0, :], 2)          # [E, H]
        alpha = segment_softmax(scores, dst, n)                 # per head
        hsz = C // cfg.n_heads
        alpha_c = jnp.repeat(alpha, hsz, axis=-1)               # [E, C]
        y = y * alpha_c[:, None, :]

        msg = rotate_irreps(y, dmats, transpose=True)           # back to global
        agg = jax.ops.segment_sum(msg, dst, num_segments=n)

        # gated nonlinearity: scalars gate every l-block per channel
        gate = jax.nn.sigmoid(
            agg[:, 0, :] @ lp["gate"]["w"] + lp["gate"]["b"]
        ).reshape(n, L + 1, C)
        agg = jnp.concatenate(
            [
                agg[:, a:b] * gate[:, l : l + 1]
                for l, (a, b) in enumerate(_sh_index_ranges(L))
            ],
            axis=1,
        )

        # equivariant RMS norm per l-block + residual
        normed = []
        for l, (a, b) in enumerate(_sh_index_ranges(L)):
            blk = agg[:, a:b]
            rms = jnp.sqrt(jnp.mean(blk * blk, axis=(1, 2), keepdims=True) + 1e-6)
            normed.append(blk / rms)
        feat = feat + jnp.concatenate(normed, axis=1)

    # invariant readout from the l=0 channel
    return _mlp_apply(params["head"], feat[:, 0, :], 2)


# ---------------------------------------------------------------------------
# family dispatcher + losses
# ---------------------------------------------------------------------------

def init_gnn(cfg: GNNConfig, d_in: int, key, d_edge_in: int = 1) -> Params:
    if cfg.kind == "gcn":
        return gcn_init(cfg, d_in, key)
    if cfg.kind == "gatedgcn":
        return gatedgcn_init(cfg, d_in, d_edge_in, key)
    if cfg.kind == "meshgraphnet":
        return meshgraphnet_init(cfg, d_in, d_edge_in, key)
    if cfg.kind == "equiformer_v2":
        return equiformer_init(cfg, d_in, key)
    raise ValueError(cfg.kind)


def gnn_forward(params, graph, cfg: GNNConfig) -> jnp.ndarray:
    fn = {
        "gcn": gcn_forward,
        "gatedgcn": gatedgcn_forward,
        "meshgraphnet": meshgraphnet_forward,
        "equiformer_v2": equiformer_forward,
    }[cfg.kind]
    return fn(params, graph, cfg)


def node_classification_loss(params, graph, cfg: GNNConfig) -> jnp.ndarray:
    """CE over labeled nodes (labels < 0 masked; full-graph + minibatch)."""
    logits = gnn_forward(params, graph, cfg)
    labels = graph["labels"]
    if "seed_slots" in graph:                 # minibatch: loss on seeds only
        logits = logits[graph["seed_slots"]]
        labels = labels[graph["seed_slots"]]
    mask = labels >= 0
    lab = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[:, None], axis=-1)[:, 0]
    return ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1)


def graph_regression_loss(params, graph, cfg: GNNConfig) -> jnp.ndarray:
    """Mean-pool per graph_id + MSE (batched_graphs/molecule regime)."""
    out = gnn_forward(params, graph, cfg)
    gid = graph["graph_id"]
    ng = graph["targets"].shape[0]
    pooled = jax.ops.segment_sum(out, gid, num_segments=ng)
    cnt = jax.ops.segment_sum(jnp.ones_like(gid, jnp.float32), gid, num_segments=ng)
    pooled = pooled / jnp.maximum(cnt[:, None], 1)
    return jnp.mean((pooled - graph["targets"]) ** 2)
