"""Model zoo: transformer (dense + MoE), GNN family, recsys."""
