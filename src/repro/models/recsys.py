"""xDeepFM (arXiv:1803.05170) with a hand-built EmbeddingBag.

JAX has no nn.EmbeddingBag and no CSR sparse — per the assignment, the
multi-hot embedding lookup is built here from `jnp.take` + `jax.ops.
segment_sum` (the hot path of the recsys family), with the table laid out
[n_fields, vocab, dim] so the vocab axis row-shards over the 'model' mesh
axis and lookups become GSPMD gather + all-to-all.

Branches: linear (per-id weight) + CIN (Pallas kernel available) + DNN.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import RecsysConfig
from repro.kernels.cin.ops import cin_layer

Params = Dict[str, Any]


def embedding_bag(
    table: jnp.ndarray,        # [vocab, dim] one field's table
    ids: jnp.ndarray,          # int32 [B, bag]
    mask: jnp.ndarray,         # [B, bag] 1 = valid id
    combiner: str = "mean",
) -> jnp.ndarray:
    """EmbeddingBag from take + segment_sum. ids flattened into one gather;
    the bag reduction is a segment_sum over the row index."""
    B, bag = ids.shape
    flat = jnp.take(table, ids.reshape(-1), axis=0)          # [B*bag, dim]
    flat = flat * mask.reshape(-1, 1)
    seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), bag)
    out = jax.ops.segment_sum(flat, seg, num_segments=B)     # [B, dim]
    if combiner == "mean":
        cnt = jax.ops.segment_sum(mask.reshape(-1), seg, num_segments=B)
        out = out / jnp.maximum(cnt[:, None], 1.0)
    return out


def init_params(cfg: RecsysConfig, key) -> Params:
    F, V, D = cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim
    ks = jax.random.split(key, 8)
    p: Params = {
        "tables": jax.random.normal(ks[0], (F, V, D)) * 0.01,
        "linear": jax.random.normal(ks[1], (F, V)) * 0.01,
        "cin": [],
        "mlp": [],
        "bias": jnp.zeros(()),
    }
    prev = F
    kc = jax.random.split(ks[2], len(cfg.cin_layers))
    for i, hk in enumerate(cfg.cin_layers):
        p["cin"].append(jax.random.normal(kc[i], (hk, prev, F)) * (prev * F) ** -0.5)
        prev = hk
    p["cin_out"] = jax.random.normal(ks[3], (sum(cfg.cin_layers),)) * 0.01

    dims = [F * D + cfg.n_dense] + list(cfg.mlp_dims) + [1]
    km = jax.random.split(ks[4], len(dims) - 1)
    for i in range(len(dims) - 1):
        p["mlp"].append({
            "w": jax.random.normal(km[i], (dims[i], dims[i + 1])) * dims[i] ** -0.5,
            "b": jnp.zeros(dims[i + 1]),
        })
    return p


def forward(
    params: Params,
    batch: Dict[str, jnp.ndarray],
    cfg: RecsysConfig,
    cin_impl: str = "ref",
) -> jnp.ndarray:
    """batch: ids [B, F, bag] int32, id_mask [B, F, bag], dense [B, n_dense].
    Returns logits [B]."""
    ids, mask = batch["ids"], batch["id_mask"]
    B, F, bag = ids.shape
    D = cfg.embed_dim

    # --- embedding bag per field (vmap over the field axis) ----------------
    emb = jax.vmap(
        lambda t, i, m: embedding_bag(t, i, m, combiner="mean"),
        in_axes=(0, 1, 1), out_axes=1,
    )(params["tables"], ids, mask)                       # [B, F, D]

    # --- linear branch ------------------------------------------------------
    lin_w = jax.vmap(
        lambda t, i, m: (jnp.take(t, i.reshape(-1)).reshape(i.shape) * m).sum(-1),
        in_axes=(0, 1, 1), out_axes=1,
    )(params["linear"], ids, mask)                       # [B, F]
    logit_lin = lin_w.sum(-1)

    # --- CIN branch ----------------------------------------------------------
    xk = emb
    pooled = []
    for w in params["cin"]:
        xk = cin_layer(emb, xk, w, impl=cin_impl)
        pooled.append(xk.sum(-1))
    logit_cin = jnp.concatenate(pooled, -1) @ params["cin_out"]

    # --- DNN branch ----------------------------------------------------------
    h = jnp.concatenate([emb.reshape(B, F * D), batch["dense"]], -1)
    for i, lp in enumerate(params["mlp"]):
        h = h @ lp["w"] + lp["b"]
        if i < len(params["mlp"]) - 1:
            h = jax.nn.relu(h)
    logit_dnn = h[:, 0]

    return logit_lin + logit_cin + logit_dnn + params["bias"]


def bce_loss(params, batch, cfg: RecsysConfig, cin_impl: str = "ref"):
    logits = forward(params, batch, cfg, cin_impl=cin_impl)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def retrieval_scores(
    params: Params,
    user_ids: jnp.ndarray,       # [1, F_user, bag]
    user_mask: jnp.ndarray,
    user_dense: jnp.ndarray,     # [1, n_dense]
    cand_ids: jnp.ndarray,       # [C, F_item, bag]
    cand_mask: jnp.ndarray,
    cfg: RecsysConfig,
    cin_impl: str = "ref",
) -> jnp.ndarray:
    """Score one query against C candidates with the FULL interaction model
    (batched-dot over broadcast user features — not a per-candidate loop)."""
    C = cand_ids.shape[0]
    fu = user_ids.shape[1]
    ids = jnp.concatenate(
        [jnp.broadcast_to(user_ids, (C, fu, user_ids.shape[2])), cand_ids], axis=1
    )
    mask = jnp.concatenate(
        [jnp.broadcast_to(user_mask, (C, fu, user_mask.shape[2])), cand_mask], axis=1
    )
    dense = jnp.broadcast_to(user_dense, (C, user_dense.shape[1]))
    return forward(
        params, {"ids": ids, "id_mask": mask, "dense": dense}, cfg,
        cin_impl=cin_impl,
    )
