"""Decoder-only transformer family covering the five assigned LM archs.

Pure-JAX (no flax): params are plain dict pytrees with layers STACKED on a
leading [L] axis and the block applied via lax.scan — compile time and HLO
size stay flat in depth (42-64-layer archs lower in seconds, and the HLO
remains parseable for the collective-roofline pass).

Variant coverage (per assigned config):
  gemma2-9b          GQA, local/global alternating sliding window, attn +
                     final logit soft-capping, GeGLU
  qwen1.5-32b        QKV bias
  mistral-nemo-12b   GQA, 128k rope
  moonshot-v1-16b-a3b  MoE 64e top-6 (fine-grained d_ff) + GQA
  mixtral-8x7b       MoE 8e top-2, sliding window

MoE dispatch is capacity-based (GShard-style position-in-expert) so expert
compute is dense per-expert GEMMs sharded over the 'model' axis (EP), and
the dispatch/combine scatter-gathers become all-to-alls under GSPMD.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import MoEConfig, TransformerConfig
from repro.kernels.flash_attention.ops import attention

Params = Dict[str, Any]

# Trace-time sharding constraints for the MoE dispatch path, set by the
# launcher (launch/steps.py) before tracing. GSPMD otherwise replicates the
# scatter/gather-based dispatch across the data axis and all-reduces
# activation-sized f32 buffers in bwd (measured 33 s collective at
# moonshot/train_4k). Keys: "x_disp" [G,E,C,d], "h" [G,Tg,d], "y" [G,E,C,d].
MOE_CONSTRAINTS: Dict[str, Any] = {}

# When set (by the launcher) to (mesh, capacity_factor), the MoE FFN runs the
# explicit all-to-all shard_map path (models/moe_a2a.py) instead of GSPMD
# scatter-dispatch. Requires T % n_chips == 0 and E % model-axis == 0.
MOE_A2A: Any = None


def _moe_constrain(name, t):
    spec = MOE_CONSTRAINTS.get(name)
    if spec is not None:
        return jax.lax.with_sharding_constraint(t, spec)
    return t


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: TransformerConfig, key: jax.Array) -> Params:
    """Stacked-layer param tree. Shapes chosen so the 'model' axis shards the
    widest dim of every large tensor (see runtime/sharding.py)."""
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv, L = cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    dt = _dtype(cfg)
    keys = jax.random.split(key, 12)

    def norm_init(*shape):
        return jnp.ones(shape, dt)

    def dense_init(k, fan_in, *shape):
        return (jax.random.normal(k, shape, jnp.float32) * (fan_in ** -0.5)).astype(dt)

    p: Params = {
        "embed": dense_init(keys[0], int(1 / 0.02**2), cfg.vocab_size, d),
        "final_norm": norm_init(d),
        "layers": {
            "attn_norm": norm_init(L, d),
            "mlp_norm": norm_init(L, d),
            "wq": dense_init(keys[1], d, L, d, hq * hd),
            "wk": dense_init(keys[2], d, L, d, hkv * hd),
            "wv": dense_init(keys[3], d, L, d, hkv * hd),
            "wo": dense_init(keys[4], hq * hd, L, hq * hd, d),
        },
    }
    if cfg.qkv_bias:
        p["layers"]["bq"] = jnp.zeros((L, hq * hd), dt)
        p["layers"]["bk"] = jnp.zeros((L, hkv * hd), dt)
        p["layers"]["bv"] = jnp.zeros((L, hkv * hd), dt)
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(keys[5], d, d, cfg.vocab_size)

    if isinstance(cfg, MoEConfig):
        E, f = cfg.n_experts, cfg.d_ff
        p["layers"]["router"] = dense_init(keys[6], d, L, d, E)
        p["layers"]["w_gate"] = dense_init(keys[7], d, L, E, d, f)
        p["layers"]["w_up"] = dense_init(keys[8], d, L, E, d, f)
        p["layers"]["w_down"] = dense_init(keys[9], f, L, E, f, d)
        if cfg.n_shared_experts:
            fs = (cfg.d_ff_shared or cfg.d_ff) * cfg.n_shared_experts
            p["layers"]["ws_gate"] = dense_init(keys[10], d, L, d, fs)
            p["layers"]["ws_up"] = dense_init(keys[10], d, L, d, fs)
            p["layers"]["ws_down"] = dense_init(keys[11], fs, L, fs, d)
    else:
        f = cfg.d_ff
        p["layers"]["w_gate"] = dense_init(keys[6], d, L, d, f)
        p["layers"]["w_up"] = dense_init(keys[7], d, L, d, f)
        p["layers"]["w_down"] = dense_init(keys[8], f, L, f, d)
    return p


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


def _act(x, kind: str):
    return jax.nn.gelu(x) if kind == "gelu" else jax.nn.silu(x)


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, Dh], pos int32 [S] (or [B, S] broadcastable)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None] * freqs           # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                    # broadcast heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _layer_window(cfg: TransformerConfig, layer_idx: jnp.ndarray) -> jnp.ndarray:
    """Per-layer sliding window size (0 = full attention) as traced int32."""
    if cfg.local_global_alternating and cfg.sliding_window:
        # gemma2: even layers local (sliding window), odd layers global
        return jnp.where(layer_idx % 2 == 0, cfg.sliding_window, 0)
    return jnp.full_like(layer_idx, cfg.sliding_window)


def _decode_attention(q, k, v, kv_len, window: int, softcap: float, scale):
    """Single-query attention against a (sharded) cache, GQA via grouped
    einsum — no KV repeat, no O(S^2) tile. q [B, Hq, 1, D]; k/v [B, Hkv, S, D].
    With the cache sharded on S this lowers to partial softmax + all-reduce
    (sequence parallelism for decode)."""
    B, Hq, _, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32))
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(S, dtype=jnp.int32)
    mask = kpos[None, None, None, :] < kv_len
    if window > 0:
        mask &= kpos[None, None, None, :] > (kv_len - 1 - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, 1, D).astype(q.dtype)


def _attention_block(
    x, lp, cfg: TransformerConfig, pos, kv_len, layer_window_static: int,
    cache_kv=None, attn_impl: str = "blocked",
):
    """x [B, S, D]; cache_kv optional (k, v) [B, Hkv, Sc, Dh] for decode."""
    B, S, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, hq, hd)
    k = k.reshape(B, S, hkv, hd)
    v = v.reshape(B, S, hkv, hd)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    q = jnp.moveaxis(q, 2, 1)   # [B, H, S, Dh]
    k = jnp.moveaxis(k, 2, 1)
    v = jnp.moveaxis(v, 2, 1)

    new_kv = (k, v)
    q_offset = None
    if cache_kv is not None:
        ck, cv = cache_kv            # [B, Hkv, Sc, Dh]
        # write the new row(s) at position kv_len - S ... kv_len - 1
        start = kv_len - S
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, start, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, start, 0))
        k, v = ck, cv
        new_kv = (ck, cv)
        q_offset = start

    if cache_kv is not None and S == 1:
        # decode hot path: grouped-einsum partial-softmax attention
        o = _decode_attention(
            q, k, v, kv_len, layer_window_static,
            cfg.attn_logit_softcap, cfg.head_dim ** -0.5,
        )
    else:
        o = attention(
            q, k, v,
            kv_len=kv_len, q_offset=q_offset,
            causal=True, window=layer_window_static,
            softcap=cfg.attn_logit_softcap,
            scale=cfg.head_dim ** -0.5,
            impl=attn_impl,
        )
    o = jnp.moveaxis(o, 1, 2).reshape(B, S, hq * hd)
    return x + o @ lp["wo"], new_kv


def _dense_mlp(x, lp, cfg):
    h = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    g = _act(h @ lp["w_gate"], cfg.act) * (h @ lp["w_up"])
    return x + g @ lp["w_down"]


def _moe_mlp(x, lp, cfg: MoEConfig):
    """Capacity-based top-k MoE with GROUP-LOCAL dispatch.

    Returns (x_out, aux_loss). Tokens are split into ``cfg.moe_groups``
    groups (set = the DP shard count by the launcher): each group routes its
    own tokens into a group-local capacity buffer [G, E, C_g, D]. That keeps
    the dispatch buffer sharded G -> 'data' and E (or the expert FFN width)
    -> 'model'; GSPMD then lowers dispatch/combine to all-to-alls over the
    EP axis. A single GLOBAL capacity buffer instead forces the scatter
    result to be replicated across the data axis — measured 16x redundant
    expert FLOPs + a 46 s collective term at mixtral/train_4k (§Perf).

    Position-in-expert comes from a stable argsort (O(n log n)); the one-hot
    cumsum alternative lowers to an O(n^2)-counted reduce-window.
    """
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k

    if MOE_A2A is not None:
        # a2a branch stays in [B, S, d] order: B-major/S-minor exactly
        # matches the (data..., model) chip order, so the shard_map boundary
        # is a zero-copy split. (The [G, Tg] group reshape below interleaves
        # batch and sequence shardings — GSPMD copes on a 2-axis mesh but
        # falls into involuntary rematerialization on the 3-axis pod mesh;
        # measured 1.85 s -> 8.8 s collective before this bypass.)
        from repro.models.moe_a2a import moe_ffn_a2a
        mesh, cf = MOE_A2A
        # pin entry AND exit to the residual-stream spec: without the exit
        # pin, GSPMD back-propagates the flat 512-way token sharding through
        # the [T,d]->[B,S,d] reshape into a 256-way-B x 2-way-S layout that
        # the 3-axis mesh cannot transition out of (involuntary remat).
        h2 = _moe_constrain("moe_out", rmsnorm(x, lp["mlp_norm"], cfg.norm_eps))
        logits = (h2 @ lp["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, exp_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        me = jnp.mean(jax.nn.one_hot(exp_idx[..., 0], E, dtype=jnp.float32),
                      axis=(0, 1))
        aux = E * jnp.sum(me * jnp.mean(probs, axis=(0, 1)))
        out = moe_ffn_a2a(
            mesh, h2.reshape(T, d), exp_idx.reshape(T, k),
            gate_vals.reshape(T, k),
            lp["w_gate"], lp["w_up"], lp["w_down"],
            act_fn=lambda t: _act(t, cfg.act), capacity_factor=cf,
        ).reshape(B, S, d)
        out = _moe_constrain("moe_out", out)  # set on pod meshes only
        if cfg.n_shared_experts:
            gs = _act(h2 @ lp["ws_gate"], cfg.act) * (h2 @ lp["ws_up"])
            out = out + gs @ lp["ws_down"]
        return x + out.astype(x.dtype), aux

    G = cfg.moe_groups or 1
    if T % G:
        G = 1
    Tg = T // G
    h = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps).reshape(G, Tg, d)
    h = _moe_constrain("h", h)

    logits = (h @ lp["router"]).astype(jnp.float32)          # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, exp_idx = jax.lax.top_k(probs, k)             # [G, Tg, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch eq. 4), global mean
    me = jnp.mean(jax.nn.one_hot(exp_idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    ce = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    capacity = int(np.ceil(Tg * k / E * cfg.capacity_factor)) if Tg >= E else Tg
    capacity = max(capacity, 4)

    def route_group(exp_g, gate_g):
        """Indices only (all [Tg*k] int/float vectors; cheap to vmap)."""
        flat_e = exp_g.reshape(-1)                           # [Tg*k]
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        seg_start = jnp.searchsorted(sorted_e,
                                     jnp.arange(E, dtype=flat_e.dtype))
        pos_sorted = (jnp.arange(Tg * k, dtype=jnp.int32)
                      - seg_start[sorted_e].astype(jnp.int32))
        pos_in_e = jnp.zeros((Tg * k,), jnp.int32).at[order].set(pos_sorted)
        keep = pos_in_e < capacity
        gate_flat = gate_g.reshape(-1) * keep.astype(jnp.float32)
        slot = jnp.where(keep, pos_in_e, capacity - 1)
        return flat_e, slot, gate_flat, keep

    flat_e, slot, gate_flat, keep = jax.vmap(route_group)(exp_idx, gate_vals)
    tok_idx = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), k)

    # token gather OUTSIDE the vmap so its [G, Tg*k, d] result can be pinned
    # (G->data, d unsharded); GSPMD otherwise d-shards it and bwd turns into
    # activation-sized f32 all-reduce chains
    h_tok = jnp.take_along_axis(
        h, jnp.broadcast_to(tok_idx[None, :, None], (G, Tg * k, 1)), axis=1)
    h_tok = _moe_constrain("h_tok", h_tok)
    h_tok = h_tok * keep.astype(h_tok.dtype)[..., None]

    def scatter_group(h_t, fe, sl):
        x_disp = jnp.zeros((E, capacity, d), h_t.dtype)
        return x_disp.at[fe, sl].add(h_t)

    x_disp = jax.vmap(scatter_group)(h_tok, flat_e, slot)
    # x_disp [G, E, C, d]: G -> data, E (or f) -> model
    x_disp = _moe_constrain("x_disp", x_disp)

    g = _act(jnp.einsum("gecd,edf->gecf", x_disp, lp["w_gate"]), cfg.act)
    u = jnp.einsum("gecd,edf->gecf", x_disp, lp["w_up"])
    y = jnp.einsum("gecf,efd->gecd", g * u, lp["w_down"])    # [G, E, C, d]
    y = _moe_constrain("y", y)

    y_tok = jax.vmap(lambda y_g, fe, sl: y_g[fe, sl])(y, flat_e, slot)
    y_tok = _moe_constrain("h_tok", y_tok)                   # [G, Tg*k, d]
    y_tok = y_tok * gate_flat[..., None]

    out = jax.vmap(
        lambda yt: jax.ops.segment_sum(yt, tok_idx, num_segments=Tg)
    )(y_tok)                                                  # [G, Tg, d]
    out = _moe_constrain("h", out)

    if cfg.n_shared_experts:
        gs = _act(h @ lp["ws_gate"], cfg.act) * (h @ lp["ws_up"])
        out = out + gs @ lp["ws_down"]
    return x + out.reshape(B, S, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _split_windows(cfg) -> Tuple[int, int]:
    """(even_layer_window, odd_layer_window) — static per scan branch."""
    if cfg.local_global_alternating and cfg.sliding_window:
        return cfg.sliding_window, 0
    return cfg.sliding_window, cfg.sliding_window


def forward_hidden(
    params: Params,
    tokens: jnp.ndarray,            # int32 [B, S]
    cfg: TransformerConfig,
    attn_impl: str = "blocked",
    act_spec=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Backbone only: returns (hidden [B, S, D] post-final-norm, aux_loss).

    ``act_spec`` (a PartitionSpec, resolved against the ambient mesh) is the
    Megatron-SP trick: the residual stream between layers is sharded over the
    TP axis on the SEQUENCE dim, so the remat-saved per-layer carries scale
    down with TP world size (without it a 42-layer 4k x 16/device run keeps
    ~20 GB of carries per chip). GSPMD re-gathers inside the attention/MLP
    where TP already pays that collective.
    """
    B, S = tokens.shape
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5, _dtype(cfg))
    pos = jnp.arange(S, dtype=jnp.int32)
    kv_len = jnp.int32(S)
    w_even, w_odd = _split_windows(cfg)
    is_moe = isinstance(cfg, MoEConfig)

    constrain = (
        (lambda t: jax.lax.with_sharding_constraint(t, act_spec))
        if act_spec is not None else (lambda t: t)
    )
    x = constrain(x)

    def block(x, lp_idx):
        lp, idx = lp_idx

        def run(window: int, x):
            x, _ = _attention_block(x, lp, cfg, pos, kv_len, window,
                                    attn_impl=attn_impl)
            if is_moe:
                return _moe_mlp(x, lp, cfg)
            return _dense_mlp(x, lp, cfg), jnp.float32(0)

        if w_even == w_odd:
            x, aux = run(w_even, x)
        else:
            x, aux = jax.lax.cond(
                idx % 2 == 0, partial(run, w_even), partial(run, w_odd), x
            )
        return constrain(x), aux

    if cfg.remat != "none":
        block = jax.checkpoint(block)

    layer_ids = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(
            lambda x, lp: block(x, lp), x, (params["layers"], layer_ids)
        )
        aux = auxs.mean()
    else:
        # unrolled path: resolve the local/global branch STATICALLY so the
        # HLO has no conditionals (exact cost_analysis for the roofline fit)
        aux = jnp.float32(0)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            win = w_even if i % 2 == 0 else w_odd
            x, _ = _attention_block(x, lp, cfg, pos, kv_len, win,
                                    attn_impl=attn_impl)
            if is_moe:
                x, a = _moe_mlp(x, lp, cfg)
                aux = aux + a / cfg.n_layers
            else:
                x = _dense_mlp(x, lp, cfg)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def _unembed_logits(params, x, cfg) -> jnp.ndarray:
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ unembed).astype(jnp.float32)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def forward(params, tokens, cfg, attn_impl: str = "blocked", act_spec=None):
    """Full forward with logits (prefill / small shapes)."""
    x, aux = forward_hidden(params, tokens, cfg, attn_impl=attn_impl,
                            act_spec=act_spec)
    return _unembed_logits(params, x, cfg), aux


def lm_loss(params, batch, cfg, attn_impl: str = "blocked",
            loss_chunks: int = 0, act_spec=None):
    """Next-token cross-entropy, CHUNKED over the sequence: the [B, S_c, V]
    logits tile is produced, reduced to per-token NLL, and freed (recomputed
    in bwd via jax.checkpoint) chunk by chunk — the full [B, S, V] f32 logits
    tensor never exists. labels = tokens shifted; -1 masks a position."""
    x, aux = forward_hidden(params, batch["tokens"], cfg, attn_impl=attn_impl,
                            act_spec=act_spec)
    labels = batch["labels"]
    B, S = labels.shape
    n_chunks = loss_chunks or cfg.loss_chunks or (8 if S >= 2048 else 1)
    while S % n_chunks:
        n_chunks -= 1

    @jax.checkpoint
    def chunk_nll(params, x_c, labels_c):
        logits = _unembed_logits(params, x_c, cfg)     # [B, S_c, V]
        mask = labels_c >= 0
        lab = jnp.where(mask, labels_c, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mask
        return nll.sum(), mask.sum()

    sc = S // n_chunks
    if n_chunks == 1:
        tot, cnt = chunk_nll(params, x, labels)
    else:
        # scan (not a python loop) so the [B, S_c, V] logits buffer is
        # assigned ONCE and reused across chunks
        xc = jnp.moveaxis(x.reshape(B, n_chunks, sc, -1), 1, 0)
        lc = jnp.moveaxis(labels.reshape(B, n_chunks, sc), 1, 0)

        def body(carry, xs):
            t0, n0 = carry
            t, n = chunk_nll(params, xs[0], xs[1])
            return (t0 + t, n0 + n), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.float32(0), jnp.int32(0)), (xc, lc))
    loss = tot / jnp.maximum(cnt, 1)
    if isinstance(cfg, MoEConfig):
        loss = loss + cfg.router_aux_loss * aux
    return loss


# ---------------------------------------------------------------------------
# serving: KV-cache decode
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int) -> Params:
    """[L, B, Hkv, S, Dh] stacked cache (scan-compatible)."""
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, _dtype(cfg)),
        "v": jnp.zeros(shape, _dtype(cfg)),
        "len": jnp.int32(0),
    }


def decode_step(
    params: Params,
    cache: Params,
    tokens: jnp.ndarray,            # int32 [B, 1] the newest token
    cfg: TransformerConfig,
    attn_impl: str = "blocked",
) -> Tuple[jnp.ndarray, Params]:
    """One serve step: append token, attend to the cache, emit logits."""
    B = tokens.shape[0]
    new_len = cache["len"] + 1
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5, _dtype(cfg))
    pos = (new_len - 1) * jnp.ones((1,), jnp.int32)
    w_even, w_odd = _split_windows(cfg)
    is_moe = isinstance(cfg, MoEConfig)

    def block(x, lp_kv_idx):
        lp, ck, cv, idx = lp_kv_idx

        def run(window: int, x):
            x, (nk, nv) = _attention_block(
                x, lp, cfg, pos, new_len, window, cache_kv=(ck, cv),
                attn_impl=attn_impl,
            )
            if is_moe:
                x, _ = _moe_mlp(x, lp, cfg)
            else:
                x = _dense_mlp(x, lp, cfg)
            return x, nk, nv

        if w_even == w_odd:
            x, nk, nv = run(w_even, x)
        else:
            x, nk, nv = jax.lax.cond(
                idx % 2 == 0, partial(run, w_even), partial(run, w_odd), x
            )
        return x, (nk, nv)

    layer_ids = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    if cfg.scan_layers:
        x, (nk, nv) = jax.lax.scan(
            block, x, (params["layers"], cache["k"], cache["v"], layer_ids)
        )
    else:
        nks, nvs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            win = w_even if i % 2 == 0 else w_odd   # static branch
            x, (k1, v1) = _attention_block(
                x, lp, cfg, pos, new_len, win,
                cache_kv=(cache["k"][i], cache["v"][i]), attn_impl=attn_impl,
            )
            if is_moe:
                x, _ = _moe_mlp(x, lp, cfg)
            else:
                x = _dense_mlp(x, lp, cfg)
            nks.append(k1)
            nvs.append(v1)
        nk, nv = jnp.stack(nks), jnp.stack(nvs)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ unembed).astype(jnp.float32)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits[:, 0], {"k": nk, "v": nv, "len": new_len}


def prefill_step(params, tokens, cfg, attn_impl: str = "blocked",
                 act_spec=None):
    """Serve prefill = full-sequence forward, no grads (the prefill_32k cell
    lowers this); steady-state decode lowers decode_step."""
    logits, _ = forward(params, tokens, cfg, attn_impl=attn_impl,
                        act_spec=act_spec)
    return logits
