"""Atomic sharded checkpointing with elastic restore.

Layout: one directory per step, one .npy per pytree leaf (path-encoded
filenames) + manifest.json (tree structure, shapes, dtypes, step, mesh
shape). Writes go to  <dir>/tmp.<step>  and are renamed atomically to
<dir>/step_<step>  only after fsync — a preempted writer never corrupts the
latest complete checkpoint. Restore re-shards to WHATEVER mesh the restoring
process runs (elastic: device count / topology may differ across restarts) by
device_put-ing host arrays against the new sharding tree.

For multi-host pods this maps to per-host shard files keyed by process index
(the manifest already records shard math); in this single-process container
every leaf is saved whole.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.analysis import guard
from repro.common import get_logger
from repro.runtime import telemetry
from repro.runtime.fault import retriable

log = get_logger("repro.ckpt")

_SEP = "__"


def _flatten(tree) -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_part(p) for p in path)
        out[key] = leaf
    return out


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    extra: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> str:
    """Atomic checkpoint write. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    with telemetry.span("checkpoint.save", step=step, leaves=len(flat)):
        manifest = {"step": step, "extra": extra or {}, "leaves": {},
                    # wall-clock is write-provenance metadata only; restore
                    # never reads it back into compute. wall_time() is the
                    # determinism-lint sanctioned seam.
                    "written_at": telemetry.wall_time()}
        for key, leaf in flat.items():
            if isinstance(leaf, jax.Array):
                # the sanctioned device->host path: metered by any active
                # TransferMeter, so checkpoint durability cost shows up as
                # EngineMetrics.checkpoint_syncs instead of hiding in the
                # measured/counted sync-equality contract. Host numpy leaves
                # (GraphStore mirrors) are not transfers and skip the meter.
                leaf = guard.fetch(
                    leaf, reason=f"checkpoint save: materialize device leaf {key}")
            arr = np.asarray(leaf)
            fname = f"{key}.npy"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            }
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())

        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic on POSIX
        _gc(ckpt_dir, keep)
    log.info("checkpoint step %d -> %s (%d leaves)", step, final, len(flat))
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if re.fullmatch(r"step_\d+", d)
    )
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if re.fullmatch(r"step_\d+", d)
    ]
    return max(steps) if steps else None


@retriable
def restore(
    ckpt_dir: str,
    like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of `like`. `shardings` (optional pytree of
    NamedSharding, same structure) re-shards for the CURRENT mesh — the
    elastic path: a checkpoint written on N devices restores onto M."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with telemetry.span("checkpoint.restore", step=step) as sp:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        flat_like = _flatten(like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for key, meta in manifest["leaves"].items():
            if key not in flat_like:
                log.warning("checkpoint leaf %s not in target tree; skipped", key)
                continue
            arr = np.load(os.path.join(d, meta["file"]))
            sh = flat_sh.get(key)
            loaded[key] = jax.device_put(arr, sh) if sh is not None else arr
        missing = set(flat_like) - set(loaded)
        if missing:
            raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")
        sp.set(leaves=len(loaded))

        leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        ordered = [
            loaded[_SEP.join(_path_part(p) for p in path)]
            for path, _ in leaves_paths
        ]
        return jax.tree_util.tree_unflatten(treedef, ordered), manifest["extra"]
