"""gemma2-9b [arXiv:2408.00118; hf]: 42L d_model=3584 16H (GQA kv=8)
d_ff=14336 vocab=256000 — local+global alternating sliding window (4096),
attn logit softcap 50, final logit softcap 30, GeGLU, head_dim 256."""
from repro.config.base import TransformerConfig
from repro.config.registry import register_arch


def full() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-9b", n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
        d_head=256, d_ff=14336, vocab_size=256000,
        sliding_window=4096, local_global_alternating=True,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        act="gelu", rope_theta=10000.0, tie_embeddings=True,
        dtype="bfloat16", remat="full",
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-9b-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab_size=512,
        sliding_window=16, local_global_alternating=True,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        act="gelu", tie_embeddings=True, dtype="float32",
    )


register_arch("gemma2-9b", full, smoke)
