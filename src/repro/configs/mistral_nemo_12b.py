"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407]: 40L d_model=5120
32H (GQA kv=8) d_ff=14336 vocab=131072 — 128k context, head_dim 128."""
from repro.config.base import TransformerConfig
from repro.config.registry import register_arch


def full() -> TransformerConfig:
    return TransformerConfig(
        name="mistral-nemo-12b", n_layers=40, d_model=5120, n_heads=32,
        n_kv_heads=8, d_head=128, d_ff=14336, vocab_size=131072,
        act="silu", rope_theta=1_000_000.0, max_position=131072,
        dtype="bfloat16", remat="full",
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="mistral-nemo-12b-smoke", n_layers=3, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=128, vocab_size=512, dtype="float32",
    )


register_arch("mistral-nemo-12b", full, smoke)
