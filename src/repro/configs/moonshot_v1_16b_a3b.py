"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: 48L d_model=2048
16H (kv=16) expert d_ff=1408 vocab=163840, MoE 64 experts top-6 + 2 shared
experts (DeepSeek-V3-style fine-grained MoE)."""
from repro.config.base import MoEConfig
from repro.config.registry import register_arch


def full() -> MoEConfig:
    return MoEConfig(
        name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
        n_kv_heads=16, d_head=128, d_ff=1408, vocab_size=163840,
        n_experts=64, top_k=6, n_shared_experts=2, d_ff_shared=1408,
        act="silu", rope_theta=50000.0, dtype="bfloat16", remat="full",
    )


def smoke() -> MoEConfig:
    return MoEConfig(
        name="moonshot-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_head=16, d_ff=48, vocab_size=512, n_experts=8, top_k=3, capacity_factor=16.0,
        n_shared_experts=1, d_ff_shared=48, dtype="float32",
    )


register_arch("moonshot-v1-16b-a3b", full, smoke)
