"""equiformer-v2 [arXiv:2306.12059]: 12 blocks, d_hidden=128, l_max=6,
m_max=2, 8 heads — SO(2)-eSCN equivariant graph attention."""
from repro.config.base import GNNConfig
from repro.config.registry import register_arch


def full() -> GNNConfig:
    return GNNConfig(name="equiformer-v2", kind="equiformer_v2", n_layers=12,
                     d_hidden=128, l_max=6, m_max=2, n_heads=8, d_out=1)


def smoke() -> GNNConfig:
    return GNNConfig(name="equiformer-v2-smoke", kind="equiformer_v2",
                     n_layers=2, d_hidden=16, l_max=3, m_max=2, n_heads=4,
                     d_out=1)


register_arch("equiformer-v2", full, smoke)
