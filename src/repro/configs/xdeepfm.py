"""xdeepfm [arXiv:1803.05170]: 39 sparse fields, embed_dim=10,
CIN 200-200-200, DNN 400-400. Tables sized 10^6 rows/field (the huge-
embedding axis of the recsys family)."""
from repro.config.base import RecsysConfig
from repro.config.registry import register_arch


def full() -> RecsysConfig:
    return RecsysConfig(
        name="xdeepfm", n_sparse=39, n_dense=13, embed_dim=10,
        vocab_per_field=1_000_000, cin_layers=(200, 200, 200),
        mlp_dims=(400, 400), multi_hot=1,
    )


def smoke() -> RecsysConfig:
    return RecsysConfig(
        name="xdeepfm-smoke", n_sparse=6, n_dense=4, embed_dim=8,
        vocab_per_field=1000, cin_layers=(16, 16), mlp_dims=(32, 16),
        multi_hot=2,
    )


register_arch("xdeepfm", full, smoke)
