"""One module per assigned architecture (+ the paper's own engine config).

Importing a module registers its full + smoke factories with the registry;
`repro.config.registry.get_arch(name)` lazy-imports on demand.
"""
