"""gatedgcn [arXiv:2003.00982 benchmarking-gnns]: 16 layers, d_hidden=70,
edge-gated aggregation with residuals + norms."""
from repro.config.base import GNNConfig
from repro.config.registry import register_arch


def full() -> GNNConfig:
    return GNNConfig(name="gatedgcn", kind="gatedgcn", n_layers=16,
                     d_hidden=70, aggregator="gated", d_out=7, d_edge=1)


def smoke() -> GNNConfig:
    return GNNConfig(name="gatedgcn-smoke", kind="gatedgcn", n_layers=3,
                     d_hidden=16, aggregator="gated", d_out=4, d_edge=1)


register_arch("gatedgcn", full, smoke)
