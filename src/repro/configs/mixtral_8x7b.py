"""mixtral-8x7b [arXiv:2401.04088]: 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000, MoE 8 experts top-2, sliding-window attention."""
from repro.config.base import MoEConfig
from repro.config.registry import register_arch


def full() -> MoEConfig:
    return MoEConfig(
        name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_head=128, d_ff=14336, vocab_size=32000,
        n_experts=8, top_k=2, sliding_window=4096,
        act="silu", rope_theta=1_000_000.0, dtype="bfloat16", remat="full",
    )


def smoke() -> MoEConfig:
    return MoEConfig(
        name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=96, vocab_size=512, n_experts=4, top_k=2, capacity_factor=16.0,
        sliding_window=16, dtype="float32",
    )


register_arch("mixtral-8x7b", full, smoke)
