"""gcn-cora [arXiv:1609.02907]: 2 layers, d_hidden=16, mean/sym-norm
aggregation — the canonical citation-network GCN."""
from repro.config.base import GNNConfig
from repro.config.registry import register_arch


def full() -> GNNConfig:
    return GNNConfig(name="gcn-cora", kind="gcn", n_layers=2, d_hidden=16,
                     aggregator="mean", norm="sym", d_out=7)


def smoke() -> GNNConfig:
    return GNNConfig(name="gcn-smoke", kind="gcn", n_layers=2, d_hidden=8,
                     aggregator="mean", norm="sym", d_out=4)


register_arch("gcn-cora", full, smoke)
