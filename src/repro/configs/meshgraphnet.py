"""meshgraphnet [arXiv:2010.03409]: 15 message-passing blocks, d_hidden=128,
sum aggregation, 2-layer MLPs, encode-process-decode."""
from repro.config.base import GNNConfig
from repro.config.registry import register_arch


def full() -> GNNConfig:
    return GNNConfig(name="meshgraphnet", kind="meshgraphnet", n_layers=15,
                     d_hidden=128, aggregator="sum", mlp_layers=2, d_out=3,
                     d_edge=4)


def smoke() -> GNNConfig:
    return GNNConfig(name="meshgraphnet-smoke", kind="meshgraphnet",
                     n_layers=2, d_hidden=32, aggregator="sum", mlp_layers=2,
                     d_out=3, d_edge=4)


register_arch("meshgraphnet", full, smoke)
