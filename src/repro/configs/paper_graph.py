"""paper-graph: the paper's own decomposition/diameter engine as an arch.
Defaults = the paper's experimental choices (CLUSTER, stop variant,
Delta_init = avg edge weight, quotient ~ n/1000)."""
from repro.config.base import GraphEngineConfig
from repro.config.registry import register_arch


def full() -> GraphEngineConfig:
    return GraphEngineConfig(name="paper-graph")


def smoke() -> GraphEngineConfig:
    return GraphEngineConfig(name="paper-graph-smoke", tau_fraction=2e-2,
                             max_stages=16)


register_arch("paper-graph", full, smoke)
