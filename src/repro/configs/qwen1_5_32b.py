"""qwen1.5-32b [hf:Qwen/Qwen1.5-0.5B family]: 64L d_model=5120 40H
(GQA kv=40 = MHA) d_ff=27392 vocab=152064 — QKV bias, SwiGLU."""
from repro.config.base import TransformerConfig
from repro.config.registry import register_arch


def full() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-32b", n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_head=128, d_ff=27392, vocab_size=152064,
        qkv_bias=True, act="silu", rope_theta=1_000_000.0,
        dtype="bfloat16", remat="full",
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-32b-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_head=16, d_ff=160, vocab_size=512, qkv_bias=True, dtype="float32",
    )


register_arch("qwen1.5-32b", full, smoke)
