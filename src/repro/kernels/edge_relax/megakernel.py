"""Persistent fused grow-superstep megakernel (Pallas TPU).

``kernel.py`` fuses ONE relaxation superstep into one pass over the edge
blocks, but a grow call is a *loop* of supersteps: between kernel launches
the planes round-trip through HBM, XLA re-issues the gather / candidate /
tuple-min chain per superstep, and the while_loop re-dispatches one
``pallas_call`` per iteration. This module runs K supersteps (K static) in a
SINGLE ``pallas_call``:

  * grid = (K, n_blocks), both dimensions "arbitrary" (sequential), so the
    Pallas pipeline double-buffers the edge-block DMA along the inner
    dimension while compute runs — edges stream HBM -> VMEM exactly once per
    superstep;
  * the node planes (d, c, pathw), the relay planes, and the frontier bitmap
    stay RESIDENT in VMEM for all K supersteps (BlockSpec index maps pin
    them to block (0, 0));
  * an on-chip frontier bitmap (``front``: 1 where the node's tuple changed
    in the previous superstep) lets dead edge blocks — blocks none of whose
    masked sources changed — skip the candidate/tuple-min compute entirely,
    with no host round-trip. Skipped blocks are counted (their DMA still
    streams: a pure DMA-stall slot the ``EngineMetrics.dma_stall_blocks``
    counter surfaces);
  * the PartialGrowth stopping rule (``core.delta_growing.growth_loop``)
    is evaluated ON CHIP before every superstep, so a fused chunk that
    reaches the stop/quiescence condition early freezes the remaining
    supersteps — the result is byte-identical to the unfused loop, never
    "K supersteps no matter what".

Frontier-skip soundness: a candidate from edge (u, v) depends only on u's
in-stage tuple (d, c, pathw), the relay planes (constant within a grow
call), the edge weight, and Delta (constant within a call). If u did not
change in superstep k-1, it emits the same candidates in superstep k that
were already merged in k-1 — merging is idempotent — so only blocks with a
changed source can produce an update. The bitmap starts all-ones, so every
block is processed at least once per grow call.

``ref.py`` (via ``core.delta_growing.growth_loop`` + ``edge_relax_ref``)
remains the byte-identical parity oracle; the megakernel parity suite
(``tests/test_megakernel.py``) runs this kernel in interpret mode on CPU.

VMEM contract: 15 int32 planes of ``n_pad`` slots stay resident (8 inputs,
4 outputs, 3 accumulator scratch) plus the [node_tile, edge_block] match
matrix. ``fits_vmem`` checks the footprint against a conservative budget;
``PallasBackend`` falls back to the unfused path when it does not fit.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.compat import tpu_compiler_params

# stats layout: one row per fused superstep + one summary row (index K).
# Per-superstep rows: executed flag, nodes changed, reached count after the
# merge, cumulative dead (frontier-skipped) blocks, continue flag.
# Summary row: supersteps executed this call, final reached count, final
# changed flag, total dead blocks, continue flag for the NEXT chunk.
STATS_W = 8
COL_EXECUTED = 0   # summary: supersteps executed in this call
COL_CHANGED = 1    # summary: changed flag after the last executed superstep
COL_REACHED = 2    # summary: |{~frozen: d < delta}| on the final planes
COL_DEAD = 3       # summary: frontier-skipped edge blocks (DMA-stall slots)
COL_CONT = 4       # summary: growth_loop cond for the next superstep

DEFAULT_K_FUSED = 8

# Conservative VMEM budget for the resident planes + match matrix (v5e has
# ~16 MiB/core; leave headroom for the streamed edge blocks and spills).
VMEM_BUDGET_BYTES = 8 * 2**20
_RESIDENT_PLANES = 15  # 8 inputs + 4 outputs + 3 accumulator scratch


def vmem_footprint_bytes(n_pad: int, node_tile: int, edge_block: int) -> int:
    """Bytes of VMEM the fused kernel keeps live: resident int32 planes,
    the [node_tile, edge_block] match matrix (×4 for the masked candidate
    intermediates), and the double-buffered edge blocks (4 arrays × 2)."""
    planes = _RESIDENT_PLANES * n_pad * 4
    match = 4 * node_tile * edge_block * 4
    edges = 2 * 4 * edge_block * 4
    return planes + match + edges


def fits_vmem(n_pad: int, node_tile: int, edge_block: int,
              budget: int = VMEM_BUDGET_BYTES) -> bool:
    return vmem_footprint_bytes(n_pad, node_tile, edge_block) <= budget


def _mega_kernel(
    # scalar prefetch
    block_tile,            # int32 [n_blocks]  node tile of each edge block
    params,                # int32 [8]: delta, half_target, num_it,
                           #            steps_base, stop_variant, ...
    # resident inputs [n_tiles, node_tile]
    d0, c0, p0, rw0, rc, rp, frozen, front0,
    # per-edge inputs, blocked [1, edge_block] along grid dim 1
    bsrc, bdst, bw, bmask,
    # resident outputs
    d, c, p, front,        # [n_tiles, node_tile]
    stats,                 # [k_fused + 1, STATS_W]
    # scratch
    acc_d, acc_c, acc_p,   # VMEM [n_tiles, node_tile] superstep accumulators
    flags,                 # SMEM [8]: running, steps, changed, dead_blocks
    *, node_tile: int, edge_block: int,
):
    INF = jnp.int32(2**31 - 1)   # traced-body constants (Pallas forbids
    BIG = jnp.int32(2**30)       # captured outer-scope arrays)
    k = pl.program_id(0)
    b = pl.program_id(1)
    n_blocks = pl.num_programs(1)
    delta = params[0]
    half_target = params[1]
    num_it = params[2]
    steps_base = params[3]
    stop_variant = params[4]

    def reached_count():
        return jnp.sum(((frozen[...] == 0) & (d[...] < delta))
                       .astype(jnp.int32))

    def cond_flag(changed_i32, steps_done, reached):
        """growth_loop.cond: changed & steps < num_it [& reached < target]."""
        more = (changed_i32 == 1) & (steps_base + steps_done < num_it)
        return more & ((stop_variant == 0) | (reached < half_target))

    # ---- once per call: land the carried planes in VMEM -------------------
    @pl.when((k == 0) & (b == 0))
    def _init_call():
        d[...] = d0[...]
        c[...] = c0[...]
        p[...] = p0[...]
        front[...] = front0[...]
        stats[...] = jnp.zeros(stats.shape, jnp.int32)
        flags[0] = 1  # running
        flags[1] = 0  # supersteps executed
        flags[2] = 1  # changed (growth_loop's initial True)
        flags[3] = 0  # dead blocks

    # ---- once per superstep: on-chip stop rule + fresh accumulators -------
    @pl.when(b == 0)
    def _start_superstep():
        live = cond_flag(flags[2], k, reached_count())
        flags[0] = jnp.where(flags[0] == 1, live.astype(jnp.int32), 0)
        acc_d[...] = jnp.full(acc_d.shape, INF, jnp.int32)
        acc_c[...] = jnp.full(acc_c.shape, INF, jnp.int32)
        acc_p[...] = jnp.full(acc_p.shape, INF, jnp.int32)

    # ---- per edge block: frontier check, candidates, tuple-min ------------
    running = flags[0] == 1
    tile = block_tile[b]
    srcv = bsrc[0]
    mk = bmask[0] != 0
    live_block = jnp.any((front[...].reshape(-1)[srcv] == 1) & mk)

    @pl.when(running & live_block)
    def _relax_block():
        gather = lambda ref: ref[...].reshape(-1)[srcv]
        dsv, csv, psv = gather(d), gather(c), gather(p)
        rw0v, rcv, rpv = gather(rw0), gather(rc), gather(rp)
        wv = bw[0]
        # candidate rule — mirror of ref.edge_relax_candidates
        live_ok = (dsv < delta) & (wv < delta) & mk
        live_d = jnp.where(live_ok, jnp.where(live_ok, dsv, 0) + wv, INF)
        w_red = jnp.maximum(wv + jnp.where(rw0v >= BIG, BIG, rw0v), 0)
        relay_ok = (rw0v < BIG) & (w_red < delta) & mk
        cand_d = jnp.where(relay_ok, w_red, live_d)
        cand_c = jnp.where(relay_ok, rcv, jnp.where(live_ok, csv, INF))
        p_base = jnp.where(relay_ok, rpv, jnp.where(live_ok, psv, 0))
        p_safe = jnp.where(p_base >= BIG, 0, p_base)
        cand_p = jnp.where(relay_ok | live_ok, p_safe + wv, INF)
        # within-block tuple-min by destination row (VPU match matrix)
        local_dst = bdst[0] - tile * node_tile
        rows = jax.lax.broadcasted_iota(
            jnp.int32, (node_tile, edge_block), 0)
        match = local_dst[None, :] == rows
        d_blk = jnp.min(jnp.where(match, cand_d[None, :], INF), axis=1)
        w1 = match & (cand_d[None, :] == d_blk[:, None])
        c_blk = jnp.min(jnp.where(w1, cand_c[None, :], INF), axis=1)
        w2 = w1 & (cand_c[None, :] == c_blk[:, None])
        p_blk = jnp.min(jnp.where(w2, cand_p[None, :], INF), axis=1)
        # lexicographic merge into the owning tile's accumulator row
        idx = (pl.ds(tile, 1), pl.ds(0, node_tile))
        ad = pl.load(acc_d, idx)[0]
        ac = pl.load(acc_c, idx)[0]
        ap = pl.load(acc_p, idx)[0]
        take = (d_blk < ad) | ((d_blk == ad) & (
            (c_blk < ac) | ((c_blk == ac) & (p_blk < ap))))
        pl.store(acc_d, idx, jnp.where(take, d_blk, ad)[None])
        pl.store(acc_c, idx, jnp.where(take, c_blk, ac)[None])
        pl.store(acc_p, idx, jnp.where(take, p_blk, ap)[None])

    @pl.when(running & ~live_block)
    def _dead_block():
        flags[3] = flags[3] + 1

    # ---- once per superstep: merge + stats ---------------------------------
    @pl.when(b == n_blocks - 1)
    def _finish_superstep():
        @pl.when(flags[0] == 1)
        def _merge():
            upd = (frozen[...] == 0) & (acc_d[...] < d[...])
            d[...] = jnp.where(upd, acc_d[...], d[...])
            c[...] = jnp.where(upd, acc_c[...], c[...])
            p[...] = jnp.where(upd, acc_p[...], p[...])
            front[...] = upd.astype(jnp.int32)
            n_changed = jnp.sum(upd.astype(jnp.int32))
            flags[1] = flags[1] + 1
            flags[2] = (n_changed > 0).astype(jnp.int32)
            reached = reached_count()
            cont = cond_flag(flags[2], flags[1], reached)
            row = jnp.zeros((STATS_W,), jnp.int32)
            row = row.at[COL_EXECUTED].set(1)
            row = row.at[COL_CHANGED].set(n_changed)
            row = row.at[COL_REACHED].set(reached)
            row = row.at[COL_DEAD].set(flags[3])
            row = row.at[COL_CONT].set(cont.astype(jnp.int32))
            pl.store(stats, (pl.ds(k, 1), pl.ds(0, STATS_W)), row[None])

        @pl.when(k == pl.num_programs(0) - 1)
        def _summary():
            reached = reached_count()
            cont = cond_flag(flags[2], flags[1], reached)
            row = jnp.zeros((STATS_W,), jnp.int32)
            row = row.at[COL_EXECUTED].set(flags[1])
            row = row.at[COL_CHANGED].set(flags[2])
            row = row.at[COL_REACHED].set(reached)
            row = row.at[COL_DEAD].set(flags[3])
            row = row.at[COL_CONT].set(cont.astype(jnp.int32))
            pl.store(stats, (pl.ds(pl.num_programs(0), 1),
                             pl.ds(0, STATS_W)), row[None])


@functools.partial(jax.jit, static_argnames=(
    "k_fused", "n_tiles", "node_tile", "edge_block", "interpret"))
def fused_grow_supersteps(
    d: jnp.ndarray,          # [n_tiles, node_tile] in-stage planes
    c: jnp.ndarray,
    p: jnp.ndarray,
    rw0: jnp.ndarray,        # relay planes (constant within a grow call)
    rc: jnp.ndarray,
    rp: jnp.ndarray,
    frozen: jnp.ndarray,     # int32 0/1
    front: jnp.ndarray,      # int32 0/1 frontier bitmap (carried)
    bsrc: jnp.ndarray,       # [n_blocks, edge_block] blocked edges
    bdst: jnp.ndarray,
    bw: jnp.ndarray,
    bmask: jnp.ndarray,
    block_tile: jnp.ndarray,  # int32 [n_blocks]
    params: jnp.ndarray,      # int32 [8]; see _mega_kernel
    k_fused: int,
    n_tiles: int,
    node_tile: int,
    edge_block: int,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """Up to ``k_fused`` supersteps in one pallas_call.

    Returns ``(d, c, p, front, stats)``; ``stats[k_fused]`` is the summary
    row (see the COL_* constants).
    """
    n_blocks = bsrc.shape[0]
    plane_spec = pl.BlockSpec((n_tiles, node_tile), lambda k, b, *_: (0, 0))
    edge_spec = pl.BlockSpec((1, edge_block), lambda k, b, *_: (b, 0))
    stats_spec = pl.BlockSpec((k_fused + 1, STATS_W), lambda k, b, *_: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(k_fused, n_blocks),
        in_specs=[plane_spec] * 8 + [edge_spec] * 4,
        out_specs=[plane_spec] * 4 + [stats_spec],
        scratch_shapes=[
            pltpu.VMEM((n_tiles, node_tile), jnp.int32),
            pltpu.VMEM((n_tiles, node_tile), jnp.int32),
            pltpu.VMEM((n_tiles, node_tile), jnp.int32),
            pltpu.SMEM((8,), jnp.int32),
        ],
    )
    out_shape = (
        [jax.ShapeDtypeStruct((n_tiles, node_tile), jnp.int32)] * 4
        + [jax.ShapeDtypeStruct((k_fused + 1, STATS_W), jnp.int32)]
    )
    kern = functools.partial(_mega_kernel, node_tile=node_tile,
                             edge_block=edge_block)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(block_tile, params, d, c, p, rw0, rc, rp, frozen, front,
      bsrc, bdst, bw, bmask)


def megakernel_growth_loop(
    state,
    bsrc, bdst, bw, bmask, block_tile,
    delta, half_target, num_it,
    n_tiles: int, node_tile: int, edge_block: int,
    k_fused: int, interpret: bool, variant: str,
):
    """PartialGrowth where the while_loop body is one FUSED K-superstep
    kernel call instead of one superstep.

    Byte-identical to ``growth_loop`` + ``edge_relax_ref``: the kernel
    evaluates the same per-superstep stopping condition on chip, so early
    stop/quiescence freezes the remaining fused slots. Traceable — the
    engine calls this from inside its jitted stage program.

    Returns ``(state, GrowthStats)`` with the kernel-level counters
    (``kernel_launches``, ``kernel_supersteps``, ``dead_blocks``) filled in.
    """
    from repro.core.delta_growing import GrowthStats
    from repro.core.state import relay_planes

    rw0, rc, rp, frozen = relay_planes(state)
    shape2 = (n_tiles, node_tile)
    r2 = lambda x: x.reshape(shape2)
    froz2 = frozen.astype(jnp.int32).reshape(shape2)
    planes_const = (r2(rw0), r2(rc), r2(rp), froz2)
    stop_flag = jnp.int32(1 if variant == "stop" else 0)
    zeros3 = jnp.zeros((3,), jnp.int32)

    def body(carry):
        d2, c2, p2, fr, steps, _, launches, dead, _, _ = carry
        params = jnp.concatenate([
            jnp.stack([jnp.int32(delta), jnp.int32(half_target),
                       jnp.int32(num_it), steps, stop_flag]), zeros3])
        d2, c2, p2, fr, stats = fused_grow_supersteps(
            d2, c2, p2, *planes_const, fr, bsrc, bdst, bw, bmask,
            block_tile, params, k_fused=k_fused, n_tiles=n_tiles,
            node_tile=node_tile, edge_block=edge_block, interpret=interpret)
        summ = stats[k_fused]
        return (d2, c2, p2, fr, steps + summ[COL_EXECUTED],
                summ[COL_CONT] == 1, launches + 1, dead + summ[COL_DEAD],
                summ[COL_REACHED], summ[COL_CHANGED])

    init = (r2(state.d), r2(state.c), r2(state.pathw),
            jnp.ones(shape2, jnp.int32), jnp.int32(0), jnp.bool_(True),
            jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(1))
    (d2, c2, p2, _, steps, _, launches, dead, reached,
     changed) = jax.lax.while_loop(lambda cr: cr[5], body, init)
    new_state = state._replace(d=d2.reshape(-1), c=c2.reshape(-1),
                               pathw=p2.reshape(-1))
    return new_state, GrowthStats(
        steps=steps, reached=reached, changed_last=changed == 1,
        kernel_launches=launches, kernel_supersteps=steps, dead_blocks=dead)
