"""Pure-jnp oracle for the Δ-growing edge relaxation (paper Section 3).

Semantics (identical to core/distributed._relax_local, restated standalone so
the kernel test suite depends only on this file):

Per edge e = (src, dst, w), with pre-gathered source planes:
  live candidate   d_src + w      when d_src < Δ and w < Δ       (light edge)
  relay candidate  max(w+rw0, 0)  when rw0 < BIG and that value < Δ
                                  (covered source relays its center's wave
                                  with the contraction rescaling folded in)
Relay beats live on the same edge (a covered source has no live wave).

Per destination node: lexicographic (d, c, pathw) tuple-min over incident
edges — smallest distance, then smallest center id (the paper's tie-break),
then the realized original-graph path weight of that winner.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

INF = jnp.int32(2**31 - 1)
BIG = jnp.int32(2**30)


def edge_relax_candidates(
    d_src: jnp.ndarray,
    c_src: jnp.ndarray,
    p_src: jnp.ndarray,
    rw0_src: jnp.ndarray,
    rc_src: jnp.ndarray,
    rp_src: jnp.ndarray,
    w: jnp.ndarray,
    mask: jnp.ndarray,
    delta: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    live_ok = (d_src < delta) & (w < delta) & mask
    live_d = jnp.where(live_ok, jnp.where(live_ok, d_src, 0) + w, INF)
    w_red = jnp.maximum(w + jnp.where(rw0_src >= BIG, BIG, rw0_src), 0)
    relay_ok = (rw0_src < BIG) & (w_red < delta) & mask
    cand_d = jnp.where(relay_ok, w_red, live_d)
    cand_c = jnp.where(relay_ok, rc_src, jnp.where(live_ok, c_src, INF))
    p_base = jnp.where(relay_ok, rp_src, jnp.where(live_ok, p_src, 0))
    p_safe = jnp.where(p_base >= BIG, 0, p_base)
    cand_p = jnp.where(relay_ok | live_ok, p_safe + w, INF)
    return cand_d, cand_c, cand_p


@partial(jax.jit, static_argnames=("n_nodes",))
def edge_relax_ref(
    d_src: jnp.ndarray,
    c_src: jnp.ndarray,
    p_src: jnp.ndarray,
    rw0_src: jnp.ndarray,
    rc_src: jnp.ndarray,
    rp_src: jnp.ndarray,
    w: jnp.ndarray,
    dst: jnp.ndarray,
    mask: jnp.ndarray,
    delta: jnp.ndarray,
    n_nodes: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns per-node (d_min, c_min, p_min); INF where no candidate."""
    cand_d, cand_c, cand_p = edge_relax_candidates(
        d_src, c_src, p_src, rw0_src, rc_src, rp_src, w, mask, delta
    )
    d_min = jax.ops.segment_min(cand_d, dst, num_segments=n_nodes)
    w1 = cand_d == d_min[dst]
    c_min = jax.ops.segment_min(jnp.where(w1, cand_c, INF), dst, num_segments=n_nodes)
    w2 = w1 & (cand_c == c_min[dst])
    p_min = jax.ops.segment_min(jnp.where(w2, cand_p, INF), dst, num_segments=n_nodes)
    # nodes with no candidate at all keep INF in all three planes
    return d_min, c_min, p_min
