"""Pallas TPU kernel for the fused Δ-growing relaxation.

The reference does 3 full HBM passes over the per-edge arrays (one
``segment_min`` per plane of the lexicographic (d, c, pathw) tuple-min) plus
the mask intermediates XLA materializes between them. This kernel makes ONE
pass: per edge block it computes the candidates on the VPU and reduces the
tuple-min into the owning node tile entirely in VMEM, carrying the partial
result across the edge blocks of a tile (blocks of one tile are consecutive
in the destination-sorted layout, so the output block stays resident).

Layout contract (produced by ``graph.structures.DeviceGraph.build``):
  * edges destination-sorted, segmented so no edge block straddles a node
    tile; padding edges point at the phantom node with mask=False;
  * ``block_tile[b]`` = node tile owning edge block b (scalar-prefetched so
    Pallas can map output blocks before the body runs);

Grid: one step per edge block (sequential — "arbitrary" dimension semantics),
output node-tile block revisited by consecutive steps. The within-block
reduce-by-key is a broadcast-compare + row-min over a [node_tile, edge_block]
match matrix: a VPU-native realization of the scatter that would be a serial
loop on TPU. int32 throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.compat import tpu_compiler_params

INF = jnp.int32(2**31 - 1)
BIG = jnp.int32(2**30)

# default tiling: 256-node tiles, 512-edge blocks -> match matrix 256x512
NODE_TILE = 256
EDGE_BLOCK = 512


def validate_tiling(node_tile: int, edge_block: int) -> None:
    """Reject tilings the kernels cannot execute correctly.

    ``edge_block`` must be a positive multiple of 128 (TPU lane width: edge
    blocks are the minor dimension of every streamed array) and ``node_tile``
    a positive power of two (``dst // node_tile`` tile assignment and the
    phantom-node padding in ``block_edges_host`` assume it).
    """
    if edge_block <= 0 or edge_block % 128 != 0:
        raise ValueError(
            f"edge_block must be a positive multiple of 128, got {edge_block}")
    if node_tile <= 0 or (node_tile & (node_tile - 1)) != 0:
        raise ValueError(
            f"node_tile must be a positive power of two, got {node_tile}")


def validate_block_tile(block_tile, n_tiles: int) -> None:
    """Check a concrete block->tile map: every block owned by a valid tile,
    and each tile's blocks CONSECUTIVE (monotone non-decreasing) — the
    carried-partial merge in ``_relax_kernel`` revisits the same output
    block across consecutive grid steps and would silently lose updates on
    an interleaved map."""
    import numpy as np
    bt = np.asarray(block_tile)
    if bt.ndim != 1 or bt.size == 0:
        raise ValueError("block_tile must be a non-empty 1-D array")
    if bt.min() < 0 or bt.max() >= n_tiles:
        raise ValueError(
            f"block_tile entries must be in [0, {n_tiles}), got range "
            f"[{int(bt.min())}, {int(bt.max())}]")
    if np.any(np.diff(bt) < 0):
        raise ValueError(
            "block_tile must be monotone non-decreasing: the kernel carries "
            "each tile's partial tuple-min across consecutive edge blocks")


def _relax_kernel(
    # scalar-prefetch
    block_tile,            # int32 [n_blocks]  node tile of each edge block
    delta_ref,             # int32 [1]
    # per-edge inputs, blocked [1, EDGE_BLOCK]
    d_src, c_src, p_src, rw0, rc, rp, w, dst, mask,
    # outputs, blocked [1, NODE_TILE] (revisited across a tile's blocks)
    d_out, c_out, p_out,
    *, node_tile: int, edge_block: int,
):
    INF = jnp.int32(2**31 - 1)   # created inside the traced body: Pallas
    BIG = jnp.int32(2**30)       # forbids captured outer-scope constants
    b = pl.program_id(0)
    delta = delta_ref[0]
    tile = block_tile[b]

    # --- candidate computation (VPU elementwise) -------------------------
    dsv, wv, mk = d_src[0], w[0], mask[0]
    rw0v = rw0[0]
    live_ok = (dsv < delta) & (wv < delta) & mk
    live_d = jnp.where(live_ok, jnp.where(live_ok, dsv, 0) + wv, INF)
    w_red = jnp.maximum(wv + jnp.where(rw0v >= BIG, BIG, rw0v), 0)
    relay_ok = (rw0v < BIG) & (w_red < delta) & mk
    cand_d = jnp.where(relay_ok, w_red, live_d)
    cand_c = jnp.where(relay_ok, rc[0], jnp.where(live_ok, c_src[0], INF))
    p_base = jnp.where(relay_ok, rp[0], jnp.where(live_ok, p_src[0], 0))
    p_safe = jnp.where(p_base >= BIG, 0, p_base)
    cand_p = jnp.where(relay_ok | live_ok, p_safe + wv, INF)

    # --- within-block tuple-min by destination row ------------------------
    local_dst = dst[0] - tile * node_tile                       # [E]
    rows = jax.lax.broadcasted_iota(jnp.int32, (node_tile, edge_block), 0)
    match = local_dst[None, :] == rows                          # [T, E]
    dmat = jnp.where(match, cand_d[None, :], INF)
    d_blk = jnp.min(dmat, axis=1)                               # [T]
    w1 = match & (cand_d[None, :] == d_blk[:, None])
    c_blk = jnp.min(jnp.where(w1, cand_c[None, :], INF), axis=1)
    w2 = w1 & (cand_c[None, :] == c_blk[:, None])
    p_blk = jnp.min(jnp.where(w2, cand_p[None, :], INF), axis=1)

    # --- merge with the carried partial result for this tile --------------
    first = jnp.where(b > 0, block_tile[jnp.maximum(b - 1, 0)] != tile, True)

    @pl.when(first)
    def _init():
        d_out[0, :] = jnp.full((node_tile,), INF, jnp.int32)
        c_out[0, :] = jnp.full((node_tile,), INF, jnp.int32)
        p_out[0, :] = jnp.full((node_tile,), INF, jnp.int32)

    d_prev, c_prev, p_prev = d_out[0, :], c_out[0, :], p_out[0, :]
    take = (d_blk < d_prev) | (
        (d_blk == d_prev) & ((c_blk < c_prev) | ((c_blk == c_prev) & (p_blk < p_prev)))
    )
    d_out[0, :] = jnp.where(take, d_blk, d_prev)
    c_out[0, :] = jnp.where(take, c_blk, c_prev)
    p_out[0, :] = jnp.where(take, p_blk, p_prev)


@functools.partial(
    jax.jit,
    static_argnames=("n_tiles", "node_tile", "edge_block", "interpret"),
)
def _edge_relax_pallas_jit(
    d_src: jnp.ndarray,     # int32 [n_blocks, EDGE_BLOCK] pre-gathered planes
    c_src: jnp.ndarray,
    p_src: jnp.ndarray,
    rw0: jnp.ndarray,
    rc: jnp.ndarray,
    rp: jnp.ndarray,
    w: jnp.ndarray,
    dst: jnp.ndarray,
    mask: jnp.ndarray,      # int32 0/1 (TPU-friendly; bool also accepted)
    block_tile: jnp.ndarray,  # int32 [n_blocks]
    delta: jnp.ndarray,       # int32 [1]
    n_tiles: int,
    node_tile: int = NODE_TILE,
    edge_block: int = EDGE_BLOCK,
    interpret: bool = False,
):
    """Fused relax + lexicographic segment-min. Returns (d, c, p) [n_tiles*T]."""
    n_blocks = d_src.shape[0]
    mask = mask.astype(jnp.bool_)

    edge_spec = pl.BlockSpec((1, edge_block), lambda b, *_: (b, 0))
    out_spec = pl.BlockSpec((1, node_tile), lambda b, bt, _d: (bt[b], 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_blocks,),
        in_specs=[edge_spec] * 9,
        out_specs=[out_spec] * 3,
    )
    out_shape = [
        jax.ShapeDtypeStruct((n_tiles, node_tile), jnp.int32) for _ in range(3)
    ]
    kern = functools.partial(_relax_kernel, node_tile=node_tile, edge_block=edge_block)
    d, c, p = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
    )(block_tile, delta, d_src, c_src, p_src, rw0, rc, rp, w, dst, mask)
    return d.reshape(-1), c.reshape(-1), p.reshape(-1)


def edge_relax_pallas(
    d_src, c_src, p_src, rw0, rc, rp, w, dst, mask, block_tile, delta,
    n_tiles: int,
    node_tile: int = NODE_TILE,
    edge_block: int = EDGE_BLOCK,
    interpret: bool = False,
):
    """Validated entry point for the fused relax kernel.

    Custom tilings that break the layout contract produced a silently wrong
    answer before; now they raise. The monotone block_tile check only runs
    on concrete (non-traced) arrays — inside a jit the map was already
    validated when the caller built it on the host.
    """
    validate_tiling(node_tile, edge_block)
    if not isinstance(block_tile, jax.core.Tracer):
        validate_block_tile(block_tile, n_tiles)
    return _edge_relax_pallas_jit(
        d_src, c_src, p_src, rw0, rc, rp, w, dst, mask, block_tile, delta,
        n_tiles, node_tile=node_tile, edge_block=edge_block,
        interpret=interpret)
