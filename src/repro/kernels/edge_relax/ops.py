"""jit'd public wrapper for the edge_relax kernel.

``edge_relax(...)`` takes flat destination-sorted per-edge arrays (the layout
``DeviceGraph.build`` produces, or any dst-sorted edge list — this wrapper
re-blocks on the fly), pre-gathers the source planes, dispatches to the
Pallas kernel (TPU) or the jnp oracle (CPU / explicit ``impl="ref"``), and
returns per-node (d_min, c_min, p_min).
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import next_multiple
from repro.kernels.edge_relax.kernel import (
    EDGE_BLOCK,
    NODE_TILE,
    edge_relax_pallas,
)
from repro.kernels.edge_relax.ref import INF, edge_relax_ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


_PALLAS_FALLBACK_WARNED = False


def _resolve_impl(impl: str) -> str:
    """Compiled-Pallas requests off TPU fall back to the jnp reference with
    a one-time warning instead of failing at trace time (Mosaic lowering is
    TPU-only; single-device CI runs on CPU). ``interpret`` is always legal —
    it IS the CPU oracle path."""
    global _PALLAS_FALLBACK_WARNED
    if impl == "pallas" and jax.default_backend() != "tpu":
        if not _PALLAS_FALLBACK_WARNED:
            _PALLAS_FALLBACK_WARNED = True
            warnings.warn(
                "edge_relax: impl='pallas' requested but the default JAX "
                "backend is not TPU; falling back to the reference "
                "implementation (use impl='interpret' to exercise the "
                "kernel body on CPU)", RuntimeWarning, stacklevel=3)
        return "ref"
    return impl


def block_edges_host(
    src: np.ndarray,
    dst: np.ndarray,
    w: np.ndarray,
    n_nodes: int,
    node_tile: int = NODE_TILE,
    edge_block: int = EDGE_BLOCK,
):
    """Host-side preprocessing: dst-sort + segment per node tile + pad.

    Returns dict of [n_blocks, edge_block] arrays + block_tile [n_blocks]
    + n_tiles. Pure numpy; do once per graph.
    """
    order = np.lexsort((src, dst))
    src, dst, w = src[order], dst[order], w[order]
    n_pad_nodes = next_multiple(n_nodes + 1, node_tile)
    n_tiles = n_pad_nodes // node_tile
    phantom = n_pad_nodes - 1

    tile_of_edge = dst // node_tile
    counts = np.bincount(tile_of_edge, minlength=n_tiles)
    # every tile gets >= 1 (possibly all-phantom) block so its output block
    # is always visited and initialized by the kernel
    padded = np.maximum(-(-counts // edge_block) * edge_block, edge_block)
    total = int(padded.sum())

    sp = np.full(total, phantom, np.int32)
    dp = np.full(total, phantom, np.int32)
    wp = np.ones(total, np.int32)
    mk = np.zeros(total, np.int32)
    si = np.concatenate([[0], np.cumsum(counts)])
    so = np.concatenate([[0], np.cumsum(padded)])
    for t in range(n_tiles):
        c = int(counts[t])
        if c == 0:
            continue
        a, b = int(si[t]), int(so[t])
        sp[b : b + c] = src[a : a + c]
        dp[b : b + c] = dst[a : a + c]
        wp[b : b + c] = w[a : a + c]
        mk[b : b + c] = 1
    # phantom padding rows must still map into their block's tile
    for t in range(n_tiles):
        a, b = int(so[t]), int(so[t] + padded[t])
        dp[a:b][mk[a:b] == 0] = min(t * node_tile, phantom)
        if padded[t]:
            dp[a:b][mk[a:b] == 0] = t * node_tile  # any row in tile t

    n_blocks = total // edge_block
    block_tile = np.repeat(np.arange(n_tiles, dtype=np.int32), padded // edge_block)
    shape = (n_blocks, edge_block)
    return {
        "src": sp.reshape(shape),
        "dst": dp.reshape(shape),
        "w": wp.reshape(shape),
        "mask": mk.reshape(shape),
        "block_tile": block_tile,
        "n_tiles": n_tiles,
        "n_pad_nodes": n_pad_nodes,
    }


@partial(jax.jit, static_argnames=("n_tiles", "node_tile", "edge_block", "impl"))
def edge_relax(
    planes: Tuple[jnp.ndarray, ...],  # (d, c, p, rw0, rc, rp) node planes [n_pad]
    blocked_src: jnp.ndarray,         # [n_blocks, E_B]
    blocked_dst: jnp.ndarray,
    blocked_w: jnp.ndarray,
    blocked_mask: jnp.ndarray,
    block_tile: jnp.ndarray,
    delta: jnp.ndarray,
    n_tiles: int,
    node_tile: int = NODE_TILE,
    edge_block: int = EDGE_BLOCK,
    impl: str = "ref",
):
    """One fused relaxation pass. Gathers source planes then reduces."""
    impl = _resolve_impl(impl)
    d, c, p, rw0, rc, rp = planes
    g = lambda x: x[blocked_src]
    if impl == "pallas" or impl == "interpret":
        return edge_relax_pallas(
            g(d), g(c), g(p), g(rw0), g(rc), g(rp),
            blocked_w, blocked_dst, blocked_mask, block_tile,
            jnp.asarray(delta, jnp.int32).reshape(1),
            n_tiles=n_tiles, node_tile=node_tile, edge_block=edge_block,
            interpret=(impl == "interpret"),
        )
    n = n_tiles * node_tile
    flat = lambda x: x.reshape(-1)
    return edge_relax_ref(
        flat(g(d)), flat(g(c)), flat(g(p)), flat(g(rw0)), flat(g(rc)), flat(g(rp)),
        flat(blocked_w), flat(blocked_dst), flat(blocked_mask).astype(bool),
        jnp.asarray(delta, jnp.int32), n,
    )
