"""Pallas TPU flash attention (forward) for the LM archs.

Online-softmax blocked attention (FlashAttention recomputation-free forward),
adapted to the TPU memory hierarchy: q/k/v tiles staged HBM->VMEM by
BlockSpecs, the (bq x bk) score tile lives only in VMEM/VREGs, MXU does both
GEMMs per tile. Supports the variants the assigned archs need:

  * GQA            (kv-head block index = q-head // group)
  * causal masking (+ dynamic q_offset for decode: query at cache position)
  * sliding window (mistral / gemma2 alternating-local layers)
  * logit softcap  (gemma2: cap * tanh(s / cap))
  * dynamic kv_len (decode against a partially filled cache)

Grid: (B, Hq, Sq/bq, Skv/bk); kv is the innermost "arbitrary" dim so the
running (m, l, acc) scratch carries across kv tiles of one query tile.
Fully-masked kv tiles short-circuit via @pl.when (no MXU work; the DMA cost
of skipped K/V tiles is noted in DESIGN.md as the known gap vs a fused
iteration-space — hillclimbed in §Perf by block-pruned index maps).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.compat import tpu_compiler_params

DEFAULT_BQ = 512
DEFAULT_BK = 512


def _flash_kernel(
    # scalar prefetch: [0] kv_len, [1] q_offset
    meta,                       # int32 [2]
    q_ref, k_ref, v_ref,        # [1, 1, bq, D], [1, 1, bk, D] x2
    o_ref,                      # [1, 1, bq, D]
    m_scr, l_scr, acc_scr,      # VMEM scratch: [bq,128], [bq,128], [bq,D]
    *,
    bq: int,
    bk: int,
    causal: bool,
    window: int,
    softcap: float,
    scale: float,
):
    neg_inf = jnp.float32(-1e30)
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    n_kb = pl.num_programs(3)
    kv_len = meta[0]
    q_off = meta[1]

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, neg_inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global positions of this tile's queries / keys
    q_pos = q_off + qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # tile-level pruning: skip tiles with no unmasked entry
    first_q = q_off + qb * bq
    last_q = first_q + bq - 1
    first_k = kb * bk
    live = first_k < kv_len
    if causal:
        live &= first_k <= last_q
    if window > 0:
        live &= (first_q - (first_k + bk - 1)) < window

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)                # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # [bq, bk]
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos < kv_len
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, neg_inf)

        m_prev = m_scr[:, :1]                              # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)          # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # [bq, bk]
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                    # [bq, 1]
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # [bq, D]
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(kb == n_kb - 1)
    def _emit():
        l = l_scr[:, :1]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale", "bq", "bk", "interpret",
    ),
)
def flash_attention_pallas(
    q: jnp.ndarray,            # [B, Hq, Sq, D]; Sq padded to multiple of bq
    k: jnp.ndarray,            # [B, Hkv, Skv, D]; Skv padded to multiple of bk
    v: jnp.ndarray,
    kv_len: jnp.ndarray,       # int32 [] — valid kv prefix (Skv when full)
    q_offset: jnp.ndarray,     # int32 [] — global position of q[:, :, 0]
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = float(scale if scale is not None else D ** -0.5)
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)

    meta = jnp.stack([kv_len.astype(jnp.int32), q_offset.astype(jnp.int32)])

    grid = (B, Hq, Sq // bq, Skv // bk)
    q_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, qb, kb, m: (b, h, qb, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, bk, D), lambda b, h, qb, kb, m: (b, h // group, kb, 0)
    )
    o_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, qb, kb, m: (b, h, qb, 0))

    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        softcap=softcap, scale=scale,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        scratch_shapes=[  # pallas: bq <= seq block, footprint bounded by block sizing above
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
    )
    # pallas: attention blocks are lane-padded by the caller, not the graph tiler
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
    )(meta, q, k, v)
