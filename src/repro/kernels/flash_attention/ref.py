"""Pure-jnp oracle for attention with the assigned archs' variants.

Supports: causal masking, GQA (n_q_heads a multiple of n_kv_heads), sliding
window (mistral/gemma2 local layers), attention logit soft-capping (gemma2),
explicit kv-length masking (decode against a partially-filled cache).

Naive O(S^2) materialization — the correctness oracle for the Pallas kernel
and the blocked-jnp implementation.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,            # [B, Hq, Sq, D]
    k: jnp.ndarray,            # [B, Hkv, Skv, D]
    v: jnp.ndarray,            # [B, Hkv, Skv, D]
    causal: bool = True,
    window: int = 0,           # 0 = full; else keys within (qpos - w, qpos]
    softcap: float = 0.0,
    scale: Optional[float] = None,
    kv_len: Optional[jnp.ndarray] = None,   # int32 [] or [B]: valid kv prefix
    q_offset: Optional[jnp.ndarray] = None, # int32 []: global pos of q[0]
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    kk = jnp.repeat(k, group, axis=1)  # [B, Hq, Skv, D]
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = jnp.arange(Sq, dtype=jnp.int32)
    if q_offset is not None:
        q_pos = q_pos + q_offset
    k_pos = jnp.arange(Skv, dtype=jnp.int32)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    mask = jnp.broadcast_to(mask[None, None], (B, 1, Sq, Skv))
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len, jnp.int32).reshape(-1)  # [] or [B] -> [B']
        klm = k_pos[None, :] < kv_len[:, None]               # [B', Skv]
        mask = mask & klm[:, None, None, :]

    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (fully masked) produce zeros, not NaNs
    any_valid = mask.any(axis=-1, keepdims=True)
    p = jnp.where(any_valid, p, 0.0)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)
