"""Public attention entry point used by the transformer models.

Three implementations of the same math:
  * "ref"     — naive O(S^2) oracle (tests, tiny shapes)
  * "blocked" — lax.scan online-softmax over kv blocks: memory-bounded in the
                HLO itself (scores tile never exceeds [bq, bk]) and
                differentiable, so it serves as the TRAIN path and the
                CPU/dry-run path. This is the TPU-native restatement of
                flash attention in pure JAX.
  * "pallas" / "interpret" — the Pallas kernel (serve hot path on TPU).

``attention`` pads Sq/Skv to tile multiples and slices back, so callers can
pass arbitrary lengths.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common import next_multiple
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref

NEG_INF = -1e30

# Roofline-probe hook: XLA's cost_analysis counts a lax.scan body ONCE, so
# the dry-run probe unrolls the kv-block loops to get exact FLOP/byte counts.
# Trace-time global; flipped only by launch/roofline_fit.py.
UNROLL_KV_SCAN = False


def _maybe_scan(step, init, xs):
    if not UNROLL_KV_SCAN:
        return jax.lax.scan(step, init, xs)
    carry = init
    stacked = []
    for j in range(int(xs.shape[0])):
        carry, out = step(carry, xs[j])
        stacked.append(out)
    if stacked and stacked[0] is not None:
        return carry, jax.tree.map(lambda *t: jnp.stack(t), *stacked)
    return carry, None


@partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bk"),
)
def attention_blocked(
    q: jnp.ndarray,            # [B, Hq, Sq, D]
    k: jnp.ndarray,            # [B, Hkv, Skv, D]
    v: jnp.ndarray,
    kv_len: Optional[jnp.ndarray] = None,
    q_offset: Optional[jnp.ndarray] = None,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    bq: int = 512,
    bk: int = 512,
) -> jnp.ndarray:
    """Online-softmax attention as a scan over kv blocks (pure JAX)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = float(scale if scale is not None else D ** -0.5)
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    kv_len = jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32)
    q_offset = jnp.asarray(0 if q_offset is None else q_offset, jnp.int32)

    # pad sequence dims to block multiples
    Sq_p, Skv_p = next_multiple(Sq, bq), next_multiple(Skv, bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
    n_kb = Skv_p // bk

    qf = (qp.astype(jnp.float32) * scale).reshape(B, Hq, Sq_p // bq, bq, D)
    kf = kp.astype(jnp.float32).reshape(B, Hkv, n_kb, bk, D)
    vf = vp.astype(jnp.float32).reshape(B, Hkv, n_kb, bk, D)

    q_pos = q_offset + jnp.arange(Sq_p, dtype=jnp.int32).reshape(Sq_p // bq, bq)

    def per_qblock(q_tile, qpos_tile, k_all, v_all):
        # q_tile [Hq, bq, D]; k_all/v_all [Hkv, n_kb, bk, D]
        def step(carry, inp):
            m, l, acc = carry
            k_t, v_t, kb = inp                      # [Hkv, bk, D]
            kk = jnp.repeat(k_t, group, axis=0)     # [Hq, bk, D]
            vv = jnp.repeat(v_t, group, axis=0)
            s = jnp.einsum("hqd,hkd->hqk", q_tile, kk)
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            k_pos = kb * bk + jnp.arange(bk, dtype=jnp.int32)
            mask = k_pos[None, :] < kv_len
            if causal:
                mask &= qpos_tile[:, None] >= k_pos[None, :]
            if window > 0:
                mask &= (qpos_tile[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.where(mask[None], jnp.exp(s - m_new[..., None]), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("hqk,hkd->hqd", p, vv)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((Hq, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((Hq, bq), jnp.float32)
        a0 = jnp.zeros((Hq, bq, D), jnp.float32)
        kbs = jnp.arange(n_kb, dtype=jnp.int32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (jnp.moveaxis(k_all, 1, 0), jnp.moveaxis(v_all, 1, 0), kbs),
        )
        safe = jnp.where(l > 0, l, 1.0)
        return acc / safe[..., None]

    # vmap over batch, then over q blocks
    out = jax.vmap(
        lambda qb_, qp_, k_, v_: jax.vmap(
            lambda qt, qpt: per_qblock(qt, qpt, k_, v_), in_axes=(1, 0), out_axes=1
        )(qb_, qp_)
    )(qf, jnp.broadcast_to(q_pos, (B,) + q_pos.shape), kf, vf)
    # out [B, Hq, n_qb, bq, D] -> [B, Hq, Sq, D]
    out = out.reshape(B, Hq, Sq_p, D)[:, :, :Sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# memory-efficient attention: FlashAttention-2 fwd/bwd in pure JAX
# ---------------------------------------------------------------------------
#
# Autodiff through the online-softmax scan saves O(S/bk) copies of the
# accumulator and probability tiles per layer (measured 4+ GB/layer/device at
# gemma2 train_4k) — a custom_vjp with the standard flash residuals (q, k, v,
# o, lse) and per-block recomputation in bwd brings attention bwd memory to
# O(bq x bk) transients, matching what the Pallas bwd kernel would do on TPU.

def _mask_block(q_pos, k_pos, kv_len, causal, window):
    m = k_pos[None, :] < kv_len
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def _mef_fwd_pass(q, k, v, kv_len, q_offset, causal, window, softcap, scale, bk):
    """Returns (o [B,Hkv,G,Sq,D] f32, lse [B,Hkv,G,Sq] f32). q pre-scaled."""
    B, Hkv, G, Sq, D = q.shape
    Skv = k.shape[2]
    n_kb = Skv // bk
    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)

    def step(carry, j):
        m_r, l_r, acc = carry
        k_j = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=2)
        v_j = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=2)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q, k_j.astype(jnp.float32))
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bk + jnp.arange(bk, dtype=jnp.int32)
        msk = _mask_block(q_pos, k_pos, kv_len, causal, window)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_r, s.max(-1))
        p = jnp.where(msk[None, None, None], jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_r - m_new)
        l_new = l_r * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_j.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    (m_r, l_r, acc), _ = _maybe_scan(step, (m0, l0, a0),
                                     jnp.arange(n_kb, dtype=jnp.int32))
    safe = jnp.where(l_r > 0, l_r, 1.0)
    o = acc / safe[..., None]
    lse = m_r + jnp.log(safe)
    return o, lse


def _mef_bwd_pass(q, k, v, o, lse, do, kv_len, q_offset,
                  causal, window, softcap, scale, bk):
    B, Hkv, G, Sq, D = q.shape
    Skv = k.shape[2]
    n_kb = Skv // bk
    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)
    delta = jnp.sum(do * o, axis=-1)                       # [B,Hkv,G,Sq]

    def step(dq, j):
        k_j = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=2).astype(jnp.float32)
        v_j = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=2).astype(jnp.float32)
        s0 = jnp.einsum("bhgqd,bhkd->bhgqk", q, k_j)       # pre-cap (q scaled)
        s = softcap * jnp.tanh(s0 / softcap) if softcap > 0 else s0
        k_pos = j * bk + jnp.arange(bk, dtype=jnp.int32)
        msk = _mask_block(q_pos, k_pos, kv_len, causal, window)
        p = jnp.where(msk[None, None, None], jnp.exp(s - lse[..., None]), 0.0)
        dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, do)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", do, v_j)
        ds = p * (dp - delta[..., None])
        if softcap > 0:
            ds = ds * (1.0 - (s / softcap) ** 2)
        dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds, k_j)
        dk_j = jnp.einsum("bhgqk,bhgqd->bhkd", ds, q)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros_like(q)
    dq, (dk_b, dv_b) = _maybe_scan(step, dq0, jnp.arange(n_kb, dtype=jnp.int32))
    dk = jnp.moveaxis(dk_b, 0, 2).reshape(B, Hkv, Skv, D)
    dv = jnp.moveaxis(dv_b, 0, 2).reshape(B, Hkv, Skv, D)
    return dq * scale, dk, dv


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _attention_mef(q, k, v, kv_len, q_offset,
                   causal, window, softcap, scale, bk):
    o, _ = _mef_fwd_pass(q.astype(jnp.float32) * scale, k, v, kv_len, q_offset,
                         causal, window, softcap, scale, bk)
    return o


def _attention_mef_fwd(q, k, v, kv_len, q_offset,
                       causal, window, softcap, scale, bk):
    qs = q.astype(jnp.float32) * scale
    o, lse = _mef_fwd_pass(qs, k, v, kv_len, q_offset,
                           causal, window, softcap, scale, bk)
    return o, (qs, k, v, o, lse, kv_len, q_offset)


def _attention_mef_bwd(causal, window, softcap, scale, bk, res, do):
    qs, k, v, o, lse, kv_len, q_offset = res
    dq, dk, dv = _mef_bwd_pass(qs, k, v, o, lse, do, kv_len, q_offset,
                               causal, window, softcap, scale, bk)
    return dq.astype(qs.dtype), dk.astype(k.dtype), dv.astype(v.dtype), None, None


_attention_mef.defvjp(_attention_mef_fwd, _attention_mef_bwd)


def attention_mef(q, k, v, kv_len=None, q_offset=None, causal=True, window=0,
                  softcap=0.0, scale=None, bk: int = 512):
    """Grouped (GQA) memory-efficient attention; same contract as
    attention_blocked but with flash-style bwd memory."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = float(scale if scale is not None else D ** -0.5)
    bk = min(bk, Skv)
    pad = (-Skv) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kv_len = jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32)
    q_offset = jnp.asarray(0 if q_offset is None else q_offset, jnp.int32)
    qg = q.reshape(B, Hkv, G, Sq, D)
    o = _attention_mef(qg, k, v, kv_len, q_offset,
                       causal, window, float(softcap), scale, bk)
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def attention(
    q, k, v,
    kv_len=None, q_offset=None,
    causal: bool = True, window: int = 0, softcap: float = 0.0,
    scale: Optional[float] = None,
    impl: str = "blocked",
    bq: int = 512, bk: int = 512,
):
    """Dispatching wrapper.

    impl: ref | blocked (flash-bwd custom_vjp; the TRAIN path) |
          blocked_ad (autodiff through the online-softmax scan; oracle for
          grad tests) | pallas | interpret.
    """
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, kv_len=kv_len,
                             q_offset=q_offset)
    if impl == "blocked":
        return attention_mef(q, k, v, kv_len=kv_len, q_offset=q_offset,
                             causal=causal, window=window, softcap=softcap,
                             scale=scale, bk=bk)
    if impl == "blocked_ad":
        return attention_blocked(q, k, v, kv_len=kv_len, q_offset=q_offset,
                                 causal=causal, window=window, softcap=softcap,
                                 scale=scale, bq=bq, bk=bk)
    # pallas paths: pad to tile multiples, TPU-minimum q tile of 8 rows
    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    bq_eff = max(min(bq, next_multiple(Sq, 8)), 8)
    bk_eff = min(bk, next_multiple(Skv, 128))
    Sq_p = next_multiple(Sq, bq_eff)
    Skv_p = next_multiple(Skv, bk_eff)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
    kvl = jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32)
    qo = jnp.asarray(0 if q_offset is None else q_offset, jnp.int32)
    out = flash_attention_pallas(
        qp, kp, vp, kvl, qo, causal=causal, window=window,
        softcap=float(softcap), scale=scale, bq=bq_eff, bk=bk_eff,
        interpret=(impl == "interpret"),
    )
    return out[:, :, :Sq]
