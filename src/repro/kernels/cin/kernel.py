"""Pallas TPU kernel for the CIN layer.

The naive lowering materializes Z[b, h, m, d] (B x H x m x D — at xdeepfm's
train_batch shape that is 65536 x 200 x 39 x 10 x 4B = 20 GB in HBM). The
kernel never materializes Z: per (batch row, d-tile) it forms the outer
product in VMEM as a [H*m, d_tile] pane and immediately compresses it with
the MXU against W_flat [H2, H*m]:

    out[b, :, dt] = W_flat @ (Xk[b, :, dt] (x) X0[b, :, dt])

VMEM working set = H*m x d_tile + W_flat, both far under 16 MB at the
assigned config (200*39*128*4 = 4 MB, 200*7800*4 = 6.2 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.compat import tpu_compiler_params


def _cin_kernel(x0_ref, xk_ref, w_ref, out_ref, *, m: int, h: int):
    # x0_ref [1, m, dt], xk_ref [1, h, dt], w_ref [h2, h*m], out [1, h2, dt]
    x0 = x0_ref[0].astype(jnp.float32)            # [m, dt]
    xk = xk_ref[0].astype(jnp.float32)            # [h, dt]
    dt = x0.shape[-1]
    # outer product pane: z[h*m, dt] = xk[h, dt] * x0[m, dt]
    z = (xk[:, None, :] * x0[None, :, :]).reshape(h * m, dt)
    out_ref[0] = jax.lax.dot_general(
        w_ref[...].astype(jnp.float32), z, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def cin_layer_pallas(
    x0: jnp.ndarray,     # [B, m, D]
    xk: jnp.ndarray,     # [B, H, D]
    w: jnp.ndarray,      # [H2, H, m]
    d_tile: int = 0,     # 0 -> whole D in one tile
    interpret: bool = False,
) -> jnp.ndarray:
    B, m, D = x0.shape
    H = xk.shape[1]
    H2 = w.shape[0]
    dt = d_tile or D
    assert D % dt == 0
    w_flat = w.reshape(H2, H * m)

    grid = (B, D // dt)
    # pallas: LM demo kernel — D % d_tile asserted above, tiles fixed by caller
    out = pl.pallas_call(
        functools.partial(_cin_kernel, m=m, h=H),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, dt), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, H, dt), lambda b, d: (b, 0, d)),
            pl.BlockSpec((H2, H * m), lambda b, d: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H2, dt), lambda b, d: (b, 0, d)),
        out_shape=jax.ShapeDtypeStruct((B, H2, D), x0.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
    )(x0, xk, w_flat)
    return out
