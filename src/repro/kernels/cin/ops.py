"""Public CIN entry point (jit'd dispatch + full-stack helper)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.cin.kernel import cin_layer_pallas
from repro.kernels.cin.ref import cin_layer_ref


def cin_layer(x0: jnp.ndarray, xk: jnp.ndarray, w: jnp.ndarray,
              impl: str = "ref", d_tile: int = 0) -> jnp.ndarray:
    if impl == "ref":
        return cin_layer_ref(x0, xk, w)
    return cin_layer_pallas(x0, xk, w, d_tile=d_tile,
                            interpret=(impl == "interpret"))


def cin(x0: jnp.ndarray, weights, impl: str = "ref") -> jnp.ndarray:
    """Full CIN stack with per-layer sum pooling -> [B, sum(H_k)]."""
    xk = x0
    pooled = []
    for w in weights:
        xk = cin_layer(x0, xk, w, impl=impl)
        pooled.append(xk.sum(axis=-1))
    return jnp.concatenate(pooled, axis=-1)
