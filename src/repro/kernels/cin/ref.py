"""Pure-jnp oracle for the xDeepFM Compressed Interaction Network layer.

One CIN layer (arXiv:1803.05170, Eq. 6):

  X^{k+1}[b, n, d] = sum_{h, m} W[n, h, m] * X^k[b, h, d] * X^0[b, m, d]

i.e. the field-wise outer product of the current hidden map with the base
embeddings, compressed along (h, m) by learned filters — a feature-map-sized
"convolution" along the embedding dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def cin_layer_ref(x0: jnp.ndarray, xk: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x0 [B, m, D], xk [B, H, D], w [H2, H, m] -> [B, H2, D]."""
    z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
    return jnp.einsum("bhmd,nhm->bnd", z, w)


def cin_ref(x0: jnp.ndarray, weights) -> jnp.ndarray:
    """Full CIN stack; returns the concatenated per-layer sum-pooling
    [B, sum(H_k)] used as the CIN logit features."""
    xk = x0
    pooled = []
    for w in weights:
        xk = cin_layer_ref(x0, xk, w)
        pooled.append(xk.sum(axis=-1))
    return jnp.concatenate(pooled, axis=-1)
