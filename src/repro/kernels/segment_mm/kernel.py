"""Pallas TPU kernel: fused scale + scatter-sum as an MXU matmul.

TPU adaptation of GE-SpMM-style gather-GEMM-scatter: the scatter-sum (which
would be a serial read-modify-write loop on the VPU) is restated as a
one-hot matmul on the systolic array:

    Y_tile [T, D] += onehot(dst_local) [T, E_B]  @  (coeff * X_src) [E_B, D]

Edges are destination-sorted and blocked so each edge block feeds exactly one
node tile (same layout contract as edge_relax); the output tile stays in VMEM
across its consecutive edge blocks. The gather X[src] is pre-staged by XLA
outside the kernel (TPU gathers from HBM are efficient; in-kernel per-row
indirection is not) — the kernel fuses everything after the gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.compat import tpu_compiler_params

NODE_TILE = 256
EDGE_BLOCK = 512


def _segment_mm_kernel(
    block_tile,             # scalar-prefetch int32 [n_blocks]
    xsrc_ref,               # [EDGE_BLOCK, D] pre-gathered rows
    coeff_ref,              # [1, EDGE_BLOCK]
    dst_ref,                # int32 [1, EDGE_BLOCK]
    y_ref,                  # [NODE_TILE, D] (revisited per tile)
    *, node_tile: int, edge_block: int,
):
    b = pl.program_id(0)
    tile = block_tile[b]
    first = jnp.where(b > 0, block_tile[jnp.maximum(b - 1, 0)] != tile, True)

    @pl.when(first)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    local = dst_ref[0] - tile * node_tile                        # [E]
    rows = jax.lax.broadcasted_iota(jnp.int32, (node_tile, edge_block), 0)
    onehot = (local[None, :] == rows).astype(jnp.float32)        # [T, E]
    msgs = xsrc_ref[...].astype(jnp.float32) * coeff_ref[0][:, None]
    y_ref[...] += jax.lax.dot_general(
        onehot, msgs, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_tiles", "node_tile", "edge_block", "interpret")
)
def segment_mm_pallas(
    x_src: jnp.ndarray,       # [n_blocks*E_B, D] pre-gathered X[src]
    coeff: jnp.ndarray,       # [n_blocks, E_B] (0 on padding edges)
    dst: jnp.ndarray,         # int32 [n_blocks, E_B]
    block_tile: jnp.ndarray,  # int32 [n_blocks]
    n_tiles: int,
    node_tile: int = NODE_TILE,
    edge_block: int = EDGE_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    n_blocks = coeff.shape[0]
    d = x_src.shape[-1]
    x_src = x_src.reshape(n_blocks * edge_block, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((edge_block, d), lambda b, bt: (b, 0)),
            pl.BlockSpec((1, edge_block), lambda b, bt: (b, 0)),
            pl.BlockSpec((1, edge_block), lambda b, bt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((node_tile, d), lambda b, bt: (bt[b], 0)),
    )
    kern = functools.partial(
        _segment_mm_kernel, node_tile=node_tile, edge_block=edge_block
    )
    # pallas: tiles validated by edge_relax.validate_tiling in the calling backend
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles * node_tile, d), x_src.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
    )(block_tile, x_src, coeff, dst)
