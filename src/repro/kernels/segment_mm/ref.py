"""Pure-jnp oracle for the GNN gather-scale-scatter primitive.

  Y[n, :] = sum over edges e with dst[e] == n of coeff[e] * X[src[e], :]

This is message passing (SpMM with per-edge scalar coefficients: GCN's
normalized adjacency, GatedGCN's gates reduce to it per channel-group,
MeshGraphNet's sum-aggregation has coeff = 1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("n_nodes",))
def segment_mm_ref(
    x: jnp.ndarray,       # [N, D] node features
    src: jnp.ndarray,     # int32 [E]
    dst: jnp.ndarray,     # int32 [E]
    coeff: jnp.ndarray,   # float [E]
    n_nodes: int,
) -> jnp.ndarray:
    msgs = x[src] * coeff[:, None]
    return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
