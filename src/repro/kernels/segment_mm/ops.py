"""Public wrapper for segment_mm: message passing over blocked edges.

``segment_mm(x, src, dst, coeff, n_nodes, impl=...)`` accepts flat edge
arrays (any order). "pallas"/"interpret" re-block destination-sorted on the
host at trace time if given numpy inputs, otherwise callers pre-block with
``block_edges_for_mm`` and call ``segment_mm_blocked``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.edge_relax.ops import block_edges_host
from repro.kernels.segment_mm.kernel import (
    EDGE_BLOCK,
    NODE_TILE,
    segment_mm_pallas,
)
from repro.kernels.segment_mm.ref import segment_mm_ref


def block_edges_for_mm(src, dst, n_nodes, node_tile=NODE_TILE, edge_block=EDGE_BLOCK):
    """Host-side blocking (reuses edge_relax layout; returns permutation so
    callers can reorder per-edge coefficients to match)."""
    order = np.lexsort((src, dst))
    blk = block_edges_host(
        np.asarray(src)[order], np.asarray(dst)[order], np.ones(len(src), np.int32),
        n_nodes, node_tile, edge_block,
    )
    blk["perm"] = order
    return blk


@partial(jax.jit, static_argnames=("n_tiles", "node_tile", "edge_block", "interpret"))
def segment_mm_blocked(
    x, blocked_src, blocked_dst, blocked_coeff, block_tile,
    n_tiles, node_tile=NODE_TILE, edge_block=EDGE_BLOCK, interpret=False,
):
    x_src = x[blocked_src.reshape(-1)]
    return segment_mm_pallas(
        x_src, blocked_coeff, blocked_dst, block_tile,
        n_tiles=n_tiles, node_tile=node_tile, edge_block=edge_block,
        interpret=interpret,
    )


def segment_mm(x, src, dst, coeff, n_nodes, impl: str = "ref",
               node_tile=NODE_TILE, edge_block=EDGE_BLOCK):
    if impl == "ref":
        return segment_mm_ref(x, src, dst, coeff, n_nodes)
    blk = block_edges_for_mm(np.asarray(src), np.asarray(dst), n_nodes,
                             node_tile, edge_block)
    coeff_np = np.asarray(coeff)[blk["perm"]]
    cb = np.zeros(blk["src"].shape, np.float32)
    cb[blk["mask"] == 1] = coeff_np
    y = segment_mm_blocked(
        jnp.asarray(x), jnp.asarray(blk["src"]), jnp.asarray(blk["dst"]),
        jnp.asarray(cb), jnp.asarray(blk["block_tile"]), blk["n_tiles"],
        node_tile, edge_block, interpret=(impl == "interpret"),
    )
    return y[:n_nodes]
