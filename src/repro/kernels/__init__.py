"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel subpackage has: kernel.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd dispatching wrapper), ref.py (pure-jnp oracle). All validated
in interpret mode on CPU; `impl="pallas"` targets real TPUs.

  edge_relax      the paper's hot spot: fused Delta-growing relax + lexicographic
                  (d, c, pathw) tuple-min in one HBM pass
  flash_attention online-softmax attention w/ GQA + sliding-window + softcap
  segment_mm      GNN message passing: scatter-sum as one-hot MXU matmul
  cin             xDeepFM compressed interaction without materializing Z
"""
from repro.kernels.edge_relax.ops import edge_relax, block_edges_host
from repro.kernels.flash_attention.ops import attention, attention_blocked
from repro.kernels.segment_mm.ops import segment_mm
from repro.kernels.cin.ops import cin, cin_layer

__all__ = [
    "edge_relax",
    "block_edges_host",
    "attention",
    "attention_blocked",
    "segment_mm",
    "cin",
    "cin_layer",
]
