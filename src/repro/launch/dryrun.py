import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--smoke-scale]
  PYTHONPATH=src python -m repro.launch.dryrun --engine          # paper engine row
                                  # (the ShardedBackend superstep — one MR round)

Each cell: jit(step, in_shardings=..., out_shardings=...).lower(*specs)
.compile(); prints memory_analysis() (fits-per-device proof) and
cost_analysis() (FLOPs/bytes for §Roofline); appends a JSON row to
--out (default /root/repo/results/dryrun.jsonl).

(No `from __future__ import annotations` here: the XLA_FLAGS lines must be
the first statements in the file, which PEP 236 forbids to combine.)
"""
import argparse
import json
import sys
import traceback

import jax
import numpy as np

from repro.config.registry import get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import all_cells, build_cell
from repro.runtime.roofline import analyze
from repro.runtime.telemetry import clock

RESULTS = "/root/repo/results/dryrun.jsonl"


def run_cell(arch: str, shape: str, multi_pod: bool, out_path: str,
             smoke: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    t0 = clock()
    cell = build_cell(arch, shape, mesh, smoke=smoke)
    with mesh:
        jitted = jax.jit(
            cell.step_fn,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.arg_specs)
        t_lower = clock() - t0
        compiled = lowered.compile()
        t_compile = clock() - t0 - t_lower

    mem = compiled.memory_analysis()
    rep = analyze(f"{arch}/{shape}", lowered, compiled, n_chips,
                  model_flops=cell.model_flops)
    row = rep.row()
    row.update({
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "multi_pod": multi_pod,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "note": cell.note,
        "ok": True,
    })
    try:
        row["arg_bytes_per_dev"] = int(mem.argument_size_in_bytes)
        row["temp_bytes_per_dev"] = int(mem.temp_size_in_bytes)
        row["output_bytes_per_dev"] = int(mem.output_size_in_bytes)
    except Exception:
        pass
    print("memory_analysis:", mem)
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    print("cost_analysis: flops=%.3e bytes=%.3e" % (
        float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0))))
    print(json.dumps(row))
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "a") as f:
            f.write(json.dumps(row) + "\n")
    return row


def run_engine(multi_pod: bool, out_path: str) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    cell = build_cell("paper-graph", "", mesh)
    t0 = clock()
    with mesh:
        lowered = jax.jit(cell.step_fn).lower(*cell.arg_specs)
        compiled = lowered.compile()
    rep = analyze(f"paper-graph/{cell.shape}", lowered, compiled, n_chips)
    row = rep.row()
    row.update({
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "multi_pod": multi_pod, "compile_s": round(clock() - t0, 1),
        "note": cell.note, "ok": True,
    })
    print("memory_analysis:", compiled.memory_analysis())
    print(json.dumps(row))
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "a") as f:
            f.write(json.dumps(row) + "\n")
    return row


def main() -> int:  # noqa: C901
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--engine", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke-scale", action="store_true",
                    help="reduced configs (CI sanity of the dry-run path)")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()

    if args.engine:
        run_engine(args.multi_pod, args.out)
        return 0

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    failures = []
    for arch, shape in cells:
        print(f"=== {arch} / {shape} (multi_pod={args.multi_pod}) ===",
              flush=True)
        try:
            run_cell(arch, shape, args.multi_pod, args.out,
                     smoke=args.smoke_scale)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
            if args.out:
                os.makedirs(os.path.dirname(args.out), exist_ok=True)
                with open(args.out, "a") as f:
                    f.write(json.dumps({
                        "name": f"{arch}/{shape}", "ok": False,
                        "multi_pod": args.multi_pod, "error": repr(e)[:500],
                    }) + "\n")
    if failures:
        print("FAILURES:", failures)
        return 1
    print("dry-run complete: all cells compiled")
    return 0


if __name__ == "__main__":
    sys.exit(main())
