"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — launch/dryrun.py must set XLA_FLAGS before any
jax initialization.

  single pod : (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use small CPU meshes like (2, 2))."""
    return jax.make_mesh(shape, axes)


def host_device_mesh(n: Optional[int] = None, axes=("data", "model")):
    """Best-effort mesh over whatever devices exist (CPU tests)."""
    n = n or len(jax.devices())
    a = 1
    while (a * 2) * (a * 2) <= n * 4 and a * a < n:
        a *= 2
    a = min(a, n)
    return jax.make_mesh((a, n // a), axes)
