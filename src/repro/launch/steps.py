"""Step builders + input_specs for every (arch x shape) cell.

`build_cell(arch_name, shape_name, mesh, smoke=False)` returns a `Cell`:
  step_fn        the function to lower (train_step / serve_step)
  arg_specs      ShapeDtypeStructs WITH NamedShardings (no allocation)
  out_shardings  sharding tree for outputs (or None)
  model_flops    analytic useful FLOPs (6ND for LM; 0 where n/a)
  donate         argnums to donate

This module is the single source of truth for what the dry-run lowers and
for what train.py/serve.py execute — the smoke tests run the same step_fn
with real (tiny) arrays.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import (
    GNNConfig,
    GraphEngineConfig,
    MoEConfig,
    RecsysConfig,
    ShapeSpec,
    TrainConfig,
    TransformerConfig,
    shapes_for_family,
)
from repro.config.registry import get_arch
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tf_mod
from repro.optim import adamw
from repro.runtime import sharding as shrules

SDS = jax.ShapeDtypeStruct


@dataclass
class Cell:
    arch: str
    shape: str
    step_fn: Callable
    arg_specs: Tuple[Any, ...]
    out_shardings: Any
    model_flops: float
    donate: Tuple[int, ...] = ()
    note: str = ""


def _sds(tree_shapes, shard_tree, mesh):
    """ShapeDtypeStruct tree with NamedShardings attached."""
    named = shrules.named(mesh, shard_tree)

    def mk(sh, sd):
        return SDS(sh.shape, sh.dtype, sharding=sd)

    return jax.tree.map(mk, tree_shapes, named)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_model_flops(cfg, shape: ShapeSpec, kind: str) -> float:
    n = cfg.active_param_count() if isinstance(cfg, MoEConfig) else cfg.param_count()
    if kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def _lm_cell(cfg: TransformerConfig, shape: ShapeSpec, mesh: Mesh,
             train_cfg: TrainConfig) -> Cell:
    tf_mod.MOE_A2A = None
    if isinstance(cfg, MoEConfig):
        # explicit-a2a EP for train/prefill (decode token counts are below
        # the chip count; those cells keep the GSPMD dispatch)
        n_chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        M = mesh.shape["model"]
        T = shape.seq_len * shape.global_batch
        if (shape.kind in ("train", "prefill") and T % n_chips == 0
                and (cfg.n_experts % M == 0 or M % cfg.n_experts == 0)):
            tf_mod.MOE_A2A = (mesh, cfg.capacity_factor)
    if isinstance(cfg, MoEConfig):
        # group-local MoE dispatch: one group per DP shard; pin the dispatch
        # buffers G->data, E->model (EP) or unsharded E for the f-TP fallback
        n_dp = int(np.prod([mesh.shape[a] for a in shrules.data_axes(mesh)]))
        cfg = dataclasses.replace(cfg, moe_groups=n_dp)
        d_ax = shrules.data_axes(mesh)
        e_ax = "model" if cfg.n_experts % mesh.shape["model"] == 0 else None
        if tf_mod.MOE_A2A is not None:
            # a2a path: tokens stay sequence-sharded over 'model' so the
            # shard_map boundary is a zero-copy split on both sides. On the
            # 3-axis pod mesh the exit must ALSO be pinned or GSPMD
            # back-propagates a 256-way-B x 2-way-S layout into attention
            # (involuntary remat); on the 2-axis mesh that pin costs an
            # extra reshard, so it is pod-only.
            tf_mod.MOE_CONSTRAINTS = {"h": P(d_ax, "model", None)}
            if "pod" in mesh.axis_names:
                tf_mod.MOE_CONSTRAINTS["moe_out"] = P(d_ax, "model", None)
        else:
            tf_mod.MOE_CONSTRAINTS = {
                "h": P(d_ax, None, None),
                "h_tok": P(d_ax, None, None),
                "x_disp": P(d_ax, e_ax, None, None),
                "y": P(d_ax, e_ax, None, None),
            }
    else:
        tf_mod.MOE_CONSTRAINTS = {}
    pspecs = shrules.lm_param_specs(cfg, mesh)
    pshapes = jax.eval_shape(partial(tf_mod.init_params, cfg),
                             jax.random.PRNGKey(0))
    params_sds = _sds(pshapes, pspecs, mesh)

    if shape.kind == "train":
        oshapes = jax.eval_shape(adamw.init_state, pshapes)
        ospecs = (
            adamw.zero1_state_specs(pspecs, pshapes,
                                    axis_size=mesh.shape["data"])
            if train_cfg.zero1 else pspecs
        )
        opt_sds = adamw.AdamWState(
            m=_sds(oshapes.m, ospecs, mesh),
            v=_sds(oshapes.v, ospecs, mesh),
            step=SDS((), jnp.int32, sharding=shrules.replicated(mesh)),
        )
        bspecs = shrules.lm_batch_specs(mesh)
        B, S = shape.global_batch, shape.seq_len
        batch_sds = _sds(
            {"tokens": SDS((B, S), jnp.int32), "labels": SDS((B, S), jnp.int32)},
            bspecs, mesh,
        )

        act_spec = P(shrules.data_axes(mesh), "model", None)

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(tf_mod.lm_loss)(
                params, batch, cfg, act_spec=act_spec)
            params, opt, stats = adamw.apply_updates(params, opt, grads, train_cfg)
            return params, opt, loss, stats

        out_sh = (
            shrules.named(mesh, pspecs),
            adamw.AdamWState(
                m=shrules.named(mesh, ospecs), v=shrules.named(mesh, ospecs),
                step=shrules.replicated(mesh),
            ),
            shrules.replicated(mesh),
            {"grad_norm": shrules.replicated(mesh), "lr": shrules.replicated(mesh)},
        )
        return Cell(cfg.name, shape.name, train_step,
                    (params_sds, opt_sds, batch_sds), out_sh,
                    _lm_model_flops(cfg, shape, "train"), donate=(0, 1))

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        batch_sds = _sds({"tokens": SDS((B, S), jnp.int32)},
                         {"tokens": P(shrules.data_axes(mesh), None)}, mesh)

        act_spec = P(shrules.data_axes(mesh), "model", None)

        def serve_prefill(params, batch):
            return tf_mod.prefill_step(params, batch["tokens"], cfg,
                                       act_spec=act_spec)

        return Cell(cfg.name, shape.name, serve_prefill,
                    (params_sds, batch_sds), None,
                    _lm_model_flops(cfg, shape, "prefill"))

    # decode: one new token against a kv cache of shape.seq_len
    B, S = shape.global_batch, shape.seq_len
    cshapes = jax.eval_shape(partial(tf_mod.init_cache, cfg, B, S))
    cspecs = shrules.lm_cache_specs(cfg, mesh, B)
    cache_sds = _sds(cshapes, cspecs, mesh)
    tok_sds = _sds({"t": SDS((B, 1), jnp.int32)},
                   {"t": P(shrules.data_axes(mesh) if B > 1 else None, None)},
                   mesh)["t"]

    def serve_decode(params, cache, tok):
        # steady-state decode: cache already holds seq_len-1 tokens
        cache = dict(cache, len=jnp.int32(S - 1))
        logits, new_cache = tf_mod.decode_step(params, cache, tok, cfg)
        return logits, new_cache

    out_sh = (shrules.replicated(mesh), shrules.named(mesh, cspecs))
    return Cell(cfg.name, shape.name, serve_decode,
                (params_sds, cache_sds, tok_sds), out_sh,
                _lm_model_flops(cfg, shape, "decode"), donate=(1,),
                note="decode against %d-token cache" % S)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

_GNN_EDGE_DIM = {"gatedgcn": 1, "meshgraphnet": 4}


def _gnn_graph_sds(cfg: GNNConfig, shape: ShapeSpec, mesh: Mesh,
                   pad_nodes: int, pad_edges: int):
    flat = shrules.flat_axes(mesh)
    d_feat = shape.d_feat or 32
    tree = {
        "x": SDS((pad_nodes, d_feat), jnp.float32),
        "src": SDS((pad_edges,), jnp.int32),
        "dst": SDS((pad_edges,), jnp.int32),
        "labels": SDS((pad_nodes,), jnp.int32),
    }
    spec = {
        "x": P(flat, None), "src": P(flat), "dst": P(flat), "labels": P(flat),
    }
    if cfg.kind == "equiformer_v2":
        tree["pos"] = SDS((pad_nodes, 3), jnp.float32)
        spec["pos"] = P(flat, None)
    if cfg.kind in _GNN_EDGE_DIM:
        tree["e"] = SDS((pad_edges, _GNN_EDGE_DIM[cfg.kind]), jnp.float32)
        spec["e"] = P(flat, None)
    return tree, spec


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _gnn_cell(cfg: GNNConfig, shape: ShapeSpec, mesh: Mesh,
              train_cfg: TrainConfig) -> Cell:
    n_dev = int(np.prod(list(mesh.shape.values())))
    d_feat = shape.d_feat or 32

    pshapes = jax.eval_shape(
        partial(gnn_mod.init_gnn, cfg, d_feat,
                d_edge_in=_GNN_EDGE_DIM.get(cfg.kind, 1)),
        jax.random.PRNGKey(0),
    )
    pspecs = shrules.gnn_param_specs(pshapes, mesh)
    params_sds = _sds(pshapes, pspecs, mesh)

    opt_shapes = jax.eval_shape(adamw.init_state, pshapes)
    opt_sds = adamw.AdamWState(
        m=_sds(opt_shapes.m, pspecs, mesh),
        v=_sds(opt_shapes.v, pspecs, mesh),
        step=SDS((), jnp.int32, sharding=shrules.replicated(mesh)),
    )

    if shape.kind == "batched_graphs":
        N = shape.n_graphs * shape.n_nodes
        E = shape.n_graphs * shape.n_edges
        pad_n, pad_e = _round_up(N, n_dev), _round_up(E, n_dev)
        tree, spec = _gnn_graph_sds(cfg, shape, mesh, pad_n, pad_e)
        flat = shrules.flat_axes(mesh)
        tree["graph_id"] = SDS((pad_n,), jnp.int32)
        tree["targets"] = SDS((shape.n_graphs, cfg.d_out), jnp.float32)
        spec["graph_id"] = P(flat)
        spec["targets"] = P(None, None)
        loss_fn = gnn_mod.graph_regression_loss
    elif shape.kind == "minibatch":
        # padded sampled-block sizes from (batch_nodes, fanout)
        b = shape.batch_nodes
        f1, f0 = shape.fanout
        n1 = b * (f1 + 1)
        n0 = n1 * (f0 + 1)
        pad_n = _round_up(n0, n_dev)
        pad_e = _round_up(n1 * f0 + b * f1, n_dev)
        tree, spec = _gnn_graph_sds(cfg, shape, mesh, pad_n, pad_e)
        flat = shrules.flat_axes(mesh)
        tree["seed_slots"] = SDS((b,), jnp.int32)
        spec["seed_slots"] = P(flat)
        loss_fn = gnn_mod.node_classification_loss
    else:  # full_graph
        pad_n = _round_up(shape.n_nodes, n_dev)
        pad_e = _round_up(shape.n_edges, n_dev)
        tree, spec = _gnn_graph_sds(cfg, shape, mesh, pad_n, pad_e)
        loss_fn = gnn_mod.node_classification_loss

    graph_sds = _sds(tree, spec, mesh)

    def train_step(params, opt, graph):
        loss, grads = jax.value_and_grad(loss_fn)(params, graph, cfg)
        params, opt, stats = adamw.apply_updates(params, opt, grads, train_cfg)
        return params, opt, loss, stats

    out_sh = (
        shrules.named(mesh, pspecs),
        adamw.AdamWState(m=shrules.named(mesh, pspecs),
                         v=shrules.named(mesh, pspecs),
                         step=shrules.replicated(mesh)),
        shrules.replicated(mesh),
        {"grad_norm": shrules.replicated(mesh), "lr": shrules.replicated(mesh)},
    )
    return Cell(cfg.name, shape.name, train_step,
                (params_sds, opt_sds, graph_sds), out_sh, 0.0, donate=(0, 1))


# ---------------------------------------------------------------------------
# Recsys cells
# ---------------------------------------------------------------------------

def _recsys_cell(cfg: RecsysConfig, shape: ShapeSpec, mesh: Mesh,
                 train_cfg: TrainConfig) -> Cell:
    pshapes = jax.eval_shape(partial(recsys_mod.init_params, cfg),
                             jax.random.PRNGKey(0))
    pspecs = shrules.recsys_param_specs(cfg, mesh)
    params_sds = _sds(pshapes, pspecs, mesh)
    d = shrules.data_axes(mesh)
    bag = max(cfg.multi_hot, 1)

    if shape.kind == "recsys_train":
        B = shape.batch
        bspec = shrules.recsys_batch_specs(mesh)
        batch_sds = _sds(
            {
                "ids": SDS((B, cfg.n_sparse, bag), jnp.int32),
                "id_mask": SDS((B, cfg.n_sparse, bag), jnp.float32),
                "dense": SDS((B, cfg.n_dense), jnp.float32),
                "labels": SDS((B,), jnp.int32),
            },
            bspec, mesh,
        )
        oshapes = jax.eval_shape(adamw.init_state, pshapes)
        ospecs = (
            adamw.zero1_state_specs(pspecs, pshapes,
                                    axis_size=mesh.shape["data"])
            if train_cfg.zero1 else pspecs
        )
        opt_sds = adamw.AdamWState(
            m=_sds(oshapes.m, ospecs, mesh),
            v=_sds(oshapes.v, ospecs, mesh),
            step=SDS((), jnp.int32, sharding=shrules.replicated(mesh)),
        )

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(recsys_mod.bce_loss)(params, batch, cfg)
            params, opt, stats = adamw.apply_updates(params, opt, grads, train_cfg)
            return params, opt, loss, stats

        out_sh = (
            shrules.named(mesh, pspecs),
            adamw.AdamWState(m=shrules.named(mesh, ospecs),
                             v=shrules.named(mesh, ospecs),
                             step=shrules.replicated(mesh)),
            shrules.replicated(mesh),
            {"grad_norm": shrules.replicated(mesh), "lr": shrules.replicated(mesh)},
        )
        return Cell(cfg.name, shape.name, train_step,
                    (params_sds, opt_sds, batch_sds), out_sh, 0.0, donate=(0, 1))

    if shape.kind == "recsys_serve":
        B = shape.batch
        batch_sds = _sds(
            {
                "ids": SDS((B, cfg.n_sparse, bag), jnp.int32),
                "id_mask": SDS((B, cfg.n_sparse, bag), jnp.float32),
                "dense": SDS((B, cfg.n_dense), jnp.float32),
            },
            {"ids": P(d, None, None), "id_mask": P(d, None, None),
             "dense": P(d, None)},
            mesh,
        )

        def serve(params, batch):
            return recsys_mod.forward(params, batch, cfg)

        return Cell(cfg.name, shape.name, serve,
                    (params_sds, batch_sds), None, 0.0)

    # retrieval: 1 query x n_candidates. Candidates shard over the data
    # axes only (1e6 divides 16/32 but not 256); the model axis is busy
    # row-sharding the embedding tables the candidate gather hits.
    C = shape.n_candidates
    fu = cfg.n_sparse // 3              # user fields
    fi = cfg.n_sparse - fu              # item fields per candidate
    flat = shrules.data_axes(mesh)
    q_sds = _sds(
        {
            "user_ids": SDS((1, fu, bag), jnp.int32),
            "user_mask": SDS((1, fu, bag), jnp.float32),
            "user_dense": SDS((1, cfg.n_dense), jnp.float32),
            "cand_ids": SDS((C, fi, bag), jnp.int32),
            "cand_mask": SDS((C, fi, bag), jnp.float32),
        },
        {
            "user_ids": P(None, None, None), "user_mask": P(None, None, None),
            "user_dense": P(None, None),
            "cand_ids": P(flat, None, None), "cand_mask": P(flat, None, None),
        },
        mesh,
    )

    # retrieval reuses a reduced-field forward: user fields + item fields
    rcfg = dataclasses.replace(cfg, n_sparse=fu + fi)

    def retrieval(params, q):
        return recsys_mod.retrieval_scores(
            params, q["user_ids"], q["user_mask"], q["user_dense"],
            q["cand_ids"], q["cand_mask"], rcfg,
        )

    return Cell(cfg.name, shape.name, retrieval, (params_sds, q_sds),
                None, 0.0, note=f"1 query x {C} candidates")


# ---------------------------------------------------------------------------
# paper engine cell (extra row beyond the 40)
# ---------------------------------------------------------------------------

def _engine_cell(cfg: GraphEngineConfig, mesh: Mesh, n_nodes: int = 1 << 24,
                 avg_degree: int = 5) -> Cell:
    """One Δ-growing superstep on a roads-USA-scale synthetic graph.

    This is the inner step of the ShardedBackend (core/backend.py): the
    decomposition engine keeps the canonical planes device-resident and runs
    this superstep inside a while_loop, so the lowered collective profile
    here is exactly the per-MR-round cost of a production run."""
    from repro.core.distributed import DistributedEngine
    from repro.graph.structures import EdgeList

    n_dev = int(np.prod(list(mesh.shape.values())))
    n = _round_up(n_nodes, n_dev)
    e_loc = _round_up(n_nodes * avg_degree // n_dev, 8)

    # build a tiny host-side plan, then OVERRIDE shapes to the target scale
    # (shard_graph on 2^24 nodes host-side is feasible but slow; the dry-run
    # only needs shapes) — we fabricate the ShardedGraph geometry directly.
    import jax.numpy as jnp
    from repro.core import distributed as dist

    eng = object.__new__(DistributedEngine)
    eng.mesh = mesh
    eng.axes = tuple(mesh.axis_names)
    eng.n_devices = n_dev
    eng.comm = "allgather"
    eng.graph = dist.ShardedGraph(
        n_nodes=n, n_pad=n, n_devices=n_dev,
        src=None, dst_local=None, weight=None, edge_mask=None,
    )
    # shapes only — arrays never touched in lower()
    eng.graph.src = SDS((n_dev, e_loc), jnp.int32)
    eng.graph.dst_local = SDS((n_dev, e_loc), jnp.int32)
    eng.graph.weight = SDS((n_dev, e_loc), jnp.int32)
    eng.graph.edge_mask = SDS((n_dev, e_loc), jnp.bool_)
    eng.q = n // n_dev
    eng._step = eng._build_superstep()

    ns = NamedSharding(mesh, P(eng.axes))
    es = NamedSharding(mesh, P(eng.axes, None))
    planes = tuple(
        SDS((n,), jnp.bool_ if i == 6 else jnp.int32, sharding=ns)
        for i in range(7)
    )
    gparts = tuple(
        SDS((n_dev, e_loc), dt, sharding=es)
        for dt in (jnp.int32, jnp.int32, jnp.int32, jnp.bool_)
    )

    def superstep(planes, gparts):
        return eng._step(planes, gparts, jnp.int32(1 << 20))

    return Cell("paper-graph", f"n{n_nodes>>20}M", superstep,
                (planes, gparts), None, 0.0,
                note="one Delta-growing superstep (1 MR round)")


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh: Mesh, smoke: bool = False,
               train_cfg: Optional[TrainConfig] = None) -> Cell:
    cfg = get_arch(arch, smoke=smoke)
    train_cfg = train_cfg or TrainConfig()
    if isinstance(cfg, GraphEngineConfig):
        return _engine_cell(cfg, mesh)
    shapes = {s.name: s for s in shapes_for_family(cfg.family)}
    shape = shapes[shape_name]
    if isinstance(cfg, TransformerConfig):  # MoEConfig subclasses it
        return _lm_cell(cfg, shape, mesh, train_cfg)
    if isinstance(cfg, GNNConfig):
        return _gnn_cell(cfg, shape, mesh, train_cfg)
    if isinstance(cfg, RecsysConfig):
        return _recsys_cell(cfg, shape, mesh, train_cfg)
    raise TypeError(type(cfg))


def all_cells() -> Tuple[Tuple[str, str], ...]:
    """The 40 assigned (arch, shape) pairs."""
    out = []
    for arch in (
        "gemma2-9b", "qwen1.5-32b", "mistral-nemo-12b", "moonshot-v1-16b-a3b",
        "mixtral-8x7b",
        "gcn-cora", "gatedgcn", "meshgraphnet", "equiformer-v2",
        "xdeepfm",
    ):
        cfg = get_arch(arch)
        for s in shapes_for_family(cfg.family):
            out.append((arch, s.name))
    return tuple(out)
