"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --smoke \
      --steps 50 --mesh 1x1 [--resume] [--grad-compression int8_ef]

Production semantics on any mesh size (the CPU container runs 1x1 or fake
multi-device): sharded params/opt state via the same specs the dry-run
proves, checkpoint/restart with data-cursor replay, preemption-safe exit,
straggler logging, optional int8 error-feedback gradient compression.
"""
from __future__ import annotations

import argparse
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.config.base import ShapeSpec, TrainConfig, TransformerConfig
from repro.config.registry import get_arch
from repro.common import Timer, get_logger
from repro.data.pipeline import DataCursor, LMTokenPipeline
from repro.launch.mesh import host_device_mesh, make_mesh
from repro.models import transformer as tf_mod
from repro.optim import adamw
from repro.runtime import sharding as shrules
from repro.runtime.compression import ef_compress_grads, init_residual
from repro.runtime.fault import PreemptionGuard, StragglerMonitor
from repro.runtime.telemetry import clock

log = get_logger("repro.train")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1", help="DxM e.g. 4x2")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    assert isinstance(cfg, TransformerConfig), "train.py drives LM archs"
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    tc = TrainConfig(steps=args.steps, lr=args.lr,
                     checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=args.ckpt_every)
    shape = ShapeSpec(name="cli", kind="train", seq_len=args.seq_len,
                      global_batch=args.batch)

    pspecs = shrules.lm_param_specs(cfg, mesh)
    with mesh:
        params = jax.jit(
            partial(tf_mod.init_params, cfg),
            out_shardings=shrules.named(mesh, pspecs),
        )(jax.random.PRNGKey(tc.seed))
    opt = adamw.init_state(params)
    residual = init_residual(params) if args.grad_compression == "int8_ef" else None
    pipe = LMTokenPipeline(cfg, shape, seed=tc.seed)
    cursor = DataCursor()

    if args.resume and ckpt.latest_step(tc.checkpoint_dir) is not None:
        state_like = {"params": params, "m": opt.m, "v": opt.v}
        restored, extra = ckpt.restore(tc.checkpoint_dir, state_like)
        params, opt = restored["params"], adamw.AdamWState(
            m=restored["m"], v=restored["v"],
            step=jnp.int32(extra.get("opt_step", 0)))
        cursor = DataCursor.from_dict(extra.get("cursor", {}))
        log.info("resumed at data step %d (opt step %d)",
                 cursor.step, int(opt.step))

    use_ef = args.grad_compression == "int8_ef"

    @partial(jax.jit, donate_argnums=(0, 1))
    def train_step(params, opt, batch, residual):
        loss, grads = jax.value_and_grad(tf_mod.lm_loss)(params, batch, cfg)
        if use_ef:
            q, s, residual = ef_compress_grads(grads, residual)
            grads = jax.tree.map(
                lambda qq, ss: qq.astype(jnp.float32) * ss, q, s)
        params, opt, stats = adamw.apply_updates(params, opt, grads, tc,
                                                 total_steps=args.steps)
        return params, opt, loss, stats, residual

    mon = StragglerMonitor()
    t_start = clock()
    with PreemptionGuard() as guard, mesh:
        while cursor.step < args.steps:
            batch_np = pipe.batch(cursor)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            with Timer() as t:
                params, opt, loss, stats, residual = train_step(
                    params, opt, batch, residual)
                jax.block_until_ready(loss)
            mon.record(cursor.step, t.seconds)
            cursor.step += 1
            if cursor.step % args.log_every == 0:
                tok_s = args.batch * args.seq_len / max(t.seconds, 1e-9)
                # sync: LM train log line, gated by --log-every
                log.info("step %d loss %.4f gnorm %.3f lr %.2e  %.0f tok/s",
                         cursor.step, float(loss), float(stats["grad_norm"]),  # sync: see above
                         float(stats["lr"]), tok_s)
            if cursor.step % tc.checkpoint_every == 0 or guard.should_stop:
                ckpt.save(tc.checkpoint_dir, cursor.step,
                          {"params": params, "m": opt.m, "v": opt.v},
                          extra={"cursor": cursor.as_dict(),
                                 # sync: checkpoint manifest scalar
                                 "opt_step": int(opt.step)},
                          keep=tc.keep_checkpoints)
            if guard.should_stop:
                log.warning("preempted: checkpointed at step %d, exiting",
                            cursor.step)
                return 0
    log.info("done: %d steps in %.1fs; stragglers flagged: %s",
             args.steps, clock() - t_start, mon.flagged)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
