import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import.

"""Exact roofline terms for the LM cells via the layer-marginal fit.

XLA's cost_analysis counts a lax.scan body ONCE, so the compile-proof
lowering (scan over 42-64 layers) undercounts FLOPs/bytes/collectives.
This probe lowers each LM cell UNROLLED (scan_layers=False, kv-block loops
unrolled, loss in one chunk) at n_layers = 2 and 4, and fits

    quantity(L) = base + marginal * L / <probe is exact: no loops left>

so  total(L_full) = base + marginal * L_full.  Probes use an even layer
count so alternating-window archs contribute one local + one global layer
per marginal pair. GNN / recsys / engine cells have no loops in their HLO —
their dry-run rows are already exact and are copied through.

  PYTHONPATH=src python -m repro.launch.roofline_fit [--multi-pod]
      [--arch gemma2-9b --shape train_4k]

Appends rows to results/roofline.jsonl.
"""
import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

import repro.kernels.flash_attention.ops as attn_ops
from repro.config.registry import get_arch
from repro.config.base import MoEConfig, TransformerConfig, shapes_for_family
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import _lm_model_flops, all_cells, build_cell
from repro.runtime.roofline import (
    HBM_BW, ICI_BW, PEAK_FLOPS, analyze, parse_collectives,
)
from repro.runtime.telemetry import clock

RESULTS = "/root/repo/results/roofline.jsonl"


def _probe_cfg(cfg, L):
    return dataclasses.replace(
        cfg, n_layers=L, scan_layers=False, loss_chunks=1,
    )


def _measure(arch, shape, mesh, cfg_override):
    """Lower+compile one probe; return (flops, bytes, coll_wire, counts)."""
    import repro.config.registry as registry

    name = cfg_override.name

    def fake_factory():
        return cfg_override

    # temporarily register the override under the arch name
    old = registry._REGISTRY.get(arch)
    registry._REGISTRY[arch] = fake_factory
    try:
        cell = build_cell(arch, shape, mesh)
    finally:
        if old is not None:
            registry._REGISTRY[arch] = old
    with mesh:
        lowered = jax.jit(
            cell.step_fn, out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        ).lower(*cell.arg_specs)
        compiled = lowered.compile()
    n_chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    rep = analyze("probe", lowered, compiled, n_chips)
    return (rep.hlo_flops, rep.hlo_bytes, rep.collective.wire_bytes,
            rep.collective.counts, cell.model_flops)


def _lm_hbm_bytes(cfg, shape, n_chips):
    """Analytic HBM traffic per step, global bytes — the fusion-aware
    counterpart of cost_analysis's unfused 'bytes accessed' (which counts
    every VMEM-resident flash/MoE tile as HBM): params read for fwd + bwd
    recompute + optimizer read/write, activation carries saved + reloaded,
    KV cache traffic for decode. Formulas in EXPERIMENTS.md §Roofline."""
    pbytes = cfg.param_count() * 2                      # bf16
    opt = cfg.param_count() * 4 * 2 * 2                 # m,v f32 read+write
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        carries = tokens * cfg.d_model * 2 * cfg.n_layers * 2   # save + load
        streams = tokens * cfg.d_model * 2 * cfg.n_layers * 8   # per-layer io
        return 3 * pbytes + opt + carries + streams
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return pbytes + tokens * cfg.d_model * 2 * cfg.n_layers * 6
    # decode: read every (active) param + the whole KV cache once per token
    n_active = (cfg.active_param_count()
                if isinstance(cfg, MoEConfig) else cfg.param_count())
    cache = (cfg.n_layers * shape.global_batch * cfg.n_kv_heads
             * shape.seq_len * cfg.head_dim * 2 * 2)
    return n_active * 2 + cache


def fit_lm_cell(arch, shape_name, mesh, multi_pod, out_path):
    cfg = get_arch(arch)
    shape_obj = {s.name: s for s in shapes_for_family(cfg.family)}[shape_name]
    kind = {"train": "train", "prefill": "prefill"}.get(shape_obj.kind, "decode")
    model_flops_full = _lm_model_flops(cfg, shape_obj, kind)
    shape = shape_name
    L_full = cfg.n_layers
    attn_ops.UNROLL_KV_SCAN = True
    try:
        t0 = clock()
        f2 = _measure(arch, shape, mesh, _probe_cfg(cfg, 2))
        f4 = _measure(arch, shape, mesh, _probe_cfg(cfg, 4))
    finally:
        attn_ops.UNROLL_KV_SCAN = False

    def fit(a, b):
        marginal = (b - a) / 2.0
        base = a - 2.0 * marginal
        return base + marginal * L_full

    flops = fit(f2[0], f4[0])
    nbytes = fit(f2[1], f4[1])
    coll = fit(f2[2], f4[2])
    model_flops = model_flops_full

    n_chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    adj_bytes = _lm_hbm_bytes(cfg, shape_obj, n_chips)
    t_comp = flops / (n_chips * PEAK_FLOPS)
    t_mem_raw = nbytes / (n_chips * HBM_BW)
    t_mem = adj_bytes / (n_chips * HBM_BW)
    t_coll = coll / ICI_BW
    bound = max(t_comp, t_mem, t_coll)
    row = {
        "name": f"{arch}/{shape}",
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "multi_pod": multi_pod,
        "chips": n_chips,
        "fitted": True,
        "hlo_gflops": round(flops / 1e9, 1),
        "hlo_gbytes_raw": round(nbytes / 1e9, 2),
        "adj_gbytes": round(adj_bytes / 1e9, 2),
        "coll_gbytes": round(coll / 1e9, 4),
        "model_gflops": round(model_flops / 1e9, 1),
        "t_compute_ms": round(t_comp * 1e3, 3),
        "t_memory_ms": round(t_mem * 1e3, 3),
        "t_memory_raw_ms": round(t_mem_raw * 1e3, 3),
        "t_collective_ms": round(t_coll * 1e3, 3),
        "bottleneck": max(
            {"compute": t_comp, "memory": t_mem, "collective": t_coll},
            key=lambda k: {"compute": t_comp, "memory": t_mem,
                           "collective": t_coll}[k]),
        "useful_ratio": round(model_flops / flops, 3) if flops else 0.0,
        "roofline_frac": round(
            (model_flops / (n_chips * PEAK_FLOPS)) / bound, 3) if bound else 0,
        "probe_s": round(clock() - t0, 1),
        "coll_counts_probe_L4": f4[3],
    }
    print(json.dumps(row), flush=True)
    with open(out_path, "a") as f:
        f.write(json.dumps(row) + "\n")
    return row


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out), exist_ok=True)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    lm = [c for c in all_cells()
          if isinstance(get_arch(c[0]), TransformerConfig)]
    cells = [(args.arch, args.shape)] if args.arch else lm
    failures = []
    for arch, shape in cells:
        print(f"=== fit {arch}/{shape} ===", flush=True)
        try:
            fit_lm_cell(arch, shape, mesh, args.multi_pod, args.out)
        except Exception as e:
            import traceback
            traceback.print_exc()
            failures.append((arch, shape, repr(e)[:200]))
    if failures:
        print("FAILURES:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
