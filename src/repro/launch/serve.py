"""Batched serving driver: prefill + steady-state decode with a KV cache,
plus a graph-analytics mode serving diameter queries through resident
``GraphSession``s — open each graph once, query many times with zero backend
rebuilds and zero edge re-uploads (asserted via ``SessionMetrics``).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --mode graph-diameter \
      --batch 8 --graph-n 2000 --queries 3 [--graph road] [--tau 12] \
      [--estimator cluster|sssp|lower|interval|cascade] \
      [--levels 2] [--tau-solve 64] \
      [--check-amortization 2.0] [--sync-budget bench]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import bench_engine_path, get_logger
from repro.config.registry import get_arch
from repro.models import transformer as tf_mod

log = get_logger("repro.serve")

ESTIMATORS = ("cluster", "sssp", "lower", "interval", "cascade")


def _make_estimator(name: str, levels: int = 0):
    from repro.core import (CascadeEstimator, ClusterQuotientEstimator,
                            DeltaSteppingEstimator, IntervalEstimator,
                            LowerBoundEstimator)

    if name == "cascade":
        # --levels 0 with an explicit --estimator cascade keeps the
        # estimator's own default depth
        return CascadeEstimator(levels=levels) if levels else CascadeEstimator()
    return {"cluster": ClusterQuotientEstimator,
            "sssp": DeltaSteppingEstimator,
            "lower": LowerBoundEstimator,
            "interval": IntervalEstimator}[name]()


def _resolve_sync_budget(spec: str, estimator: str = "cluster"):
    """"off" -> None (disabled), "bench" -> the recorded BENCH_engine.json
    budget (the "cascade" block's when serving the cascade — its extra
    levels legitimately cost more syncs than the flat pipeline — else the
    "pipeline" block's), anything else -> an explicit integer ceiling (0 is
    a real ceiling — every host sync fails it — not "off")."""
    if spec == "off":
        return None
    if spec == "bench":
        with open(bench_engine_path()) as f:
            bench = json.load(f)
        if estimator == "cascade" and "cascade" in bench:
            return int(bench["cascade"]["host_syncs_total"])
        return int(bench["pipeline"]["host_syncs_total"])
    return int(spec)


def _query_syncs(result) -> int:
    """Host syncs to judge against the per-pipeline budget. For a composite
    (DiameterInterval) the merged panel total would trivially exceed a
    single-pipeline budget, so judge its WORST member instead — every
    estimator in the panel must individually stay within budget."""
    estimates = getattr(result, "estimates", None)
    if estimates:
        return max(_query_syncs(r) for r in estimates.values())
    pm = getattr(result, "pipeline", None)
    return pm.total_host_syncs if pm is not None else 0


def serve_graph_diameter(args) -> int:
    """Steady-state diameter serving on resident sessions.

    Every graph is opened ONCE into a ``SessionPool`` (all sessions share
    one edge-pad bucket, hence one compiled pipeline); each session then
    serves ``--queries`` queries. The first query of the first session pays
    compilation; everything after streams warm. Exit status is non-zero
    when ``--check-amortization`` / ``--sync-budget`` contracts are
    violated, or when any warm query rebuilt a backend or re-uploaded edge
    arrays (the ``SessionMetrics`` contract)."""
    from repro.common import next_multiple
    from repro.config.base import GraphEngineConfig
    from repro.core import DiameterInterval, SessionPool
    from repro.launch.diameter import build_graph

    graphs = [build_graph(args.graph, args.graph_n, seed=s)
              for s in range(args.batch)]
    cfg = GraphEngineConfig(backend=args.backend)
    # --levels alone activates the cascade (same contract as
    # launch/diameter.py); other estimators don't take levels
    est_name = args.estimator
    if args.levels and est_name == "cluster":
        est_name = "cascade"
    elif args.levels and est_name not in ("cascade",):
        log.warning("--levels %d is ignored by --estimator %s",
                    args.levels, est_name)
    estimator = _make_estimator(est_name, levels=args.levels)
    sync_budget = _resolve_sync_budget(args.sync_budget, est_name)

    pool = SessionPool(cfg, tau_solve=args.tau_solve)
    # one shared edge-pad bucket across the whole batch (per-graph buckets
    # would pad to different sizes and recompile)
    e_pad = next_multiple(max(g.n_edges for g in graphs) or 1,
                          pool.edge_bucket)
    with pool:
        sessions = [pool.open(g, tau=args.tau, e_pad=e_pad) for g in graphs]

        worst_syncs, failures = 0, []
        t0 = time.perf_counter()
        cold: list[float] = []  # first query per session (session 0 compiles)
        warm: list[float] = []
        for round_idx in range(args.queries):
            if round_idx == 1:
                # the SessionMetrics contract: from here on, NOTHING may
                # build a backend or upload an edge array
                builds0 = pool.metrics.backend_builds
                uploads0 = pool.metrics.edge_uploads
            for i, sess in enumerate(sessions):
                tq = time.perf_counter()
                res = sess.estimate(estimator)
                dt = time.perf_counter() - tq
                (cold if round_idx == 0 else warm).append(dt)
                worst_syncs = max(worst_syncs, _query_syncs(res))
                if isinstance(res, DiameterInterval):
                    log.info("graph[%d] q%d: diameter in [%d, %d] "
                             "connected=%s host_syncs=%d %.3fs",
                             i, round_idx, res.lower, res.upper,
                             res.connected, _query_syncs(res), dt)
                else:
                    log.info("graph[%d] q%d: phi=%d clusters=%d connected=%s "
                             "host_syncs=%d %.3fs", i, round_idx,
                             res.phi_approx, res.n_clusters, res.connected,
                             _query_syncs(res), dt)
        total = time.perf_counter() - t0

        m = pool.metrics
        if args.queries > 1:
            rebuilds = m.backend_builds - builds0
            reuploads = m.edge_uploads - uploads0
            log.info("warm path: %d backend rebuilds, %d edge re-uploads "
                     "over %d warm queries", rebuilds, reuploads, len(warm))
            if rebuilds or reuploads:
                failures.append(
                    f"warm queries must be resident: {rebuilds} rebuilds, "
                    f"{reuploads} re-uploads")
        t_cold = cold[0]
        steady = (cold[1:] + warm) or [t_cold]
        per_warm = sum(steady) / len(steady)
        amort = t_cold / max(per_warm, 1e-9)
        log.info("opened %d sessions; first query %.2fs (compile), steady "
                 "state %.3fs/query (%.1f queries/s, %.1fx amortization), "
                 "%.2fs total", len(sessions), t_cold, per_warm,
                 1.0 / max(per_warm, 1e-9), amort, total)
        log.info("session metrics: %s", m)
        if args.check_amortization and amort < args.check_amortization:
            failures.append(f"amortization {amort:.1f}x below required "
                            f"{args.check_amortization:.1f}x")
        if sync_budget is not None and worst_syncs > sync_budget:
            failures.append(f"host syncs {worst_syncs} exceed the recorded "
                            f"bench budget {sync_budget}")
    for f in failures:
        log.error("FAIL: %s", f)
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "graph-diameter"])
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # graph-diameter mode
    ap.add_argument("--graph", default="road",
                    choices=["road", "social", "mesh"])
    from repro.launch.diameter import (add_cascade_arguments,
                                       add_tau_argument, validate_cascade,
                                       validate_tau)

    ap.add_argument("--graph-n", type=int, default=2000)
    add_tau_argument(ap)
    add_cascade_arguments(ap)
    ap.add_argument("--backend", default="single",
                    choices=["single", "sharded", "pallas"])
    ap.add_argument("--queries", type=int, default=2,
                    help="diameter queries per resident session")
    ap.add_argument("--estimator", default="cluster", choices=ESTIMATORS)
    ap.add_argument("--check-amortization", type=float, default=0.0,
                    help="fail unless cold/warm query amortization reaches "
                         "this ratio (0 = off)")
    ap.add_argument("--sync-budget", default="off",
                    help="per-query host-sync ceiling: off | bench "
                         "(use the recorded BENCH_engine.json value) | <int>")
    args = ap.parse_args()
    validate_tau(ap, args.tau)
    validate_cascade(ap, args)
    if args.queries < 1:
        ap.error("--queries must be >= 1")
    if args.batch < 1:
        ap.error("--batch must be >= 1")
    if args.sync_budget not in ("off", "bench"):
        try:
            int(args.sync_budget)
        except ValueError:
            ap.error(f"--sync-budget must be off | bench | <int> "
                     f"(got {args.sync_budget!r})")

    if args.mode == "graph-diameter":
        return serve_graph_diameter(args)

    cfg = get_arch(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params = tf_mod.init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    cache = tf_mod.init_cache(cfg, args.batch, max_len)

    decode = jax.jit(lambda p, c, t: tf_mod.decode_step(p, c, t, cfg))

    # prefill by streaming the prompt through decode (keeps ONE compiled
    # step; a production server would batch-prefill via forward())
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[:, i:i+1])
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = []
    t0 = time.time()
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(args.gen):
        toks.append(cur)
        logits, cache = decode(params, cache, cur)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(
                sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    out = np.asarray(jnp.concatenate(toks, axis=1))
    log.info("prefill %.2fs (%.1f tok/s)  decode %.2fs (%.1f tok/s/seq)",
             t_prefill, args.batch * args.prompt_len / t_prefill,
             t_decode, args.gen / t_decode)
    log.info("generated ids[0,:8] = %s", out[0, :8].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
