"""Batched serving driver: prefill + steady-state decode with a KV cache,
plus a graph-analytics mode serving diameter queries over many small graphs
through ONE compiled pipeline (``approximate_diameter_batch``).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --mode graph-diameter \
      --batch 8 --graph-n 2000 [--graph road] [--tau 12]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import get_logger
from repro.config.registry import get_arch
from repro.models import transformer as tf_mod

log = get_logger("repro.serve")


def serve_graph_diameter(args) -> int:
    """Steady-state diameter serving: a batch of same-sized graphs shares
    one compiled decompose->quotient->solve pipeline, so graph 2..N pay
    only execution, not compilation (the serving win this mode measures)."""
    from repro.config.base import GraphEngineConfig
    from repro.core import approximate_diameter_batch
    from repro.launch.diameter import build_graph

    graphs = [build_graph(args.graph, args.graph_n, seed=s)
              for s in range(args.batch)]
    cfg = GraphEngineConfig(backend=args.backend)
    # ONE batch call so every graph shares the same edge-pad bucket (two
    # calls would pad to different group maxima and recompile); per-graph
    # wall time comes from each estimate's own Timer.
    ests = approximate_diameter_batch(graphs, cfg, tau=args.tau or None)
    for i, est in enumerate(ests):
        log.info("graph[%d]: phi=%d clusters=%d connected=%s host_syncs=%d "
                 "%.3fs", i, est.phi_approx, est.n_clusters, est.connected,
                 est.pipeline.total_host_syncs if est.pipeline else -1,
                 est.seconds)
    t_first = ests[0].seconds
    warm = [e.seconds for e in ests[1:]]
    per_warm = sum(warm) / max(len(warm), 1)
    log.info("first graph %.2fs (compile), steady state %.3fs/graph "
             "(%.1f graphs/s, %.1fx amortization)",
             t_first, per_warm, 1.0 / max(per_warm, 1e-9),
             t_first / max(per_warm, 1e-9))
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "graph-diameter"])
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # graph-diameter mode
    ap.add_argument("--graph", default="road",
                    choices=["road", "social", "mesh"])
    ap.add_argument("--graph-n", type=int, default=2000)
    ap.add_argument("--tau", type=int, default=0)
    ap.add_argument("--backend", default="single",
                    choices=["single", "sharded", "pallas"])
    args = ap.parse_args()

    if args.mode == "graph-diameter":
        return serve_graph_diameter(args)

    cfg = get_arch(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params = tf_mod.init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    cache = tf_mod.init_cache(cfg, args.batch, max_len)

    decode = jax.jit(lambda p, c, t: tf_mod.decode_step(p, c, t, cfg))

    # prefill by streaming the prompt through decode (keeps ONE compiled
    # step; a production server would batch-prefill via forward())
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[:, i:i+1])
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = []
    t0 = time.time()
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(args.gen):
        toks.append(cur)
        logits, cache = decode(params, cache, cur)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(
                sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    out = np.asarray(jnp.concatenate(toks, axis=1))
    log.info("prefill %.2fs (%.1f tok/s)  decode %.2fs (%.1f tok/s/seq)",
             t_prefill, args.batch * args.prompt_len / t_prefill,
             t_decode, args.gen / t_decode)
    log.info("generated ids[0,:8] = %s", out[0, :8].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
