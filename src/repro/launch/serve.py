"""Batched serving driver: prefill + steady-state decode with a KV cache,
plus a graph-analytics mode serving diameter queries through resident
``GraphSession``s — open each graph once, query many times with zero backend
rebuilds and zero edge re-uploads (asserted via ``SessionMetrics``). With
``--update-trace`` the mode becomes a DYNAMIC replay: seeded
``temporal_trace`` mutation batches are interleaved with the queries, every
post-update bracket is checked, and the amortized update cost is reported
against a full re-decomposition.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --mode graph-diameter \
      --batch 8 --graph-n 2000 --queries 3 [--graph road] [--tau 12] \
      [--estimator cluster|sssp|lower|interval|cascade|dynamic] \
      [--levels 2] [--tau-solve 64] \
      [--update-trace 4] [--update-events 64] [--update-mix mixed] \
      [--check-amortization 2.0] [--sync-budget bench]
"""
from __future__ import annotations

import argparse
import contextlib
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import bench_engine_path, get_logger
from repro.config.registry import get_arch
from repro.models import transformer as tf_mod
from repro.runtime import telemetry
from repro.runtime.fault import EXIT_PREEMPTED, Preempted, PreemptionGuard

log = get_logger("repro.serve")

ESTIMATORS = ("cluster", "sssp", "lower", "interval", "cascade", "dynamic")

# update-trace event mixes: (p_insert, p_reweight, p_delete)
UPDATE_MIXES = {"insert": (1.0, 0.0, 0.0),
                "mixed": (0.4, 0.4, 0.2),
                "delete": (0.1, 0.1, 0.8)}


def _check_estimator_name(name: str) -> None:
    if name not in ESTIMATORS:
        raise ValueError(
            f"unknown estimator {name!r} (expected one of {ESTIMATORS})")


def _make_estimator(name: str, levels: int = 0):
    from repro.core import (CascadeEstimator, ClusterQuotientEstimator,
                            DeltaSteppingEstimator, DynamicQuotientEstimator,
                            IntervalEstimator, LowerBoundEstimator)

    _check_estimator_name(name)
    if name == "cascade":
        # --levels 0 with an explicit --estimator cascade keeps the
        # estimator's own default depth
        return CascadeEstimator(levels=levels) if levels else CascadeEstimator()
    return {"cluster": ClusterQuotientEstimator,
            "sssp": DeltaSteppingEstimator,
            "lower": LowerBoundEstimator,
            "interval": IntervalEstimator,
            "dynamic": DynamicQuotientEstimator}[name]()


def _resolve_sync_budget(spec: str, estimator: str = "cluster"):
    """"off" -> None (disabled), "bench" -> the recorded BENCH_engine.json
    budget (the "cascade" block's when serving the cascade — its extra
    levels legitimately cost more syncs than the flat pipeline — else the
    "pipeline" block's, which also covers "dynamic": a maintained query
    syncs strictly less than the flat pipeline), anything else -> an
    explicit integer ceiling (0 is a real ceiling — every host sync fails
    it — not "off"). Unknown estimator names are rejected outright instead
    of silently falling through to the cluster default."""
    _check_estimator_name(estimator)
    if spec == "off":
        return None
    if spec == "bench":
        with open(bench_engine_path()) as f:
            bench = json.load(f)
        if estimator == "cascade" and "cascade" in bench:
            return int(bench["cascade"]["host_syncs_total"])
        return int(bench["pipeline"]["host_syncs_total"])
    return int(spec)


def _query_syncs(result) -> int:
    """Host syncs to judge against the per-pipeline budget. For a composite
    (DiameterInterval) the merged panel total would trivially exceed a
    single-pipeline budget, so judge its WORST member instead — every
    estimator in the panel must individually stay within budget."""
    estimates = getattr(result, "estimates", None)
    if estimates:
        return max(_query_syncs(r) for r in estimates.values())
    pm = getattr(result, "pipeline", None)
    return pm.total_host_syncs if pm is not None else 0


def serve_graph_diameter(args) -> int:
    """Steady-state diameter serving on resident sessions.

    Every graph is opened ONCE into a ``SessionPool`` (all sessions share
    one edge-pad bucket, hence one compiled pipeline); each session then
    serves ``--queries`` queries. The first query of the first session pays
    compilation; everything after streams warm. Exit status is non-zero
    when ``--check-amortization`` / ``--sync-budget`` contracts are
    violated, or when any warm query rebuilt a backend or re-uploaded edge
    arrays (the ``SessionMetrics`` contract)."""
    from repro.common import next_multiple
    from repro.config.base import GraphEngineConfig
    from repro.core import DiameterInterval, SessionPool
    from repro.launch.diameter import build_graph

    from repro.graph import temporal_trace

    graphs = [build_graph(args.graph, args.graph_n, seed=s)
              for s in range(args.batch)]
    cfg = GraphEngineConfig(backend=args.backend, autotune=args.autotune,
                            mode=args.engine_mode,
                            deterministic=args.deterministic)
    # --levels alone activates the cascade (same contract as
    # launch/diameter.py); other estimators don't take levels
    est_name = args.estimator
    if args.levels and est_name == "cluster":
        est_name = "cascade"
    elif args.levels and est_name not in ("cascade",):
        log.warning("--levels %d is ignored by --estimator %s",
                    args.levels, est_name)
    if args.update_trace and est_name == "cluster":
        # replaying mutations against per-query full re-decompositions
        # would defeat the dynamic subsystem being exercised
        log.info("--update-trace: serving through the maintained "
                 "dynamic-quotient estimator")
        est_name = "dynamic"
    estimator = _make_estimator(est_name, levels=args.levels)
    sync_budget = _resolve_sync_budget(args.sync_budget, est_name)
    traces = []
    if args.update_trace:
        p_ins, p_rw, p_del = UPDATE_MIXES[args.update_mix]
        events = args.update_events or max(g.n_edges // 200 for g in graphs)
        traces = [temporal_trace(g, args.update_trace,
                                 events_per_batch=events, p_insert=p_ins,
                                 p_reweight=p_rw, p_delete=p_del, seed=s)
                  for s, g in enumerate(graphs)]

    # preemption-safe serving: a checkpoint-dir arms per-session stage
    # checkpointers (subdirs g0, g1, ...) under one process-level guard;
    # a SIGTERM mid-decomposition checkpoints, exits EXIT_PREEMPTED (75),
    # and a --resume rerun finishes the bracket byte-identically
    pguard = PreemptionGuard() if args.checkpoint_dir else None
    pool = SessionPool(cfg, tau_solve=args.tau_solve,
                       checkpoint_dir=args.checkpoint_dir,
                       shards=args.shards, resume=args.resume, guard=pguard)
    # one shared edge-pad bucket across the whole batch (per-graph buckets
    # would pad to different sizes and recompile)
    e_pad = next_multiple(max(g.n_edges for g in graphs) or 1,
                          pool.edge_bucket)
    # --telemetry-out arms the span tracer (zero host syncs: span
    # attribution is meter-stack bookkeeping, never a jax transfer — the
    # --sync-budget contract below holds bit-identically with it on) and
    # a registry fed per-estimator latency histograms by the query loop
    tracer = telemetry.Tracer() if args.telemetry_out else None
    registry = telemetry.MetricsRegistry() if args.telemetry_out else None
    tele_cm = (telemetry.tracing(tracer) if tracer is not None
               else contextlib.nullcontext())
    with tele_cm, pool:
        sessions = [pool.open(g, tau=args.tau, e_pad=e_pad) for g in graphs]
        if args.preempt_after:
            # TEST HOOK (kill-and-resume smoke): real SIGTERM at this stage
            # boundary of the FIRST session's first decomposition
            ck = sessions[0].checkpointer
            if ck is None:
                raise SystemExit("--preempt-after requires --checkpoint-dir")
            ck.preempt_after_stage = args.preempt_after

        worst_syncs, failures = 0, []
        # per-query results are COLLECTED here and logged in one pass after
        # the loop: the timed serving loop does no formatting/IO, and every
        # scalar it touches rides the batched guard.fetch sites inside the
        # estimators (sync-lint contract — see repro.analysis)
        records: list[tuple] = []  # (graph, round, result, syncs, dt)
        update_lines: list[tuple] = []
        from repro.analysis import guard

        t0 = telemetry.clock()
        cold: list[float] = []  # first query per session (session 0 compiles)
        warm: list[float] = []
        try:
            with (pguard if pguard is not None
                  else contextlib.nullcontext()), \
                    guard.measured_transfers() as meter, \
                    telemetry.span("serve.replay", batch=args.batch,
                                   queries=args.queries, estimator=est_name):
                for round_idx in range(args.queries):
                    if round_idx == 1:
                        # the SessionMetrics contract: from here on, NOTHING
                        # may build a backend or upload an edge array
                        builds0 = pool.metrics.backend_builds
                        uploads0 = pool.metrics.edge_uploads
                    if round_idx and traces:
                        # replay: one mutation batch per session between
                        # rounds (update work counts in DynamicMetrics, not
                        # the warm-query residency counters — the buffers
                        # are mutated IN PLACE)
                        for i, sess in enumerate(sessions):
                            if round_idx - 1 < len(traces[i]):
                                with telemetry.span("serve.update", graph=i,
                                                    batch=round_idx - 1):
                                    rep = sess.apply_updates(
                                        traces[i][round_idx - 1])
                                update_lines.append((i, round_idx - 1, rep))
                    for i, sess in enumerate(sessions):
                        tq = telemetry.clock()
                        with telemetry.span("serve.query", graph=i,
                                            round=round_idx) as qs:
                            res = sess.estimate(estimator)
                            syncs = _query_syncs(res)
                            qs.set(host_syncs=syncs)
                        dt = telemetry.clock() - tq
                        (cold if round_idx == 0 else warm).append(dt)
                        if registry is not None:
                            kind = "cold" if round_idx == 0 else "warm"
                            registry.observe(
                                f"serve.latency.{est_name}", dt)
                            registry.observe(
                                f"serve.latency.{est_name}.{kind}", dt)
                        worst_syncs = max(worst_syncs, syncs)
                        records.append((i, round_idx, res, syncs, dt))
        except Preempted as p:
            log.warning("preempted at stage %d; checkpoint durable at %s — "
                        "rerun with --resume to finish byte-identically",
                        p.stage, p.path)
            return EXIT_PREEMPTED
        total = telemetry.clock() - t0

        for i, u_idx, rep in update_lines:
            log.info("graph[%d] u%d: %s sweeps=%d dead=%d", i, u_idx,
                     rep.action, rep.supersteps, rep.dead_nodes)
        for i, round_idx, res, syncs, dt in records:
            if isinstance(res, DiameterInterval):
                log.info("graph[%d] q%d: diameter in [%d, %d] connected=%s "
                         "host_syncs=%d %.3fs", i, round_idx, res.lower,
                         res.upper, res.connected, syncs, dt)
            else:
                log.info("graph[%d] q%d: phi=%d clusters=%d connected=%s "
                         "host_syncs=%d %.3fs", i, round_idx, res.phi_approx,
                         res.n_clusters, res.connected, syncs, dt)
        log.info("measured device->host transfers: %d over %d queries "
                 "(all via guard.fetch)", meter.transfers, len(records))

        m = pool.metrics
        if args.queries > 1:
            rebuilds = m.backend_builds - builds0
            reuploads = m.edge_uploads - uploads0
            log.info("warm path: %d backend rebuilds, %d edge re-uploads "
                     "over %d warm queries", rebuilds, reuploads, len(warm))
            if rebuilds or reuploads:
                failures.append(
                    f"warm queries must be resident: {rebuilds} rebuilds, "
                    f"{reuploads} re-uploads")
        if traces:
            from repro.core import IntervalEstimator

            # drain any batches beyond the query rounds, then certify the
            # final bracket of every mutated session
            for i, sess in enumerate(sessions):
                for b in traces[i][max(args.queries - 1, 0):]:
                    sess.apply_updates(b)
                iv = sess.estimate(IntervalEstimator())  # raises if inverted
                log.info("graph[%d] final bracket [%d, %d] connected=%s",
                         i, iv.lower, iv.upper, iv.connected)
            dm = [s.dynamic.metrics for s in sessions]
            upd_steps = sum(m.update_supersteps + m.rebuild_supersteps
                            for m in dm)
            upd_batches = sum(m.batches for m in dm)
            baseline = max(m.baseline_supersteps for m in dm)
            amort_upd = upd_steps / max(upd_batches, 1)
            log.info("update replay: %d batches, %.1f supersteps/batch "
                     "amortized vs %d for a full re-decomposition (%d "
                     "rebuilds)", upd_batches, amort_upd, baseline,
                     sum(m.full_rebuilds for m in dm))
            if args.check_update_cost and baseline and \
                    amort_upd * args.check_update_cost > baseline:
                failures.append(
                    f"amortized update cost {amort_upd:.1f} supersteps/batch "
                    f"exceeds 1/{args.check_update_cost:g} of a full "
                    f"re-decomposition ({baseline})")
        t_cold = cold[0]
        steady = (cold[1:] + warm) or [t_cold]
        per_warm = sum(steady) / len(steady)
        amort = t_cold / max(per_warm, 1e-9)
        log.info("opened %d sessions; first query %.2fs (compile), steady "
                 "state %.3fs/query (%.1f queries/s, %.1fx amortization), "
                 "%.2fs total", len(sessions), t_cold, per_warm,
                 1.0 / max(per_warm, 1e-9), amort, total)
        log.info("session metrics: %s", m)
        if args.check_amortization and amort < args.check_amortization:
            failures.append(f"amortization {amort:.1f}x below required "
                            f"{args.check_amortization:.1f}x")
        if sync_budget is not None and worst_syncs > sync_budget:
            failures.append(f"host syncs {worst_syncs} exceed the recorded "
                            f"bench budget {sync_budget}")
        if args.telemetry_out:
            registry.ingest(m, "session")
            registry.ingest(meter, "serve.transfers")
            for i, sess in enumerate(sessions):
                dyn = getattr(sess, "dynamic", None)
                if dyn is not None:
                    registry.ingest(dyn.metrics, f"dynamic.g{i}")
            written = telemetry.write_telemetry(
                args.telemetry_out, tracer, registry)
            log.info("telemetry: %d spans, %d measured transfers attributed "
                     "-> %s", len(tracer.spans), tracer.total_transfers(),
                     sorted(written.values()))
    for f in failures:
        log.error("FAIL: %s", f)
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "graph-diameter"])
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # graph-diameter mode
    ap.add_argument("--graph", default="road",
                    choices=["road", "social", "mesh"])
    from repro.launch.diameter import (add_autotune_argument,
                                       add_cascade_arguments,
                                       add_engine_mode_argument,
                                       add_tau_argument,
                                       add_telemetry_argument,
                                       validate_cascade, validate_tau)

    ap.add_argument("--graph-n", type=int, default=2000)
    add_tau_argument(ap)
    add_cascade_arguments(ap)
    add_autotune_argument(ap)
    add_engine_mode_argument(ap)
    add_telemetry_argument(ap)
    ap.add_argument("--backend", default="single",
                    choices=["single", "sharded", "pallas"])
    ap.add_argument("--queries", type=int, default=2,
                    help="diameter queries per resident session")
    ap.add_argument("--estimator", default="cluster", choices=ESTIMATORS)
    ap.add_argument("--shards", type=int, default=0,
                    help="back each session with a partition-sharded "
                         "GraphStore of this many shards (0 = flat storage)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="arm per-session stage-boundary checkpointing "
                         "(preemption-safe serving; subdirs g0, g1, ...)")
    ap.add_argument("--resume", action="store_true",
                    help="continue decompositions from the latest stage "
                         "checkpoints in --checkpoint-dir")
    ap.add_argument("--preempt-after", type=int, default=0,
                    help="TEST HOOK: deliver a real SIGTERM at this stage "
                         "boundary of the first session's decomposition "
                         "(kill-and-resume smoke; requires --checkpoint-dir)")
    ap.add_argument("--update-trace", type=int, default=0,
                    help="replay this many temporal_trace mutation batches "
                         "per session, interleaved with the query rounds "
                         "(0 = static serving)")
    ap.add_argument("--update-events", type=int, default=0,
                    help="events per mutation batch (0 = ~0.5%% of edges)")
    ap.add_argument("--update-mix", default="mixed",
                    choices=sorted(UPDATE_MIXES))
    ap.add_argument("--check-update-cost", type=float, default=0.0,
                    help="fail unless amortized update supersteps stay "
                         "below baseline/THIS (e.g. 5 = the 1/5 contract; "
                         "0 = off)")
    ap.add_argument("--check-amortization", type=float, default=0.0,
                    help="fail unless cold/warm query amortization reaches "
                         "this ratio (0 = off)")
    ap.add_argument("--sync-budget", default="off",
                    help="per-query host-sync ceiling: off | bench "
                         "(use the recorded BENCH_engine.json value) | <int>")
    args = ap.parse_args()
    validate_tau(ap, args.tau)
    validate_cascade(ap, args)
    from repro.core import check_engine_mode
    check_engine_mode(args.engine_mode)  # before any graph/device work
    if args.queries < 1:
        ap.error("--queries must be >= 1")
    if args.batch < 1:
        ap.error("--batch must be >= 1")
    if args.update_trace < 0:
        ap.error("--update-trace must be >= 0")
    if args.update_events < 0:
        ap.error("--update-events must be >= 0")
    if args.sync_budget not in ("off", "bench"):
        try:
            int(args.sync_budget)
        except ValueError:
            ap.error(f"--sync-budget must be off | bench | <int> "
                     f"(got {args.sync_budget!r})")

    if args.mode == "graph-diameter":
        return serve_graph_diameter(args)

    cfg = get_arch(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params = tf_mod.init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    cache = tf_mod.init_cache(cfg, args.batch, max_len)

    decode = jax.jit(lambda p, c, t: tf_mod.decode_step(p, c, t, cfg))

    # prefill by streaming the prompt through decode (keeps ONE compiled
    # step; a production server would batch-prefill via forward())
    t0 = telemetry.clock()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[:, i:i+1])
    jax.block_until_ready(logits)
    t_prefill = telemetry.clock() - t0

    toks = []
    t0 = telemetry.clock()
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(args.gen):
        toks.append(cur)
        logits, cache = decode(params, cache, cur)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(
                sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = telemetry.clock() - t0

    out = np.asarray(jnp.concatenate(toks, axis=1))  # sync: one post-loop fetch of all decoded ids
    log.info("prefill %.2fs (%.1f tok/s)  decode %.2fs (%.1f tok/s/seq)",
             t_prefill, args.batch * args.prompt_len / t_prefill,
             t_decode, args.gen / t_decode)
    log.info("generated ids[0,:8] = %s", out[0, :8].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
