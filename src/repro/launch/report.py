"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from results/*.jsonl.

  PYTHONPATH=src python -m repro.launch.report > /root/repo/results/tables.md

The §Repro / §Perf prose sections live in EXPERIMENTS.md itself; this tool
regenerates the mechanical tables after a new dry-run / fit sweep.
"""
from __future__ import annotations

import json
import os
import sys
from collections import OrderedDict

RESULTS = "/root/repo/results"


def _load_latest(path, key=lambda r: (r["name"], r.get("multi_pod", False))):
    rows = OrderedDict()
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            rows[key(r)] = r
    return rows


def dryrun_table() -> str:
    rows = _load_latest(os.path.join(RESULTS, "dryrun.jsonl"))
    out = ["| cell | mesh | compile_s | args MB/dev | temp MB/dev | "
           "collectives (count) | fits 16G? |",
           "|---|---|---|---|---|---|---|"]
    for (name, mp), r in sorted(rows.items()):
        if not r.get("ok"):
            out.append(f"| {name} | {'2x16x16' if mp else '16x16'} | FAILED | | | | |")
            continue
        args_mb = r.get("arg_bytes_per_dev", 0) / 1e6
        temp_mb = r.get("temp_bytes_per_dev", 0) / 1e6
        tot = (r.get("arg_bytes_per_dev", 0) + r.get("temp_bytes_per_dev", 0)
               + r.get("output_bytes_per_dev", 0)) / 1e9
        colls = " ".join(f"{k}:{v}" for k, v in r.get("collectives", {}).items())
        out.append(
            f"| {name} | {r['mesh']} | {r.get('compile_s', '?')} | "
            f"{args_mb:.0f} | {temp_mb:.0f} | {colls} | "
            f"{'yes' if tot < 16 else f'NO ({tot:.0f}G)'} |")
    return "\n".join(out)


def roofline_table() -> str:
    fitted = _load_latest(os.path.join(RESULTS, "roofline.jsonl"))
    raw = _load_latest(os.path.join(RESULTS, "dryrun.jsonl"))
    out = ["| cell | mesh | t_compute | t_memory | t_collective | bottleneck | "
           "useful | roofline_frac |",
           "|---|---|---|---|---|---|---|---|"]

    def fmt_ms(v):
        return f"{v:.2f}ms" if v >= 0.01 else f"{v*1000:.1f}us"

    seen = set()
    for (name, mp), r in sorted(fitted.items()):
        out.append(
            f"| {name} | {r['mesh']} | {fmt_ms(r['t_compute_ms'])} | "
            f"{fmt_ms(r['t_memory_ms'])} | {fmt_ms(r['t_collective_ms'])} | "
            f"{r['bottleneck']} | {r['useful_ratio']} | {r['roofline_frac']} |")
        seen.add((name, mp))
    for (name, mp), r in sorted(raw.items()):
        if (name, mp) in seen or not r.get("ok") or mp:
            continue
        arch = name.split("/")[0]
        if arch in ("gemma2-9b", "qwen1.5-32b", "mistral-nemo-12b",
                    "moonshot-v1-16b-a3b", "mixtral-8x7b"):
            continue  # LM rows come from the fit
        out.append(
            f"| {name} | {r['mesh']} | {fmt_ms(r['t_compute_ms'])} | "
            f"{fmt_ms(r['t_memory_ms'])} | {fmt_ms(r['t_collective_ms'])} | "
            f"{r['bottleneck']} | n/a | {r.get('roofline_frac', 0)} |")
    return "\n".join(out)


def bench_tables() -> str:
    out = []
    for name in ("table1_graphs", "table2_stop_variant", "table3_vs_sssp",
                 "table4_sigma", "delta_init"):
        path = os.path.join(RESULTS, f"{name}.json")
        if not os.path.exists(path):
            continue
        rows = json.load(open(path))
        if not rows:
            continue
        cols = list(rows[0].keys())
        out.append(f"\n#### {name}\n")
        out.append("| " + " | ".join(cols) + " |")
        out.append("|" + "---|" * len(cols))
        for r in rows:
            out.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)


def main() -> int:
    print("## §Dry-run (generated)\n")
    print(dryrun_table())
    print("\n## §Roofline (generated)\n")
    print(roofline_table())
    print("\n## §Repro benchmark tables (generated)\n")
    print(bench_tables())
    return 0


if __name__ == "__main__":
    sys.exit(main())
