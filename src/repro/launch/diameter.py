"""Graph-analytics launcher: the paper's diameter-approximation pipeline.

  PYTHONPATH=src python -m repro.launch.diameter --graph road --n 20000 \
      [--variant stop] [--delta-init avg] [--tau 16] [--distributed] \
      [--comm halo] [--compare-sssp]
"""
from __future__ import annotations

import argparse

import jax

from repro.common import get_logger
from repro.config.base import GraphEngineConfig
from repro.core import approximate_diameter, diameter_2approx_sssp
from repro.core.distributed import DistributedEngine
from repro.graph import grid_mesh, random_geometric, social_like
from repro.launch.mesh import host_device_mesh

log = get_logger("repro.diameter")


def build_graph(kind: str, n: int, seed: int):
    if kind == "road":
        return random_geometric(n, avg_degree=3.0, seed=seed)
    if kind == "social":
        import math
        return social_like(max(int(math.log2(max(n, 2))), 4), 8, seed=seed,
                           weight_dist="uniform", high=2**26)
    if kind == "mesh":
        side = max(int(n ** 0.5), 4)
        return grid_mesh(side, "bimodal", heavy_w=10**6, heavy_p=0.1, seed=seed)
    raise ValueError(kind)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="road", choices=["road", "social", "mesh"])
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--tau", type=int, default=0)
    ap.add_argument("--variant", default="stop", choices=["stop", "complete"])
    ap.add_argument("--delta-init", default="avg")
    ap.add_argument("--cluster2", action="store_true")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--comm", default="allgather", choices=["allgather", "halo"])
    ap.add_argument("--compare-sssp", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    g = build_graph(args.graph, args.n, args.seed)
    log.info("graph: %d nodes, %d directed edges", g.n_nodes, g.n_edges)
    cfg = GraphEngineConfig(variant=args.variant, delta_init=args.delta_init,
                            use_cluster2=args.cluster2, seed=args.seed)

    relax_fn = None
    if args.distributed:
        mesh = host_device_mesh()
        eng = DistributedEngine(g, mesh, comm=args.comm)
        relax_fn = eng.make_relax_fn()
        log.info("distributed engine on %s devices, comm=%s",
                 dict(mesh.shape), args.comm)

    est = approximate_diameter(g, cfg, tau=args.tau or None, relax_fn=relax_fn)
    log.info("Phi_approx = %d  (quotient %d + 2 x radius %d)  "
             "clusters=%d stages=%d growing_steps=%d  %.2fs",
             est.phi_approx, est.phi_quotient, est.radius, est.n_clusters,
             est.n_stages, est.growing_steps, est.seconds)

    if args.compare_sssp:
        lb, ub, ss = diameter_2approx_sssp(g, seed=args.seed)
        log.info("SSSP-BF: lower=%d upper=%d supersteps=%d  "
                 "(CLUSTER rounds: %d -> %.1fx fewer)",
                 lb, ub, ss, est.growing_steps,
                 ss / max(est.growing_steps, 1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
