"""Graph-analytics launcher: the paper's diameter-approximation pipeline on
a resident ``GraphSession`` (open once, query with any estimator).

  PYTHONPATH=src python -m repro.launch.diameter --graph road --n 20000 \
      [--variant stop] [--delta-init avg] [--tau 16] \
      [--levels 2] [--tau-solve 64] \
      [--backend single|sharded|pallas] [--comm halo] [--partition cluster] \
      [--compare-sssp] [--interval]

``--levels N`` runs the multi-level quotient cascade (``CascadeEstimator``):
whenever the quotient still exceeds ``--tau-solve`` clusters, the engine
re-enters on the quotient itself (up to N extra levels) before the batched
BF solve. ``--compare-sssp`` and ``--interval`` run the competitor
estimators against the SAME session — no re-upload between methods.
``--distributed`` is kept as an alias for ``--backend sharded``.
"""
from __future__ import annotations

import argparse
import contextlib

import jax

from repro.common import get_logger
from repro.config.base import GraphEngineConfig
from repro.core import (
    CascadeEstimator,
    ClusterQuotientEstimator,
    DeltaSteppingEstimator,
    IntervalEstimator,
    check_engine_mode,
    cluster,
    open_session,
)
from repro.graph import GraphStore, grid_mesh, random_geometric, social_like
from repro.runtime import telemetry
from repro.runtime.fault import EXIT_PREEMPTED, Preempted, PreemptionGuard

log = get_logger("repro.diameter")


def add_tau_argument(ap: argparse.ArgumentParser) -> None:
    """The shared --tau CLI contract (also used by launch/serve.py)."""
    ap.add_argument("--tau", type=int, default=None,
                    help="decomposition tau (>= 1); default: the paper's "
                         "n/1000 rule via tau_for()")


def add_cascade_arguments(ap: argparse.ArgumentParser) -> None:
    """The shared --levels/--tau-solve CLI contract (also launch/serve.py)."""
    ap.add_argument("--levels", type=int, default=0,
                    help="extra quotient-cascade decomposition levels "
                         "(0 = flat single-level pipeline)")
    ap.add_argument("--tau-solve", type=int, default=None,
                    help="quotient solve budget (>= 2): cascade whenever the "
                         "quotient exceeds this many clusters; default "
                         "DEFAULT_TAU_SOLVE")


def add_autotune_argument(ap: argparse.ArgumentParser) -> None:
    """The shared --autotune CLI contract (also used by launch/serve.py)."""
    ap.add_argument("--autotune", default="off",
                    choices=["off", "auto", "record"],
                    help="graph-statistics autotuner (core/autotune.py): "
                         "derive tau/tau-solve/delta-init/kernel tiling from "
                         "one device stats pass; explicit flags stay pinned. "
                         "'record' persists the tuning cache to JSON")


def add_engine_mode_argument(ap: argparse.ArgumentParser) -> None:
    """The shared --engine-mode CLI contract (also used by launch/serve.py).

    Deliberately NOT an argparse ``choices`` list: unknown names flow into
    ``check_engine_mode`` so the CLI and the library raise the same
    ValueError listing the valid modes (regression-tested, mirroring the
    serve.py estimator-name contract).
    """
    ap.add_argument("--engine-mode", default="stages",
                    help="decomposition mode (core/engine.py): 'stages' "
                         "(paper stage loop, default), 'oneshot' "
                         "(exponential-shift single fixpoint), or 'auto' "
                         "(defer to the autotuning record)")
    ap.add_argument("--deterministic", action="store_true",
                    help="oneshot mode: hash-derived shifts — the "
                         "decomposition is a seed-independent function of "
                         "the graph")


def add_telemetry_argument(ap: argparse.ArgumentParser) -> None:
    """The shared --telemetry-out CLI contract (also used by serve.py)."""
    ap.add_argument("--telemetry-out", default=None, metavar="DIR",
                    help="write a span trace (trace.json, loads in "
                         "ui.perfetto.dev), spans.jsonl and metrics.prom "
                         "under DIR. Tracing adds zero host syncs: the "
                         "transfer-equality contracts hold bit-identically "
                         "with it on (see docs/engine.md, Telemetry)")


def validate_tau(ap: argparse.ArgumentParser, tau) -> None:
    if tau is not None and tau < 1:
        ap.error(f"--tau must be >= 1 (got {tau}); omit it to use the "
                 "paper's n/1000 default")


def validate_cascade(ap: argparse.ArgumentParser, args) -> None:
    if args.levels < 0:
        ap.error(f"--levels must be >= 0 (got {args.levels})")
    if args.tau_solve is not None and args.tau_solve < 2:
        ap.error(f"--tau-solve must be >= 2 (got {args.tau_solve})")


def build_graph(kind: str, n: int, seed: int):
    if kind == "road":
        return random_geometric(n, avg_degree=3.0, seed=seed)
    if kind == "social":
        import math
        return social_like(max(int(math.log2(max(n, 2))), 4), 8, seed=seed,
                           weight_dist="uniform", high=2**26)
    if kind == "mesh":
        side = max(int(n ** 0.5), 4)
        return grid_mesh(side, "bimodal", heavy_w=10**6, heavy_p=0.1, seed=seed)
    raise ValueError(kind)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="road", choices=["road", "social", "mesh"])
    ap.add_argument("--n", type=int, default=10_000)
    add_tau_argument(ap)
    add_cascade_arguments(ap)
    add_autotune_argument(ap)
    add_engine_mode_argument(ap)
    add_telemetry_argument(ap)
    ap.add_argument("--variant", default="stop", choices=["stop", "complete"])
    ap.add_argument("--delta-init", default="avg")
    ap.add_argument("--cluster2", action="store_true")
    ap.add_argument("--backend", default="single",
                    choices=["single", "sharded", "pallas"])
    ap.add_argument("--distributed", action="store_true",
                    help="alias for --backend sharded")
    ap.add_argument("--comm", default="halo", choices=["halo", "allgather"],
                    help="sharded collective: halo (static boundary-row "
                         "exchange, default) or allgather (full-plane "
                         "baseline); results are byte-identical")
    ap.add_argument("--partition", default="range", choices=["range", "cluster"],
                    help="sharded backend node relabeling (cluster = "
                         "locality-aware, from a pilot decomposition)")
    ap.add_argument("--shards", type=int, default=0,
                    help="GraphStore shard count (0 = device count for the "
                         "sharded backend, unsharded otherwise); >1 also "
                         "works with --backend single for storage-level "
                         "slab/halo introspection")
    ap.add_argument("--compress", action="store_true",
                    help="hold resident GraphStore slabs compressed "
                         "(lossless delta codec, decompressed on demand)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="arm stage-boundary checkpointing of the "
                         "decomposition state (preemption-safe; see "
                         "checkpoint/checkpoint.py)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest stage checkpoint in "
                         "--checkpoint-dir (byte-identical finish)")
    ap.add_argument("--compare-sssp", action="store_true")
    ap.add_argument("--interval", action="store_true",
                    help="run the full estimator panel and report the "
                         "certified [lower, upper] bracket")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    validate_tau(ap, args.tau)
    validate_cascade(ap, args)
    check_engine_mode(args.engine_mode)  # before any graph/device work
    backend_kind = "sharded" if args.distributed else args.backend

    g = build_graph(args.graph, args.n, args.seed)
    log.info("graph: %d nodes, %d directed edges", g.n_nodes, g.n_edges)
    cfg = GraphEngineConfig(variant=args.variant, delta_init=args.delta_init,
                            use_cluster2=args.cluster2, seed=args.seed,
                            backend=backend_kind, comm=args.comm,
                            mode=args.engine_mode,
                            deterministic=args.deterministic)

    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")
    shards = args.shards
    if shards == 0 and backend_kind == "sharded":
        shards = int(jax.device_count())
    store = None
    if shards > 1 or args.compress:
        centers = None
        if backend_kind == "sharded" and args.partition == "cluster":
            # pilot decomposition -> locality-aware relabeling inside the
            # store -> smaller halo for the sharded grow path
            pilot = cluster(g, max(16 if args.tau is None else args.tau, 4),
                            seed=args.seed)
            centers = pilot.final_c
        store = GraphStore(g, n_shards=max(shards, 1), centers=centers,
                           compress=args.compress)
        log.info("GraphStore: %d shards, halo_k=%d, halo %d B/superstep vs "
                 "full-plane %d B/superstep, resident %d B (raw %d B)",
                 store.n_shards, store.halo_k(),
                 store.halo_bytes_per_superstep(),
                 store.fullplane_bytes_per_superstep(),
                 store.resident_bytes(), store.raw_bytes())
    # the session builds the backend from cfg.backend (make_backend hands a
    # GraphStore's prebuilt slab/halo layout to the DistributedEngine)

    guard = PreemptionGuard() if args.checkpoint_dir else None
    # --telemetry-out arms the span tracer for the whole session lifetime
    # (open/pack, decomposition stages, quotient, solve); the estimators'
    # spans no-op when it is absent
    tracer = telemetry.Tracer() if args.telemetry_out else None
    tele_cm = (telemetry.tracing(tracer) if tracer is not None
               else contextlib.nullcontext())
    with tele_cm:
        sess = open_session(g if store is None else None, cfg,
                            tau=args.tau, tau_solve=args.tau_solve,
                            autotune=args.autotune, store=store,
                            checkpoint_dir=args.checkpoint_dir,
                            resume=args.resume, guard=guard)
        if sess.tuning is not None:
            t = sess.tuning
            log.info("autotuned: tau=%d tau_solve=%d levels=%d delta0=%d "
                     "tiling=(%d,%d) fuse=%d", t.tau, t.tau_solve, t.levels,
                     t.delta_init, t.node_tile, t.edge_block, t.fuse)
        if args.levels > 0:
            estimator = CascadeEstimator(levels=args.levels)
        elif sess.tuning is not None:
            estimator = None  # session default: tuned cascade depth
        else:
            estimator = ClusterQuotientEstimator()
        try:
            with (guard if guard is not None else contextlib.nullcontext()):
                est = sess.estimate(estimator)
        except Preempted as p:
            log.warning("preempted at stage %d; checkpoint durable at %s — "
                        "rerun with --resume to finish byte-identically",
                        p.stage, p.path)
            return EXIT_PREEMPTED
        log.info("Phi_approx = %d  (quotient %d + 2 x radius %d)  "
                 "clusters=%d stages=%d growing_steps=%d connected=%s  %.2fs",
                 est.phi_approx, est.phi_quotient, est.radius, est.n_clusters,
                 est.n_stages, est.growing_steps, est.connected, est.seconds)
        if est.pipeline is not None:
            pm = est.pipeline
            log.info("pipeline host syncs: %d total (decompose %d + finalize "
                     "%d + quotient %d + solve %d); solve supersteps=%d "
                     "q_edges=%d",
                     pm.total_host_syncs, pm.decompose_syncs,
                     pm.finalize_syncs, pm.quotient_syncs, pm.solve_syncs,
                     pm.solve_supersteps, pm.n_quotient_edges)
            if pm.cascade_levels:
                log.info("cascade: %d extra levels, clusters per level %s, "
                         "supersteps per level %s, syncs per level %s",
                         pm.cascade_levels, pm.level_clusters,
                         pm.level_supersteps, pm.level_syncs)

        if args.compare_sssp:
            # same resident session: the competitor re-uses the device
            # buffers
            sssp = sess.estimate(DeltaSteppingEstimator(seed=args.seed))
            # phi_approx (= 2 ecc) stays an int even when upper is dropped
            # on disconnected inputs
            log.info("SSSP-BF: lower=%d 2xecc=%d supersteps=%d connected=%s  "
                     "(CLUSTER rounds: %d -> %.1fx fewer)",
                     sssp.lower, sssp.phi_approx, sssp.growing_steps,
                     sssp.connected, est.growing_steps,
                     sssp.growing_steps / max(est.growing_steps, 1))
        if args.interval:
            iv = sess.estimate(IntervalEstimator())
            log.info("certified bracket: diameter in [%d, %d] connected=%s "
                     "(merged host syncs=%d) %.2fs", iv.lower, iv.upper,
                     iv.connected, iv.pipeline.total_host_syncs, iv.seconds)
        log.info("session metrics: %s", sess.metrics)
        if args.telemetry_out:
            registry = telemetry.MetricsRegistry()
            if est.pipeline is not None:
                registry.ingest(est.pipeline, "pipeline")
            registry.ingest(sess.metrics, "session")
            written = telemetry.write_telemetry(args.telemetry_out, tracer,
                                                registry)
            log.info("telemetry: %d spans, %d measured transfers attributed "
                     "-> %s", len(tracer.spans), tracer.total_transfers(),
                     sorted(written.values()))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
