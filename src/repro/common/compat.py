"""Version shims for jax APIs that moved between releases.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` (where the
replication-check kwarg is ``check_rep``) to a top-level export (where it is
``check_vma``). Likewise Pallas renamed ``pltpu.TPUCompilerParams`` to
``pltpu.CompilerParams``. The installed toolchain pins jax 0.4.x, which only
ships the old spellings — route the calls in this repo through these shims so
the code runs on both.
"""
from __future__ import annotations

from typing import Any, Callable

import jax


def shard_map(
    f: Callable,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
) -> Callable:
    """``jax.shard_map`` on new jax; experimental fallback on jax 0.4.x.

    ``check_vma`` maps onto the old API's ``check_rep`` (same meaning:
    validate that outputs are replicated where the out_specs claim so).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def tpu_compiler_params(**kwargs) -> Any:
    """``pltpu.CompilerParams`` on new jax, ``TPUCompilerParams`` on 0.4.x."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
