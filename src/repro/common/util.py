"""Small shared utilities used across the framework.

Nothing in here touches jax device state at import time — important because
launch/dryrun.py must be able to set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import logging
import sys
from typing import Any, Iterable

import jax
import numpy as np

from repro.runtime.telemetry import clock


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def next_multiple(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x`` (and >= m)."""
    return max(m, ceil_div(x, m) * m)


def pad_to_multiple(arr: np.ndarray, multiple: int, axis: int = 0, fill: Any = 0) -> np.ndarray:
    """Pad ``arr`` along ``axis`` so its length is a multiple of ``multiple``."""
    n = arr.shape[axis]
    target = next_multiple(n, multiple)
    return pad_axis_to(arr, target, axis=axis, fill=fill)


def pad_axis_to(arr: np.ndarray, target: int, axis: int = 0, fill: Any = 0) -> np.ndarray:
    """Pad ``arr`` along ``axis`` with ``fill`` up to length ``target``."""
    n = arr.shape[axis]
    if n == target:
        return arr
    if n > target:
        raise ValueError(f"cannot pad axis {axis} of length {n} down to {target}")
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, target - n)
    return np.pad(arr, widths, mode="constant", constant_values=fill)


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all arrays in a pytree (works on ShapeDtypeStruct too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        shape = getattr(leaf, "shape", ())
        dtype = np.dtype(getattr(leaf, "dtype", np.float32))
        total += int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    return total


def tree_num_params(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(getattr(l, "shape", ()), dtype=np.int64)) for l in leaves)


def bench_engine_path() -> str:
    """Repo-root ``BENCH_engine.json`` — the ONE location the engine bench
    writes and the serve sync-budget check reads (both must agree)."""
    import os

    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, "BENCH_engine.json")


class Timer:
    """Context-manager wall timer. ``with Timer() as t: ...; t.seconds``.

    Reads time through ``telemetry.clock()`` — the one determinism-lint
    sanctioned clock seam — so every Timer site is covered without a
    per-site ``# det:`` pragma."""

    def __enter__(self) -> "Timer":
        self._t0 = clock()
        self.seconds = 0.0
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = clock() - self._t0


_LOGGERS: dict[str, logging.Logger] = {}


def get_logger(name: str = "repro") -> logging.Logger:
    if name in _LOGGERS:
        return _LOGGERS[name]
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter("[%(asctime)s %(name)s] %(message)s", "%H:%M:%S"))
        logger.addHandler(handler)
        logger.setLevel(logging.INFO)
        logger.propagate = False
    _LOGGERS[name] = logger
    return logger


def batched(iterable: Iterable, n: int):
    """Yield lists of up to ``n`` items."""
    buf = []
    for item in iterable:
        buf.append(item)
        if len(buf) == n:
            yield buf
            buf = []
    if buf:
        yield buf
