"""Shared low-level utilities: dtypes, padding, timing, logging, jax shims."""
from repro.common.compat import shard_map
from repro.common.util import (
    bench_engine_path,
    ceil_div,
    pad_to_multiple,
    pad_axis_to,
    next_multiple,
    tree_size_bytes,
    tree_num_params,
    Timer,
    get_logger,
)

__all__ = [
    "shard_map",
    "bench_engine_path",
    "ceil_div",
    "pad_to_multiple",
    "pad_axis_to",
    "next_multiple",
    "tree_size_bytes",
    "tree_num_params",
    "Timer",
    "get_logger",
]
