from repro.optim.adamw import AdamWState, init_state, apply_updates, wsd_schedule, global_norm, zero1_state_specs
