"""AdamW with global-norm clipping, WSD schedule, and ZeRO-1 sharding.

Raw-JAX optimizer (no optax offline): state is {m, v, step}. ZeRO-1 is a
SHARDING decision, not an algorithm change — `zero1_state_specs` places m/v
shards over the 'data' axis on the dimension the parameter itself does not
shard, so optimizer memory scales down with DP world size; the update math
is unchanged and GSPMD inserts the reduce-scatter/all-gather pair.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config.base import TrainConfig


class AdamWState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def init_state(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def wsd_schedule(step, cfg: TrainConfig, total_steps: int = 0):
    """Warmup-stable-decay. Decay phase only if total_steps known."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    lr = cfg.lr * warm
    if total_steps:
        decay_start = int(0.8 * total_steps)
        frac = jnp.clip(
            (step - decay_start) / max(total_steps - decay_start, 1), 0.0, 1.0
        )
        lr = lr * (1.0 - 0.9 * frac)
    return lr


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.float32(0)))


def apply_updates(  # jit at the train-step level (donation handled there)

    params,
    state: AdamWState,
    grads,
    cfg: TrainConfig,
    total_steps: int = 0,
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    step = state.step + 1
    lr = wsd_schedule(step, cfg, total_steps)

    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v, grads)
    # bias correction folded into scalars — no mh/vh temporaries (these are
    # full f32 param-sized trees; materializing them doubles optimizer HBM)
    t = step.astype(jnp.float32)
    c1 = 1.0 / (1 - b1 ** t)
    c2s = jnp.sqrt(1 - b2 ** t)

    def upd(p, m_, v_):
        delta = (c1 * m_) / (jnp.sqrt(v_) / c2s + eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(m, v, step), {"grad_norm": gn, "lr": lr}


def zero1_state_specs(param_specs, param_shapes=None, data_axis: str = "data",
                      axis_size: int = 0):
    """ZeRO-1: shard each m/v over `data_axis` on the largest dimension the
    parameter leaves unsharded AND whose size divides by the axis. Leaves
    with no eligible dim stay on the param's own spec (replicated m/v).

    `param_shapes` (same-structure tree of ShapeDtypeStructs/arrays) enables
    the divisibility check; without it, specs are returned unchanged except
    the first free dim heuristic is skipped entirely (safe default)."""
    if param_shapes is None:
        return param_specs

    def spec_for(ps: P, shape_like):
        shape = tuple(getattr(shape_like, "shape", ()))
        dims = list(ps) if ps else [None] * len(shape)
        while len(dims) < len(shape):
            dims.append(None)
        best, best_size = -1, 0
        for i, (d, n) in enumerate(zip(dims, shape)):
            if d is None and axis_size and n % axis_size == 0 and n > best_size:
                best, best_size = i, n
        if best >= 0:
            dims[best] = data_axis
        return P(*dims)

    return jax.tree.map(
        spec_for, param_specs, param_shapes,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )
