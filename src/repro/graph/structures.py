"""Graph containers.

``EdgeList`` is the host-side (numpy) representation: directed edge triples
(src, dst, w). Undirected graphs store both directions. ``DeviceGraph`` is the
device-ready representation used by the engine and the GNN models: edges sorted
by destination, padded to a multiple of the edge-block size, plus CSR-style
block pointers consumed by the Pallas relaxation kernel.

All distances/weights are int32. INF_I32 marks "unreached"; weight arithmetic
is guarded so INF never overflows (sources at INF are masked before the add).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import ceil_div, next_multiple

INF_I32 = np.int32(2**31 - 1)
# Largest admissible edge weight / path weight. Weights are "polynomial in n"
# (paper §2); we enforce < 2^30 so d + w never overflows int32.
MAX_WEIGHT = np.int32(2**30 - 1)


def weight_scale_for(max_weight: int, cap: int = int(MAX_WEIGHT)) -> int:
    """Smallest integer ``s`` with ``ceil(max_weight / s) <= cap`` — the
    rescale factor that folds wider-than-int32 weights (e.g. int64 quotient
    sums) back into the engine's admissible [1, cap] range."""
    return max(-(-int(max_weight) // int(cap)), 1)


def rescale_weights(w: np.ndarray, cap: int = int(MAX_WEIGHT)):
    """Ceil-rescale positive integer weights into [1, cap].

    Returns ``(w_rescaled, scale)`` with ``w_rescaled = ceil(w / scale)``.
    Ceiling keeps shortest paths conservative: for any path,
    ``scale * sum(ceil(w/scale)) >= sum(w)``, so distances (and therefore
    diameter upper bounds) computed on the rescaled graph, multiplied back
    by ``scale``, still upper-bound the true ones.
    """
    w = np.asarray(w, dtype=np.int64)
    wmax = int(w.max()) if len(w) else 0
    scale = weight_scale_for(wmax, cap)
    return np.maximum((w + scale - 1) // scale, 1), scale


@dataclass
class EdgeList:
    """Host-side directed edge list. Undirected graphs carry both directions."""

    n_nodes: int
    src: np.ndarray  # int32 [E]
    dst: np.ndarray  # int32 [E]
    weight: np.ndarray  # int32 [E]

    def __post_init__(self) -> None:
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        self.weight = np.asarray(self.weight, dtype=np.int32)
        if not (len(self.src) == len(self.dst) == len(self.weight)):
            raise ValueError("src/dst/weight length mismatch")
        if len(self.weight) and (self.weight.min() < 1 or self.weight.max() > MAX_WEIGHT):
            raise ValueError("edge weights must be in [1, 2^30)")

    @property
    def n_edges(self) -> int:
        return len(self.src)

    @staticmethod
    def from_undirected(n_nodes: int, u: np.ndarray, v: np.ndarray, w: np.ndarray) -> "EdgeList":
        """Symmetrize: every undirected {u,v} becomes u->v and v->u."""
        src = np.concatenate([u, v]).astype(np.int32)
        dst = np.concatenate([v, u]).astype(np.int32)
        ww = np.concatenate([w, w]).astype(np.int32)
        return EdgeList(n_nodes, src, dst, ww)

    def sorted_by_dst(self) -> "EdgeList":
        order = np.lexsort((self.src, self.dst))
        return EdgeList(self.n_nodes, self.src[order], self.dst[order], self.weight[order])

    def degrees(self) -> Tuple[np.ndarray, np.ndarray]:
        out = np.bincount(self.src, minlength=self.n_nodes)
        inn = np.bincount(self.dst, minlength=self.n_nodes)
        return out.astype(np.int64), inn.astype(np.int64)

    def remove_self_loops(self) -> "EdgeList":
        keep = self.src != self.dst
        return EdgeList(self.n_nodes, self.src[keep], self.dst[keep], self.weight[keep])

    def coalesce(self) -> "EdgeList":
        """Keep minimum weight among parallel edges."""
        key = self.dst.astype(np.int64) * self.n_nodes + self.src.astype(np.int64)
        order = np.lexsort((self.weight, key))
        key_s = key[order]
        first = np.ones(len(key_s), dtype=bool)
        first[1:] = key_s[1:] != key_s[:-1]
        idx = order[first]
        return EdgeList(self.n_nodes, self.src[idx], self.dst[idx], self.weight[idx])


@dataclass
class DeviceGraph:
    """Device-ready destination-sorted, padded edge arrays.

    Padding edges point from the sentinel source ``n_nodes`` (a phantom node
    whose state is pinned at INF) to destination ``n_nodes`` as well; node
    arrays carry one extra trailing slot for the phantom so no masking is
    needed in the inner relaxation loop.

    ``tile_ptr`` maps node tiles to edge-block ranges for the Pallas kernel:
    tile t owns nodes [t*node_tile, (t+1)*node_tile) and its candidate edges
    live in edge blocks [tile_ptr[t], tile_ptr[t+1]).
    """

    n_nodes: int
    n_edges: int  # real (unpadded) edge count
    src: jnp.ndarray  # int32 [Ep]
    dst: jnp.ndarray  # int32 [Ep]
    weight: jnp.ndarray  # int32 [Ep]
    node_tile: int
    edge_block: int
    tile_ptr: jnp.ndarray  # int32 [n_tiles+1]

    @property
    def n_padded_nodes(self) -> int:
        # +1 phantom slot, rounded up to node_tile
        return next_multiple(self.n_nodes + 1, self.node_tile)

    @property
    def n_tiles(self) -> int:
        return self.n_padded_nodes // self.node_tile

    @staticmethod
    def build(
        edges: EdgeList,
        node_tile: int = 256,
        edge_block: int = 512,
    ) -> "DeviceGraph":
        e = edges.sorted_by_dst()
        n = e.n_nodes
        n_pad_nodes = next_multiple(n + 1, node_tile)
        n_tiles = n_pad_nodes // node_tile

        # Split destination-sorted edges so no edge block straddles a node-tile
        # boundary: pad each tile's edge segment to a multiple of edge_block.
        dst = e.dst
        tile_of_edge = dst // node_tile
        counts = np.bincount(tile_of_edge, minlength=n_tiles).astype(np.int64)
        padded_counts = np.where(counts > 0, ((counts + edge_block - 1) // edge_block) * edge_block, 0)
        total = int(padded_counts.sum())
        total = max(total, edge_block)

        src_p = np.full(total, n, dtype=np.int32)  # phantom source
        dst_p = np.full(total, n, dtype=np.int32)  # phantom destination
        w_p = np.ones(total, dtype=np.int32)

        starts_in = np.concatenate([[0], np.cumsum(counts)])[:-1]
        starts_out = np.concatenate([[0], np.cumsum(padded_counts)])[:-1]
        for t in range(n_tiles):
            c = int(counts[t])
            if c == 0:
                continue
            si, so = int(starts_in[t]), int(starts_out[t])
            src_p[so : so + c] = e.src[si : si + c]
            dst_p[so : so + c] = e.dst[si : si + c]
            w_p[so : so + c] = e.weight[si : si + c]

        tile_ptr = np.zeros(n_tiles + 1, dtype=np.int32)
        tile_ptr[1:] = np.cumsum(padded_counts // edge_block)

        return DeviceGraph(
            n_nodes=n,
            n_edges=e.n_edges,
            src=jnp.asarray(src_p),
            dst=jnp.asarray(dst_p),
            weight=jnp.asarray(w_p),
            node_tile=node_tile,
            edge_block=edge_block,
            tile_ptr=jnp.asarray(tile_ptr),
        )


# ---------------------------------------------------------------------------
# mutable resident edge buffers — MOVED to repro.graph.storage
# ---------------------------------------------------------------------------

# capacity is kept at a multiple of this so the engine's shape-keyed jit
# caches see one program per capacity bucket, not per edge count.
# (Defined here, imported by storage.py: structures must stay importable
# without pulling the storage layer in.)
EDGE_STORE_BUCKET = 256


def __getattr__(name: str):
    # PEP 562 back-compat: ``EdgeStore`` lives in repro.graph.storage now
    # (absorbed into the partition-aware GraphStore layer), but the old
    # ``from repro.graph.structures import EdgeStore`` keeps working.
    # Lazy so structures never imports storage at module load (storage
    # imports structures; eager re-export would be a cycle).
    if name == "EdgeStore":
        from repro.graph.storage import EdgeStore

        return EdgeStore
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def to_scipy_csr(edges: EdgeList):
    """Build a scipy CSR matrix (for oracle shortest paths in tests/quotient)."""
    import scipy.sparse as sp

    return sp.csr_matrix(
        (edges.weight.astype(np.float64), (edges.src, edges.dst)),
        shape=(edges.n_nodes, edges.n_nodes),
    )
