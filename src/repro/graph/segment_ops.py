"""Segment reductions with the paper's tie-break semantics.

A Delta-growing step (paper Section 3) updates node v from edge (u, v) with
  candidate d = d_u + w(u,v), candidate center c = c_u
choosing, per v, the candidate with the *smallest d, then smallest center
index*. We realize this lexicographic argmin with a cascade of segment_min
passes (TPU/int64-free). A third pass carries the realized-path weight
(`pathw`) of the winning candidate, used for exact cluster radii and quotient
edge weights (see DESIGN.md Section 5.2).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

INF = jnp.int32(2**31 - 1)


def _sentinel(x: jnp.ndarray):
    """Dtype-matched masking sentinel for the tie-break passes.

    For int32 this is exactly the engine's INF; for wider integer dtypes
    (the quotient pass coalesces int64 weights) it is the dtype max, so a
    masked-out candidate can never beat a real one.
    """
    if jnp.issubdtype(x.dtype, jnp.floating):
        return jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
    return jnp.asarray(jnp.iinfo(x.dtype).max, x.dtype)


@partial(jax.jit, static_argnames=("num_segments",))
def segment_min_pair(
    cand_d: jnp.ndarray,
    cand_c: jnp.ndarray,
    seg: jnp.ndarray,
    num_segments: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Lexicographic (d, c) segment-min. Returns per-segment (d_min, c_min)."""
    d_min = jax.ops.segment_min(cand_d, seg, num_segments=num_segments)
    is_winner = cand_d == d_min[seg]
    c_masked = jnp.where(is_winner, cand_c, _sentinel(cand_c))
    c_min = jax.ops.segment_min(c_masked, seg, num_segments=num_segments)
    return d_min, c_min


@partial(jax.jit, static_argnames=("num_segments",))
def segment_min_triple(
    cand_d: jnp.ndarray,
    cand_c: jnp.ndarray,
    cand_p: jnp.ndarray,
    seg: jnp.ndarray,
    num_segments: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(d, c, pathw) lexicographic segment-min (three chained passes)."""
    d_min = jax.ops.segment_min(cand_d, seg, num_segments=num_segments)
    w1 = cand_d == d_min[seg]
    c_min = jax.ops.segment_min(
        jnp.where(w1, cand_c, _sentinel(cand_c)), seg, num_segments=num_segments)
    w2 = w1 & (cand_c == c_min[seg])
    p_min = jax.ops.segment_min(
        jnp.where(w2, cand_p, _sentinel(cand_p)), seg, num_segments=num_segments)
    return d_min, c_min, p_min


def relax_candidates(
    d_src: jnp.ndarray,
    w: jnp.ndarray,
    active_src: jnp.ndarray,
    light: jnp.ndarray,
) -> jnp.ndarray:
    """Per-edge candidate distances; INF where the relaxation is inadmissible.

    ``d_src`` values at INF are masked *before* the add, so int32 arithmetic
    never overflows (admissible d_src < Delta <= 2^30 and w < 2^30).
    """
    ok = active_src & light
    return jnp.where(ok, jnp.where(ok, d_src, 0) + w, INF)


@partial(jax.jit, static_argnames=("num_segments", "agg"))
def segment_aggregate(values: jnp.ndarray, seg: jnp.ndarray, num_segments: int, agg: str = "sum"):
    """Shared GNN aggregation entry point (sum/mean/max/min)."""
    if agg == "sum":
        return jax.ops.segment_sum(values, seg, num_segments=num_segments)
    if agg == "mean":
        s = jax.ops.segment_sum(values, seg, num_segments=num_segments)
        ones = jnp.ones(values.shape[:1] + (1,) * (values.ndim - 1), dtype=values.dtype)
        cnt = jax.ops.segment_sum(jnp.broadcast_to(ones, values.shape[:1] + (1,) * (values.ndim - 1)), seg, num_segments=num_segments)
        return s / jnp.maximum(cnt, 1)
    if agg == "max":
        return jax.ops.segment_max(values, seg, num_segments=num_segments)
    if agg == "min":
        return jax.ops.segment_min(values, seg, num_segments=num_segments)
    raise ValueError(f"unknown agg {agg!r}")
