from repro.graph.structures import (
    EdgeList,
    DeviceGraph,
    INF_I32,
    MAX_WEIGHT,
    rescale_weights,
    weight_scale_for,
)
from repro.graph.storage import EdgeStore, GraphStore
from repro.graph.generators import (
    grid_mesh,
    random_geometric,
    random_connected,
    rmat,
    road_like,
    social_like,
    assign_weights,
    temporal_trace,
)
from repro.graph.segment_ops import segment_min_pair, relax_candidates

__all__ = [
    "EdgeList",
    "EdgeStore",
    "GraphStore",
    "DeviceGraph",
    "INF_I32",
    "MAX_WEIGHT",
    "rescale_weights",
    "weight_scale_for",
    "grid_mesh",
    "random_geometric",
    "rmat",
    "road_like",
    "random_connected",
    "social_like",
    "assign_weights",
    "temporal_trace",
    "segment_min_pair",
    "relax_candidates",
]
