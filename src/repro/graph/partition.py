"""Node/edge partitioning for the distributed engine.

Two partitioners:
  - ``range_partition``: contiguous node ranges (baseline).
  - ``cluster_partition``: locality-aware assignment derived from the paper's
    own CLUSTER decomposition — clusters are bin-packed onto devices so most
    edges become device-internal, shrinking the halo/collective term. This is
    the paper's technique reused as a systems feature (DESIGN.md Section 4).

Both return a relabeling permutation ``perm`` (new id -> old id) such that new
node ids are contiguous per device: device d owns [d*Q, (d+1)*Q).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.common import ceil_div
from repro.graph.structures import EdgeList


def range_partition(n_nodes: int, n_devices: int) -> np.ndarray:
    return np.arange(n_nodes, dtype=np.int32)  # identity permutation


def cluster_partition(centers: np.ndarray, n_devices: int) -> np.ndarray:
    """Locality-preserving packing of clusters onto devices.

    ``centers[u]`` = cluster center id of node u (output of the engine).
    Clusters are laid out in center-id order (center ids correlate with
    graph locality for the generators and for BFS/Hilbert-ordered real
    graphs) and devices are filled contiguously to ~n/n_devices, so nodes of
    one cluster never split across devices and NEIGHBORING clusters tend to
    share a device — both cut the halo. Returns perm (new -> old) with
    contiguous per-device ranges.
    """
    n = len(centers)
    cap = ceil_div(n, n_devices)
    uniq, counts = np.unique(centers, return_counts=True)  # sorted by center id
    dev_of_cluster = {}
    load = 0
    dev = 0
    for c, cnt in zip(uniq, counts):
        if load + cnt > cap and dev < n_devices - 1 and load > 0:
            dev += 1
            load = 0
        dev_of_cluster[int(c)] = dev
        load += int(cnt)

    dev_of_node = np.fromiter((dev_of_cluster[int(c)] for c in centers),
                              dtype=np.int64, count=n)
    # stable sort by (device, cluster, id) -> contiguous device ranges with
    # whole clusters kept together
    perm = np.lexsort((np.arange(n), centers, dev_of_node)).astype(np.int32)
    return perm


def apply_partition(edges: EdgeList, perm: np.ndarray) -> Tuple[EdgeList, np.ndarray]:
    """Relabel node ids by ``perm`` (new -> old). Returns (edges', inv_perm)."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm), dtype=np.int32)
    return (
        EdgeList(edges.n_nodes, inv[edges.src], inv[edges.dst], edges.weight),
        inv,
    )


def partition_for_backend(
    edges: EdgeList,
    backend: str,
    n_devices: int,
    centers: np.ndarray = None,
) -> np.ndarray:
    """Backend-aware partition choice (perm, new id -> old id).

    Only the sharded backend pays for edge cuts (halo/collective bytes), so
    it gets the cluster-locality relabeling when a pilot decomposition's
    ``centers`` is available; the single-device and Pallas backends keep the
    identity ordering (their dst-sorted layouts are already locality-friendly
    and relabeling would only churn the quotient ids).
    """
    if backend != "sharded" or n_devices <= 1 or centers is None:
        return range_partition(edges.n_nodes, n_devices)
    return cluster_partition(centers, n_devices)


def cut_fraction(edges: EdgeList, n_devices: int) -> float:
    """Fraction of edges crossing device boundaries under contiguous ranges."""
    q = ceil_div(edges.n_nodes, n_devices)
    cross = (edges.src // q) != (edges.dst // q)
    return float(cross.mean()) if edges.n_edges else 0.0
