"""Node/edge partitioning for the distributed engine.

Two partitioners:
  - ``range_partition``: contiguous node ranges (baseline).
  - ``cluster_partition``: locality-aware assignment derived from the paper's
    own CLUSTER decomposition — clusters are bin-packed onto devices so most
    edges become device-internal, shrinking the halo/collective term. This is
    the paper's technique reused as a systems feature (DESIGN.md Section 4).

Both return a relabeling permutation ``perm`` (new id -> old id) such that new
node ids are contiguous per device: device d owns [d*Q, (d+1)*Q).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.common import ceil_div
from repro.graph.structures import EdgeList


def range_partition(n_nodes: int, n_devices: int) -> np.ndarray:
    return np.arange(n_nodes, dtype=np.int32)  # identity permutation


def _contiguous_fill(counts: np.ndarray, n_devices: int) -> np.ndarray:
    """Balanced CONTIGUOUS segmentation of the cluster-size sequence:
    clusters (in center-id order) accumulate onto device d until its load
    reaches the d-th balanced threshold ``total * (d+1) / P``. Max load is
    bounded by ``total/P + max_cluster - 1`` — unlike the old count-based
    fill, which dumped the whole size skew onto the last device."""
    total = int(counts.sum())
    thresholds = (total * (np.arange(1, n_devices + 1))) // n_devices
    cum = np.cumsum(counts)
    # device of cluster i = number of thresholds strictly below cum[i-1]
    # (i.e. the segment whose threshold cum[i] first reaches)
    return np.searchsorted(thresholds, cum, side="left").clip(
        max=n_devices - 1).astype(np.int64)


def _lpt_fill(counts: np.ndarray, n_devices: int) -> np.ndarray:
    """Greedy largest-first (LPT) bin packing: clusters sorted by size
    descending, each placed on the currently least-loaded device. Max load
    within 4/3 of optimal; ties (equal sizes, equal loads) break
    deterministically by center id / device id."""
    order = np.argsort(-counts, kind="stable")  # largest first, ties by id
    loads = np.zeros(n_devices, dtype=np.int64)
    dev = np.zeros(len(counts), dtype=np.int64)
    for ci in order:
        d = int(np.argmin(loads))  # least-loaded; lowest id on ties
        dev[ci] = d
        loads[d] += int(counts[ci])
    return dev


def _max_load(counts: np.ndarray, dev: np.ndarray, n_devices: int) -> int:
    return int(np.bincount(dev, weights=counts,
                           minlength=n_devices).max()) if len(counts) else 0


def cluster_partition(centers: np.ndarray, n_devices: int,
                      imbalance_tolerance: float = 1.5) -> np.ndarray:
    """Locality-preserving, load-balanced packing of clusters onto devices.

    ``centers[u]`` = cluster center id of node u (output of the engine).
    Nodes of one cluster never split across devices. Two deterministic
    packers, picked by measured load:

      1. balanced contiguous fill (default): clusters stay in center-id
         order — center ids correlate with graph locality for the
         generators and for BFS/Hilbert-ordered real graphs, so
         neighboring clusters share a device and the edge cut stays low —
         with device boundaries placed at balanced LOAD thresholds
         (max load <= total/P + largest cluster).
      2. greedy largest-first bin packing (LPT): engaged only when the
         size distribution is so skewed that contiguity costs real
         balance (contiguous max load > ``imbalance_tolerance`` x the LPT
         max load); sacrifices adjacency for the 4/3-of-optimal bound.

    Both choices and all tie-breaks are deterministic functions of
    ``centers``, so the permutation is replayable. Returns perm
    (new -> old) with contiguous per-device node ranges.
    """
    n = len(centers)
    centers = np.asarray(centers)
    uniq, inv_idx, counts = np.unique(centers, return_inverse=True,
                                      return_counts=True)
    dev_of_cluster = _contiguous_fill(counts, n_devices)
    lpt = _lpt_fill(counts, n_devices)
    if (_max_load(counts, dev_of_cluster, n_devices)
            > imbalance_tolerance * max(_max_load(counts, lpt, n_devices), 1)):
        dev_of_cluster = lpt

    dev_of_node = dev_of_cluster[inv_idx]
    # stable sort by (device, cluster, id) -> contiguous device ranges with
    # whole clusters kept together
    perm = np.lexsort((np.arange(n), centers, dev_of_node)).astype(np.int32)
    return perm


def apply_partition(edges: EdgeList, perm: np.ndarray) -> Tuple[EdgeList, np.ndarray]:
    """Relabel node ids by ``perm`` (new -> old). Returns (edges', inv_perm)."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm), dtype=np.int32)
    return (
        EdgeList(edges.n_nodes, inv[edges.src], inv[edges.dst], edges.weight),
        inv,
    )


def partition_for_backend(
    edges: EdgeList,
    backend: str,
    n_devices: int,
    centers: np.ndarray = None,
) -> np.ndarray:
    """Backend-aware partition choice (perm, new id -> old id).

    Only the sharded backend pays for edge cuts (halo/collective bytes), so
    it gets the cluster-locality relabeling when a pilot decomposition's
    ``centers`` is available; the single-device and Pallas backends keep the
    identity ordering (their dst-sorted layouts are already locality-friendly
    and relabeling would only churn the quotient ids).
    """
    if backend != "sharded" or n_devices <= 1 or centers is None:
        return range_partition(edges.n_nodes, n_devices)
    return cluster_partition(centers, n_devices)


def cut_fraction(edges: EdgeList, n_devices: int) -> float:
    """Fraction of edges crossing device boundaries under contiguous ranges."""
    q = ceil_div(edges.n_nodes, n_devices)
    cross = (edges.src // q) != (edges.dst // q)
    return float(cross.mean()) if edges.n_edges else 0.0
