"""Synthetic graph generators mirroring the paper's benchmark families.

The paper evaluates on (a) DIMACS road networks (high diameter, low density,
weights = travel times), (b) SNAP social networks with synthetic weights
(lj-uniform: uniform in [1, 2^26]), and (c) a 1024x1024 square mesh with
bimodal weights (1e6 w.p. 0.1 else 1) for the Delta-sensitivity experiment.
Offline we reproduce each *family* with seeded generators at configurable
scale; DESIGN.md records this substitution.

``temporal_trace`` extends the families into the DYNAMIC workload class:
seeded batches of insert / reweight / delete events over an existing
``EdgeList``, the one trace source shared by ``benchmarks/kernel_bench.py``
(the "dynamic" block), ``launch/serve.py --update-trace`` replay, and
``tests/test_dynamic.py``.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.graph.structures import EdgeList, MAX_WEIGHT


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def assign_weights(
    n_edges: int,
    dist: str = "uniform",
    seed: int = 0,
    low: int = 1,
    high: int = 2**26,
    sigma: float = 2.0,
    mu: float = 1.0,
    heavy_w: int = 10**6,
    heavy_p: float = 0.1,
) -> np.ndarray:
    """Weight distributions used across the paper's experiments.

    - "uniform": U[low, high]       (lj-uniform, paper Table 1)
    - "normal":  |N(mu, sigma)| symmetrized around mu, >= 1 (paper Table 4)
    - "bimodal": heavy_w w.p. heavy_p else 1 (paper's Delta-init mesh exp.)
    - "unit":    all ones (sigma = 0 row of Table 4)
    """
    r = _rng(seed)
    if dist == "uniform":
        w = r.integers(low, high + 1, size=n_edges)
    elif dist == "normal":
        # symmetrized around mu so weights stay >= 1 (paper Section 5)
        w = np.abs(r.normal(0.0, sigma, size=n_edges)) + mu
        w = np.maximum(np.rint(w), 1.0)
    elif dist == "bimodal":
        w = np.where(r.random(n_edges) < heavy_p, heavy_w, 1)
    elif dist == "unit":
        w = np.ones(n_edges)
    else:
        raise ValueError(f"unknown weight dist {dist!r}")
    return np.clip(w, 1, int(MAX_WEIGHT)).astype(np.int32)


def grid_mesh(side: int, weight_dist: str = "unit", seed: int = 0, **wkw) -> EdgeList:
    """side x side square mesh (paper's Delta experiment topology)."""
    n = side * side
    ids = np.arange(n, dtype=np.int32).reshape(side, side)
    # horizontal + vertical undirected edges
    hu, hv = ids[:, :-1].ravel(), ids[:, 1:].ravel()
    vu, vv = ids[:-1, :].ravel(), ids[1:, :].ravel()
    u = np.concatenate([hu, vu])
    v = np.concatenate([hv, vv])
    w = assign_weights(len(u), weight_dist, seed, **wkw)
    return EdgeList.from_undirected(n, u, v, w)


def random_geometric(n: int, avg_degree: float = 3.0, seed: int = 0, weight_scale: int = 10_000) -> EdgeList:
    """Road-network-like graph: random points, k-nearest-style local edges,
    weights proportional to euclidean distance (like travel times)."""
    r = _rng(seed)
    pts = r.random((n, 2))
    # grid-bucket neighbor search to stay O(n)
    k = max(2, int(round(avg_degree)))
    cell = int(np.sqrt(n / 4)) + 1
    gx = np.minimum((pts[:, 0] * cell).astype(np.int64), cell - 1)
    gy = np.minimum((pts[:, 1] * cell).astype(np.int64), cell - 1)
    bucket = gx * cell + gy
    order = np.argsort(bucket, kind="stable")
    us, vs = [], []
    # connect each point to the next k points in bucket-sorted order (approx
    # spatial locality) + a chain to guarantee connectivity
    for off in range(1, k + 1):
        us.append(order[:-off])
        vs.append(order[off:])
    u = np.concatenate(us).astype(np.int32)
    v = np.concatenate(vs).astype(np.int32)
    d = np.sqrt(((pts[u] - pts[v]) ** 2).sum(axis=1))
    w = np.maximum((d * weight_scale).astype(np.int64), 1).astype(np.int32)
    return EdgeList.from_undirected(n, u, v, w).remove_self_loops().coalesce()


def road_like(n: int, seed: int = 0) -> EdgeList:
    """Alias with road-network-ish defaults (avg degree ~2.5, distance weights)."""
    return random_geometric(n, avg_degree=3.0, seed=seed)


def rmat(
    n_log2: int,
    n_edges: int,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    weight_dist: str = "uniform",
    **wkw,
) -> EdgeList:
    """RMAT power-law generator (social-network-like; livejournal/orkut family)."""
    r = _rng(seed)
    n = 1 << n_log2
    u = np.zeros(n_edges, dtype=np.int64)
    v = np.zeros(n_edges, dtype=np.int64)
    for level in range(n_log2):
        p = r.random(n_edges)
        # quadrant choice: a | b | c | d
        right = p >= a + b  # goes to bottom half for u
        down_v = ((p >= a) & (p < a + b)) | (p >= a + b + c)
        u = (u << 1) | right.astype(np.int64)
        v = (v << 1) | down_v.astype(np.int64)
    # guarantee connectivity with a random chain through all touched nodes
    perm = r.permutation(n)
    u = np.concatenate([u, perm[:-1]])
    v = np.concatenate([v, perm[1:]])
    w = assign_weights(len(u), weight_dist, seed + 1, **wkw)
    return (
        EdgeList.from_undirected(n, u.astype(np.int32), v.astype(np.int32), w)
        .remove_self_loops()
        .coalesce()
    )


def social_like(n_log2: int = 14, edge_factor: int = 8, seed: int = 0, **wkw) -> EdgeList:
    return rmat(n_log2, (1 << n_log2) * edge_factor, seed=seed, **wkw)


def temporal_trace(
    edges: EdgeList,
    n_batches: int,
    *,
    events_per_batch: int = 64,
    p_insert: float = 0.4,
    p_reweight: float = 0.4,
    p_delete: float = 0.2,
    insert_mode: str = "local",
    seed: int = 0,
) -> List:
    """Seeded update-trace generator: ``n_batches`` batches of
    insert/reweight/delete events over an evolving copy of ``edges``.

    The trace is simulated on the host so every event is VALID at its
    position in the stream (reweights/deletes name edges that exist then;
    a key is mutated at most once per batch), and SYMMETRIC — the graphs
    here store both directions of each undirected edge, so every event is
    emitted for both. Weights are drawn uniformly from the input graph's
    own [min, max] weight range, keeping the trace inside the family's
    distribution.

    ``insert_mode="local"`` splices new edges between endpoints of two
    existing edges (the 2-hop locality of real network churn — road works,
    social triangle closure); ``"random"`` draws uniform endpoint pairs
    (long-range shortcuts, the adversarial case for incremental repair).

    Returns a list of ``repro.core.dynamic.UpdateBatch``.
    """
    from repro.core.dynamic import UpdateBatch  # deferred: graph <- core cycle

    if n_batches < 0:
        raise ValueError(f"n_batches must be >= 0, got {n_batches}")
    if insert_mode not in ("local", "random"):
        raise ValueError(f"insert_mode must be local|random, got {insert_mode!r}")
    p_total = p_insert + p_reweight + p_delete
    if p_total <= 0:
        raise ValueError("at least one event probability must be positive")
    n = edges.n_nodes
    if n < 2:
        raise ValueError("temporal_trace needs a graph with >= 2 nodes")
    r = _rng(seed)
    # deletes/reweights are emitted for BOTH directions, so only pairs
    # present in both are eligible (every generator family symmetrizes;
    # one-directional strays just never get picked)
    fwd = {(int(u), int(v)) for u, v in zip(edges.src, edges.dst) if u < v}
    bwd = {(int(v), int(u)) for u, v in zip(edges.src, edges.dst) if u > v}
    wmap = {}
    for u, v, w in zip(edges.src, edges.dst, edges.weight):
        key = (int(u), int(v)) if u < v else (int(v), int(u))
        wmap[key] = min(int(w), wmap.get(key, int(w)))
    live = {k: wmap[k] for k in fwd & bwd}
    w_lo = int(edges.weight.min()) if edges.n_edges else 1
    w_hi = int(edges.weight.max()) if edges.n_edges else 1

    def draw_w(k):
        # inclusive of the graph's own [min, max] range, never beyond it
        # (w_lo == w_hi collapses to the constant weight)
        return r.integers(w_lo, w_hi + 1, size=k).astype(np.int64)

    batches: List = []
    for _ in range(n_batches):
        keys = list(live)
        mutated = set()
        ins, rw, dl = [], [], []
        kinds = r.choice(3, size=events_per_batch,
                         p=np.array([p_insert, p_reweight, p_delete]) / p_total)
        for kind in kinds:
            if kind == 0:
                for _try in range(32):
                    if insert_mode == "local" and keys:
                        a = keys[int(r.integers(len(keys)))]
                        b = keys[int(r.integers(len(keys)))]
                        u, v = a[int(r.integers(2))], b[int(r.integers(2))]
                    else:
                        u, v = map(int, r.integers(0, n, 2))
                    u, v = (u, v) if u < v else (v, u)
                    if u != v and (u, v) not in live and (u, v) not in mutated:
                        w = int(draw_w(1)[0])
                        live[(u, v)] = w
                        mutated.add((u, v))
                        ins.append((u, v, w))
                        break
            elif not keys:
                continue
            else:
                for _try in range(32):
                    key = keys[int(r.integers(len(keys)))]
                    if key in mutated or key not in live:
                        continue
                    mutated.add(key)
                    if kind == 1:
                        w = int(draw_w(1)[0])
                        live[key] = w
                        rw.append((*key, w))
                    else:
                        del live[key]
                        dl.append(key)
                    break
        batches.append(UpdateBatch.merge([
            UpdateBatch.inserts([e[0] for e in ins], [e[1] for e in ins],
                                [e[2] for e in ins]),
            UpdateBatch.reweights([e[0] for e in rw], [e[1] for e in rw],
                                  [e[2] for e in rw]),
            UpdateBatch.deletes([e[0] for e in dl], [e[1] for e in dl]),
        ]))
    return batches


def random_connected(n: int, n_edges: int, seed: int = 0, weight_dist: str = "uniform", **wkw) -> EdgeList:
    """Uniform random connected multigraph (for property tests)."""
    r = _rng(seed)
    perm = r.permutation(n)
    cu = perm[:-1].astype(np.int64)
    cv = perm[1:].astype(np.int64)
    extra = max(0, n_edges - (n - 1))
    eu = r.integers(0, n, size=extra)
    ev = r.integers(0, n, size=extra)
    u = np.concatenate([cu, eu])
    v = np.concatenate([cv, ev])
    keep = u != v
    u, v = u[keep], v[keep]
    w = assign_weights(len(u), weight_dist, seed + 7, **wkw)
    return EdgeList.from_undirected(n, u.astype(np.int32), v.astype(np.int32), w).coalesce()
