"""Synthetic graph generators mirroring the paper's benchmark families.

The paper evaluates on (a) DIMACS road networks (high diameter, low density,
weights = travel times), (b) SNAP social networks with synthetic weights
(lj-uniform: uniform in [1, 2^26]), and (c) a 1024x1024 square mesh with
bimodal weights (1e6 w.p. 0.1 else 1) for the Delta-sensitivity experiment.
Offline we reproduce each *family* with seeded generators at configurable
scale; DESIGN.md records this substitution.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graph.structures import EdgeList, MAX_WEIGHT


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def assign_weights(
    n_edges: int,
    dist: str = "uniform",
    seed: int = 0,
    low: int = 1,
    high: int = 2**26,
    sigma: float = 2.0,
    mu: float = 1.0,
    heavy_w: int = 10**6,
    heavy_p: float = 0.1,
) -> np.ndarray:
    """Weight distributions used across the paper's experiments.

    - "uniform": U[low, high]       (lj-uniform, paper Table 1)
    - "normal":  |N(mu, sigma)| symmetrized around mu, >= 1 (paper Table 4)
    - "bimodal": heavy_w w.p. heavy_p else 1 (paper's Delta-init mesh exp.)
    - "unit":    all ones (sigma = 0 row of Table 4)
    """
    r = _rng(seed)
    if dist == "uniform":
        w = r.integers(low, high + 1, size=n_edges)
    elif dist == "normal":
        # symmetrized around mu so weights stay >= 1 (paper Section 5)
        w = np.abs(r.normal(0.0, sigma, size=n_edges)) + mu
        w = np.maximum(np.rint(w), 1.0)
    elif dist == "bimodal":
        w = np.where(r.random(n_edges) < heavy_p, heavy_w, 1)
    elif dist == "unit":
        w = np.ones(n_edges)
    else:
        raise ValueError(f"unknown weight dist {dist!r}")
    return np.clip(w, 1, int(MAX_WEIGHT)).astype(np.int32)


def grid_mesh(side: int, weight_dist: str = "unit", seed: int = 0, **wkw) -> EdgeList:
    """side x side square mesh (paper's Delta experiment topology)."""
    n = side * side
    ids = np.arange(n, dtype=np.int32).reshape(side, side)
    # horizontal + vertical undirected edges
    hu, hv = ids[:, :-1].ravel(), ids[:, 1:].ravel()
    vu, vv = ids[:-1, :].ravel(), ids[1:, :].ravel()
    u = np.concatenate([hu, vu])
    v = np.concatenate([hv, vv])
    w = assign_weights(len(u), weight_dist, seed, **wkw)
    return EdgeList.from_undirected(n, u, v, w)


def random_geometric(n: int, avg_degree: float = 3.0, seed: int = 0, weight_scale: int = 10_000) -> EdgeList:
    """Road-network-like graph: random points, k-nearest-style local edges,
    weights proportional to euclidean distance (like travel times)."""
    r = _rng(seed)
    pts = r.random((n, 2))
    # grid-bucket neighbor search to stay O(n)
    k = max(2, int(round(avg_degree)))
    cell = int(np.sqrt(n / 4)) + 1
    gx = np.minimum((pts[:, 0] * cell).astype(np.int64), cell - 1)
    gy = np.minimum((pts[:, 1] * cell).astype(np.int64), cell - 1)
    bucket = gx * cell + gy
    order = np.argsort(bucket, kind="stable")
    us, vs = [], []
    # connect each point to the next k points in bucket-sorted order (approx
    # spatial locality) + a chain to guarantee connectivity
    for off in range(1, k + 1):
        us.append(order[:-off])
        vs.append(order[off:])
    u = np.concatenate(us).astype(np.int32)
    v = np.concatenate(vs).astype(np.int32)
    d = np.sqrt(((pts[u] - pts[v]) ** 2).sum(axis=1))
    w = np.maximum((d * weight_scale).astype(np.int64), 1).astype(np.int32)
    return EdgeList.from_undirected(n, u, v, w).remove_self_loops().coalesce()


def road_like(n: int, seed: int = 0) -> EdgeList:
    """Alias with road-network-ish defaults (avg degree ~2.5, distance weights)."""
    return random_geometric(n, avg_degree=3.0, seed=seed)


def rmat(
    n_log2: int,
    n_edges: int,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    weight_dist: str = "uniform",
    **wkw,
) -> EdgeList:
    """RMAT power-law generator (social-network-like; livejournal/orkut family)."""
    r = _rng(seed)
    n = 1 << n_log2
    u = np.zeros(n_edges, dtype=np.int64)
    v = np.zeros(n_edges, dtype=np.int64)
    for level in range(n_log2):
        p = r.random(n_edges)
        # quadrant choice: a | b | c | d
        right = p >= a + b  # goes to bottom half for u
        down_v = ((p >= a) & (p < a + b)) | (p >= a + b + c)
        u = (u << 1) | right.astype(np.int64)
        v = (v << 1) | down_v.astype(np.int64)
    # guarantee connectivity with a random chain through all touched nodes
    perm = r.permutation(n)
    u = np.concatenate([u, perm[:-1]])
    v = np.concatenate([v, perm[1:]])
    w = assign_weights(len(u), weight_dist, seed + 1, **wkw)
    return (
        EdgeList.from_undirected(n, u.astype(np.int32), v.astype(np.int32), w)
        .remove_self_loops()
        .coalesce()
    )


def social_like(n_log2: int = 14, edge_factor: int = 8, seed: int = 0, **wkw) -> EdgeList:
    return rmat(n_log2, (1 << n_log2) * edge_factor, seed=seed, **wkw)


def random_connected(n: int, n_edges: int, seed: int = 0, weight_dist: str = "uniform", **wkw) -> EdgeList:
    """Uniform random connected multigraph (for property tests)."""
    r = _rng(seed)
    perm = r.permutation(n)
    cu = perm[:-1].astype(np.int64)
    cv = perm[1:].astype(np.int64)
    extra = max(0, n_edges - (n - 1))
    eu = r.integers(0, n, size=extra)
    ev = r.integers(0, n, size=extra)
    u = np.concatenate([cu, eu])
    v = np.concatenate([cv, ev])
    keep = u != v
    u, v = u[keep], v[keep]
    w = assign_weights(len(u), weight_dist, seed + 7, **wkw)
    return EdgeList.from_undirected(n, u.astype(np.int32), v.astype(np.int32), w).coalesce()
