"""Neighbor sampler for minibatch GNN training (minibatch_lg regime).

Layer-wise fanout sampling (GraphSAGE style): given seed nodes, sample up to
``fanout[l]`` in-neighbors per node per layer, building a block-bipartite
subgraph per layer. Host-side numpy (the data-pipeline tier); outputs are
fixed-shape padded arrays so the jitted train step never recompiles.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.graph.structures import EdgeList


@dataclass
class SampledBlock:
    """One message-passing layer block: edges from src_ids -> dst slots."""

    src_index: np.ndarray  # int32 [E_pad] indices into the layer's node table
    dst_index: np.ndarray  # int32 [E_pad] indices into the next layer's node table
    edge_mask: np.ndarray  # bool  [E_pad]
    n_dst: int


@dataclass
class SampledBatch:
    node_ids: np.ndarray  # int32 [N_pad] global ids of all nodes involved
    node_mask: np.ndarray  # bool [N_pad]
    blocks: List[SampledBlock]
    seed_slots: np.ndarray  # int32 [B] positions of the seed nodes in node_ids


class NeighborSampler:
    def __init__(self, edges: EdgeList, fanout: Sequence[int], seed: int = 0):
        e = edges.sorted_by_dst()
        self.n = e.n_nodes
        self.fanout = tuple(fanout)
        # CSR over incoming edges
        self.indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(self.indptr, e.dst + 1, 1)
        np.cumsum(self.indptr, out=self.indptr)
        self.srcs = e.src
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> SampledBatch:
        seeds = np.asarray(seeds, dtype=np.int32)
        frontier = seeds
        layers_nodes = [seeds]
        raw_blocks: List[Tuple[np.ndarray, np.ndarray]] = []  # (src_gid, dst_gid)
        for f in self.fanout:
            lo = self.indptr[frontier]
            hi = self.indptr[frontier + 1]
            deg = (hi - lo).astype(np.int64)
            k = np.minimum(deg, f)
            # sample k[i] neighbors for node i (with replacement when deg>f
            # would need rejection; replacement is standard for SAGE)
            total = int(k.sum())
            dst_rep = np.repeat(frontier, k)
            base = np.repeat(lo, k)
            offs = (self.rng.random(total) * np.repeat(np.maximum(deg, 1), k)).astype(np.int64)
            src_g = self.srcs[base + offs]
            raw_blocks.append((src_g.astype(np.int32), dst_rep.astype(np.int32)))
            frontier = np.unique(src_g).astype(np.int32)
            layers_nodes.append(frontier)

        all_nodes = np.unique(np.concatenate(layers_nodes)).astype(np.int32)
        lookup = {int(g): i for i, g in enumerate(all_nodes)}
        remap = np.vectorize(lookup.__getitem__, otypes=[np.int32])

        n_pad = _next_pow2(len(all_nodes))
        node_ids = np.zeros(n_pad, dtype=np.int32)
        node_ids[: len(all_nodes)] = all_nodes
        node_mask = np.zeros(n_pad, dtype=bool)
        node_mask[: len(all_nodes)] = True

        blocks = []
        max_e = max((len(s) for s, _ in raw_blocks), default=1)
        e_pad = _next_pow2(max_e)
        # reverse: blocks are applied deepest-first
        for src_g, dst_g in reversed(raw_blocks):
            si = np.zeros(e_pad, dtype=np.int32)
            di = np.zeros(e_pad, dtype=np.int32)
            m = np.zeros(e_pad, dtype=bool)
            if len(src_g):
                si[: len(src_g)] = remap(src_g)
                di[: len(dst_g)] = remap(dst_g)
                m[: len(src_g)] = True
            blocks.append(SampledBlock(si, di, m, n_dst=n_pad))

        seed_slots = remap(seeds)
        return SampledBatch(node_ids, node_mask, blocks, seed_slots)


def _next_pow2(x: int) -> int:
    p = 1
    while p < max(x, 1):
        p <<= 1
    return p
